"""Elastic runtime tests: resharder, expert placement, controller, data
pipeline, checkpoint+restore-with-rescale, optimizer."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import cep
from repro.data import pipeline as dp
from repro.elastic import controller as ec
from repro.elastic import expert_place as ep
from repro.elastic import resharder as rs
from repro.elastic.rescale_exec import ElasticRescaler, ProgramCache
from repro.train import optimizer as O


# ------------------------------------------------------------------ resharder
def test_apply_reshard_preserves_data_and_moves_minimum():
    n = 10_000
    rng = np.random.default_rng(0)
    flat = rng.standard_normal(n).astype(np.float32)
    k_old, k_new = 8, 9
    old = [rs.gather_host_shard(flat, k_old, h) for h in range(k_old)]
    new, moved = rs.apply_reshard(old, n, k_old, k_new)
    rebuilt = np.concatenate(new)
    np.testing.assert_array_equal(rebuilt, flat)
    assert moved == cep.migrated_edges_exact(n, k_old, k_new)
    assert moved < n * k_old / (k_old + 1)  # beats hash-based reshuffle


def test_reshard_plan_summary():
    plan = rs.plan_reshard({"w": ((1024, 1024), 4), "b": ((1024,), 4)}, 16, 17)
    s = plan.summary()
    assert 0 < s["moved_frac"] < 0.6
    assert s["moved_frac"] < s["random_frac"]


# ------------------------------------------------------- expert placement
def test_expert_placement_reduces_cross_group_traffic():
    rng = np.random.default_rng(1)
    e = 32
    # Two co-activation communities of 16 experts each.
    stats = rng.random((e, e)) * 0.1
    stats[:16, :16] += 5.0
    stats[16:, 16:] += 5.0
    stats = (stats + stats.T) / 2
    np.fill_diagonal(stats, 0)
    order = ep.order_experts(stats)
    assert sorted(order.tolist()) == list(range(e))
    placed = ep.ExpertPlacement(order, k_groups=2)
    naive = ep.ExpertPlacement(np.arange(e), k_groups=2)
    rng2 = np.random.default_rng(2)
    shuffled = ep.ExpertPlacement(rng2.permutation(e), k_groups=2)
    t_placed = ep.cross_group_traffic(stats, placed)
    t_shuffled = ep.cross_group_traffic(stats, shuffled)
    assert t_placed < 0.7 * t_shuffled
    # Elastic EP resize: O(1) plan, bounded movement.
    placed2, moved = placed.rescale(3)
    assert placed2.k_groups == 3 and 0 < moved <= e


def test_coactivation_graph_from_routing_trace():
    rng = np.random.default_rng(3)
    ids = rng.integers(0, 16, size=(500, 2))
    g = ep.coactivation_graph(ids, 16)
    assert g.num_vertices == 16 and g.num_edges > 0


# ----------------------------------------------------------------- controller
def test_controller_detects_preemption_and_straggler():
    t = [0.0]
    ctl = ec.ElasticController(4, dead_after_s=5.0, straggler_lag_steps=10, clock=lambda: t[0])
    for h in range(4):
        ctl.heartbeat(h, step=100)
    t[0] = 4.0
    for h in range(3):  # host 3 goes silent (spot preemption)
        ctl.heartbeat(h, step=110)
    t[0] = 7.0
    ev = ctl.poll()
    assert ev and ev.kind == "scale_in" and ev.lost_hosts == (3,) and ctl.k == 3
    assert 0 < ev.plan_edges_moved_frac < 1
    # Straggler: host 2 stops progressing.
    for step in (150, 200):
        for h in (0, 1):
            ctl.heartbeat(h, step)
        ctl.heartbeat(2, 111)
        t[0] += 1.0
    ev2 = ctl.poll()
    assert ev2 and ev2.kind == "straggler" and 2 in ev2.lost_hosts
    ev3 = ctl.add_hosts(2)
    assert ev3.kind == "scale_out" and ctl.k == 4
    # Interleaved event logs are ordered by one monotonic seq (frozen events
    # can't rely on wall-clock: the test clock above never moves during polls).
    assert (ev.seq, ev2.seq, ev3.seq) == (0, 1, 2)
    assert [e.seq for e in ctl.events] == [0, 1, 2]


def test_scale_event_seq_is_monotonic_across_controllers_and_kinds():
    t = [0.0]
    ctl = ec.ElasticController(3, dead_after_s=5.0, clock=lambda: t[0])
    events = [ctl.add_hosts(1), ctl.add_hosts(2)]
    t[0] = 1.0
    for h in range(4):
        ctl.heartbeat(h, 1)  # hosts 4, 5 never beat
    t[0] = 6.0
    events.append(ctl.poll())
    assert all(e is not None for e in events)
    seqs = [e.seq for e in events]
    assert seqs == [0, 1, 2] and [e.seq for e in ctl.events] == seqs
    # A fresh controller restarts its own counter (per-log ordering).
    assert ec.ElasticController(2).add_hosts(1).seq == 0


def test_mark_event_rate_gauge_runs_on_injected_clock():
    # Regression: _mark_event used to read time.perf_counter() directly, so
    # the events/s gauge — the autoscaler's rate signal — could not be driven
    # by a fake clock and disagreed with heartbeat/poll liveness timing.
    from repro.obs import metrics as OM

    t = [0.0]
    reg = OM.MetricsRegistry()
    ctl = ec.ElasticController(2, clock=lambda: t[0], metrics_registry=reg)
    gauge = reg.gauge("controller.events_per_s")
    ctl.add_hosts(1)
    assert gauge.value == 0.0  # one event: no inter-event interval yet
    t[0] = 2.0  # exactly 0.5 events/s on the FAKE timeline
    ctl.add_hosts(1)
    assert gauge.value == pytest.approx(0.5)
    t[0] = 2.5  # 2 events/s raw → EMA 0.8*0.5 + 0.2*2.0
    ctl.add_hosts(1)
    assert gauge.value == pytest.approx(0.8 * 0.5 + 0.2 * 2.0)
    # A frozen clock between events leaves the gauge untouched (dt == 0).
    before = gauge.value
    ctl.add_hosts(1)
    assert gauge.value == before


def test_poll_eviction_clamps_at_k_min_floor():
    # Regression: evicting every laggard in one poll could drive k to 0 and
    # emit a scale plan to zero partitions. The floor keeps the most recently
    # beating hosts alive and surfaces the clamp in the event reason.
    t = [0.0]
    ctl = ec.ElasticController(4, dead_after_s=5.0, clock=lambda: t[0], k_min=2)
    ctl.heartbeat(2, step=1)
    t[0] = 1.0
    ctl.heartbeat(3, step=1)  # host 3 beat most recently, then 2, then 0/1
    t[0] = 10.0  # ALL hosts are now past dead_after_s
    ev = ctl.poll()
    assert ev is not None and ev.kind == "scale_in"
    assert ctl.k == 2  # floor held: k never reached 0
    assert set(ev.lost_hosts) == {0, 1}  # stalest evicted, freshest retained
    assert ctl.hosts[2].alive and ctl.hosts[3].alive
    assert "clamped at k_min=2" in ev.reason and "[2, 3]" in ev.reason
    # When the floor retains EVERY candidate there is no event at all.
    ctl2 = ec.ElasticController(1, dead_after_s=5.0, clock=lambda: t[0], k_min=1)
    t[0] += 10.0  # the lone host goes dark — but it IS the floor
    assert ctl2.poll() is None and ctl2.k == 1
    with pytest.raises(ValueError):
        ec.ElasticController(2, k_min=0)


# ---------------------------------------------------------------- ProgramCache
# The LRU is load-bearing for three program families (rescale migration,
# ingest scatter, streaming compact) — unit-test the container itself, not
# just the end-to-end eviction behavior of test_rescale_exec.py.
def test_program_cache_lru_eviction_order():
    c = ProgramCache(2)
    c.put("a", 1)
    c.put("b", 2)
    assert list(c) == ["a", "b"]  # least- to most-recently used
    assert c.get("a") == 1  # hit refreshes recency …
    assert list(c) == ["b", "a"]
    c.put("c", 3)  # … so "b", not "a", is the victim
    assert list(c) == ["a", "c"] and "b" not in c
    assert c.get("b") is None and len(c) == 2


def test_program_cache_capacity_one_thrash():
    c = ProgramCache(1)
    for i in range(5):
        c.put(("k", i), i)
        assert len(c) == 1 and c.get(("k", i)) == i
        if i:
            assert ("k", i - 1) not in c  # every put evicts the previous entry
    # Re-putting the resident key must not evict it.
    c.put(("k", 4), 40)
    assert len(c) == 1 and c.get(("k", 4)) == 40


def test_program_cache_kind_prefixed_keys_do_not_collide():
    """StreamingEngine keys scatter/compact programs by a kind prefix over
    otherwise-identical shape signatures; one cache must hold all kinds and a
    hit on one kind must not serve (or evict) another."""
    c = ProgramCache(3)
    sig = (8, 128, 4)  # same static shape signature for every family
    c.put(("migrate",) + sig, "m")
    c.put(("scatter",) + sig, "s")
    c.put(("compact",) + sig, "c")
    assert len(c) == 3
    assert c.get(("scatter",) + sig) == "s"
    assert c.get(("migrate",) + sig) == "m"
    assert c.get(("compact",) + sig) == "c"
    # Capacity pressure evicts by recency across kinds, not by kind.
    c.put(("migrate",) + (9, 128, 4), "m2")
    assert ("scatter",) + sig not in c  # LRU victim was the scatter entry
    assert c.get(("migrate",) + sig) == "m" and c.get(("compact",) + sig) == "c"


def test_program_cache_resize_has_no_stale_reuse():
    """Changing program_cache_size means a NEW rescaler/cache: programs traced
    under the old capacity must not leak into the new instance, and the new
    capacity is enforced from the first put."""
    src = np.arange(64, dtype=np.int64)
    dst = (src + 1) % 64
    from repro.graphs import engine as E

    r1 = ElasticRescaler(program_cache_size=4)
    for k_new in (5, 6, 7):
        r1.rescale(E.pack_ordered(src, dst, 64, 4), k_new)
    assert len(r1._programs) == 3 and r1.program_cache_size == 4

    r2 = ElasticRescaler(program_cache_size=1)
    assert len(r2._programs) == 0  # nothing carried over from r1
    d2, _ = r2.rescale(E.pack_ordered(src, dst, 64, 4), 5)
    r2.rescale(d2, 6)
    assert len(r2._programs) == 1  # new capacity enforced immediately
    assert list(r2._programs)[0][2:4] == (5, 6)  # only the latest program kept
    assert len(r1._programs) == 3  # and the old instance is untouched


def test_program_cache_span_repair_kind_coexists_and_rekeys():
    """ISSUE-5 satellite: the span-repair programs live in the SAME bounded
    LRU as the streaming engine's scatter/compact programs under a kind
    prefix, and changes to span length, k, or e_max each produce a fresh key
    (no stale program reuse)."""
    from repro.core import ordering
    from repro.core.graph import rmat_graph
    from repro.launch import mesh as MM
    from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream
    from repro.stream.incremental import StreamConfig

    g = rmat_graph(6, 4, seed=2)
    order = ordering.geo_order(g, seed=0)
    o = IncrementalOrderer(
        g.src[order].astype(np.int64), g.dst[order].astype(np.int64),
        g.num_vertices, regions=4,
        config=StreamConfig(partial_drift=1.0, full_drift=99.0, span_regions=2),
    )
    o._baseline_kappa = o._kappa() / 1.5  # monitor always fires 'partial'
    eng = StreamingEngine(o, MM.make_graph_mesh(1), program_cache_size=16)
    stream = SyntheticStream(g, batch_size=24, seed=3)

    def span_keys():
        return [k for k in eng._programs if k[0] == "span_repair"]

    eng.ingest(stream.batch(), verify=True)  # scatter program
    eng.monitor()  # span program #1 (k=4, e_cap_0, s=2)
    assert {k[0] for k in eng._programs} == {"scatter", "span_repair"}
    k1 = span_keys()[-1]
    eng.monitor()
    assert len(span_keys()) == 1  # same signature → cache hit, no retrace
    eng.rescale(6, verify=True)  # compact program; k and e_cap both change
    eng.monitor()
    assert {k[0] for k in eng._programs} == {"scatter", "span_repair", "compact"}
    k2 = span_keys()[-1]
    assert k2 != k1 and k2[2] == 6 and k1[2] == 4  # k re-keys
    o.grow()  # e_max changes at the same k
    eng._resync()
    eng.monitor()
    k3 = span_keys()[-1]
    assert k3 != k2 and k3[4] > k2[4]  # e_cap re-keys
    # Span length re-keys: a 1-region span at the same k / e_cap.
    o.config = StreamConfig(partial_drift=1.0, full_drift=99.0, span_regions=1)
    eng.monitor()
    k4 = span_keys()[-1]
    assert k4 != k3 and k4[5] == 1 and k3[5] == 2
    eng.verify_bit_identity()  # none of the re-keyed programs went stale
    assert len(span_keys()) == 4  # all four coexist in the one LRU


def test_program_cache_hits_shared_across_rescale_kinds():
    """One ElasticRescaler instance serves repeated oscillation between
    configurations from cache: the second pass over the same (k_old, k_new)
    pairs must trace nothing new."""
    src = np.arange(60, dtype=np.int64)
    dst = (src + 7) % 60
    from repro.graphs import engine as E

    r = ElasticRescaler(program_cache_size=8)
    for _ in range(2):  # second lap = pure cache hits
        d = E.pack_ordered(src, dst, 60, 4)
        d, _ = r.rescale(d, 6)
        d, _ = r.rescale(d, 4)
    assert len(r._programs) == 2  # (4→6) and (6→4), each traced exactly once


def test_program_cache_counters_per_kind():
    """ISSUE-6 satellite: the cache counts hits/misses/evictions PER KIND so
    event logs can prove an escalation never paid a compile. get-miss then
    put then get-hit is the compile-once discipline; misses == compiles."""
    c = ProgramCache(2)
    key_a, key_b = ("scatter", 8, 64), ("span_repair", 8, 64)
    assert c.get(key_a) is None  # miss counted
    c.put(key_a, "a")
    assert c.get(key_a) == "a"  # hit counted
    assert c.get(key_b) is None
    c.put(key_b, "b")
    assert c.counters_snapshot() == {
        "scatter": {"hits": 1, "misses": 1, "evictions": 0},
        "span_repair": {"hits": 0, "misses": 1, "evictions": 0},
    }
    # Eviction is billed to the VICTIM's kind, at put time.
    c.put(("splice", 8, 64), "s")  # evicts key_a (LRU: b was put after a's hit)
    snap = c.counters_snapshot()
    assert snap["scatter"]["evictions"] == 1
    assert snap["span_repair"]["evictions"] == 0
    assert "splice" not in snap  # put counts nothing for its own kind


def test_program_cache_touch_counts_hit_only_when_present():
    """touch refreshes recency and counts a hit IF present; an absent key
    counts NOTHING — the warm-up probe must not inflate the miss count the
    builder's own get-miss is about to record (misses == compiles)."""
    c = ProgramCache(2)
    key = ("full_reorder", 4, 128)
    assert c.touch(key) is False
    assert c.counters_snapshot() == {}  # absent touch left no trace
    c.put(key, "p")
    assert c.touch(key) is True
    assert c.counters_snapshot() == {"full_reorder": {"hits": 1, "misses": 0, "evictions": 0}}
    # touch refreshes recency like get: the untouched entry is the victim.
    c.put(("other", 1), "q")
    c.touch(key)
    c.put(("third", 2), "r")
    assert key in c and ("other", 1) not in c


def test_program_cache_counters_snapshot_is_isolated():
    """Snapshots attached to events must not alias the live counters."""
    c = ProgramCache(2)
    c.get(("scatter", 1))  # miss
    snap = c.counters_snapshot()
    c.put(("scatter", 1), "x")
    c.get(("scatter", 1))  # hit after snapshot
    assert snap == {"scatter": {"hits": 0, "misses": 1, "evictions": 0}}
    snap["scatter"]["misses"] = 99  # mutating the snapshot …
    assert c.counters["scatter"]["misses"] == 1  # … never reaches the cache


def test_program_cache_counters_snapshot_is_lazy_copy_on_write():
    """Observability satellite: snapshotting costs a flag, not a deep copy —
    the live mapping is handed out as-is and only CLONED by the cache's next
    counter mutation, so every IngestEvent's snapshot stays frozen at its
    emit-time values while back-to-back snapshots (no cache activity between
    events) share one dict."""
    c = ProgramCache(4)
    c.get(("scatter", 1))  # miss
    s1 = c.counters_snapshot()
    s2 = c.counters_snapshot()
    assert s1 is s2  # idle cache: zero copies between events
    c.put(("scatter", 1), "x")
    c.get(("scatter", 1))  # hit → clone-before-mutate detaches s1/s2
    s3 = c.counters_snapshot()
    assert s3 is not s1
    assert s1 == {"scatter": {"hits": 0, "misses": 1, "evictions": 0}}
    assert s3["scatter"] == {"hits": 1, "misses": 1, "evictions": 0}
    # A new kind appearing later never leaks into earlier snapshots.
    c.get(("splice", 2))  # miss on a fresh kind
    s4 = c.counters_snapshot()
    assert s4 is not s3 and "splice" in s4 and "splice" not in s3


# ------------------------------------------------------------------- data
def test_data_pipeline_deterministic_and_elastic():
    dc = dp.DataConfig(vocab_size=1000, seq_len=16, global_batch=64)
    gb = dp.global_batch(dc, step=7)
    assert gb["tokens"].shape == (64, 16)
    # Union of host shards == global batch, for any k.
    for k in (4, 5):
        rows = [dp.host_batch(dc, 7, k, h) for h in range(k)]
        got = np.concatenate([r["tokens"] for r in rows])
        np.testing.assert_array_equal(got, gb["tokens"])
    # Rescale plan touches < half the samples for +1 host.
    plan = dp.rescale_moves(dc, 4, 5)
    assert plan.migrated_edges <= 64 * 0.6


# ------------------------------------------------------------------ optimizer
def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.asarray([2.0, -3.0, 1.5])}
    opt = O.OptConfig(peak_lr=0.1, warmup_steps=5, total_steps=200, weight_decay=0.0)
    state = O.init_opt_state(params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2)

    p = params
    for _ in range(150):
        g = jax.grad(loss_fn)(p)
        p, state, _ = O.adamw_update(p, g, state, opt)
    assert float(loss_fn(p)) < 1e-2


def test_lr_schedule_shape():
    opt = O.OptConfig(peak_lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(O.lr_schedule(opt, 0)) < 0.2
    assert float(O.lr_schedule(opt, 10)) == pytest.approx(1.0, rel=0.05)
    assert float(O.lr_schedule(opt, 99)) == pytest.approx(0.1, rel=0.15)


# ------------------------------------------------------------------ checkpoint
def test_checkpoint_save_restore_roundtrip(tmp_path):
    from repro.checkpoint import store

    tree = {
        "a": jnp.arange(37, dtype=jnp.float32).reshape(37),
        "nested": {"b": jnp.ones((5, 7), jnp.float32) * 3},
    }
    store.save(tree, tmp_path, step=3, k_shards=4)
    restored, bytes_touched = store.restore(tmp_path, 3, k_new=5, template=tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["nested"]["b"]), np.asarray(tree["nested"]["b"]))
    assert bytes_touched > 0  # rescale 4→5 must account moved bytes
