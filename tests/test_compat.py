"""compat layer: portable shard_map / axis_size / mesh helpers / donate_jit.

The repo rule is "never import shard_map directly" — these tests pin the
behaviours the rest of the codebase relies on, on whatever jax is installed.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.launch import mesh as MM


def test_no_direct_shard_map_imports_outside_compat():
    import pathlib
    import re

    # Catches every spelling: "from jax import lax, shard_map" (the seed
    # repo's exact bug), "from jax.experimental import shard_map",
    # "from jax.experimental.shard_map import ...", "jax.shard_map(...)".
    direct = re.compile(
        r"from\s+jax(\.[\w.]+)?\s+import\s+[^\n]*\bshard_map\b"
        r"|\bjax(\.\w+)*\.shard_map\b"
    )
    root = pathlib.Path(compat.__file__).parent
    offenders = []
    for path in root.rglob("*.py"):
        if path.name == "compat.py":
            continue
        if direct.search(path.read_text()):
            offenders.append(str(path))
    assert not offenders, f"import shard_map via repro.compat, not directly: {offenders}"


def test_jax_version_tuple():
    assert compat.JAX_VERSION >= (0, 4, 35), "support policy: jax >= 0.4.35"


def test_shard_map_runs_with_check_vma_kwarg():
    mesh = MM.make_test_mesh(data=1, model=1)

    def local(x):
        return lax.psum(x, "data")

    fn = compat.shard_map(local, mesh=mesh, in_specs=P("data"), out_specs=P(), check_vma=False)
    x = jnp.arange(4, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(fn(x)), np.asarray(x))


def test_axis_size_inside_shard_map():
    mesh = MM.make_test_mesh(data=1, model=1)

    def local(x):
        return x * compat.axis_size("data")

    fn = compat.shard_map(local, mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False)
    assert int(fn(jnp.asarray(3))) == 3  # axis size is 1 on the test mesh


def test_mesh_axis_helpers():
    mesh = MM.make_test_mesh(data=1, model=1)
    assert compat.mesh_axis_sizes(mesh) == {"data": 1, "model": 1}
    assert compat.mesh_axis_size(mesh, "model") == 1
    assert compat.mesh_axis_size(mesh, "nonexistent") == 1
    assert compat.mesh_axis_size(mesh, "nonexistent", default=7) == 7


def test_donate_jit_matches_jit_and_stays_quiet():
    def f(x, y):
        return x + y

    x = jnp.arange(8, dtype=jnp.float32)
    y = jnp.ones(8, dtype=jnp.float32)
    fn = compat.donate_jit(f, donate_argnums=(0,))
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # any donation warning would fail here
        got = fn(x, y)
    np.testing.assert_allclose(np.asarray(got), np.arange(8) + 1.0)


def test_donate_jit_decorator_form():
    @compat.donate_jit(donate_argnums=(0,))
    def g(x):
        return 2 * x

    assert int(g(jnp.asarray(21))) == 42
