"""compat layer: portable shard_map / axis_size / mesh helpers / donate_jit.

The repo rule is "never import shard_map directly" — these tests pin the
behaviours the rest of the codebase relies on, on whatever jax is installed.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.launch import mesh as MM


def test_no_direct_shard_map_imports_outside_compat():
    import pathlib
    import re

    # Catches every spelling: "from jax import lax, shard_map" (the seed
    # repo's exact bug), "from jax.experimental import shard_map",
    # "from jax.experimental.shard_map import ...", "jax.shard_map(...)".
    direct = re.compile(
        r"from\s+jax(\.[\w.]+)?\s+import\s+[^\n]*\bshard_map\b"
        r"|\bjax(\.\w+)*\.shard_map\b"
    )
    root = pathlib.Path(compat.__file__).parent
    offenders = []
    for path in root.rglob("*.py"):
        if path.name == "compat.py":
            continue
        if direct.search(path.read_text()):
            offenders.append(str(path))
    assert not offenders, f"import shard_map via repro.compat, not directly: {offenders}"


def test_jax_version_tuple():
    assert compat.JAX_VERSION >= (0, 4, 35), "support policy: jax >= 0.4.35"


def test_shard_map_runs_with_check_vma_kwarg():
    mesh = MM.make_test_mesh(data=1, model=1)

    def local(x):
        return lax.psum(x, "data")

    fn = compat.shard_map(local, mesh=mesh, in_specs=P("data"), out_specs=P(), check_vma=False)
    x = jnp.arange(4, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(fn(x)), np.asarray(x))


def test_axis_size_inside_shard_map():
    mesh = MM.make_test_mesh(data=1, model=1)

    def local(x):
        return x * compat.axis_size("data")

    fn = compat.shard_map(local, mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False)
    assert int(fn(jnp.asarray(3))) == 3  # axis size is 1 on the test mesh


def test_mesh_axis_helpers():
    mesh = MM.make_test_mesh(data=1, model=1)
    assert compat.mesh_axis_sizes(mesh) == {"data": 1, "model": 1}
    assert compat.mesh_axis_size(mesh, "model") == 1
    assert compat.mesh_axis_size(mesh, "nonexistent") == 1
    assert compat.mesh_axis_size(mesh, "nonexistent", default=7) == 7


def test_donate_jit_matches_jit_and_stays_quiet():
    def f(x, y):
        return x + y

    x = jnp.arange(8, dtype=jnp.float32)
    y = jnp.ones(8, dtype=jnp.float32)
    fn = compat.donate_jit(f, donate_argnums=(0,))
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # any donation warning would fail here
        got = fn(x, y)
    np.testing.assert_allclose(np.asarray(got), np.arange(8) + 1.0)


def test_donate_jit_decorator_form():
    @compat.donate_jit(donate_argnums=(0,))
    def g(x):
        return 2 * x

    assert int(g(jnp.asarray(21))) == 42


# --------------------------------------------------------------- distributed
def test_process_helpers_single_process():
    """Outside a jax.distributed group the process helpers report the
    1-process degenerate case every multi-host code path must handle."""
    assert compat.process_count() == 1
    assert compat.process_index() == 0


def test_enable_cpu_collectives_finds_a_knob():
    """Supported jax versions all have one spelling of the CPU-collectives
    knob; idempotent (initialize_from_env may race a user's own call)."""
    assert compat.enable_cpu_collectives() is True
    assert compat.enable_cpu_collectives() is True  # idempotent


def test_force_host_device_flags_builds_explicitly():
    from repro.launch.multihost import force_host_device_flags

    assert force_host_device_flags(8) == "--xla_force_host_platform_device_count=8"
    # Replaces an existing count instead of string-patching it — the exact
    # failure mode of .replace("8", "512") on a flag whose digits collide.
    got = force_host_device_flags(
        512, "--xla_dump_to=/tmp/d --xla_force_host_platform_device_count=8"
    )
    assert got == "--xla_dump_to=/tmp/d --xla_force_host_platform_device_count=512"
    assert force_host_device_flags(4, got).count("device_count") == 1


def test_put_global_and_local_shard_rows_degenerate_single_process():
    """put_global / local_shard_rows on a 1-device mesh: the degenerate case
    of the multi-host path (DESIGN.md §10) — same layout as device_put."""
    from jax.sharding import NamedSharding

    from repro.launch import multihost as MH

    mesh = MM.make_graph_mesh(1)
    arr = np.arange(12, dtype=np.int32).reshape(6, 2)
    committed = MH.put_global(arr, NamedSharding(mesh, P("graph", None)))
    np.testing.assert_array_equal(np.asarray(committed), arr)
    blocks = MH.local_shard_rows(committed)
    assert [(lo, hi) for lo, hi, _ in blocks] == [(0, 6)]
    np.testing.assert_array_equal(blocks[0][2], arr)
    np.testing.assert_array_equal(MH.host_read(committed), arr)
