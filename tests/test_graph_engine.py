"""Graph engine validation vs networkx (single device; multi-device variant
lives in test_multidevice-style subprocess below)."""
import networkx as nx
import numpy as np
import pytest

from repro.core import baselines, ordering
from repro.core.graph import Graph, rmat_graph
from repro.graphs import engine as E
from repro.launch import mesh as MM


@pytest.fixture(scope="module")
def small():
    g = rmat_graph(6, 4, seed=5)
    nxg = nx.Graph()
    nxg.add_nodes_from(range(g.num_vertices))
    nxg.add_edges_from(zip(g.src.tolist(), g.dst.tolist()))
    return g, nxg


@pytest.fixture(scope="module")
def mesh1():
    return MM.make_test_mesh(data=1, model=1)


def _data(g, k=4):
    order = ordering.geo_order(g, seed=0)
    return E.cep_engine_data(g, order, k)


def test_pagerank_matches_networkx(small, mesh1):
    g, nxg = small
    data = _data(g)
    pr = np.asarray(E.pagerank(data, mesh1, iterations=50))
    want = nx.pagerank(nxg, alpha=0.85, max_iter=100, tol=1e-10)
    want_v = np.array([want[i] for i in range(g.num_vertices)])
    np.testing.assert_allclose(pr, want_v, rtol=5e-3, atol=1e-5)


def test_sssp_matches_networkx(small, mesh1):
    g, nxg = small
    data = _data(g)
    dist, iters = E.sssp(data, mesh1, source=0)
    lengths = nx.single_source_shortest_path_length(nxg, 0)
    got = np.asarray(dist)
    for v in range(g.num_vertices):
        if v in lengths:
            assert got[v] == pytest.approx(lengths[v]), v
        else:
            assert got[v] > 1e8
    assert iters > 0


def test_wcc_matches_networkx(small, mesh1):
    g, nxg = small
    data = _data(g)
    lab, _ = E.wcc(data, mesh1)
    lab = np.asarray(lab).astype(np.int64)
    comps = list(nx.connected_components(nxg))
    for comp in comps:
        ls = {lab[v] for v in comp}
        assert len(ls) == 1, "component must share one label"
    # Distinct components get distinct labels.
    reps = [lab[next(iter(c))] for c in comps]
    assert len(set(reps)) == len(comps)


def test_geo_partition_has_fewer_mirrors_than_hash(small, mesh1):
    g, _ = small
    k = 8
    geo = _data(g, k)
    hsh = E.build_engine_data(g, baselines.hash_1d(g, k), k)
    assert geo.mirrors < hsh.mirrors
    assert E.comm_volume_per_iteration(geo) < E.comm_volume_per_iteration(hsh)


def test_pagerank_invariant_to_partitioning(small, mesh1):
    """Results must not depend on how edges are partitioned (engine soundness)."""
    g, _ = small
    d1 = _data(g, 2)
    d2 = E.build_engine_data(g, baselines.hash_1d(g, 7), 7)
    p1 = np.asarray(E.pagerank(d1, mesh1, iterations=30))
    p2 = np.asarray(E.pagerank(d2, mesh1, iterations=30))
    np.testing.assert_allclose(p1, p2, rtol=1e-5, atol=1e-8)
