"""GEO ordering tests (paper §4, Thm. 6) + Alg.3/Alg.4 cross-checks."""
import numpy as np
import pytest
from conftest import hypothesis_or_stub

from repro.core import cep, metrics, ordering, theory
from repro.core.graph import Graph, grid_graph, powerlaw_graph, ring_graph, rmat_graph

given, settings, st = hypothesis_or_stub()


def _rf_of_order(g, order, k):
    s, d = g.src[order], g.dst[order]
    return metrics.replication_factor_ordered(s, d, k, g.num_vertices)


def test_order_is_permutation():
    g = rmat_graph(8, 8, seed=1)
    order = ordering.geo_order(g, seed=1)
    assert order.shape[0] == g.num_edges
    assert np.array_equal(np.sort(order), np.arange(g.num_edges))


@pytest.mark.parametrize("gen,args", [
    (rmat_graph, (8, 8)),
    (powerlaw_graph, (2000, 2.3)),
    (grid_graph, (40,)),
])
def test_geo_beats_random_ordering(gen, args):
    g = gen(*args, seed=3) if gen is not grid_graph else gen(*args)
    geo = ordering.geo_order(g, seed=0)
    rnd = ordering.random_edge_order(g, seed=0)
    for k in (4, 16, 64):
        rf_geo = _rf_of_order(g, geo, k)
        rf_rnd = _rf_of_order(g, rnd, k)
        assert rf_geo < rf_rnd, (k, rf_geo, rf_rnd)


def test_theorem6_upper_bound():
    # RF_k ≤ (|V| + |E| + k)/|V| for GEO+CEP.
    for seed in range(3):
        g = rmat_graph(7, 8, seed=seed)
        order = ordering.geo_order(g, seed=seed)
        for k in (4, 8, 32, 128):
            rf = _rf_of_order(g, order, k)
            assert rf <= theory.bound_general(g.num_vertices, g.num_edges, k) + 1e-9


def test_geo_close_to_baseline_algorithm3():
    """Alg. 4 (PQ) should reach quality comparable to Alg. 3 (direct objective)."""
    g = rmat_graph(5, 4, seed=7)  # tiny: Alg. 3 is O(|V|^2 |E| ...)
    fast = ordering.geo_order(g, k_min=2, k_max=8, seed=0)
    slow = ordering.geo_order_baseline(g, k_min=2, k_max=8, seed=0)
    assert np.array_equal(np.sort(slow), np.arange(g.num_edges))
    for k in (2, 4, 8):
        rf_fast = _rf_of_order(g, fast, k)
        rf_slow = _rf_of_order(g, slow, k)
        assert rf_fast <= rf_slow * 1.25 + 1e-9, (k, rf_fast, rf_slow)


def test_objective_equals_sum_of_rf():
    """Lemma 1: Eq.(6)/(7) over a complete ordering == Σ_k RF_k·|V| / |V|."""
    g = rmat_graph(5, 4, seed=2)
    order = ordering.random_edge_order(g, seed=1)
    s, d = g.src[order], g.dst[order]
    kmin, kmax = 2, 6
    obj = ordering.ordering_objective(s, d, g.num_edges, g.num_vertices, kmin, kmax)
    direct = sum(
        metrics.replication_factor_ordered(s, d, k, g.num_vertices) for k in range(kmin, kmax + 1)
    )
    assert obj == pytest.approx(direct, rel=1e-12)


def test_ring_graph_geo_is_near_optimal():
    # On a ring, contiguous edge chunks are optimal: RF_k ≈ (|V| + k)/|V|.
    g = ring_graph(512)
    order = ordering.geo_order(g, seed=0)
    for k in (4, 16):
        rf = _rf_of_order(g, order, k)
        optimal = (g.num_vertices + k) / g.num_vertices
        assert rf <= optimal * 1.02, (k, rf, optimal)


@given(scale=st.integers(4, 7), ef=st.integers(2, 8), seed=st.integers(0, 10))
@settings(max_examples=15, deadline=None)
def test_geo_order_property_valid_and_bounded(scale, ef, seed):
    g = rmat_graph(scale, ef, seed=seed)
    order = ordering.geo_order(g, seed=seed)
    assert np.array_equal(np.sort(order), np.arange(g.num_edges))
    rf = _rf_of_order(g, order, 8)
    assert 1.0 <= rf <= theory.bound_general(g.num_vertices, g.num_edges, 8)


def test_delta_zero_vs_default():
    """δ controls two-hop pull-in (Fig. 5): default δ should beat δ=1 quality."""
    g = rmat_graph(8, 8, seed=4)
    d_default = ordering.geo_order(g, seed=0)
    d_one = ordering.geo_order(g, delta=1, seed=0)
    rf_default = np.mean([_rf_of_order(g, d_default, k) for k in (4, 16, 64)])
    rf_one = np.mean([_rf_of_order(g, d_one, k) for k in (4, 16, 64)])
    assert rf_default <= rf_one * 1.05


def test_parallel_geo_quality_and_validity():
    """Beyond-paper: block-parallel GEO (the paper's §7 future work)."""
    g = rmat_graph(9, 8, seed=11)
    seq = ordering.geo_order(g, seed=0)
    for balance in (False, True):
        par, counts = ordering.parallel_geo_order(g, workers=4, seed=0, balance_edges=balance)
        assert np.array_equal(np.sort(par), np.arange(g.num_edges))
        assert sum(counts) == g.num_edges
        for k in (4, 16):
            rf_p = _rf_of_order(g, par, k)
            rf_s = _rf_of_order(g, seq, k)
            rf_r = _rf_of_order(g, ordering.random_edge_order(g, 0), k)
            # Quality-first mode stays near sequential; balanced mode must
            # still clearly beat random ordering.
            bound = 1.35 if not balance else 2.5
            assert rf_p <= rf_s * bound, (balance, k, rf_p, rf_s)
            assert rf_p < rf_r, (balance, k)
    # Edge-balanced mode: near-equal region loads.
    _, counts = ordering.parallel_geo_order(g, workers=4, seed=0, balance_edges=True)
    assert max(counts) <= 1.3 * (sum(counts) / len(counts))
