"""Streaming-graph subsystem: update model, incremental orderer, on-device
ingest (tier-1 scale — the mesh-of-1 degenerate case; the 8-device suite is
tests/test_stream_sharded.py)."""
import numpy as np
import pytest
from conftest import hypothesis_or_stub

from repro.core import metrics, ordering
from repro.core.graph import rmat_graph
from repro.elastic import controller as ec
from repro.graphs import engine as E
from repro.launch import mesh as MM
from repro.stream import (
    EdgeUpdateBatch,
    IncrementalOrderer,
    StreamConfig,
    StreamingEngine,
    SyntheticStream,
    best_insert_position,
)

given, settings, st = hypothesis_or_stub()


@pytest.fixture(scope="module")
def ordered():
    g = rmat_graph(7, 6, seed=0)
    order = ordering.geo_order(g, seed=0)
    return g, g.src[order].astype(np.int64), g.dst[order].astype(np.int64)


def make_orderer(ordered, regions=4, **cfg):
    g, src, dst = ordered
    config = StreamConfig(**cfg) if cfg else StreamConfig()
    return g, IncrementalOrderer(src, dst, g.num_vertices, regions=regions, config=config)


# ------------------------------------------------------------------- updates
def test_update_batch_canonicalizes():
    b = EdgeUpdateBatch(
        insert=np.array([[3, 1], [1, 3], [2, 2], [4, 5]]),
        delete=np.array([[9, 7]]),
    )
    # Dedup (1,3)/(3,1), drop the self loop, canonicalize src < dst.
    assert b.insert.tolist() == [[1, 3], [4, 5]]
    assert b.delete.tolist() == [[7, 9]]
    assert b.num_updates == 3


def test_synthetic_stream_is_deterministic_and_consistent():
    g = rmat_graph(6, 4, seed=1)
    s1 = SyntheticStream(g, batch_size=32, seed=7)
    s2 = SyntheticStream(g, batch_size=32, seed=7)
    live = {(int(u), int(v)) for u, v in zip(g.src, g.dst)}
    for _ in range(5):
        b1, b2 = s1.batch(), s2.batch()
        np.testing.assert_array_equal(b1.insert, b2.insert)
        np.testing.assert_array_equal(b1.delete, b2.delete)
        # Batches apply delete-then-insert (IncrementalOrderer.apply order).
        for u, v in b1.delete.tolist():
            assert (u, v) in live  # deletes always name live edges
            live.discard((u, v))
        for u, v in b1.insert.tolist():
            assert (u, v) not in live  # inserts are always novel
            live.add((u, v))
    assert {tuple(e) for e in s1.edges().tolist()} == live
    with pytest.raises(ValueError, match="in order"):
        s1.batch(99)


def test_stream_and_orderer_live_sets_stay_in_sync():
    """Regression: a delete that hash-picks a same-batch insert used to leave
    the orderer and generator with different live sets."""
    g = rmat_graph(6, 4, seed=1)
    order = ordering.geo_order(g, seed=0)
    o = IncrementalOrderer(
        g.src[order].astype(np.int64), g.dst[order].astype(np.int64),
        g.num_vertices, regions=3,
    )
    s = SyntheticStream(g, batch_size=64, delete_frac=0.4, seed=3)
    for _ in range(20):
        o.apply(s.batch())
    got = {(int(a), int(b)) for a, b in zip(*o.snapshot())}
    assert got == {tuple(e) for e in s.edges().tolist()}
    assert o.num_edges == s.num_edges


def test_synthetic_stream_different_seeds_differ():
    g = rmat_graph(6, 4, seed=1)
    a = SyntheticStream(g, batch_size=32, seed=0).batch()
    b = SyntheticStream(g, batch_size=32, seed=1).batch()
    assert a.insert.tolist() != b.insert.tolist()


# ----------------------------------------------------- bursty stream (ISSUE 6)
def test_synthetic_stream_burst_schedule_and_shapes():
    """Bursts land on the LAST batch of each window (a pure function of the
    index), are burst_factor× the base size, and draw deletes at
    burst_delete_frac; off-burst batches keep the base plan."""
    g = rmat_graph(7, 8, seed=1)
    s = SyntheticStream(
        g, batch_size=16, delete_frac=0.25, seed=5,
        burst_every=4, burst_factor=3, burst_delete_frac=0.5,
    )
    for b in range(8):
        assert s.is_burst(b) == (b % 4 == 3)
        n_del, n_ins = s.batch_shape(b)
        if s.is_burst(b):
            assert n_del + n_ins == 16 * 3 and n_del == 24  # 48 × 0.5
        else:
            assert n_del + n_ins == 16 and n_del == 4  # 16 × 0.25
        batch = s.batch()
        # The graph is large enough that the plan is never clamped.
        assert batch.num_deletes == n_del and batch.num_inserts == n_ins


def test_synthetic_stream_burst_replay_is_stateless(ordered):
    """The stateless-replay contract survives bursty mode: two generators
    with the same (seed, burst plan) emit identical batches, and the orderer's
    live set tracks the generator's through the churn spikes."""
    g, src, dst = ordered
    kw = dict(batch_size=24, delete_frac=0.3, seed=9, burst_every=3, burst_factor=4)
    s1 = SyntheticStream(g, **kw)
    s2 = SyntheticStream(g, **kw)
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=4)
    for b in range(7):
        b1, b2 = s1.batch(), s2.batch()
        np.testing.assert_array_equal(b1.insert, b2.insert)
        np.testing.assert_array_equal(b1.delete, b2.delete)
        o.apply(b1)
    got = {(int(a), int(c)) for a, c in zip(*o.snapshot())}
    assert got == {tuple(e) for e in s1.edges().tolist()}


def test_synthetic_stream_burst_default_delete_frac_and_off_mode():
    g = rmat_graph(6, 4, seed=1)
    s = SyntheticStream(g, batch_size=16, delete_frac=0.25, burst_every=2)
    assert s.burst_delete_frac == 0.25  # defaults to the base delete_frac
    off = SyntheticStream(g, batch_size=16)
    assert not any(off.is_burst(b) for b in range(20))  # burst_every=0 = never
    assert off.batch_shape(3) == (4, 12)


def test_synthetic_stream_burst_validation():
    g = rmat_graph(5, 4, seed=1)
    with pytest.raises(ValueError, match="burst_every"):
        SyntheticStream(g, burst_every=-1)
    with pytest.raises(ValueError, match="burst_factor"):
        SyntheticStream(g, burst_every=2, burst_factor=0)
    with pytest.raises(ValueError, match="burst_delete_frac"):
        SyntheticStream(g, burst_every=2, burst_delete_frac=1.0)


# ------------------------------------------------------------------- orderer
def test_orderer_snapshot_roundtrips_initial_order(ordered):
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=4)
    s, d = o.snapshot()
    np.testing.assert_array_equal(s, src)
    np.testing.assert_array_equal(d, dst)
    assert o.num_edges == g.num_edges
    assert o.capacity == 4 * o.slots_per_region


def test_orderer_insert_delete_idempotent(ordered):
    g, o = make_orderer(ordered)
    e0 = o.num_edges
    batch = EdgeUpdateBatch(
        insert=np.array([[int(g.src[0]), int(g.dst[0])]]),  # duplicate insert
        delete=np.array([[g.num_vertices - 1, g.num_vertices - 2]]),  # absent
    )
    counts = o.apply(batch)
    assert counts == {"inserted": 0, "deleted": 0, "skipped": 2}
    assert o.num_edges == e0
    # Real delete then re-insert lands the edge back.
    edge = [int(g.src[5]), int(g.dst[5])]
    o.apply(EdgeUpdateBatch(insert=np.zeros((0, 2)), delete=np.array([edge])))
    assert o.num_edges == e0 - 1
    o.apply(EdgeUpdateBatch(insert=np.array([edge]), delete=np.zeros((0, 2))))
    assert o.num_edges == e0
    s, d = o.snapshot()
    assert {(int(a), int(b)) for a, b in zip(s, d)} == {
        (int(a), int(b)) for a, b in zip(g.src, g.dst)
    }


def test_orderer_locality_placement_beats_append(ordered):
    """Streaming a locality-heavy update mix, the locality placement must not
    lose to naive append-at-end on the monitored region objective."""
    g, src, dst = ordered
    o_loc = IncrementalOrderer(src, dst, g.num_vertices, regions=4)
    stream = SyntheticStream(g, batch_size=64, seed=3)
    batches = [stream.batch() for _ in range(4)]
    for b in batches:
        o_loc.apply(b)
    # Append-only variant: same updates, placement forced to the append path
    # by emptying the incident index lookups.
    o_app = IncrementalOrderer(src, dst, g.num_vertices, regions=4)
    real_incident = o_app._incident
    o_app._incident = {}
    for b in batches:
        o_app.apply(b)
    o_app._incident = real_incident
    assert o_loc.region_vertex_sum() <= o_app.region_vertex_sum()


def test_orderer_grow_on_overflow(ordered):
    """Inserting past the slot array's free capacity (bucketed slack included
    — slots_per_region is 256-aligned with growth headroom) must grow it in
    place without losing edges."""
    g, src, dst = ordered
    o = IncrementalOrderer(
        src, dst, g.num_vertices, regions=2, config=StreamConfig(slack=0.05)
    )
    spr0 = o.slots_per_region
    free0 = int(o.capacity - o.num_edges)
    rng = np.random.default_rng(0)
    new = []
    existing = {(int(a), int(b)) for a, b in zip(src, dst)}
    while len(new) <= free0:  # one past capacity forces the grow
        u, v = int(rng.integers(0, g.num_vertices)), int(rng.integers(0, g.num_vertices))
        e = (min(u, v), max(u, v))
        if u != v and e not in existing:
            existing.add(e)
            new.append(e)
    o.apply(EdgeUpdateBatch(insert=np.array(new), delete=np.zeros((0, 2))))
    assert o.slots_per_region > spr0 and o.needs_resync
    s, d = o.snapshot()
    assert s.shape[0] == o.num_edges  # nothing lost in the grow


def test_partial_reorder_improves_objective_and_keeps_graph(ordered):
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=4)
    rng = np.random.default_rng(1)
    # Degrade: random cross-community inserts.
    new = set()
    while len(new) < 60:
        u, v = sorted(rng.integers(0, g.num_vertices, 2).tolist())
        if u != v and (u, v) not in new:
            new.add((u, v))
    o.apply(EdgeUpdateBatch(insert=np.array(sorted(new)), delete=np.zeros((0, 2))))
    before_edges = {(int(a), int(b)) for a, b in zip(*o.snapshot())}
    before_obj = o.region_vertex_sum()
    o.drain_ops()  # isolate the re-order's own ops
    n = o.partial_reorder()
    assert n > 0 and not o.needs_resync  # span rewrite travels as slot ops
    after_edges = {(int(a), int(b)) for a, b in zip(*o.snapshot())}
    assert after_edges == before_edges  # re-order never changes the graph
    assert o.region_vertex_sum() <= before_obj
    # The emitted ops cover exactly the span's slots and carry no degree
    # deltas (a re-order moves edges, it never adds or removes them).
    ops, deg = o.drain_ops()
    assert deg == {}
    spr = o.slots_per_region
    span_regions = {op.slot // spr for op in ops}
    assert len(span_regions) == o.config.span_regions
    assert len(ops) == len(span_regions) * spr


def test_full_rebuild_matches_fresh_geo(ordered):
    g, o = make_orderer(ordered)
    stream = SyntheticStream(g, batch_size=64, seed=5)
    for _ in range(3):
        o.apply(stream.batch())
    o.full_rebuild(seed=0)
    assert o.needs_resync and abs(o.drift() - 1.0) < 1e-9
    s, d = o.snapshot()
    gg = o.graph()
    fresh = ordering.geo_order(gg, seed=0)
    np.testing.assert_array_equal(s, gg.src[fresh])
    np.testing.assert_array_equal(d, gg.dst[fresh])


def test_rf_vs_oracle_margin_under_monitored_stream(ordered):
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=4)
    stream = SyntheticStream(g, batch_size=32, seed=2)
    for _ in range(10):
        o.apply(stream.batch())
        o.maybe_escalate()
        o.needs_resync = False
    inc, oracle = o.rf_vs_oracle(4)
    assert inc <= oracle * o.config.rf_margin + 1e-9


# ---------------------------------------------------- objective property tests
def _check_objective_invariant_under_within_chunk_permutation(seed, k):
    """Eq. (7) at a single k sums per-chunk vertex counts: permuting edges
    WITHIN a chunk must not change it (satellite: ordering_objective
    invariance)."""
    g = rmat_graph(5, 3, seed=seed)
    order = ordering.random_edge_order(g, seed=seed)
    s, d = g.src[order].astype(np.int64), g.dst[order].astype(np.int64)
    base = ordering.ordering_objective(s, d, g.num_edges, g.num_vertices, k, k)
    rng = np.random.default_rng(seed)
    from repro.core import cep

    bounds = cep.chunk_bounds(g.num_edges, k)
    s2, d2 = s.copy(), d.copy()
    for p in range(k):
        lo, hi = int(bounds[p]), int(bounds[p + 1])
        perm = lo + rng.permutation(hi - lo)
        s2[lo:hi], d2[lo:hi] = s2[perm], d2[perm]
    permuted = ordering.ordering_objective(s2, d2, g.num_edges, g.num_vertices, k, k)
    assert permuted == pytest.approx(base, rel=1e-12)


def _check_incremental_placement_never_worse_than_append(seed, k):
    """best_insert_position (the exact oracle of the streaming placement)
    must never pick a position with a worse objective than append-at-end."""
    g = rmat_graph(4, 3, seed=seed)
    order = ordering.geo_order(g, seed=seed)
    s, d = g.src[order].astype(np.int64), g.dst[order].astype(np.int64)
    rng = np.random.default_rng(seed)
    u, v = 0, 0
    while u == v:
        u, v = rng.integers(0, g.num_vertices, 2).tolist()
    pos = best_insert_position(s, d, int(u), int(v), g.num_vertices, k)
    assert 0 <= pos <= s.shape[0]

    def obj_at(p):
        return ordering.ordering_objective(
            np.insert(s, p, min(u, v)), np.insert(d, p, max(u, v)),
            g.num_edges + 1, g.num_vertices, k, k,
        )

    assert obj_at(pos) <= obj_at(s.shape[0]) + 1e-12


@given(seed=st.integers(0, 8), k=st.integers(2, 6))
@settings(max_examples=12, deadline=None)
def test_objective_invariant_under_within_chunk_permutation(seed, k):
    _check_objective_invariant_under_within_chunk_permutation(seed, k)


@given(seed=st.integers(0, 10), k=st.integers(2, 5))
@settings(max_examples=12, deadline=None)
def test_incremental_placement_never_worse_than_append(seed, k):
    _check_incremental_placement_never_worse_than_append(seed, k)


@pytest.mark.parametrize("seed,k", [(0, 2), (1, 3), (2, 4), (5, 6)])
def test_objective_properties_deterministic(seed, k):
    """Deterministic fallback (conftest hypothesis shim skips @given without
    hypothesis): same properties on fixed examples."""
    _check_objective_invariant_under_within_chunk_permutation(seed, k)
    _check_incremental_placement_never_worse_than_append(seed, min(k, 5))


# --------------------------------------- device span repair (ISSUE-5 tentpole)
def _degraded_orderer(seed, regions=4, span_regions=1, delta=None, scale=5):
    """Randomized graph + randomized degradation: the span-repair property
    fixtures. Returns the orderer after cross-community noise inserts."""
    g = rmat_graph(scale, 4, seed=seed)
    order = ordering.geo_order(g, seed=seed)
    cfg = StreamConfig(span_regions=span_regions, delta=delta)
    o = IncrementalOrderer(
        g.src[order].astype(np.int64), g.dst[order].astype(np.int64),
        g.num_vertices, regions=regions, config=cfg,
    )
    rng = np.random.default_rng(seed + 1)
    new = set()
    while len(new) < 25:
        u, v = sorted(rng.integers(0, g.num_vertices, 2).tolist())
        if u != v and (u, v) not in new:
            new.add((u, v))
    o.apply(EdgeUpdateBatch(insert=np.array(sorted(new)), delete=np.zeros((0, 2))))
    o.drain_ops()
    return g, o


def _check_span_repair_never_worse_than_geo(seed, span_regions, delta):
    """Satellite 1: for randomized graphs, spans, and δ windows, the span
    repair's resulting objective is never worse than the host geo_order span
    oracle (geo fed to the candidate selection), never worse than the current
    layout (production identity candidate), and the device program computes
    the byte-identical permutation to the host mirror."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import span_reorder as SRK

    g, o = _degraded_orderer(seed, span_regions=span_regions, delta=delta)
    r0, r1 = o.span_bounds()
    u, v, valid = o.span_arrays(r0, r1)
    assert valid.sum() >= 2
    ks = SRK.eval_ks(o.config.k_min, o.config.k_max)
    ident = SRK.identity_candidate(valid)
    geo = o.geo_span_candidate(u, v, valid)

    def obj(order):
        return SRK.span_objective_host(u, v, valid, order, ks)

    sel_geo, _ = SRK.select_span_order_host(u, v, valid, g.num_vertices, geo, ks)
    assert obj(sel_geo) <= obj(geo)  # never worse than the geo span oracle
    sel_id, _ = SRK.select_span_order_host(u, v, valid, g.num_vertices, ident, ks)
    assert obj(sel_id) <= obj(ident)  # production: never worse than current
    # Differential oracle: the traced program picks the identical permutation.
    dev = np.asarray(
        jax.jit(
            lambda a, b, c, d: SRK.select_span_order_device(
                a, b, c, g.num_vertices, d, ks, use_pallas=True
            )
        )(
            jnp.asarray(u, jnp.int32), jnp.asarray(v, jnp.int32),
            jnp.asarray(valid), jnp.asarray(geo, jnp.int32),
        )
    )
    np.testing.assert_array_equal(dev, sel_geo)


@given(seed=st.integers(0, 12), span=st.integers(1, 3), delta=st.sampled_from([None, 16, 64]))
@settings(max_examples=10, deadline=None)
def test_span_repair_never_worse_than_geo_oracle(seed, span, delta):
    _check_span_repair_never_worse_than_geo(seed, span, delta)


@pytest.mark.parametrize("seed,span,delta", [(0, 1, None), (1, 2, 16), (2, 3, 64), (5, 2, None)])
def test_span_repair_never_worse_deterministic(seed, span, delta):
    """Deterministic fallback (conftest hypothesis shim skips @given without
    hypothesis)."""
    _check_span_repair_never_worse_than_geo(seed, span, delta)


def _force_partial_engine(mode, seed=7, span_regions=2):
    g, o = _degraded_orderer(seed, span_regions=span_regions, scale=6)
    # Thresholds pinned so the monitor fires the partial rung every batch and
    # never escalates to full — the rung under test.
    o.config = StreamConfig(partial_drift=1.0, full_drift=99.0, span_regions=span_regions)
    o._baseline_kappa = o._kappa() / 1.5  # drift == 1.5 > partial, < full
    return g, o, StreamingEngine(o, MM.make_graph_mesh(1), span_repair=mode)


def test_span_repair_oracle_mode_bit_identical_to_host_path():
    """Satellite 1, second clause: in oracle mode the device program applies
    the host geo span order verbatim — buffers byte-identical to the PR-3
    host path on the same stream."""
    packs = {}
    for mode in ("oracle", "host"):
        g, o, eng = _force_partial_engine(mode)
        stream = SyntheticStream(g, batch_size=32, seed=11)
        for _ in range(3):
            eng.ingest(stream.batch(), verify=True)
            assert eng.monitor() == "partial"
            eng.verify_bit_identity()
        packs[mode] = E.unshard_engine_data(eng.data)
    for field in ("edges", "mask", "degrees"):
        np.testing.assert_array_equal(
            np.asarray(getattr(packs["oracle"], field)),
            np.asarray(getattr(packs["host"], field)),
        )


def test_span_repair_device_mode_matches_mirror_over_stream():
    """Production device rung: repairs land on the mesh while the host mirror
    advances the slot array — byte-identical after every event, including
    around a rescale that re-keys the span program."""
    g, o, eng = _force_partial_engine("device")
    stream = SyntheticStream(g, batch_size=32, seed=13)
    for b in range(5):
        if b == 3:
            eng.rescale(6, verify=True)
        eng.ingest(stream.batch(), verify=True)
        assert eng.monitor() == "partial"
        eng.verify_bit_identity()
    assert eng.last_repair == "device"
    assert eng.rung_counts["partial"] == 5 and eng.rung_s["partial"] > 0


def test_span_repair_differential_mode_never_worse_than_geo_end_to_end():
    g, o, eng = _force_partial_engine("differential")
    stream = SyntheticStream(g, batch_size=32, seed=17)
    for _ in range(3):
        eng.ingest(stream.batch(), verify=True)
        assert eng.monitor() == "partial"
        eng.verify_bit_identity()
    assert eng.last_repair == "differential"


def test_span_repair_skips_tiny_spans():
    """A span with <2 live edges must not launch the device program."""
    src = np.array([0, 2], dtype=np.int64)
    dst = np.array([1, 3], dtype=np.int64)
    o = IncrementalOrderer(src, dst, 8, regions=2)
    eng = StreamingEngine(o, MM.make_graph_mesh(1))
    o.apply(EdgeUpdateBatch(insert=np.zeros((0, 2)), delete=np.array([[0, 1]])))
    eng._sync_pending()
    o.drift = lambda: 1.05  # force the partial rung
    assert eng.monitor() == "partial"
    assert eng.last_repair == "skipped"
    eng.verify_bit_identity()


# ------------------------------------------- escalation ladder (satellite 2)
def test_escalation_rung_selection_at_exact_thresholds(ordered):
    """Thresholds are strict: drift exactly at a threshold does not fire."""
    g, o = make_orderer(ordered)
    cfg = o.config
    for drift, want in [
        (1.0, "none"),
        (cfg.partial_drift, "none"),  # exactly at the partial threshold
        (np.nextafter(cfg.partial_drift, 2.0), "partial"),
        (cfg.full_drift, "partial"),  # exactly at the full threshold
        (np.nextafter(cfg.full_drift, 2.0), "full"),
        (cfg.full_drift * 2, "full"),
    ]:
        o.drift = lambda d=drift: d  # instance attr shadows the method
        assert o.escalation() == want, f"drift={drift}"
    del o.drift


def test_maybe_escalate_delegates_partial_rung(ordered):
    g, o = make_orderer(ordered)
    o.drift = lambda: o.config.partial_drift + 0.01
    ran = []
    before = o.slot_src.copy()
    assert o.maybe_escalate(partial_fn=lambda: ran.append(1)) == "partial"
    assert ran == [1]
    np.testing.assert_array_equal(o.slot_src, before)  # delegate owned the work
    del o.drift


def test_partial_cooldown_hysteresis(ordered):
    """A fired partial opens a partial_cooldown window reporting 'none'; the
    full rung ignores the window and resets it."""
    g, o = make_orderer(ordered, partial_cooldown=2)
    o.drift = lambda: o.config.partial_drift + 0.01
    ran = []
    fn = lambda: ran.append(1)
    assert o.maybe_escalate(partial_fn=fn) == "partial"  # fires, opens window
    assert o.maybe_escalate(partial_fn=fn) == "none"  # cooling (2 left)
    assert o.maybe_escalate(partial_fn=fn) == "none"  # cooling (1 left)
    assert o.maybe_escalate(partial_fn=fn) == "partial"  # window closed
    assert len(ran) == 2
    o.drift = lambda: o.config.full_drift + 0.01
    assert o.maybe_escalate(partial_fn=fn) == "full"  # ignores + resets window
    o.drift = lambda: o.config.partial_drift + 0.01
    assert o.maybe_escalate(partial_fn=fn) == "partial"  # no leftover cooldown
    assert len(ran) == 3
    del o.drift


def test_drift_carried_across_relayouts_reset_only_by_full_rebuild(ordered):
    g, o = make_orderer(ordered)
    stream = SyntheticStream(g, batch_size=64, seed=21)
    for _ in range(4):
        o.apply(stream.batch())
    d0 = o.drift()
    assert d0 != 1.0
    o.relayout(6)  # rescale under ingest: drift VALUE carried across k change
    assert o.drift() == pytest.approx(d0, rel=1e-9)
    o.grow()  # slot-array growth: carried too
    assert o.drift() == pytest.approx(d0, rel=1e-9)
    o.full_rebuild()  # only a full rebuild moves the yardstick
    assert o.drift() == pytest.approx(1.0, abs=1e-9)


def test_per_rung_counters_and_timings_recorded_on_ingest_events(ordered):
    g, src, dst = ordered
    o = IncrementalOrderer(
        src, dst, g.num_vertices, regions=4,
        config=StreamConfig(partial_drift=1.0, full_drift=99.0, span_regions=2),
    )
    o._baseline_kappa = o._kappa() / 1.5  # every monitor fires 'partial'
    eng = StreamingEngine(o, MM.make_graph_mesh(1))
    ctl = ec.ElasticController(4)
    ctl.attach_stream(eng)
    stream = SyntheticStream(g, batch_size=32, seed=23)
    events = [ctl.ingest(stream.batch()) for _ in range(3)]
    for i, ev in enumerate(events):
        assert ev.escalation == "partial" and ev.repair == "device"
        assert ev.rung_count == i + 1  # cumulative firings of this rung
        assert ev.monitor_s > 0 and ev.rung_total_s > 0
    assert events[-1].rung_total_s >= events[0].rung_total_s
    assert eng.rung_counts == {"none": 0, "partial": 3, "full": 0}
    assert sum(eng.rung_counts.values()) == len(events)


def test_rung_total_s_cumulative_and_consistent_with_engine(ordered):
    """Rung accounting contract (DESIGN.md §13): each IngestEvent's
    rung_total_s is the engine's CUMULATIVE rung_s for that event's rung at
    emit time — monotone per rung, never reset mid-stream — and every
    monitored second lands in exactly one rung (the controller's monitor_s
    envelops the engine's own accounting from just outside the call)."""
    g, src, dst = ordered
    o = IncrementalOrderer(
        src, dst, g.num_vertices, regions=4,
        config=StreamConfig(partial_drift=1.0, full_drift=99.0, span_regions=2),
    )
    o._baseline_kappa = o._kappa() / 1.5  # every monitor fires 'partial'
    eng = StreamingEngine(o, MM.make_graph_mesh(1))
    ctl = ec.ElasticController(4)
    ctl.attach_stream(eng)
    stream = SyntheticStream(g, batch_size=32, seed=29)
    last_total: dict = {}
    for _ in range(5):
        ev = ctl.ingest(stream.batch())
        assert ev.rung_total_s >= last_total.get(ev.escalation, 0.0)
        last_total[ev.escalation] = ev.rung_total_s
        # The emit-time snapshot IS the engine accumulator's current value.
        assert ev.rung_total_s == pytest.approx(eng.rung_s[ev.escalation])
        assert ev.rung_count == eng.rung_counts[ev.escalation]
    events = [e for e in ctl.events if e.kind == "ingest"]
    engine_total = sum(eng.rung_s.values())
    monitor_total = sum(e.monitor_s for e in events)
    assert engine_total <= monitor_total  # enveloped from outside
    assert monitor_total - engine_total < 5e-3 * len(events)  # …by call overhead only


def test_rebuild_s_matches_tracer_rebuild_spans(ordered):
    """IngestEvent.rebuild_s (dispatch_s on the dispatch batch, commit_s on
    the commit batch) must agree with the tracer's rebuild.dispatch /
    rebuild.commit span for that same batch: the span envelops the timed
    inner region, so duration >= rebuild_s and close. Flight batches report
    rebuild_s == 0.0 — the per-monitor reset semantics."""
    from repro.obs import trace as OT

    g, src, dst = ordered
    o = IncrementalOrderer(
        src, dst, g.num_vertices, regions=4,
        config=StreamConfig(partial_drift=1.0, full_drift=1.0),
    )
    o._baseline_kappa = o._kappa() / 1.5  # every unsuppressed monitor: 'full'
    tracer = OT.Tracer(capacity=4096)
    eng = StreamingEngine(
        o, MM.make_graph_mesh(1), full_rebuild="geo", rebuild_flight=1,
        tracer=tracer,
    )
    ctl = ec.ElasticController(4)
    ctl.attach_stream(eng)
    stream = SyntheticStream(g, batch_size=32, seed=31)
    seen = set()
    for _ in range(8):
        n0 = len(tracer)
        ev = ctl.ingest(stream.batch())
        new = tracer.spans()[n0:]
        if ev.rebuild_state in ("dispatch", "commit"):
            spans = [s for s in new if s.name == f"rebuild.{ev.rebuild_state}"]
            assert len(spans) == 1
            assert ev.rebuild_s > 0.0
            assert spans[0].duration_s >= ev.rebuild_s
            assert spans[0].duration_s == pytest.approx(
                ev.rebuild_s, rel=0.5, abs=5e-3
            )
            seen.add(ev.rebuild_state)
        elif ev.rebuild_state == "flight":
            assert ev.rebuild_s == 0.0
            assert not [s for s in new if s.phase == "rebuild"]
    assert seen == {"dispatch", "commit"}
def test_streaming_engine_bit_identity_through_stream_and_rescales(ordered):
    """Small-scale version of the acceptance: ingest batches with two
    interleaved rescales; the sharded pack stays bit-identical to the host
    slot oracle at every step (verify=True raises otherwise)."""
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=4)
    eng = StreamingEngine(o, MM.make_graph_mesh(1))
    stream = SyntheticStream(g, batch_size=32, seed=4)
    for b in range(6):
        if b == 2:
            rs = eng.rescale(6, verify=True)
            assert rs.k_old == 4 and rs.k_new == 6 and rs.moved_edges > 0
        if b == 4:
            rs = eng.rescale(3, verify=True)
            assert rs.k_new == 3
        stats = eng.ingest(stream.batch(), verify=True)
        assert stats.num_edges == o.num_edges
        eng.monitor()
    assert eng.data.k == 3 and eng.data.num_edges == o.num_edges


def test_rescale_flushes_pending_host_ops(ordered):
    """Regression: orderer.apply called directly (outside engine.ingest)
    followed by engine.rescale used to drop the pending slot ops — the gather
    read a stale device buffer against the post-apply host layout."""
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=4)
    eng = StreamingEngine(o, MM.make_graph_mesh(1))
    stream = SyntheticStream(g, batch_size=32, seed=9)
    o.apply(stream.batch())  # host-only: device mirror not yet synced
    eng.rescale(6, verify=True)  # raises on divergence without the flush


def test_orderer_rejects_out_of_range_vertices(ordered):
    g, o = make_orderer(ordered)
    with pytest.raises(ValueError, match="out of range"):
        o.apply(EdgeUpdateBatch(insert=np.array([[-3, 5]]), delete=np.zeros((0, 2))))
    with pytest.raises(ValueError, match="out of range"):
        o.apply(
            EdgeUpdateBatch(insert=np.array([[1, g.num_vertices]]), delete=np.zeros((0, 2)))
        )


def test_streaming_pack_runs_gas_between_ingests(ordered):
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=3)
    eng = StreamingEngine(o, MM.make_graph_mesh(1))
    stream = SyntheticStream(g, batch_size=32, seed=6)
    eng.ingest(stream.batch(), verify=True)
    # Reference: re-pack the orderer's snapshot from scratch.
    s, d = o.snapshot()
    ref = E.pack_ordered(s, d, g.num_vertices, 3)
    np.testing.assert_allclose(
        np.asarray(E.pagerank(eng.data, iterations=10)),
        np.asarray(E.pagerank(ref, MM.make_test_mesh(1, 1), iterations=10)),
        rtol=1e-6, atol=1e-9,
    )
    ds, its = E.sssp(eng.data, source=0)
    dr, itr = E.sssp(ref, MM.make_test_mesh(1, 1), source=0)
    assert its == itr
    np.testing.assert_array_equal(np.asarray(ds), np.asarray(dr))


def test_pack_slots_layout_and_scratch_column(ordered):
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=4)
    data = E.pack_slots(o.slot_src, o.slot_dst, o.slot_valid, 4, g.num_vertices)
    assert data.edges.shape == (4, o.slots_per_region + 1, 2)
    assert np.all(np.asarray(data.mask)[:, -1] == 0)  # scratch col always masked
    assert data.num_edges == o.num_edges and data.mirrors == -1
    # Occupied slots keep their (region, column) coordinates.
    mask = np.asarray(data.mask)[:, :-1].reshape(-1)
    np.testing.assert_array_equal(mask.astype(bool), o.slot_valid)


def test_pack_ordered_slack_rows(ordered):
    g, src, dst = ordered
    tight = E.pack_ordered(src, dst, g.num_vertices, 4)
    slack = E.pack_ordered(src, dst, g.num_vertices, 4, e_max=int(tight.edges.shape[1]) + 7)
    assert slack.edges.shape[1] == tight.edges.shape[1] + 7
    np.testing.assert_array_equal(
        np.asarray(slack.edges)[:, : tight.edges.shape[1]], np.asarray(tight.edges)
    )
    assert np.all(np.asarray(slack.mask)[:, tight.edges.shape[1] :] == 0)
    with pytest.raises(ValueError, match="e_max"):
        E.pack_ordered(src, dst, g.num_vertices, 4, e_max=1)


# --------------------------------------------- vectorized placement (perf)
class _ReferencePlacementOrderer(IncrementalOrderer):
    """The pre-vectorization placement: per-insert occupancy rescans and
    Python-sorted medians. Kept as the decision oracle for the batched
    free-slot cache / np.partition path (ROADMAP follow-up: placement
    decisions must be bit-identical, only faster)."""

    def _median_slot(self, u, v):
        inc = sorted(self._incident.get(u, set()) | self._incident.get(v, set()))
        return inc[len(inc) // 2] if inc else None

    def _free_in(self, region, near=None):
        lo = region * self._spr
        free = np.flatnonzero(~self.slot_valid[lo : lo + self._spr])
        if free.size == 0:
            return None
        if near is None:
            return int(lo + free[0])
        return int(lo + free[np.argmin(np.abs(free + lo - near))])

    def _any_free_slot(self, near):
        free = np.flatnonzero(~self.slot_valid)
        if free.size == 0:
            return None
        if near is None:
            return int(free[0])
        return int(free[np.argmin(np.abs(free - near))])


@pytest.mark.parametrize("seed,delete_frac", [(2, 0.25), (5, 0.4), (9, 0.0)])
def test_vectorized_placement_decisions_unchanged(seed, delete_frac):
    """Stream identical batches (incl. grows and partial re-orders) through
    the vectorized orderer and the reference implementation: every slot
    assignment must be identical — the vectorization may only change speed."""
    g = rmat_graph(7, 6, seed=0)
    order = ordering.geo_order(g, seed=0)
    src, dst = g.src[order].astype(np.int64), g.dst[order].astype(np.int64)
    fast = IncrementalOrderer(src, dst, g.num_vertices, regions=4)
    ref = _ReferencePlacementOrderer(src, dst, g.num_vertices, regions=4)
    s1 = SyntheticStream(g, batch_size=64, delete_frac=delete_frac, seed=seed)
    s2 = SyntheticStream(g, batch_size=64, delete_frac=delete_frac, seed=seed)
    for i in range(10):
        c1 = fast.apply(s1.batch())
        c2 = ref.apply(s2.batch())
        assert c1 == c2
        if i == 5:  # escalation path rewrites spans in both
            assert fast.partial_reorder(0) == ref.partial_reorder(0)
        np.testing.assert_array_equal(fast.slot_src, ref.slot_src)
        np.testing.assert_array_equal(fast.slot_dst, ref.slot_dst)
        np.testing.assert_array_equal(fast.slot_valid, ref.slot_valid)
    assert fast.slots_per_region == ref.slots_per_region


def test_free_slot_cache_stays_exact(ordered):
    """The incremental free-slot cache must mirror slot_valid exactly after
    any mix of inserts, deletes, span rewrites, and grows."""
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=4)
    stream = SyntheticStream(g, batch_size=48, delete_frac=0.35, seed=12)
    for _ in range(8):
        o.apply(stream.batch())
        o.maybe_escalate()
        o.needs_resync = False
        for r in range(o.regions):
            lo = r * o.slots_per_region
            want = lo + np.flatnonzero(~o.slot_valid[lo : lo + o.slots_per_region])
            np.testing.assert_array_equal(o._free_slots(r), want)
            assert o._free[r] == want.size  # counters agree with the cache


# ------------------------------------------------- interleaving property test
def _check_random_interleaving(seed: int, steps: int = 8):
    """Drive a random interleaving of ingest() and scale events through the
    controller; after EVERY event the sharded pack must equal the host slot
    oracle byte-for-byte and the shared seq must stay strictly monotonic
    across mixed event kinds."""
    g = rmat_graph(6, 4, seed=1)
    order = ordering.geo_order(g, seed=0)
    o = IncrementalOrderer(
        g.src[order].astype(np.int64), g.dst[order].astype(np.int64),
        g.num_vertices, regions=4,
    )
    eng = StreamingEngine(o, MM.make_graph_mesh(1))
    clock = [0.0]
    ctl = ec.ElasticController(4, dead_after_s=5.0, clock=lambda: clock[0])
    ctl.attach_stream(eng)
    stream = SyntheticStream(g, batch_size=24, seed=seed)
    rng = np.random.default_rng(seed)
    events = []
    for _ in range(steps):
        alive = ctl.k
        choices = ["ingest", "ingest", "scale_out"] + (["scale_in"] if alive > 2 else [])
        action = choices[int(rng.integers(0, len(choices)))]
        if action == "ingest":
            events.append(ctl.ingest(stream.batch()))
        elif action == "scale_out":
            events.append(ctl.add_hosts(int(rng.integers(1, 3))))
        else:  # scale_in: one live host goes silent, the rest stay fresh
            victim = max(h for h, st in ctl.hosts.items() if st.alive)
            clock[0] += ctl.dead_after_s + 1.0  # victim's beat is now stale …
            for h, st in ctl.hosts.items():
                if st.alive and h != victim:
                    ctl.heartbeat(h, 1)  # … every other host just beat
            ev = ctl.poll()
            assert ev is not None and ev.kind == "scale_in"
            events.append(ev)
        # Invariant 1: device mirror == host slot oracle after every event.
        eng.verify_bit_identity()
        assert eng.k == ctl.k == o.regions
    # Invariant 2: one strictly monotonic seq across mixed event kinds.
    seqs = [e.seq for e in events]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    assert [e.seq for e in ctl.events] == list(range(len(ctl.events)))
    assert {e.kind for e in events} >= {"ingest"}  # mixed logs really mixed
    return [e.kind for e in events]


@given(seed=st.integers(0, 24))
@settings(max_examples=8, deadline=None)
def test_random_interleaving_matches_oracle_and_seq_monotonic(seed):
    _check_random_interleaving(seed)


@pytest.mark.parametrize("seed", [0, 3, 11, 17])
def test_random_interleaving_deterministic(seed):
    """Deterministic fallback (conftest hypothesis shim skips @given without
    hypothesis): fixed seeds chosen to cover scale_out, scale_in, and ingest
    interleavings."""
    kinds = _check_random_interleaving(seed)
    assert len(kinds) == 8


def test_interleaving_seeds_cover_both_scale_kinds():
    """The fallback seeds must actually exercise both scale directions
    between ingests (otherwise the deterministic variant silently degrades)."""
    kinds = sum((_check_random_interleaving(s) for s in (0, 3, 11, 17)), [])
    assert "scale_out" in kinds and "scale_in" in kinds and "ingest" in kinds


# -------------------------------------------------------------- controller
def test_controller_ingest_and_scale_events_share_seq(ordered):
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=4)
    eng = StreamingEngine(o, MM.make_graph_mesh(1))
    t = [0.0]
    ctl = ec.ElasticController(4, dead_after_s=5.0, clock=lambda: t[0])
    ctl.attach_stream(eng)
    stream = SyntheticStream(g, batch_size=32, seed=8)
    ev0 = ctl.ingest(stream.batch())
    assert ev0.kind == "ingest" and ev0.inserted > 0
    # A preemption mid-stream: scale event executes on the streaming pack.
    t[0] = 1.0
    for h in range(3):
        ctl.heartbeat(h, 1)
    t[0] = 6.0
    ev1 = ctl.poll()
    assert ev1 is not None and ev1.kind == "scale_in" and ev1.executed
    assert eng.k == 3 and eng.data.k == 3
    ev2 = ctl.ingest(stream.batch())
    eng.verify_bit_identity()
    # One shared monotonic seq across kinds → interleaved logs are orderable.
    assert (ev0.seq, ev1.seq, ev2.seq) == (0, 1, 2)
    assert [e.seq for e in ctl.events] == [0, 1, 2]


def test_attached_stream_takes_precedence_over_engine_data(ordered):
    """Regression: with both attach_engine and attach_stream, a scale event
    whose k_new equals the stream's current k must NOT fall through to the
    stale non-streaming pack."""
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=5)
    eng = StreamingEngine(o, MM.make_graph_mesh(1))
    ctl = ec.ElasticController(4)
    ctl.attach_engine(E.pack_ordered(src, dst, g.num_vertices, 4))
    ctl.attach_stream(eng)
    ev = ctl.add_hosts(1)  # k_new = 5 == stream.k: nothing to execute
    assert ev.k_new == 5 and not ev.executed and ctl.rescale_stats == []
    assert ctl.engine_data.k == 4  # stale pack untouched
    np.asarray(ctl.engine_data.edges)  # and not donated away
    ev2 = ctl.add_hosts(1)  # k_new = 6: executes on the STREAM
    assert ev2.executed and eng.k == 6 and ctl.engine_data.k == 4
    assert ctl.rescale_stats[-1].k_new == 6
    eng.verify_bit_identity()


def test_controller_ingest_requires_stream():
    ctl = ec.ElasticController(2)
    with pytest.raises(ValueError, match="attach_stream"):
        ctl.ingest(EdgeUpdateBatch(insert=np.zeros((0, 2)), delete=np.zeros((0, 2))))
