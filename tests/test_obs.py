"""Observability layer unit tests (DESIGN.md §13): span tracer + ring
semantics, Chrome-trace export/merge/validation, metrics registry
(histogram exactness, bucket fallback, snapshot flattening), structured
event-log JSONL round-trips, and the peak-RSS gauge convention."""
import json

import numpy as np
import pytest

from repro.obs import log as OL
from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.obs import trace_export as OX


# ------------------------------------------------------------------ tracer
class TestTracer:
    def test_disabled_records_nothing_and_shares_null_span(self):
        t = OT.Tracer(capacity=16, enabled=False)
        s1 = t.span("ingest.batch")
        s2 = t.span("rung.monitor")
        assert s1 is s2  # the shared no-op CM — no per-call allocation
        with s1:
            pass
        assert t.recorded == 0 and len(t) == 0

    def test_span_records_name_phase_duration(self):
        t = OT.Tracer(capacity=16)
        with t.span("ingest.scatter"):
            pass
        with t.span("custom", phase="special"):
            pass
        spans = t.spans()
        assert [s.name for s in spans] == ["ingest.scatter", "custom"]
        # Phase defaults to the dotted prefix; explicit phase wins.
        assert [s.phase for s in spans] == ["ingest", "special"]
        assert all(s.t1 >= s.t0 and s.duration_s >= 0.0 for s in spans)

    def test_nesting_orders_by_exit(self):
        t = OT.Tracer(capacity=16)
        with t.span("outer.a"):
            with t.span("outer.b"):
                pass
        names = [s.name for s in t.spans()]
        assert names == ["outer.b", "outer.a"]  # inner exits (records) first
        inner, outer = t.spans()
        assert outer.t0 <= inner.t0 and inner.t1 <= outer.t1

    def test_ring_bounds_and_dropped_counter(self):
        t = OT.Tracer(capacity=4)
        for i in range(10):
            with t.span(f"x.{i}"):
                pass
        assert t.recorded == 10 and len(t) == 4 and t.dropped == 6
        assert [s.name for s in t.spans()] == [f"x.{i}" for i in range(6, 10)]
        t.clear()
        assert t.recorded == 0 and t.dropped == 0 and not t.spans()

    def test_span_survives_exceptions(self):
        t = OT.Tracer(capacity=4)
        with pytest.raises(RuntimeError):
            with t.span("ingest.batch"):
                raise RuntimeError("boom")
        assert [s.name for s in t.spans()] == ["ingest.batch"]

    def test_global_default_disabled_and_settable(self):
        assert OT.get_tracer().enabled is False
        t = OT.Tracer(capacity=8)
        try:
            assert OT.set_tracer(t) is t and OT.get_tracer() is t
            with OT.span("transfer.put_global"):
                pass
            assert [s.name for s in t.spans()] == ["transfer.put_global"]
        finally:
            OT.set_tracer(None)
        assert OT.get_tracer().enabled is False
        with OT.span("transfer.put_global"):
            pass  # no-op again
        assert OT.get_tracer().recorded == 0

    def test_annotate_enters_profiler_annotation(self):
        # compat.profiler_annotation falls back to nullcontext — either way
        # the span must still record.
        t = OT.Tracer(capacity=4, annotate=True)
        with t.span("rebuild.dispatch"):
            pass
        assert t.recorded == 1


# ------------------------------------------------------------ trace export
def _traced(n=3, process=0):
    t = OT.Tracer(capacity=64)
    for i in range(n):
        with t.span(f"ingest.batch{i}"):
            pass
        with t.span("rung.monitor"):
            pass
    return OX.chrome_trace(t, process=process, process_name=f"proc{process}")


class TestChromeTrace:
    def test_export_structure(self):
        tr = _traced(n=2)
        assert OX.validate_chrome_trace(tr) == []
        events = tr["traceEvents"]
        meta = [e for e in events if e["ph"] == "M"]
        xs = [e for e in events if e["ph"] == "X"]
        assert len(xs) == 4
        # One process_name + one thread_name per phase track.
        names = {e["name"] for e in meta}
        assert names == {"process_name", "thread_name"}
        tracks = {e["args"]["name"] for e in meta if e["name"] == "thread_name"}
        assert tracks == {"ingest", "rung"}
        # Phase == cat == its track's thread_name; tids are per-phase.
        tids = {e["cat"]: e["tid"] for e in xs}
        assert len(tids) == 2
        assert all(isinstance(e["ts"], float) and e["dur"] >= 0.0 for e in xs)

    def test_merge_rebases_and_keeps_pids(self):
        merged = OX.merge_traces([_traced(process=0), _traced(process=1)])
        assert OX.validate_chrome_trace(merged) == []
        xs = [e for e in merged["traceEvents"] if e["ph"] == "X"]
        assert {e["pid"] for e in xs} == {0, 1}
        assert min(e["ts"] for e in xs) == 0.0
        assert merged["otherData"]["p0.spans_recorded"] == 6
        assert merged["otherData"]["p1.spans_recorded"] == 6

    def test_write_is_plain_json(self, tmp_path):
        p = tmp_path / "trace.json"
        OX.write_chrome_trace(str(p), _traced())
        assert OX.validate_chrome_trace(json.loads(p.read_text())) == []

    def test_validate_rejects_malformed(self):
        assert OX.validate_chrome_trace([]) == ["trace is not a JSON object"]
        assert OX.validate_chrome_trace({"traceEvents": []}) == [
            "traceEvents missing or empty"
        ]
        bad = {"traceEvents": [{"ph": "X", "name": "a", "pid": 0, "tid": 0,
                                "ts": 0.0, "dur": -1.0}]}
        assert any("negative dur" in p for p in OX.validate_chrome_trace(bad))
        meta_only = {"traceEvents": [{"ph": "M", "name": "process_name",
                                      "pid": 0, "tid": 0}]}
        assert OX.validate_chrome_trace(meta_only) == ["no complete ('X') span events"]


# ----------------------------------------------------------------- metrics
class TestMetrics:
    def test_counter_gauge(self):
        r = OM.MetricsRegistry()
        c = r.counter("stream.updates")
        c.inc()
        c.inc(4)
        g = r.gauge("queue.depth")
        g.set(3)
        g.set(7)
        snap = r.snapshot()
        assert snap["stream.updates"] == 5.0 and snap["queue.depth"] == 7.0
        # get-or-create returns the SAME object; kind mismatch raises.
        assert r.counter("stream.updates") is c
        with pytest.raises(TypeError):
            r.gauge("stream.updates")

    def test_histogram_exact_percentiles(self):
        h = OM.Histogram()
        vals = [0.001 * (i + 1) for i in range(100)]
        for v in vals:
            h.observe(v)
        assert h.exact
        assert h.percentile(50) == pytest.approx(np.percentile(vals, 50))
        assert h.percentile(99) == pytest.approx(np.percentile(vals, 99))
        assert h.total == 100 and h.sum == pytest.approx(sum(vals))

    def test_histogram_bucket_fallback_is_conservative(self):
        h = OM.Histogram(sample_cap=8)
        vals = [0.001 * (i + 1) for i in range(64)]
        for v in vals:
            h.observe(v)
        assert not h.exact
        # Bucket upper bound: never understates the true percentile.
        for q in (50, 90, 99):
            assert h.percentile(q) >= np.percentile(vals, q) * 0.999

    def test_histogram_overflow_bucket_answers_max_sample(self):
        h = OM.Histogram(bounds=(0.1, 1.0), sample_cap=4)
        for v in (5.0, 6.0, 7.0, 8.0, 9.0, 10.0):
            h.observe(v)  # all in the unbounded overflow bucket
        assert h.percentile(99) == 10.0

    def test_snapshot_flattens_histograms_summably(self):
        r = OM.MetricsRegistry()
        h = r.histogram("lat", bounds=(0.1, 1.0))
        h.observe(0.05)
        h.observe(0.5)
        h.observe(5.0)
        snap = r.snapshot()
        assert snap["lat.count"] == 3.0 and snap["lat.sum"] == pytest.approx(5.55)
        np.testing.assert_array_equal(snap["lat.buckets"], [1.0, 1.0, 1.0])
        # Sum of two processes' snapshots == snapshot of the merged stream —
        # the invariant snapshot_global's psum relies on.
        r2 = OM.MetricsRegistry()
        h2 = r2.histogram("lat", bounds=(0.1, 1.0))
        h2.observe(0.2)
        snap2 = r2.snapshot()
        total = snap["lat.buckets"] + snap2["lat.buckets"]
        np.testing.assert_array_equal(total, [1.0, 2.0, 1.0])

    def test_snapshot_global_single_process_identity(self):
        from repro.launch import mesh as MM

        r = OM.MetricsRegistry()
        r.counter("a").inc(3)
        r.histogram("b", bounds=(1.0,)).observe(0.5)
        g = r.snapshot_global(MM.make_graph_mesh(1))
        local = r.snapshot()
        assert g["a"] == local["a"] == 3.0
        assert g["b.count"] == 1.0
        np.testing.assert_array_equal(
            np.asarray(g["b.buckets"]), local["b.buckets"]
        )

    def test_null_registry_inert_and_allocation_free(self):
        n = OM.NULL
        m = n.counter("x")
        assert m is n.gauge("y") is n.histogram("z")
        m.inc()
        m.set(5)
        m.observe(1.0)
        assert n.snapshot() == {} and n.names() == []
        assert n.percentiles("z") == {"p50": 0.0, "p90": 0.0, "p99": 0.0}

    def test_record_peak_rss_process_indexed_gauges(self):
        r = OM.MetricsRegistry()
        mb = OM.record_peak_rss(r, process_index=1, process_count=3)
        assert mb > 0.0
        snap = r.snapshot()
        assert snap["process.peak_rss_mb.p1"] == pytest.approx(mb)
        assert snap["process.peak_rss_mb.p0"] == 0.0
        assert snap["process.peak_rss_mb.p2"] == 0.0


# ------------------------------------------------------------- event JSONL
def _controller_with_events():
    from repro.elastic import controller as ec
    from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream
    from repro.core.graph import rmat_graph
    from repro.core import ordering
    from repro.launch import mesh as MM

    g = rmat_graph(7, 6, seed=0)
    order = ordering.geo_order(g, seed=0)
    orderer = IncrementalOrderer(
        g.src[order].astype(np.int64), g.dst[order].astype(np.int64),
        g.num_vertices, regions=4,
    )
    engine = StreamingEngine(orderer, MM.make_graph_mesh(1))
    ctl = ec.ElasticController(4, clock=lambda: 0.0)
    ctl.attach_stream(engine)
    stream = SyntheticStream(g, batch_size=16, seed=1)
    for _ in range(3):
        ctl.ingest(stream.batch())
    ctl.add_hosts(2)  # a ScaleEvent between IngestEvents
    ctl.ingest(stream.batch())
    return ctl


class TestEventsJsonl:
    def test_round_trip_preserves_order_and_fields(self):
        ctl = _controller_with_events()
        text = ctl.events_jsonl()
        back = OL.events_from_jsonl(text)
        assert back == list(ctl.events)  # frozen dataclasses: field equality
        kinds = [type(e).__name__ for e in back]
        assert "ScaleEvent" in kinds and "IngestEvent" in kinds
        seqs = [e.seq for e in back]
        assert seqs == sorted(seqs)

    def test_drop_timings_zeroes_only_wall_fields(self):
        ctl = _controller_with_events()
        for line in ctl.events_jsonl(drop_timings=True).splitlines():
            d = json.loads(line)
            for k, v in d.items():
                if k.endswith("_s") and isinstance(v, float):
                    assert v == 0.0, f"{d['event']}.{k} not zeroed"
        # Non-timing content survives intact.
        back = OL.events_from_jsonl(ctl.events_jsonl(drop_timings=True))
        assert [e.seq for e in back] == [e.seq for e in ctl.events]
        assert [getattr(e, "kind", None) for e in back] == [
            getattr(e, "kind", None) for e in ctl.events
        ]

    def test_unknown_event_type_rejected(self):
        with pytest.raises(ValueError, match="unknown event type"):
            OL.event_from_dict({"event": "MysteryEvent"})
