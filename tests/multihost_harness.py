"""Per-process worker for the multi-host acceptance (tests/test_multihost.py).

``launch.multihost.spawn_local_cluster`` runs this file once per process; each
worker joins the ``jax.distributed`` group via ``initialize_from_env``, builds
the SAME graph deterministically from the seed (no host is special — this is
the "replicated deterministic load" path of DESIGN.md §10), and executes the
two prior acceptances on the now-global ``graph`` mesh:

* **rescale** — the PR-2 acceptance: pack at k=8 over all processes' devices,
  execute ScalePlans 8 → 12 → 8 (``ElasticRescaler``, ``recheck=False`` so no
  collective readback hides in the timed path);
* **stream** — the PR-3 acceptance: ingest batches through the controller
  with a scale-out to 12 and a preemption down to 7 interleaved
  (``StreamingEngine`` + ``ElasticController``).

One tracer + metrics registry (repro.obs) spans all phases: the record
additionally carries this process's Chrome-trace fragment, its local metric
snapshot, the psum_host-aggregated global snapshot, a process-indexed peak
RSS gauge, and drop-timings JSONL event logs — the observability acceptance
surface the parent test checks (merge, sum-of-locals, byte-identical logs).

Each process writes ONLY its local shard rows (`local_shard_rows`) plus a
stats/event JSON to ``--out``; the parent test reassembles the global buffers
from all processes' files and compares them byte-for-byte against the
single-process oracle it computes itself — so the proof never trusts a
cross-process collective to check cross-process execution. Logs go to stdout
(one line per step, prefixed with the process id) so spawn_local_cluster can
print per-process traces when something fails in CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"))

from repro.launch import multihost as MH  # noqa: E402  (before jax device init)

SPEC = MH.initialize_from_env()  # must run before the first jax computation

import jax  # noqa: E402

from repro.core import cep, ordering  # noqa: E402
from repro.core.graph import rmat_graph  # noqa: E402
from repro.elastic import controller as ec  # noqa: E402
from repro.elastic.rescale_exec import EDGE_BYTES, ElasticRescaler  # noqa: E402
from repro.graphs import engine as E  # noqa: E402
from repro.launch import mesh as MM  # noqa: E402
from repro.launch import sharding as SH  # noqa: E402
from repro.obs import metrics as OM  # noqa: E402
from repro.obs import trace as OT  # noqa: E402
from repro.obs import trace_export as OX  # noqa: E402
from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream  # noqa: E402
from repro.stream.incremental import StreamConfig  # noqa: E402

GRAPH_SCALE = 8
GRAPH_EDGE_FACTOR = 6
GRAPH_SEED = 0
STREAM_SEED = 1
STREAM_BATCH = 64
REBUILD_SEED = 2
REBUILD_FLIGHT = 1


def stream_config() -> StreamConfig:
    """Stream phase config: a 2-region span so partial re-orders can move the
    monitored objective, full rebuilds parked out of the way — the ISSUE-5
    acceptance wants the DEVICE span-repair rung exercised across the process
    boundary, not drowned by resync uploads."""
    return StreamConfig(full_drift=99.0, span_regions=2)


def rebuild_config() -> StreamConfig:
    """Rebuild phase config: both thresholds parked high so the natural drift
    never escalates — the ISSUE-6 acceptance forces exactly ONE async full
    rebuild at a scripted batch, keeping the event log byte-reproducible for
    the parent's host replay."""
    return StreamConfig(partial_drift=40.0, full_drift=50.0)


def force_partial_baseline(orderer: IncrementalOrderer) -> None:
    """Pin drift ≈ 1.5 (> partial_drift, < full_drift) so every monitor step
    deterministically fires the partial rung — the parent's host replay
    applies the identical pin, keeping decisions byte-reproducible."""
    orderer._baseline_kappa = orderer._kappa() / 1.5


def log(pid: int, msg: str) -> None:
    print(f"[proc {pid}] {msg}", flush=True)


def build_ordered():
    """The acceptance graph + GEO order — bit-identical in every process."""
    g = rmat_graph(GRAPH_SCALE, GRAPH_EDGE_FACTOR, seed=GRAPH_SEED)
    order = ordering.geo_order(g, seed=0)
    return g, g.src[order], g.dst[order]


def save_blocks(store: dict, name: str, arr) -> None:
    """Record this process's local shard rows of a global array."""
    for lo, hi, data in MH.local_shard_rows(arr):
        store[f"{name}__{lo}__{hi}"] = data


def run_rescale_phase(src, dst, num_vertices, mesh, store: dict,
                      tracer=None, registry=None) -> dict:
    pid = jax.process_index()
    n = int(src.shape[0])
    rescaler = ElasticRescaler(tracer=tracer, metrics_registry=registry)
    d8 = E.pack_ordered_sharded(src, dst, num_vertices, 8, mesh)
    log(pid, f"packed k=8 over {len(jax.devices())} global devices")

    import time

    t0 = time.perf_counter()
    plan_out = cep.scale_plan(n, 8, 12)
    plan_s = time.perf_counter() - t0
    d12, s_out = rescaler.execute(d8, plan_out, recheck=False)
    log(pid, f"8->12 executed: cross_process_bytes={s_out.cross_process_bytes}")
    save_blocks(store, "rescale_k12_edges", d12.edges)
    save_blocks(store, "rescale_k12_mask", d12.mask)

    plan_in = cep.scale_plan(n, 12, 8)
    d8b, s_in = rescaler.execute(d12, plan_in, recheck=False)
    log(pid, f"12->8 executed: cross_process_bytes={s_in.cross_process_bytes}")
    save_blocks(store, "rescale_k8_edges", d8b.edges)
    save_blocks(store, "rescale_k8_mask", d8b.mask)

    def stats_dict(s):
        return {
            "k_old": s.k_old, "k_new": s.k_new,
            "migrated_edges": s.migrated_edges, "migrated_bytes": s.migrated_bytes,
            "cross_device_edges": s.cross_device_edges,
            "cross_device_bytes": s.cross_device_bytes,
            "cross_process_edges": s.cross_process_edges,
            "cross_process_bytes": s.cross_process_bytes,
            "devices": s.devices, "processes": s.processes,
            "exec_s": s.elapsed_s,
        }

    return {
        "plan_s": plan_s,
        "out": stats_dict(s_out),
        "in": stats_dict(s_in),
        "edge_bytes": EDGE_BYTES,
    }


def stream_script(ctl, stream, clock):
    """The PR-3 rescale-under-ingest acceptance script — now with the drift
    baseline pinned so every ingest's monitor fires the PARTIAL rung (the
    ISSUE-5 device span repair) — expressed once so the parent test can
    replay the identical controller decisions host-side."""
    ctl.ingest(stream.batch())
    ctl.ingest(stream.batch())  # partial re-orders around the scale-out …
    ctl.add_hosts(4)  # 8 -> 12 under ingest
    ctl.ingest(stream.batch())
    clock[0] = 1.0
    for h in range(7):
        ctl.heartbeat(h, 1)
    clock[0] = 6.0
    ctl.poll()  # 5 silent hosts preempted: 12 -> 7
    ctl.ingest(stream.batch())  # … and after the preemption
    ctl.ingest(stream.batch())


def run_stream_phase(g, src, dst, mesh, store: dict,
                     tracer=None, registry=None) -> dict:
    pid = jax.process_index()
    o = IncrementalOrderer(
        src.astype(np.int64), dst.astype(np.int64), g.num_vertices,
        regions=8, config=stream_config(),
    )
    force_partial_baseline(o)
    # span_repair="device": the rung under test
    eng = StreamingEngine(o, mesh, tracer=tracer, metrics_registry=registry)
    clock = [0.0]
    ctl = ec.ElasticController(
        8, dead_after_s=5.0, clock=lambda: clock[0],
        tracer=tracer, metrics_registry=registry,
    )
    ctl.attach_stream(eng)
    stream = SyntheticStream(g, batch_size=STREAM_BATCH, seed=STREAM_SEED)
    stream_script(ctl, stream, clock)
    log(pid, f"stream script done: k={eng.k}, events={len(ctl.events)}")
    eng.verify_bit_identity()  # in-child check (collective unshard)
    log(pid, "in-child bit identity OK")

    save_blocks(store, "stream_edges", eng.data.edges)
    save_blocks(store, "stream_mask", eng.data.mask)
    save_blocks(store, "stream_degrees", eng.data.degrees)
    events = [
        {
            "kind": ev.kind,
            "seq": ev.seq,
            "executed": getattr(ev, "executed", None),
            "cross_process_bytes": getattr(ev, "cross_process_bytes", None),
            "escalation": getattr(ev, "escalation", None),
            "repair": getattr(ev, "repair", None),
        }
        for ev in ctl.events
    ]
    return {
        "k_final": eng.k,
        "num_edges": o.num_edges,
        "events": events,
        "rung_counts": eng.rung_counts,
        # Structured log with wall-clock fields zeroed: the only
        # nondeterministic event content on a deterministic replica, so the
        # parent asserts the two processes' logs are BYTE-identical.
        "events_jsonl": ctl.events_jsonl(drop_timings=True),
    }


def run_rebuild_phase(g, src, dst, mesh, store: dict,
                      tracer=None, registry=None) -> dict:
    """ISSUE-6 acceptance: one async full rebuild (geo mode, flight 1) flies
    across the 2-process mesh — dispatch on batch 2, flight through batch 3,
    commit with a delta splice, two quiet batches around it. The parent
    replays the identical protocol host-side and byte-compares the pack."""
    pid = jax.process_index()
    o = IncrementalOrderer(
        src.astype(np.int64), dst.astype(np.int64), g.num_vertices,
        regions=8, config=rebuild_config(),
    )
    eng = StreamingEngine(
        o, mesh, full_rebuild="geo", rebuild_flight=REBUILD_FLIGHT,
        tracer=tracer, metrics_registry=registry,
    )
    ctl = ec.ElasticController(8, tracer=tracer, metrics_registry=registry)
    ctl.attach_stream(eng)
    stream = SyntheticStream(g, batch_size=STREAM_BATCH, seed=REBUILD_SEED)
    states = []
    for b in range(5):
        if b == 2:
            o.drift = lambda: 99.0  # force the dispatch on this batch only
        ctl.ingest(stream.batch())
        if b == 2:
            del o.drift
        states.append(eng.rebuild_state)
    log(pid, f"rebuild script done: states={states}")
    eng.verify_bit_identity()  # in-child check (collective unshard)
    log(pid, "rebuild in-child bit identity OK")

    save_blocks(store, "rebuild_edges", eng.data.edges)
    save_blocks(store, "rebuild_mask", eng.data.mask)
    rebuilds = [e for e in ctl.events if e.kind == "full_rebuild"]
    return {
        "num_edges": o.num_edges,
        "states": states,
        "events": [{"kind": e.kind, "seq": e.seq} for e in ctl.events],
        "rebuilds": [
            {
                "mode": e.mode, "committed": e.committed, "aborted": e.aborted,
                "snapshot_edges": e.snapshot_edges,
                "replayed_batches": e.replayed_batches,
                "splice_ops": e.splice_ops, "flight_batches": e.flight_batches,
                "seq": e.seq,
            }
            for e in rebuilds
        ],
        "program_cache": eng.program_cache_counters(),
        "events_jsonl": ctl.events_jsonl(drop_timings=True),
    }


def snapshot_to_json(snap: dict) -> dict:
    """Registry snapshots carry numpy bucket vectors — JSON-ify them."""
    return {
        k: (np.asarray(v).tolist() if isinstance(v, np.ndarray) else float(v))
        for k, v in snap.items()
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True, help="directory for per-process results")
    args = ap.parse_args()
    pid = jax.process_index()
    log(pid, f"{jax.process_count()} processes, {len(jax.local_devices())} local / "
             f"{len(jax.devices())} global devices")

    g, src, dst = build_ordered()
    mesh = MM.make_graph_mesh()  # spans every process's devices
    # ONE tracer + registry across all three phases (DESIGN.md §13): the
    # trace fragment and metric snapshots below are the observability
    # acceptance — per-process ingest/rung/rebuild/rescale span tracks that
    # merge into a single Chrome trace, and a registry whose psum_host-
    # aggregated snapshot must equal the sum of the per-process ones.
    # set_tracer also routes launch/multihost's transfer.* spans here.
    tracer = OT.set_tracer(OT.Tracer(capacity=1 << 16))
    registry = OM.MetricsRegistry()
    store: dict = {}
    record = {
        "process_id": pid,
        "num_processes": jax.process_count(),
        "devices": len(jax.devices()),
        "device_process_map": SH.device_process_map(mesh).tolist(),
        "graph": {"num_vertices": g.num_vertices, "num_edges": g.num_edges},
        "rescale": run_rescale_phase(src, dst, g.num_vertices, mesh, store,
                                     tracer, registry),
    }
    record["stream"] = run_stream_phase(g, src, dst, mesh, store, tracer, registry)
    record["rebuild"] = run_rebuild_phase(g, src, dst, mesh, store, tracer, registry)

    peak_mb = OM.record_peak_rss(registry)
    local_snap = registry.snapshot()
    global_snap = registry.snapshot_global(mesh)  # collective: same point everywhere
    log(pid, f"obs: {tracer.recorded} spans, {len(local_snap)} snapshot entries, "
             f"peak_rss={peak_mb:.1f}MB")
    record["obs"] = {
        "peak_rss_mb": peak_mb,
        "spans_recorded": tracer.recorded,
        "spans_dropped": tracer.dropped,
        "trace": OX.chrome_trace(tracer, process=pid, process_name=f"proc{pid}"),
        "local_snapshot": snapshot_to_json(local_snap),
        "global_snapshot": snapshot_to_json(global_snap),
    }

    os.makedirs(args.out, exist_ok=True)
    np.savez(os.path.join(args.out, f"proc{pid}.npz"), **store)
    with open(os.path.join(args.out, f"proc{pid}.json"), "w") as fh:
        json.dump(record, fh, indent=2)
    log(pid, "DONE")


if __name__ == "__main__":
    main()
