"""Baseline partitioners + metrics + theory tests (paper Tables 2/4/5)."""
import numpy as np
import pytest

from repro.core import baselines, cep, metrics, ordering, theory
from repro.core.graph import powerlaw_graph, rmat_graph


@pytest.fixture(scope="module")
def g():
    return rmat_graph(8, 8, seed=0)


def _assert_valid_partition(part, e, k):
    part = np.asarray(part)
    assert part.shape == (e,)
    assert part.min() >= 0 and part.max() < k


@pytest.mark.parametrize("method,eb", [
    ("hash_1d", 1.25),
    ("bvc_partition", 1.01),
    # vertex-keyed hashing inherits degree skew on small RMAT graphs
    ("hash_2d", 3.0),
    ("dbh", 3.0),
])
def test_hash_partitioners_valid_and_balanced(g, method, eb):
    k = 16
    part = getattr(baselines, method)(g, k)
    _assert_valid_partition(part, g.num_edges, k)
    assert metrics.edge_balance(part, k) < eb


def test_hdrf_valid_and_better_than_random(g):
    k = 8
    part = baselines.hdrf(g, k)
    _assert_valid_partition(part, g.num_edges, k)
    rf_hdrf = metrics.replication_factor(g.src, g.dst, part, k, g.num_vertices)
    rf_rand = metrics.replication_factor(g.src, g.dst, baselines.hash_1d(g, k), k, g.num_vertices)
    assert rf_hdrf < rf_rand


def test_ne_partition_quality(g):
    k = 8
    part = baselines.ne_partition(g, k)
    _assert_valid_partition(part, g.num_edges, k)
    assert metrics.edge_balance(part, k) < 1.05
    rf_ne = metrics.replication_factor(g.src, g.dst, part, k, g.num_vertices)
    rf_rand = metrics.replication_factor(g.src, g.dst, baselines.hash_1d(g, k), k, g.num_vertices)
    assert rf_ne < rf_rand


def test_geo_cep_competitive_with_ne(g):
    """Paper's headline quality claim: GEO+CEP ≈ NE, both ≪ hash methods."""
    k = 16
    order = ordering.geo_order(g, seed=0)
    s, d = g.src[order], g.dst[order]
    rf_geo = metrics.replication_factor_ordered(s, d, k, g.num_vertices)
    rf_ne = metrics.replication_factor(
        g.src, g.dst, baselines.ne_partition(g, k), k, g.num_vertices
    )
    rf_1d = metrics.replication_factor(g.src, g.dst, baselines.hash_1d(g, k), k, g.num_vertices)
    assert rf_geo < rf_1d * 0.75
    assert rf_geo < rf_ne * 1.5  # same quality class as NE


def test_rcm_order_and_cvp(g):
    order = baselines.rcm_edge_order(g)
    assert np.array_equal(np.sort(order), np.arange(g.num_edges))
    vpart = baselines.spectral_vertex_partition(g, 4)
    assert vpart.shape == (g.num_vertices,)
    epart = baselines.vertex_to_edge_partition(g, vpart, 4)
    _assert_valid_partition(epart, g.num_edges, 4)


def test_bvc_migration_matches_cep_class(g):
    """§6.4.3: BVC and CEP migrate similar edge counts (both are chunk/arc based)."""
    e = g.num_edges
    cep_moved = cep.migrated_edges_exact(e, 8, 9)
    # BVC ring: same chunk arithmetic over the hash order.
    assert cep_moved < cep.migration_cost_random(e, 8, 1)


def test_replication_factor_bounds(g):
    k = 8
    part = baselines.hash_1d(g, k)
    rf = metrics.replication_factor(g.src, g.dst, part, k, g.num_vertices)
    assert 1.0 <= rf <= k
    assert metrics.mirror_count(g.src, g.dst, part, k, g.num_vertices) >= 0


def test_partition_vertex_counts_oracle():
    src = np.array([0, 1, 2, 3], dtype=np.int32)
    dst = np.array([1, 2, 3, 0], dtype=np.int32)
    part = np.array([0, 0, 1, 1], dtype=np.int32)
    counts = metrics.partition_vertex_counts(src, dst, part, 2)
    assert list(counts) == [3, 3]


def test_theory_table2_qualitative():
    rows = theory.table2()
    # Bounds shrink as the power-law gets steeper (α ↑ ⇒ less skew).
    for m in ("Random1D", "Grid2D", "DBH", "Proposed"):
        assert rows[2.8][m] < rows[2.2][m]
    # Paper's published Table 2: proposed < every hash-based method, > NE.
    for a, row in theory.PAPER_TABLE2.items():
        for m in ("Random1D", "Grid2D", "DBH", "HDRF", "BVC"):
            assert row["Proposed"] < row[m]
        assert row["Proposed"] > row["NE"]
    # Thm 6 specialization: 1 + ζ(α−1)/(2ζ(α)).
    from scipy.special import zeta
    a = 2.4
    assert theory.bound_proposed(a, 256, 10**6) == pytest.approx(
        1 + zeta(1.4) / (2 * zeta(2.4)) + 256 / 10**6
    )


def test_powerlaw_graph_is_skewed():
    g2 = powerlaw_graph(5000, alpha=2.2, seed=0)
    deg = g2.degrees()
    assert deg.max() > 10 * deg.mean()
