"""CEP unit + property tests (paper §3.3, Thms 1 & 2)."""
import numpy as np
import pytest
from conftest import hypothesis_or_stub

from repro.core import cep

given, settings, st = hypothesis_or_stub()


def test_paper_example_fig3():
    # |E| = 14, k = 4 → chunks of 3, 3, 4, 4 starting at 0, 3, 6, 10.
    assert [cep.chunk_size(14, 4, p) for p in range(4)] == [3, 3, 4, 4]
    assert [int(cep.chunk_start(14, 4, p)) for p in range(4)] == [0, 3, 6, 10]
    assert list(cep.chunk_bounds(14, 4)) == [0, 3, 6, 10, 14]


@given(e=st.integers(1, 10**9), k=st.integers(1, 512))
@settings(max_examples=200)
def test_chunks_partition_exactly(e, k):
    b = cep.chunk_bounds(e, k)
    assert b[0] == 0 and b[-1] == e
    sizes = np.diff(b)
    assert sizes.sum() == e
    # Perfect balance: sizes differ by at most 1 (ε ≈ 0 in Def. 2).
    assert sizes.max() - sizes.min() <= 1
    # Closed form matches the naive summation (Thm. 1).
    for p in range(0, min(k, 7)):
        naive = sum((e + x) // k for x in range(p))
        assert int(cep.chunk_start(e, k, p)) == naive


@given(e=st.integers(1, 10**6), k=st.integers(1, 128), data=st.data())
@settings(max_examples=150)
def test_id2p_matches_algorithm2(e, k, data):
    i = data.draw(st.integers(0, e - 1))
    assert int(cep.id2p(e, k, i)) == cep.id2p_loop(e, k, i)


@given(e=st.integers(2, 10**7), k=st.integers(1, 64))
@settings(max_examples=100)
def test_id2p_inverts_bounds(e, k):
    b = cep.chunk_bounds(e, k)
    nonempty = b[1:] > b[:-1]
    starts = b[:-1][nonempty]
    ends = (b[1:] - 1)[nonempty]
    ps = np.arange(k)[nonempty]
    assert np.array_equal(cep.id2p(e, k, starts), ps)
    assert np.array_equal(cep.id2p(e, k, ends), ps)


@given(e=st.integers(100, 10**6), k=st.integers(2, 64), x=st.integers(1, 16))
@settings(max_examples=100)
def test_scale_plan_consistency(e, k, x):
    plan = cep.scale_plan(e, k, k + x)
    covered = sorted([(lo, hi) for lo, hi, *_ in plan.moves] + [(lo, hi) for lo, hi, _ in plan.stay])
    # Plan tiles [0, E) exactly.
    pos = 0
    for lo, hi in covered:
        assert lo == pos and hi > lo
        pos = hi
    assert pos == e
    # Every "move" segment really changes partitions; plan is tiny.
    assert len(plan.moves) + len(plan.stay) <= 2 * (k + x) + 2


def test_migration_matches_theorem2():
    # Thm 2 approximation vs exact overlay plan, |E| >> k, x.
    e = 10_000_000
    for k, x in [(8, 1), (16, 1), (16, 4), (32, 8), (64, 1)]:
        exact = cep.migrated_edges_exact(e, k, k + x)
        approx = cep.migration_cost_theorem2(e, k, x)
        assert exact <= e
        assert abs(exact - approx) / e < 0.15, (k, x, exact, approx)


def test_corollary1_half_edges_for_x1():
    e = 10_000_000
    for k in [4, 8, 16, 32, 64]:
        exact = cep.migrated_edges_exact(e, k, k + 1)
        assert abs(exact - e / 2) / e < 0.07, (k, exact)
        # And far less than random hashing's k/(k+1)·|E|.
        assert exact < cep.migration_cost_random(e, k, 1)


def test_scale_in_is_reverse_of_scale_out():
    e = 1_000_000
    out = cep.migrated_edges_exact(e, 16, 20)
    back = cep.migrated_edges_exact(e, 20, 16)
    assert out == back


def test_id2p_is_jax_traceable():
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda i: cep.id2p(14, 4, i))
    got = f(jnp.arange(14))
    expect = [cep.id2p_loop(14, 4, i) for i in range(14)]
    assert list(np.asarray(got)) == expect


def test_id2p_matches_loop_exhaustive_small_grids():
    """Regression for the k > |E| (f = 0) degenerate case: id2p must agree
    with the paper's Algorithm-2 loop for every i on exhaustive small grids,
    scalar and vectorized alike."""
    for e in range(1, 26):
        for k in range(1, 31):  # includes every e < k combination
            ids = np.arange(e)
            vec = np.asarray(cep.id2p(e, k, ids))
            loop = np.array([cep.id2p_loop(e, k, i) for i in range(e)])
            np.testing.assert_array_equal(vec, loop, err_msg=f"e={e} k={k}")
            for i in range(e):  # scalar-int path too
                assert int(cep.id2p(e, k, i)) == loop[i]


def test_id2p_traceable_with_dynamic_num_edges():
    """id2p must trace with |E| itself a tracer (used by jitted rescale
    planning) — including the f = 0 branch, where the old max(f, 1) guard
    raised TracerBoolConversionError."""
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda e, i: cep.id2p(e, 5, i))
    for e, i in [(3, 0), (3, 2), (4, 3), (17, 11), (5, 4)]:
        assert int(f(jnp.asarray(e), jnp.asarray(i))) == cep.id2p_loop(e, 5, i)
