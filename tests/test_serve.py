"""Serving front end: cached query programs match the one-shot entry points,
queries survive rescale-under-ingest and async full rebuilds, and the serve
loop's accounting is internally consistent (ISSUE-9)."""
import numpy as np
import pytest

from repro.core import ordering
from repro.core.graph import rmat_graph
from repro.elastic import autoscale as EA
from repro.elastic import controller as ec
from repro.graphs import engine as ge
from repro.launch import mesh as MM
from repro.launch import serve as LS
from repro.obs import metrics as OM
from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream
from repro.stream.incremental import StreamConfig
from repro.stream.workload import OpenLoopWorkload


def _engine(scale=7, regions=2, seed=0, **kw):
    g = rmat_graph(scale, 8, seed=seed)
    order = ordering.geo_order(g, seed=0)
    src, dst = g.src[order].astype(np.int64), g.dst[order].astype(np.int64)
    orderer = IncrementalOrderer(src, dst, g.num_vertices, regions=regions)
    return g, StreamingEngine(orderer, MM.make_graph_mesh(None), **kw)


# ----------------------------------------------------------- query programs
def test_query_programs_match_one_shot_entry_points():
    _, engine = _engine()
    data = engine.data
    ranks = ge.query_program(
        "pagerank", num_vertices=data.num_vertices, mesh=data.mesh, iterations=20
    )(data.edges, data.mask, data.degrees)
    np.testing.assert_allclose(
        np.asarray(ranks), np.asarray(ge.pagerank(data)), rtol=1e-6, atol=1e-9
    )
    dist, iters = ge.query_program(
        "sssp", num_vertices=data.num_vertices, mesh=data.mesh
    )(data.edges, data.mask, 3)
    ref_dist, ref_iters = ge.sssp(data, source=3)
    np.testing.assert_array_equal(np.asarray(dist), np.asarray(ref_dist))
    assert iters == ref_iters
    lab, _ = ge.query_program("wcc", num_vertices=data.num_vertices, mesh=data.mesh)(
        data.edges, data.mask
    )
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(ge.wcc(data)[0]))


def test_query_program_is_cached_and_source_is_an_operand():
    _, engine = _engine()
    data = engine.data
    kw = dict(num_vertices=data.num_vertices, mesh=data.mesh)
    # Same (kind, layout, params) → the SAME program object (compile paid once).
    assert ge.query_program("sssp", **kw) is ge.query_program("sssp", **kw)
    prog = ge.query_program("sssp", **kw)
    # Different sources reuse the program — source is a traced operand.
    d0, _ = prog(data.edges, data.mask, 0)
    d5, _ = prog(data.edges, data.mask, 5)
    assert np.asarray(d0)[0] == 0.0 and np.asarray(d5)[5] == 0.0
    with pytest.raises(ValueError):
        ge.query_program("nope", **kw)


def test_queries_survive_rescale_and_full_rebuild():
    g, engine = _engine(regions=4)
    qe = LS.QueryEngine(engine)
    base, _ = qe.query("pagerank")
    # Rescale under the query engine's feet: same graph, new layout.
    engine.rescale(3)
    engine.verify_bit_identity()
    after, _ = qe.query("pagerank")
    np.testing.assert_allclose(np.asarray(base), np.asarray(after), rtol=1e-5, atol=1e-8)

    # Async full rebuild: ingest with thresholds that force the full rung,
    # then query the committed pack — answers must reflect the NEW graph.
    g2 = rmat_graph(7, 8, seed=3)
    order = ordering.geo_order(g2, seed=0)
    src, dst = g2.src[order].astype(np.int64), g2.dst[order].astype(np.int64)
    cfg = StreamConfig(partial_drift=1.01, full_drift=1.02, span_regions=2)
    orderer = IncrementalOrderer(src, dst, g2.num_vertices, regions=4, config=cfg)
    eng2 = StreamingEngine(
        orderer, MM.make_graph_mesh(None), span_repair="device",
        full_rebuild="geo", rebuild_flight=1,
    )
    qe2 = LS.QueryEngine(eng2)
    stream = SyntheticStream(g2, batch_size=64, seed=2, burst_every=3, burst_factor=4)
    committed = 0
    for _ in range(20):
        eng2.ingest(stream.batch())
        eng2.monitor()
        _, elapsed = qe2.query("wcc")  # a query between every batch
        assert elapsed > 0.0
        committed = sum(1 for r in eng2.drain_rebuild_events() if r["committed"])
        if committed:
            break
    assert committed >= 1, "stream never committed a full rebuild"
    eng2.verify_bit_identity()
    # The post-rebuild pack answers queries consistently with a from-scratch
    # engine over the same live edge set.
    (lab, _), _ = qe2.query("wcc")
    live = orderer.snapshot()
    fresh = IncrementalOrderer(live[0], live[1], g2.num_vertices, regions=4)
    ref_engine = StreamingEngine(fresh, MM.make_graph_mesh(None))
    ref, _ = ge.wcc(ref_engine.data)
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(ref))


def test_query_engine_single_timing_read_feeds_registry():
    reg = OM.MetricsRegistry()
    _, engine = _engine()
    qe = LS.QueryEngine(engine, registry=reg)
    _, e1 = qe.query("sssp", source=1)
    _, e2 = qe.query("wcc")
    h = reg.histogram("serve.query_measured_s")
    assert h.total == 2 and reg.counter("serve.queries").value == 2.0
    # The recorded samples ARE the returned elapsed values (one read each).
    assert sorted(np.asarray(h._samples).tolist()) == sorted([e1, e2])


# ---------------------------------------------------------------- serve loop
def _loop(ticks=24, base_rate=6.0, k0=2, autoscaler=True):
    g, engine = _engine(regions=k0)
    reg = OM.MetricsRegistry()
    ref = []
    ctl = ec.ElasticController(
        k0, clock=lambda: ref[0].now if ref else 0.0, metrics_registry=reg
    )
    ctl.attach_stream(engine)
    if autoscaler:
        ctl.attach_autoscaler(
            EA.AutoscalePolicy(
                EA.AutoscaleConfig(
                    k_min=1, k_max=8, queue_high_per_host=2.0, queue_low=0.5,
                    ema=0.6, out_cooldown_s=4.0, in_cooldown_s=8.0,
                )
            )
        )
    workload = OpenLoopWorkload(
        num_vertices=g.num_vertices, base_rate=base_rate, day_ticks=ticks,
        diurnal_amp=0.7, burst_every=0, seed=0,
    )
    updates = SyntheticStream(g, batch_size=8, seed=0)
    loop = LS.ServeLoop(
        ctl, workload, updates=updates, registry=reg,
        config=LS.ServeConfig(probe_every=4),
    )
    ref.append(loop)
    return loop, ctl, engine, reg


def test_serve_loop_accounting_is_consistent():
    loop, ctl, engine, reg = _loop()
    loop.run(24)
    loop.drain()
    s = loop.summary()
    assert s["served"] == len(loop.records) > 0
    assert s["backlog"] == 0  # drain retired everything
    assert s["slo_violations"] == sum(1 for r in loop.records if r.violated)
    assert reg.histogram("serve.latency_s").total == s["served"]
    # FIFO on the virtual timeline: retirement ticks are non-decreasing and
    # nothing retires before it arrives.
    ticks = [r.tick for r in loop.records]
    assert ticks == sorted(ticks)
    assert all(r.tick >= r.arrival_tick for r in loop.records)
    # Modeled latency = wait + service, exactly.
    c = loop.config
    for r in loop.records:
        assert r.latency_s == pytest.approx(
            (r.tick - r.arrival_tick) * c.tick_s + c.tick_s / c.per_host_rate
        )
    # Probes ran and measured real device time.
    assert any(r.measured_s > 0 for r in loop.records)
    # One ingest per tick rode along, all on the shared seq log.
    ingests = [e for e in ctl.events if e.kind == "ingest"]
    assert len(ingests) == 24
    seqs = [e.seq for e in ctl.events]
    assert seqs == sorted(seqs)


def test_serve_loop_autoscales_and_stays_bit_identical():
    loop, ctl, engine, _ = _loop(ticks=32, base_rate=10.0)
    loop.run(32)
    assert loop.scale_events, "load never moved k"
    assert all(e.executed for e in loop.scale_events)
    assert engine.k == ctl.k
    assert engine.verify_bit_identity()
    s = loop.summary()
    assert len(s["migrated_bytes_per_decision"]) == len(loop.scale_events)
    assert len(s["moved_edges_per_decision"]) == len(loop.scale_events)
    assert all(m > 0 for m in s["moved_edges_per_decision"])
    assert s["k_path"][0] == 2 and len(s["k_path"]) == len(loop.scale_events) + 1


def test_serve_loop_requires_stream_and_sheds_at_capacity():
    g, _ = _engine()
    ctl = ec.ElasticController(2)
    workload = OpenLoopWorkload(num_vertices=g.num_vertices, base_rate=4.0)
    with pytest.raises(ValueError):
        LS.ServeLoop(ctl, workload)
    # Admission bound: a tiny queue cap sheds the overflow and counts it.
    loop, *_ = _loop(autoscaler=False)
    loop.config = LS.ServeConfig(queue_cap=2, probe_every=0, per_host_rate=0.5)
    loop.run(12)
    assert loop.shed > 0
    assert len(loop.queue) <= 2