"""Elasticity under failure (DESIGN.md §15): preemption-driven shrink,
chunked checkpoint recovery, and the fault-injection drill.

Four layers of proof, cheapest first:

* **Units** — ``LeaseBoard`` liveness semantics on an injected fake clock;
  ``ElasticController.report_failure`` (k_min floor, FailureEvent sequenced
  before the shrink, both autoscaler cooldown windows armed); partition-
  scoped ``restore_partitions`` bit-equality against the live lost ranges.
* **Staleness boundaries** — kill at the batch AFTER a snapshot, kill
  mid-rebuild-flight (the flight is NOT survived; the ladder re-fires),
  kill during a rescale commit (torn WAL barrier ⇒ fall back to the
  pre-scale state). Replay-tail lengths (``wal_steps``) are pinned.
* **Property** — hypothesis races ingest / rescale / async-rebuild / kill
  interleavings; every run must end bit-identical to a no-failure oracle
  that executed the same decisions without losing state, with each
  controller generation's shared seq strictly monotonic (FailureEvents
  included). A fixed-interleaving test covers the same executor when
  hypothesis is absent (house style: conftest.hypothesis_or_stub).
* **The drill** (subprocess, CI multihost job) — a real SIGKILL of one
  process of a 2×4 cluster mid-stream: lease-expiry detection from the
  parent, group reaped with the victim's partial log surfaced, a fresh
  1×4 recovery cluster restoring from the checkpoint directory and
  continuing — final order byte-identical to the host oracle.
"""
import json
import os
import sys
import time

import numpy as np
import pytest

from conftest import hypothesis_or_stub
from repro.checkpoint import CheckpointError, SlotCheckpoint
from repro.elastic import controller as ec
from repro.elastic.autoscale import AutoscaleConfig, AutoscalePolicy
from repro.launch import multihost as MH
from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream
from repro.stream.incremental import StreamConfig

import faults_harness as FH

given, settings, st = hypothesis_or_stub()

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_PROCS = 2
DEVS_PER_PROC = 4
# Long enough that the checkpoint-writing survivor is still mid-stream when
# the parent abandons the group (kill at ~step 4 + ~2s lease expiry at 4
# batches/s ≈ step 13): recovery must genuinely replay a tail.
DRILL_BATCHES = 20
KILL_STEP = 4

_UNSUPPORTED_MARKERS = (
    "gloo",
    "cpu_collectives",
    "collectives_implementation",
    "Unable to initialize backend",
    "UNIMPLEMENTED",
    "DEADLINE_EXCEEDED",
)
_BOOTSTRAP_BANNER = "global devices"


# --------------------------------------------------------------- LeaseBoard
class TestLeaseBoard:
    def test_stamp_age_dead(self, tmp_path):
        clk = [0.0]
        board = MH.LeaseBoard(tmp_path, lease_s=1.0, clock=lambda: clk[0])
        board.stamp(0, 5)
        clk[0] = 0.5
        assert board.dead(2) == []  # p1 never stamped but is younger than t0+1s
        assert board.step(0) == 5 and board.step(1) == -1
        clk[0] = 1.5
        assert board.dead(2) == [0, 1]  # frozen stamp ages like silence
        board.stamp(0, 6)
        assert board.dead(2) == [1]
        assert board.survivors(2) == [0]

    def test_surviving_devices_process_major(self, tmp_path):
        clk = [10.0]
        board = MH.LeaseBoard(tmp_path, lease_s=1.0, clock=lambda: clk[0])
        board.stamp(0, 0)
        board.stamp(1, 0)
        clk[0] = 10.5
        board.stamp(0, 1)  # p1's lease now freezes
        clk[0] = 11.2  # p0 age 0.7 (alive), p1 age 1.2 (expired)
        assert board.survivors(2) == [0]
        assert board.surviving_devices(2, 4) == [0, 1, 2, 3]

    def test_torn_lease_reads_as_never_stamped(self, tmp_path):
        clk = [0.0]
        board = MH.LeaseBoard(tmp_path, lease_s=1.0, clock=lambda: clk[0])
        (tmp_path / "lease_p0.json").write_text('{"step": 3, "t"')  # torn
        assert board.read(0) is None
        assert board.step(0) == -1
        clk[0] = 2.0
        assert 0 in board.dead(1)  # aged from board construction

    def test_wait_for_step(self, tmp_path):
        board = MH.LeaseBoard(tmp_path, lease_s=1.0)
        board.stamp(0, 3)
        assert board.wait_for_step(0, 2, timeout=1.0) == 3
        with pytest.raises(TimeoutError):
            board.wait_for_step(1, 0, timeout=0.05, poll_s=0.01)


# ------------------------------------------------------------ report_failure
class TestReportFailure:
    def _controller(self, n, **kw):
        clk = [100.0]
        ctl = ec.ElasticController(n, clock=lambda: clk[0], **kw)
        return ctl, clk

    def test_failure_sequenced_before_shrink(self):
        ctl, _ = self._controller(8)
        fev, sev = ctl.report_failure([4, 5, 6, 7], detect_s=0.25)
        assert fev.kind == "failure" and fev.k_old == 8 and fev.k_new == 4
        assert fev.detect_s == 0.25
        assert sev is not None and sev.kind == "scale_in" and sev.k_new == 4
        assert fev.seq < sev.seq  # detection precedes the plan in the total order
        assert ctl.events == [fev, sev]
        assert ctl.k == 4

    def test_k_min_floor_retains_hosts(self):
        ctl, _ = self._controller(2, k_min=2)
        fev, sev = ctl.report_failure([0, 1])
        assert fev.lost_hosts == () and fev.k_new == 2
        assert sev is None  # the floor retained every candidate: no shrink
        assert ctl.k == 2

    def test_k_min_partial_clamp(self):
        ctl, _ = self._controller(3, k_min=2)
        fev, sev = ctl.report_failure([1, 2])
        assert fev.k_new == 2 and len(fev.lost_hosts) == 1
        assert "clamped at k_min=2" in fev.reason
        assert sev is not None and sev.k_new == 2

    def test_dead_hosts_not_re_evicted(self):
        ctl, _ = self._controller(4)
        ctl.report_failure([3])
        fev, sev = ctl.report_failure([3, 2])  # 3 already dead
        assert fev.lost_hosts == (2,)
        assert ctl.k == 2

    def test_failure_arms_both_autoscaler_cooldowns(self):
        ctl, clk = self._controller(4)
        pol = AutoscalePolicy(AutoscaleConfig(out_cooldown_s=10.0, in_cooldown_s=30.0))
        ctl.attach_autoscaler(pol)
        ctl.report_failure([3])
        assert pol._next_out_t == 100.0 + 10.0
        assert pol._next_in_t == 100.0 + 30.0

    def test_note_external_scale_never_shortens(self):
        pol = AutoscalePolicy(AutoscaleConfig(out_cooldown_s=10.0, in_cooldown_s=30.0))
        pol._next_in_t = 500.0  # already armed further out
        pol.note_external_scale(100.0)
        assert pol._next_in_t == 500.0
        assert pol._next_out_t == 110.0

    def test_failure_event_roundtrips_jsonl(self):
        from repro.obs import log as OL

        ctl, _ = self._controller(4)
        ctl.report_failure([3], detect_s=0.5, restored_bytes=123, replayed_records=2)
        back = OL.events_from_jsonl(ctl.events_jsonl())
        assert back == ctl.events


# ------------------------------------------------------------ shared helpers
def _drill_graph():
    return FH.build_ordered()


def _make_pipeline(src, dst, num_vertices, regions, cfg, **eng_kw):
    o = IncrementalOrderer(src, dst, num_vertices, regions=regions, config=cfg)
    eng = StreamingEngine(o, span_repair="host", **eng_kw)
    ctl = ec.ElasticController(regions)
    ctl.attach_stream(eng)
    return o, eng, ctl


def _slots(o):
    return o.slot_src.copy(), o.slot_dst.copy(), o.slot_valid.copy()


def _assert_slots_equal(a, b, msg=""):
    assert np.array_equal(a[0], b[0]), f"slot_src diverged {msg}"
    assert np.array_equal(a[1], b[1]), f"slot_dst diverged {msg}"
    assert np.array_equal(a[2], b[2]), f"slot_valid diverged {msg}"


# ------------------------------------------------------- staleness boundaries
class TestStalenessBoundaries:
    """Kill at each durability boundary; pin the replay-tail (``wal_steps``)
    the restore must walk."""

    def _stream(self, g, n):
        s = SyntheticStream(g, batch_size=32, delete_frac=0.3, seed=9)
        return [s.batch() for _ in range(n)]

    def test_kill_at_batch_after_snapshot(self, tmp_path):
        g, src, dst = _drill_graph()
        cfg = FH.drill_config()
        o, eng, ctl = _make_pipeline(src, dst, g.num_vertices, 4, cfg)
        ctl.attach_checkpoint(SlotCheckpoint(tmp_path, interval=4))
        batches = self._stream(g, 6)
        for b in batches:  # snapshots at steps 0 and 4; batch 5 is WAL-only
            ctl.ingest(b)
        want = _slots(o)
        o2, info = SlotCheckpoint(tmp_path, interval=4).restore(config=cfg)
        assert info["manifest_step"] == 4
        assert info["wal_steps"] == [5]  # exactly one record past the snapshot
        assert info["replayed"] == 1
        _assert_slots_equal(_slots(o2), want, "(kill after snapshot)")

    def test_kill_right_on_snapshot_has_empty_tail(self, tmp_path):
        g, src, dst = _drill_graph()
        cfg = FH.drill_config()
        o, eng, ctl = _make_pipeline(src, dst, g.num_vertices, 4, cfg)
        ctl.attach_checkpoint(SlotCheckpoint(tmp_path, interval=4))
        for b in self._stream(g, 5):  # last batch (step 4) snapshots
            ctl.ingest(b)
        o2, info = SlotCheckpoint(tmp_path, interval=4).restore(config=cfg)
        assert info["wal_steps"] == [] and info["replayed"] == 0
        _assert_slots_equal(_slots(o2), _slots(o), "(kill on snapshot)")

    def test_kill_mid_rebuild_flight_aborts_and_ladder_refires(self, tmp_path):
        g, src, dst = _drill_graph()
        cfg = FH.drill_config()
        o, eng, ctl = _make_pipeline(
            src, dst, g.num_vertices, 4, cfg, full_rebuild="geo", rebuild_flight=3
        )
        ctl.attach_checkpoint(SlotCheckpoint(tmp_path, interval=100))
        batches = self._stream(g, 8)
        ctl.ingest(batches[0])  # first batch forces the initial full snapshot
        ctl.ingest(batches[1])
        o.drift = lambda: 1e6
        ctl.ingest(batches[2])  # dispatch
        del o.drift
        assert eng.rebuild_state == "dispatch" and eng.rebuilds_in_flight == 1
        ctl.ingest(batches[3])  # in flight — and this is where we "die"
        assert eng.rebuilds_in_flight == 1
        want = _slots(o)  # flight state never touched the slot arrays

        o2, info = SlotCheckpoint(tmp_path, interval=100).restore(config=cfg)
        assert info["wal_steps"] == [1, 2, 3]  # dispatch batch is a plain record
        _assert_slots_equal(_slots(o2), want, "(kill mid-flight)")
        eng2 = StreamingEngine.from_restored(
            o2, span_repair="host", full_rebuild="geo", rebuild_flight=3
        )
        assert eng2.rebuilds_in_flight == 0  # the flight is NOT survived
        ctl2 = ec.ElasticController(4)
        ctl2.attach_stream(eng2)
        o2.drift = lambda: 1e6  # drift is still past the rung threshold …
        ctl2.ingest(batches[4])
        del o2.drift
        assert eng2.rebuild_state == "dispatch"  # … so the ladder re-fires

    def test_commit_after_flight_forces_full_snapshot(self, tmp_path):
        g, src, dst = _drill_graph()
        cfg = FH.drill_config()
        o, eng, ctl = _make_pipeline(
            src, dst, g.num_vertices, 4, cfg, full_rebuild="geo", rebuild_flight=1
        )
        ck = SlotCheckpoint(tmp_path, interval=100)
        ctl.attach_checkpoint(ck)
        batches = self._stream(g, 5)
        ctl.ingest(batches[0])
        o.drift = lambda: 1e6
        ctl.ingest(batches[1])  # dispatch
        del o.drift
        ctl.ingest(batches[2])  # commit: re-layout ⇒ epoch bump
        assert eng.rebuild_state == "commit"
        o2, info = SlotCheckpoint(tmp_path, interval=100).restore(config=cfg)
        # The commit batch's durability record is a FULL snapshot (slot ops
        # cannot replay across the re-layout), so the tail after it is empty.
        assert info["manifest_step"] == 2 and info["wal_steps"] == []
        _assert_slots_equal(_slots(o2), _slots(o), "(rebuild commit)")

    def test_kill_during_rescale_commit(self, tmp_path):
        g, src, dst = _drill_graph()
        cfg = FH.drill_config()
        o, eng, ctl = _make_pipeline(src, dst, g.num_vertices, 4, cfg)
        ck = SlotCheckpoint(tmp_path, interval=100)
        ctl.attach_checkpoint(ck)
        batches = self._stream(g, 4)
        for b in batches[:3]:
            ctl.ingest(b)
        pre_scale = _slots(o)
        ctl._emit("scale_in", 4, 2, (2, 3), "drill shrink")  # writes the barrier
        post_scale = _slots(o)

        # Committed barrier: restore replays relayout(2) — the re-plan stands.
        o2, info = SlotCheckpoint(tmp_path, interval=100).restore(config=cfg)
        assert o2.regions == 2
        assert info["wal_steps"] == [1, 2]  # batch tail around the barrier
        _assert_slots_equal(_slots(o2), post_scale, "(committed barrier)")

        # Torn barrier (SIGKILL mid-append): the tear truncates the WAL tail,
        # so recovery falls back to the PRE-scale state — the rescale never
        # became durable and simply re-runs after recovery.
        wal = tmp_path / "wal.jsonl"
        lines = wal.read_text().splitlines(keepends=True)
        wal.write_text("".join(lines[:-1]) + lines[-1][: len(lines[-1]) // 2])
        o3, info3 = SlotCheckpoint(tmp_path, interval=100).restore(config=cfg)
        assert o3.regions == 4
        assert info3["wal_steps"] == [1, 2]
        _assert_slots_equal(_slots(o3), pre_scale, "(torn barrier)")


# ------------------------------------------------------- partition-scoped restore
class TestPartitionRestore:
    def test_lost_partitions_bit_equal_and_cheaper(self, tmp_path):
        g, src, dst = _drill_graph()
        cfg = FH.drill_config()
        o, eng, ctl = _make_pipeline(src, dst, g.num_vertices, 8, cfg)
        ck = SlotCheckpoint(tmp_path, interval=3)
        ctl.attach_checkpoint(ck)
        s = SyntheticStream(g, batch_size=32, delete_frac=0.3, seed=9)
        for _ in range(5):  # snapshots at 0, 3; WAL tail covers 4
            ctl.ingest(s.batch())
        spr = o.slots_per_region
        chunks, info = ck.restore_partitions([1, 5])
        for r in (1, 5):
            lo = r * spr
            assert np.array_equal(chunks[r][0], o.slot_src[lo : lo + spr])
            assert np.array_equal(chunks[r][1], o.slot_dst[lo : lo + spr])
            assert np.array_equal(chunks[r][2], o.slot_valid[lo : lo + spr])
        _, full_info = SlotCheckpoint(tmp_path, interval=3).restore(config=cfg)
        assert info["bytes_read"] < full_info["bytes_read"]
        # The recovery bill scales with LOST partitions, not graph size.
        one, one_info = ck.restore_partitions([1])
        assert one_info["bytes_read"] < info["bytes_read"]

    def test_refuses_across_scale_barrier(self, tmp_path):
        g, src, dst = _drill_graph()
        cfg = FH.drill_config()
        o, eng, ctl = _make_pipeline(src, dst, g.num_vertices, 8, cfg)
        ck = SlotCheckpoint(tmp_path, interval=100)
        ctl.attach_checkpoint(ck)
        s = SyntheticStream(g, batch_size=32, seed=9)
        ctl.ingest(s.batch())
        ctl._emit("scale_in", 8, 4, (4, 5, 6, 7), "shrink")
        with pytest.raises(CheckpointError, match="scale"):
            ck.restore_partitions([1])

    def test_out_of_range_partition(self, tmp_path):
        g, src, dst = _drill_graph()
        cfg = FH.drill_config()
        o, eng, ctl = _make_pipeline(src, dst, g.num_vertices, 4, cfg)
        ck = SlotCheckpoint(tmp_path)
        ctl.attach_checkpoint(ck)
        s = SyntheticStream(g, batch_size=32, seed=9)
        ctl.ingest(s.batch())
        with pytest.raises(CheckpointError, match="out of range"):
            ck.restore_partitions([7])


# ----------------------------------------------------------- property (race)
def _run_race(actions, tmp_path):
    """Execute an action interleaving twice — subject (with kills: crash +
    cold restore + failure shrink) and mirror (same decisions, never loses
    state) — and return both final states plus the subject's per-generation
    event logs."""
    g, src, dst = _drill_graph()
    cfg = FH.drill_config()
    stream = SyntheticStream(g, batch_size=32, delete_frac=0.3, seed=11)
    batches = [stream.batch() for _ in range(len(actions) + 1)]
    eng_kw = dict(full_rebuild="geo", rebuild_flight=2)

    o, eng, ctl = _make_pipeline(src, dst, g.num_vertices, 4, cfg, **eng_kw)
    ck = SlotCheckpoint(tmp_path, interval=2)
    ctl.attach_checkpoint(ck)
    bi = 0
    durable = False
    mirror_ops = []  # the decisions the no-failure mirror must repeat
    generations = [ctl]

    def scale_in(c):
        k_old = c.k
        hid = max(h.host_id for h in c.hosts.values() if h.alive)
        c.hosts[hid].alive = False
        c._emit("scale_in", k_old, c.k, (hid,), "race scale_in")

    for act in actions:
        if act in ("ingest", "rebuild") and bi < len(batches):
            if act == "rebuild":
                ctl.stream.orderer.drift = lambda: 1e6
            ctl.ingest(batches[bi])
            if act == "rebuild":
                del ctl.stream.orderer.drift
            mirror_ops.append((act, bi))
            bi += 1
            durable = True
        elif act == "scale_in" and ctl.k > 2:
            scale_in(ctl)
            mirror_ops.append(("scale_in", None))
        elif act == "scale_out":
            ctl.add_hosts(1)
            mirror_ops.append(("scale_out", None))
        elif act == "kill" and durable and ctl.k >= 2:
            # Crash: live state gone; cold-restore, re-home, failure shrink.
            ck = SlotCheckpoint(tmp_path, interval=2)
            o, info = ck.restore(config=cfg)
            eng = StreamingEngine.from_restored(o, span_repair="host", **eng_kw)
            k_cur = o.regions
            ctl = ec.ElasticController(k_cur)
            ctl.attach_stream(eng)
            ctl.attach_checkpoint(ck)
            ctl._batch_step = info["step"]
            fev, sev = ctl.report_failure([k_cur - 1], reason="race kill")
            generations.append(ctl)
            mirror_ops.append(("failure_shrink", sev.k_new if sev else None))

    # Mirror: same decision sequence, no state loss, no checkpoint.
    mo, meng, mctl = _make_pipeline(src, dst, g.num_vertices, 4, cfg, **eng_kw)
    for op, arg in mirror_ops:
        if op in ("ingest", "rebuild"):
            if op == "rebuild":
                mo.drift = lambda: 1e6
            mctl.ingest(batches[arg])
            if op == "rebuild":
                del mo.drift
        elif op == "scale_in":
            scale_in(mctl)
        elif op == "scale_out":
            mctl.add_hosts(1)
        elif op == "failure_shrink" and arg is not None:
            k_old = mctl.k
            lost = sorted(h.host_id for h in mctl.hosts.values() if h.alive)[arg - k_old :]
            for hid in lost:
                mctl.hosts[hid].alive = False
            mctl._emit("scale_in", k_old, arg, tuple(lost), "mirror failure shrink")
    return ctl, mctl, generations


def _assert_race_invariants(ctl, mctl, generations):
    subject, mirror = ctl.stream.orderer, mctl.stream.orderer
    assert subject.regions == mirror.regions
    _assert_slots_equal(_slots(subject), _slots(mirror), "(race vs mirror)")
    ctl.stream.verify_bit_identity()
    mctl.stream.verify_bit_identity()
    for gen_i, c in enumerate(generations):
        seqs = [ev.seq for ev in c.events]
        assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs), (
            f"generation {gen_i}: seq not strictly monotonic: {seqs}"
        )
        if gen_i > 0:  # every recovery generation leads with its FailureEvent
            assert c.events and c.events[0].kind == "failure"


@given(
    actions=st.lists(
        st.sampled_from(["ingest", "scale_in", "scale_out", "rebuild", "kill"]),
        min_size=3,
        max_size=7,
    )
)
@settings(max_examples=6, deadline=None)
def test_race_recovery_matches_no_failure_mirror(actions, tmp_path_factory):
    tmp = tmp_path_factory.mktemp("race")
    _assert_race_invariants(*_run_race(actions, tmp))


def test_race_fixed_interleaving(tmp_path):
    """Deterministic fallback of the property test: one interleaving that
    hits every action kind — ingest, scale both ways, an async rebuild
    racing a kill, and a second kill after the recovery."""
    actions = [
        "ingest", "scale_out", "ingest", "rebuild", "kill",
        "ingest", "scale_in", "ingest", "kill", "ingest",
    ]
    _assert_race_invariants(*_run_race(actions, tmp_path))


# ------------------------------------------------------------------ the drill
@pytest.fixture(scope="module")
def drill(tmp_path_factory):
    """Run the full drill once: live 2×4 cluster, SIGKILL process 1 at batch
    KILL_STEP, lease-expiry detection, group reaped, 1×4 recovery cluster
    restores and continues. Returns every artifact the tests below check."""
    shared = tmp_path_factory.mktemp("drill_shared")
    out = tmp_path_factory.mktemp("drill_out")
    harness = os.path.join(ROOT, "tests", "faults_harness.py")
    cluster = MH.launch_local_cluster(
        N_PROCS,
        DEVS_PER_PROC,
        [harness, "--mode", "live", "--dir", str(shared), "--out", str(out),
         "--batches", str(DRILL_BATCHES)],
        cwd=ROOT,
    )
    board = MH.LeaseBoard(shared / "leases", lease_s=FH.LEASE_S)
    deadline = time.monotonic() + 300.0
    try:
        while board.step(1) < KILL_STEP:
            if cluster.poll(0) is not None or cluster.poll(1) is not None:
                res = cluster.wait(10.0)
                logs = res.format_logs()
                print(logs, file=sys.stderr)
                bootstrapped = any(_BOOTSTRAP_BANNER in p.stdout for p in res.procs)
                if not bootstrapped and any(m in logs for m in _UNSUPPORTED_MARKERS):
                    pytest.skip(f"localhost jax.distributed unsupported here:\n{logs[-2000:]}")
                pytest.fail(f"live cluster died before the kill step:\n{logs}")
            if time.monotonic() > deadline:
                cluster.wait(5.0)
                pytest.fail(f"victim never reached batch {KILL_STEP}")
            time.sleep(0.02)

        t_kill = time.monotonic()
        cluster.kill(1, reason="drill preemption")
        while 1 not in board.dead(N_PROCS):
            assert time.monotonic() - t_kill < 60.0, "lease of the killed process never expired"
            time.sleep(0.05)
        detect_s = time.monotonic() - t_kill
        # The survivor is stranded in its next collective (the victim died
        # holding the group) — a real control plane abandons the group.
        cluster.kill(0, reason="stranded survivor abandoned with the group")
    finally:
        live_res = cluster.wait(30.0)

    recover_res = MH.spawn_local_cluster(
        1,
        DEVS_PER_PROC,
        [harness, "--mode", "recover", "--dir", str(shared), "--out", str(out),
         "--batches", str(DRILL_BATCHES), "--detect-s", f"{detect_s:.6f}",
         "--lost-hosts", "4,5,6,7"],
        timeout=540.0,
        cwd=ROOT,
    )
    if not recover_res.ok:
        logs = recover_res.format_logs()
        print(logs, file=sys.stderr)
        pytest.fail(f"recovery cluster failed:\n{logs}")
    with open(out / "recover.json") as fh:
        record = json.load(fh)
    shards = dict(np.load(out / "recover.npz"))
    return {
        "live": live_res,
        "detect_s": detect_s,
        "record": record,
        "shards": shards,
    }


def _drill_oracle(last_durable: int):
    """Host replay of the drill WITHOUT the failure: same batches, same
    re-plan (8 → 4 after the last durable batch), state never lost. Returns
    (final orderer, restore-point slot triple)."""
    g, src, dst = FH.build_ordered()
    o = IncrementalOrderer(
        src, dst, g.num_vertices, regions=FH.REGIONS, config=FH.drill_config()
    )
    stream = SyntheticStream(g, batch_size=FH.STREAM_BATCH, seed=FH.STREAM_SEED)
    snap = None
    for b in range(DRILL_BATCHES):
        o.apply(stream.batch())
        o.needs_resync = False
        o.drain_ops()
        if b == last_durable:
            snap = _slots(o)
            o.relayout(4)
            o.drain_gather_map()
            o.needs_resync = False
    assert snap is not None
    return o, snap


class TestDrill:
    def test_group_reaped_with_partial_logs(self, drill):
        res = drill["live"]
        assert res.procs[1].returncode == -9  # SIGKILL, reaped (no zombie)
        assert res.procs[0].returncode is not None
        # The victim's PARTIAL log survived, attributably prefixed …
        assert any(
            line.startswith("[p1] ") and "live: batch" in line
            for line in res.procs[1].stdout.splitlines()
        )
        # … and the injected kill is recorded where the logs are read.
        assert "SIGKILL injected" in res.procs[1].stderr

    def test_detection_latency_bounded(self, drill):
        # Expiry can't beat the lease window, and on a quiet box the
        # detector fires within a couple of windows of the kill.
        assert 0.0 < drill["detect_s"] < 10 * FH.LEASE_S
        assert drill["record"]["failure_event"]["detect_s"] == pytest.approx(
            drill["detect_s"], abs=1e-6
        )

    def test_failure_event_and_replan(self, drill):
        fe = drill["record"]["failure_event"]
        assert fe["k_old"] == 8 and fe["k_new"] == 4
        assert fe["lost_hosts"] == [4, 5, 6, 7]
        assert fe["restored_bytes"] > 0
        kinds = drill["record"]["event_kinds"]
        assert kinds[0] == "failure" and kinds[1] == "scale_in"
        seqs = drill["record"]["event_seqs"]
        assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
        assert drill["record"]["k_final"] == 4

    def test_recovery_bit_identical_to_oracle(self, drill):
        last_durable = drill["record"]["restore"]["step"]
        assert 0 <= last_durable < DRILL_BATCHES - 1
        oracle, restore_point = _drill_oracle(last_durable)
        sh = drill["shards"]
        # At the recovery point: the restored order IS the pre-failure order.
        _assert_slots_equal(
            (sh["restore_src"], sh["restore_dst"], sh["restore_valid"]),
            restore_point,
            "(drill restore point)",
        )
        # At the end: the recovered run and the never-failed oracle agree
        # byte-for-byte — exactly-once recovery.
        _assert_slots_equal(
            (sh["final_src"], sh["final_dst"], sh["final_valid"]),
            _slots(oracle),
            "(drill final)",
        )

    def test_recovered_pack_matches_oracle_pack(self, drill):
        from repro.graphs import engine as GE

        last_durable = drill["record"]["restore"]["step"]
        oracle, _ = _drill_oracle(last_durable)
        pack = GE.pack_slots(
            oracle.slot_src, oracle.slot_dst, oracle.slot_valid, 4, oracle.num_vertices
        )
        sh = drill["shards"]
        rows = {}
        for key, data in sh.items():
            if key.startswith("final_edges__"):
                _, lo, hi = key.rsplit("__", 2)
                for r in range(int(lo), int(hi)):
                    rows[r] = data[r - int(lo)]
        got = np.stack([rows[r] for r in sorted(rows)])
        # k=4 on g=4 devices: partition_row is the identity, so the global
        # row order IS partition order.
        assert np.array_equal(got, np.asarray(pack.edges))
