"""Mesh-sharded elastic runtime on 8 real (host) devices.

These tests run in-process and need >= 8 devices, so they are skipped in the
tier-1 suite (1 CPU device) and run by the CI ``multidevice`` job under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``. The same acceptance
properties are also proven inside tier-1 by the subprocess-based test in
tests/test_multidevice.py.
"""
import jax
import numpy as np
import pytest

from repro.core import cep, ordering
from repro.core.graph import rmat_graph
from repro.elastic import controller as ec
from repro.elastic.rescale_exec import EDGE_BYTES, ElasticRescaler
from repro.graphs import engine as E
from repro.launch import mesh as MM
from repro.launch import sharding as SH

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


@pytest.fixture(scope="module")
def ordered():
    g = rmat_graph(8, 6, seed=0)
    order = ordering.geo_order(g, seed=0)
    return g, g.src[order], g.dst[order]


@pytest.fixture(scope="module")
def mesh():
    return MM.make_graph_mesh(8)


@pytest.fixture(scope="module")
def rescaler():
    return ElasticRescaler()


def test_round_robin_device_placement(ordered, mesh):
    """Partition p's buffer rows physically live on graph-axis device p % 8,
    for k below / equal to / above (and not dividing) the device count."""
    g, src, dst = ordered
    dev_order = list(mesh.devices.ravel())
    for k in (5, 8, 12):
        sdata = E.pack_ordered_sharded(src, dst, g.num_vertices, k, mesh)
        assert sdata.k_pad % 8 == 0 and sdata.devices == 8
        m = sdata.rows_per_device
        for shard in sdata.edges.addressable_shards:
            d = dev_order.index(shard.device)
            lo = shard.index[0].start or 0
            assert lo == d * m  # device d holds rows [d·m, (d+1)·m)
            for r in range(lo, lo + m):
                p = SH.row_partition(r, k, 8)
                if p < k:
                    assert SH.partition_device(p, 8) == d


def test_acceptance_8_12_8_bit_identical_and_thm2_cross_device(ordered, mesh, rescaler):
    """The ISSUE's acceptance: executing 8→12→8 on the sharded buffers is
    byte-identical to the single-device pack_ordered oracle, and the reported
    cross-device migrated bytes equal ScalePlan.migrated_bytes (Thm. 2)."""
    g, src, dst = ordered
    d8 = E.pack_ordered_sharded(src, dst, g.num_vertices, 8, mesh)
    plan_out = cep.scale_plan(g.num_edges, 8, 12)
    d12, s_out = rescaler.execute(d8, plan_out, verify=True)
    assert s_out.oracle_checked and s_out.devices == 8
    assert s_out.cross_device_bytes == plan_out.migrated_bytes(EDGE_BYTES)
    assert s_out.cross_device_edges + s_out.on_device_edges == s_out.migrated_edges

    want12 = E.pack_ordered(src, dst, g.num_vertices, 12)
    got12 = E.unshard_engine_data(d12)
    np.testing.assert_array_equal(np.asarray(got12.edges), np.asarray(want12.edges))
    np.testing.assert_array_equal(np.asarray(got12.mask), np.asarray(want12.mask))

    plan_in = cep.scale_plan(g.num_edges, 12, 8)
    back, s_in = rescaler.execute(d12, plan_in, verify=True)
    assert s_in.cross_device_bytes == plan_in.migrated_bytes(EDGE_BYTES)
    orig = E.pack_ordered(src, dst, g.num_vertices, 8)
    got8 = E.unshard_engine_data(back)
    np.testing.assert_array_equal(np.asarray(got8.edges), np.asarray(orig.edges))
    np.testing.assert_array_equal(np.asarray(got8.mask), np.asarray(orig.mask))


@pytest.mark.parametrize("k_old,k_new", [(5, 9), (12, 20), (3, 7), (20, 16), (7, 8)])
def test_sharded_rescale_matches_oracle_awkward_k(ordered, mesh, rescaler, k_old, k_new):
    """k need not equal or divide the device count: padded rows stay masked
    and the executed result still matches the from-scratch pack."""
    g, src, dst = ordered
    sdata = E.pack_ordered_sharded(src, dst, g.num_vertices, k_old, mesh)
    new, stats = rescaler.rescale(sdata, k_new, verify=True)
    assert stats.oracle_checked
    assert stats.cross_device_edges + stats.on_device_edges == stats.migrated_edges
    # Cross-device accounting agrees with the plan + round-robin layout.
    plan = cep.scale_plan(g.num_edges, k_old, k_new)
    want_cross = sum(
        hi - lo for lo, hi, s, d in plan.moves if s % 8 != d % 8
    )
    assert stats.cross_device_edges == want_cross


def test_sharded_roundtrip_bit_identical_on_mesh(ordered, mesh, rescaler):
    g, src, dst = ordered
    d5 = E.pack_ordered_sharded(src, dst, g.num_vertices, 5, mesh)
    d11, _ = rescaler.rescale(d5, 11, verify=True)
    back, _ = rescaler.rescale(d11, 5, verify=True)
    orig = E.pack_ordered(src, dst, g.num_vertices, 5)
    got = E.unshard_engine_data(back)
    np.testing.assert_array_equal(np.asarray(got.edges), np.asarray(orig.edges))
    np.testing.assert_array_equal(np.asarray(got.mask), np.asarray(orig.mask))


def test_gas_apps_on_sharded_buffers_match_replicated(ordered, mesh):
    """PageRank / SSSP / WCC shard_map directly over the distributed rows and
    must agree with the replicated single-buffer engine."""
    g, src, dst = ordered
    ref = E.pack_ordered(src, dst, g.num_vertices, 12)
    tm = MM.make_test_mesh(1, 1)
    sdata = E.pack_ordered_sharded(src, dst, g.num_vertices, 12, mesh)

    np.testing.assert_allclose(
        np.asarray(E.pagerank(sdata, iterations=15)),
        np.asarray(E.pagerank(ref, tm, iterations=15)),
        rtol=1e-6, atol=1e-9,
    )
    ds, its = E.sssp(sdata, source=0)
    dr, itr = E.sssp(ref, tm, source=0)
    assert its == itr
    np.testing.assert_array_equal(np.asarray(ds), np.asarray(dr))
    ls, _ = E.wcc(sdata)
    lr, _ = E.wcc(ref, tm)
    np.testing.assert_array_equal(np.asarray(ls), np.asarray(lr))


def test_gas_after_on_mesh_migration(ordered, mesh, rescaler):
    """The migrated ShardedEngineData is live engine state on the mesh."""
    g, src, dst = ordered
    d8 = E.pack_ordered_sharded(src, dst, g.num_vertices, 8, mesh)
    p8 = np.asarray(E.pagerank(d8, iterations=15))  # before donation consumes d8
    d12, _ = rescaler.rescale(d8, 12)
    p12 = np.asarray(E.pagerank(d12, iterations=15))
    np.testing.assert_allclose(p8, p12, rtol=1e-5, atol=1e-8)


def test_controller_reports_executed_cross_device_traffic(ordered, mesh):
    g, src, dst = ordered
    t = [0.0]
    ctl = ec.ElasticController(8, dead_after_s=5.0, clock=lambda: t[0])
    ctl.attach_engine(E.pack_ordered(src, dst, g.num_vertices, 8), mesh=mesh)
    t[0] = 1.0
    for h in range(7):
        ctl.heartbeat(h, 1)
    t[0] = 5.6  # host 7 missed its beat
    ev = ctl.poll()
    assert ev is not None and ev.kind == "scale_in" and ev.executed
    stats = ctl.rescale_stats[0]
    assert ev.cross_device_bytes == stats.cross_device_bytes > 0
    # 8→7 on 8 devices: every old partition sits alone on its device, so all
    # migrated rows cross a device boundary — the Thm.-2 bytes ARE the traffic.
    assert stats.cross_device_bytes == cep.scale_plan(
        g.num_edges, 8, 7
    ).migrated_bytes(EDGE_BYTES)
    want = E.pack_ordered(src, dst, g.num_vertices, 7)
    got = E.unshard_engine_data(ctl.engine_data)
    np.testing.assert_array_equal(np.asarray(got.edges), np.asarray(want.edges))


def test_sharded_noop_and_degenerate_chunks_on_mesh(mesh):
    g = rmat_graph(4, 1, seed=2)  # tiny: |E| < 8 devices ⇒ zero-size chunks
    order = np.arange(g.num_edges)
    src, dst = g.src[order], g.dst[order]
    sdata = E.pack_ordered_sharded(src, dst, g.num_vertices, 3, mesh)
    same, stats = ElasticRescaler().rescale(sdata, 3)
    assert same is sdata and stats.copy_ops == 0
    np.asarray(same.edges)  # not donated away
    k_new = g.num_edges + 5  # some partitions own zero edges
    new, stats = ElasticRescaler().rescale(sdata, k_new, verify=True)
    assert stats.oracle_checked
    want = E.pack_ordered(src, dst, g.num_vertices, k_new)
    got = E.unshard_engine_data(new)
    np.testing.assert_array_equal(np.asarray(got.edges), np.asarray(want.edges))
