"""Hypothesis property tests on system invariants + reference equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import hypothesis_or_stub

from repro.core import cep, metrics, ordering
from repro.core.graph import rmat_graph
from repro.models import config as MC
from repro.models import layers as L
from repro.models import model as M

given, settings, st = hypothesis_or_stub()


# ------------------------------------------------------------------ orderings
@given(scale=st.integers(4, 7), seed=st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_vertex_order_lift_is_permutation(scale, seed):
    g = rmat_graph(scale, 4, seed=seed)
    rank = np.random.default_rng(seed).permutation(g.num_vertices)
    lifted = ordering.lift_vertex_order(g, rank)
    assert np.array_equal(np.sort(lifted), np.arange(g.num_edges))


@given(scale=st.integers(4, 6), k=st.integers(2, 16), seed=st.integers(0, 3))
@settings(max_examples=15, deadline=None)
def test_rf_bounds_for_any_partition(scale, k, seed):
    g = rmat_graph(scale, 4, seed=seed)
    part = np.random.default_rng(seed).integers(0, k, g.num_edges).astype(np.int32)
    rf = metrics.replication_factor(g.src, g.dst, part, k, g.num_vertices)
    # 1·(touched/|V|) ≤ RF ≤ min(k, avg_degree)·…: use loose-but-true bounds.
    touched = np.unique(np.concatenate([g.src, g.dst])).shape[0]
    assert touched / g.num_vertices <= rf + 1e-9
    assert rf <= 2 * g.num_edges / g.num_vertices + 1e-9  # Σ|V(E_p)| ≤ 2|E|


@given(e=st.integers(10, 10**6), ks=st.tuples(st.integers(1, 64), st.integers(1, 64)))
@settings(max_examples=60, deadline=None)
def test_rescale_is_involution_and_bounded(e, ks):
    k1, k2 = ks
    moved_there = cep.migrated_edges_exact(e, k1, k2)
    moved_back = cep.migrated_edges_exact(e, k2, k1)
    assert moved_there == moved_back
    assert 0 <= moved_there <= e
    if k1 == k2:
        assert moved_there == 0


# ------------------------------------------------------------------ layers
def test_rope_identity_at_position_zero():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 1, 16))
    out = L.rope(x, jnp.zeros((1,), jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6)


def test_rope_is_norm_preserving():
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 8, 32))
    out = L.rope(x, jnp.arange(8))
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(out), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )


@pytest.mark.parametrize("s,bq,bk", [(32, 8, 8), (64, 16, 32), (48, 512, 1024)])
def test_mea_attention_matches_dense_reference(s, bq, bk):
    from repro.kernels import ref

    b, h, hd = 2, 3, 16
    key = jax.random.PRNGKey(s)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, s, hd))
    k = jax.random.normal(kk, (b, h, s, hd))
    v = jax.random.normal(kv, (b, h, s, hd))
    got = L.mea_attention(q, k, v, causal=True, block_q=bq, block_kv=bk)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_chunked_ce_matches_direct():
    b, s, d, v = 2, 16, 8, 50
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (b, s, d))
    emb = jax.random.normal(jax.random.PRNGKey(4), (v, d))
    tgt = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, v)
    got = M.chunked_ce_loss(x, emb, tgt, chunk=4)
    logits = x @ emb.T
    want = -jnp.mean(
        jnp.take_along_axis(jax.nn.log_softmax(logits, -1), tgt[..., None], -1)
    )
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


# ------------------------------------------------------------------ MoE
def _ref_moe(p, x, cfg):
    """Naive per-expert loop reference (no capacity drops)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ np.asarray(p["router"], np.float32)
    logits[:, cfg.num_experts:] = -1e30
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    gv, ei = jax.lax.top_k(probs, cfg.experts_per_token)
    gv = gv / gv.sum(-1, keepdims=True)
    out = np.zeros_like(np.asarray(xf, np.float32))
    for t in range(xf.shape[0]):
        for j in range(cfg.experts_per_token):
            e = int(ei[t, j])
            h = jax.nn.silu(xf[t] @ p["w1"][e]) * (xf[t] @ p["w3"][e])
            out[t] += float(gv[t, j]) * np.asarray(h @ p["w2"][e])
    y = out.reshape(b, s, d)
    if cfg.num_shared_experts:
        y = y + np.asarray(L.mlp_block(p["shared"], x, cfg.act), np.float32)
    return y


def test_moe_gather_dispatch_matches_naive_reference():
    import dataclasses

    cfg = dataclasses.replace(
        MC.ModelConfig(
            name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
            num_kv_heads=2, head_dim=8, d_ff=0, vocab_size=64,
            num_experts=5, experts_per_token=2, moe_d_ff=24,
            capacity_factor=16.0,  # no drops → exact match expected
            num_experts_alloc=8,   # padded experts must carry zero traffic
        )
    )
    rng = np.random.default_rng(0)
    ea = cfg.experts_alloc
    p = {
        "router": rng.standard_normal((cfg.d_model, ea)).astype(np.float32) * 0.5,
        "w1": rng.standard_normal((ea, cfg.d_model, cfg.moe_d_ff)).astype(np.float32) * 0.2,
        "w3": rng.standard_normal((ea, cfg.d_model, cfg.moe_d_ff)).astype(np.float32) * 0.2,
        "w2": rng.standard_normal((ea, cfg.moe_d_ff, cfg.d_model)).astype(np.float32) * 0.2,
    }
    x = jnp.asarray(rng.standard_normal((2, 6, cfg.d_model)), jnp.float32)
    got, aux = L.moe_block({k: jnp.asarray(v) for k, v in p.items()}, x, cfg)
    want = _ref_moe(p, np.asarray(x), cfg)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


# ------------------------------------------------------------------ data
@given(k=st.integers(1, 9), step=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_host_shards_tile_global_batch(k, step):
    from repro.data import pipeline as dp

    dc = dp.DataConfig(vocab_size=97, seq_len=8, global_batch=24)
    gb = dp.global_batch(dc, step)
    got = np.concatenate([dp.host_batch(dc, step, k, h)["tokens"] for h in range(k)])
    np.testing.assert_array_equal(got, gb["tokens"])
