"""Multi-host acceptance: a 2-process × 4-device localhost cluster executes
the PR-2 rescale acceptance (8 → 12 → 8) and the PR-3 rescale-under-ingest
acceptance on ONE global ``graph`` mesh, with migrations crossing a real
process boundary.

The proof deliberately avoids trusting the thing under test: each worker
(tests/multihost_harness.py) writes only the shard rows its own devices hold,
and this parent reassembles the global buffers from both processes' files and
compares them byte-for-byte against oracles computed single-process right
here — the same ``pack_ordered`` / ``pack_slots`` + row-permutation oracles
the 8-device single-process suite uses. Cross-process traffic is re-derived
independently from the ScalePlan overlay and the partition→process map the
cluster reported.

Skips gracefully (with the per-process logs) when the installed jax cannot
form localhost process groups; CI runs it in the dedicated ``multihost`` job.
"""
import json
import os
import sys

import numpy as np
import pytest

from repro.core import cep
from repro.elastic.rescale_exec import EDGE_BYTES
from repro.graphs import engine as E
from repro.launch import multihost as MH
from repro.launch import sharding as SH
from repro.stream import IncrementalOrderer, SyntheticStream
from repro.stream.ingest import IngestStats, StreamRescaleStats

import multihost_harness as H

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_PROCS = 2
DEVS_PER_PROC = 4
G_DEVICES = N_PROCS * DEVS_PER_PROC

_UNSUPPORTED_MARKERS = (
    "gloo",
    "cpu_collectives",
    "collectives_implementation",
    "Unable to initialize backend",
    "UNIMPLEMENTED",
    "DEADLINE_EXCEEDED",
)
# Printed by the harness only once the process group has formed: failures
# AFTER this banner are regressions in the code under test, never an
# unsupported-platform skip — otherwise a deadlocked collective would turn
# the multihost CI job green.
_BOOTSTRAP_BANNER = "global devices"


@pytest.fixture(scope="module")
def cluster(tmp_path_factory):
    """Spawn the 2×4 cluster once; every test reads its artifacts."""
    out = tmp_path_factory.mktemp("multihost")
    res = MH.spawn_local_cluster(
        N_PROCS,
        DEVS_PER_PROC,
        [os.path.join(ROOT, "tests", "multihost_harness.py"), "--out", str(out)],
        timeout=540.0,
        cwd=ROOT,
    )
    if not res.ok:
        logs = res.format_logs()
        print(logs, file=sys.stderr)  # per-process logs for CI diagnosis
        bootstrapped = any(_BOOTSTRAP_BANNER in p.stdout for p in res.procs)
        if not bootstrapped and any(m in logs for m in _UNSUPPORTED_MARKERS):
            pytest.skip(f"localhost jax.distributed unsupported here:\n{logs[-2000:]}")
        pytest.fail(f"multihost harness failed:\n{logs}")
    records = []
    shards = []
    for pid in range(N_PROCS):
        with open(out / f"proc{pid}.json") as fh:
            records.append(json.load(fh))
        shards.append(dict(np.load(out / f"proc{pid}.npz")))
    return records, shards


def reassemble(shards, name: str, global_rows: int) -> np.ndarray:
    """Merge every process's (lo, hi) row blocks into the global array,
    requiring full coverage and byte-agreement on any overlap (replicated
    arrays overlap fully)."""
    rows = {}
    shape_tail = None
    for store in shards:
        for key, data in store.items():
            if not key.startswith(name + "__"):
                continue
            _, lo, hi = key.rsplit("__", 2)
            lo, hi = int(lo), int(hi)
            shape_tail = data.shape[1:]
            for r in range(lo, hi):
                row = data[r - lo]
                if r in rows:
                    assert np.array_equal(rows[r], row), f"{name}: divergent row {r}"
                else:
                    rows[r] = row
    assert shape_tail is not None, f"no shards found for {name}"
    assert sorted(rows) == list(range(global_rows)), (
        f"{name}: rows covered {sorted(rows)} != 0..{global_rows - 1}"
    )
    return np.stack([rows[r] for r in range(global_rows)])


def expected_global_pack(src, dst, num_vertices: int, k: int, g: int):
    """The single-process oracle: pack_ordered at k, rows permuted into the
    device-major layout a g-device mesh holds (pure numpy — no mesh here)."""
    pack = E.pack_ordered(src, dst, num_vertices, k)
    k_pad = SH.padded_partition_count(k, g)
    e_max = int(pack.edges.shape[1])
    edges = np.zeros((k_pad, e_max, 2), dtype=np.int32)
    mask = np.zeros((k_pad, e_max), dtype=np.float32)
    rows = [SH.partition_row(p, k, g) for p in range(k)]
    edges[rows] = np.asarray(pack.edges)
    mask[rows] = np.asarray(pack.mask)
    return edges, mask


class _HostReplayStream:
    """Minimal StreamingEngine protocol over a bare IncrementalOrderer, so the
    harness's controller script replays host-side with the exact decision
    sequence but no devices — the parent's oracle for the stream phase. The
    partial rung runs the numpy MIRROR of the device span repair
    (kernels/span_reorder.py), byte-identical to what the cluster's on-mesh
    program wrote; rescale stats are recomputed from the gather-map overlay
    and the cluster's reported partition→process map, so cross_process_bytes
    can be checked plan-exact without trusting the thing under test."""

    def __init__(self, orderer, g_devices: int | None = None, pmap=None):
        self.o = orderer
        self.g_devices = g_devices
        self.pmap = None if pmap is None else np.asarray(pmap)

    @property
    def k(self) -> int:
        return self.o.regions

    def ingest(self, batch) -> IngestStats:
        counts = self.o.apply(batch)
        self.o.needs_resync = False
        self.o.drain_ops()
        return IngestStats(
            inserted=counts["inserted"], deleted=counts["deleted"],
            skipped=counts["skipped"], scatter_ops=0, resynced=False,
            elapsed_s=0.0, num_edges=self.o.num_edges,
        )

    def monitor(self) -> str:
        esc = self.o.maybe_escalate(
            partial_fn=lambda: self.o.partial_reorder_mirror(emit_ops=False)
        )
        self.o.needs_resync = False
        self.o.drain_ops()
        return esc

    def rescale(self, k_new: int) -> StreamRescaleStats:
        k_old, spr_old = self.o.regions, self.o.slots_per_region
        self.o.relayout(int(k_new))
        gm = self.o.drain_gather_map()
        self.o.needs_resync = False
        spr_new = self.o.slots_per_region
        new_slots = np.flatnonzero(gm >= 0)
        old_slots = gm[new_slots]
        new_regions = new_slots // spr_new
        old_regions = old_slots // spr_old
        moved = int(np.count_nonzero(new_regions != old_regions))
        cross = xproc = 0
        if self.g_devices is not None:
            g = self.g_devices
            changed = new_regions != old_regions
            cross = int(np.count_nonzero(changed & (new_regions % g != old_regions % g)))
            if self.pmap is not None:
                xproc = int(np.count_nonzero(
                    changed & (self.pmap[new_regions % g] != self.pmap[old_regions % g])
                ))
        return StreamRescaleStats(
            k_old=k_old, k_new=int(k_new), num_edges=self.o.num_edges,
            moved_edges=moved, cep_plan_edges=0, cross_device_edges=cross,
            cross_device_bytes=cross * EDGE_BYTES, elapsed_s=0.0,
            cross_process_edges=xproc, cross_process_bytes=xproc * EDGE_BYTES,
        )


def replay_stream_oracle(g, src, dst, pmap=None):
    """Replay the harness's controller script on the host only; returns the
    final orderer (its slot arrays are the byte oracle) + the controller
    (its event log carries the independently recomputed rescale traffic)."""
    from repro.elastic import controller as ec

    o = IncrementalOrderer(
        src.astype(np.int64), dst.astype(np.int64), g.num_vertices,
        regions=8, config=H.stream_config(),
    )
    H.force_partial_baseline(o)
    clock = [0.0]
    ctl = ec.ElasticController(8, dead_after_s=5.0, clock=lambda: clock[0])
    ctl.attach_stream(_HostReplayStream(o, g_devices=G_DEVICES, pmap=pmap))
    stream = SyntheticStream(g, batch_size=H.STREAM_BATCH, seed=H.STREAM_SEED)
    H.stream_script(ctl, stream, clock)
    return o, ctl


def replay_rebuild_oracle(g, src, dst):
    """Host-only replay of the harness's async-rebuild protocol (geo mode,
    flight 1): the double-buffered begin/commit calls are pure host slot
    operations, so the parent reproduces the committed layout without any
    devices — the byte oracle for the cluster's spliced pack."""
    from repro.kernels import full_reorder as FRK

    o = IncrementalOrderer(
        src.astype(np.int64), dst.astype(np.int64), g.num_vertices,
        regions=8, config=H.rebuild_config(),
    )
    stream = SyntheticStream(g, batch_size=H.STREAM_BATCH, seed=H.REBUILD_SEED)

    def step():
        o.apply(stream.batch())
        o.needs_resync = False
        o.drain_ops()

    step()
    step()
    step()  # batch 2: the engine's monitor dispatches AFTER this apply
    u, v, valid = o.slot_src.copy(), o.slot_dst.copy(), o.slot_valid.copy()
    o.begin_full_rebuild()
    cand = FRK.geo_full_candidate(
        u, v, valid, g.num_vertices, o.config.k_min, o.config.k_max
    )
    live = cand[: int(valid.sum())]
    step()  # batch 3 flies — queued for the commit's replay
    assert o.commit_full_rebuild(u[live], v[live])
    o.needs_resync = False
    o.drain_ops()
    step()  # batch 4: quiet post-commit batch
    return o


# --------------------------------------------------------------------- tests
def test_cluster_spans_two_processes(cluster):
    records, _ = cluster
    for pid, rec in enumerate(records):
        assert rec["process_id"] == pid
        assert rec["num_processes"] == N_PROCS
        assert rec["devices"] == G_DEVICES
        assert rec["rescale"]["out"]["devices"] == G_DEVICES
        assert rec["rescale"]["out"]["processes"] == N_PROCS
    # Balanced partition→process map: each process owns devs_per_proc axis
    # positions, and every process reports the same map.
    pmap = records[0]["device_process_map"]
    assert sorted(pmap) == sorted([p for p in range(N_PROCS) for _ in range(DEVS_PER_PROC)])
    assert all(rec["device_process_map"] == pmap for rec in records)


def test_rescale_acceptance_matches_single_process_oracle(cluster):
    """8 → 12 → 8 on the 2-process mesh: gathered shard rows byte-identical
    to the single-process pack oracle at each step."""
    records, shards = cluster
    g, src, dst = H.build_ordered()
    for k, name in ((12, "rescale_k12"), (8, "rescale_k8")):
        want_edges, want_mask = expected_global_pack(src, dst, g.num_vertices, k, G_DEVICES)
        got_edges = reassemble(shards, f"{name}_edges", want_edges.shape[0])
        got_mask = reassemble(shards, f"{name}_mask", want_mask.shape[0])
        np.testing.assert_array_equal(got_edges, want_edges)
        np.testing.assert_array_equal(got_mask, want_mask)
        assert got_edges.dtype == want_edges.dtype and got_mask.dtype == want_mask.dtype


def test_cross_process_bytes_equal_plan_boundary_bytes(cluster):
    """For the one-partition-per-device 8 → 12 rescale the reported
    cross_process_bytes must equal the ScalePlan bytes whose move ranges cross
    the process boundary — recomputed here from the raw overlay and the
    reported partition→process map, independent of RescaleStats."""
    records, _ = cluster
    g, src, dst = H.build_ordered()
    pmap = records[0]["device_process_map"]
    for key, k_old, k_new in (("out", 8, 12), ("in", 12, 8)):
        plan = cep.scale_plan(g.num_edges, k_old, k_new)
        expect_edges = sum(
            hi - lo
            for lo, hi, s, d in plan.moves
            if pmap[s % G_DEVICES] != pmap[d % G_DEVICES]
        )
        for rec in records:
            got = rec["rescale"][key]
            assert got["cross_process_edges"] == expect_edges
            assert got["cross_process_bytes"] == expect_edges * EDGE_BYTES
            # The NIC bill is a strict subset of cross-device traffic, and
            # the one-partition-per-device scale-out moves every migrated
            # edge across devices (PR-2 invariant, now split by process).
            assert got["cross_process_edges"] <= got["cross_device_edges"]
            assert 0 < got["cross_process_edges"] < got["migrated_edges"]
    # Both processes must agree on every non-timing stat (same plan, same map).
    def strip_times(r):
        return {
            key: {f: v for f, v in stats.items() if not f.endswith("_s")}
            for key, stats in r.items()
            if isinstance(stats, dict)
        }

    assert strip_times(records[0]["rescale"]) == strip_times(records[1]["rescale"])


def test_stream_acceptance_matches_host_replay_oracle(cluster):
    """Rescale-under-ingest on the 2-process mesh: the final streaming pack,
    reassembled from per-process shard rows, equals pack_slots of a host-only
    replay of the same controller script, byte for byte."""
    records, shards = cluster
    g, src, dst = H.build_ordered()
    o, ctl = replay_stream_oracle(g, src, dst, pmap=records[0]["device_process_map"])
    assert o.regions == records[0]["stream"]["k_final"] == 7
    assert o.num_edges == records[0]["stream"]["num_edges"]

    pack = E.pack_slots(o.slot_src, o.slot_dst, o.slot_valid, o.regions, g.num_vertices)
    want_edges, want_mask = np.asarray(pack.edges), np.asarray(pack.mask)
    k_pad = SH.padded_partition_count(o.regions, G_DEVICES)
    rows = [SH.partition_row(p, o.regions, G_DEVICES) for p in range(o.regions)]
    glob_edges = np.zeros((k_pad,) + want_edges.shape[1:], want_edges.dtype)
    glob_mask = np.zeros((k_pad,) + want_mask.shape[1:], want_mask.dtype)
    glob_edges[rows] = want_edges
    glob_mask[rows] = want_mask

    got_edges = reassemble(shards, "stream_edges", k_pad)
    got_mask = reassemble(shards, "stream_mask", k_pad)
    got_deg = reassemble(shards, "stream_degrees", g.num_vertices)
    np.testing.assert_array_equal(got_edges, glob_edges)
    np.testing.assert_array_equal(got_mask, glob_mask)
    np.testing.assert_array_equal(got_deg, np.asarray(pack.degrees))


def test_stream_events_ordered_and_consistent_across_processes(cluster):
    records, _ = cluster
    ev0 = records[0]["stream"]["events"]
    for rec in records:
        evs = rec["stream"]["events"]
        assert evs == ev0  # every process sees the identical event log
        seqs = [e["seq"] for e in evs]
        assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
        kinds = [e["kind"] for e in evs]
        assert "ingest" in kinds and ("scale_out" in kinds and "scale_in" in kinds)
        for e in evs:
            if e["kind"] in ("scale_out", "scale_in"):
                assert e["executed"] is True
                assert e["cross_process_bytes"] is not None and e["cross_process_bytes"] >= 0


def test_async_rebuild_on_cluster_matches_host_replay_oracle(cluster):
    """ISSUE-6 satellite: one async full rebuild (geo mode, flight 1) flew
    across the 2-process mesh — dispatch, one flight batch, commit with a
    delta splice — and the committed pack, reassembled from per-process shard
    rows, equals the host-only replay byte for byte. Event logs agree across
    processes and the RebuildEvent is sequenced at completion-commit time."""
    records, shards = cluster
    g, src, dst = H.build_ordered()
    o = replay_rebuild_oracle(g, src, dst)

    rb0 = records[0]["rebuild"]
    for rec in records:
        got = rec["rebuild"]
        assert got == rb0  # every process saw the identical protocol
        assert got["states"] == ["", "", "dispatch", "commit", ""]
        assert got["num_edges"] == o.num_edges
        (rb,) = got["rebuilds"]
        assert rb["mode"] == "geo" and rb["committed"] and not rb["aborted"]
        assert rb["flight_batches"] == H.REBUILD_FLIGHT
        assert rb["replayed_batches"] == 1  # exactly the flight batch
        assert rb["snapshot_edges"] > 0
        # Completion-commit sequencing: the RebuildEvent lands immediately
        # before the IngestEvent of the batch whose monitor committed it.
        seqs = [e["seq"] for e in got["events"]]
        assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
        kinds = [e["kind"] for e in got["events"]]
        assert kinds.count("full_rebuild") == 1
        assert kinds.index("full_rebuild") == 3  # after ingests 0-2, before #3
        # The whole-graph program compiled ONCE; the splice stayed warm too.
        pc = got["program_cache"]
        assert pc["full_reorder"]["misses"] == 1 and pc["splice"]["misses"] == 1

    pack = E.pack_slots(o.slot_src, o.slot_dst, o.slot_valid, o.regions, g.num_vertices)
    want_edges, want_mask = np.asarray(pack.edges), np.asarray(pack.mask)
    k_pad = SH.padded_partition_count(o.regions, G_DEVICES)
    rows = [SH.partition_row(p, o.regions, G_DEVICES) for p in range(o.regions)]
    glob_edges = np.zeros((k_pad,) + want_edges.shape[1:], want_edges.dtype)
    glob_mask = np.zeros((k_pad,) + want_mask.shape[1:], want_mask.dtype)
    glob_edges[rows] = want_edges
    glob_mask[rows] = want_mask
    np.testing.assert_array_equal(reassemble(shards, "rebuild_edges", k_pad), glob_edges)
    np.testing.assert_array_equal(reassemble(shards, "rebuild_mask", k_pad), glob_mask)


def test_stream_partial_escalations_ran_on_device_and_match_replay(cluster):
    """ISSUE-5 satellite: the stream forced partial escalations on the
    2-process cluster — every ingest fired the DEVICE span-repair rung — and
    the host replay's ladder decisions and rescale traffic agree event for
    event, with stream-rescale cross_process_bytes plan-exact against the
    gather-map overlay recomputed here."""
    records, _ = cluster
    g, src, dst = H.build_ordered()
    _, ctl = replay_stream_oracle(g, src, dst, pmap=records[0]["device_process_map"])
    want = [
        {
            "kind": ev.kind,
            "escalation": getattr(ev, "escalation", None),
            "cross_process_bytes": getattr(ev, "cross_process_bytes", None),
        }
        for ev in ctl.events
    ]
    for rec in records:
        evs = rec["stream"]["events"]
        assert len(evs) == len(want)
        ingests = [e for e in evs if e["kind"] == "ingest"]
        assert ingests and all(e["escalation"] == "partial" for e in ingests)
        assert all(e["repair"] == "device" for e in ingests)
        assert rec["stream"]["rung_counts"]["partial"] == len(ingests)
        for got, w in zip(evs, want):
            assert got["kind"] == w["kind"]
            assert got["escalation"] == w["escalation"]
            if got["kind"] in ("scale_out", "scale_in"):
                # The NIC bill the cluster reported == the bill recomputed
                # from the host replay's own gather map and the reported
                # partition→process map.
                assert got["cross_process_bytes"] == w["cross_process_bytes"]
                assert w["cross_process_bytes"] > 0  # 2×4 really crossed the NIC


# ----------------------------------------------------------- observability
REQUIRED_PHASES = {"ingest", "rung", "rebuild", "rescale"}


def test_trace_fragments_merge_into_per_process_phase_tracks(cluster):
    """Observability acceptance (DESIGN.md §13): each process exported a
    valid Chrome-trace fragment covering every runtime phase, and the merged
    trace keeps one track set per process — pid × phase swimlanes — with
    timestamps rebased to a common origin."""
    from repro.obs import trace_export as OX

    records, _ = cluster
    traces = []
    for pid, rec in enumerate(records):
        tr = rec["obs"]["trace"]
        assert OX.validate_chrome_trace(tr) == []
        assert rec["obs"]["spans_dropped"] == 0  # ring sized for the script
        xs = [e for e in tr["traceEvents"] if e["ph"] == "X"]
        assert {e["pid"] for e in xs} == {pid}
        assert REQUIRED_PHASES <= {e["cat"] for e in xs}
        traces.append(tr)
    merged = OX.merge_traces(traces)
    assert OX.validate_chrome_trace(merged) == []
    xs = [e for e in merged["traceEvents"] if e["ph"] == "X"]
    assert {e["pid"] for e in xs} == set(range(N_PROCS))
    for pid in range(N_PROCS):
        assert REQUIRED_PHASES <= {e["cat"] for e in xs if e["pid"] == pid}
    assert min(e["ts"] for e in xs) == 0.0  # rebased to the earliest span
    # Track naming metadata survived the merge for both processes.
    meta = [e for e in merged["traceEvents"] if e["ph"] == "M"]
    assert {e["pid"] for e in meta if e["name"] == "process_name"} == set(range(N_PROCS))


def test_global_metrics_snapshot_equals_sum_of_locals(cluster):
    """The psum_host-aggregated registry snapshot must equal the key-wise sum
    of the per-process snapshots — the SUM-aggregation contract of
    obs/metrics.py, exercised over a real 2-process collective. Exact for
    integer-valued entries (counts, buckets); float-tolerance for wall-clock
    sums (the collective may traverse float32 on non-x64 jax)."""
    records, _ = cluster
    locs = [rec["obs"]["local_snapshot"] for rec in records]
    globs = [rec["obs"]["global_snapshot"] for rec in records]
    assert set(locs[0]) == set(locs[1]) == set(globs[0]) == set(globs[1])
    for key in sorted(globs[0]):
        # Every process computed the identical aggregate (it's a collective).
        np.testing.assert_array_equal(globs[0][key], globs[1][key], err_msg=key)
        want = np.asarray(locs[0][key], np.float64) + np.asarray(locs[1][key], np.float64)
        got = np.asarray(globs[0][key], np.float64)
        if np.all(want == np.round(want)):
            np.testing.assert_array_equal(got, want, err_msg=key)
        else:
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7, err_msg=key)


def test_peak_rss_surfaced_per_process_through_registry(cluster):
    """S6: each process's peak RSS arrives through the metrics registry as a
    process-indexed gauge — own index carries the value, the other zero — so
    the summed global snapshot reads back BOTH peaks individually, replacing
    the old stdout-marker parsing."""
    records, _ = cluster
    for pid, rec in enumerate(records):
        local = rec["obs"]["local_snapshot"]
        own = local[f"process.peak_rss_mb.p{pid}"]
        other = local[f"process.peak_rss_mb.p{1 - pid}"]
        assert own == pytest.approx(rec["obs"]["peak_rss_mb"]) and own > 0.0
        assert other == 0.0
    gs = records[0]["obs"]["global_snapshot"]
    for pid, rec in enumerate(records):
        assert gs[f"process.peak_rss_mb.p{pid}"] == pytest.approx(
            rec["obs"]["peak_rss_mb"], rel=1e-5
        )


def test_event_jsonl_logs_byte_identical_across_processes(cluster):
    """S2: with wall-clock fields zeroed (the only nondeterministic event
    content on deterministic replicas), the structured JSONL event logs of
    the two processes are BYTE-identical, and they round-trip to first-class
    events preserving the shared seq order."""
    from repro.obs import log as OL

    records, _ = cluster
    for phase in ("stream", "rebuild"):
        text0 = records[0][phase]["events_jsonl"]
        assert text0 == records[1][phase]["events_jsonl"]
        events = OL.events_from_jsonl(text0)
        assert len(events) == len(records[0][phase]["events"])
        seqs = [e.seq for e in events]
        assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
        kinds = {type(e).__name__ for e in events}
        assert "IngestEvent" in kinds
    # The rebuild phase's log carries the RebuildEvent at its commit seq.
    rebuild_events = OL.events_from_jsonl(records[0]["rebuild"]["events_jsonl"])
    assert [type(e).__name__ for e in rebuild_events].count("RebuildEvent") == 1
