"""Shared test helpers.

`hypothesis` is an *optional* test dependency (see requirements-test.txt).
Property-based tests must skip cleanly when it is absent instead of breaking
collection for their whole module (which is what a bare
``from hypothesis import given`` does, and a module-level
``pytest.importorskip`` would throw away every deterministic test in the
module too).
"""
import pytest


def hypothesis_or_stub():
    """Return ``(given, settings, st)``.

    With hypothesis installed these are the real objects. Without it,
    ``given(...)`` decorates the test with a skip marker and ``settings`` /
    ``st`` are inert placeholders, so deterministic tests in the same module
    still collect and run.
    """
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st

        return given, settings, st
    except ModuleNotFoundError:
        skip = pytest.mark.skip(reason="hypothesis not installed")

        class _Strategies:
            def __getattr__(self, name):
                return lambda *a, **k: None

        def given(*a, **k):
            return lambda fn: skip(fn)

        def settings(*a, **k):
            return lambda fn: fn

        return given, settings, _Strategies()
