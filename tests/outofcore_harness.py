"""Per-process worker for the out-of-core acceptance (tests/test_outofcore.py).

``spawn_local_cluster`` runs this once per process. Each worker executes the
out-of-core pipeline end to end WITHOUT ever materializing the full edge
list in one array:

* **generate** — the graph is an ``RmatShardPlan``: any process regenerates
  any shard statelessly (data/shards.py), so there is no ingest shuffle;
* **rank + count** (phase A) — the locality rank comes from a bounded
  stride sample; each process bincounts the chunk-load histogram over ITS
  shards only and merges by ``psum_host``;
* **order + commit** (phase B) — chunk membership and per-chunk GEO order
  are pure functions of (plan, rank, splits), so the partitions this
  process's devices own are filled by regenerating + ordering one chunk at
  a time (LRU of one ordered chunk) and committed shard-by-shard via
  ``pack_slots_sharded_stream`` — CEP-chunk sizes per partition, so the
  pack is rescalable;
* **rescale** (phase C) — ElasticRescaler executes 8 → 12 → 8 on the
  committed pack across the process boundary;
* **stream** (phase D) — a bounded-memory ``OutOfCoreIngestor`` (spill
  layer) ingests stateless ``stream_edges`` batches through the elastic
  controller; spill counters ride on the IngestEvents.

The worker writes only its local shard rows plus a stats JSON; the parent
test reassembles the global buffers and byte-compares them against the
in-core oracle composition it computes itself (hier_order_edges +
pack_slots), then gates RF quality against the sequential geo_order oracle.
Peak RSS is printed in the ``PEAK_RSS_MB:`` marker format benchmarks parse.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, ROOT)

from repro.launch import multihost as MH  # noqa: E402  (before jax device init)

SPEC = MH.initialize_from_env()  # must run before the first jax computation

import jax  # noqa: E402

from benchmarks.common import emit_peak_rss, peak_rss_mb  # noqa: E402
from repro.core import cep  # noqa: E402
from repro.core import hier_order as HO  # noqa: E402
from repro.data import shards as DS  # noqa: E402
from repro.elastic import controller as ec  # noqa: E402
from repro.elastic.rescale_exec import ElasticRescaler  # noqa: E402
from repro.graphs import engine as GE  # noqa: E402
from repro.launch import mesh as MM  # noqa: E402
from repro.stream import EdgeUpdateBatch, OutOfCoreIngestor, SpillConfig  # noqa: E402

def _env_int(name: str, default: int) -> int:
    return int(os.environ.get(name, default))


# One config, shared with the parent's oracle (imported from here). The
# REPRO_OC_* overrides exist for benchmarks/bench_outofcore.py, which reuses
# this worker at 2^23+-edge scale; the test defaults stay pinned so the
# parent oracle and the workers always agree.
PLAN = DS.RmatShardPlan(
    scale=_env_int("REPRO_OC_SCALE", 12),
    edge_factor=_env_int("REPRO_OC_EF", 8),
    seed=_env_int("REPRO_OC_SEED", 0),
    num_shards=_env_int("REPRO_OC_SHARDS", 4),
)
CFG = HO.HierConfig(
    num_chunks=_env_int("REPRO_OC_CHUNKS", 4),
    # The working-set knob: chunk_splits adds chunks until none exceeds it.
    # Each chunk materialization pays one full candidate rescan, so the bench
    # raises it at 2^23+ scale (bigger but still bounded chunks) rather than
    # paying O(candidates) per 2^17-edge sliver.
    max_chunk_edges=_env_int("REPRO_OC_MAX_CHUNK", 1 << 17),
    seam_window=0,
    seed=0,
)
SAMPLE_STRIDE = _env_int("REPRO_OC_STRIDE", 2)
SKIP_BLOCKS = bool(_env_int("REPRO_OC_SKIP_BLOCKS", 0))
K_PACK = 8
K_UP = 12
STREAM_BATCHES = 6
STREAM_BATCH_SIZE = 256
SPILL_REGIONS = 64
SPILL_SPR = 128
SPILL_RESIDENT = 8


def log(pid: int, msg: str) -> None:
    print(f"[proc {pid}] {msg}", flush=True)


def save_blocks(store: dict, name: str, arr) -> None:
    for lo, hi, data in MH.local_shard_rows(arr):
        store[f"{name}__{lo}__{hi}"] = data


# --------------------------------------------------------- pure composition
def build_rank_and_splits(mesh):
    """Phase A: sample → rank (every process derives the identical rank from
    the identical bounded sample), then the chunk-load histogram summed over
    processes — each bincounts only its OWN shards."""
    pid = jax.process_index()
    n_procs = jax.process_count()
    sample = DS.sample_edges(PLAN, SAMPLE_STRIDE)
    rank = HO.locality_rank(sample, PLAN.num_vertices, CFG.seed, mode=CFG.rank_mode)
    load_local = np.zeros(PLAN.num_vertices, dtype=np.int32)
    for s in range(pid, PLAN.num_shards, n_procs):
        load_local += HO.chunk_load(rank, DS.shard_edges(PLAN, s)).astype(np.int32)
    load = MH.psum_host(load_local, mesh).astype(np.int64)
    splits = HO.chunk_splits(load, CFG)
    sizes = [int(load[int(splits[c]) : int(splits[c + 1])].sum())
             for c in range(splits.shape[0] - 1)]
    return rank, splits, sizes


class ChunkMaterializer:
    """Ordered chunk edges as a pure function of (plan, rank, splits, cfg):
    regenerate every shard, keep only this chunk's edges (candidate order,
    the same order the in-core oracle filters in), GEO-order the block.
    Caches ONE chunk — the resident bound the pipeline promises."""

    def __init__(self, rank, splits, sizes):
        self.rank, self.splits, self.sizes = rank, splits, sizes
        self.bounds = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)
        self._cached = (-1, None)

    def chunk(self, c: int) -> np.ndarray:
        if self._cached[0] == c:
            return self._cached[1]
        blocks = []
        for s in range(PLAN.num_shards):
            es = DS.shard_edges(PLAN, s)
            cid = HO.chunk_of_edges(self.splits, self.rank, es)
            blocks.append(es[cid == c])
        block = np.concatenate(blocks) if blocks else np.empty((0, 2), np.int64)
        perm = HO.order_edge_block(block, CFG, seed=CFG.seed + c)
        self._cached = (c, block[perm])
        return self._cached[1]

    def ordered_range(self, lo: int, hi: int) -> np.ndarray:
        """Edges [lo, hi) of the global ordered sequence — touches only the
        chunks overlapping the range."""
        out = []
        c = int(np.searchsorted(self.bounds, lo, side="right") - 1)
        while lo < hi:
            ce = self.chunk(c)
            s = lo - int(self.bounds[c])
            e = min(hi, int(self.bounds[c + 1])) - int(self.bounds[c])
            out.append(ce[s:e])
            lo += e - s
            c += 1
        return np.concatenate(out) if out else np.empty((0, 2), np.int64)


def commit_pack(mat: ChunkMaterializer, mesh):
    """Phase B commit: partition p holds CEP chunk p of the ordered sequence
    (prefix-valid slots, so the pack is rescalable by range copies), staged
    one partition at a time through pack_slots_sharded_stream."""
    total = int(mat.bounds[-1])
    cep_bounds = cep.chunk_bounds(total, K_PACK)
    spr = int(np.diff(cep_bounds).max())

    def part_fn(p):
        lo, hi = int(cep_bounds[p]), int(cep_bounds[p + 1])
        ed = mat.ordered_range(lo, hi)
        src = np.zeros(spr, dtype=np.int64)
        dst = np.zeros(spr, dtype=np.int64)
        valid = np.zeros(spr, dtype=bool)
        n = ed.shape[0]
        src[:n], dst[:n], valid[:n] = ed[:, 0], ed[:, 1], True
        return src, dst, valid

    return GE.pack_slots_sharded_stream(part_fn, K_PACK, PLAN.num_vertices, mesh, spr)


def run_rescale_phase(data, store: dict) -> dict:
    pid = jax.process_index()
    rescaler = ElasticRescaler()
    n = data.num_edges
    d_up, s_out = rescaler.execute(data, cep.scale_plan(n, K_PACK, K_UP), recheck=False)
    log(pid, f"{K_PACK}->{K_UP} executed: cross_process_bytes={s_out.cross_process_bytes}")
    if not SKIP_BLOCKS:
        save_blocks(store, "rescale_up_edges", d_up.edges)
        save_blocks(store, "rescale_up_mask", d_up.mask)
    d_back, s_in = rescaler.execute(d_up, cep.scale_plan(n, K_UP, K_PACK), recheck=False)
    log(pid, f"{K_UP}->{K_PACK} executed: cross_process_bytes={s_in.cross_process_bytes}")
    if not SKIP_BLOCKS:
        save_blocks(store, "rescale_back_edges", d_back.edges)
        save_blocks(store, "rescale_back_mask", d_back.mask)
    return {
        "out": {"cross_process_bytes": s_out.cross_process_bytes,
                "migrated_edges": s_out.migrated_edges},
        "in": {"cross_process_bytes": s_in.cross_process_bytes,
               "migrated_edges": s_in.migrated_edges},
    }


def run_stream_phase() -> dict:
    """Phase D: bounded-memory ingest tail. Every process runs the identical
    deterministic script — the parent asserts both landed the same state."""
    ing = OutOfCoreIngestor(
        PLAN.num_vertices, SPILL_REGIONS, SPILL_SPR,
        config=SpillConfig(max_resident=SPILL_RESIDENT),
    )
    ctl = ec.ElasticController(jax.process_count())
    ctl.attach_stream(ing)
    inserted = skipped = 0
    for b in range(STREAM_BATCHES):
        ins = DS.stream_edges(PLAN, b, STREAM_BATCH_SIZE)
        ev = ctl.ingest(EdgeUpdateBatch(insert=ins, delete=np.empty((0, 2), np.int64)))
        inserted += ev.inserted
        skipped += ev.skipped
    last = ctl.events[-1]
    return {
        "num_edges": ing.num_edges,
        "inserted": inserted,
        "skipped": skipped,
        "resident": ing.store.resident,
        "spill": dict(last.spill),
        "seqs": [e.seq for e in ctl.events],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    args = ap.parse_args()
    pid = jax.process_index()
    log(pid, f"{jax.process_count()} processes, {len(jax.local_devices())} local / "
             f"{len(jax.devices())} global devices")

    mesh = MM.make_graph_mesh()
    store: dict = {}
    wall = {}

    t0 = time.perf_counter()
    rank, splits, sizes = build_rank_and_splits(mesh)
    wall["rank"] = time.perf_counter() - t0
    log(pid, f"phase A: {len(sizes)} chunks, sizes={sizes} "
             f"(peak rss {peak_rss_mb(include_children=False):.0f} MB)")
    mat = ChunkMaterializer(rank, splits, sizes)

    t0 = time.perf_counter()
    data = commit_pack(mat, mesh)
    wall["commit"] = time.perf_counter() - t0
    log(pid, f"phase B: committed k={data.k} |E|={data.num_edges} "
             f"(peak rss {peak_rss_mb(include_children=False):.0f} MB)")
    if not SKIP_BLOCKS:
        save_blocks(store, "commit_edges", data.edges)
        save_blocks(store, "commit_mask", data.mask)
        save_blocks(store, "commit_degrees", data.degrees)

    t0 = time.perf_counter()
    rescale = run_rescale_phase(data, store)
    wall["rescale"] = time.perf_counter() - t0
    log(pid, f"phase C: rescaled (peak rss {peak_rss_mb(include_children=False):.0f} MB)")
    t0 = time.perf_counter()
    stream = run_stream_phase()
    wall["stream"] = time.perf_counter() - t0

    record = {
        "process_id": pid,
        "num_processes": jax.process_count(),
        "devices": len(jax.devices()),
        "splits": [int(x) for x in splits],
        "chunk_sizes": [int(s) for s in sizes],
        "num_edges": int(data.num_edges),
        "rescale": rescale,
        "stream": stream,
        "wall": {k: round(v, 3) for k, v in wall.items()},
    }

    os.makedirs(args.out, exist_ok=True)
    np.savez(os.path.join(args.out, f"proc{pid}.npz"), **store)
    with open(os.path.join(args.out, f"proc{pid}.json"), "w") as fh:
        json.dump(record, fh, indent=2)
    emit_peak_rss()
    log(pid, "DONE")


if __name__ == "__main__":
    main()
