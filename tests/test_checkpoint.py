"""Checkpoint store (checkpoint/store.py): the on-disk CEP-chunk layout
contract, round-tripping the streaming pack_slots layout plus the orderer's
slot state, resharded (k → k') restore, and the Thm.-2 bytes-touched
accounting — the checkpoint path the out-of-core pipeline leans on when a
preempted host's replacement pulls only its own chunk."""
import json

import numpy as np
import pytest

from repro.checkpoint import store
from repro.core import cep, ordering
from repro.core.graph import rmat_graph
from repro.graphs import engine as GE
from repro.stream import IncrementalOrderer, SyntheticStream


@pytest.fixture(scope="module")
def slots():
    """Drifted slot arrays: stream a few batches so the slot array has real
    gaps/tombstones — the layout a checkpoint must preserve exactly."""
    g = rmat_graph(7, 6, seed=0)
    order = ordering.geo_order(g, seed=0)
    o = IncrementalOrderer(
        g.src[order].astype(np.int64), g.dst[order].astype(np.int64),
        g.num_vertices, regions=4,
    )
    stream = SyntheticStream(g, batch_size=48, delete_frac=0.3, seed=5)
    for _ in range(6):
        o.apply(stream.batch())
    o.needs_resync = False
    o.drain_ops()
    return g, o


def orderer_tree(g, o):
    """The checkpointable orderer state: the slot triple IS the stream's
    durable state (dicts/devices rebuild from it)."""
    return {
        "slot": {
            "src": o.slot_src.copy(),
            "dst": o.slot_dst.copy(),
            "valid": o.slot_valid.copy(),
        },
        "meta": np.asarray([g.num_vertices, o.regions], dtype=np.int64),
    }


# ------------------------------------------------------------ layout contract
def test_shard_files_hold_exact_cep_chunks(tmp_path, slots):
    """Disk contract: shard_<h>.npz holds, per tensor, exactly the CEP chunk
    [bounds[h], bounds[h+1]) of the FLATTENED tensor — so a replacement host
    can address its chunk without reading any other shard."""
    g, o = slots
    tree = orderer_tree(g, o)
    k = 5
    d = store.save(tree, tmp_path, step=2, k_shards=k)
    manifest = json.loads((d / "manifest.json").read_text())
    assert manifest["step"] == 2 and manifest["k_shards"] == k
    named = {t["name"]: t for t in manifest["tensors"]}
    assert set(named) == {"slot/src", "slot/dst", "slot/valid", "meta"}
    for h in range(k):
        with np.load(d / f"shard_{h}.npz") as z:
            for name, t in named.items():
                flat = np.asarray(tree["slot"][name.split("/")[1]] if "/" in name
                                  else tree[name]).reshape(-1)
                b = cep.chunk_bounds(flat.shape[0], k)
                np.testing.assert_array_equal(z[name], flat[int(b[h]):int(b[h + 1])])


def test_chunks_partition_each_tensor(tmp_path, slots):
    """Concatenating every shard's chunk of a tensor reproduces the flattened
    tensor with nothing dropped or duplicated."""
    g, o = slots
    tree = orderer_tree(g, o)
    k = 3
    d = store.save(tree, tmp_path, step=0, k_shards=k)
    chunks = []
    for h in range(k):
        with np.load(d / f"shard_{h}.npz") as z:
            chunks.append(z["slot/src"])
    np.testing.assert_array_equal(np.concatenate(chunks), o.slot_src)


# ----------------------------------------------- pack_slots layout round-trip
@pytest.mark.parametrize("k_new", [4, 6, 2])
def test_pack_slots_layout_roundtrip_resharded(tmp_path, slots, k_new):
    """The full streaming pack (edges/mask/degrees, scratch column included)
    plus the orderer slot state round-trips byte-exactly through save at k=4
    and restore at any k' — resharding must never touch a byte's VALUE, only
    where it lives."""
    g, o = slots
    pack = GE.pack_slots(o.slot_src, o.slot_dst, o.slot_valid, o.regions, g.num_vertices)
    tree = dict(orderer_tree(g, o), pack={
        "edges": np.asarray(pack.edges),
        "mask": np.asarray(pack.mask),
        "degrees": np.asarray(pack.degrees),
    })
    store.save(tree, tmp_path, step=7, k_shards=4)
    restored, bytes_touched = store.restore(tmp_path, 7, k_new=k_new, template=tree)
    for name in ("src", "dst", "valid"):
        np.testing.assert_array_equal(restored["slot"][name], tree["slot"][name])
        assert restored["slot"][name].dtype == tree["slot"][name].dtype
    for name in ("edges", "mask", "degrees"):
        np.testing.assert_array_equal(restored["pack"][name], tree["pack"][name])
    # Internal consistency: re-packing the restored slot state reproduces the
    # restored pack — slot state and pack stayed mutually coherent.
    repack = GE.pack_slots(
        restored["slot"]["src"], restored["slot"]["dst"], restored["slot"]["valid"],
        o.regions, g.num_vertices,
    )
    np.testing.assert_array_equal(np.asarray(repack.edges), restored["pack"]["edges"])
    np.testing.assert_array_equal(np.asarray(repack.mask), restored["pack"]["mask"])
    assert (bytes_touched == 0) == (k_new == 4)


def test_bytes_touched_matches_cep_model(tmp_path, slots):
    """bytes_touched is exactly Σ_tensors migrated_edges_exact(|T|, k, k')
    · itemsize — the Thm.-2 restore bill, not a full-reshuffle bill."""
    g, o = slots
    tree = orderer_tree(g, o)
    k_old, k_new = 4, 7
    store.save(tree, tmp_path, step=1, k_shards=k_old)
    _, bytes_touched = store.restore(tmp_path, 1, k_new=k_new)
    expect = 0
    for _, a in (
        ("slot/src", o.slot_src), ("slot/dst", o.slot_dst),
        ("slot/valid", o.slot_valid), ("meta", np.zeros(2, np.int64)),
    ):
        a = np.asarray(a)
        expect += cep.migrated_edges_exact(a.size, k_old, k_new) * a.itemsize
    assert bytes_touched == expect
    # The whole point: far less than re-reading everything.
    total_bytes = sum(np.asarray(a).nbytes for a in
                      (o.slot_src, o.slot_dst, o.slot_valid)) + 16
    assert bytes_touched < total_bytes


def test_restore_without_template_returns_named_dict(tmp_path, slots):
    g, o = slots
    store.save(orderer_tree(g, o), tmp_path, step=4, k_shards=3)
    arrays, bytes_touched = store.restore(tmp_path, 4, k_new=3)
    assert set(arrays) == {"slot/src", "slot/dst", "slot/valid", "meta"}
    np.testing.assert_array_equal(arrays["slot/valid"], o.slot_valid)
    assert bytes_touched == 0


def test_tiny_tensor_survives_more_shards_than_elements(tmp_path):
    """A tensor with fewer elements than shards (and a scalar) must still
    round-trip: trailing shards carry empty chunks, not garbage."""
    tree = {"tiny": np.arange(3, dtype=np.int32), "scalar": np.float32(2.5)}
    store.save(tree, tmp_path, step=0, k_shards=6)
    arrays, _ = store.restore(tmp_path, 0, k_new=2)
    np.testing.assert_array_equal(arrays["tiny"], tree["tiny"])
    assert arrays["scalar"].shape == () and float(arrays["scalar"]) == 2.5

# -------------------------------------------------------------- error paths
def test_restore_missing_step_is_typed(tmp_path, slots):
    g, o = slots
    store.save(orderer_tree(g, o), tmp_path, step=3, k_shards=2)
    with pytest.raises(store.MissingStepError, match="step 9"):
        store.restore(tmp_path, 9, k_new=2)
    assert issubclass(store.MissingStepError, store.CheckpointError)


def test_restore_mismatched_template_treedef(tmp_path, slots):
    """A template whose treedef names different leaves must fail loudly with
    BOTH sides of the diff — not silently reshape into the wrong pytree."""
    g, o = slots
    store.save(orderer_tree(g, o), tmp_path, step=0, k_shards=2)
    bad = {"slot": {"src": o.slot_src, "dst": o.slot_dst}, "extra": np.zeros(3)}
    with pytest.raises(store.TemplateMismatchError) as ei:
        store.restore(tmp_path, 0, k_new=2, template=bad)
    assert "extra" in str(ei.value) and "slot/valid" in str(ei.value)


def test_restore_missing_shard_file(tmp_path, slots):
    g, o = slots
    d = store.save(orderer_tree(g, o), tmp_path, step=1, k_shards=3)
    (d / "shard_1.npz").unlink()
    with pytest.raises(store.CorruptShardError, match="shard_1.npz missing"):
        store.restore(tmp_path, 1, k_new=3)


def test_restore_truncated_shard_file(tmp_path, slots):
    """A partially written shard (torn npz) is CorruptShardError, never a raw
    zipfile/np.load exception leaking through."""
    g, o = slots
    d = store.save(orderer_tree(g, o), tmp_path, step=1, k_shards=3)
    blob = (d / "shard_2.npz").read_bytes()
    (d / "shard_2.npz").write_bytes(blob[: len(blob) // 2])
    with pytest.raises(store.CorruptShardError, match="shard_2.npz"):
        store.restore(tmp_path, 1, k_new=3)


def test_restore_wrong_chunk_shape(tmp_path, slots):
    """A shard whose chunk length disagrees with the manifest bounds is
    corrupt even when the npz itself parses."""
    g, o = slots
    d = store.save(orderer_tree(g, o), tmp_path, step=2, k_shards=2)
    with np.load(d / "shard_0.npz") as z:
        tensors = {n: z[n] for n in z.files}
    tensors["slot/src"] = tensors["slot/src"][:-1]
    np.savez(d / "shard_0.npz", **tensors)
    with pytest.raises(store.CorruptShardError, match="manifest chunk"):
        store.restore(tmp_path, 2, k_new=2)


def test_slot_checkpoint_restore_without_manifest(tmp_path):
    ck = store.SlotCheckpoint(tmp_path)
    with pytest.raises(store.MissingStepError, match="no manifest"):
        ck.restore()


def _fresh_ck_pipeline(tmp_path, slots, interval=2):
    g, o_seed = slots
    o = IncrementalOrderer(
        o_seed.slot_src[o_seed.slot_valid].copy(),
        o_seed.slot_dst[o_seed.slot_valid].copy(),
        g.num_vertices, regions=4,
    )
    ck = store.SlotCheckpoint(tmp_path, interval=interval)
    stream = SyntheticStream(g, batch_size=32, delete_frac=0.3, seed=7)
    for step in range(4):
        b = stream.batch()
        o.apply(b)
        o.needs_resync = False
        o.drain_ops()
        ck.note_batch(o, b, step)
    return o, ck


def test_slot_checkpoint_missing_chunk_file(tmp_path, slots):
    o, ck = _fresh_ck_pipeline(tmp_path, slots)
    victim = next(tmp_path.glob("chunk_r2_s*.npz"))
    victim.unlink()
    with pytest.raises(store.CorruptShardError, match="chunk_r2"):
        store.SlotCheckpoint(tmp_path).restore()


def test_slot_checkpoint_truncated_chunk_file(tmp_path, slots):
    o, ck = _fresh_ck_pipeline(tmp_path, slots)
    victim = next(tmp_path.glob("chunk_r1_s*.npz"))
    blob = victim.read_bytes()
    victim.write_bytes(blob[: len(blob) // 2])
    with pytest.raises(store.CorruptShardError, match="unreadable"):
        store.SlotCheckpoint(tmp_path).restore()


def test_slot_checkpoint_manifest_missing_region(tmp_path, slots):
    o, ck = _fresh_ck_pipeline(tmp_path, slots)
    m = max(tmp_path.glob("manifest_*.json"),
            key=lambda p: int(p.stem.split("_")[1]))
    doc = json.loads(m.read_text())
    del doc["chunk_step"]["3"]
    m.write_text(json.dumps(doc))
    with pytest.raises(store.CorruptShardError, match="lacks region 3"):
        store.SlotCheckpoint(tmp_path).restore()
