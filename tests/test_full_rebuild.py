"""Async on-mesh full rebuild (DESIGN.md §11): the whole-graph GEO re-order
kernel's host/device bit identity, the double-buffered dispatch → flight →
commit protocol through the StreamingEngine + ElasticController, the abort
path, the anticipation/shadow extensions of the escalation ladder, and an
interleaving property test mixing async rebuilds with ingest and rescales."""
import logging

import numpy as np
import pytest
from conftest import hypothesis_or_stub

from repro.core import ordering
from repro.core.graph import rmat_graph
from repro.elastic import controller as ec
from repro.kernels import full_reorder as FRK
from repro.launch import mesh as MM
from repro.stream import (
    IncrementalOrderer,
    StreamConfig,
    StreamingEngine,
    SyntheticStream,
)

given, settings, st = hypothesis_or_stub()


@pytest.fixture(scope="module")
def ordered():
    g = rmat_graph(7, 6, seed=0)
    order = ordering.geo_order(g, seed=0)
    return g, g.src[order].astype(np.int64), g.dst[order].astype(np.int64)


def make_orderer(ordered, regions=4, **cfg):
    g, src, dst = ordered
    config = StreamConfig(**cfg) if cfg else StreamConfig()
    return g, IncrementalOrderer(src, dst, g.num_vertices, regions=regions, config=config)


def drifted_slots(ordered, batches=6, seed=5):
    """Slot arrays with real drift + dead slots: stream a few batches."""
    g, o = make_orderer(ordered)
    stream = SyntheticStream(g, batch_size=48, delete_frac=0.3, seed=seed)
    for _ in range(batches):
        o.apply(stream.batch())
    o.needs_resync = False
    o.drain_ops()
    return g, o


# --------------------------------------------------------- kernel differential
def test_geo_full_candidate_matches_host_geo_order(ordered):
    """The geo candidate IS host geo_order expressed over slot ids: applying
    it to the slot arrays reproduces geo_order's edge sequence exactly, with
    dead slots packed last."""
    g, o = drifted_slots(ordered)
    cand = FRK.geo_full_candidate(o.slot_src, o.slot_dst, o.slot_valid, g.num_vertices)
    cap = o.slot_valid.shape[0]
    assert sorted(cand.tolist()) == list(range(cap))  # a true permutation
    n_live = int(o.slot_valid.sum())
    live = cand[:n_live]
    assert o.slot_valid[live].all() and not o.slot_valid[cand[n_live:]].any()
    gg = o.graph()
    order = ordering.geo_order(gg, o.config.k_min, o.config.k_max, seed=0)
    np.testing.assert_array_equal(o.slot_src[live], gg.src[order])
    np.testing.assert_array_equal(o.slot_dst[live], gg.dst[order])


def test_full_order_host_device_bit_identity(ordered):
    """The step-parallel greedy: numpy mirror == traced program, byte for
    byte, dead slots included (they sort last)."""
    g, o = drifted_slots(ordered)
    u, v, valid = o.slot_src, o.slot_dst, o.slot_valid
    n_live = int(valid.sum())
    deg = np.bincount(np.concatenate([u[valid], v[valid]]), minlength=1)
    alpha, beta, delta = FRK.greedy_params(
        n_live, o.config.k_min, o.config.k_max, int(deg.max())
    )
    permpos = FRK.fallback_positions(g.num_vertices)
    host = FRK.full_order_host(u, v, valid, g.num_vertices, alpha, beta, delta, permpos)
    dev = np.asarray(
        FRK.full_order_device(
            u.astype(np.int32), v.astype(np.int32), valid, g.num_vertices,
            np.int32(alpha), np.int32(beta), np.int32(delta), permpos.astype(np.int32),
        )
    )
    np.testing.assert_array_equal(host, dev.astype(np.int64))
    assert valid[host[:n_live]].all() and not valid[host[n_live:]].any()


def test_select_full_order_never_worse_than_incumbent(ordered):
    """Candidate selection with the incumbent (identity) as the candidate:
    the chosen order's exact objective can never exceed the incumbent's."""
    g, o = drifted_slots(ordered)
    u, v, valid = o.slot_src, o.slot_dst, o.slot_valid
    n_live = int(valid.sum())
    deg = np.bincount(np.concatenate([u[valid], v[valid]]), minlength=1)
    alpha, beta, delta = FRK.greedy_params(
        n_live, o.config.k_min, o.config.k_max, int(deg.max())
    )
    permpos = FRK.fallback_positions(g.num_vertices)
    ks = FRK.eval_ks_full(o.config.k_min, o.config.k_max, o.regions)
    incumbent = FRK.identity_candidate(valid)
    chosen, chose_cand = FRK.select_full_order_host(
        u, v, valid, g.num_vertices, incumbent, ks, alpha, beta, delta, permpos
    )
    obj_chosen = FRK.full_objective_host(u, v, valid, chosen, ks)
    obj_inc = FRK.full_objective_host(u, v, valid, incumbent, ks)
    assert obj_chosen <= obj_inc
    if chose_cand:  # the candidate wins only on a STRICT improvement
        assert obj_inc < FRK.full_objective_host(
            u, v, valid,
            FRK.full_order_host(u, v, valid, g.num_vertices, alpha, beta, delta, permpos),
            ks,
        )


def test_greedy_params_rejects_int32_overflow():
    with pytest.raises(ValueError, match="overflow int32"):
        FRK.greedy_params(2**28, 2, 64, max_degree=1000)


def test_greedy_fits_int32_boundary_exact():
    """The predicate is pinned at exactly 2^31: with k_min=k_max=1 the bound
    collapses to E·(max_degree+1), so E=2^21, d+1=2^10 lands exactly ON the
    bound (reject) and E=2^21−1 lands one step under (fit) — and
    ``greedy_params`` agrees with the predicate on both sides."""
    assert not FRK.greedy_fits_int32(2**21, 1, 1, 2**10 - 1)
    assert FRK.greedy_fits_int32(2**21 - 1, 1, 1, 2**10 - 1)
    with pytest.raises(ValueError, match="overflow int32"):
        FRK.greedy_params(2**21, 1, 1, 2**10 - 1)
    alpha, beta, delta = FRK.greedy_params(2**21 - 1, 1, 1, 2**10 - 1)
    assert (alpha, beta) == (2**21 - 1, 0)


def test_device_rebuild_falls_back_to_host_on_int32_overflow(caplog):
    """A hub graph past the int32 priority bound must not abort the device
    full rung: the engine degrades to the host geo_order path (mode label
    ``device+host-fallback``), warns exactly once per engine, and the device
    pack stays bit-identical to the host mirror after the commit."""
    E = 26_000  # star graph: max_degree == E pushes the bound past 2^31
    src = np.zeros(E, dtype=np.int64)
    dst = np.arange(1, E + 1, dtype=np.int64)
    o = IncrementalOrderer(src, dst, E + 1, regions=4, config=StreamConfig(**QUIET))
    assert not FRK.greedy_fits_int32(E, o.config.k_min, o.config.k_max, E)
    eng = StreamingEngine(
        o, MM.make_graph_mesh(1), full_rebuild="device", rebuild_flight=0
    )
    with caplog.at_level(logging.WARNING, logger="repro.stream.ingest"):
        o.drift = lambda: 99.0
        assert eng.monitor() == "full"
        (rec,) = eng.drain_rebuild_events()
        assert rec["committed"] and not rec["aborted"]
        assert rec["mode"] == "device+host-fallback"
        eng.verify_bit_identity()
        assert eng.monitor() == "full"  # a second rebuild must not re-warn
        del o.drift
    (rec2,) = eng.drain_rebuild_events()
    assert rec2["mode"] == "device+host-fallback"
    eng.verify_bit_identity()
    warnings = [
        r for r in caplog.records if "falling back to host geo_order" in r.message
    ]
    assert len(warnings) == 1


# High thresholds so ONLY the mocked drift escalates — the forced-cycle tests
# need the rung count under their control, not the stream's natural drift.
QUIET = dict(partial_drift=40.0, full_drift=50.0)


# ----------------------------------------------- engine: flight 0 ≡ host mode
def test_flight_zero_geo_commit_matches_host_full_rebuild(ordered):
    """rebuild_flight=0 commits inside one monitor call — the synchronous
    oracle-equivalence mode: the committed slot arrays equal a host-mode
    full_rebuild of an identically-streamed twin, byte for byte."""
    g, src, dst = ordered
    o_async = IncrementalOrderer(src, dst, g.num_vertices, regions=4, config=StreamConfig(**QUIET))
    o_host = IncrementalOrderer(src, dst, g.num_vertices, regions=4, config=StreamConfig(**QUIET))
    eng = StreamingEngine(
        o_async, MM.make_graph_mesh(1), full_rebuild="geo", rebuild_flight=0
    )
    s1 = SyntheticStream(g, batch_size=48, seed=5)
    s2 = SyntheticStream(g, batch_size=48, seed=5)
    for _ in range(4):
        eng.ingest(s1.batch())
        o_host.apply(s2.batch())
        o_host.needs_resync = False
        o_host.drain_ops()
    o_async.drift = lambda: 99.0  # force the full rung
    assert eng.monitor() == "full"
    del o_async.drift
    o_host.full_rebuild()
    o_host.needs_resync = False
    np.testing.assert_array_equal(o_async.slot_src, o_host.slot_src)
    np.testing.assert_array_equal(o_async.slot_dst, o_host.slot_dst)
    np.testing.assert_array_equal(o_async.slot_valid, o_host.slot_valid)
    eng.verify_bit_identity()
    (rec,) = eng.drain_rebuild_events()
    assert rec["committed"] and not rec["aborted"]
    assert rec["flight_batches"] == 0 and rec["replayed_batches"] == 0


# ------------------------------------------- engine: async dispatch → commit
def run_async_cycle(ordered, mode="geo", flight=2, batches=8, seed=7):
    """Drive one forced async rebuild cycle through the controller: drift is
    pinned high for the dispatch batch only, so exactly one rebuild flies."""
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=4, config=StreamConfig(**QUIET))
    eng = StreamingEngine(o, MM.make_graph_mesh(1), full_rebuild=mode, rebuild_flight=flight)
    ctl = ec.ElasticController(4)
    ctl.attach_stream(eng)
    stream = SyntheticStream(g, batch_size=48, seed=seed)
    events = []
    for b in range(batches):
        if b == 2:
            o.drift = lambda: 99.0  # escalate: dispatch on this batch
        events.append(ctl.ingest(stream.batch()))
        if b == 2:
            del o.drift
        eng.verify_bit_identity()
    return o, eng, ctl, events


def test_async_rebuild_dispatch_flight_commit_protocol(ordered):
    o, eng, ctl, events = run_async_cycle(ordered, flight=2)
    # Batch 2 dispatches (rung 'full', non-blocking), 3 flies, 4 commits.
    assert events[2].escalation == "full" and events[2].rebuild_state == "dispatch"
    assert events[2].repair == "dispatch" and events[2].rebuilds_in_flight == 1
    assert events[3].escalation == "none" and events[3].rebuild_state == "flight"
    assert events[3].rebuilds_in_flight == 1
    assert events[4].escalation == "full" and events[4].rebuild_state == "commit"
    assert events[4].repair == "geo" and events[4].rebuilds_in_flight == 0
    assert events[4].rebuild_s > 0  # the commit's blocked cost is on ITS batch
    # The completed rebuild is its own event, sequenced just before batch 4's.
    rebuilds = [e for e in ctl.events if e.kind == "full_rebuild"]
    assert len(rebuilds) == 1
    rb = rebuilds[0]
    assert rb.committed and not rb.aborted and rb.mode == "geo"
    assert rb.flight_batches == 2 and rb.replayed_batches == 2
    assert rb.snapshot_edges > 0 and rb.dispatch_s > 0 and rb.commit_s > 0
    assert rb.seq == events[4].seq - 1
    # One strictly monotonic seq across ingest + rebuild events.
    assert [e.seq for e in ctl.events] == list(range(len(ctl.events)))
    # The committed order re-baselined the drift monitor.
    assert o.drift() < 99.0


def test_async_rebuild_differential_mode_self_verifies(ordered):
    """Differential mode scores geo against the greedy and bit-verifies at
    commit (verify_bit_identity raises inside _commit_rebuild on divergence)."""
    o, eng, ctl, events = run_async_cycle(ordered, mode="differential", flight=1)
    rebuilds = [e for e in ctl.events if e.kind == "full_rebuild"]
    assert len(rebuilds) == 1 and rebuilds[0].committed
    assert rebuilds[0].mode == "differential" and rebuilds[0].flight_batches == 1
    assert events[3].repair == "differential"


def test_async_rebuild_device_mode_commits_and_stays_bit_identical(ordered):
    """Device mode (greedy vs incumbent): whatever the selection picked, the
    device pack must mirror the host slots byte-for-byte after the commit —
    run_async_cycle verifies after every batch."""
    o, eng, ctl, events = run_async_cycle(ordered, mode="device", flight=2)
    rebuilds = [e for e in ctl.events if e.kind == "full_rebuild"]
    assert len(rebuilds) == 1 and rebuilds[0].committed
    assert events[4].repair == "device"


def test_async_rebuild_abort_on_rescale(ordered):
    """A rescale mid-flight voids the snapshot: the rebuild aborts (bit
    identity intact), and the ladder re-fires once drift is measured again."""
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=4, config=StreamConfig(**QUIET))
    eng = StreamingEngine(o, MM.make_graph_mesh(1), full_rebuild="geo", rebuild_flight=3)
    ctl = ec.ElasticController(4)
    ctl.attach_stream(eng)
    stream = SyntheticStream(g, batch_size=48, seed=11)
    ctl.ingest(stream.batch())
    o.drift = lambda: 99.0
    ev = ctl.ingest(stream.batch())  # dispatch
    assert ev.rebuild_state == "dispatch" and eng.rebuilds_in_flight == 1
    scale = ctl.add_hosts(2)  # rescale 4 → 6 mid-flight
    assert scale.executed and eng.rebuilds_in_flight == 0
    eng.verify_bit_identity()
    ev2 = ctl.ingest(stream.batch())  # drift still high: ladder re-fires
    del o.drift
    assert ev2.rebuild_state == "dispatch" and eng.rebuilds_in_flight == 1
    rebuilds = [e for e in ctl.events if e.kind == "full_rebuild"]
    assert len(rebuilds) == 1
    rb = rebuilds[0]
    assert rb.aborted and not rb.committed
    assert rb.replayed_batches == 0 and rb.splice_ops == 0 and rb.commit_s == 0.0
    # The abort was sequenced before the re-dispatch batch's IngestEvent.
    assert rb.seq < ev2.seq
    assert [e.seq for e in ctl.events] == list(range(len(ctl.events)))


def test_escalation_suppressed_while_rebuild_in_flight(ordered):
    """Mid-flight monitors report 'none' even at full-rung drift: the drift
    being measured is already being repaired."""
    o, eng, ctl, events = run_async_cycle(ordered, flight=2)
    assert events[3].escalation == "none" and events[3].repair == ""


# ------------------------------------- ladder: anticipation + partial shadow
def test_escalation_full_lookahead_boundary(ordered):
    """The full threshold stays strict under anticipation: d + lookahead must
    EXCEED full_drift; the smallest representable excess fires."""
    g, o = make_orderer(ordered)
    full = o.config.full_drift
    o.drift = lambda: full  # exactly AT the threshold
    assert o.escalation() == "partial"  # strict: no fire without anticipation
    assert o.escalation(full_lookahead=1e-9) == "full"  # any excess fires
    o.drift = lambda: full - 0.02
    assert o.escalation(full_lookahead=0.01) == "partial"  # projection too short
    assert o.escalation(full_lookahead=0.05) == "full"
    del o.drift


def test_escalation_partial_shadow_suppression(ordered):
    """A partial in the shadow of a projected full reports 'none'; a shadow
    short of the full threshold leaves the partial decision untouched; an
    actual full always outranks the shadow."""
    g, o = make_orderer(ordered)
    cfg = o.config
    d = cfg.partial_drift + 0.01
    o.drift = lambda: d
    gap = cfg.full_drift - d
    assert o.escalation() == "partial"  # no shadow: classic decision
    assert o.escalation(partial_shadow=gap) == "partial"  # projects exactly AT
    assert o.escalation(partial_shadow=gap + 0.01) == "none"  # suppressed
    o.drift = lambda: cfg.full_drift + 0.01
    assert o.escalation(partial_shadow=99.0) == "full"
    del o.drift


def test_full_via_lookahead_resets_partial_cooldown(ordered):
    """An anticipated full passes through maybe_escalate like a classic one:
    it ignores an open cooldown window and resets it."""
    g, o = make_orderer(ordered, partial_cooldown=3)
    o.drift = lambda: o.config.partial_drift + 0.01
    ran = {"partial": 0, "full": 0}
    pfn = lambda: ran.__setitem__("partial", ran["partial"] + 1)
    ffn = lambda: ran.__setitem__("full", ran["full"] + 1)
    assert o.maybe_escalate(partial_fn=pfn, full_fn=ffn) == "partial"  # opens window
    assert o.maybe_escalate(partial_fn=pfn, full_fn=ffn) == "none"  # cooling
    look = o.config.full_drift  # enough to project any drift past the threshold
    assert o.maybe_escalate(partial_fn=pfn, full_fn=ffn, full_lookahead=look) == "full"
    assert o.maybe_escalate(partial_fn=pfn, full_fn=ffn) == "partial"  # window reset
    assert ran == {"partial": 2, "full": 1}
    del o.drift


def test_partial_shadow_does_not_consume_cooldown(ordered):
    """A shadow-suppressed partial reports 'none' WITHOUT opening or draining
    the hysteresis window — suppression is a decision, not a firing."""
    g, o = make_orderer(ordered, partial_cooldown=2)
    o.drift = lambda: o.config.partial_drift + 0.01
    ran = []
    shadow = o.config.full_drift  # projects any drift past the threshold
    assert o.maybe_escalate(partial_fn=lambda: ran.append(1), partial_shadow=shadow) == "none"
    assert o.maybe_escalate(partial_fn=lambda: ran.append(1)) == "partial"  # fires now
    assert ran == [1]
    del o.drift


# --------------------------------------------- interleaving property test
def _check_rebuild_interleaving(seed: int, steps: int = 10):
    """Random interleaving of ingest / scale_out / scale_in with REAL async
    rebuilds (geo mode, flight 1, baseline pinned so the ladder fires): after
    every event the sharded pack equals the host slot oracle byte-for-byte,
    the shared seq stays strictly monotonic across all three event kinds, and
    every completed rebuild either committed or was aborted by a rescale."""
    g = rmat_graph(6, 4, seed=1)
    order = ordering.geo_order(g, seed=0)
    o = IncrementalOrderer(
        g.src[order].astype(np.int64), g.dst[order].astype(np.int64),
        g.num_vertices, regions=4,
    )
    o._baseline_kappa = o._kappa() / 1.5  # drift ≈ 1.5 → full rung fires
    eng = StreamingEngine(o, MM.make_graph_mesh(1), full_rebuild="geo", rebuild_flight=1)
    clock = [0.0]
    ctl = ec.ElasticController(4, dead_after_s=5.0, clock=lambda: clock[0])
    ctl.attach_stream(eng)
    stream = SyntheticStream(g, batch_size=24, seed=seed)
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        alive = ctl.k
        choices = ["ingest", "ingest", "scale_out"] + (["scale_in"] if alive > 2 else [])
        action = choices[int(rng.integers(0, len(choices)))]
        if action == "ingest":
            ctl.ingest(stream.batch())
        elif action == "scale_out":
            ctl.add_hosts(int(rng.integers(1, 3)))
        else:
            victim = max(h for h, hs in ctl.hosts.items() if hs.alive)
            clock[0] += ctl.dead_after_s + 1.0
            for h, hs in ctl.hosts.items():
                if hs.alive and h != victim:
                    ctl.heartbeat(h, 1)
            assert ctl.poll() is not None
        eng.verify_bit_identity()
        assert eng.k == ctl.k == o.regions
    assert [e.seq for e in ctl.events] == list(range(len(ctl.events)))
    rebuilds = [e for e in ctl.events if e.kind == "full_rebuild"]
    for rb in rebuilds:
        assert rb.committed != rb.aborted or not rb.committed  # never both
        if rb.committed:
            assert rb.flight_batches >= 1  # flight=1: commit is never same-batch
        else:
            assert rb.aborted  # only a rescale abort yields an uncommitted one
    return [e.kind for e in ctl.events]


@given(seed=st.integers(0, 24))
@settings(max_examples=6, deadline=None)
def test_rebuild_interleaving_matches_oracle_and_seq_monotonic(seed):
    _check_rebuild_interleaving(seed)


@pytest.mark.parametrize("seed", [0, 4, 11])
def test_rebuild_interleaving_deterministic(seed):
    kinds = _check_rebuild_interleaving(seed)
    assert "ingest" in kinds


def test_rebuild_interleaving_seeds_exercise_rebuilds():
    """The fallback seeds must actually complete at least one rebuild AND one
    abort across the set (otherwise the deterministic variant silently stops
    covering the async machinery)."""
    kinds = sum((_check_rebuild_interleaving(s) for s in (0, 4, 11)), [])
    assert "full_rebuild" in kinds
