"""Streaming subsystem on 8 real (host) devices: sharded ingest scatter,
compact rescale, and the bit-identity oracle across a live stream.

Skipped in the tier-1 suite (1 CPU device); run by the CI ``multidevice`` job
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``. A subprocess
smoke of the same acceptance properties lives in tests/test_multidevice.py so
tier-1 still exercises the sharded path.
"""
import jax
import numpy as np
import pytest

from repro.core import ordering
from repro.core.graph import rmat_graph
from repro.elastic import controller as ec
from repro.graphs import engine as E
from repro.launch import mesh as MM
from repro.launch import sharding as SH
from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


@pytest.fixture(scope="module")
def ordered():
    g = rmat_graph(8, 6, seed=0)
    order = ordering.geo_order(g, seed=0)
    return g, g.src[order].astype(np.int64), g.dst[order].astype(np.int64)


@pytest.fixture(scope="module")
def mesh():
    return MM.make_graph_mesh(8)


def test_streaming_pack_rows_live_on_round_robin_devices(ordered, mesh):
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=12)  # 12 ∤ 8
    eng = StreamingEngine(o, mesh)
    sdata = eng.data
    assert sdata.k_pad % 8 == 0 and sdata.devices == 8
    dev_order = list(mesh.devices.ravel())
    m = sdata.rows_per_device
    for shard in sdata.edges.addressable_shards:
        d = dev_order.index(shard.device)
        lo = shard.index[0].start or 0
        assert lo == d * m
        for r in range(lo, lo + m):
            p = SH.row_partition(r, 12, 8)
            if p < 12:
                assert SH.partition_device(p, 8) == d


def test_sharded_ingest_bit_identical_over_stream(ordered, mesh):
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=8)
    eng = StreamingEngine(o, mesh)
    stream = SyntheticStream(g, batch_size=64, seed=1)
    for _ in range(5):
        stats = eng.ingest(stream.batch(), verify=True)  # raises on divergence
        assert stats.num_edges == o.num_edges
        eng.monitor()
    eng.verify_bit_identity()


def test_sharded_rescale_under_ingest_with_cross_device_accounting(ordered, mesh):
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=8)
    eng = StreamingEngine(o, mesh)
    stream = SyntheticStream(g, batch_size=64, seed=2)
    eng.ingest(stream.batch(), verify=True)
    rs_out = eng.rescale(12, verify=True)  # k → k+x under ingest
    # Every region sits alone on its device at k=8 → every region-ownership
    # change is device traffic, and the accounting must agree.
    assert rs_out.cross_device_edges <= rs_out.moved_edges
    assert rs_out.cross_device_bytes == rs_out.cross_device_edges * 8
    eng.ingest(stream.batch(), verify=True)
    rs_in = eng.rescale(5, verify=True)  # k → k−y, 5 ∤ 8 devices
    assert rs_in.k_new == 5 and eng.data.k == 5
    eng.ingest(stream.batch(), verify=True)
    # GAS still runs on the migrated streaming pack.
    s, d = o.snapshot()
    ref = E.pack_ordered(s, d, g.num_vertices, 5)
    np.testing.assert_allclose(
        np.asarray(E.pagerank(eng.data, iterations=10)),
        np.asarray(E.pagerank(ref, MM.make_test_mesh(1, 1), iterations=10)),
        rtol=1e-6, atol=1e-9,
    )


def test_sharded_device_span_repair_bit_identical_over_stream(ordered, mesh):
    """ISSUE-5 satellite (sharded variant): the on-mesh span-repair program —
    jnp objective path, since Pallas is gated off on multi-device meshes —
    stays byte-identical to the host mirror across forced partial escalations
    and a rescale that re-keys the program."""
    from repro.stream.incremental import StreamConfig

    g, src, dst = ordered
    o = IncrementalOrderer(
        src, dst, g.num_vertices, regions=8,
        config=StreamConfig(partial_drift=1.0, full_drift=99.0, span_regions=2),
    )
    o._baseline_kappa = o._kappa() / 1.5  # every monitor fires 'partial'
    eng = StreamingEngine(o, mesh, span_repair="device")
    stream = SyntheticStream(g, batch_size=64, seed=5)
    for b in range(4):
        if b == 2:
            eng.rescale(12, verify=True)
        eng.ingest(stream.batch(), verify=True)
        assert eng.monitor() == "partial" and eng.last_repair == "device"
        eng.verify_bit_identity()
    assert eng.rung_counts["partial"] == 4
    keys = [k for k in eng._programs if k[0] == "span_repair"]
    assert keys and all(k[7] is False for k in keys)  # use_pallas gated off


def test_sharded_differential_span_repair_never_worse_than_geo(ordered, mesh):
    """Sharded differential mode: geo candidate scored on device, result
    byte-identical to the host mirror's selection."""
    from repro.stream.incremental import StreamConfig

    g, src, dst = ordered
    o = IncrementalOrderer(
        src, dst, g.num_vertices, regions=8,
        config=StreamConfig(partial_drift=1.0, full_drift=99.0, span_regions=2),
    )
    o._baseline_kappa = o._kappa() / 1.5
    eng = StreamingEngine(o, mesh, span_repair="differential")
    stream = SyntheticStream(g, batch_size=64, seed=6)
    for _ in range(2):
        eng.ingest(stream.batch(), verify=True)
        assert eng.monitor() == "partial"
        eng.verify_bit_identity()


def test_sharded_escalation_resync_stays_bit_identical(ordered, mesh):
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=8)
    eng = StreamingEngine(o, mesh)
    # Middle rung: the span rewrite reaches the mesh as one scatter.
    n = o.partial_reorder()
    assert n > 0 and not o.needs_resync
    ops, deg = o.drain_ops()
    eng._scatter(ops, deg)
    eng.verify_bit_identity()
    # Top rung: full rebuild forces a resync upload.
    o.full_rebuild()
    assert o.needs_resync
    eng._resync()
    eng.verify_bit_identity()


def test_sharded_async_full_rebuild_commits_bit_identical(ordered, mesh):
    """ISSUE-6 (sharded variant): the async full rebuild — dispatch against
    shadow buffers, fly for one batch, commit with a delta splice — stays
    byte-identical to the host slot oracle on an 8-device mesh, and both the
    whole-graph re-order and splice programs land in the one program LRU."""
    from repro.stream.incremental import StreamConfig

    g, src, dst = ordered
    o = IncrementalOrderer(
        src, dst, g.num_vertices, regions=8,
        config=StreamConfig(partial_drift=40.0, full_drift=50.0),
    )
    eng = StreamingEngine(o, mesh, full_rebuild="geo", rebuild_flight=1)
    ctl = ec.ElasticController(8)
    ctl.attach_stream(eng)
    stream = SyntheticStream(g, batch_size=64, seed=7)
    states = []
    for b in range(4):
        if b == 1:
            o.drift = lambda: 99.0  # force the dispatch on this batch
        ctl.ingest(stream.batch())
        if b == 1:
            del o.drift
        states.append(eng.rebuild_state)
        eng.verify_bit_identity()  # raises on any host/device divergence
    assert states == ["", "dispatch", "commit", ""]
    rebuilds = [e for e in ctl.events if e.kind == "full_rebuild"]
    assert len(rebuilds) == 1
    rb = rebuilds[0]
    assert rb.committed and rb.flight_batches == 1 and rb.replayed_batches == 1
    assert [e.seq for e in ctl.events] == list(range(len(ctl.events)))
    kinds = {k[0] for k in eng._programs}
    assert "full_reorder" in kinds and "splice" in kinds


def test_controller_interleaves_sharded_ingest_and_scale(ordered, mesh):
    g, src, dst = ordered
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=8)
    eng = StreamingEngine(o, mesh)
    t = [0.0]
    ctl = ec.ElasticController(8, dead_after_s=5.0, clock=lambda: t[0])
    ctl.attach_stream(eng)
    stream = SyntheticStream(g, batch_size=64, seed=3)
    ctl.ingest(stream.batch())
    t[0] = 1.0
    for h in range(6):
        ctl.heartbeat(h, 1)
    t[0] = 6.0
    ev = ctl.poll()  # hosts 6, 7 preempted → rescale on the mesh
    assert ev is not None and ev.executed and eng.k == 6
    ctl.ingest(stream.batch())
    eng.verify_bit_identity()
    seqs = [e.seq for e in ctl.events]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
