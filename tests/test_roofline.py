"""Roofline machinery unit tests: HLO shape/byte parsing, loop-aware
collective accounting, analytic cost sanity."""
import pytest

from repro import configs
from repro.launch import roofline as R
from repro.models.config import SHAPES


def test_shape_bytes():
    assert R.shape_bytes("f32[16,512,9496]{2,1,0}") == 16 * 512 * 9496 * 4
    assert R.shape_bytes("bf16[8]{0}") == 16
    assert R.shape_bytes("(f32[2,2]{1,0}, bf16[4]{0})") == 16 + 8
    assert R.shape_bytes("pred[]") == 1  # scalar: one element
    assert R.shape_bytes("no shapes here") == 0


HLO = """\
HloModule test, entry_computation_layout={()->f32[]}

%wide.body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %ar = f32[4]{0} all-reduce(%x), to_apply=%add
  ROOT %t = tuple(%i, %ar)
}

%wide.cond (p: (s32[], f32[4])) -> pred[] {
  %c = s32[] constant(7)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %w = (s32[], f32[4]) while(%init), condition=%wide.cond, body=%wide.body
  %ag = f32[8]{0} all-gather(%y), dimensions={0}
  ROOT %out = f32[4]{0} get-tuple-element(%w), index=1
}
"""


def test_collective_bytes_weights_loops():
    got = R.collective_bytes(HLO)
    assert got["all-reduce"] == 7 * 16  # 7 trips × f32[4]
    assert got["all-gather"] == 32
    assert got["total"] == 7 * 16 + 32


def test_trip_count_parse():
    comps = R._split_computations(HLO)
    assert "wide.cond" in comps and "wide.body" in comps and "main" in comps
    assert R._trip_count(comps["wide.cond"]) == 7


def test_roofline_terms_and_bottleneck():
    r = R.Roofline(
        flops_per_chip=1.97e14, hbm_bytes_per_chip=819e9 / 2,
        collective_bytes_per_chip=50e9 / 4, chips=256, model_flops_global=1.97e14 * 256 * 0.5,
    )
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(0.5)
    assert r.t_collective == pytest.approx(0.25)
    assert r.bottleneck == "compute"
    assert r.mfu_upper_bound == pytest.approx(0.5)


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-moe-16b", "mamba2-1.3b"])
@pytest.mark.parametrize("shape", ["train_4k", "prefill_32k", "decode_32k"])
def test_analytic_costs_positive_and_ordered(arch, shape):
    cfg = configs.get_config(arch)
    c = R.analytic_costs(cfg, SHAPES[shape], 256, microbatches=4, model_shards=16)
    assert c["flops_per_chip"] > 0 and c["hbm_bytes_per_chip"] > 0
    # Training must cost more FLOPs than prefill which costs more than decode.
    if shape == "train_4k":
        pre = R.analytic_costs(cfg, SHAPES["prefill_32k"], 256, model_shards=16)
        dec = R.analytic_costs(cfg, SHAPES["decode_32k"], 256, model_shards=16)
        assert c["flops_per_chip"] > pre["flops_per_chip"] > dec["flops_per_chip"]


def test_model_flops_moe_uses_active_params():
    moe = configs.get_config("deepseek-moe-16b")
    dense_equiv = R.model_flops(moe, SHAPES["train_4k"])
    assert dense_equiv < 6 * moe.param_count() * SHAPES["train_4k"].global_batch * SHAPES["train_4k"].seq_len
