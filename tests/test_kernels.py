"""Per-kernel allclose tests vs the pure-jnp/numpy oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cep, metrics, ordering
from repro.core.graph import rmat_graph
from repro.kernels import decode_attention as dec
from repro.kernels import edge_spmv, flash_attention, ref, segment_rf
from repro.kernels import ops


# ---------------------------------------------------------------- segment_rf
@pytest.mark.parametrize("c,w", [(4, 8), (16, 64), (7, 40), (33, 24)])
def test_segment_rf_kernel_matches_ref(c, w):
    rng = np.random.default_rng(c * 100 + w)
    rows = rng.integers(0, 50, size=(c, w)).astype(np.int32)
    pad_mask = rng.random((c, w)) < 0.2
    rows[pad_mask] = segment_rf.PAD_ID
    rows_sorted = np.sort(rows, axis=1)
    got = np.asarray(segment_rf.segment_distinct_counts(jnp.asarray(rows_sorted)))
    want = ref.segment_distinct_counts_ref(rows_sorted, int(segment_rf.PAD_ID))
    assert np.array_equal(got, want)


def test_rf_kernel_end_to_end_matches_metrics():
    g = rmat_graph(7, 6, seed=0)
    order = ordering.geo_order(g, seed=0)
    s, d = g.src[order], g.dst[order]
    for k in (4, 8, 16):
        got = ops.replication_factor_kernel(s, d, k, g.num_vertices)
        want = metrics.replication_factor_ordered(s, d, k, g.num_vertices)
        assert got == pytest.approx(want, rel=1e-6)


# ----------------------------------------------------------------- edge_spmv
@pytest.mark.parametrize("c,we,wv", [(2, 16, 32), (5, 64, 128), (3, 128, 256)])
def test_spmv_kernel_matches_ref(c, we, wv):
    rng = np.random.default_rng(c)
    src = rng.integers(0, wv + 1, size=(c, we)).astype(np.int32)  # wv == padding
    dst = rng.integers(0, wv + 1, size=(c, we)).astype(np.int32)
    w = rng.standard_normal((c, we)).astype(np.float32)
    x = rng.standard_normal((c, wv)).astype(np.float32)
    got = np.asarray(edge_spmv.spmv_blocked(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w), jnp.asarray(x)))
    want = ref.spmv_blocked_ref(src, dst, w, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_chunked_spmv_end_to_end():
    g = rmat_graph(6, 4, seed=1)
    order = ordering.geo_order(g, seed=0)
    s, d = g.src[order], g.dst[order]
    k = 4
    bounds = np.asarray(cep.chunk_bounds(g.num_edges, k))
    window = g.num_vertices  # full window → no fallback edges
    starts = [0] * k
    x = np.random.default_rng(0).standard_normal(g.num_vertices).astype(np.float32)
    w = np.ones(g.num_edges, dtype=np.float32)
    y = ops.chunked_spmv(s, d, w, x, bounds, starts, window)
    want = np.zeros_like(x)
    np.add.at(want, d, x[s])
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,s,d,window,softcap",
    [
        (1, 2, 128, 64, None, None),
        (2, 1, 256, 32, None, None),
        (1, 2, 256, 64, 128, None),     # sliding window
        (1, 1, 128, 64, None, 30.0),    # gemma2-style softcap
        (2, 2, 384, 128, 256, 50.0),
    ],
)
def test_flash_attention_matches_ref(b, h, s, d, window, softcap, dtype):
    rng = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (b, h, s, d), dtype)
    k = jax.random.normal(kk, (b, h, s, d), dtype)
    v = jax.random.normal(kv, (b, h, s, d), dtype)
    got = flash_attention.flash_attention(
        q, k, v, causal=True, window=window, softcap=softcap, block_q=128, block_kv=128
    )
    want = ref.attention_ref(q, k, v, causal=True, window=window, softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_noncausal():
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 128, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 128, 32))
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 128, 32))
    got = flash_attention.flash_attention(q, k, v, causal=False)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------- decode attention
@pytest.mark.parametrize("bh,gq,s,d,block_s", [(2, 4, 512, 64, 128), (1, 1, 1024, 32, 256), (3, 8, 256, 128, 256)])
def test_decode_attention_matches_ref(bh, gq, s, d, block_s):
    rng = jax.random.PRNGKey(42)
    kq, kk, kv, kl = jax.random.split(rng, 4)
    q = jax.random.normal(kq, (bh, gq, d))
    k = jax.random.normal(kk, (bh, s, d))
    v = jax.random.normal(kv, (bh, s, d))
    cache_len = jax.random.randint(kl, (bh,), 1, s + 1, dtype=jnp.int32)
    got = dec.decode_attention(q, k, v, cache_len, block_s=block_s)
    want = ref.decode_attention_ref(q, k, v, cache_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_decode_merge_is_associative_across_devices():
    """The LSE merge must give identical results however tiles are grouped —
    this is what makes sequence-parallel sharded decode correct."""
    bh, gq, s, d = 2, 2, 1024, 64
    rng = jax.random.PRNGKey(7)
    kq, kk, kv = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (bh, gq, d))
    k = jax.random.normal(kk, (bh, s, d))
    v = jax.random.normal(kv, (bh, s, d))
    cache_len = jnp.full((bh,), s, jnp.int32)
    o, m, l = dec.decode_attention_partials(q, k, v, cache_len, block_s=128)
    # Merge all 8 tiles at once.
    all_at_once, _ = dec.merge_partials(o, m, l, axis=1)
    # Merge per "device" (two groups of 4), then merge the groups.
    o1, m1, l1 = o[:, :4], m[:, :4], l[:, :4]
    o2, m2, l2 = o[:, 4:], m[:, 4:], l[:, 4:]
    g1, lse1 = dec.merge_partials(o1, m1, l1, axis=1)
    g2, lse2 = dec.merge_partials(o2, m2, l2, axis=1)
    # A merged group re-enters the merge as (o=out, m=lse, l=1).
    stacked_o = jnp.stack([g1, g2], axis=1)
    stacked_m = jnp.stack([lse1, lse2], axis=1)  # lse keeps the trailing 1-dim
    stacked_l = jnp.ones_like(stacked_m)
    grouped, _ = dec.merge_partials(stacked_o, stacked_m, stacked_l, axis=1)
    np.testing.assert_allclose(np.asarray(grouped), np.asarray(all_at_once), rtol=1e-5, atol=1e-5)
