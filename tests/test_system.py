"""End-to-end behaviour tests for the whole system."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.data import pipeline as dp
from repro.models import model as M
from repro.train import optimizer as O
from repro.train import steps as S


def test_training_learns_the_synthetic_chain():
    """A few dozen steps on the Markov-chain data must beat the noise floor."""
    cfg = configs.get_smoke("qwen2-1.5b")
    dc = dp.DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=16)
    opt = O.OptConfig(peak_lr=2e-3, warmup_steps=10, total_steps=80)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = O.init_opt_state(params)
    step_fn = jax.jit(S.make_train_step(cfg, opt))
    losses = []
    for step in range(80):
        gb = dp.global_batch(dc, step)
        batch = {k: jnp.asarray(v) for k, v in gb.items()}
        params, state, m = step_fn(params, state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    # ln(V) ≈ 6.24 noise floor; the chain is 7/8 predictable once learned.
    assert np.mean(losses[-10:]) < np.mean(losses[:5]) - 1.0, losses[::10]


def test_microbatched_step_matches_plain_grads_direction():
    cfg = configs.get_smoke("gemma3-4b")
    dc = dp.DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    opt = O.OptConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    gb = {k: jnp.asarray(v) for k, v in dp.global_batch(dc, 0).items()}
    p1, _, m1 = jax.jit(S.make_train_step(cfg, opt))(params, O.init_opt_state(params), gb)
    p2, _, m2 = jax.jit(S.make_train_step(cfg, opt, microbatches=4))(
        params, O.init_opt_state(params), gb
    )
    # Same data, same loss (up to accumulation-order float noise).
    assert float(m2["loss"]) == pytest.approx(float(m1["loss"]), rel=2e-3)
    # Updates agree closely.
    d1 = np.asarray(p1["embed"] - params["embed"], np.float32)
    d2 = np.asarray(p2["embed"] - params["embed"], np.float32)
    cos = (d1 * d2).sum() / (np.linalg.norm(d1) * np.linalg.norm(d2) + 1e-12)
    assert cos > 0.99


def test_serve_generates_greedy_tokens_consistently():
    """Prefill+decode must keep cache positions and finite logits in lockstep."""
    cfg = configs.get_smoke("hymba-1.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(0)
    b, s = 2, 24
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    cache = M.init_cache(cfg, b, s + 8)
    logits, cache = jax.jit(lambda p, bt, c: M.forward_prefill(p, cfg, bt, c))(params, batch, cache)
    tok = jnp.argmax(logits, -1)
    dec = jax.jit(lambda p, t, c: M.forward_decode(p, cfg, t, c))
    for i in range(4):
        logits, cache = dec(params, tok[:, None].astype(jnp.int32), cache)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        assert int(cache["pos"]) == s + 1 + i
        tok = jnp.argmax(logits, -1)


def test_elastic_rescale_preserves_training_state(tmp_path):
    """save @k → restore @k−1 must reproduce the exact same next-step loss."""
    from repro.checkpoint import store

    cfg = configs.get_smoke("qwen2-1.5b")
    dc = dp.DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    opt = O.OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20)
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    state = O.init_opt_state(params)
    step_fn = jax.jit(S.make_train_step(cfg, opt))
    for step in range(3):
        gb = {k: jnp.asarray(v) for k, v in dp.global_batch(dc, step).items()}
        params, state, m = step_fn(params, state, gb)
    store.save({"p": params, "s": state}, tmp_path, step=3, k_shards=4)
    tree, _ = store.restore(tmp_path, 3, k_new=3, template={"p": params, "s": state})
    gb = {k: jnp.asarray(v) for k, v in dp.global_batch(dc, 3).items()}
    _, _, m_orig = step_fn(params, state, gb)
    _, _, m_rest = step_fn(tree["p"], tree["s"], gb)
    assert float(m_rest["loss"]) == pytest.approx(float(m_orig["loss"]), rel=1e-6)
