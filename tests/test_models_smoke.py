"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes + finiteness; prefill↔decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_patches, cfg.d_model)) * 0.02, jnp.float32
        )
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)) * 0.02, jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_smoke_forward_train(arch):
    cfg = configs.get_smoke(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(lambda p, b: M.forward_train(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(metrics["ce_loss"]) > 0
    # Loss should start near ln(V) for random init (uniform predictions).
    assert abs(float(metrics["ce_loss"]) - np.log(cfg.vocab_size)) < 2.0, arch


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_smoke_grads_finite(arch):
    cfg = configs.get_smoke(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg, b=1, s=16)

    def loss_fn(p):
        return M.forward_train(p, cfg, batch)[0]

    grads = jax.jit(jax.grad(loss_fn))(params)
    flat, _ = jax.tree_util.tree_flatten(grads)
    for leaf in flat:
        assert np.all(np.isfinite(np.asarray(leaf, np.float32))), arch


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_smoke_prefill_then_decode_matches_full_forward(arch):
    """logits(prefill(t_0..t_{n-1})) and decode(t_n) must match a full forward
    over t_0..t_n — validates every cache path (KV, SSM state, conv, cross)."""
    cfg = configs.get_smoke(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    b, s = 2, 16
    max_len = 32
    batch = _batch(cfg, b=b, s=s, seed=3)
    cache = M.init_cache(cfg, b, max_len)
    logits_pre, cache = jax.jit(lambda p, bt, c: M.forward_prefill(p, cfg, bt, c))(
        params, batch, cache
    )
    next_tok = jnp.asarray(np.random.default_rng(4).integers(0, cfg.vocab_size, (b, 1)), jnp.int32)
    logits_dec, cache2 = jax.jit(lambda p, t, c: M.forward_decode(p, cfg, t, c))(
        params, next_tok, cache
    )
    assert logits_dec.shape == (b, cfg.vocab_size)
    assert int(cache2["pos"]) == s + 1

    # Ground truth: full forward over the s+1 tokens, take positions s-1 and s.
    full_batch = dict(batch)
    full_batch["tokens"] = jnp.concatenate([batch["tokens"], next_tok], axis=1)
    fresh = M.init_cache(cfg, b, max_len)
    logits_full, _ = jax.jit(lambda p, bt, c: M.forward_prefill(p, cfg, bt, c))(
        params, full_batch, fresh
    )
    # forward_prefill returns last-position logits == decode-step ground truth.
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full), rtol=2e-3, atol=2e-3
    )


def test_param_counts_match_scale():
    """Full configs should land in the advertised parameter range."""
    expected = {
        "phi-3-vision-4.2b": (3.5e9, 4.5e9),
        "gemma3-4b": (3.0e9, 5.0e9),
        "qwen3-8b": (6.5e9, 9.0e9),
        "qwen2-1.5b": (1.2e9, 2.0e9),
        "gemma2-9b": (8.0e9, 10.5e9),
        "whisper-small": (0.15e9, 0.45e9),
        "mamba2-1.3b": (1.0e9, 1.7e9),
        "deepseek-moe-16b": (13e9, 19e9),
        "granite-moe-3b-a800m": (2.0e9, 4.0e9),
        "hymba-1.5b": (1.0e9, 2.2e9),
    }
    for arch, (lo, hi) in expected.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n:.3e}")


def test_moe_active_params_smaller():
    cfg = configs.get_config("deepseek-moe-16b")
    assert cfg.active_param_count() < 0.35 * cfg.param_count()
