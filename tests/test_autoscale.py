"""Autoscaler boundary behavior: watermark strictness, cooldown thresholds,
clamps, the no-signal guard, no-flapping under oscillating load, and bit
identity of the pack through policy-driven rescales (ISSUE-9)."""
import numpy as np
import pytest

from repro.elastic import autoscale as EA
from repro.elastic import controller as ec
from repro.obs import metrics as OM


def _registry(queue=0.0, rate=0.0, walls=()):
    r = OM.MetricsRegistry()
    r.gauge("controller.queue_depth").set(queue)
    r.gauge("controller.events_per_s").set(rate)
    h = r.histogram("controller.batch_wall_s")
    for w in walls:
        h.observe(w)
    return r


# Unsmoothed (ema=1.0) so unit tests hit the raw watermark arithmetic.
def _cfg(**kw):
    base = dict(
        k_min=1, k_max=8, step_out=2, step_in=1, queue_high_per_host=4.0,
        queue_low=0.5, ema=1.0, out_cooldown_s=10.0, in_cooldown_s=30.0,
    )
    base.update(kw)
    return EA.AutoscaleConfig(**base)


# ------------------------------------------------------------ watermark edges
def test_high_watermark_is_strictly_greater():
    pol = EA.AutoscalePolicy(_cfg())
    # Exactly AT the watermark (queue == 4.0/host × k) must NOT trigger.
    assert pol.decide(k=2, now=0.0, registry=_registry(queue=8.0)) is None
    assert pol.log[-1].held_by == "steady"
    # One above does.
    out = pol.decide(k=2, now=100.0, registry=_registry(queue=8.0 + 1e-9))
    assert out is not None and out[0] == 4 and "queue" in out[1]


def test_scale_in_requires_every_signal_calm():
    walls = [0.01] * 5
    pol = EA.AutoscalePolicy(_cfg(rate_low=2.0))
    # Queue at the low watermark AND rate under its low bound → in.
    got = pol.decide(k=4, now=0.0, registry=_registry(queue=0.5, rate=1.0, walls=walls))
    assert got is not None and got[0] == 3
    # Rate at/above rate_low vetoes (strict <) even with an empty queue.
    pol2 = EA.AutoscalePolicy(_cfg(rate_low=2.0))
    assert pol2.decide(k=4, now=0.0, registry=_registry(queue=0.0, rate=2.0, walls=walls)) is None
    assert pol2.log[-1].held_by == "steady"


def test_p99_signal_drives_both_directions():
    slo = 0.1
    pol = EA.AutoscalePolicy(_cfg(p99_high_s=slo, p99_low_frac=0.5))
    # p99 over the SLO scales out even with an empty queue.
    out = pol.decide(k=2, now=0.0, registry=_registry(walls=[0.2] * 10))
    assert out is not None and out[0] == 4 and "p99" in out[1]
    # p99 in the dead band [0.5·SLO, SLO] blocks scale-in.
    pol2 = EA.AutoscalePolicy(_cfg(p99_high_s=slo, p99_low_frac=0.5))
    assert pol2.decide(k=2, now=0.0, registry=_registry(walls=[0.07] * 10)) is None
    # p99 under the low fraction allows it.
    pol3 = EA.AutoscalePolicy(_cfg(p99_high_s=slo, p99_low_frac=0.5))
    got = pol3.decide(k=2, now=0.0, registry=_registry(walls=[0.01] * 10))
    assert got is not None and got[0] == 1


# ------------------------------------------------------------------ cooldowns
def test_out_cooldown_boundary_is_inclusive():
    pol = EA.AutoscalePolicy(_cfg())
    hot = _registry(queue=100.0)
    assert pol.decide(k=2, now=0.0, registry=hot) is not None
    # Strictly inside the window: held, and the log says why.
    assert pol.decide(k=4, now=10.0 - 1e-6, registry=hot) is None
    assert pol.log[-1].held_by == "cooldown"
    # Exactly at expiry (elapsed == cooldown): re-armed.
    assert pol.decide(k=4, now=10.0, registry=hot) is not None


def test_scale_out_arms_the_in_window():
    walls = [0.01] * 3
    pol = EA.AutoscalePolicy(_cfg())
    assert pol.decide(k=2, now=0.0, registry=_registry(queue=100.0, walls=walls)) is not None
    calm = _registry(queue=0.0, walls=walls)
    # Past the OUT cooldown but inside the IN window armed by the out: held.
    assert pol.decide(k=4, now=15.0, registry=calm) is None
    assert pol.log[-1].held_by == "cooldown"
    assert pol.decide(k=4, now=30.0, registry=calm) is not None


def test_scale_in_arms_the_out_window():
    walls = [0.01] * 3
    pol = EA.AutoscalePolicy(_cfg())
    assert pol.decide(k=4, now=0.0, registry=_registry(queue=0.0, walls=walls)) is not None
    # An immediate spike cannot reverse the shrink inside the out window …
    assert pol.decide(k=3, now=5.0, registry=_registry(queue=100.0)) is None
    assert pol.log[-1].held_by == "cooldown"
    # … but can once it expires.
    assert pol.decide(k=3, now=10.0, registry=_registry(queue=100.0)) is not None


# --------------------------------------------------------------------- clamps
def test_k_max_and_k_min_clamp_decisions():
    hot = _registry(queue=1e6)
    pol = EA.AutoscalePolicy(_cfg(k_max=4))
    assert pol.decide(k=4, now=0.0, registry=hot) is None
    assert pol.log[-1].held_by == "clamp"
    # Step lands on the ceiling, not past it.
    got = pol.decide(k=3, now=0.0, registry=hot)
    assert got is not None and got[0] == 4
    calm = _registry(queue=0.0, walls=[0.01])
    pol2 = EA.AutoscalePolicy(_cfg(k_min=2))
    assert pol2.decide(k=2, now=0.0, registry=calm) is None
    assert pol2.log[-1].held_by == "clamp"
    pol3 = EA.AutoscalePolicy(_cfg(k_min=2, step_in=5))
    got = pol3.decide(k=4, now=0.0, registry=calm)
    assert got is not None and got[0] == 2  # floor, not k - step

    with pytest.raises(ValueError):
        EA.AutoscaleConfig(k_min=3, k_max=2)
    with pytest.raises(ValueError):
        EA.AutoscaleConfig(ema=0.0)


# ------------------------------------------------------------- no-signal guard
def test_silent_registry_is_not_idleness():
    # A registry that never saw load must not trigger scale-in: silence is
    # "no signal", not "no load". Both a fresh registry and the NULL registry.
    for reg in (_registry(), OM.NULL):
        pol = EA.AutoscalePolicy(_cfg())
        assert pol.decide(k=4, now=0.0, registry=reg) is None
        assert pol.log[-1].held_by == "no_signal"


# --------------------------------------------------- oscillating load, no flap
def test_no_flapping_under_oscillating_load():
    # Load square-waves well above/below the watermarks every tick — the
    # worst case for a naive threshold policy. With EMA smoothing and both
    # cooldown windows armed by every decision, opposite-direction decisions
    # must stay >= out_cooldown apart (the structural no-flap property
    # bench_serve gates on).
    cfg = _cfg(ema=0.5, out_cooldown_s=5.0, in_cooldown_s=10.0, k_min=1, k_max=8)
    pol = EA.AutoscalePolicy(cfg)
    k = 4
    decided = []  # (now, kind)
    walls = [0.01] * 3
    for t in range(200):
        queue = 200.0 if t % 2 == 0 else 0.0
        got = pol.decide(k=k, now=float(t), registry=_registry(queue=queue, walls=walls))
        if got is not None:
            kind = "out" if got[0] > k else "in"
            decided.append((float(t), kind))
            k = got[0]
    assert decided, "oscillating load never produced a decision"
    for (ta, ka), (tb, kb) in zip(decided, decided[1:]):
        if ka != kb:
            assert tb - ta >= cfg.out_cooldown_s, (
                f"flap: {ka}@{ta} reversed by {kb}@{tb}"
            )
    # The EMA keeps the mean of the square wave in view: with the high
    # watermark under the mean, the policy ratchets OUT and never flaps in.
    assert all(kind == "out" for _, kind in decided)
    assert k == cfg.k_max


def test_ema_smoothing_absorbs_single_burst():
    # One bursty reading must not trigger: with ema=0.2 a single 100-deep
    # spike over a calm baseline stays under the 4/host × k=4 watermark.
    pol = EA.AutoscalePolicy(_cfg(ema=0.2))
    for t in range(5):
        assert pol.decide(k=4, now=float(t), registry=_registry(queue=1.0)) is None
    assert pol.decide(k=4, now=5.0, registry=_registry(queue=70.0)) is None
    assert pol.log[-1].queue == pytest.approx(0.2 * 70.0 + 0.8 * pol.log[-2].queue)
    # A SUSTAINED surge does trigger once the EMA catches up.
    fired = None
    for t in range(6, 12):
        fired = pol.decide(k=4, now=float(t), registry=_registry(queue=70.0))
        if fired:
            break
    assert fired is not None


# --------------------------------------- policy-driven rescale, bit identity
def test_policy_rescale_executes_on_stream_with_bit_identity():
    from repro.core import ordering
    from repro.core.graph import rmat_graph
    from repro.launch import mesh as MM
    from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream

    g = rmat_graph(7, 8, seed=0)
    order = ordering.geo_order(g, seed=0)
    src, dst = g.src[order].astype(np.int64), g.dst[order].astype(np.int64)
    orderer = IncrementalOrderer(src, dst, g.num_vertices, regions=2)
    engine = StreamingEngine(orderer, MM.make_graph_mesh(None))

    t = [0.0]
    reg = OM.MetricsRegistry()
    ctl = ec.ElasticController(2, clock=lambda: t[0], metrics_registry=reg)
    ctl.attach_stream(engine)
    pol = EA.AutoscalePolicy(_cfg(out_cooldown_s=1.0, in_cooldown_s=2.0))
    ctl.attach_autoscaler(pol)
    stream = SyntheticStream(g, batch_size=8, seed=1)

    assert ctl.autoscale() is None  # no signal yet: silence holds k
    ctl.ingest(stream.batch())  # lands a wall observation + rate sample
    ctl.note_backlog(100)  # serve-side pressure
    ev_out = ctl.autoscale()
    assert ev_out is not None and ev_out.kind == "scale_out" and ev_out.executed
    assert ctl.k == 4 and engine.k == 4
    assert engine.verify_bit_identity()  # pack byte-matches the slot oracle

    t[0] = 10.0  # clear both cooldown windows
    ctl.note_backlog(0)
    ev_in = ctl.autoscale()
    assert ev_in is not None and ev_in.kind == "scale_in" and ev_in.executed
    assert ctl.k == 3 and engine.k == 3
    assert engine.verify_bit_identity()
    # Shared seq order across ingest + policy events, and signal-carrying
    # reasons in the log.
    seqs = [e.seq for e in ctl.events]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    assert "autoscale out" in ev_out.reason and "autoscale in" in ev_in.reason
    # Ingest keeps working on the rescaled pack.
    ctl.ingest(stream.batch())
    assert engine.verify_bit_identity()


def test_attach_autoscaler_respects_controller_floor():
    ctl = ec.ElasticController(4, k_min=2)
    with pytest.raises(ValueError):
        ctl.attach_autoscaler(EA.AutoscalePolicy(_cfg(k_min=1)))
    ctl.attach_autoscaler(EA.AutoscalePolicy(_cfg(k_min=2)))
    assert ctl.autoscale() is None  # NULL registry: no signal, no decision