"""ElasticRescaler: executed k_old → k_new migration ≡ from-scratch packing,
with exactly ScalePlan.migrated_bytes of cross-partition traffic."""
import numpy as np
import pytest

from repro.core import baselines, cep, ordering
from repro.core.graph import rmat_graph
from repro.elastic import controller as ec
from repro.elastic.rescale_exec import EDGE_BYTES, ElasticRescaler
from repro.graphs import engine as E


@pytest.fixture(scope="module")
def ordered():
    g = rmat_graph(8, 6, seed=0)
    order = ordering.geo_order(g, seed=0)
    return g, g.src[order], g.dst[order]


@pytest.fixture(scope="module")
def rescaler():
    return ElasticRescaler()


# Scale-out and scale-in, including non-adjacent k and co-prime pairs.
PAIRS = [(8, 12), (12, 8), (4, 5), (5, 4), (16, 20), (20, 16), (3, 7), (2, 3)]


@pytest.mark.parametrize("k_old,k_new", PAIRS)
def test_executed_equals_from_scratch(ordered, rescaler, k_old, k_new):
    g, src, dst = ordered
    data = E.pack_ordered(src, dst, g.num_vertices, k_old)
    plan = cep.scale_plan(g.num_edges, k_old, k_new)
    new, stats = rescaler.execute(data, plan, verify=True)
    want = E.pack_ordered(src, dst, g.num_vertices, k_new)
    np.testing.assert_array_equal(np.asarray(new.edges), np.asarray(want.edges))
    np.testing.assert_array_equal(np.asarray(new.mask), np.asarray(want.mask))
    np.testing.assert_array_equal(np.asarray(new.degrees), np.asarray(want.degrees))
    assert new.k == k_new and new.num_edges == g.num_edges
    # Metrics re-check must agree with the from-scratch pack's quality numbers.
    assert new.mirrors == want.mirrors
    assert new.replication_factor == pytest.approx(want.replication_factor, abs=0)
    assert stats.oracle_checked


@pytest.mark.parametrize("k_old,k_new", PAIRS)
def test_bytes_copied_equal_plan_migrated_bytes(ordered, rescaler, k_old, k_new):
    g, src, dst = ordered
    data = E.pack_ordered(src, dst, g.num_vertices, k_old)
    plan = cep.scale_plan(g.num_edges, k_old, k_new)
    _, stats = rescaler.execute(data, plan)
    assert stats.migrated_edges == plan.migrated_edges
    assert stats.migrated_bytes == plan.migrated_bytes(EDGE_BYTES)
    # Moved + stayed rows account for every edge exactly once.
    assert stats.migrated_edges + stats.stay_edges == g.num_edges
    # The program is O(overlay ranges), never O(|E|).
    assert stats.copy_ops <= k_old + k_new


def test_roundtrip_bit_identical(ordered, rescaler):
    g, src, dst = ordered
    d8 = E.pack_ordered(src, dst, g.num_vertices, 8)
    d12, _ = rescaler.rescale(d8, 12, verify=True)
    back, _ = rescaler.rescale(d12, 8, verify=True)
    orig = E.pack_ordered(src, dst, g.num_vertices, 8)
    np.testing.assert_array_equal(np.asarray(back.edges), np.asarray(orig.edges))
    np.testing.assert_array_equal(np.asarray(back.mask), np.asarray(orig.mask))
    assert back.mirrors == orig.mirrors


def test_degenerate_more_partitions_than_edges(rescaler):
    g = rmat_graph(4, 1, seed=2)  # tiny: |E| can be < k_new
    order = np.arange(g.num_edges)
    src, dst = g.src[order], g.dst[order]
    k_new = g.num_edges + 5
    data = E.pack_ordered(src, dst, g.num_vertices, 2)
    new, _ = rescaler.rescale(data, k_new, verify=True)
    want = E.pack_ordered(src, dst, g.num_vertices, k_new)
    np.testing.assert_array_equal(np.asarray(new.edges), np.asarray(want.edges))


def test_rejects_non_cep_layout(ordered, rescaler):
    g, _, _ = ordered
    hashed = E.build_engine_data(g, baselines.hash_1d(g, 4), 4)
    with pytest.raises(ValueError, match="not CEP-chunked"):
        rescaler.rescale(hashed, 5)


def test_rejects_mismatched_plan(ordered, rescaler):
    g, src, dst = ordered
    data = E.pack_ordered(src, dst, g.num_vertices, 4)
    with pytest.raises(ValueError, match="k_old"):
        rescaler.execute(data, cep.scale_plan(g.num_edges, 5, 6))
    with pytest.raises(ValueError, match=r"\|E\|"):
        rescaler.execute(data, cep.scale_plan(g.num_edges + 1, 4, 5))


def test_unpack_ordered_roundtrip(ordered):
    g, src, dst = ordered
    data = E.pack_ordered(src, dst, g.num_vertices, 7)
    s2, d2 = E.unpack_ordered(data)
    np.testing.assert_array_equal(s2, src)
    np.testing.assert_array_equal(d2, dst)


def test_controller_executes_attached_engine(ordered):
    g, src, dst = ordered
    t = [0.0]
    ctl = ec.ElasticController(4, dead_after_s=5.0, clock=lambda: t[0])
    ctl.attach_engine(E.pack_ordered(src, dst, g.num_vertices, 4))
    t[0] = 1.0
    for h in (0, 1, 2):
        ctl.heartbeat(h, 1)
    t[0] = 5.6  # host 3 missed its beat; 0-2 are fresh
    ev = ctl.poll()
    assert ev is not None and ev.kind == "scale_in" and ev.executed
    assert ctl.engine_data.k == 3
    want = E.pack_ordered(src, dst, g.num_vertices, 3)
    np.testing.assert_array_equal(np.asarray(ctl.engine_data.edges), np.asarray(want.edges))
    assert ctl.rescale_stats[0].migrated_edges == cep.migrated_edges_exact(g.num_edges, 4, 3)
    # Executed events report the fraction actually migrated, not the
    # synthetic state_elements model.
    assert ev.plan_edges_moved_frac == pytest.approx(
        ctl.rescale_stats[0].migrated_edges / g.num_edges
    )


def test_controller_without_engine_still_plans_only():
    t = [0.0]
    ctl = ec.ElasticController(3, dead_after_s=5.0, clock=lambda: t[0])
    t[0] = 1.0
    ctl.heartbeat(0, 1)
    ctl.heartbeat(1, 1)
    t[0] = 5.6
    ev = ctl.poll()
    assert ev is not None and not ev.executed and ctl.engine_data is None


def test_rescaled_engine_runs_pagerank(ordered):
    """The migrated EngineData is live engine state, not just buffers."""
    from repro.launch import mesh as MM

    g, src, dst = ordered
    mesh = MM.make_test_mesh(data=1, model=1)
    d4 = E.pack_ordered(src, dst, g.num_vertices, 4)
    p4 = np.asarray(E.pagerank(d4, mesh, iterations=20))  # before: d4 is donated
    d6, _ = ElasticRescaler().rescale(d4, 6)
    p6 = np.asarray(E.pagerank(d6, mesh, iterations=20))
    np.testing.assert_allclose(p4, p6, rtol=1e-5, atol=1e-8)


def test_recheck_false_skips_host_metrics(ordered, rescaler):
    g, src, dst = ordered
    data = E.pack_ordered(src, dst, g.num_vertices, 4)
    new, stats = rescaler.rescale(data, 6, recheck=False)
    assert new.mirrors == -1 and np.isnan(new.replication_factor)
    assert stats.recheck_s == 0.0 or stats.recheck_s < 1e-3
    # Buffers are still the real migration result.
    want = E.pack_ordered(src, dst, g.num_vertices, 6)
    np.testing.assert_array_equal(np.asarray(new.edges), np.asarray(want.edges))


def test_noop_rescale_returns_same_buffers():
    g = rmat_graph(6, 4, seed=1)
    order = np.arange(g.num_edges)
    data = E.pack_ordered(g.src[order], g.dst[order], g.num_vertices, 3)
    new, stats = ElasticRescaler().rescale(data, 3)
    assert new is data and stats.migrated_edges == 0 and stats.copy_ops == 0
    np.asarray(new.edges)  # must NOT have been donated away


def test_program_cache_is_lru_bounded(ordered):
    g, src, dst = ordered
    r = ElasticRescaler(program_cache_size=2)
    for k_old, k_new in [(4, 5), (5, 6), (6, 7)]:  # 3 distinct program keys
        data = E.pack_ordered(src, dst, g.num_vertices, k_old)
        r.rescale(data, k_new, verify=True)
    assert len(r._programs) == 2
    # (4, 5) was evicted (LRU); re-executing it retraces and still verifies.
    # Keys are kind-prefixed: ("migrate", n, k_old, k_new, mesh).
    keys = list(r._programs)
    assert all(key[0] == "migrate" and key[2:4] != (4, 5) for key in keys)
    data = E.pack_ordered(src, dst, g.num_vertices, 4)
    _, stats = r.rescale(data, 5, verify=True)
    assert stats.oracle_checked and len(r._programs) == 2
    # A cache hit refreshes recency instead of evicting.
    data = E.pack_ordered(src, dst, g.num_vertices, 4)
    r.rescale(data, 5)
    assert len(r._programs) == 2 and list(r._programs)[-1][2:4] == (4, 5)


def test_program_cache_size_validation():
    with pytest.raises(ValueError, match="program_cache_size"):
        ElasticRescaler(program_cache_size=0)


# ----------------------- sharded path, degenerate mesh of 1 (tier-1 safe) ----
@pytest.fixture(scope="module")
def graph_mesh():
    from repro.launch import mesh as MM

    return MM.make_graph_mesh(1)


def test_single_device_stats_have_no_cross_device_traffic(ordered, rescaler):
    g, src, dst = ordered
    data = E.pack_ordered(src, dst, g.num_vertices, 8)
    _, stats = rescaler.rescale(data, 12)
    assert stats.devices == 1 and stats.cross_device_edges == 0
    assert stats.on_device_edges == stats.migrated_edges


@pytest.mark.parametrize("k_old,k_new", [(8, 12), (12, 8), (3, 7)])
def test_sharded_mesh1_bit_identical(ordered, rescaler, graph_mesh, k_old, k_new):
    """Mesh of 1 is the degenerate case of the sharded path, not a fork: the
    executed migration must still match the single-device oracle bit-for-bit."""
    g, src, dst = ordered
    sdata = E.pack_ordered_sharded(src, dst, g.num_vertices, k_old, graph_mesh)
    new, stats = rescaler.rescale(sdata, k_new, verify=True)
    assert isinstance(new, E.ShardedEngineData) and new.k == k_new
    assert stats.devices == 1 and stats.cross_device_edges == 0
    want = E.pack_ordered(src, dst, g.num_vertices, k_new)
    got = E.unshard_engine_data(new)
    np.testing.assert_array_equal(np.asarray(got.edges), np.asarray(want.edges))
    np.testing.assert_array_equal(np.asarray(got.mask), np.asarray(want.mask))


def test_sharded_roundtrip_bit_identical(ordered, rescaler, graph_mesh):
    g, src, dst = ordered
    d8 = E.pack_ordered_sharded(src, dst, g.num_vertices, 8, graph_mesh)
    d12, _ = rescaler.rescale(d8, 12, verify=True)
    back, _ = rescaler.rescale(d12, 8, verify=True)
    orig = E.pack_ordered(src, dst, g.num_vertices, 8)
    got = E.unshard_engine_data(back)
    np.testing.assert_array_equal(np.asarray(got.edges), np.asarray(orig.edges))
    np.testing.assert_array_equal(np.asarray(got.mask), np.asarray(orig.mask))


def test_sharded_noop_returns_same_object(ordered, graph_mesh):
    g, src, dst = ordered
    sdata = E.pack_ordered_sharded(src, dst, g.num_vertices, 4, graph_mesh)
    new, stats = ElasticRescaler().rescale(sdata, 4)
    assert new is sdata and stats.copy_ops == 0 and stats.devices == 1
    np.asarray(new.edges)  # must NOT have been donated away


def test_sharded_more_partitions_than_edges(graph_mesh):
    g = rmat_graph(4, 1, seed=2)  # tiny: |E| < k_new ⇒ zero-size chunks
    order = np.arange(g.num_edges)
    src, dst = g.src[order], g.dst[order]
    k_new = g.num_edges + 5
    sdata = E.pack_ordered_sharded(src, dst, g.num_vertices, 2, graph_mesh)
    new, stats = ElasticRescaler().rescale(sdata, k_new, verify=True)
    assert stats.oracle_checked and new.k == k_new
    want = E.pack_ordered(src, dst, g.num_vertices, k_new)
    got = E.unshard_engine_data(new)
    np.testing.assert_array_equal(np.asarray(got.edges), np.asarray(want.edges))


def test_sharded_rejects_non_cep_layout(ordered, graph_mesh):
    g, _, _ = ordered
    hashed = E.build_engine_data(g, baselines.hash_1d(g, 4), 4)
    sdata = E.shard_engine_data(hashed, graph_mesh)
    with pytest.raises(ValueError, match="not CEP-chunked"):
        ElasticRescaler().rescale(sdata, 5)


def test_sharded_rescaled_engine_runs_pagerank(ordered, graph_mesh):
    g, src, dst = ordered
    d4 = E.pack_ordered_sharded(src, dst, g.num_vertices, 4, graph_mesh)
    d6, _ = ElasticRescaler().rescale(d4, 6)
    p_sharded = np.asarray(E.pagerank(d6, iterations=20))  # mesh from the data
    from repro.launch import mesh as MM

    ref = E.pack_ordered(src, dst, g.num_vertices, 6)
    p_ref = np.asarray(E.pagerank(ref, MM.make_test_mesh(1, 1), iterations=20))
    np.testing.assert_allclose(p_sharded, p_ref, rtol=1e-6, atol=1e-9)


def test_controller_attach_engine_with_mesh(ordered, graph_mesh):
    g, src, dst = ordered
    t = [0.0]
    ctl = ec.ElasticController(4, dead_after_s=5.0, clock=lambda: t[0])
    ctl.attach_engine(E.pack_ordered(src, dst, g.num_vertices, 4), mesh=graph_mesh)
    assert isinstance(ctl.engine_data, E.ShardedEngineData)
    t[0] = 1.0
    for h in (0, 1, 2):
        ctl.heartbeat(h, 1)
    t[0] = 5.6
    ev = ctl.poll()
    assert ev is not None and ev.executed and ctl.engine_data.k == 3
    # Mesh of 1: everything migrated on-device, so no cross-device traffic.
    assert ev.cross_device_bytes == 0
    assert ctl.rescale_stats[0].on_device_edges == ctl.rescale_stats[0].migrated_edges
    want = E.pack_ordered(src, dst, g.num_vertices, 3)
    got = E.unshard_engine_data(ctl.engine_data)
    np.testing.assert_array_equal(np.asarray(got.edges), np.asarray(want.edges))
