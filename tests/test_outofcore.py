"""Out-of-core chunked GEO pipeline (single-process half).

Covers the stateless generation/draw contracts (one mix_hash for every
deterministic stream in the repo — the property tests the helper's docstring
promises), the hierarchical ordering pipeline (core/hier_order.py) with its
small-scale RF differential against the in-core ``geo_order`` oracle, the
shard-streamed ``pack_slots`` commit, and the cold-region spill layer.  The
2-process end-to-end acceptance rides on tests/outofcore_harness.py via the
``cluster`` fixture below.
"""
import numpy as np
import pytest
from conftest import hypothesis_or_stub

from repro.core import hier_order as HO
from repro.core.baselines import mix_hash, splitmix64
from repro.core.graph import Graph, grid_graph, powerlaw_graph, rmat_graph
from repro.core.metrics import replication_factor_ordered
from repro.core.ordering import geo_order
from repro.data import shards as DS
from repro.elastic import controller as ec
from repro.graphs import engine as GE
from repro.launch import mesh as MM
from repro.stream import (
    EdgeUpdateBatch,
    OutOfCoreIngestor,
    SpillConfig,
    SpillStore,
    SyntheticStream,
)

given, settings, st = hypothesis_or_stub()


# ---------------------------------------------------- one stateless draw (S6)
@given(
    seed=st.integers(0, 2**31 - 1),
    major=st.integers(0, 2**40),
    minor=st.integers(0, 2**20),
    salt=st.integers(0, 255),
)
@settings(max_examples=60, deadline=None)
def test_mix_hash_scalar_vector_agree(seed, major, minor, salt):
    """The same (seed, major, minor, salt) yields the same u64 draw whether
    hashed as a scalar or as an element of a broadcast array — the property
    that lets call sites vectorize freely without forking the contract."""
    scalar = int(mix_hash(seed, major, minor, salt))
    vec = mix_hash(seed, np.asarray([major, major + 1]), minor, salt)
    assert int(vec[0]) == scalar
    vec2 = mix_hash(seed, major, np.arange(minor, minor + 3), salt)
    assert int(vec2[0]) == scalar
    assert 0 <= scalar < 2**64


def test_mix_hash_scalar_vector_agree_deterministic():
    """Deterministic pin of the hypothesis property above (the stub skips it
    when hypothesis is absent): scalar and vectorized draws agree on a grid
    of keys."""
    for seed in (0, 1, 2**31 - 1):
        for major in (0, 17, 2**40):
            vec = mix_hash(seed, np.asarray([major, major + 1]), 5, 3)
            assert int(vec[0]) == int(mix_hash(seed, major, 5, 3))
            vec2 = mix_hash(seed, major, np.arange(5, 8), 3)
            assert int(vec2[0]) == int(mix_hash(seed, major, 5, 3))


def test_region_of_symmetric_deterministic():
    ing = OutOfCoreIngestor(2**20, regions=7, slots_per_region=4)
    rng = np.random.default_rng(0)
    for u, v in rng.integers(0, 2**20, size=(50, 2)).tolist():
        assert ing.region_of(u, v) == ing.region_of(v, u)
        lo, hi = min(u, v), max(u, v)
        key = np.uint64(lo) * np.uint64(2**20) + np.uint64(hi)
        assert ing.region_of(u, v) == int(splitmix64(key) % np.uint64(7))


def test_mix_hash_shared_across_call_sites():
    """SyntheticStream and data/shards hash through the SAME helper with the
    same key layout: the stream's private draw equals a direct mix_hash call,
    and stream_edges' pairs are recomputable from raw mix_hash draws."""
    g = rmat_graph(6, 4, seed=3)
    stream = SyntheticStream(g, batch_size=16, seed=42)
    for batch, pos, salt in [(0, 0, 1), (3, 7, 2), (11, 5, 3)]:
        assert stream._h(batch, pos, salt) == int(mix_hash(42, batch, pos, salt))
    plan = DS.RmatShardPlan(scale=8, edge_factor=4, seed=9)
    got = DS.stream_edges(plan, batch=5, size=64, salt=2)
    pos = np.arange(64, dtype=np.uint64)
    nv = np.uint64(plan.num_vertices)
    u = mix_hash(9, 5, pos, DS._SALT_STREAM + 4) % nv
    v = mix_hash(9, 5, pos, DS._SALT_STREAM + 5) % nv
    lo, hi = np.minimum(u, v).astype(np.int64), np.maximum(u, v).astype(np.int64)
    keep = lo != hi
    np.testing.assert_array_equal(got, np.stack([lo[keep], hi[keep]], axis=1))


@given(seed=st.integers(0, 2**16), start=st.integers(0, 2**12), n=st.integers(1, 64))
@settings(max_examples=25, deadline=None)
def test_candidate_edges_stateless_in_index(seed, start, n):
    """candidate_edges over any index subset equals the same rows of a full
    scan — the regenerate-is-the-shuffle property: an edge's value depends
    on (seed, index) only, never on which process asks or in what company."""
    plan = DS.RmatShardPlan(scale=7, edge_factor=8, seed=seed)
    idx = np.arange(start % plan.num_candidates, plan.num_candidates, 7)[:n]
    subset = DS.candidate_edges(plan, idx)
    singles = [DS.candidate_edges(plan, np.asarray([i])) for i in idx]
    np.testing.assert_array_equal(
        subset,
        np.concatenate(singles) if singles else np.empty((0, 2), np.int64),
    )


def test_shards_partition_candidates_and_reshard_invariant():
    """Shard edges concatenated in shard order ARE the full candidate scan
    (nothing lost/duplicated at shard boundaries), for ANY shard count —
    regenerating under a different num_shards is a free reshard."""
    full = DS.candidate_edges(DS.RmatShardPlan(scale=8, edge_factor=8, seed=1),
                              np.arange(DS.RmatShardPlan(scale=8, edge_factor=8).num_candidates))
    for num_shards in (1, 3, 5):
        plan = DS.RmatShardPlan(scale=8, edge_factor=8, seed=1, num_shards=num_shards)
        got = np.concatenate([DS.shard_edges(plan, s) for s in range(num_shards)])
        np.testing.assert_array_equal(got, full)


def test_sample_edges_is_direct_strided_scan():
    plan = DS.RmatShardPlan(scale=8, edge_factor=8, seed=4)
    np.testing.assert_array_equal(
        DS.sample_edges(plan, stride=4, dedup=False),
        DS.candidate_edges(plan, np.arange(0, plan.num_candidates, 4)),
    )


@given(u=st.integers(0, 2**20 - 1), v=st.integers(0, 2**20 - 1))
@settings(max_examples=40, deadline=None)
def test_region_of_symmetric_and_stateless(u, v):
    """Content addressing: region_of is orientation-free and a pure function
    of the canonical edge — any process (or a later delete) resolves the
    same region with zero shared state."""
    ing = OutOfCoreIngestor(2**20, regions=7, slots_per_region=4)
    assert ing.region_of(u, v) == ing.region_of(v, u)
    lo, hi = min(u, v), max(u, v)
    key = np.uint64(lo) * np.uint64(2**20) + np.uint64(hi)
    assert ing.region_of(u, v) == int(splitmix64(key) % np.uint64(7))


# ----------------------------------------------- hierarchical pipeline units
def test_chunk_load_additive_across_shards():
    """The load histogram of the whole edge list equals the sum of per-shard
    histograms — the property that lets every process bincount only its own
    shards and merge by collective sum."""
    plan = DS.RmatShardPlan(scale=9, edge_factor=8, seed=0, num_shards=4)
    edges = np.concatenate([DS.shard_edges(plan, s) for s in range(4)])
    rank = HO.locality_rank(edges, plan.num_vertices, seed=0)
    whole = HO.chunk_load(rank, edges)
    summed = sum(HO.chunk_load(rank, DS.shard_edges(plan, s)) for s in range(4))
    np.testing.assert_array_equal(whole, summed)


def test_chunk_splits_balance_and_membership():
    """Equal-load cuts: every chunk's edge count stays within one rank's
    keyed degree of E/C, membership is consistent with the splits, and the
    split array is strictly ascending 0 … V."""
    g = rmat_graph(12, 16, seed=0)
    edges = np.stack([g.src, g.dst], axis=1).astype(np.int64)
    cfg = HO.HierConfig(num_chunks=6)
    rank = HO.locality_rank(edges, g.num_vertices, seed=0)
    load = HO.chunk_load(rank, edges)
    splits = HO.chunk_splits(load, cfg)
    assert splits[0] == 0 and splits[-1] == g.num_vertices
    assert (np.diff(splits) > 0).all()
    cid = HO.chunk_of_edges(splits, rank, edges)
    assert cid.min() >= 0 and cid.max() < splits.shape[0] - 1
    counts = np.bincount(cid, minlength=splits.shape[0] - 1)
    target = edges.shape[0] / (splits.shape[0] - 1)
    max_keyed_degree = int(load.max())
    assert (np.abs(counts - target) <= max_keyed_degree + 1).all()
    # Pure in (load, cfg): identical inputs, identical splits.
    np.testing.assert_array_equal(splits, HO.chunk_splits(load.copy(), cfg))


def test_max_chunk_edges_is_a_real_bound():
    """Asking for chunks under a byte budget yields MORE chunks, each within
    max_chunk_edges + one keyed degree — the out-of-core memory contract."""
    g = rmat_graph(11, 16, seed=1)
    edges = np.stack([g.src, g.dst], axis=1).astype(np.int64)
    cfg = HO.HierConfig(num_chunks=2, max_chunk_edges=4096)
    rank = HO.locality_rank(edges, g.num_vertices, seed=0)
    load = HO.chunk_load(rank, edges)
    splits = HO.chunk_splits(load, cfg)
    assert splits.shape[0] - 1 >= g.num_edges // 4096
    counts = np.bincount(
        HO.chunk_of_edges(splits, rank, edges), minlength=splits.shape[0] - 1
    )
    assert counts.max() <= 4096 + int(load.max())


def test_order_edge_block_duplicates_ride_adjacent():
    """Duplicate rows follow their key's first occurrence: the ordered block
    restricted to unique keys is a permutation of the unique edge set, and
    copies are contiguous runs."""
    g = rmat_graph(7, 6, seed=2)
    edges = np.stack([g.src, g.dst], axis=1).astype(np.int64)
    dup = np.concatenate([edges, edges[:40], edges[:10]])
    rng = np.random.default_rng(0)
    dup = dup[rng.permutation(dup.shape[0])]
    perm = HO.order_edge_block(dup, HO.HierConfig(), seed=0)
    assert sorted(perm.tolist()) == list(range(dup.shape[0]))
    out = dup[perm]
    key = out[:, 0] * np.int64(g.num_vertices) + out[:, 1]
    # Copies contiguous: each key occupies exactly one run.
    change = np.flatnonzero(np.diff(key) != 0).shape[0] + 1
    assert change == np.unique(key).shape[0]


def test_chunk_mode_mirror_matches_device():
    """chunk_mode="device" (on-mesh greedy) and "mirror" (its numpy twin)
    produce the identical permutation — the byte-exact host mirror the
    differential mode leans on."""
    g = rmat_graph(7, 6, seed=0)
    edges = np.stack([g.src, g.dst], axis=1).astype(np.int64)
    p_dev = HO.order_edge_block(edges, HO.HierConfig(chunk_mode="device"), seed=3)
    p_mir = HO.order_edge_block(edges, HO.HierConfig(chunk_mode="mirror"), seed=3)
    np.testing.assert_array_equal(p_dev, p_mir)


def test_seam_spans_never_overlap():
    spans = HO.seam_spans([100, 30, 8, 200], seam_window=2048)
    for (lo, hi), (lo2, hi2) in zip(spans, spans[1:]):
        assert hi <= lo2
    assert all(lo < hi for lo, hi in spans)
    assert HO.seam_spans([5, 0, 7], seam_window=16) == []  # degenerate boundary


def test_hier_order_permutation_and_deterministic():
    g = rmat_graph(9, 8, seed=0)
    cfg = HO.HierConfig(num_chunks=4)
    perm, info = HO.hier_order(g, cfg)
    assert sorted(perm.tolist()) == list(range(g.num_edges))
    perm2, info2 = HO.hier_order(g, cfg)
    np.testing.assert_array_equal(perm, perm2)
    np.testing.assert_array_equal(info["splits"], info2["splits"])
    assert sum(info["chunk_sizes"]) == g.num_edges


# ------------------------------------------------ RF differential (the gate)
def _worst_ratio(g: Graph, cfg: HO.HierConfig) -> float:
    edges = np.stack([g.src, g.dst], axis=1).astype(np.int64)
    ordered, _ = HO.hier_order_edges(edges, g.num_vertices, cfg)
    o = geo_order(g, seed=0)
    so, do = g.src[o], g.dst[o]
    worst = 0.0
    for k in (4, 8, 16, 32, 64, 128):
        rf_h = replication_factor_ordered(ordered[:, 0], ordered[:, 1], k, g.num_vertices)
        rf_o = replication_factor_ordered(so, do, k, g.num_vertices)
        worst = max(worst, rf_h / rf_o)
    return worst


@pytest.mark.parametrize(
    "name,make,cfg",
    [
        # Low-degree lattice: needs the full-stream bfs rank (a sparse sample
        # fragments below percolation); 8 chunks.
        ("grid128", lambda: grid_graph(128), HO.HierConfig(num_chunks=8, rank_mode="bfs")),
        # Heavy-tailed sparse: geo first-touch rank, 8 chunks.
        ("powerlaw60k", lambda: powerlaw_graph(60_000, seed=0), HO.HierConfig(num_chunks=8)),
        # Dense skewed RMAT: 4 chunks (num_chunks is a memory knob, not
        # parallel slack — see the hier_order module docstring).
        ("rmat14", lambda: rmat_graph(14, 16, seed=0), HO.HierConfig(num_chunks=4)),
    ],
)
def test_hier_rf_within_margin_of_incore_oracle(name, make, cfg):
    """THE acceptance differential: hierarchical (bounded-memory) ordering
    stays within 1.10× of the sequential in-core geo_order oracle's RF at
    every k in {4..128}, on every tested graph family."""
    worst = _worst_ratio(make(), cfg)
    assert worst <= 1.10, f"{name}: worst RF ratio {worst:.4f} > 1.10"


# ------------------------------------------- shard-streamed pack_slots commit
def test_pack_slots_sharded_stream_matches_oracle():
    """Unsharded (1-device mesh), the shard-streamed commit is byte-identical
    to the in-core pack_slots oracle — edges, mask, degrees, and edge count."""
    g = rmat_graph(8, 6, seed=0)
    k, spr = 4, -(-g.num_edges // 4)
    cap = k * spr
    slot_src = np.zeros(cap, dtype=np.int64)
    slot_dst = np.zeros(cap, dtype=np.int64)
    slot_valid = np.zeros(cap, dtype=bool)
    order = geo_order(g, seed=0)
    slot_src[: g.num_edges] = g.src[order]
    slot_dst[: g.num_edges] = g.dst[order]
    slot_valid[: g.num_edges] = True
    mesh = MM.make_graph_mesh(1)
    oracle = GE.pack_slots(slot_src, slot_dst, slot_valid, k, g.num_vertices)

    def part_fn(p):
        sl = slice(p * spr, (p + 1) * spr)
        return slot_src[sl], slot_dst[sl], slot_valid[sl]

    sharded = GE.pack_slots_sharded_stream(part_fn, k, g.num_vertices, mesh, spr)
    np.testing.assert_array_equal(np.asarray(sharded.edges), np.asarray(oracle.edges))
    np.testing.assert_array_equal(np.asarray(sharded.mask), np.asarray(oracle.mask))
    np.testing.assert_array_equal(
        np.asarray(sharded.degrees), np.asarray(oracle.degrees)
    )
    assert sharded.num_edges == g.num_edges and sharded.k == k


def test_local_slot_partitions_cover_k_once():
    mesh = MM.make_graph_mesh(1)
    parts = GE.local_slot_partitions(5, mesh)
    assert sorted(parts) == list(range(5))  # single process owns everything


# ----------------------------------------------------------- spill layer
def test_spill_store_bounds_residency_and_faults_exact():
    store = SpillStore(regions=10, slots_per_region=8, config=SpillConfig(max_resident=3))
    written = {}
    for p in range(10):
        src, dst, valid = store.get(p)
        src[0], dst[0], valid[0] = 100 + p, 200 + p, True
        written[p] = (100 + p, 200 + p)
        store.evict_to_budget()
        assert store.resident <= 3
    assert store.counters["spills"] >= 7
    assert store.counters["bytes_spilled"] > 0
    # Faulting every region back returns the exact bytes written.
    for p in range(10):
        src, dst, valid = store.get(p)
        assert (int(src[0]), int(dst[0])) == written[p] and bool(valid[0])
        store.evict_to_budget()
    assert store.counters["faults"] >= 7
    assert store.counters["bytes_faulted"] > 0


def test_spill_store_lru_is_least_recently_touched():
    store = SpillStore(regions=4, slots_per_region=4, config=SpillConfig(max_resident=2))
    for p in range(3):
        src, dst, valid = store.get(p)
        valid[0] = True
    store.touch(0)  # 0 is now most recent; 1 is the LRU victim
    store.evict_to_budget()
    assert set(store._hot) == {0, 2}


def test_spill_store_disk_mode_roundtrip(tmp_path):
    cfg = SpillConfig(max_resident=1, directory=str(tmp_path / "spill"))
    store = SpillStore(regions=3, slots_per_region=4, config=cfg)
    for p in range(3):
        src, dst, valid = store.get(p)
        src[1], dst[1], valid[1] = 7 * p + 1, 7 * p + 2, True
        store.evict_to_budget()
    files = sorted((tmp_path / "spill").iterdir())
    assert len(files) == 2  # two spilled region files on disk
    for p in range(3):
        src, dst, valid = store.get(p)
        assert (int(src[1]), int(dst[1])) == (7 * p + 1, 7 * p + 2)
        store.evict_to_budget()
    # Faulted files are consumed (read + removed), not left to go stale.
    assert len(list((tmp_path / "spill").iterdir())) <= 2


def test_spill_store_drops_empty_blocks_without_serializing():
    store = SpillStore(regions=6, slots_per_region=4, config=SpillConfig(max_resident=1))
    for p in range(6):
        store.get(p)  # created zeroed, never written
    store.evict_to_budget()
    assert store.counters["spills"] == 0 and store.counters["bytes_spilled"] == 0
    assert store.resident == 1


def test_outofcore_ingestor_live_set_exact_under_spill():
    """Differential vs a python-set oracle through a random insert/delete
    stream: the spilled+faulted live set is EXACTLY the oracle's — spilling
    must never lose or duplicate an edge. spr is sized generously so no
    region-full skip muddies the accounting (skips are themselves asserted
    zero)."""
    V, regions = 500, 16
    ing = OutOfCoreIngestor(V, regions, slots_per_region=256,
                            config=SpillConfig(max_resident=4))
    oracle: set = set()
    rng = np.random.default_rng(7)
    skipped = 0
    for step in range(12):
        ins = rng.integers(0, V, size=(60, 2))
        ins = ins[ins[:, 0] != ins[:, 1]]
        dele = (
            np.asarray(sorted(oracle), dtype=np.int64)[
                rng.permutation(len(oracle))[: len(oracle) // 4]
            ]
            if oracle
            else np.empty((0, 2), np.int64)
        )
        stats = ing.ingest(EdgeUpdateBatch(insert=ins, delete=dele))
        skipped += stats.skipped
        for u, v in dele.tolist():
            oracle.discard((min(u, v), max(u, v)))
        for u, v in ins.tolist():
            oracle.add((min(u, v), max(u, v)))
        assert ing.store.resident <= 4
    # Dedup-in-batch means skips only from duplicates, never capacity.
    src, dst = ing.snapshot()
    got = set(zip(src.tolist(), dst.tolist()))
    assert got == oracle
    assert ing.num_edges == len(oracle)
    assert ing.store.counters["spills"] > 0 and ing.store.counters["faults"] > 0


def test_outofcore_ingestor_duplicate_and_absent_are_skips():
    ing = OutOfCoreIngestor(100, regions=4, slots_per_region=8)
    s0 = ing.ingest(EdgeUpdateBatch(insert=np.asarray([[2, 1]]),
                                    delete=np.empty((0, 2), np.int64)))
    assert s0.inserted == 1
    s1 = ing.ingest(EdgeUpdateBatch(insert=np.asarray([[1, 2]]),
                                    delete=np.empty((0, 2), np.int64)))
    assert s1.inserted == 0 and s1.skipped == 1  # same canonical edge again
    s2 = ing.ingest(EdgeUpdateBatch(insert=np.empty((0, 2), np.int64),
                                    delete=np.asarray([[5, 6]])))
    assert s2.deleted == 0 and s2.skipped == 1  # absent delete is idempotent
    assert ing.num_edges == 1


def test_controller_ingest_event_carries_spill_counters():
    """The attached-stream protocol: an OutOfCoreIngestor behind the elastic
    controller produces IngestEvents whose ``spill`` dict exposes the store
    counters + resident size — spill traffic lands in the shared event log."""
    ing = OutOfCoreIngestor(1000, regions=12, slots_per_region=16,
                            config=SpillConfig(max_resident=2))
    ctl = ec.ElasticController(4)
    ctl.attach_stream(ing)
    rng = np.random.default_rng(3)
    ev = None
    for b in range(4):
        ins = rng.integers(0, 1000, size=(40, 2))
        ev = ctl.ingest(EdgeUpdateBatch(insert=ins[ins[:, 0] != ins[:, 1]],
                                        delete=np.empty((0, 2), np.int64)))
    assert ev.kind == "ingest" and ev.escalation == "none"
    assert set(ev.spill) == {"spills", "faults", "bytes_spilled", "bytes_faulted", "resident"}
    assert ev.spill["resident"] <= 2 and ev.spill["spills"] > 0
    assert [e.seq for e in ctl.events] == list(range(len(ctl.events)))


# =================================================== 2-process acceptance
# The end-to-end out-of-core run: tests/outofcore_harness.py executes
# generate → rank/count → chunk-order → shard-streamed commit → rescale →
# spill-bounded stream on a real 2-process jax.distributed cluster; this
# parent reassembles the written row blocks and byte-compares against the
# in-core oracle composition it computes itself.
import os
import sys

import outofcore_harness as OH
from benchmarks.common import parse_peak_rss
from repro.core import cep
from repro.launch import multihost as MH

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_PROCS = 2
DEVS_PER_PROC = 4

_UNSUPPORTED_MARKERS = (
    "gloo",
    "cpu_collectives",
    "collectives_implementation",
    "Unable to initialize backend",
    "UNIMPLEMENTED",
    "DEADLINE_EXCEEDED",
)
_BOOTSTRAP_BANNER = "global devices"


@pytest.fixture(scope="module")
def cluster(tmp_path_factory):
    out = tmp_path_factory.mktemp("outofcore")
    res = MH.spawn_local_cluster(
        N_PROCS,
        DEVS_PER_PROC,
        [os.path.join(ROOT, "tests", "outofcore_harness.py"), "--out", str(out)],
        timeout=540.0,
        cwd=ROOT,
    )
    if not res.ok:
        logs = res.format_logs()
        print(logs, file=sys.stderr)
        bootstrapped = any(_BOOTSTRAP_BANNER in p.stdout for p in res.procs)
        if not bootstrapped and any(m in logs for m in _UNSUPPORTED_MARKERS):
            pytest.skip(f"localhost jax.distributed unsupported here:\n{logs[-2000:]}")
        pytest.fail(f"out-of-core harness failed:\n{logs}")
    records, shards = [], []
    import json

    for pid in range(N_PROCS):
        with open(out / f"proc{pid}.json") as fh:
            records.append(json.load(fh))
        shards.append(dict(np.load(out / f"proc{pid}.npz")))
    return res, records, shards


@pytest.fixture(scope="module")
def oracle():
    """The in-core oracle composition — same plan, same config, one process,
    full edge list in memory (fine at test scale)."""
    edges = np.concatenate(
        [DS.shard_edges(OH.PLAN, s) for s in range(OH.PLAN.num_shards)]
    )
    sample = DS.sample_edges(OH.PLAN, OH.SAMPLE_STRIDE)
    ordered, info = HO.hier_order_edges(edges, OH.PLAN.num_vertices, OH.CFG, sample=sample)
    total = int(ordered.shape[0])
    bounds = cep.chunk_bounds(total, OH.K_PACK)
    spr = int(np.diff(bounds).max())
    cap = OH.K_PACK * spr
    slot_src = np.zeros(cap, dtype=np.int64)
    slot_dst = np.zeros(cap, dtype=np.int64)
    slot_valid = np.zeros(cap, dtype=bool)
    for p in range(OH.K_PACK):
        lo, hi = int(bounds[p]), int(bounds[p + 1])
        n = hi - lo
        slot_src[p * spr : p * spr + n] = ordered[lo:hi, 0]
        slot_dst[p * spr : p * spr + n] = ordered[lo:hi, 1]
        slot_valid[p * spr : p * spr + n] = True
    pack = GE.pack_slots(slot_src, slot_dst, slot_valid, OH.K_PACK, OH.PLAN.num_vertices)
    return edges, ordered, info, pack


def reassemble(shards, name: str, global_rows: int) -> np.ndarray:
    """Merge per-process (lo, hi) row blocks; overlaps must byte-agree."""
    rows = {}
    for store in shards:
        for key, data in store.items():
            if not key.startswith(name + "__"):
                continue
            _, lo, hi = key.rsplit("__", 2)
            lo, hi = int(lo), int(hi)
            for r in range(lo, hi):
                row = data[r - lo]
                if r in rows:
                    assert np.array_equal(rows[r], row), f"{name}: divergent row {r}"
                else:
                    rows[r] = row
    assert sorted(rows) == list(range(global_rows)), f"{name}: incomplete row coverage"
    return np.stack([rows[r] for r in range(global_rows)])


def test_processes_agree_on_plan(cluster):
    """Phase A is coordination-free: both processes derived identical splits,
    chunk sizes, and total edge count from their disjoint shard histograms."""
    _, records, _ = cluster
    assert records[0]["splits"] == records[1]["splits"]
    assert records[0]["chunk_sizes"] == records[1]["chunk_sizes"]
    assert records[0]["num_edges"] == records[1]["num_edges"]


def test_commit_is_byte_identical_to_incore_oracle(cluster, oracle):
    """The shard-streamed commit — no process ever held the full edge list —
    equals the in-core pack_slots oracle byte for byte."""
    _, records, shards = cluster
    edges, ordered, info, pack = oracle
    assert records[0]["num_edges"] == int(ordered.shape[0])
    got_edges = reassemble(shards, "commit_edges", OH.K_PACK)
    got_mask = reassemble(shards, "commit_mask", OH.K_PACK)
    got_deg = reassemble(shards, "commit_degrees", OH.PLAN.num_vertices)
    np.testing.assert_array_equal(got_edges, np.asarray(pack.edges))
    np.testing.assert_array_equal(got_mask, np.asarray(pack.mask))
    np.testing.assert_array_equal(got_deg.reshape(-1), np.asarray(pack.degrees))


def test_rescale_roundtrip_returns_to_commit(cluster, oracle):
    """8 → 12 → 8 across the process boundary lands back on the committed
    pack — identical live-edge prefix per partition (the rescaler sizes its
    own slot width, so raw shapes may differ by the scratch column)."""
    _, records, shards = cluster
    pack = oracle[3]
    edges = np.asarray(pack.edges)
    mask = np.asarray(pack.mask)
    back_edges = reassemble(shards, "rescale_back_edges", OH.K_PACK)
    back_mask = reassemble(shards, "rescale_back_mask", OH.K_PACK)
    for p in range(OH.K_PACK):
        want_live = mask[p] > 0
        got_live = back_mask[p] > 0
        n = int(want_live.sum())
        assert int(got_live.sum()) == n
        assert got_live[:n].all(), f"partition {p}: not prefix-valid after round trip"
        np.testing.assert_array_equal(back_edges[p][:n], edges[p][want_live])


def test_rescale_up_preserves_ordered_sequence(cluster, oracle):
    """At k=12 the flat ordered edge list is invariant: concatenating the
    partition prefixes (partition-major) reproduces the oracle's ordered
    sequence, and per-partition counts are the CEP chunk sizes at k=12."""
    from repro.launch import sharding as SH

    _, records, shards = cluster
    ordered = oracle[1]
    total = int(ordered.shape[0])
    g = N_PROCS * DEVS_PER_PROC
    k_pad = SH.padded_partition_count(OH.K_UP, g)
    up_edges = reassemble(shards, "rescale_up_edges", k_pad)
    up_mask = reassemble(shards, "rescale_up_mask", k_pad)
    sizes = np.diff(cep.chunk_bounds(total, OH.K_UP))
    flat = []
    for p in range(OH.K_UP):
        row = SH.partition_row(p, OH.K_UP, g)
        count = int((up_mask[row] > 0).sum())
        assert count == sizes[p], f"partition {p}: {count} != {sizes[p]}"
        live = up_mask[row] > 0
        flat.append(up_edges[row][live])
    np.testing.assert_array_equal(np.concatenate(flat), ordered.astype(np.int32))


def test_quality_within_margin_of_geo_oracle(oracle):
    """The acceptance RF gate on the distributed composition's order (proven
    byte-identical to this oracle): within 1.10× of sequential geo_order at
    every k — duplicates ride along in the hierarchical sequence, the oracle
    orders the deduped graph."""
    edges, ordered, _, _ = oracle
    V = OH.PLAN.num_vertices
    key = edges[:, 0] * np.int64(V) + edges[:, 1]
    _, first = np.unique(key, return_index=True)
    g = Graph.from_edges(edges[np.sort(first)], V)
    o = geo_order(g, seed=0)
    so, do = g.src[o], g.dst[o]
    worst = 0.0
    for k in (4, 8, 16, 32, 64, 128):
        rf_h = replication_factor_ordered(ordered[:, 0], ordered[:, 1], k, V)
        rf_o = replication_factor_ordered(so, do, k, V)
        worst = max(worst, rf_h / rf_o)
    assert worst <= 1.10, f"worst RF ratio {worst:.4f} > 1.10"


def test_stream_phase_deterministic_and_spill_bounded(cluster):
    """The spill-bounded ingest tail: both processes' stateless replays land
    the identical live-edge count, residency stayed within budget, and spill
    traffic actually happened (the counters prove the bound bit)."""
    _, records, _ = cluster
    s0, s1 = records[0]["stream"], records[1]["stream"]
    assert s0["num_edges"] == s1["num_edges"] > 0
    assert s0["inserted"] == s1["inserted"]
    assert s0["skipped"] == s1["skipped"]
    for s in (s0, s1):
        assert s["resident"] <= OH.SPILL_RESIDENT
        assert s["spill"]["spills"] > 0
        assert s["seqs"] == list(range(len(s["seqs"])))


def test_peak_rss_markers_emitted(cluster):
    res, _, _ = cluster
    for p in res.procs:
        rss = parse_peak_rss(p.stdout)
        assert rss is not None and rss > 0
