"""Multi-(host-)device tests: run in subprocesses so XLA_FLAGS can force 8
devices without polluting the main test process (which must see 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.launch.multihost import force_host_device_flags  # noqa: E402


def run_with_devices(code: str, n: int = 8, timeout: int = 420) -> str:
    """Run ``code`` in a child forced to ``n`` host devices.

    The device-count flag is built explicitly (force_host_device_flags strips
    any pre-existing count and preserves unrelated flags) — never patched with
    string substitution, which corrupts the value whenever the old count's
    digits appear elsewhere in the string. Only the child's env copy is
    touched; tests that must mutate ``os.environ`` in the child restore it in
    a ``finally`` (see test_production_mesh_shapes)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = force_host_device_flags(n, env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=ROOT,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sp_decode_attention_matches_plain():
    run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch import mesh as MM
        from repro.models import dist as D
        from repro.models import layers as L

        mesh = MM.make_test_mesh(data=2, model=4)
        b, hq, hkv, hd, s = 4, 8, 2, 16, 64
        key = jax.random.PRNGKey(0)
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (b, hq, 1, hd))
        ck = jax.random.normal(kk, (b, hkv, s, hd))
        cv = jax.random.normal(kv, (b, hkv, s, hd))
        pos = jnp.asarray(40, jnp.int32)

        dist = D.Distribution(mesh=mesh, batch_axes=("data",), seq_axes=("model",))
        with mesh:
            got = jax.jit(lambda q, k, v: D.sp_decode_attention(
                dist, q, k, v, pos, window=None, softcap=None, scale=hd**-0.5))(q, ck, cv)
        # Plain reference: mea_attention over the cache with kv_len mask.
        want = L.mea_attention(q, ck, cv, causal=True, q_offset=pos,
                               kv_len=jnp.full((b,), pos + 1, jnp.int32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

        # Windowed + softcapped variant.
        with mesh:
            got_w = jax.jit(lambda q, k, v: D.sp_decode_attention(
                dist, q, k, v, pos, window=jnp.asarray(16), softcap=20.0, scale=hd**-0.5))(q, ck, cv)
        import repro.models.layers as L2
        import functools
        want_w = L.mea_attention(q, ck, cv, causal=True, q_offset=pos, window=jnp.asarray(16),
                                 softcap=20.0, kv_len=jnp.full((b,), pos + 1, jnp.int32))
        np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w), rtol=2e-5, atol=2e-5)
        print("SP-DECODE-OK")
    """)


def test_sp_cache_update_writes_owner_shard_only():
    run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.launch import mesh as MM
        from repro.models import dist as D

        mesh = MM.make_test_mesh(data=2, model=4)
        b, hkv, s, hd = 2, 2, 32, 8
        cache = jnp.zeros((b, hkv, s, hd))
        newk = jnp.ones((b, hkv, 1, hd))
        dist = D.Distribution(mesh=mesh, batch_axes=("data",), seq_axes=("model",))
        for pos in (0, 7, 8, 31):
            with mesh:
                out = jax.jit(lambda c, n: D.sp_cache_update(dist, c, n, jnp.asarray(pos)))(cache, newk)
            out = np.asarray(out)
            assert np.all(out[:, :, pos] == 1.0), pos
            mask = np.ones(s, bool); mask[pos] = False
            assert np.all(out[:, :, mask] == 0.0), pos
        print("CACHE-OK")
    """)


def test_full_decode_step_with_sp_matches_single_device():
    run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro import configs
        from repro.launch import mesh as MM
        from repro.models import dist as D, model as M

        cfg = configs.get_smoke("qwen3-8b")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        b, s, maxlen = 2, 16, 32
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
                 "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
        cache = M.init_cache(cfg, b, maxlen)
        logits_p, cache = jax.jit(lambda p, bt, c: M.forward_prefill(p, cfg, bt, c))(params, batch, cache)
        tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 1)), jnp.int32)
        # Plain decode (no dist ctx).
        logits_plain, _ = jax.jit(lambda p, t, c: M.forward_decode(p, cfg, t, c))(params, tok, cache)
        # SP decode on a 2x4 mesh.
        mesh = MM.make_test_mesh(data=2, model=4)
        dist = D.Distribution(mesh=mesh, batch_axes=("data",), seq_axes=("model",))
        with mesh, D.use_distribution(dist):
            logits_sp, _ = jax.jit(lambda p, t, c: M.forward_decode(p, cfg, t, c))(params, tok, cache)
        np.testing.assert_allclose(np.asarray(logits_sp), np.asarray(logits_plain), rtol=5e-4, atol=5e-4)
        print("SP-FULL-OK")
    """)


def test_compressed_allreduce_dp_grads():
    run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.launch import mesh as MM
        from repro.train import compression as C

        mesh = MM.make_test_mesh(data=8, model=1)
        params = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((16, 4)), jnp.float32)}
        batch = {"x": jnp.asarray(np.random.default_rng(1).standard_normal((32, 16)), jnp.float32),
                 "y": jnp.asarray(np.random.default_rng(2).standard_normal((32, 4)), jnp.float32)}

        def loss_fn(p, b):
            pred = b["x"] @ p["w"]
            return jnp.mean((pred - b["y"]) ** 2)

        err = jax.tree.map(lambda p: jnp.zeros_like(p), params)
        fn = C.make_compressed_dp_grad_fn(lambda p, b: loss_fn(p, b), mesh, axis="data")
        with mesh:
            loss, grads, err2 = jax.jit(fn)(params, batch, err)
        g_true = jax.grad(lambda p: loss_fn(p, batch))(params)
        rel = np.abs(np.asarray(grads["w"]) - np.asarray(g_true["w"])).max() / (np.abs(np.asarray(g_true["w"])).max() + 1e-9)
        assert rel < 0.05, rel  # int8 quantization error bound (shared pmax scale)
        assert float(loss) > 0
        print("COMPRESS-OK", rel)
    """)


def test_sharded_rescale_acceptance_8dev():
    """Tentpole acceptance inside tier-1: on 8 forced host devices, executing
    a ScalePlan on the graph-mesh-sharded buffers is bit-identical to the
    single-device pack_ordered oracle, and the reported cross-device migrated
    bytes equal ScalePlan.migrated_bytes (Thm. 2). Full coverage lives in
    tests/test_rescale_sharded.py (CI multidevice job)."""
    run_with_devices("""
        import numpy as np
        from repro.core import cep, ordering
        from repro.core.graph import rmat_graph
        from repro.elastic.rescale_exec import EDGE_BYTES, ElasticRescaler
        from repro.graphs import engine as E
        from repro.launch import mesh as MM

        g = rmat_graph(8, 6, seed=0)
        order = ordering.geo_order(g, seed=0)
        src, dst = g.src[order], g.dst[order]
        mesh = MM.make_graph_mesh(8)
        r = ElasticRescaler()

        d8 = E.pack_ordered_sharded(src, dst, g.num_vertices, 8, mesh)
        plan_out = cep.scale_plan(g.num_edges, 8, 12)
        d12, s_out = r.execute(d8, plan_out, verify=True)
        assert s_out.devices == 8
        assert s_out.cross_device_bytes == plan_out.migrated_bytes(EDGE_BYTES)
        # GAS runs directly over the sharded rows (k=12 ∤ 8 devices is fine);
        # must happen before the scale-in donates d12's buffers.
        pr = np.asarray(E.pagerank(d12, iterations=10))
        ref = E.pack_ordered(src, dst, g.num_vertices, 12)
        pr_ref = np.asarray(E.pagerank(ref, MM.make_test_mesh(1, 1), iterations=10))
        np.testing.assert_allclose(pr, pr_ref, rtol=1e-6, atol=1e-9)
        plan_in = cep.scale_plan(g.num_edges, 12, 8)
        back, s_in = r.execute(d12, plan_in, verify=True)
        assert s_in.cross_device_bytes == plan_in.migrated_bytes(EDGE_BYTES)
        orig = E.pack_ordered(src, dst, g.num_vertices, 8)
        got = E.unshard_engine_data(back)
        np.testing.assert_array_equal(np.asarray(got.edges), np.asarray(orig.edges))
        np.testing.assert_array_equal(np.asarray(got.mask), np.asarray(orig.mask))
        print("SHARDED-RESCALE-OK")
    """)


def test_sharded_stream_ingest_acceptance_8dev():
    """Streaming acceptance inside tier-1: on 8 forced host devices, on-device
    ingest + two rescales-under-ingest stay bit-identical to the host slot
    oracle, and ingest+scale events share one monotonic seq. Full coverage
    lives in tests/test_stream_sharded.py (CI multidevice job)."""
    run_with_devices("""
        import numpy as np
        from repro.core import ordering
        from repro.core.graph import rmat_graph
        from repro.elastic import controller as ec
        from repro.launch import mesh as MM
        from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream

        g = rmat_graph(8, 6, seed=0)
        order = ordering.geo_order(g, seed=0)
        src, dst = g.src[order].astype(np.int64), g.dst[order].astype(np.int64)
        o = IncrementalOrderer(src, dst, g.num_vertices, regions=8)
        eng = StreamingEngine(o, MM.make_graph_mesh(8))
        t = [0.0]
        ctl = ec.ElasticController(8, dead_after_s=5.0, clock=lambda: t[0])
        ctl.attach_stream(eng)
        stream = SyntheticStream(g, batch_size=64, seed=1)

        ctl.ingest(stream.batch())
        ev_up = ctl.add_hosts(4)          # k → k+x under ingest
        assert ev_up.executed and eng.k == 12
        eng.verify_bit_identity()
        ctl.ingest(stream.batch())
        t[0] = 1.0
        for h in range(7):
            ctl.heartbeat(h, 1)
        t[0] = 6.0
        ev_down = ctl.poll()              # k → k−y (5 silent hosts preempted)
        assert ev_down is not None and ev_down.executed and eng.k == 7
        ctl.ingest(stream.batch())
        eng.verify_bit_identity()
        inc, oracle = eng.rf_vs_oracle()
        assert inc <= oracle * o.config.rf_margin + 1e-9, (inc, oracle)
        seqs = [e.seq for e in ctl.events]
        assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
        print("SHARDED-STREAM-OK")
    """)


def test_production_mesh_shapes():
    """512 forced devices come from run_with_devices(n=512) building the flag
    explicitly. The child re-asserts the count instead of patching XLA_FLAGS
    with str.replace (which corrupted the flag whenever the digits of the old
    count appeared in the new one), and any env mutation it does make is
    restored in a finally."""
    run_with_devices("""
        import os
        from repro.launch.multihost import force_host_device_flags
        saved = os.environ.get("XLA_FLAGS")
        os.environ["XLA_FLAGS"] = force_host_device_flags(512, saved or "")
        try:
            import jax
            from repro.launch import mesh as MM
            m1 = MM.make_production_mesh()
            assert m1.devices.shape == (16, 16) and m1.axis_names == ("data", "model")
            m2 = MM.make_production_mesh(multi_pod=True)
            assert m2.devices.shape == (2, 16, 16) and m2.axis_names == ("pod", "data", "model")
            assert MM.num_chips(m2) == 512
        finally:
            if saved is None:
                os.environ.pop("XLA_FLAGS", None)
            else:
                os.environ["XLA_FLAGS"] = saved
        print("MESH-OK")
    """, n=512)
