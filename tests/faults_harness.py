"""Per-process worker for the failure drill (tests/test_faults.py).

Two modes, both launched by ``launch.multihost.launch_local_cluster``:

* ``--mode live`` — a 2-process × 4-device cluster streams update batches
  through the elastic controller. Every process stamps a ``LeaseBoard``
  lease after each batch (the liveness heartbeat of DESIGN.md §15);
  process 0 additionally runs a ``SlotCheckpoint`` so every batch is
  durable (WAL record or interval snapshot). The PARENT test SIGKILLs
  process 1 mid-stream — a preemption with no goodbye — which strands
  process 0 in its next collective; the parent then abandons the whole
  group (kill + reap) exactly like a real control plane would. The
  checkpoint directory and the frozen lease stamps are all that survive,
  and that is the point of the drill.

* ``--mode recover`` — a FRESH 1-process × 4-device cluster (half the dead
  one) cold-restores the orderer from the checkpoint (snapshot chunks +
  replayed WAL tail), re-homes the pack onto the surviving mesh via
  ``StreamingEngine.from_restored`` (shard-streamed commit), reports the
  failure through ``ElasticController.report_failure`` — FailureEvent +
  re-plan k 8 → 4 over the survivors — and then CONTINUES the remaining
  batches by index (``SyntheticStream`` is a pure function of (seed, b)).
  It writes the restore-point and final slot arrays plus the final device
  pack to ``--out``; the parent proves both bit-identical to a host oracle
  that replayed the same stream (and the same re-plan) without ever
  failing — exactly-once recovery, not approximately-once.

Escalation thresholds are parked high (``drill_config``) so the slot state
is a pure function of (applied batches, rescales): the in-process property
and boundary tests (test_faults.py) cover kill × ladder interleavings; the
subprocess drill is about real SIGKILL, real lease expiry, real disk.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"))

from repro.launch import multihost as MH  # noqa: E402  (before jax device init)

SPEC = MH.initialize_from_env()  # must run before the first jax computation

import jax  # noqa: E402

from repro.checkpoint import SlotCheckpoint  # noqa: E402
from repro.core import ordering  # noqa: E402
from repro.core.graph import rmat_graph  # noqa: E402
from repro.elastic import controller as ec  # noqa: E402
from repro.launch import mesh as MM  # noqa: E402
from repro.obs import metrics as OM  # noqa: E402
from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream  # noqa: E402
from repro.stream.incremental import StreamConfig  # noqa: E402

GRAPH_SCALE = 7
GRAPH_EDGE_FACTOR = 6
GRAPH_SEED = 0
STREAM_SEED = 3
STREAM_BATCH = 64
REGIONS = 8
CKPT_INTERVAL = 3
LEASE_S = 2.0
# Per-batch throttle in live mode: the parent must win the race between
# "victim reaches the kill step" and "stream runs out of batches".
THROTTLE_S = 0.25


def drill_config() -> StreamConfig:
    """Escalation parked out of the way: the drill's slot state must be a
    pure function of the applied batches + rescales so the parent's host
    oracle replay is a plain ``apply`` loop."""
    return StreamConfig(partial_drift=99.0, full_drift=999.0)


def log(pid: int, msg: str) -> None:
    print(f"[proc {pid}] {msg}", flush=True)


def build_ordered():
    g = rmat_graph(GRAPH_SCALE, GRAPH_EDGE_FACTOR, seed=GRAPH_SEED)
    order = ordering.geo_order(g, seed=0)
    return g, g.src[order].astype(np.int64), g.dst[order].astype(np.int64)


def save_blocks(store: dict, name: str, arr) -> None:
    for lo, hi, data in MH.local_shard_rows(arr):
        store[f"{name}__{lo}__{hi}"] = data


def run_live(args) -> None:
    pid = jax.process_index()
    g, src, dst = build_ordered()
    mesh = MM.make_graph_mesh()
    board = MH.LeaseBoard(os.path.join(args.dir, "leases"), lease_s=LEASE_S)
    registry = OM.MetricsRegistry()
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=REGIONS, config=drill_config())
    eng = StreamingEngine(o, mesh, metrics_registry=registry)
    ctl = ec.ElasticController(REGIONS, metrics_registry=registry)
    ctl.attach_stream(eng)
    if pid == 0:
        # One durability writer: process 0's orderer is a full deterministic
        # replica, so its checkpoint covers the whole slot array. Process 1
        # (the drill's victim) only stamps leases.
        ctl.attach_checkpoint(
            SlotCheckpoint(
                os.path.join(args.dir, "ckpt"),
                interval=CKPT_INTERVAL,
                metrics_registry=registry,
            )
        )
    stream = SyntheticStream(g, batch_size=STREAM_BATCH, seed=STREAM_SEED)
    log(pid, f"live: {jax.process_count()} processes, {len(jax.devices())} global devices")
    for step in range(args.batches):
        ctl.ingest(stream.batch())
        board.stamp(pid, step)
        log(pid, f"live: batch {step} done, |E|={o.num_edges}")
        time.sleep(THROTTLE_S)
    # Reaching here means the parent never killed anyone — the drill failed
    # upstream; record enough to make that diagnosable.
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, f"live_proc{pid}.json"), "w") as fh:
        json.dump({"process_id": pid, "completed_all": True, "batches": args.batches}, fh)
    log(pid, "live: DONE (never killed)")


def run_recover(args) -> None:
    pid = jax.process_index()
    g, _, _ = build_ordered()
    mesh = MM.make_graph_mesh()
    registry = OM.MetricsRegistry()
    lost = [int(h) for h in args.lost_hosts.split(",") if h != ""]
    ck = SlotCheckpoint(
        os.path.join(args.dir, "ckpt"), interval=CKPT_INTERVAL, metrics_registry=registry
    )
    t0 = time.perf_counter()
    o, info = ck.restore(config=drill_config())
    restore_s = time.perf_counter() - t0
    last_durable = info["step"]
    log(
        pid,
        f"recover: restored to batch {last_durable} "
        f"(manifest {info['manifest_step']}, replayed {info['replayed']} WAL records, "
        f"{info['bytes_read']} bytes)",
    )
    store: dict = {
        "restore_src": o.slot_src.copy(),
        "restore_dst": o.slot_dst.copy(),
        "restore_valid": o.slot_valid.copy(),
    }

    t1 = time.perf_counter()
    eng = StreamingEngine.from_restored(o, mesh, metrics_registry=registry)
    commit_s = time.perf_counter() - t1
    ctl = ec.ElasticController(REGIONS, metrics_registry=registry)
    ctl.attach_stream(eng)
    ctl.attach_checkpoint(ck)
    ctl._batch_step = last_durable  # continue the durable step numbering
    fev, sev = ctl.report_failure(
        lost,
        detect_s=args.detect_s,
        reason="process lease expired (drill)",
        restored_bytes=info["bytes_read"],
        restore_s=restore_s,
        replayed_records=info["replayed"],
    )
    log(pid, f"recover: failure shrink k {fev.k_old} -> {fev.k_new} executed={sev.executed}")

    stream = SyntheticStream(g, batch_size=STREAM_BATCH, seed=STREAM_SEED)
    for b in range(last_durable + 1):
        stream.batch()  # regenerate (and discard) the already-durable prefix
    for b in range(last_durable + 1, args.batches):
        ctl.ingest(stream.batch(b))
    eng.verify_bit_identity()
    log(pid, f"recover: continued through batch {args.batches - 1}, k={eng.k}")

    store["final_src"] = o.slot_src.copy()
    store["final_dst"] = o.slot_dst.copy()
    store["final_valid"] = o.slot_valid.copy()
    save_blocks(store, "final_edges", eng.data.edges)
    save_blocks(store, "final_mask", eng.data.mask)
    peak_mb = OM.record_peak_rss(registry)
    record = {
        "process_id": pid,
        "devices": len(jax.devices()),
        "restore": dict(info),
        "restore_s": restore_s,
        "commit_s": commit_s,
        "k_final": eng.k,
        "num_edges": o.num_edges,
        "failure_event": {
            "lost_hosts": list(fev.lost_hosts),
            "k_old": fev.k_old,
            "k_new": fev.k_new,
            "detect_s": fev.detect_s,
            "restored_bytes": fev.restored_bytes,
            "replayed_records": fev.replayed_records,
            "seq": fev.seq,
        },
        "event_seqs": [ev.seq for ev in ctl.events],
        "event_kinds": [ev.kind for ev in ctl.events],
        "events_jsonl": ctl.events_jsonl(drop_timings=True),
        "peak_rss_mb": peak_mb,
    }
    os.makedirs(args.out, exist_ok=True)
    np.savez(os.path.join(args.out, "recover.npz"), **store)
    with open(os.path.join(args.out, "recover.json"), "w") as fh:
        json.dump(record, fh, indent=2)
    log(pid, "recover: DONE")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", required=True, choices=["live", "recover"])
    ap.add_argument("--dir", required=True, help="shared checkpoint + lease directory")
    ap.add_argument("--out", required=True, help="directory for result artifacts")
    ap.add_argument("--batches", type=int, default=12)
    ap.add_argument("--detect-s", type=float, default=0.0)
    ap.add_argument("--lost-hosts", default="")
    args = ap.parse_args()
    if args.mode == "live":
        run_live(args)
    else:
        run_recover(args)


if __name__ == "__main__":
    main()
