"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see common.emit). Individual benches:
``python -m benchmarks.bench_quality`` etc. Select subsets with
``python -m benchmarks.run fig9 table2``.
"""
from __future__ import annotations

import sys
import time

MODULES = [
    ("fig9_partition_time", "benchmarks.bench_partition_time"),
    ("fig10_11_quality", "benchmarks.bench_quality"),
    ("fig5_delta", "benchmarks.bench_delta"),
    ("fig13_migration", "benchmarks.bench_migration"),
    ("rescale_exec", "benchmarks.bench_rescale_exec"),
    ("stream_ingest", "benchmarks.bench_stream"),
    ("serve_autoscale", "benchmarks.bench_serve"),
    ("multihost", "benchmarks.bench_multihost"),
    ("fig15_scalability", "benchmarks.bench_scalability"),
    ("table2_theory", "benchmarks.bench_theory"),
    ("table6_apps", "benchmarks.bench_apps"),
    ("elastic_lm", "benchmarks.bench_elastic_lm"),
    ("roofline", "benchmarks.bench_roofline"),
]


def main() -> None:
    import importlib

    wanted = [a.lower() for a in sys.argv[1:]]
    print("name,us_per_call,derived")
    for tag, modname in MODULES:
        if wanted and not any(w in tag for w in wanted):
            continue
        t0 = time.time()
        mod = importlib.import_module(modname)
        try:
            mod.run()
            print(f"# {tag}: done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # keep the suite going; a failed bench is a bug
            print(f"# {tag}: FAILED {type(e).__name__}: {e}", flush=True)


if __name__ == "__main__":
    main()
