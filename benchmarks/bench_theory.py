"""Table 2 — theoretical upper bounds on RF for power-law graphs, plus an
empirical check that GEO+CEP respects the Thm.-6 bound."""
from __future__ import annotations

import numpy as np

from repro.core import metrics, ordering, theory
from repro.core.graph import powerlaw_graph

from .common import emit


def run() -> None:
    rows = theory.table2()
    for a, row in rows.items():
        derived = ";".join(f"{m}={v:.2f}" for m, v in row.items())
        emit(f"table2/alpha{a}", 0.0, derived)
    for a, row in theory.PAPER_TABLE2.items():
        derived = ";".join(f"{m}={v:.2f}" for m, v in row.items())
        emit(f"table2_paper/alpha{a}", 0.0, derived)
    # Empirical Thm. 6 check on a generated power-law graph.
    for a in (2.2, 2.6):
        g = powerlaw_graph(20000, alpha=a, seed=0)
        order = ordering.geo_order(g, seed=0)
        for k in (16, 128):
            rf = metrics.replication_factor_ordered(g.src[order], g.dst[order], k, g.num_vertices)
            bound = theory.bound_general(g.num_vertices, g.num_edges, k)
            emit(f"table2_empirical/alpha{a}/k{k}", 0.0, f"rf={rf:.3f};thm6_bound={bound:.3f};ok={rf<=bound}")


if __name__ == "__main__":
    run()
