"""Failure-recovery benchmark — the ISSUE-10 acceptance record.

Streams a synthetic update stream over a GEO-ordered RMAT base graph with a
``SlotCheckpoint`` riding every batch (WAL record or interval snapshot), then
measures the full preemption-recovery path of DESIGN.md §15 and records it
in ``BENCH_recovery.json``:

* ``detect``   — the failure detector's cost split into its two parts: the
                 lease window itself (the policy floor nothing can beat) and
                 the measured wall cost of one ``LeaseBoard.dead()``
                 classification walk (the per-poll price, microseconds);
* ``recovery`` — the detect → re-plan → restore → re-commit latency
                 breakdown: cold restore (snapshot chunks + WAL tail
                 replay), ``report_failure`` (FailureEvent + shrink over the
                 survivors), and the shard-streamed re-commit of the
                 restored order onto the surviving mesh
                 (``StreamingEngine.from_restored``);
* ``restored_bytes`` — the partition-scoped restore bill for losing 1, 2,
                 and 4 of k=8 partitions (``restore_partitions``): bytes
                 read vs the lost partitions' in-memory footprint and vs a
                 full cold restore. The acceptance: the bill scales with
                 LOST partitions, not |E| — each point stays within an npz
                 container-overhead slack of its lost-partition footprint;
* ``bit_identity`` — the cold-restored slot state equals the live orderer's
                 state at the durable step, byte-for-byte;
* ``continuation`` — per-batch ingest cost after recovery vs before the
                 crash (the recovered runtime is not degraded);
* peak RSS (the whole point of chunked checkpoints is bounded memory).

``--smoke`` runs a scaled-down graph and prints the table without writing
the artifact — surfaced in the CI multihost job log. The committed
BENCH_recovery.json is the baseline of record, gated by
``benchmarks.check_regression``.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.checkpoint import SlotCheckpoint
from repro.core import ordering
from repro.core.graph import rmat_graph
from repro.elastic import controller as ec
from repro.launch import multihost as MH
from repro.obs import metrics as OM
from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream

from .common import emit, peak_rss_mb

K0 = 8
LEASE_S = 2.0


def run(
    *,
    scale: int = 12,
    edge_factor: int = 8,
    batches: int = 48,
    batch_size: int = 256,
    interval: int = 6,
    ckpt_dir: str,
    out_json: str | None = "BENCH_recovery.json",
) -> dict:
    g = rmat_graph(scale, edge_factor, seed=0)
    order = ordering.geo_order(g, seed=0)
    src = g.src[order].astype(np.int64)
    dst = g.dst[order].astype(np.int64)

    registry = OM.MetricsRegistry()
    o = IncrementalOrderer(src, dst, g.num_vertices, regions=K0)
    eng = StreamingEngine(o, metrics_registry=registry)
    ctl = ec.ElasticController(K0, metrics_registry=registry)
    ctl.attach_stream(eng)
    ck = SlotCheckpoint(ckpt_dir, interval=interval, metrics_registry=registry)
    ctl.attach_checkpoint(ck)

    stream = SyntheticStream(g, batch_size=batch_size, delete_frac=0.3, seed=3)
    pre_walls = []
    for _ in range(batches):
        t0 = time.perf_counter()
        ctl.ingest(stream.batch())
        pre_walls.append(time.perf_counter() - t0)
    live_slots = (o.slot_src.copy(), o.slot_dst.copy(), o.slot_valid.copy())
    slot_bytes_total = sum(a.nbytes for a in live_slots)
    spr = o.slots_per_region

    # ---------------------------------------------------------------- detect
    # The detector is a file walk, no collectives: its recurring cost is one
    # dead() classification per poll; its latency floor is the lease window.
    clk = [100.0]
    board = MH.LeaseBoard(f"{ckpt_dir}/leases", lease_s=LEASE_S, clock=lambda: clk[0])
    for pid in range(2):
        board.stamp(pid, batches - 1)
    clk[0] = 100.0 + LEASE_S + 0.5  # the victim's lease froze; it just expired
    board.stamp(0, batches)  # the survivor kept renewing
    t0 = time.perf_counter()
    dead = board.dead(2)
    classify_s = time.perf_counter() - t0
    assert dead == [1], dead
    detect_s = LEASE_S + classify_s  # policy floor + one classification walk

    # --------------------------------------------- restore → re-plan → commit
    # "The process died": the live objects above are gone; everything from
    # here runs off the checkpoint directory, exactly like the drill harness.
    t0 = time.perf_counter()
    o2, info = SlotCheckpoint(ckpt_dir, interval=interval).restore()
    restore_s = time.perf_counter() - t0
    bit_identity = (
        np.array_equal(o2.slot_src, live_slots[0])
        and np.array_equal(o2.slot_dst, live_slots[1])
        and np.array_equal(o2.slot_valid, live_slots[2])
    )

    t0 = time.perf_counter()
    eng2 = StreamingEngine.from_restored(o2, metrics_registry=registry)
    commit_s = time.perf_counter() - t0
    eng2.verify_bit_identity()

    ctl2 = ec.ElasticController(K0, metrics_registry=registry)
    ctl2.attach_stream(eng2)
    ctl2._batch_step = info["step"]
    t0 = time.perf_counter()
    fev, sev = ctl2.report_failure(
        [K0 // 2 + i for i in range(K0 // 2)],
        detect_s=detect_s,
        reason="process lease expired (bench)",
        restored_bytes=info["bytes_read"],
        restore_s=restore_s,
        replayed_records=info["replayed"],
    )
    replan_s = time.perf_counter() - t0
    total_s = detect_s + restore_s + replan_s + commit_s

    post_walls = []
    for _ in range(8):  # the recovered runtime keeps streaming (now at k/2)
        t0 = time.perf_counter()
        ctl2.ingest(stream.batch())
        post_walls.append(time.perf_counter() - t0)
    eng2.verify_bit_identity()

    # ------------------------------------------------- restored-bytes scaling
    # Thm.-2-style accounting: a replacement host pulls only the chunks of
    # the partitions it inherits (+ their WAL tail ops), so the bill must
    # track lost-partition count, not |E|. npz containers carry a per-file
    # header/compression envelope — the 1.5x slack gated downstream.
    series = []
    for lost_n in (1, 2, 4):
        lost = list(range(lost_n))
        ckp = SlotCheckpoint(ckpt_dir, interval=interval)
        t0 = time.perf_counter()
        chunks, pinfo = ckp.restore_partitions(lost)
        part_s = time.perf_counter() - t0
        ok = all(
            np.array_equal(chunks[r][0], live_slots[0][r * spr : (r + 1) * spr])
            and np.array_equal(chunks[r][1], live_slots[1][r * spr : (r + 1) * spr])
            and np.array_equal(chunks[r][2], live_slots[2][r * spr : (r + 1) * spr])
            for r in lost
        )
        series.append(
            {
                "lost_partitions": lost_n,
                "bytes_read": int(pinfo["bytes_read"]),
                "lost_bytes": int(pinfo["lost_bytes"]),
                "bytes_per_lost_bytes": pinfo["bytes_read"] / pinfo["lost_bytes"],
                "frac_of_full_restore": pinfo["bytes_read"] / info["bytes_read"],
                "replayed_ops": int(pinfo["replayed_ops"]),
                "restore_ms": part_s * 1e3,
                "bit_identity": bool(ok),
            }
        )
        emit(
            f"restore_partitions[{lost_n}]",
            part_s * 1e6,
            f"bytes={pinfo['bytes_read']}/{pinfo['lost_bytes']}",
        )

    snap = registry.snapshot()
    result = {
        "config": {
            "scale": scale,
            "edge_factor": edge_factor,
            "num_edges": int(g.num_edges),
            "batches": batches,
            "batch_size": batch_size,
            "interval": interval,
            "k0": K0,
            "lease_s": LEASE_S,
        },
        "detect": {
            "lease_s": LEASE_S,
            "classify_us": classify_s * 1e6,
            "detect_s": detect_s,
        },
        "recovery": {
            "detect_s": detect_s,
            "restore_s": restore_s,
            "replan_s": replan_s,
            "commit_s": commit_s,
            "total_s": total_s,
            "restored_bytes": int(info["bytes_read"]),
            "slot_bytes_total": int(slot_bytes_total),
            "replayed_wal_records": int(info["replayed"]),
            "manifest_step": int(info["manifest_step"]),
            "durable_step": int(info["step"]),
            "k_after": int(fev.k_new),
            "failure_event_seq": int(fev.seq),
            "scale_event_seq": int(sev.seq) if sev is not None else None,
        },
        "restored_bytes": series,
        "bit_identity": bool(bit_identity),
        "continuation": {
            "pre_crash_batch_ms": float(np.median(pre_walls) * 1e3),
            "post_recovery_batch_ms": float(np.median(post_walls) * 1e3),
        },
        "checkpoint_counters": {
            k: snap[k]
            for k in (
                "checkpoint.snapshots",
                "checkpoint.snapshot_bytes",
                "checkpoint.wal_records",
                "checkpoint.wal_bytes",
                "checkpoint.restore_bytes",
            )
            if k in snap
        },
        "peak_rss_mb": peak_rss_mb(),
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1)
    return result


def print_table(r: dict) -> None:
    rec = r["recovery"]
    print(
        f"recovery: detect {rec['detect_s']:.3f}s (lease {r['detect']['lease_s']}s "
        f"+ classify {r['detect']['classify_us']:.0f}us) | "
        f"restore {rec['restore_s'] * 1e3:.1f}ms "
        f"({rec['restored_bytes']} B, {rec['replayed_wal_records']} WAL records) | "
        f"replan {rec['replan_s'] * 1e3:.2f}ms | commit {rec['commit_s'] * 1e3:.1f}ms | "
        f"total {rec['total_s']:.3f}s"
    )
    print(f"bit_identity: {r['bit_identity']} | k {r['config']['k0']} -> {rec['k_after']}")
    for p in r["restored_bytes"]:
        print(
            f"  lost {p['lost_partitions']}/{r['config']['k0']}: "
            f"{p['bytes_read']} B read vs {p['lost_bytes']} B lost "
            f"(x{p['bytes_per_lost_bytes']:.2f}, {p['frac_of_full_restore']:.2f} of full, "
            f"{p['replayed_ops']} ops replayed, bit_identity={p['bit_identity']})"
        )
    print(
        f"continuation: {r['continuation']['pre_crash_batch_ms']:.2f}ms/batch before, "
        f"{r['continuation']['post_recovery_batch_ms']:.2f}ms/batch after | "
        f"peak RSS {r['peak_rss_mb']:.1f} MB"
    )


def main() -> None:
    import tempfile

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down graph; print the table, no JSON artifact")
    ap.add_argument("--out", default="BENCH_recovery.json")
    args = ap.parse_args()
    with tempfile.TemporaryDirectory() as d:
        if args.smoke:
            result = run(scale=9, edge_factor=6, batches=16, batch_size=64,
                         interval=4, ckpt_dir=d, out_json=None)
        else:
            result = run(ckpt_dir=d, out_json=args.out)
    print_table(result)
    # Asserted in EVERY run (--smoke included): recovery must be exact, and
    # the partition bill must actually scale with what was lost.
    assert result["bit_identity"], "cold restore diverged from the live state"
    bys = [p["bytes_read"] for p in result["restored_bytes"]]
    assert bys == sorted(bys) and bys[0] < bys[-1], f"no scaling: {bys}"


if __name__ == "__main__":
    main()
