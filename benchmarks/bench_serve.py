"""Serving + autoscaling benchmark — the ISSUE-9 acceptance.

Runs an open-loop query workload (bursty arrivals riding a diurnal ramp,
stream/workload.py — the replayable stand-in for millions of users) against
a live StreamingEngine for two virtual "days", with update batches ingesting
every tick and the traffic-driven autoscaler (elastic/autoscale.py) free to
move k in both directions. Records in ``BENCH_serve.json``:

* ``latency``    — modeled p50/p99 query latency on the virtual timeline
                   (wait + service in the deterministic G/G/k queue — the
                   machine-independent numbers the SLO gates), SLO-violation
                   count/fraction, served/shed counts;
* ``probes``     — REAL measured on-device query latency (single
                   perf_counter pair around dispatch + block_until_ready)
                   sampled throughout the run, including queries landing
                   right after rescales and async rebuild commits;
* ``autoscaler`` — every decision with its signal-carrying reason, the k
                   path, per-direction counts, and the hysteresis proof:
                   ≥ 2 scale-outs AND ≥ 2 scale-ins with ZERO flap pairs
                   (opposite-direction decisions closer than the flap
                   window) — asserted in-run, --smoke included;
* ``migration``  — migrated bytes per scale decision (straight from
                   ``ScaleEvent.cross_device_bytes``; honestly 0 on a
                   one-device mesh) plus the layout-level moved-edges view;
* ``bit_identity`` — the sharded pack byte-matched the host slot oracle
                   after EVERY event (ingest and policy-driven rescale both;
                   ``verify_bit_identity`` raises on first divergence).

The whole system — controller, autoscaler, workload, serve loop — runs on
ONE virtual clock the loop advances, so the entire trajectory (every
decision, every latency) is a pure function of (seed, config) and replays
identically on any machine. Only the probe timings are machine-speed
dependent, and nothing gates on them.

``--smoke`` runs a scaled-down two-day scenario (same structural asserts,
no JSON) — surfaced in the CI multidevice job log.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import ordering
from repro.core.graph import rmat_graph
from repro.elastic import autoscale as EA
from repro.elastic import controller as ec
from repro.launch import mesh as MM
from repro.launch import serve as LS
from repro.obs import metrics as OM
from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream
from repro.stream.workload import OpenLoopWorkload

from .common import emit, peak_rss_mb

K0 = 4
SLO_FRAC_MAX = 0.35  # committed-artifact gate: ≤ 35% of queries may miss SLO
P99_SLO_FACTOR = 3.0  # committed-artifact gate: modeled p99 ≤ 3× the SLO
FLAP_GAP_TICKS = 6  # opposite-direction decisions closer than this = a flap


def _flap_pairs(policy, tick_s: float) -> int:
    """Opposite-direction decision pairs closer than the flap window, from
    the policy's own signal log (each decide() call records its clock)."""
    decisions = [s for s in policy.log if s.decision]
    flaps = 0
    for a, b in zip(decisions, decisions[1:]):
        if a.decision != b.decision and (b.now - a.now) < FLAP_GAP_TICKS * tick_s:
            flaps += 1
    return flaps


def run(
    scale: int = 9,
    edge_factor: int = 8,
    day_ticks: int = 96,
    days: int = 2,
    ingest_batch: int = 32,
    out_json: str | None = "BENCH_serve.json",
    mesh_size: int | None = 1,
    seed: int = 0,
) -> dict:
    strict = out_json is not None  # smoke skips the workload-tuned SLO gates
    ticks = day_ticks * days

    g = rmat_graph(scale, edge_factor, seed=seed)
    order = ordering.geo_order(g, seed=0)
    src, dst = g.src[order].astype(np.int64), g.dst[order].astype(np.int64)
    orderer = IncrementalOrderer(src, dst, g.num_vertices, regions=K0)
    registry = OM.MetricsRegistry()
    engine = StreamingEngine(
        orderer, MM.make_graph_mesh(mesh_size),
        warm_scatter_caps=(ingest_batch, 2 * ingest_batch),
        metrics_registry=registry,
    )

    # The serve loop owns the virtual clock; the controller reads it through
    # this indirection (the loop is constructed after the controller).
    loop_ref: list = []
    ctl = ec.ElasticController(
        K0, clock=lambda: loop_ref[0].now if loop_ref else 0.0,
        metrics_registry=registry,
    )
    ctl.attach_stream(engine)
    policy = EA.AutoscalePolicy(
        EA.AutoscaleConfig(
            k_min=2, k_max=16, step_out=2, step_in=2,
            queue_high_per_host=3.0, queue_low=0.5, ema=0.6,
            out_cooldown_s=8.0, in_cooldown_s=16.0,
        )
    )
    ctl.attach_autoscaler(policy)
    workload = OpenLoopWorkload(
        num_vertices=g.num_vertices, base_rate=K0 * 2.0, day_ticks=day_ticks,
        diurnal_amp=0.8, burst_every=day_ticks // 4, burst_factor=3.0, seed=seed,
    )
    updates = SyntheticStream(g, batch_size=ingest_batch, seed=seed)
    cfg = LS.ServeConfig()
    loop = LS.ServeLoop(ctl, workload, updates=updates, config=cfg, registry=registry)
    loop_ref.append(loop)
    loop.queries.warm()  # pre-pay the query compiles before any probe is timed

    t0 = time.perf_counter()
    loop.run(ticks)
    loop.drain()
    wall_s = time.perf_counter() - t0
    s = loop.summary()

    decisions = [
        {
            "seq": ev.seq, "kind": ev.kind, "k_old": ev.k_old, "k_new": ev.k_new,
            "reason": ev.reason, "executed": ev.executed,
            "cross_device_bytes": int(ev.cross_device_bytes),
            "moved_edges": s["moved_edges_per_decision"][i],
        }
        for i, ev in enumerate(loop.scale_events)
    ]
    flaps = _flap_pairs(policy, cfg.tick_s)
    held = {}
    for sig in policy.log:
        if sig.held_by:
            held[sig.held_by] = held.get(sig.held_by, 0) + 1
    seqs = [e.seq for e in ctl.events]
    probe_hist = registry.histogram("serve.query_measured_s")

    result = {
        "scenario": {
            "vertices": int(g.num_vertices), "base_edges": int(g.num_edges),
            "final_edges": orderer.num_edges,
            "ticks": ticks, "day_ticks": day_ticks, "tick_s": cfg.tick_s,
            "k0": K0, "ingest_batch": ingest_batch,
            "per_host_rate": cfg.per_host_rate, "slo_s": cfg.slo_s,
            "workload": {
                "base_rate": workload.base_rate, "diurnal_amp": workload.diurnal_amp,
                "burst_every": workload.burst_every, "burst_factor": workload.burst_factor,
            },
            "events_seq_monotonic": seqs == sorted(seqs) and len(set(seqs)) == len(seqs),
            "serve_wall_s": round(wall_s, 2),
        },
        "latency": {
            "p50_s": round(s["latency_p50_s"], 3),
            "p99_s": round(s["latency_p99_s"], 3),
            "served": s["served"], "shed": s["shed"],
            "slo_violations": s["slo_violations"],
            "slo_frac": round(s["slo_frac"], 4),
            "acceptance_slo_frac": bool(s["slo_frac"] <= SLO_FRAC_MAX),
            "acceptance_p99_within_3x_slo": bool(
                s["latency_p99_s"] <= P99_SLO_FACTOR * cfg.slo_s
            ),
        },
        "probes": {
            "count": int(probe_hist.total),
            "p50_ms": round(probe_hist.percentile(50) * 1e3, 2),
            "p99_ms": round(probe_hist.percentile(99) * 1e3, 2),
        },
        "autoscaler": {
            "decisions": decisions,
            "k_path": s["k_path"],
            "scale_outs": s["scale_outs"],
            "scale_ins": s["scale_ins"],
            "flap_pairs": flaps,
            "held": held,
            "evaluations": len(policy.log),
            "acceptance_two_each_direction": bool(
                s["scale_outs"] >= 2 and s["scale_ins"] >= 2
            ),
            "acceptance_no_flapping": flaps == 0,
        },
        "migration": {
            "bytes_per_decision": s["migrated_bytes_per_decision"],
            "moved_edges_per_decision": s["moved_edges_per_decision"],
            "total_cross_device_bytes": sum(s["migrated_bytes_per_decision"]),
        },
        # verify_bit_identity raised on any divergence (every ingest + every
        # policy-driven rescale was checked), so reaching here proves it.
        "bit_identity": {
            "checked_events": ticks + len(loop.scale_events),
            "all_identical": True,
        },
        "peak_rss_mb": round(peak_rss_mb(), 1),
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1)
    emit("serve/latency_p50", s["latency_p50_s"] * 1e6, f"p99_s={s['latency_p99_s']:.2f}")
    emit("serve/probe_query", probe_hist.percentile(50) * 1e6,
         f"p99_ms={result['probes']['p99_ms']}")
    emit("serve/slo", 0.0, f"violations={s['slo_violations']} frac={s['slo_frac']:.3f}")
    emit("serve/autoscale", 0.0,
         f"outs={s['scale_outs']} ins={s['scale_ins']} flaps={flaps} k_path={s['k_path']}")

    # Structural acceptances, asserted in EVERY run (--smoke included):
    # these are properties of the deterministic virtual-clock trajectory,
    # not machine-speed ratios.
    assert result["scenario"]["events_seq_monotonic"], "event seq log not monotonic"
    assert result["autoscaler"]["acceptance_two_each_direction"], (
        f"autoscaler moved k {s['scale_outs']} out / {s['scale_ins']} in — "
        f"need >= 2 each (k_path {s['k_path']})"
    )
    assert result["autoscaler"]["acceptance_no_flapping"], (
        f"{flaps} flap pairs (opposite decisions within {FLAP_GAP_TICKS} ticks)"
    )
    assert result["probes"]["count"] > 0, "no real query was ever probed"
    if strict:
        assert result["latency"]["acceptance_slo_frac"], (
            f"SLO violation fraction {s['slo_frac']:.3f} > {SLO_FRAC_MAX}"
        )
        assert result["latency"]["acceptance_p99_within_3x_slo"], (
            f"modeled p99 {s['latency_p99_s']:.2f}s > {P99_SLO_FACTOR}x SLO {cfg.slo_s}s"
        )
    return result


def print_summary(result: dict) -> None:
    """Compact table for the CI multidevice job log."""
    lat, a = result["latency"], result["autoscaler"]
    print(f"\nserve: {lat['served']} queries over {result['scenario']['ticks']} ticks "
          f"(wall {result['scenario']['serve_wall_s']}s)")
    print(f"  modeled p50 {lat['p50_s']}s p99 {lat['p99_s']}s | SLO misses "
          f"{lat['slo_violations']} ({100 * lat['slo_frac']:.1f}%) | shed {lat['shed']}")
    print(f"  probes: {result['probes']['count']} real queries, "
          f"p50 {result['probes']['p50_ms']}ms p99 {result['probes']['p99_ms']}ms")
    print(f"  autoscaler: {a['scale_outs']} out + {a['scale_ins']} in, "
          f"{a['flap_pairs']} flaps, k path {a['k_path']} (held: {a['held']})")
    for d in a["decisions"]:
        print(f"    seq {d['seq']}: {d['kind']} {d['k_old']}->{d['k_new']} "
              f"moved_edges={d['moved_edges']} bytes={d['cross_device_bytes']} — {d['reason']}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down two-day scenario; print the table, no JSON")
    args = ap.parse_args()
    if args.smoke:
        # Smoke spans every visible device (the CI multidevice job forces 8)
        # and keeps both days, so the ≥2-each-direction hysteresis assert
        # runs on the sharded path too.
        result = run(scale=8, day_ticks=48, ingest_batch=16,
                     out_json=None, mesh_size=None)
    else:
        result = run()
    print_summary(result)


if __name__ == "__main__":
    main()
