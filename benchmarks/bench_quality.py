"""Figs. 10/11 — replication factor of GEO+CEP vs partitioners & orderings."""
from __future__ import annotations

import time

import numpy as np

from repro.core import baselines, metrics, ordering

from .common import bench_graph, emit, timeit


def _rf_part(g, part, k):
    return metrics.replication_factor(g.src, g.dst, part, k, g.num_vertices)


def _rf_order(g, order, k):
    return metrics.replication_factor_ordered(g.src[order], g.dst[order], k, g.num_vertices)


def run(scale: int = 12, edge_factor: int = 12) -> None:
    g = bench_graph(scale, edge_factor)
    t0 = time.perf_counter()
    geo = ordering.geo_order(g, seed=0)
    t_geo = (time.perf_counter() - t0) * 1e6
    emit("fig11/geo_preprocess", t_geo, f"V={g.num_vertices};E={g.num_edges}")

    ks = (4, 16, 64, 128)
    # --- Fig 10: partitioners ---
    for k in ks:
        emit(f"fig10/geo+cep/k{k}", 0.0, f"rf={_rf_order(g, geo, k):.3f}")
    for name, fn in [
        ("1d", baselines.hash_1d),
        ("2d", baselines.hash_2d),
        ("dbh", baselines.dbh),
        ("bvc", baselines.bvc_partition),
    ]:
        for k in ks:
            emit(f"fig10/{name}/k{k}", 0.0, f"rf={_rf_part(g, fn(g, k), k):.3f}")
    for k in (4, 16):  # slow baselines at small k only
        emit(f"fig10/ne/k{k}", 0.0, f"rf={_rf_part(g, baselines.ne_partition(g, k), k):.3f}")
        emit(f"fig10/hdrf/k{k}", 0.0, f"rf={_rf_part(g, baselines.hdrf(g, k), k):.3f}")
        vp = baselines.spectral_vertex_partition(g, k)
        ep = baselines.vertex_to_edge_partition(g, vp, k)
        emit(f"fig10/mts/k{k}", 0.0, f"rf={_rf_part(g, ep, k):.3f}")

    # --- Fig 11: orderings (all consumed by CEP) ---
    orders = {
        "geo": geo,
        "rcm": baselines.rcm_edge_order(g),
        "bfs": ordering.bfs_edge_order(g, seed=0),
        "deg": ordering.degree_edge_order(g),
        "def": ordering.default_edge_order(g),
        "rand": ordering.random_edge_order(g, seed=0),
    }
    for name, o in orders.items():
        rfs = [_rf_order(g, o, k) for k in ks]
        emit(f"fig11/{name}", 0.0, "rf_k4..128=" + "/".join(f"{r:.3f}" for r in rfs))


if __name__ == "__main__":
    run()
