"""Fig. 9 — elapsed time to (re)compute a k-way partition.

CEP is O(1): computing *the partition function* (all chunk boundaries +
ID2P closure) never touches edges. Every other method is Ω(|E|).
"""
from __future__ import annotations

import numpy as np

from repro.core import baselines, cep, ordering

from .common import bench_graph, emit, timeit


def run(scale: int = 12, edge_factor: int = 12) -> None:
    g = bench_graph(scale, edge_factor)
    e = g.num_edges
    for k in (4, 16, 64, 128):
        t_cep = timeit(lambda: cep.chunk_bounds(e, k), repeats=5, number=100)
        emit(f"fig9/cep/k{k}", t_cep, f"E={e};O(1)")
        t_1d = timeit(lambda: baselines.hash_1d(g, k))
        emit(f"fig9/hash1d/k{k}", t_1d, f"speedup_cep={t_1d / max(t_cep, 1e-9):.0f}x")
        t_2d = timeit(lambda: baselines.hash_2d(g, k))
        emit(f"fig9/hash2d/k{k}", t_2d, "")
        t_dbh = timeit(lambda: baselines.dbh(g, k))
        emit(f"fig9/dbh/k{k}", t_dbh, "")
        t_bvc = timeit(lambda: baselines.bvc_partition(g, k))
        emit(f"fig9/bvc/k{k}", t_bvc, "")
    k = 16
    t_ne = timeit(lambda: baselines.ne_partition(g, k), repeats=1)
    emit(f"fig9/ne/k{k}", t_ne, "")
    t_hdrf = timeit(lambda: baselines.hdrf(g, k), repeats=1)
    emit(f"fig9/hdrf/k{k}", t_hdrf, "")
    # Scaling event k → k+1: CEP needs only a new plan (O(k)); hash methods
    # recompute every edge.
    t_plan = timeit(lambda: cep.scale_plan(e, 16, 17), repeats=5, number=20)
    emit("fig9/cep_scale_plan/16to17", t_plan, "O(k) plan, no edge pass")
    # Thm. 1: CEP cost is independent of |E| — same arithmetic at 1B edges.
    t_1b = timeit(lambda: cep.chunk_bounds(10**9, 128), repeats=5, number=100)
    emit("fig9/cep/k128_E1e9", t_1b, "E=1e9;size-independent")


if __name__ == "__main__":
    run()
