"""Streaming-graph subsystem benchmark — the ISSUE-3 acceptance scenario.

Runs a ≥10k-update synthetic stream over a GEO-ordered RMAT base graph with
two rescales interleaved (k → k+x → k−y), all through the elastic controller
(ingest events + scale events on one seq-ordered log), and records in
``BENCH_stream.json``:

* ``ingest``      — per-batch on-device ingest latency (median/p90) and
                    edges/s, vs the cost of a full geo_order re-run
                    (acceptance: ingest ≥ 10× cheaper). The quality monitor's
                    escalations are NOT hidden inside that number: the
                    ``amortized`` block reports the full per-batch wall time
                    including partial re-orders and full GEO rebuilds, with
                    per-rung costs — that is the true cost of keeping the
                    stream rescalable at oracle-margin quality;
* ``quality``     — RF of the incremental order vs a full-GEO oracle re-run
                    at every checkpoint (acceptance: within 10%);
* ``bit_identity``— the sharded pack equals the host slot oracle after
                    unshard at every checkpoint (acceptance: byte-for-byte);
* ``rescale``     — latency + movement of the two rescales-under-ingest.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import ordering
from repro.elastic import controller as ec
from repro.launch import mesh as MM
from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream

from .common import emit

K0, K_UP, K_DOWN = 8, 12, 6


def run(
    scale: int = 11,
    edge_factor: int = 10,
    batches: int = 100,
    batch_size: int = 100,
    out_json: str = "BENCH_stream.json",
) -> dict:
    from repro.core.graph import rmat_graph

    g = rmat_graph(scale, edge_factor, seed=0)
    t0 = time.perf_counter()
    order = ordering.geo_order(g, seed=0)
    t_geo_base = time.perf_counter() - t0
    src, dst = g.src[order].astype(np.int64), g.dst[order].astype(np.int64)

    orderer = IncrementalOrderer(src, dst, g.num_vertices, regions=K0)
    engine = StreamingEngine(orderer, MM.make_graph_mesh(1))
    # Simulated clock: liveness must be driven by the scenario's script, not
    # by how fast this machine happens to run the stream.
    clock = [0.0]
    ctl = ec.ElasticController(K0, clock=lambda: clock[0])
    ctl.attach_stream(engine)
    stream = SyntheticStream(g, batch_size=batch_size, seed=1)

    ingest_s: list[float] = []  # host placement + device ingest, no monitor
    batch_wall_s: list[float] = []  # ingest + quality monitor + escalations
    monitor_by_rung: dict = {"none": [], "partial": [], "full": []}
    updates = 0
    esc = {"none": 0, "partial": 0, "full": 0}
    checkpoints: list[dict] = []
    rescales: list[dict] = []

    def checkpoint(b: int) -> None:
        engine.verify_bit_identity()  # raises on any divergence
        inc, oracle = engine.rf_vs_oracle()
        checkpoints.append(
            {"batch": b, "k": engine.k, "edges": orderer.num_edges,
             "rf_incremental": round(inc, 4), "rf_oracle": round(oracle, 4),
             "ratio": round(inc / oracle, 4)}
        )

    def rescale_via_controller(k_new: int) -> None:
        # Drive through the controller so scale + ingest share the seq log.
        ev = (ctl.add_hosts(k_new - ctl.k) if k_new > ctl.k
              else ctl.poll())
        assert ev is not None and ev.executed and engine.k == k_new
        stats = ctl.rescale_stats[-1]
        rescales.append(
            {"k_old": stats.k_old, "k_new": stats.k_new, "seq": ev.seq,
             "moved_edges": stats.moved_edges, "cep_plan_edges": stats.cep_plan_edges,
             "cross_device_edges": stats.cross_device_edges,
             "elapsed_ms": round(stats.elapsed_s * 1e3, 3)}
        )

    t_start = time.perf_counter()
    for b in range(batches):
        if b == batches * 2 // 5:  # scale out k → k+x under ingest
            rescale_via_controller(K_UP)
        if b == batches * 3 // 4:  # scale in k → k−y: preempt hosts, poll
            clock[0] += ctl.dead_after_s + 1.0
            for h in sorted(ctl.hosts)[K_UP - K_DOWN :]:
                ctl.heartbeat(h, step=b)  # survivors beat; the rest went dark
            rescale_via_controller(K_DOWN)
        t_b = time.perf_counter()
        ev = ctl.ingest(stream.batch())
        batch_wall_s.append(time.perf_counter() - t_b)
        esc[ev.escalation] += 1
        ingest_s.append(ev.elapsed_s)
        monitor_by_rung[ev.escalation].append(ev.monitor_s)
        updates += ev.inserted + ev.deleted + ev.skipped
        if b % max(1, batches // 10) == max(1, batches // 10) - 1:
            checkpoint(b)
    t_stream = time.perf_counter() - t_start

    # Full re-ordering cost on the FINAL graph — what every batch would pay
    # without the incremental path.
    t1 = time.perf_counter()
    ordering.geo_order(orderer.graph(), seed=0)
    t_geo_final = time.perf_counter() - t1

    med = float(np.median(ingest_s))
    p90 = float(np.percentile(ingest_s, 90))
    speedup = t_geo_final / med
    mean_wall = float(np.mean(batch_wall_s))
    amortized_speedup = t_geo_final / mean_wall
    worst_ratio = max(c["ratio"] for c in checkpoints)
    seqs = [e.seq for e in ctl.events]
    result = {
        "scenario": {
            "base_edges": int(g.num_edges), "final_edges": orderer.num_edges,
            "vertices": int(g.num_vertices), "batches": batches,
            "batch_size": batch_size, "updates": updates,
            "k_path": [K0, K_UP, K_DOWN],
            "events_seq_monotonic": seqs == sorted(seqs) and len(set(seqs)) == len(seqs),
        },
        "ingest": {
            "median_ms": round(med * 1e3, 3),
            "p90_ms": round(p90 * 1e3, 3),
            "updates_per_s": round(updates / sum(ingest_s), 1),
            "full_geo_reorder_ms": round(t_geo_final * 1e3, 1),
            "speedup_vs_full_reorder": round(speedup, 1),
            "acceptance_10x": speedup >= 10.0,
            "base_geo_order_s": round(t_geo_base, 3),
        },
        # The honest total: ingest latency above EXCLUDES the quality
        # monitor's escalation work; this block includes it (per-batch wall
        # time of ingest + monitor, and what each ladder rung cost).
        "amortized": {
            "mean_batch_wall_ms": round(mean_wall * 1e3, 3),
            "speedup_vs_reorder_every_batch": round(amortized_speedup, 1),
            "escalations": esc,
            "monitor_mean_ms_by_rung": {
                rung: round(float(np.mean(ts)) * 1e3, 2) if ts else 0.0
                for rung, ts in monitor_by_rung.items()
            },
            "stream_wall_s": round(t_stream, 2),
        },
        "quality": {
            "checkpoints": checkpoints,
            "worst_ratio": round(worst_ratio, 4),
            "acceptance_rf_margin_1.10": worst_ratio <= 1.10,
        },
        "bit_identity": {"checked_checkpoints": len(checkpoints), "all_identical": True},
        "rescale": rescales,
    }
    with open(out_json, "w") as f:
        json.dump(result, f, indent=1)
    emit("stream/ingest_batch", med * 1e6, f"updates_per_s={result['ingest']['updates_per_s']}")
    emit("stream/batch_amortized", mean_wall * 1e6, f"incl_escalations_speedup={amortized_speedup:.1f}x")
    emit("stream/full_reorder", t_geo_final * 1e6, f"ingest_speedup={speedup:.1f}x")
    emit("stream/rf_worst_ratio", 0.0, f"ratio={worst_ratio:.3f}")
    for r in rescales:
        emit(f"stream/rescale_{r['k_old']}to{r['k_new']}", r["elapsed_ms"] * 1e3,
             f"moved={r['moved_edges']}")
    assert result["ingest"]["acceptance_10x"], f"ingest only {speedup:.1f}x cheaper than full reorder"
    assert result["quality"]["acceptance_rf_margin_1.10"], f"RF drifted to {worst_ratio:.3f}x oracle"
    # Regression floor: even counting every escalation, streaming must beat
    # repartitioning from scratch on each batch.
    assert amortized_speedup >= 2.0, f"amortized cost only {amortized_speedup:.1f}x better"
    return result


if __name__ == "__main__":
    run()
