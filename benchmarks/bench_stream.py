"""Streaming-graph subsystem benchmark — the ISSUE-3/5/6 acceptance.

Runs a ≥10k-update synthetic stream over a GEO-ordered RMAT base graph with
two rescales interleaved (k → k+x → k−y), all through the elastic controller
(ingest events + scale events + rebuild events on one seq-ordered log), with
the partial re-order rung executing ON-DEVICE (ISSUE-5: the cached
span-repair program of kernels/span_reorder.py) and the full-rebuild rung
running ASYNCHRONOUSLY against shadow buffers (the ISSUE-6 tentpole:
dispatch → ``rebuild_flight`` batches of overlapped ingest → commit + delta
splice, DESIGN.md §11). Records in ``BENCH_stream.json``:

* ``ingest``      — per-batch on-device ingest latency (median/p90) and
                    edges/s, vs the cost of a full geo_order re-run
                    (acceptance: ingest ≥ 10× cheaper);
* ``amortized``   — the full per-batch wall time including the quality
                    monitor's escalations, with per-rung counts and costs.
                    ISSUE-6 acceptance: mean batch wall ≤ 3× the ingest-only
                    median (``issue_target_within_3x_ingest`` is COMPUTED
                    from these numbers and asserted, in --smoke runs too);
* ``full_rung``   — async rebuild accounting: dispatch/commit cost, replayed
                    delta batches, splice ops, and the proof that no commit
                    blocked ingest for more than its one batch;
* ``program_cache`` — per-kind hit/miss/eviction counters walked across the
                    event log: the escalation program kinds (span_repair /
                    full_reorder / splice) must show ZERO misses inside the
                    monitored stream — escalations never pay a compile;
* ``partial_rung``— device span-repair cost vs the host geo_order span repair
                    (acceptance: ≥ 5× cheaper; PR-3 recorded ~51 ms/partial);
* ``quality``     — RF of the incremental order vs a full-GEO oracle re-run
                    at every checkpoint (acceptance: within 10%);
* ``bit_identity``— the sharded pack equals the host slot oracle after EVERY
                    event (byte-for-byte; raises on first divergence);
* ``rescale``     — latency + movement of the two rescales-under-ingest;
* ``rebuild_under_burst`` — a bursty-stream sub-run (SyntheticStream burst
                    mode) stressing the commit's delta-splice path with
                    churn spikes while rebuilds are in flight;
* ``observability`` — the runtime tracing layer's own ledger (DESIGN.md
                    §13): spans/batch, microbenchmarked per-span cost, the
                    registry's scalar snapshot, and the proof that tracing
                    the stream costs < 2% of the amortized batch wall
                    (gated in strict runs AND by check_regression).

``--trace out.json`` exports the stream's span timeline as Chrome-trace JSON
(chrome://tracing / ui.perfetto.dev — one track per phase).
``--smoke`` runs a scaled-down stream and prints the per-rung timing table —
surfaced in the CI multidevice AND multihost job logs so rung-cost
regressions are visible without downloading artifacts.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import ordering
from repro.elastic import controller as ec
from repro.launch import mesh as MM
from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.obs import trace_export as OX
from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream
from repro.stream.incremental import StreamConfig

from .common import emit, peak_rss_mb

K0, K_UP, K_DOWN = 8, 12, 6

# The PR-3 scenario config (default thresholds, 1-region spans) so the
# partial-rung cost is apples-to-apples with the committed 50.79 ms "before"
# figure; wider spans were measured to cost proportionally more without
# changing the escalation trajectory (candidate selection keeps the incumbent
# layout on most repairs — the noise-degraded spans retain good residual GEO
# order). partial_cooldown=6: at the fine-grained batch size below, drift
# crosses the partial threshold and then STAYS above it for the rest of the
# cycle — without hysteresis the span rung would re-fire on every one of
# those batches, re-repairing a span it just repaired (span repairs plateau
# after the first pass on the same drifted layout; rung costs in
# ``partial_rung`` are measured standalone and are unaffected).
CONFIG = StreamConfig(partial_cooldown=6)

PR3_PARTIAL_MS = 50.79  # committed BENCH_stream.json before the device rung

# Program kinds only the escalation ladder dispatches: the cache-counter walk
# below proves their misses (== compiles) stay flat across the monitored
# stream. Scatter cap-buckets legitimately compile on first occurrence inside
# the stream (pre-existing behavior), and compact/warm compiles happen inside
# a rescale's own reported latency — both excluded by design.
ESCALATION_KINDS = ("span_repair", "full_reorder", "splice")


def _escalation_misses(pc: dict) -> int:
    return sum(pc.get(k, {}).get("misses", 0) for k in ESCALATION_KINDS)


def _stream_escalation_compiles(events) -> int:
    """Walk the seq-ordered event log: new escalation-kind misses appearing
    at an INGEST event were paid inside the monitored ingest+monitor path."""
    compiles = 0
    prev = None
    for e in events:
        pc = getattr(e, "program_cache", None)
        if not pc:
            continue  # RebuildEvents / counter-less events carry no snapshot
        cur = _escalation_misses(pc)
        if prev is not None and e.kind == "ingest":
            compiles += max(0, cur - prev)
        prev = cur
    return compiles


def _rebuild_under_burst(
    full_rebuild: str, rebuild_flight: int, mesh_size: int | None,
) -> dict:
    """Bursty sub-run: churn spikes (burst batches ``burst_factor``× the base
    size at a heavier delete ratio) landing while full rebuilds are in
    flight — the commit's delta-splice path under maximum pressure. Bit
    identity is verified after every event; the returned accounting shows the
    rebuilds actually overlapped burst ingest (replayed delta batches > 0)."""
    from repro.core.graph import rmat_graph

    g = rmat_graph(9, 8, seed=3)
    order = ordering.geo_order(g, seed=0)
    src, dst = g.src[order].astype(np.int64), g.dst[order].astype(np.int64)
    # Aggressive thresholds so the stream escalates to full rebuilds often
    # enough that bursts land mid-flight.
    cfg = StreamConfig(partial_drift=1.01, full_drift=1.03, span_regions=2)
    orderer = IncrementalOrderer(src, dst, g.num_vertices, regions=4, config=cfg)
    engine = StreamingEngine(
        orderer, MM.make_graph_mesh(mesh_size), span_repair="device",
        full_rebuild=full_rebuild, rebuild_flight=rebuild_flight,
        warm_scatter_caps=(64, 128, 256, 512),  # burst batches hit big buckets
    )
    ctl = ec.ElasticController(4, clock=lambda: 0.0)
    ctl.attach_stream(engine)
    stream = SyntheticStream(
        g, batch_size=64, seed=2,
        burst_every=5, burst_factor=4, burst_delete_frac=0.4,
    )
    batches = 25
    burst_updates = 0
    for b in range(batches):
        ev = ctl.ingest(stream.batch())
        engine.verify_bit_identity()
        if stream.is_burst(b):
            burst_updates += ev.inserted + ev.deleted + ev.skipped
    while engine.rebuilds_in_flight:
        ctl.ingest(stream.batch())
        engine.verify_bit_identity()
    rebuilds = [e for e in ctl.events if e.kind == "full_rebuild"]
    committed = [r for r in rebuilds if r.committed]
    return {
        "batches": batches,
        "burst_batches": sum(1 for b in range(batches) if stream.is_burst(b)),
        "burst_updates": burst_updates,
        "final_edges": orderer.num_edges,
        "rebuilds": len(rebuilds),
        "committed": len(committed),
        "replayed_batches_total": sum(r.replayed_batches for r in committed),
        "splice_ops_total": sum(r.splice_ops for r in committed),
        "escalations": dict(engine.rung_counts),
        # verify_bit_identity raised on any divergence above.
        "bit_identity_all_events": True,
    }


def _host_rung_ms(orderer: IncrementalOrderer, reps: int = 3) -> float:
    """Cost of the PR-3 HOST partial rung (geo_order on the extracted span)
    on a reconstruction of the final stream state — the honest same-machine
    'before' figure for the device rung."""
    ts = []
    for _ in range(reps):
        src, dst = orderer.snapshot()
        clone = IncrementalOrderer(
            src, dst, orderer.num_vertices,
            regions=orderer.regions, config=orderer.config,
        )
        t0 = time.perf_counter()
        clone.partial_reorder()
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts)) * 1e3


def _span_cost_s(tracer, n: int = 20000) -> float:
    """Per-span enter/exit cost of ``tracer`` (fresh instance — never the one
    whose ring becomes the exported trace)."""
    t0 = time.perf_counter()
    for _ in range(n):
        with tracer.span("obs.cost"):
            pass
    return (time.perf_counter() - t0) / n


def run(
    scale: int = 11,
    edge_factor: int = 10,
    # 400 × 25 (same 10k updates as the PR-3 scenario's 100 × 100): the async
    # rung targets the fine-grained streaming regime — batches arriving
    # constantly, per-batch latency the metric — which is exactly what the
    # 3×-ingest amortization bound and the never-blocks-more-than-one-batch
    # guarantee protect.
    batches: int = 400,
    batch_size: int = 25,
    out_json: str | None = "BENCH_stream.json",
    span_repair: str = "device",
    mesh_size: int | None = 1,
    full_rebuild: str = "geo",
    rebuild_flight: int = 2,
    trace_out: str | None = None,
) -> dict:
    from repro.core.graph import rmat_graph

    strict = out_json is not None  # smoke runs skip machine-speed acceptances

    g = rmat_graph(scale, edge_factor, seed=0)
    t0 = time.perf_counter()
    order = ordering.geo_order(g, seed=0)
    t_geo_base = time.perf_counter() - t0
    src, dst = g.src[order].astype(np.int64), g.dst[order].astype(np.int64)

    # Observability (DESIGN.md §13): the tracer records every runtime span of
    # the monitored stream (ingest/rung/rebuild/rescale + transfer.*, the
    # latter via the process-global default); the registry double-enters the
    # same phases as latency histograms. Both ride INSIDE the timed regions —
    # the overhead ledger below proves they cost < 2% of the batch wall.
    tracer = OT.Tracer(capacity=1 << 18)
    registry = OM.MetricsRegistry()

    orderer = IncrementalOrderer(src, dst, g.num_vertices, regions=K0, config=CONFIG)
    engine = StreamingEngine(
        orderer, MM.make_graph_mesh(mesh_size), span_repair=span_repair,
        full_rebuild=full_rebuild, rebuild_flight=rebuild_flight,
        # Seed the expected scatter op-capacity buckets so not even the first
        # batch (or the first after a rescale) pays a compile in-stream.
        warm_scatter_caps=(batch_size, 2 * batch_size),
        tracer=tracer, metrics_registry=registry,
    )
    # Simulated clock: liveness must be driven by the scenario's script, not
    # by how fast this machine happens to run the stream.
    clock = [0.0]
    ctl = ec.ElasticController(
        K0, clock=lambda: clock[0], tracer=tracer, metrics_registry=registry,
    )
    ctl.attach_stream(engine)
    stream = SyntheticStream(g, batch_size=batch_size, seed=1)

    ingest_s: list[float] = []  # host placement + device ingest, no monitor
    batch_wall_s: list[float] = []  # ingest + quality monitor + escalations
    monitor_by_rung: dict = {"none": [], "partial": [], "full": []}
    updates = 0
    checkpoints: list[dict] = []
    rescales: list[dict] = []

    def checkpoint(b: int) -> None:
        inc, oracle = engine.rf_vs_oracle()
        checkpoints.append(
            {"batch": b, "k": engine.k, "edges": orderer.num_edges,
             "rf_incremental": round(inc, 4), "rf_oracle": round(oracle, 4),
             "ratio": round(inc / oracle, 4)}
        )

    def rescale_via_controller(k_new: int) -> None:
        # Drive through the controller so scale + ingest share the seq log.
        ev = (ctl.add_hosts(k_new - ctl.k) if k_new > ctl.k
              else ctl.poll())
        assert ev is not None and ev.executed and engine.k == k_new
        stats = ctl.rescale_stats[-1]
        rescales.append(
            {"k_old": stats.k_old, "k_new": stats.k_new, "seq": ev.seq,
             "moved_edges": stats.moved_edges, "cep_plan_edges": stats.cep_plan_edges,
             "cross_device_edges": stats.cross_device_edges,
             "elapsed_ms": round(stats.elapsed_s * 1e3, 3)}
        )
        engine.verify_bit_identity()  # byte-compare after every event

    # Global-default tracer for the stream's lifetime: launch/multihost's
    # transfer.* spans (put_global / host_read / psum_host) report through
    # get_tracer(), not an injected handle. Restored in the finally so the
    # burst sub-run and rung baselines below stay untraced.
    OT.set_tracer(tracer)
    try:
        t_start = time.perf_counter()
        for b in range(batches):
            if b == batches * 2 // 5:  # scale out k → k+x under ingest
                rescale_via_controller(K_UP)
            if b == batches * 3 // 4:  # scale in k → k−y: preempt hosts, poll
                clock[0] += ctl.dead_after_s + 1.0
                for h in sorted(ctl.hosts)[K_UP - K_DOWN :]:
                    ctl.heartbeat(h, step=b)  # survivors beat; the rest went dark
                rescale_via_controller(K_DOWN)
            batch = stream.batch()  # generator cost is workload, not system, cost
            t_b = time.perf_counter()
            ev = ctl.ingest(batch)
            batch_wall_s.append(time.perf_counter() - t_b)
            ingest_s.append(ev.elapsed_s)
            monitor_by_rung[ev.escalation].append(ev.monitor_s)
            updates += ev.inserted + ev.deleted + ev.skipped
            # Stream bit-identity after EVERY event (outside the timed region):
            # the device span repair must never diverge from the host mirror.
            engine.verify_bit_identity()
            if b % max(1, batches // 10) == max(1, batches // 10) - 1:
                checkpoint(b)
        t_stream = time.perf_counter() - t_start
        # The registry view of the monitored stream, captured HERE — before the
        # flight-flush ingests below land extra observations. The artifact's
        # ingest percentiles are derived from this histogram (exact: the ring
        # still holds every sample), not recomputed from a side list.
        ingest_hist = registry.histogram("stream.ingest.batch_s")
        assert ingest_hist.exact and ingest_hist.total == batches, (
            f"registry saw {ingest_hist.total} ingest observations, "
            f"expected {batches} (exact={ingest_hist.exact})"
        )
        ingest_pcts = ingest_hist.percentiles()
        ingest_sum_s = float(ingest_hist.sum)
        OM.record_peak_rss(registry)
        reg_snapshot = registry.snapshot()
        # A rebuild still in flight at stream end: complete it so the accounting
        # below sees every dispatched rebuild through to its commit.
        while engine.rebuilds_in_flight:
            ev = ctl.ingest(stream.batch())
            engine.verify_bit_identity()
    finally:
        OT.set_tracer(None)
    esc = dict(engine.rung_counts)

    # Full re-ordering cost on the FINAL graph — what every batch would pay
    # without the incremental path — and the PR-3 host partial rung on the
    # same final state, the device rung's before/after baseline.
    t1 = time.perf_counter()
    ordering.geo_order(orderer.graph(), seed=0)
    t_geo_final = time.perf_counter() - t1
    host_rung_ms = _host_rung_ms(orderer)

    burst = _rebuild_under_burst(full_rebuild, rebuild_flight, mesh_size)

    # Registry-derived ingest latencies; identical samples to the ingest_s
    # side list (asserted above), so this is a derivation change, not a
    # measurement change.
    med = float(ingest_pcts["p50"])
    p90 = float(ingest_pcts["p90"])
    speedup = t_geo_final / med
    mean_wall = float(np.mean(batch_wall_s))
    amortized_speedup = t_geo_final / mean_wall
    partial_ms = (
        float(np.mean(monitor_by_rung["partial"])) * 1e3
        if monitor_by_rung["partial"] else 0.0
    )
    worst_ratio = max(c["ratio"] for c in checkpoints)
    seqs = [e.seq for e in ctl.events]
    rebuilds = [e for e in ctl.events if e.kind == "full_rebuild"]
    committed = [r for r in rebuilds if r.committed]
    # The non-blocking proof, from the event log itself: no ingest batch both
    # dispatched and committed a rebuild (rebuild_flight >= 1), i.e. the full
    # rung never holds ingest for longer than the one commit batch.
    ingest_events = [e for e in ctl.events if e.kind == "ingest"]
    dispatch_batches = sum(1 for e in ingest_events if e.rebuild_state == "dispatch")
    commit_batches = sum(1 for e in ingest_events if e.rebuild_state == "commit")
    esc_compiles = _stream_escalation_compiles(ctl.events)

    # Observability ledger (DESIGN.md §13 acceptance): the in-stream tracing
    # cost, computed deterministically — actual spans per batch × the
    # microbenchmarked per-span enter/exit cost, as a fraction of the
    # amortized batch wall — rather than differencing two noisy stream runs.
    # spans_per_batch counts EVERY recorded span (rescales and flight-flush
    # included), so the fraction over-states, never hides, the true cost.
    spans_per_batch = tracer.recorded / max(1, batches)
    span_cost_s = _span_cost_s(OT.Tracer(capacity=1 << 18))
    noop_cost_s = _span_cost_s(OT.Tracer(capacity=1, enabled=False))
    overhead_frac = spans_per_batch * span_cost_s / mean_wall
    trace = OX.chrome_trace(tracer, process=0, process_name="bench_stream")
    trace_problems = OX.validate_chrome_trace(trace)
    registry_scalars = {
        k: round(float(v), 6) for k, v in reg_snapshot.items()
        if not k.endswith(".buckets")
    }
    result = {
        "scenario": {
            "base_edges": int(g.num_edges), "final_edges": orderer.num_edges,
            "vertices": int(g.num_vertices), "batches": batches,
            "batch_size": batch_size, "updates": updates,
            "k_path": [K0, K_UP, K_DOWN],
            "span_repair": span_repair, "span_regions": CONFIG.span_regions,
            "full_rebuild": full_rebuild, "rebuild_flight": rebuild_flight,
            "events_seq_monotonic": seqs == sorted(seqs) and len(set(seqs)) == len(seqs),
        },
        "ingest": {
            "median_ms": round(med * 1e3, 3),
            "p90_ms": round(p90 * 1e3, 3),
            "updates_per_s": round(updates / ingest_sum_s, 1),
            "full_geo_reorder_ms": round(t_geo_final * 1e3, 1),
            "speedup_vs_full_reorder": round(speedup, 1),
            "acceptance_10x": speedup >= 10.0,
            "base_geo_order_s": round(t_geo_base, 3),
        },
        # The honest total: ingest latency above EXCLUDES the quality
        # monitor's escalation work; this block includes it (per-batch wall
        # time of ingest + monitor, and what each ladder rung cost).
        "amortized": {
            "mean_batch_wall_ms": round(mean_wall * 1e3, 3),
            "speedup_vs_reorder_every_batch": round(amortized_speedup, 1),
            "vs_ingest_only_median": round(mean_wall / med, 2),
            # ISSUE-6 target, COMPUTED from this run's numbers (asserted
            # below, in --smoke too): the async full rung — dispatch against
            # shadow buffers, commit + delta splice rebuild_flight batches
            # later — must keep the full per-batch wall within 3× the
            # ingest-only median.
            "issue_target_within_3x_ingest": bool(mean_wall <= 3.0 * med),
            "escalations": esc,
            "monitor_mean_ms_by_rung": {
                rung: round(float(np.mean(ts)) * 1e3, 2) if ts else 0.0
                for rung, ts in monitor_by_rung.items()
            },
            "stream_wall_s": round(t_stream, 2),
        },
        # ISSUE-6 tentpole: the async full-rebuild rung, from the event log.
        "full_rung": {
            "mode": full_rebuild,
            "rebuild_flight": rebuild_flight,
            "rebuilds": len(rebuilds),
            "committed": len(committed),
            "aborted": sum(1 for r in rebuilds if r.aborted),
            "dispatch_mean_ms": round(
                float(np.mean([r.dispatch_s for r in rebuilds])) * 1e3, 2
            ) if rebuilds else 0.0,
            "commit_mean_ms": round(
                float(np.mean([r.commit_s for r in committed])) * 1e3, 2
            ) if committed else 0.0,
            "replayed_batches_total": sum(r.replayed_batches for r in committed),
            "splice_ops_total": sum(r.splice_ops for r in committed),
            "dispatch_batches": dispatch_batches,
            "commit_batches": commit_batches,
            # True ⇔ every COMMITTED rebuild stayed in flight ≥1 batch (its
            # dispatch and commit landed on different batches): the rung never
            # blocked ingest for more than the one commit batch. Aborted
            # rebuilds (a rescale voided the snapshot) never commit, so they
            # never block — whatever batch the abort landed on.
            "never_blocks_more_than_one_batch": all(
                r.flight_batches >= 1 for r in committed
            ),
        },
        # Escalations never pay a compile: every span/full/splice program
        # signature is warmed at layout changes, and the counter walk across
        # the event log shows zero escalation-kind misses inside the stream.
        "program_cache": {
            "final": engine.program_cache_counters(),
            "escalation_compiles_in_stream": esc_compiles,
            "proof_no_escalation_compiles": esc_compiles == 0,
        },
        # ISSUE-5 tentpole: device span repair vs the host rungs. The honest
        # "before" is PR-3's committed 50.79 ms partial mean; host_geo_mean_ms
        # is today's host-mode rung on the same final state — itself ~3×
        # cheaper than PR-3's because this PR also optimized geo_order's hot
        # loop (bit-identical order), which deflates that comparison.
        "partial_rung": {
            "mode": span_repair,
            "device_mean_ms": round(partial_ms, 2),
            "host_geo_mean_ms": round(host_rung_ms, 2),
            "speedup_vs_host_rung": round(host_rung_ms / max(partial_ms, 1e-9), 1),
            "pr3_recorded_partial_ms": PR3_PARTIAL_MS,
            "speedup_vs_pr3_rung": round(PR3_PARTIAL_MS / max(partial_ms, 1e-9), 1),
            "issue_target_5x_drop": partial_ms * 5.0 <= PR3_PARTIAL_MS,
        },
        "quality": {
            "checkpoints": checkpoints,
            "worst_ratio": round(worst_ratio, 4),
            "acceptance_rf_margin_1.10": worst_ratio <= 1.10,
        },
        # verify_bit_identity raised on any divergence, so reaching here means
        # every one of the stream's events byte-matched the host oracle.
        "bit_identity": {"checked_events": len(batch_wall_s) + len(rescales),
                         "all_identical": True},
        "rescale": rescales,
        "rebuild_under_burst": burst,
        # Runtime observability layer (DESIGN.md §13): span accounting, the
        # < 2% overhead proof, and the registry's scalar snapshot (histogram
        # percentiles over the SAME samples the sections above report).
        "observability": {
            "spans_recorded": int(tracer.recorded),
            "spans_dropped": int(tracer.dropped),
            "span_phases": sorted({s.phase for s in tracer.spans()}),
            "spans_per_batch": round(spans_per_batch, 2),
            "span_cost_us": round(span_cost_s * 1e6, 4),
            "noop_span_cost_us": round(noop_cost_s * 1e6, 4),
            "overhead_frac_of_batch_wall": round(overhead_frac, 6),
            "overhead_within_2pct": bool(overhead_frac <= 0.02),
            "trace_well_formed": not trace_problems,
            "registry": registry_scalars,
        },
    }
    result["peak_rss_mb"] = round(peak_rss_mb(), 1)
    if trace_out:
        OX.write_chrome_trace(trace_out, trace)
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1)
    emit("stream/ingest_batch", med * 1e6, f"updates_per_s={result['ingest']['updates_per_s']}")
    emit("stream/batch_amortized", mean_wall * 1e6, f"incl_escalations_speedup={amortized_speedup:.1f}x")
    emit("stream/full_reorder", t_geo_final * 1e6, f"ingest_speedup={speedup:.1f}x")
    emit("stream/partial_rung_device", partial_ms * 1e3, f"host_rung={host_rung_ms:.1f}ms")
    emit("stream/rf_worst_ratio", 0.0, f"ratio={worst_ratio:.3f}")
    for r in rescales:
        emit(f"stream/rescale_{r['k_old']}to{r['k_new']}", r["elapsed_ms"] * 1e3,
             f"moved={r['moved_edges']}")
    assert result["quality"]["acceptance_rf_margin_1.10"], f"RF drifted to {worst_ratio:.3f}x oracle"
    # Protocol acceptances, asserted in EVERY run (--smoke included) — these
    # are structural properties of the async rung, not machine-speed ratios.
    assert result["scenario"]["events_seq_monotonic"], "event seq log not monotonic"
    assert not trace_problems, f"exported trace malformed: {trace_problems}"
    assert not result["observability"]["spans_dropped"], (
        "tracer ring overflowed — raise its capacity so the export is complete"
    )
    assert result["program_cache"]["proof_no_escalation_compiles"], (
        f"{esc_compiles} escalation-kind compiles paid inside the stream"
    )
    if full_rebuild != "host" and rebuild_flight >= 1:
        assert result["full_rung"]["never_blocks_more_than_one_batch"], (
            "a full rebuild blocked ingest beyond its one commit batch"
        )
        # ISSUE-6 acceptance, COMPUTED from this run's measurements and
        # asserted here (in --smoke too) rather than hand-recorded: the async
        # full rung keeps the amortized batch wall within 3× the ingest-only
        # median.
        assert result["amortized"]["issue_target_within_3x_ingest"], (
            f"amortized {mean_wall * 1e3:.1f}ms > 3x ingest median {med * 1e3:.1f}ms"
        )
        # The burst sub-run must have actually overlapped: at least one
        # rebuild committed with delta batches replayed onto the new order.
        assert burst["committed"] >= 1 and burst["replayed_batches_total"] >= 1, (
            f"burst sub-run never exercised the delta-splice path: {burst}"
        )
    if strict:
        assert result["ingest"]["acceptance_10x"], f"ingest only {speedup:.1f}x cheaper than full reorder"
        # Regression floor: even counting every escalation, streaming must
        # beat repartitioning from scratch on each batch.
        assert amortized_speedup >= 2.0, f"amortized cost only {amortized_speedup:.1f}x better"
        # ISSUE-5 regression gates, same-run ratios first so they hold on
        # slower machines: the device rung must beat today's host rung
        # outright and stay well under PR-3's recorded 50.79 ms partial mean.
        assert partial_ms <= host_rung_ms, (
            f"device rung {partial_ms:.1f}ms lost to host rung {host_rung_ms:.1f}ms"
        )
        assert partial_ms * 3.0 <= PR3_PARTIAL_MS, (
            f"partial rung {partial_ms:.1f}ms not 3x under PR-3's {PR3_PARTIAL_MS}ms"
        )
        # Observability tentpole gate: tracing the full 400×25 stream must
        # cost under 2% of the amortized batch wall.
        assert result["observability"]["overhead_within_2pct"], (
            f"tracing overhead {overhead_frac * 100:.2f}% of batch wall > 2%"
        )
    return result


def print_rung_table(result: dict) -> None:
    """The per-rung timing table (CI multidevice job log surface)."""
    amort = result["amortized"]
    print("\nper-rung escalation table (stream of "
          f"{result['scenario']['updates']} updates, "
          f"{result['scenario']['batches']} batches):")
    print(f"  {'rung':<10}{'count':>8}{'mean ms':>12}")
    for rung in ("none", "partial", "full"):
        print(f"  {rung:<10}{amort['escalations'].get(rung, 0):>8}"
              f"{amort['monitor_mean_ms_by_rung'].get(rung, 0.0):>12.2f}")
    pr = result["partial_rung"]
    print(f"  device rung {pr['device_mean_ms']:.2f}ms vs host geo rung "
          f"{pr['host_geo_mean_ms']:.2f}ms ({pr['speedup_vs_host_rung']:.1f}x); "
          f"amortized {amort['mean_batch_wall_ms']:.1f}ms/batch "
          f"({amort['vs_ingest_only_median']:.2f}x ingest-only median)")
    fr = result["full_rung"]
    if fr["rebuilds"]:
        print(f"  async full rung ({fr['mode']}, flight={fr['rebuild_flight']}): "
              f"{fr['committed']}/{fr['rebuilds']} committed, dispatch "
              f"{fr['dispatch_mean_ms']:.1f}ms + commit {fr['commit_mean_ms']:.1f}ms, "
              f"{fr['replayed_batches_total']} delta batches replayed "
              f"({fr['splice_ops_total']} splice ops); 3x-ingest target "
              f"{'MET' if result['amortized']['issue_target_within_3x_ingest'] else 'missed'}")
    burst = result["rebuild_under_burst"]
    print(f"  burst sub-run: {burst['committed']}/{burst['rebuilds']} rebuilds "
          f"committed under {burst['burst_batches']} burst batches, "
          f"{burst['replayed_batches_total']} delta batches "
          f"({burst['splice_ops_total']} splice ops) replayed")
    obs = result["observability"]
    print(f"  observability: {obs['spans_recorded']} spans "
          f"({obs['spans_per_batch']:.1f}/batch) across phases "
          f"{','.join(obs['span_phases'])}; span cost {obs['span_cost_us']:.2f}us "
          f"-> {obs['overhead_frac_of_batch_wall'] * 100:.3f}% of batch wall "
          f"({'within' if obs['overhead_within_2pct'] else 'OVER'} the 2% budget)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down stream; print the per-rung table, no JSON")
    ap.add_argument("--span-repair", default="device",
                    choices=["device", "host", "oracle", "differential"])
    ap.add_argument("--full-rebuild", default="geo",
                    choices=["host", "geo", "device", "differential"],
                    help="full-rung mode: host = legacy sync resync; geo/device/"
                         "differential = async on-mesh rebuild (DESIGN.md §11)")
    ap.add_argument("--rebuild-flight", type=int, default=2,
                    help="batches a dispatched rebuild stays in flight "
                         "(0 = synchronous dispatch+commit)")
    ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                    help="export the stream's span trace as Chrome-trace JSON "
                         "(open in chrome://tracing or ui.perfetto.dev)")
    args = ap.parse_args()
    if args.smoke:
        # Smoke spans every visible device (the CI multidevice job forces 8),
        # so the per-rung table below reflects the SHARDED span-repair path.
        # batch_size 24 keeps the per-batch churn FRACTION small enough that
        # the escalation ladder — and with it the 3x-ingest amortized gate and
        # the RF-margin gate, both asserted in smoke too — runs the same
        # anticipate/dispatch/commit cadence as the full fine-grained
        # scenario, partial rungs included, with measured RF headroom under
        # the 1.10 margin.
        result = run(scale=9, edge_factor=8, batches=30, batch_size=24,
                     out_json=None, span_repair=args.span_repair, mesh_size=None,
                     full_rebuild=args.full_rebuild,
                     rebuild_flight=args.rebuild_flight, trace_out=args.trace)
    else:
        result = run(span_repair=args.span_repair,
                     full_rebuild=args.full_rebuild,
                     rebuild_flight=args.rebuild_flight, trace_out=args.trace)
    print_rung_table(result)


if __name__ == "__main__":
    main()
