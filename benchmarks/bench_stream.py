"""Streaming-graph subsystem benchmark — the ISSUE-3/ISSUE-5 acceptance.

Runs a ≥10k-update synthetic stream over a GEO-ordered RMAT base graph with
two rescales interleaved (k → k+x → k−y), all through the elastic controller
(ingest events + scale events on one seq-ordered log), with the partial
re-order rung executing ON-DEVICE (the ISSUE-5 tentpole: the cached
span-repair program of kernels/span_reorder.py, host bookkeeping via its
byte-exact numpy mirror). Records in ``BENCH_stream.json``:

* ``ingest``      — per-batch on-device ingest latency (median/p90) and
                    edges/s, vs the cost of a full geo_order re-run
                    (acceptance: ingest ≥ 10× cheaper);
* ``amortized``   — the full per-batch wall time including the quality
                    monitor's escalations, with per-rung counts and costs.
                    ISSUE-5 acceptance: mean batch wall ≤ 3× the ingest-only
                    median — the device rung must not dominate the stream;
* ``partial_rung``— device span-repair cost vs the host geo_order span repair
                    measured on the same final state, same machine
                    (acceptance: ≥ 5× cheaper; PR-3 recorded ~51 ms/partial);
* ``quality``     — RF of the incremental order vs a full-GEO oracle re-run
                    at every checkpoint (acceptance: within 10%);
* ``bit_identity``— the sharded pack equals the host slot oracle after EVERY
                    event (byte-for-byte; raises on first divergence);
* ``rescale``     — latency + movement of the two rescales-under-ingest.

``--smoke`` runs a scaled-down stream and prints the per-rung timing table —
surfaced in the CI multidevice job log so rung-cost regressions are visible
without downloading artifacts.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import ordering
from repro.elastic import controller as ec
from repro.launch import mesh as MM
from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream
from repro.stream.incremental import StreamConfig

from .common import emit

K0, K_UP, K_DOWN = 8, 12, 6

# The PR-3 scenario config (defaults, 1-region spans) so the partial-rung
# cost is apples-to-apples with the committed 50.79 ms "before" figure; wider
# spans were measured to cost proportionally more without changing the
# escalation trajectory (candidate selection keeps the incumbent layout on
# most repairs — the noise-degraded spans retain good residual GEO order).
CONFIG = StreamConfig()

PR3_PARTIAL_MS = 50.79  # committed BENCH_stream.json before the device rung


def _host_rung_ms(orderer: IncrementalOrderer, reps: int = 3) -> float:
    """Cost of the PR-3 HOST partial rung (geo_order on the extracted span)
    on a reconstruction of the final stream state — the honest same-machine
    'before' figure for the device rung."""
    ts = []
    for _ in range(reps):
        src, dst = orderer.snapshot()
        clone = IncrementalOrderer(
            src, dst, orderer.num_vertices,
            regions=orderer.regions, config=orderer.config,
        )
        t0 = time.perf_counter()
        clone.partial_reorder()
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts)) * 1e3


def run(
    scale: int = 11,
    edge_factor: int = 10,
    batches: int = 100,
    batch_size: int = 100,
    out_json: str | None = "BENCH_stream.json",
    span_repair: str = "device",
    mesh_size: int | None = 1,
) -> dict:
    from repro.core.graph import rmat_graph

    strict = out_json is not None  # smoke runs skip the timing acceptances

    g = rmat_graph(scale, edge_factor, seed=0)
    t0 = time.perf_counter()
    order = ordering.geo_order(g, seed=0)
    t_geo_base = time.perf_counter() - t0
    src, dst = g.src[order].astype(np.int64), g.dst[order].astype(np.int64)

    orderer = IncrementalOrderer(src, dst, g.num_vertices, regions=K0, config=CONFIG)
    engine = StreamingEngine(orderer, MM.make_graph_mesh(mesh_size), span_repair=span_repair)
    # Simulated clock: liveness must be driven by the scenario's script, not
    # by how fast this machine happens to run the stream.
    clock = [0.0]
    ctl = ec.ElasticController(K0, clock=lambda: clock[0])
    ctl.attach_stream(engine)
    stream = SyntheticStream(g, batch_size=batch_size, seed=1)

    ingest_s: list[float] = []  # host placement + device ingest, no monitor
    batch_wall_s: list[float] = []  # ingest + quality monitor + escalations
    monitor_by_rung: dict = {"none": [], "partial": [], "full": []}
    updates = 0
    checkpoints: list[dict] = []
    rescales: list[dict] = []

    def checkpoint(b: int) -> None:
        inc, oracle = engine.rf_vs_oracle()
        checkpoints.append(
            {"batch": b, "k": engine.k, "edges": orderer.num_edges,
             "rf_incremental": round(inc, 4), "rf_oracle": round(oracle, 4),
             "ratio": round(inc / oracle, 4)}
        )

    def rescale_via_controller(k_new: int) -> None:
        # Drive through the controller so scale + ingest share the seq log.
        ev = (ctl.add_hosts(k_new - ctl.k) if k_new > ctl.k
              else ctl.poll())
        assert ev is not None and ev.executed and engine.k == k_new
        stats = ctl.rescale_stats[-1]
        rescales.append(
            {"k_old": stats.k_old, "k_new": stats.k_new, "seq": ev.seq,
             "moved_edges": stats.moved_edges, "cep_plan_edges": stats.cep_plan_edges,
             "cross_device_edges": stats.cross_device_edges,
             "elapsed_ms": round(stats.elapsed_s * 1e3, 3)}
        )
        engine.verify_bit_identity()  # byte-compare after every event

    t_start = time.perf_counter()
    for b in range(batches):
        if b == batches * 2 // 5:  # scale out k → k+x under ingest
            rescale_via_controller(K_UP)
        if b == batches * 3 // 4:  # scale in k → k−y: preempt hosts, poll
            clock[0] += ctl.dead_after_s + 1.0
            for h in sorted(ctl.hosts)[K_UP - K_DOWN :]:
                ctl.heartbeat(h, step=b)  # survivors beat; the rest went dark
            rescale_via_controller(K_DOWN)
        t_b = time.perf_counter()
        ev = ctl.ingest(stream.batch())
        batch_wall_s.append(time.perf_counter() - t_b)
        ingest_s.append(ev.elapsed_s)
        monitor_by_rung[ev.escalation].append(ev.monitor_s)
        updates += ev.inserted + ev.deleted + ev.skipped
        # Stream bit-identity after EVERY event (outside the timed region):
        # the device span repair must never diverge from the host mirror.
        engine.verify_bit_identity()
        if b % max(1, batches // 10) == max(1, batches // 10) - 1:
            checkpoint(b)
    t_stream = time.perf_counter() - t_start
    esc = dict(engine.rung_counts)

    # Full re-ordering cost on the FINAL graph — what every batch would pay
    # without the incremental path — and the PR-3 host partial rung on the
    # same final state, the device rung's before/after baseline.
    t1 = time.perf_counter()
    ordering.geo_order(orderer.graph(), seed=0)
    t_geo_final = time.perf_counter() - t1
    host_rung_ms = _host_rung_ms(orderer)

    med = float(np.median(ingest_s))
    p90 = float(np.percentile(ingest_s, 90))
    speedup = t_geo_final / med
    mean_wall = float(np.mean(batch_wall_s))
    amortized_speedup = t_geo_final / mean_wall
    partial_ms = (
        float(np.mean(monitor_by_rung["partial"])) * 1e3
        if monitor_by_rung["partial"] else 0.0
    )
    worst_ratio = max(c["ratio"] for c in checkpoints)
    seqs = [e.seq for e in ctl.events]
    result = {
        "scenario": {
            "base_edges": int(g.num_edges), "final_edges": orderer.num_edges,
            "vertices": int(g.num_vertices), "batches": batches,
            "batch_size": batch_size, "updates": updates,
            "k_path": [K0, K_UP, K_DOWN],
            "span_repair": span_repair, "span_regions": CONFIG.span_regions,
            "events_seq_monotonic": seqs == sorted(seqs) and len(set(seqs)) == len(seqs),
        },
        "ingest": {
            "median_ms": round(med * 1e3, 3),
            "p90_ms": round(p90 * 1e3, 3),
            "updates_per_s": round(updates / sum(ingest_s), 1),
            "full_geo_reorder_ms": round(t_geo_final * 1e3, 1),
            "speedup_vs_full_reorder": round(speedup, 1),
            "acceptance_10x": speedup >= 10.0,
            "base_geo_order_s": round(t_geo_base, 3),
        },
        # The honest total: ingest latency above EXCLUDES the quality
        # monitor's escalation work; this block includes it (per-batch wall
        # time of ingest + monitor, and what each ladder rung cost).
        "amortized": {
            "mean_batch_wall_ms": round(mean_wall * 1e3, 3),
            "speedup_vs_reorder_every_batch": round(amortized_speedup, 1),
            "vs_ingest_only_median": round(mean_wall / med, 2),
            # ISSUE-5 target: ≤ 3× the ingest-only median. The partial rung no
            # longer moves this needle (it is ~10% of batch wall); the floor
            # is the FULL rung — host geo_order must fire ~10×/100 batches to
            # hold the 1.10 RF margin on this stream, and ~180 ms × 10% is
            # ~half the mean batch wall on its own (ROADMAP follow-up:
            # device-side / async full rebuild).
            "issue_target_within_3x_ingest": mean_wall <= 3.0 * med,
            "escalations": esc,
            "monitor_mean_ms_by_rung": {
                rung: round(float(np.mean(ts)) * 1e3, 2) if ts else 0.0
                for rung, ts in monitor_by_rung.items()
            },
            "stream_wall_s": round(t_stream, 2),
        },
        # ISSUE-5 tentpole: device span repair vs the host rungs. The honest
        # "before" is PR-3's committed 50.79 ms partial mean; host_geo_mean_ms
        # is today's host-mode rung on the same final state — itself ~3×
        # cheaper than PR-3's because this PR also optimized geo_order's hot
        # loop (bit-identical order), which deflates that comparison.
        "partial_rung": {
            "mode": span_repair,
            "device_mean_ms": round(partial_ms, 2),
            "host_geo_mean_ms": round(host_rung_ms, 2),
            "speedup_vs_host_rung": round(host_rung_ms / max(partial_ms, 1e-9), 1),
            "pr3_recorded_partial_ms": PR3_PARTIAL_MS,
            "speedup_vs_pr3_rung": round(PR3_PARTIAL_MS / max(partial_ms, 1e-9), 1),
            "issue_target_5x_drop": partial_ms * 5.0 <= PR3_PARTIAL_MS,
        },
        "quality": {
            "checkpoints": checkpoints,
            "worst_ratio": round(worst_ratio, 4),
            "acceptance_rf_margin_1.10": worst_ratio <= 1.10,
        },
        # verify_bit_identity raised on any divergence, so reaching here means
        # every one of the stream's events byte-matched the host oracle.
        "bit_identity": {"checked_events": len(batch_wall_s) + len(rescales),
                         "all_identical": True},
        "rescale": rescales,
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1)
    emit("stream/ingest_batch", med * 1e6, f"updates_per_s={result['ingest']['updates_per_s']}")
    emit("stream/batch_amortized", mean_wall * 1e6, f"incl_escalations_speedup={amortized_speedup:.1f}x")
    emit("stream/full_reorder", t_geo_final * 1e6, f"ingest_speedup={speedup:.1f}x")
    emit("stream/partial_rung_device", partial_ms * 1e3, f"host_rung={host_rung_ms:.1f}ms")
    emit("stream/rf_worst_ratio", 0.0, f"ratio={worst_ratio:.3f}")
    for r in rescales:
        emit(f"stream/rescale_{r['k_old']}to{r['k_new']}", r["elapsed_ms"] * 1e3,
             f"moved={r['moved_edges']}")
    assert result["quality"]["acceptance_rf_margin_1.10"], f"RF drifted to {worst_ratio:.3f}x oracle"
    if strict:
        assert result["ingest"]["acceptance_10x"], f"ingest only {speedup:.1f}x cheaper than full reorder"
        # Regression floor: even counting every escalation, streaming must
        # beat repartitioning from scratch on each batch.
        assert amortized_speedup >= 2.0, f"amortized cost only {amortized_speedup:.1f}x better"
        # ISSUE-5 regression gates, same-run ratios first so they hold on
        # slower machines (the aspirational targets are recorded as
        # issue_target_* fields): the device rung must beat today's host rung
        # outright, stay well under PR-3's recorded 50.79 ms partial mean,
        # and the amortized batch wall must stay ≤8× the ingest-only median
        # (achieved ~5×; bounded below by the host full-GEO rung — see the
        # amortized block's note and the ROADMAP follow-up).
        assert partial_ms <= host_rung_ms, (
            f"device rung {partial_ms:.1f}ms lost to host rung {host_rung_ms:.1f}ms"
        )
        assert partial_ms * 3.0 <= PR3_PARTIAL_MS, (
            f"partial rung {partial_ms:.1f}ms not 3x under PR-3's {PR3_PARTIAL_MS}ms"
        )
        assert mean_wall <= 8.0 * med, (
            f"amortized {mean_wall * 1e3:.1f}ms > 8x ingest median {med * 1e3:.1f}ms"
        )
    return result


def print_rung_table(result: dict) -> None:
    """The per-rung timing table (CI multidevice job log surface)."""
    amort = result["amortized"]
    print("\nper-rung escalation table (stream of "
          f"{result['scenario']['updates']} updates, "
          f"{result['scenario']['batches']} batches):")
    print(f"  {'rung':<10}{'count':>8}{'mean ms':>12}")
    for rung in ("none", "partial", "full"):
        print(f"  {rung:<10}{amort['escalations'].get(rung, 0):>8}"
              f"{amort['monitor_mean_ms_by_rung'].get(rung, 0.0):>12.2f}")
    pr = result["partial_rung"]
    print(f"  device rung {pr['device_mean_ms']:.2f}ms vs host geo rung "
          f"{pr['host_geo_mean_ms']:.2f}ms ({pr['speedup_vs_host_rung']:.1f}x); "
          f"amortized {amort['mean_batch_wall_ms']:.1f}ms/batch "
          f"({amort['vs_ingest_only_median']:.2f}x ingest-only median)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down stream; print the per-rung table, no JSON")
    ap.add_argument("--span-repair", default="device",
                    choices=["device", "host", "oracle", "differential"])
    args = ap.parse_args()
    if args.smoke:
        # Smoke spans every visible device (the CI multidevice job forces 8),
        # so the per-rung table below reflects the SHARDED span-repair path.
        result = run(scale=9, edge_factor=8, batches=20, batch_size=64,
                     out_json=None, span_repair=args.span_repair, mesh_size=None)
    else:
        result = run(span_repair=args.span_repair)
    print_rung_table(result)


if __name__ == "__main__":
    main()
