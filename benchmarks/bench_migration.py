"""Figs. 13/14 — migrated edges under the paper's ScaleOut/ScaleIn scenario
(26→27→…→36 and 36→…→26), CEP vs BVC vs 1D hash; plus Thm.-2 check."""
from __future__ import annotations

import numpy as np

from repro.core import baselines, cep

from .common import bench_graph, emit


def _hash_migrated(g, k0, k1, seed=0) -> int:
    p0 = baselines.hash_1d(g, k0, seed)
    p1 = baselines.hash_1d(g, k1, seed)
    return int(np.sum(p0 != p1))


def run(scale: int = 12, edge_factor: int = 12) -> None:
    g = bench_graph(scale, edge_factor)
    e = g.num_edges
    for name, seq in [("scaleout", range(26, 36)), ("scalein", range(36, 26, -1))]:
        cep_total = sum(cep.migrated_edges_exact(e, k, k + (1 if name == "scaleout" else -1)) for k in seq)
        hash_total = sum(_hash_migrated(g, k, k + (1 if name == "scaleout" else -1)) for k in seq)
        # BVC ≡ chunk arithmetic on the hash ring ⇒ same counts as CEP (paper §6.4.3).
        emit(f"fig13/cep/{name}", 0.0, f"moved={cep_total};frac={cep_total/ (e*10):.3f}")
        emit(f"fig13/bvc/{name}", 0.0, f"moved={cep_total};same_as_cep=true")
        emit(f"fig13/1d/{name}", 0.0, f"moved={hash_total};frac={hash_total/(e*10):.3f}")
    # Theorem 2 closed form vs exact overlay.
    for k, x in [(8, 1), (26, 1), (16, 4)]:
        exact = cep.migrated_edges_exact(e, k, k + x)
        approx = cep.migration_cost_theorem2(e, k, x)
        emit(f"fig13/thm2/k{k}x{x}", 0.0, f"exact={exact};approx={approx:.0f};err={(abs(exact-approx)/e):.3f}")


if __name__ == "__main__":
    run()
