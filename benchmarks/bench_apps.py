"""Table 6 — graph applications (PageRank / SSSP / WCC) on the JAX engine:
elapsed time + communication volume under different partitioners."""
from __future__ import annotations

import time

import numpy as np

from repro.core import baselines, ordering
from repro.graphs import engine as E
from repro.launch import mesh as MM

from .common import bench_graph, emit


def run(scale: int = 11, edge_factor: int = 10, k: int = 8) -> None:
    g = bench_graph(scale, edge_factor)
    mesh = MM.make_test_mesh(1, 1)
    geo = ordering.geo_order(g, seed=0)
    partitions = {
        "geo+cep": None,  # via cep_engine_data
        "1d": baselines.hash_1d(g, k),
        "2d": baselines.hash_2d(g, k),
        "dbh": baselines.dbh(g, k),
    }
    for name, part in partitions.items():
        data = E.cep_engine_data(g, geo, k) if part is None else E.build_engine_data(g, part, k)
        com = E.comm_volume_per_iteration(data)
        t0 = time.perf_counter()
        pr = E.pagerank(data, mesh, iterations=10)
        t_pr = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        _, it_s = E.sssp(data, mesh, source=0)
        t_ss = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        _, it_w = E.wcc(data, mesh)
        t_wc = (time.perf_counter() - t0) * 1e6
        emit(
            f"table6/{name}/k{k}",
            t_pr,
            f"rf={data.replication_factor:.3f};mirrors={data.mirrors};"
            f"com_per_iter_bytes={com};sssp_us={t_ss:.0f};wcc_us={t_wc:.0f}",
        )


if __name__ == "__main__":
    run()
