"""Fig. 15 — GEO scalability: elapsed time vs RMAT size / edge factor, with a
linear fit demonstrating O(E)-ish practical scaling (billion-edge runs are
extrapolated; single-core container)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import ordering
from repro.core.graph import rmat_graph

from .common import emit


def run() -> None:
    sizes = []
    times = []
    for scale, ef in [(10, 8), (11, 8), (12, 8), (12, 16), (13, 16)]:
        g = rmat_graph(scale, ef, seed=1)
        t0 = time.perf_counter()
        ordering.geo_order(g, seed=0)
        t = time.perf_counter() - t0
        sizes.append(g.num_edges)
        times.append(t)
        emit(f"fig15/rmat_s{scale}_ef{ef}", t * 1e6, f"E={g.num_edges};us_per_edge={t*1e6/g.num_edges:.2f}")
    # Linear fit t = a·E + b: report per-edge cost + extrapolation to 1B edges.
    a, b = np.polyfit(sizes, times, 1)
    emit("fig15/linear_fit", 0.0, f"us_per_edge={a*1e6:.3f};extrapolated_1B_edges_s={a*1e9 + b:.0f}")

    # Beyond-paper: block-parallel GEO (the paper's §7 future work).
    from repro.core import metrics

    g = rmat_graph(13, 10, seed=1)
    seq = ordering.geo_order(g, seed=0)
    rf_seq = np.mean([
        metrics.replication_factor_ordered(g.src[seq], g.dst[seq], k, g.num_vertices)
        for k in (4, 16, 64)
    ])
    for workers in (2, 4, 8):
        for bal in (False, True):
            t0 = time.perf_counter()
            par, counts = ordering.parallel_geo_order(g, workers=workers, seed=0, balance_edges=bal)
            t = time.perf_counter() - t0
            rf = np.mean([
                metrics.replication_factor_ordered(g.src[par], g.dst[par], k, g.num_vertices)
                for k in (4, 16, 64)
            ])
            # Wall-clock on a real cluster ≈ max-region fraction of total.
            eff = t * max(counts) / max(sum(counts), 1)
            emit(
                f"parallel_geo/w{workers}_{'edgebal' if bal else 'vertbal'}",
                t * 1e6,
                f"rf_ratio_vs_seq={rf/rf_seq:.3f};cluster_wallclock_est_us={eff*1e6:.0f};"
                f"load_balance={max(counts)/(sum(counts)/len(counts)):.2f}",
            )


if __name__ == "__main__":
    run()
