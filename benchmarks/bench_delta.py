"""Fig. 5 — quality/time trade-off of the two-hop range δ in GEO."""
from __future__ import annotations

import time

import numpy as np

from repro.core import metrics, ordering

from .common import bench_graph, emit


def run(scale: int = 11, edge_factor: int = 10) -> None:
    g = bench_graph(scale, edge_factor)
    ks = (4, 8, 16, 32, 64, 128)
    base_delta = max(1, g.num_edges // 128)
    for mult, label in [(0, "0"), (1, "1x"), (10, "10x"), (100, "100x")]:
        delta = max(1, base_delta * mult) if mult else 1
        t0 = time.perf_counter()
        order = ordering.geo_order(g, delta=delta, seed=0)
        t = (time.perf_counter() - t0) * 1e6
        rf = np.mean([
            metrics.replication_factor_ordered(g.src[order], g.dst[order], k, g.num_vertices)
            for k in ks
        ])
        emit(f"fig5/delta_{label}", t, f"avg_rf={rf:.3f}")


if __name__ == "__main__":
    run()
