"""Fig. 5 — quality/time trade-off of the two-hop range δ in GEO."""
from __future__ import annotations

import time

import numpy as np

from repro.core import metrics, ordering

from .common import bench_graph, emit


def run(scale: int = 11, edge_factor: int = 10) -> None:
    g = bench_graph(scale, edge_factor)
    ks = (4, 8, 16, 32, 64, 128)
    base_delta = max(1, g.num_edges // 128)
    # Label and value must agree: δ=1 is the no-two-hop floor (labeled "1",
    # not "0"), the rest are true multiples of the paper's default δ.
    series = [("1", 1)] + [(f"{m}x", base_delta * m) for m in (1, 10, 100)]
    assert all(delta >= 1 for _, delta in series)
    assert dict(series)["1x"] == base_delta and dict(series)["10x"] == 10 * base_delta
    for label, delta in series:
        t0 = time.perf_counter()
        order = ordering.geo_order(g, delta=delta, seed=0)
        t = (time.perf_counter() - t0) * 1e6
        rf = np.mean([
            metrics.replication_factor_ordered(g.src[order], g.dst[order], k, g.num_vertices)
            for k in ks
        ])
        emit(f"fig5/delta_{label}", t, f"avg_rf={rf:.3f},delta={delta}")


if __name__ == "__main__":
    run()
