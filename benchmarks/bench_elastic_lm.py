"""Beyond-paper: elastic LM-state rescale via CEP vs hash-sharded restore.

Plans the k→k±1 reshard of a full qwen2-1.5b checkpoint (params + optimizer
moments) and reports bytes moved — then *executes* each rescale with
ElasticRescaler on a block-proxy pack (each packed row stands for a fixed-size
block of the flattened checkpoint) so the serving scenario reports executed,
not just planned, migration bytes and the on-device program latency.
Also exercises MoE expert-placement rescale.
"""
from __future__ import annotations

import numpy as np

from repro import configs
from repro.elastic import expert_place as ep
from repro.elastic import resharder as rs
from repro.elastic.rescale_exec import ElasticRescaler
from repro.graphs import engine as E

from .common import emit

PROXY_ROWS = 1 << 17  # checkpoint blocks packed as rescaler rows (≫ k², so
# the row-granularity CEP plan tracks the element-exact moved fraction)


def _executed_stats(rescaler: ElasticRescaler, k_old: int, k_new: int):
    """Execute the k_old→k_new rescale on the block-proxy pack. Row ids are
    synthetic (the rescaler moves ranges, never reads endpoints); recheck is
    skipped — graph quality metrics are meaningless for checkpoint blocks."""
    ids = np.zeros(PROXY_ROWS, dtype=np.int64)
    data = E.pack_ordered(ids, ids, 1, k_old)
    return rescaler.execute(data, rescaler.plan(data, k_new), recheck=False)[1]


def run() -> None:
    cfg = configs.get_config("qwen2-1.5b")
    n = cfg.param_count()
    shapes = {
        "params_bf16": ((n,), 2),
        "adam_m_f32": ((n,), 4),
        "adam_v_f32": ((n,), 4),
    }
    rescaler = ElasticRescaler()
    for k_old, k_new in [(16, 17), (16, 15), (256, 257), (16, 32)]:
        plan = rs.plan_reshard(shapes, k_old, k_new)
        s = plan.summary()
        stats = _executed_stats(rescaler, k_old, k_new)
        # Each executed row stands for total_bytes/PROXY_ROWS checkpoint bytes.
        executed_frac = stats.migrated_edges / stats.num_edges
        executed_bytes = executed_frac * s["total_bytes"]
        emit(
            f"elastic/reshard_{k_old}to{k_new}", stats.elapsed_s * 1e6,
            f"moved_GB={s['moved_bytes']/1e9:.2f};moved_frac={s['moved_frac']:.3f};"
            f"executed_GB={executed_bytes/1e9:.2f};executed_frac={executed_frac:.3f};"
            f"executed_ops={stats.copy_ops};hash_frac={s['random_frac']:.3f}",
        )
        # Block granularity only rounds at chunk boundaries: the executed
        # fraction must track the element-exact plan to within a couple of
        # rows per overlay boundary (≤ k_old + k_new of them).
        slack = 2 * (k_old + k_new) / PROXY_ROWS
        assert abs(executed_frac - s["moved_frac"]) <= slack + 1e-9, (
            executed_frac, s["moved_frac"])
    # MoE expert placement: co-activation-aware EP groups + elastic resize.
    rng = np.random.default_rng(0)
    e = 64
    stats = rng.random((e, e))
    for c in range(0, e, 8):  # 8 co-activation communities
        stats[c : c + 8, c : c + 8] += 4.0
    stats = (stats + stats.T) / 2
    np.fill_diagonal(stats, 0)
    order = ep.order_experts(stats)
    placed = ep.ExpertPlacement(order, 8)
    naive = ep.ExpertPlacement(np.arange(e), 8)
    rng2 = np.random.default_rng(1)
    shuf = ep.ExpertPlacement(rng2.permutation(e), 8)
    emit(
        "elastic/expert_traffic", 0.0,
        f"geo={ep.cross_group_traffic(stats, placed):.0f};"
        f"default={ep.cross_group_traffic(stats, naive):.0f};"
        f"shuffled={ep.cross_group_traffic(stats, shuf):.0f}",
    )
    _, moved = placed.rescale(9)
    emit("elastic/expert_rescale_8to9", 0.0, f"experts_moved={moved}/64")


if __name__ == "__main__":
    run()
