"""Beyond-paper: elastic LM-state rescale via CEP vs hash-sharded restore.

Plans the k→k±1 reshard of a full qwen2-1.5b checkpoint (params + optimizer
moments) and reports bytes moved; demonstrates the paper's Thm.-2 benefit at
framework scale. Also exercises MoE expert-placement rescale.
"""
from __future__ import annotations

import numpy as np

from repro import configs
from repro.elastic import expert_place as ep
from repro.elastic import resharder as rs

from .common import emit


def run() -> None:
    cfg = configs.get_config("qwen2-1.5b")
    n = cfg.param_count()
    shapes = {
        "params_bf16": ((n,), 2),
        "adam_m_f32": ((n,), 4),
        "adam_v_f32": ((n,), 4),
    }
    for k_old, k_new in [(16, 17), (16, 15), (256, 257), (16, 32)]:
        plan = rs.plan_reshard(shapes, k_old, k_new)
        s = plan.summary()
        emit(
            f"elastic/reshard_{k_old}to{k_new}", 0.0,
            f"moved_GB={s['moved_bytes']/1e9:.2f};moved_frac={s['moved_frac']:.3f};"
            f"hash_frac={s['random_frac']:.3f}",
        )
    # MoE expert placement: co-activation-aware EP groups + elastic resize.
    rng = np.random.default_rng(0)
    e = 64
    stats = rng.random((e, e))
    for c in range(0, e, 8):  # 8 co-activation communities
        stats[c : c + 8, c : c + 8] += 4.0
    stats = (stats + stats.T) / 2
    np.fill_diagonal(stats, 0)
    order = ep.order_experts(stats)
    placed = ep.ExpertPlacement(order, 8)
    naive = ep.ExpertPlacement(np.arange(e), 8)
    rng2 = np.random.default_rng(1)
    shuf = ep.ExpertPlacement(rng2.permutation(e), 8)
    emit(
        "elastic/expert_traffic", 0.0,
        f"geo={ep.cross_group_traffic(stats, placed):.0f};"
        f"default={ep.cross_group_traffic(stats, naive):.0f};"
        f"shuffled={ep.cross_group_traffic(stats, shuf):.0f}",
    )
    _, moved = placed.rescale(9)
    emit("elastic/expert_rescale_8to9", 0.0, f"experts_moved={moved}/64")


if __name__ == "__main__":
    run()
