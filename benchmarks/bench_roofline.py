"""§Roofline — render the dry-run artifact table (reads artifacts/dryrun)."""
from __future__ import annotations

import json
import pathlib

from .common import emit

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def run() -> None:
    if not ART.exists():
        emit("roofline/missing", 0.0, "run repro.launch.dryrun first")
        return
    for mesh_dir in sorted(ART.iterdir()):
        if not mesh_dir.is_dir():
            continue
        for f in sorted(mesh_dir.glob("*.json")):
            rec = json.loads(f.read_text())
            name = f"roofline/{mesh_dir.name}/{rec['arch']}/{rec['shape']}"
            if rec.get("skipped"):
                emit(name, 0.0, "skipped=" + rec.get("reason", "")[:60])
                continue
            if not rec.get("ok"):
                emit(name, 0.0, "FAILED=" + rec.get("error", "")[:80])
                continue
            r = rec["roofline"]
            mem = rec["memory_analysis"].get("total_per_device_bytes", 0) / 2**30
            emit(
                name,
                rec.get("compile_s", 0) * 1e6,
                f"bottleneck={r['bottleneck']};tc={r['t_compute_s']:.2e};"
                f"tm={r['t_memory_s']:.2e};tn={r['t_collective_s']:.2e};"
                f"useful={r['useful_flops_ratio']:.2f};mfu_ub={r['mfu_upper_bound']:.3f};"
                f"mem_GiB={mem:.2f}",
            )


if __name__ == "__main__":
    run()
