"""Executed-migration cost: ElasticRescaler (CEP overlay range copies) vs a
full hash repartition, across k ∈ {4…128} on the quickstart graph; plus the
acceptance round-trip k=8 → 12 → 8 with bit-identity and Thm.-2 checks.

Also runs a forced-8-device mode (subprocess with
``--xla_force_host_platform_device_count=8``): the same plans executed as
on-mesh migrations over the ``graph`` axis, reporting per-device program
size (copy ops / bytes written per device) and the cross-device traffic,
which for one-partition-per-device rescales equals the Thm.-2 bytes exactly.

Emits the usual ``name,us_per_call,derived`` CSV and writes the full record
to BENCH_rescale.json (committed — the repo's evidence that rescaling moves
only the theorem-predicted ranges, not ≈ k/(k+x)·|E| like hashing).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from repro.core import baselines, cep, ordering
from repro.elastic.rescale_exec import EDGE_BYTES, ElasticRescaler, plan_segments
from repro.graphs import engine as E

from .common import bench_graph, emit, peak_rss_mb

_CHILD_FLAG = "--multidevice-child"
_JSON_MARK = "MULTIDEVICE-JSON:"


def _hash_baseline(g, k_old, k_new, seed=0):
    """Hash repartition k_old → k_new: count relabeled edges and time a full
    repack (there is no incremental path — every moved edge is re-placed)."""
    p0 = baselines.hash_1d(g, k_old, seed)
    p1 = baselines.hash_1d(g, k_new, seed)
    moved = int(np.sum(p0 != p1))
    t0 = time.perf_counter()
    E.build_engine_data(g, p1, k_new)
    return moved, time.perf_counter() - t0


def _best_exec(rescaler, pack, plan, repeats=3):
    """Min-of-N executed migration; repack each round so donation semantics
    stay honest on backends that actually invalidate the donated buffer."""
    best = None
    for _ in range(repeats):
        _, stats = rescaler.execute(pack(), plan, verify=True)
        best = stats if best is None or stats.elapsed_s < best.elapsed_s else best
    return best


def run(scale: int = 12, edge_factor: int = 12, out_path: str = "BENCH_rescale.json") -> dict:
    g = bench_graph(scale, edge_factor)  # == examples/quickstart.py's graph
    order = ordering.geo_order(g, seed=0)
    src, dst = g.src[order], g.dst[order]
    n = g.num_edges
    rescaler = ElasticRescaler()
    record = {
        "graph": {"rmat_scale": scale, "edge_factor": edge_factor, "seed": 0,
                  "num_vertices": g.num_vertices, "num_edges": n},
        "edge_bytes": EDGE_BYTES,
        "sweep": [],
    }

    for k in (4, 8, 16, 32, 64, 128):
        k_new = k + 1  # the paper's elasticity step (Cor. 1: ≈ |E|/2 moves)
        plan = cep.scale_plan(n, k, k_new)
        pack = lambda: E.pack_ordered(src, dst, g.num_vertices, k)
        stats = _best_exec(rescaler, pack, plan)
        hash_moved, hash_s = _hash_baseline(g, k, k_new)
        row = {
            "k_old": k, "k_new": k_new,
            "cep_moved_edges": stats.migrated_edges,
            "cep_moved_bytes": stats.migrated_bytes,
            "cep_moved_frac": stats.migrated_edges / n,
            "cep_exec_us": stats.elapsed_s * 1e6,
            "cep_recheck_us": stats.recheck_s * 1e6,  # host metrics re-check + oracle
            "cep_total_us": (stats.elapsed_s + stats.recheck_s) * 1e6,
            "cep_copy_ops": stats.copy_ops,
            "bit_identical_to_scratch": stats.oracle_checked,
            "hash_moved_edges": hash_moved,
            "hash_moved_bytes": hash_moved * EDGE_BYTES,
            "hash_moved_frac": hash_moved / n,
            "hash_repack_us": hash_s * 1e6,
        }
        record["sweep"].append(row)
        emit(f"rescale/cep/k{k}->{k_new}", row["cep_exec_us"],
             f"moved={stats.migrated_edges};frac={row['cep_moved_frac']:.3f};"
             f"ops={stats.copy_ops};total_us={row['cep_total_us']:.0f}")
        emit(f"rescale/hash/k{k}->{k_new}", row["hash_repack_us"],
             f"moved={hash_moved};frac={row['hash_moved_frac']:.3f}")

    # ---- acceptance round-trip: 8 → 12 → 8, bit-identical both ways -------
    d8 = E.pack_ordered(src, dst, g.num_vertices, 8)
    plan_out = cep.scale_plan(n, 8, 12)
    d12, s_out = rescaler.execute(d8, plan_out, verify=True)
    back, s_in = rescaler.rescale(d12, 8, verify=True)
    orig = E.pack_ordered(src, dst, g.num_vertices, 8)
    identical = bool(
        np.array_equal(np.asarray(back.edges), np.asarray(orig.edges))
        and np.array_equal(np.asarray(back.mask), np.asarray(orig.mask))
    )
    thm2 = cep.migration_cost_theorem2(n, 8, 4)
    # Thm. 2 is a closed-form approximation with O(k) rounding slack; the
    # executed copies must sit within that slack of the prediction.
    within_thm2 = s_out.migrated_edges <= thm2 + (plan_out.k_old + plan_out.k_new)
    record["roundtrip_8_12_8"] = {
        "bit_identical": identical,
        "out_moved_edges": s_out.migrated_edges,
        "in_moved_edges": s_in.migrated_edges,
        "thm2_predicted_edges": thm2,
        "within_thm2_prediction": bool(within_thm2),
        "hash_frac_k8_x4": cep.migration_cost_random(n, 8, 4) / n,
        "out_exec_us": s_out.elapsed_s * 1e6,
        "in_exec_us": s_in.elapsed_s * 1e6,
    }
    assert identical, "round trip must be bit-identical to the original pack"
    assert within_thm2, (s_out.migrated_edges, thm2)
    emit("rescale/roundtrip/8-12-8", s_out.elapsed_s * 1e6,
         f"bit_identical={identical};moved={s_out.migrated_edges};thm2={thm2:.0f}")

    # ---- forced-8-device mode: the same plans as on-mesh migrations --------
    md = _spawn_multidevice(scale, edge_factor)
    if md is not None:
        record["multidevice"] = md
        for row in md["sweep"]:
            emit(
                f"rescale/mesh8/k{row['k_old']}->{row['k_new']}",
                row["exec_us"],
                f"cross_dev_bytes={row['cross_device_bytes']};"
                f"on_dev_edges={row['on_device_edges']};"
                f"max_dev_ops={max(d['copy_ops'] for d in row['per_device'])}",
            )

    record["peak_rss_mb"] = round(peak_rss_mb(), 1)
    with open(out_path, "w") as fh:
        json.dump(record, fh, indent=2)
        fh.write("\n")
    return record


def run_multidevice(scale: int = 12, edge_factor: int = 12) -> dict:
    """Sharded-path sweep; must run in a process that already sees >= 8
    devices (the parent spawns one via _spawn_multidevice)."""
    import jax

    from repro.launch import mesh as MM
    from repro.launch import sharding as SH

    g = bench_graph(scale, edge_factor)
    order = ordering.geo_order(g, seed=0)
    src, dst = g.src[order], g.dst[order]
    n = g.num_edges
    ndev = 8
    assert len(jax.devices()) >= ndev, "run via the parent (forces 8 host devices)"
    mesh = MM.make_graph_mesh(ndev)
    rescaler = ElasticRescaler()
    out = {"devices": ndev, "sweep": []}

    # 8→12→8 is the acceptance pair; 12→20 exercises k ∤ devices with a
    # genuine on-device/cross-device split; 5→9 starts below the device count.
    for k_old, k_new in [(8, 12), (12, 8), (12, 20), (5, 9)]:
        plan = cep.scale_plan(n, k_old, k_new)
        best = None
        for _ in range(3):
            sdata = E.pack_ordered_sharded(src, dst, g.num_vertices, k_old, mesh)
            _, stats = rescaler.execute(sdata, plan, verify=True)
            best = stats if best is None or stats.elapsed_s < best.elapsed_s else best
        # Per-device program size: copy ops landing on each device and the
        # bytes they write (stays + local shifts are shard-local; moves whose
        # endpoints share a device never touch the interconnect).
        per_dev = [
            {"device": d, "copy_ops": 0, "bytes_written": 0, "recv_bytes": 0}
            for d in range(ndev)
        ]
        for lo, hi, s, d in plan_segments(plan):
            dev = SH.partition_device(d, ndev)
            per_dev[dev]["copy_ops"] += 1
            per_dev[dev]["bytes_written"] += (hi - lo) * EDGE_BYTES
            if SH.partition_device(s, ndev) != dev:
                per_dev[dev]["recv_bytes"] += (hi - lo) * EDGE_BYTES
        k_pad_new = SH.padded_partition_count(k_new, ndev)
        e_max_new = int(np.diff(cep.chunk_bounds(n, k_new)).max())
        out["sweep"].append({
            "k_old": k_old, "k_new": k_new,
            "migrated_edges": best.migrated_edges,
            "migrated_bytes": best.migrated_bytes,
            "cross_device_edges": best.cross_device_edges,
            "cross_device_bytes": best.cross_device_bytes,
            "on_device_edges": best.on_device_edges,
            "cross_device_equals_thm2": bool(
                best.cross_device_bytes == plan.migrated_bytes(EDGE_BYTES)
            ),
            "bit_identical_to_scratch": best.oracle_checked,
            "exec_us": best.elapsed_s * 1e6,
            "copy_ops": best.copy_ops,
            "per_device_shard_bytes": (k_pad_new // ndev) * e_max_new * EDGE_BYTES,
            "per_device": per_dev,
        })
    return out


def _spawn_multidevice(scale: int, edge_factor: int):
    """Run run_multidevice in a child with 8 forced host devices (XLA device
    count is fixed at import, so the parent can't widen its own platform)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_rescale_exec", _CHILD_FLAG,
         str(scale), str(edge_factor)],
        capture_output=True, text=True, timeout=600, env=env, cwd=root,
    )
    if r.returncode != 0:
        emit("rescale/mesh8/FAILED", 0.0, (r.stderr or r.stdout).strip()[-200:])
        return None
    for line in r.stdout.splitlines():
        if line.startswith(_JSON_MARK):
            return json.loads(line[len(_JSON_MARK):])
    return None


if __name__ == "__main__":
    if _CHILD_FLAG in sys.argv:
        i = sys.argv.index(_CHILD_FLAG)
        md_record = run_multidevice(int(sys.argv[i + 1]), int(sys.argv[i + 2]))
        print(_JSON_MARK + json.dumps(md_record))
    else:
        run()
