"""Executed-migration cost: ElasticRescaler (CEP overlay range copies) vs a
full hash repartition, across k ∈ {4…128} on the quickstart graph; plus the
acceptance round-trip k=8 → 12 → 8 with bit-identity and Thm.-2 checks.

Emits the usual ``name,us_per_call,derived`` CSV and writes the full record
to BENCH_rescale.json (committed — the repo's evidence that rescaling moves
only the theorem-predicted ranges, not ≈ k/(k+x)·|E| like hashing).
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import baselines, cep, ordering
from repro.elastic.rescale_exec import EDGE_BYTES, ElasticRescaler
from repro.graphs import engine as E

from .common import bench_graph, emit


def _hash_baseline(g, k_old, k_new, seed=0):
    """Hash repartition k_old → k_new: count relabeled edges and time a full
    repack (there is no incremental path — every moved edge is re-placed)."""
    p0 = baselines.hash_1d(g, k_old, seed)
    p1 = baselines.hash_1d(g, k_new, seed)
    moved = int(np.sum(p0 != p1))
    t0 = time.perf_counter()
    E.build_engine_data(g, p1, k_new)
    return moved, time.perf_counter() - t0


def _best_exec(rescaler, pack, plan, repeats=3):
    """Min-of-N executed migration; repack each round so donation semantics
    stay honest on backends that actually invalidate the donated buffer."""
    best = None
    for _ in range(repeats):
        _, stats = rescaler.execute(pack(), plan, verify=True)
        best = stats if best is None or stats.elapsed_s < best.elapsed_s else best
    return best


def run(scale: int = 12, edge_factor: int = 12, out_path: str = "BENCH_rescale.json") -> dict:
    g = bench_graph(scale, edge_factor)  # == examples/quickstart.py's graph
    order = ordering.geo_order(g, seed=0)
    src, dst = g.src[order], g.dst[order]
    n = g.num_edges
    rescaler = ElasticRescaler()
    record = {
        "graph": {"rmat_scale": scale, "edge_factor": edge_factor, "seed": 0,
                  "num_vertices": g.num_vertices, "num_edges": n},
        "edge_bytes": EDGE_BYTES,
        "sweep": [],
    }

    for k in (4, 8, 16, 32, 64, 128):
        k_new = k + 1  # the paper's elasticity step (Cor. 1: ≈ |E|/2 moves)
        plan = cep.scale_plan(n, k, k_new)
        pack = lambda: E.pack_ordered(src, dst, g.num_vertices, k)
        stats = _best_exec(rescaler, pack, plan)
        hash_moved, hash_s = _hash_baseline(g, k, k_new)
        row = {
            "k_old": k, "k_new": k_new,
            "cep_moved_edges": stats.migrated_edges,
            "cep_moved_bytes": stats.migrated_bytes,
            "cep_moved_frac": stats.migrated_edges / n,
            "cep_exec_us": stats.elapsed_s * 1e6,
            "cep_recheck_us": stats.recheck_s * 1e6,  # host metrics re-check + oracle
            "cep_total_us": (stats.elapsed_s + stats.recheck_s) * 1e6,
            "cep_copy_ops": stats.copy_ops,
            "bit_identical_to_scratch": stats.oracle_checked,
            "hash_moved_edges": hash_moved,
            "hash_moved_bytes": hash_moved * EDGE_BYTES,
            "hash_moved_frac": hash_moved / n,
            "hash_repack_us": hash_s * 1e6,
        }
        record["sweep"].append(row)
        emit(f"rescale/cep/k{k}->{k_new}", row["cep_exec_us"],
             f"moved={stats.migrated_edges};frac={row['cep_moved_frac']:.3f};"
             f"ops={stats.copy_ops};total_us={row['cep_total_us']:.0f}")
        emit(f"rescale/hash/k{k}->{k_new}", row["hash_repack_us"],
             f"moved={hash_moved};frac={row['hash_moved_frac']:.3f}")

    # ---- acceptance round-trip: 8 → 12 → 8, bit-identical both ways -------
    d8 = E.pack_ordered(src, dst, g.num_vertices, 8)
    plan_out = cep.scale_plan(n, 8, 12)
    d12, s_out = rescaler.execute(d8, plan_out, verify=True)
    back, s_in = rescaler.rescale(d12, 8, verify=True)
    orig = E.pack_ordered(src, dst, g.num_vertices, 8)
    identical = bool(
        np.array_equal(np.asarray(back.edges), np.asarray(orig.edges))
        and np.array_equal(np.asarray(back.mask), np.asarray(orig.mask))
    )
    thm2 = cep.migration_cost_theorem2(n, 8, 4)
    # Thm. 2 is a closed-form approximation with O(k) rounding slack; the
    # executed copies must sit within that slack of the prediction.
    within_thm2 = s_out.migrated_edges <= thm2 + (plan_out.k_old + plan_out.k_new)
    record["roundtrip_8_12_8"] = {
        "bit_identical": identical,
        "out_moved_edges": s_out.migrated_edges,
        "in_moved_edges": s_in.migrated_edges,
        "thm2_predicted_edges": thm2,
        "within_thm2_prediction": bool(within_thm2),
        "hash_frac_k8_x4": cep.migration_cost_random(n, 8, 4) / n,
        "out_exec_us": s_out.elapsed_s * 1e6,
        "in_exec_us": s_in.elapsed_s * 1e6,
    }
    assert identical, "round trip must be bit-identical to the original pack"
    assert within_thm2, (s_out.migrated_edges, thm2)
    emit("rescale/roundtrip/8-12-8", s_out.elapsed_s * 1e6,
         f"bit_identical={identical};moved={s_out.migrated_edges};thm2={thm2:.0f}")

    with open(out_path, "w") as fh:
        json.dump(record, fh, indent=2)
        fh.write("\n")
    return record


if __name__ == "__main__":
    run()
