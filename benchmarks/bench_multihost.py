"""Multi-host rescale cost on a real 2-process localhost cluster.

Spawns a 2-process × 4-device ``jax.distributed`` group
(``launch.multihost.spawn_local_cluster``) and executes ScalePlans on the
global ``graph`` mesh, so cross-device migrations cross an actual process
boundary (gloo collectives on CPU — the same code path a multi-NIC cluster
takes, minus the physical wire). Records, per (k_old → k_new):

* plan latency (the O(k) overlay) and executed program latency;
* migrated bytes vs the Thm.-2 closed form — the paper's headline bound;
* ``cross_process_bytes`` — the subset of Thm.-2 bytes that is genuinely the
  *network bill*, vs same-host device copies (for one-partition-per-device
  rescales every migrated byte crosses devices, and the process split is
  decided purely by the partition→process map);
* a streaming section: per-batch ingest latency on the 2-process mesh and one
  rescale-under-ingest with its cross-process traffic.

Writes BENCH_multihost.json (committed) and emits the usual CSV lines.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from repro.core import cep, ordering
from repro.elastic.rescale_exec import EDGE_BYTES, ElasticRescaler

from .common import bench_graph, emit, emit_peak_rss, parse_peak_rss, peak_rss_mb

_JSON_MARK = "MULTIHOST-JSON:"
N_PROCS = 2
DEVS_PER_PROC = 4
SCALE, EDGE_FACTOR = 12, 12


def run_child() -> dict:
    """Executes the sweep inside one process of the spawned cluster."""
    from repro.launch import multihost as MH

    spec = MH.initialize_from_env()
    import jax

    from repro.graphs import engine as E
    from repro.launch import mesh as MM
    from repro.launch import sharding as SH
    from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream

    assert spec is not None, "run via the parent (python -m benchmarks.bench_multihost)"
    g = bench_graph(SCALE, EDGE_FACTOR)
    order = ordering.geo_order(g, seed=0)
    src, dst = g.src[order], g.dst[order]
    n = g.num_edges
    mesh = MM.make_graph_mesh()
    ndev = len(jax.devices())
    rescaler = ElasticRescaler()
    out = {
        "processes": jax.process_count(),
        "devices": ndev,
        "devs_per_proc": ndev // jax.process_count(),
        "device_process_map": SH.device_process_map(mesh).tolist(),
        "graph": {"rmat_scale": SCALE, "edge_factor": EDGE_FACTOR, "seed": 0,
                  "num_vertices": g.num_vertices, "num_edges": n},
        "edge_bytes": EDGE_BYTES,
        "sweep": [],
    }

    for k_old, k_new in [(8, 12), (12, 8), (12, 20), (5, 9)]:
        t0 = time.perf_counter()
        plan = cep.scale_plan(n, k_old, k_new)
        plan_s = time.perf_counter() - t0
        best = None
        for _ in range(3):
            sdata = E.pack_ordered_sharded(src, dst, g.num_vertices, k_old, mesh)
            _, stats = rescaler.execute(sdata, plan, recheck=False)
            best = stats if best is None or stats.elapsed_s < best.elapsed_s else best
        x = k_new - k_old
        thm2 = cep.migration_cost_theorem2(n, k_old, x) if x > 0 else None
        out["sweep"].append({
            "k_old": k_old, "k_new": k_new,
            "plan_us": plan_s * 1e6,
            "exec_us": best.elapsed_s * 1e6,
            "migrated_edges": best.migrated_edges,
            "migrated_bytes": best.migrated_bytes,
            "thm2_predicted_edges": thm2,
            "within_thm2_prediction": (
                None if thm2 is None
                else bool(best.migrated_edges <= thm2 + (k_old + k_new))
            ),
            "cross_device_edges": best.cross_device_edges,
            "cross_device_bytes": best.cross_device_bytes,
            "cross_process_edges": best.cross_process_edges,
            "cross_process_bytes": best.cross_process_bytes,
            "cross_process_frac_of_migrated": (
                best.cross_process_edges / max(best.migrated_edges, 1)
            ),
            "one_partition_per_device": k_old == ndev,
        })

    # Streaming on the 2-process mesh: ingest cadence + rescale-under-ingest.
    o = IncrementalOrderer(
        src.astype(np.int64), dst.astype(np.int64), g.num_vertices, regions=8
    )
    eng = StreamingEngine(o, mesh)
    stream = SyntheticStream(g, batch_size=256, seed=1)
    ingest_s = []
    for _ in range(4):
        st = eng.ingest(stream.batch())
        ingest_s.append(st.elapsed_s)
    rs = eng.rescale(12)
    out["stream"] = {
        "batch_size": 256,
        "ingest_us_per_batch": [s * 1e6 for s in ingest_s],
        "rescale": {
            "k_old": rs.k_old, "k_new": rs.k_new,
            "moved_edges": rs.moved_edges,
            "cep_plan_edges": rs.cep_plan_edges,
            "cross_device_bytes": rs.cross_device_bytes,
            "cross_process_bytes": rs.cross_process_bytes,
            "exec_us": rs.elapsed_s * 1e6,
        },
    }
    eng.verify_bit_identity()
    out["stream"]["bit_identical_to_host_oracle"] = True
    return out


def run(out_path: str = "BENCH_multihost.json") -> dict | None:
    from repro.launch import multihost as MH

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env_extra = {
        "PYTHONPATH": os.path.join(root, "src")
        + os.pathsep
        + os.environ.get("PYTHONPATH", "")
    }
    res = MH.spawn_local_cluster(
        N_PROCS,
        DEVS_PER_PROC,
        ["-m", "benchmarks.bench_multihost", "--child"],
        timeout=900.0,
        env_extra=env_extra,
        cwd=root,
    )
    if not res.ok:
        emit("multihost/FAILED", 0.0, res.format_logs()[-200:].replace("\n", " "))
        print(res.format_logs(), file=sys.stderr)
        return None
    record = None
    for line in res.procs[0].stdout.splitlines():
        if line.startswith(_JSON_MARK):
            record = json.loads(line[len(_JSON_MARK):])
    assert record is not None, "child emitted no JSON record"
    for row in record["sweep"]:
        emit(
            f"multihost/rescale/k{row['k_old']}->{row['k_new']}",
            row["exec_us"],
            f"plan_us={row['plan_us']:.0f};"
            f"xproc_bytes={row['cross_process_bytes']};"
            f"xdev_bytes={row['cross_device_bytes']};"
            f"migrated={row['migrated_edges']}",
        )
    emit(
        "multihost/stream/ingest",
        float(np.mean(record["stream"]["ingest_us_per_batch"])),
        f"rescale_xproc_bytes={record['stream']['rescale']['cross_process_bytes']}",
    )
    record["peak_rss_mb"] = {
        "parent": round(peak_rss_mb(), 1),
        "per_process": [parse_peak_rss(p.stdout) for p in res.procs],
    }
    with open(out_path, "w") as fh:
        json.dump(record, fh, indent=2)
        fh.write("\n")
    return record


if __name__ == "__main__":
    if "--child" in sys.argv:
        print(_JSON_MARK + json.dumps(run_child()), flush=True)
        emit_peak_rss()
    else:
        run()
