"""Out-of-core pipeline benchmark — the ISSUE-7 acceptance.

Drives tests/outofcore_harness.py (the same worker the 2-process acceptance
test byte-verifies against the in-core oracle) at 2^23+-candidate scale on a
real 2-process ``jax.distributed`` cluster, and records in
``BENCH_outofcore.json``:

* ``scale``   — the run's plan (vertices, candidate edges, live edges after
                self-loop drop, shard/chunk geometry) plus the duplicate
                fraction the hierarchical order carries along;
* ``preprocess`` — per-phase wall (rank sample, shard-streamed commit) and
                end-to-end edges/s for the slowest process: the number the
                "time-efficient" in the paper title is about;
* ``rescale`` — the 8 → 12 → 8 on-mesh rescales executed on the committed
                pack, with cross-process byte movement;
* ``stream``  — the spill-bounded ingest tail (resident regions, spill /
                fault counters from the IngestEvents);
* ``memory``  — per-process peak RSS (``PEAK_RSS_MB:`` markers parsed from
                the worker logs) vs the MEASURED in-core reference (a fresh
                subprocess materializing the full deduped edge list and
                running sequential geo_order on it — the pipeline this PR
                replaces), and the ``rss_bounded`` gate CI's
                check_regression re-asserts: every worker stayed under half
                the in-core reference (floored by the jax baseline, capped
                by an absolute ceiling);
* ``quality`` — the small-scale RF differential of the exact distributed
                composition (stride sample → hierarchical order) against the
                sequential in-core geo_order oracle, worst ratio over seeds
                {0, 1, 7} × k ∈ {4 … 128} (acceptance: ≤ 1.10). Quality is a
                pure function of (plan, config), proven byte-identical to
                the cluster's output by tests/test_outofcore.py, so it is
                measured at a scale where the oracle is cheap.

``--smoke`` runs a scaled-down cluster (and a single-seed differential) and
prints the table without writing the artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import numpy as np

from repro.core import hier_order as HO
from repro.core.graph import Graph
from repro.core.metrics import replication_factor_ordered
from repro.core.ordering import geo_order
from repro.data import shards as DS
from repro.launch import multihost as MH

from .common import emit, parse_peak_rss

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HARNESS = os.path.join(ROOT, "tests", "outofcore_harness.py")
K_SET = (4, 8, 16, 32, 64, 128)

# The full-scale plan: 2^19 vertices x edge factor 17 = 8,912,896 candidate
# edges (> 2^23; at ef 16 the self-loop drop lands a few hundred edges SHORT
# of 2^23, so 16 would not honestly clear the "2^23+" bar). Chunk and sample
# sizes are the worker's memory knobs: host geo_order's working set is the
# RSS driver (~170 B/edge measured), so 2^20-edge chunks and a stride-16
# rank sample keep the per-worker peak around 1 GB where the measured
# in-core reference is ~2.5 GB.
FULL = dict(scale=19, ef=17, shards=16, chunks=4, stride=16,
            max_chunk=1 << 20)
SMOKE = dict(scale=13, ef=8, shards=4, chunks=4, stride=2, max_chunk=1 << 17)

# Per-worker peak-RSS gate: at most HALF of the measured in-core reference
# (one process deduping the full edge list and running sequential geo_order
# on it — the pipeline this PR replaces), with a floor where the jax+numpy
# baseline (~225 MB at toy scale) dominates and an absolute ceiling as a
# backstop against both measurements drifting up together.
RSS_BASELINE_MB = 256.0
RSS_INCORE_FRACTION = 0.5
RSS_CEILING_MB = 1536.0


def quality_differential(seeds, *, scale=12, ef=8, shards=4, stride=2, chunks=4):
    """Worst RF ratio of the distributed composition's order vs the in-core
    geo_order oracle, over seeds x K_SET — the same (plan, config) pipeline
    the cluster runs, at a scale where the sequential oracle is cheap."""
    cfg = HO.HierConfig(num_chunks=chunks, seam_window=0, seed=0)
    worst, table = 0.0, []
    for seed in seeds:
        plan = DS.RmatShardPlan(scale=scale, edge_factor=ef, seed=seed,
                                num_shards=shards)
        edges = np.concatenate(
            [DS.shard_edges(plan, s) for s in range(plan.num_shards)])
        ordered, _ = HO.hier_order_edges(
            edges, plan.num_vertices, cfg,
            sample=DS.sample_edges(plan, stride))
        key = edges[:, 0] * np.int64(plan.num_vertices) + edges[:, 1]
        _, first = np.unique(key, return_index=True)
        g = Graph.from_edges(edges[np.sort(first)], plan.num_vertices)
        o = geo_order(g, seed=0)
        so, do = g.src[o], g.dst[o]
        ratios = {}
        for k in K_SET:
            rf_h = replication_factor_ordered(ordered[:, 0], ordered[:, 1],
                                              k, plan.num_vertices)
            rf_o = replication_factor_ordered(so, do, k, plan.num_vertices)
            ratios[k] = rf_h / rf_o
        worst = max(worst, max(ratios.values()))
        table.append({"seed": seed,
                      "ratios": {str(k): round(r, 4) for k, r in ratios.items()}})
    return worst, table


def measure_incore_reference(p):
    """Peak RSS (MB) and geo wall of the in-core pipeline this PR replaces:
    ONE process materializes the full deduped edge list and runs sequential
    geo_order on it. Measured in a fresh subprocess so ru_maxrss is its own."""
    import subprocess
    import sys
    import time

    code = (
        "import sys, time, numpy as np\n"
        "sys.path.insert(0, 'src')\n"
        "from repro.core.graph import Graph\n"
        "from repro.core.ordering import geo_order\n"
        "from repro.data import shards as DS\n"
        "from benchmarks.common import emit_peak_rss\n"
        f"plan = DS.RmatShardPlan(scale={p['scale']}, edge_factor={p['ef']}, "
        f"seed=0, num_shards={p['shards']})\n"
        "edges = np.concatenate([DS.shard_edges(plan, s)"
        " for s in range(plan.num_shards)])\n"
        "key = edges[:, 0] * np.int64(plan.num_vertices) + edges[:, 1]\n"
        "g = Graph.from_edges("
        "edges[np.sort(np.unique(key, return_index=True)[1])],"
        " plan.num_vertices)\n"
        "t0 = time.perf_counter()\n"
        "geo_order(g, seed=0)\n"
        "print(f'GEO_S:{time.perf_counter() - t0:.1f}')\n"
        "emit_peak_rss()\n"
    )
    t0 = time.perf_counter()
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=ROOT, env={**os.environ, "PYTHONPATH": "src"})
    if r.returncode != 0:
        raise SystemExit(f"in-core reference run failed:\n{r.stderr[-2000:]}")
    rss = parse_peak_rss(r.stdout)
    geo_s = float(next(line.split(":", 1)[1] for line in r.stdout.splitlines()
                       if line.startswith("GEO_S:")))
    return rss, geo_s, time.perf_counter() - t0


def run_cluster(p, *, n_procs=2, devs_per_proc=4, timeout=540.0):
    """Spawn the out-of-core worker cluster at plan ``p``; return the per-
    process stat records and parsed peak-RSS markers."""
    out = tempfile.mkdtemp(prefix="bench_outofcore_")
    env = {
        "REPRO_OC_SCALE": p["scale"], "REPRO_OC_EF": p["ef"],
        "REPRO_OC_SHARDS": p["shards"], "REPRO_OC_CHUNKS": p["chunks"],
        "REPRO_OC_STRIDE": p["stride"], "REPRO_OC_SKIP_BLOCKS": 1,
        "REPRO_OC_MAX_CHUNK": p["max_chunk"],
    }
    res = MH.spawn_local_cluster(
        n_procs, devs_per_proc, [HARNESS, "--out", out],
        timeout=timeout, env_extra=env, cwd=ROOT)
    if not res.ok:
        print(res.format_logs())
        raise SystemExit("out-of-core worker cluster failed")
    records, rss = [], []
    for pid in range(n_procs):
        with open(os.path.join(out, f"proc{pid}.json")) as fh:
            records.append(json.load(fh))
        rss.append(parse_peak_rss(res.procs[pid].stdout))
    assert all(r is not None for r in rss), "worker missing PEAK_RSS_MB marker"
    assert records[0]["num_edges"] == records[-1]["num_edges"]
    return records, rss


def run(p, *, quality_seeds=(0, 1, 7), out_json="BENCH_outofcore.json"):
    records, rss = run_cluster(p)
    r0 = records[0]
    num_edges = r0["num_edges"]
    candidates = (1 << p["scale"]) * p["ef"]
    # Preprocess throughput is gated by the slowest process (they run the
    # collective phases together).
    pre_wall = max(r["wall"]["rank"] + r["wall"]["commit"] for r in records)
    edges_per_s = num_edges / pre_wall

    worst_ratio, table = quality_differential(quality_seeds)

    # Duplicate mass the hierarchical order carries along (dedup happens at
    # query time, not ingest) — measured in the parent, which is not under
    # the out-of-core RSS gate.
    plan = DS.RmatShardPlan(scale=p["scale"], edge_factor=p["ef"],
                            num_shards=p["shards"])
    full_keys = np.concatenate([
        DS.shard_edges(plan, s)[:, 0] * np.int64(plan.num_vertices)
        + DS.shard_edges(plan, s)[:, 1]
        for s in range(plan.num_shards)])
    duplicate_fraction = 1.0 - len(np.unique(full_keys)) / max(num_edges, 1)
    del full_keys

    incore_mb, incore_geo_s, incore_wall_s = measure_incore_reference(p)
    rss_limit = min(RSS_CEILING_MB,
                    max(RSS_BASELINE_MB, RSS_INCORE_FRACTION * incore_mb))
    rss_bounded = max(rss) <= rss_limit

    result = {
        "bench": "outofcore",
        "cluster": {"processes": r0["num_processes"], "devices": r0["devices"]},
        "scale": {
            "num_vertices": 1 << p["scale"],
            "candidate_edges": candidates,
            "num_edges": num_edges,
            "duplicate_fraction": round(duplicate_fraction, 4),
            "num_shards": p["shards"],
            "num_chunks": len(r0["chunk_sizes"]),
            "max_chunk_edges": p["max_chunk"],
            "chunk_sizes": r0["chunk_sizes"],
            "sample_stride": p["stride"],
        },
        "preprocess": {
            "wall_s": {ph: max(r["wall"][ph] for r in records)
                       for ph in ("rank", "commit")},
            "edges_per_s": round(edges_per_s, 1),
            # The in-core rival measured in the same bench run: sequential
            # geo_order on the full deduped edge list (order only — no
            # generation, no pack, no rescalable layout).
            "incore_geo_s": round(incore_geo_s, 1),
            "incore_total_s": round(incore_wall_s, 1),
        },
        "rescale": {
            "wall_s": max(r["wall"]["rescale"] for r in records),
            "up": r0["rescale"]["out"],
            "back": r0["rescale"]["in"],
        },
        "stream": dict(r0["stream"], wall_s=max(r["wall"]["stream"]
                                                for r in records)),
        "memory": {
            "peak_rss_mb_per_process": [round(x, 1) for x in rss],
            "rss_limit_mb": round(rss_limit, 1),
            "incore_reference_mb": round(incore_mb, 1),
            "incore_geo_s": round(incore_geo_s, 1),
            "rss_bounded": bool(rss_bounded),
        },
        "quality": {
            "differential_scale": 12,
            "seeds": list(quality_seeds),
            "table": table,
            "worst_ratio": round(worst_ratio, 4),
            "acceptance_rf_margin_1.10": worst_ratio <= 1.10,
        },
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1)
    emit("outofcore/preprocess", pre_wall * 1e6, f"edges_per_s={edges_per_s:.0f}")
    emit("outofcore/rescale_roundtrip", result["rescale"]["wall_s"] * 1e6,
         f"cross_process_bytes={r0['rescale']['out']['cross_process_bytes']}")
    emit("outofcore/peak_rss", 0.0,
         f"mb={max(rss):.0f} incore_ref={incore_mb:.0f}")
    emit("outofcore/rf_worst_ratio", 0.0, f"ratio={worst_ratio:.3f}")
    assert result["quality"]["acceptance_rf_margin_1.10"], (
        f"RF drifted to {worst_ratio:.3f}x oracle")
    assert result["memory"]["rss_bounded"], (
        f"worker peak RSS {max(rss):.0f} MB breaks the out-of-core bound")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down cluster + single-seed differential; "
                         "print the table, no JSON")
    args = ap.parse_args()
    if args.smoke:
        run(SMOKE, quality_seeds=(0,), out_json=None)
    else:
        run(FULL)


if __name__ == "__main__":
    main()
