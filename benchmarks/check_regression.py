"""Bench-regression gate over COMMITTED benchmark artifacts.

CI reruns benchmarks only as smokes — the committed BENCH_*.json records are
the performance baseline of record. This checker re-asserts the acceptance
gates that those records claim, so a PR that edits an artifact (or regresses
the code that regenerates one and commits the new numbers) fails loudly
instead of silently shipping a worse baseline:

* ``amortized.issue_target_within_3x_ingest`` must be true — the streaming
  ladder's amortized batch wall stays within 3× of pure ingest.
* ``quality.worst_ratio`` ≤ 1.10 — incremental order quality stays within
  the RF acceptance margin of the from-scratch GEO oracle at every
  checkpoint.
* ``observability.overhead_within_2pct`` must be true — span tracing inside
  the monitored stream costs < 2% of the amortized batch wall.

A ``trace.json`` argument is gated on Chrome-trace WELL-FORMEDNESS instead
(``repro.obs.trace_export.validate_chrome_trace`` over the multidevice
smoke's freshly exported span timeline).

Exit code 0 = all gates hold; 1 = a gate failed or the artifact is missing
a gated field (a silently dropped gate is a failure, not a pass).

Usage: ``python -m benchmarks.check_regression [BENCH_stream.json ...]``
"""
from __future__ import annotations

import json
import sys

DEFAULT_ARTIFACTS = ["BENCH_stream.json"]


def _get(record: dict, dotted: str):
    cur = record
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def check_stream(record: dict) -> list[str]:
    """Gate failures (empty = pass) for a BENCH_stream.json record."""
    failures = []
    within3x = _get(record, "amortized.issue_target_within_3x_ingest")
    if within3x is None:
        failures.append("amortized.issue_target_within_3x_ingest: missing")
    elif within3x is not True:
        failures.append(
            "amortized.issue_target_within_3x_ingest is false "
            f"(mean batch wall {_get(record, 'amortized.mean_batch_wall_ms')}ms "
            f"vs ingest median {_get(record, 'ingest.median_ms')}ms)"
        )
    worst = _get(record, "quality.worst_ratio")
    if worst is None:
        failures.append("quality.worst_ratio: missing")
    elif float(worst) > 1.10:
        failures.append(f"quality.worst_ratio {worst} > 1.10")
    within2 = _get(record, "observability.overhead_within_2pct")
    if within2 is None:
        failures.append("observability.overhead_within_2pct: missing")
    elif within2 is not True:
        failures.append(
            "observability.overhead_within_2pct is false (tracing cost "
            f"{_get(record, 'observability.overhead_frac_of_batch_wall')} "
            "of the amortized batch wall)"
        )
    return failures


def check_serve(record: dict) -> list[str]:
    """Gate failures for a BENCH_serve.json record: the autoscaler must have
    proven hysteresis (≥ 2 decisions each direction, zero flap pairs), the
    serving SLO must hold, and the pack must have stayed byte-identical to
    the oracle through every policy-driven rescale."""
    failures = []
    outs = _get(record, "autoscaler.scale_outs")
    ins = _get(record, "autoscaler.scale_ins")
    if outs is None or ins is None:
        failures.append("autoscaler.scale_outs/scale_ins: missing")
    elif int(outs) < 2 or int(ins) < 2:
        failures.append(f"autoscaler moved k only {outs} out / {ins} in (need >= 2 each)")
    flaps = _get(record, "autoscaler.flap_pairs")
    if flaps is None:
        failures.append("autoscaler.flap_pairs: missing")
    elif int(flaps) != 0:
        failures.append(f"autoscaler.flap_pairs {flaps} != 0")
    frac = _get(record, "latency.slo_frac")
    if frac is None:
        failures.append("latency.slo_frac: missing")
    elif float(frac) > 0.35:
        failures.append(f"latency.slo_frac {frac} > 0.35")
    p99 = _get(record, "latency.p99_s")
    slo = _get(record, "scenario.slo_s")
    if p99 is None or slo is None:
        failures.append("latency.p99_s / scenario.slo_s: missing")
    elif float(p99) > 3.0 * float(slo):
        failures.append(f"latency.p99_s {p99} > 3x SLO {slo}")
    ident = _get(record, "bit_identity.all_identical")
    if ident is None:
        failures.append("bit_identity.all_identical: missing")
    elif ident is not True:
        failures.append("bit_identity.all_identical is false")
    return failures


def check_trace(record: dict) -> list[str]:
    """Well-formedness gate for an exported Chrome-trace JSON (the CI
    multidevice smoke's trace.json artifact)."""
    from repro.obs.trace_export import validate_chrome_trace

    return validate_chrome_trace(record)


def check_outofcore(record: dict) -> list[str]:
    """Gate failures for a BENCH_outofcore.json record: the small-scale
    hierarchical-vs-in-core differential must hold on every tested graph,
    and no stage may have materialized the full edge list in one process."""
    failures = []
    worst = _get(record, "quality.worst_ratio")
    if worst is None:
        failures.append("quality.worst_ratio: missing")
    elif float(worst) > 1.10:
        failures.append(f"quality.worst_ratio {worst} > 1.10")
    bounded = _get(record, "memory.rss_bounded")
    if bounded is None:
        failures.append("memory.rss_bounded: missing")
    elif bounded is not True:
        failures.append("memory.rss_bounded is false")
    return failures


def check_recovery(record: dict) -> list[str]:
    """Gate failures for a BENCH_recovery.json record (ISSUE-10): recovery
    must be exact (bit identity), the detect → re-plan → restore → commit
    path must stay within a generous latency bound, and the partition-scoped
    restore bill must scale with LOST partitions, not |E| — every series
    point within npz-container slack of its lost-partition footprint."""
    failures = []
    if _get(record, "bit_identity") is not True:
        failures.append("bit_identity is not true — recovery diverged")
    total = _get(record, "recovery.total_s")
    if total is None:
        failures.append("recovery.total_s: missing")
    elif float(total) > 60.0:
        failures.append(f"recovery.total_s {total} > 60.0")
    series = _get(record, "restored_bytes")
    if not isinstance(series, list) or not series:
        failures.append("restored_bytes series: missing")
        return failures
    prev = -1
    for p in series:
        n, br, lb = p.get("lost_partitions"), p.get("bytes_read"), p.get("lost_bytes")
        if br is None or lb is None:
            failures.append(f"restored_bytes[{n}]: missing bytes fields")
            continue
        if p.get("bit_identity") is not True:
            failures.append(f"restored_bytes[{n}]: partition restore diverged")
        if float(br) > float(lb) * 1.5:
            failures.append(
                f"restored_bytes[{n}]: {br} B read > 1.5x the {lb} B lost "
                "(partition restore no longer scales with what was lost)"
            )
        if float(br) <= prev:
            failures.append(f"restored_bytes[{n}]: bytes not increasing with lost count")
        prev = float(br)
    k0 = _get(record, "config.k0")
    frac1 = series[0].get("frac_of_full_restore")
    if k0 and frac1 is not None and float(frac1) > 2.0 / float(k0):
        failures.append(
            f"restored_bytes[1]: {frac1} of a full restore exceeds 2/k0 — "
            "a single lost partition is paying for the whole graph"
        )
    return failures


CHECKERS = {
    "BENCH_stream.json": check_stream,
    "BENCH_recovery.json": check_recovery,
    "BENCH_outofcore.json": check_outofcore,
    "BENCH_serve.json": check_serve,
    "trace.json": check_trace,
}


def main(argv: list[str]) -> int:
    paths = argv or list(DEFAULT_ARTIFACTS)
    rc = 0
    for path in paths:
        name = path.rsplit("/", 1)[-1]
        checker = CHECKERS.get(name)
        if checker is None:
            print(f"{path}: no gates registered — nothing to check")
            continue
        try:
            with open(path) as f:
                record = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: FAIL — unreadable artifact ({e})")
            rc = 1
            continue
        failures = checker(record)
        if failures:
            rc = 1
            for msg in failures:
                print(f"{path}: FAIL — {msg}")
        else:
            print(f"{path}: OK")
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
