"""Shared benchmark helpers. Output contract: ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import time

import numpy as np


def timeit(fn, *, repeats: int = 3, number: int = 1) -> float:
    """Best-of wall time per call, in microseconds."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(number):
            fn()
        best = min(best, (time.perf_counter() - t0) / number)
    return best * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def bench_graph(scale: int = 12, edge_factor: int = 12, seed: int = 0):
    from repro.core.graph import rmat_graph

    return rmat_graph(scale, edge_factor, seed=seed)


# ---------------------------------------------------------------- peak RSS
# Every BENCH_*.json artifact records peak RSS so memory regressions (the
# out-of-core pipeline's whole point) are as visible as time regressions.
RSS_MARK = "PEAK_RSS_MB:"


def peak_rss_mb(include_children: bool = True) -> float:
    """Peak resident set size of this process (and, by default, the largest
    of its reaped children — covers spawn_local_cluster workers), in MiB.
    Linux ru_maxrss is KiB."""
    import resource

    peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    if include_children:
        peak = max(peak, resource.getrusage(resource.RUSAGE_CHILDREN).ru_maxrss)
    return peak / 1024.0


def emit_peak_rss() -> None:
    """Print this process's own peak RSS in the marker format cluster
    parents parse out of child logs (``parse_peak_rss``)."""
    print(f"{RSS_MARK}{peak_rss_mb(include_children=False):.1f}", flush=True)


def parse_peak_rss(text: str):
    """Largest ``PEAK_RSS_MB:`` marker in a child log, or None. The marker is
    searched WITHIN each line, not at line start: ``spawn_local_cluster``
    prefixes captured lines with the child's process index (``[p0] ``).
    New code should prefer ``repro.obs.metrics.record_peak_rss`` (per-process
    gauges through the metrics registry) over stdout-marker parsing."""
    best = None
    for line in str(text).splitlines():
        idx = line.find(RSS_MARK)
        if idx >= 0:
            val = float(line[idx + len(RSS_MARK):].strip())
            best = val if best is None else max(best, val)
    return best
