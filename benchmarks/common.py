"""Shared benchmark helpers. Output contract: ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import time

import numpy as np


def timeit(fn, *, repeats: int = 3, number: int = 1) -> float:
    """Best-of wall time per call, in microseconds."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(number):
            fn()
        best = min(best, (time.perf_counter() - t0) / number)
    return best * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def bench_graph(scale: int = 12, edge_factor: int = 12, seed: int = 0):
    from repro.core.graph import rmat_graph

    return rmat_graph(scale, edge_factor, seed=seed)
