"""MoE expert placement with GEO+CEP (beyond-paper application).

Builds an expert co-activation graph from a routing trace of the reduced
deepseek-moe model, GEO-orders experts, CEP-chunks them into EP groups, and
shows (i) less cross-group all-to-all mass than naive/shuffled placement and
(ii) O(1) elastic EP-group resize with minimal expert movement.

  PYTHONPATH=src python examples/expert_placement.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.elastic import expert_place as ep
from repro.models import model as M


def routing_trace(cfg, params, n_batches=8, b=4, s=32):
    """Collect top-k expert ids from the real router of layer 0."""
    rng = np.random.default_rng(0)
    router = np.asarray(params["layers"]["router"][0], np.float32)  # (D, E)
    embed = np.asarray(params["embed"], np.float32)
    ids = []
    for i in range(n_batches):
        toks = rng.integers(0, cfg.vocab_size, (b * s,))
        x = embed[toks]  # (T, D)
        logits = x @ router
        top = np.argsort(-logits, axis=1)[:, : cfg.experts_per_token]
        ids.append(top)
    return np.concatenate(ids)


def main() -> None:
    cfg = configs.get_smoke("deepseek-moe-16b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # A freshly-initialized router routes ~uniformly, so there is no
    # co-activation structure to exploit yet. Emulate a *trained* router whose
    # experts specialized in pairs (the structure GEO discovers in practice):
    # experts 2i and 2i+1 share a direction in embedding space.
    router = np.array(params["layers"]["router"][0], np.float32)  # writable copy
    rng = np.random.default_rng(7)
    for i in range(0, cfg.num_experts, 2):
        shared = rng.standard_normal(cfg.d_model) * 0.15
        router[:, i] += shared
        router[:, i + 1] += shared
    params["layers"]["router"] = params["layers"]["router"].at[0].set(jnp.asarray(router))
    trace = routing_trace(cfg, params)
    e = cfg.num_experts
    print(f"experts={e}, top-k={cfg.experts_per_token}, trace={trace.shape[0]} tokens")

    stats = np.zeros((e, e))
    for row in trace:
        for i in range(len(row)):
            for j in range(i + 1, len(row)):
                stats[row[i], row[j]] += 1
                stats[row[j], row[i]] += 1

    order = ep.order_experts(stats)
    k_groups = 4
    placed = ep.ExpertPlacement(order, k_groups)
    naive = ep.ExpertPlacement(np.arange(e), k_groups)
    shuffled = ep.ExpertPlacement(np.random.default_rng(1).permutation(e), k_groups)
    for name, pl in [("GEO+CEP", placed), ("default", naive), ("shuffled", shuffled)]:
        t = ep.cross_group_traffic(stats, pl)
        print(f"  {name:8s}: cross-group co-activation mass = {t:,.0f}")
    new_placed, moved = placed.rescale(k_groups + 1)
    print(f"elastic EP resize {k_groups}→{k_groups+1}: {moved} of {e} experts move "
          f"(hash placement would move ≈{e * k_groups // (k_groups+1)})")
    print("groups:", new_placed.groups())


if __name__ == "__main__":
    main()
