"""End-to-end elastic LM training with a simulated spot-instance preemption.

Trains a reduced qwen2-family model, checkpoints in CEP host chunks, then a
"preemption" removes a host mid-run: the controller emits a scale event, the
checkpoint is restored onto k-1 hosts via the CEP overlay plan (moving only
Thm.-2-minimal bytes), the data pipeline re-chunks its sample space, and
training resumes deterministically. Loss must keep decreasing across the
rescale.

  PYTHONPATH=src python examples/train_elastic.py [--steps 200]
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import store
from repro.data import pipeline as dp
from repro.elastic import controller as ec
from repro.models import model as M
from repro.train import optimizer as O
from repro.train import steps as S


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = configs.get_smoke("qwen2-1.5b")
    dc = dp.DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch)
    opt = O.OptConfig(peak_lr=1e-3, warmup_steps=20, total_steps=args.steps)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = O.init_opt_state(params)
    train_step = jax.jit(S.make_train_step(cfg, opt))

    k_hosts = 4
    ctl = ec.ElasticController(k_hosts, dead_after_s=2.0, state_elements=cfg.param_count())
    ckpt_dir = tempfile.mkdtemp(prefix="elastic_ckpt_")
    preempt_at = args.steps // 2
    losses = []

    step = 0
    while step < args.steps:
        # Hosts materialize their CEP data chunks; we emulate all of them.
        shards = [dp.host_batch(dc, step, ctl.k, h) for h in range(ctl.k)]
        batch = {
            "tokens": jnp.asarray(np.concatenate([s["tokens"] for s in shards])),
            "targets": jnp.asarray(np.concatenate([s["targets"] for s in shards])),
        }
        params, opt_state, metrics = train_step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        for h in range(ctl.k):
            ctl.heartbeat(h, step)

        if step == preempt_at:
            store.save({"params": params, "opt": opt_state}, ckpt_dir, step, k_shards=ctl.k)
            # Spot preemption: host (k-1) vanishes — stops heartbeating.
            import time as _t

            dead = max(ctl.hosts)
            print(f"step {step}: !! simulated preemption of host {dead}")
            t0 = ctl.clock()
            while ctl.clock() - t0 < 2.5:
                for h in range(ctl.k):
                    if h != dead:
                        ctl.heartbeat(h, step)
                _t.sleep(0.3)
            ev = ctl.poll()
            assert ev is not None and ev.kind == "scale_in"
            print(f"  controller: {ev.reason} → k={ev.k_new}; "
                  f"CEP plan moves {ev.plan_edges_moved_frac:.1%} of state "
                  f"(hash resharding would move {ev.k_old/(ev.k_old+1):.1%})")
            tree, moved = store.restore(
                ckpt_dir, step, k_new=ctl.k, template={"params": params, "opt": opt_state}
            )
            params, opt_state = tree["params"], tree["opt"]
            print(f"  restored step-{step} checkpoint onto {ctl.k} hosts "
                  f"({moved/1e6:.1f} MB crossed hosts)")
        if step % 25 == 0:
            print(f"step {step:4d} k={ctl.k} loss={losses[-1]:.4f}")
        step += 1

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss {first:.3f} → {last:.3f} across a mid-run rescale "
          f"({'OK: decreased' if last < first else 'FAILED to decrease'})")


if __name__ == "__main__":
    main()
