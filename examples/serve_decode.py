"""Batched serving demo: prefill a prompt batch, decode tokens step by step,
report tokens/s. Uses the reduced gemma3 config (sliding-window + global).

  PYTHONPATH=src python examples/serve_decode.py [--tokens 64]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--arch", default="gemma3-4b")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.tokens
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}

    prefill = jax.jit(lambda p, b, c: M.forward_prefill(p, cfg, b, c))
    decode = jax.jit(lambda p, t, c: M.forward_decode(p, cfg, t, c))

    cache = M.init_cache(cfg, args.batch, max_len)
    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch}×{args.prompt_len} tokens in {t_prefill*1e3:.1f}ms")

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.tokens - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    total = args.batch * (args.tokens - 1)
    print(f"decode: {total} tokens in {dt:.2f}s → {total/dt:,.0f} tok/s "
          f"(greedy, batch={args.batch})")
    seq = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print("sample token ids:", seq[0, :16].tolist())


if __name__ == "__main__":
    main()
