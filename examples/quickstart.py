"""Quickstart: the paper in 60 lines — order once, rescale forever.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.core import cep, metrics, ordering
from repro.core.graph import rmat_graph


def main() -> None:
    # 1. A skewed social-network-like graph (RMAT, ~100k edges).
    g = rmat_graph(scale=12, edge_factor=12, seed=0)
    print(f"graph: |V|={g.num_vertices:,} |E|={g.num_edges:,}")

    # 2. Preprocess ONCE: GEO orders edges so nearby edges share vertices.
    t0 = time.time()
    order = ordering.geo_order(g, k_min=4, k_max=128)
    print(f"GEO ordering: {time.time()-t0:.2f}s (one-time)")
    src, dst = g.src[order], g.dst[order]

    # 3. Partition to ANY k in O(1) — just chunk arithmetic.
    for k in (4, 16, 64, 128):
        t0 = time.time()
        bounds = cep.chunk_bounds(g.num_edges, k)
        dt_us = (time.time() - t0) * 1e6
        rf = metrics.replication_factor_ordered(src, dst, k, g.num_vertices)
        print(f"  k={k:4d}: partition computed in {dt_us:7.1f}us, RF={rf:.3f}")

    # 4. Elastic rescale 16 → 17 workers: move only the overlay ranges.
    plan = cep.scale_plan(g.num_edges, 16, 17)
    frac = plan.migrated_edges / g.num_edges
    print(f"rescale 16→17: move {plan.migrated_edges:,} edges "
          f"({frac:.1%}; hash-based would move {16/17:.1%})")
    # Corollary 1: ≈ |E|/2 for x=1.
    print(f"Cor.1 check: moved≈|E|/2 → {plan.migrated_edges / (g.num_edges/2):.3f}")

    # 5. EXECUTE the rescale on device: the plan's ranges become batched
    #    slice copies over the packed (k, E_max, 2) engine buffers.
    from repro.elastic.rescale_exec import ElasticRescaler
    from repro.graphs import engine as E

    rescaler = ElasticRescaler()
    rescaler.execute(E.pack_ordered(src, dst, g.num_vertices, 16), plan)  # warm the jit
    data = E.pack_ordered(src, dst, g.num_vertices, 16)
    new_data, stats = rescaler.execute(data, plan, verify=True)
    print(f"executed 16→17 in {stats.elapsed_s*1e3:.2f}ms: "
          f"{stats.migrated_bytes:,}B over {stats.copy_ops} slice copies, "
          f"bit-identical to a from-scratch k=17 pack (RF={new_data.replication_factor:.3f})")

    # 6. STREAM updates while staying rescalable: incremental ordering on the
    #    host, scatter-based ingest on device, full-GEO quality oracle. The
    #    quality monitor's PARTIAL re-order rung also runs on-mesh: a cached
    #    span-repair program recomputes the degraded span's order from the
    #    sharded buffers and scatters it back, while the host advances its
    #    bookkeeping through the byte-exact numpy mirror — engine.monitor()
    #    below never ships a span re-upload (span_repair="host" restores the
    #    old behavior). (Full scenario + committed numbers:
    #    python -m benchmarks.run stream → BENCH_stream.json.)
    from repro.launch import mesh as MM
    from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream

    orderer = IncrementalOrderer(src, dst, g.num_vertices, regions=8)
    engine = StreamingEngine(orderer, MM.make_graph_mesh(1))
    stream = SyntheticStream(g, batch_size=256, seed=1)
    for _ in range(4):
        st = engine.ingest(stream.batch(), verify=True)
        engine.monitor()
    rs = engine.rescale(12, verify=True)
    rf_inc, rf_oracle = engine.rf_vs_oracle()
    print(f"streamed 4x256 updates (last batch {st.elapsed_s*1e3:.1f}ms, "
          f"bit-identical to host oracle), rescaled 8→12 live in "
          f"{rs.elapsed_s*1e3:.1f}ms; RF {rf_inc:.3f} vs full-GEO {rf_oracle:.3f} "
          f"({rf_inc/rf_oracle:.2f}x)")

    # 7. MULTI-HOST: the same rescale across a real jax.distributed process
    #    group — a 2-process localhost cluster; the reported cross_process
    #    bytes are what a real cluster pays on the network (DESIGN.md §10;
    #    full acceptance: tests/test_multihost.py, BENCH_multihost.json).
    from repro.launch.multihost import spawn_local_cluster

    worker = """
from repro.launch.multihost import initialize_from_env
spec = initialize_from_env()
import jax
from repro.core import cep, ordering
from repro.core.graph import rmat_graph
from repro.elastic.rescale_exec import ElasticRescaler
from repro.graphs import engine as E
from repro.launch import mesh as MM
g = rmat_graph(scale=8, edge_factor=6, seed=0)   # every process: same seed
order = ordering.geo_order(g, seed=0)
mesh = MM.make_graph_mesh()                      # spans both processes
data = E.pack_ordered_sharded(g.src[order], g.dst[order], g.num_vertices, 4, mesh)
_, stats = ElasticRescaler().rescale(data, 6, recheck=False)
print(f"proc {jax.process_index()}/{jax.process_count()}: 4->6 moved "
      f"{stats.migrated_bytes}B, {stats.cross_process_bytes}B across the "
      f"process boundary ({stats.devices} devices)")
"""
    res = spawn_local_cluster(2, 2, ["-c", worker], timeout=300.0)
    if res.ok:
        for p in res.procs:
            print(f"  {p.stdout.strip()}")
    else:  # e.g. a jaxlib without CPU collectives — the single-host story above stands
        print("  multi-host demo skipped (no localhost process-group support here)")

    # 8. ASYNC FULL REBUILD: when drift escalates past the partial rung, the
    #    whole-graph re-order runs as a device program against SHADOW buffers
    #    while ingest keeps landing on the live ones — dispatch, fly for
    #    rebuild_flight batches, then one commit batch splices the flight's
    #    delta onto the new order and swaps it live (DESIGN.md §11). Ingest
    #    never blocks for longer than that one commit. full_rebuild="host"
    #    restores the synchronous stop-the-world rung.
    orderer2 = IncrementalOrderer(src, dst, g.num_vertices, regions=8)
    engine2 = StreamingEngine(
        orderer2, MM.make_graph_mesh(1), full_rebuild="geo", rebuild_flight=2
    )
    stream2 = SyntheticStream(g, batch_size=256, seed=2)
    engine2.ingest(stream2.batch(), verify=True)
    orderer2.drift = lambda: 99.0  # force the top rung for the demo
    engine2.monitor()  # dispatch: returns immediately, rebuild in flight
    del orderer2.drift
    states = [engine2.rebuild_state]
    while engine2.rebuilds_in_flight:  # ingest continues UNDER the rebuild
        engine2.ingest(stream2.batch(), verify=True)
        engine2.monitor()
        states.append(engine2.rebuild_state)
    (rb,) = engine2.drain_rebuild_events()
    engine2.verify_bit_identity()
    print(f"async full rebuild: {' -> '.join(s or 'ingest' for s in states)}; "
          f"re-ordered {rb['snapshot_edges']:,} edges while {rb['flight_batches']} "
          f"batches kept ingesting, replayed {rb['replayed_batches']} onto the new "
          f"order as {rb['splice_ops']} splice ops "
          f"(dispatch {rb['dispatch_s']*1e3:.0f}ms async, "
          f"commit {rb['commit_s']*1e3:.0f}ms blocked)")

    # 9. OUT-OF-CORE PREPROCESS: past 2^23 edges the graph never exists as
    #    one array. The input is a stateless shard PLAN (any process
    #    regenerates any shard, or a strided sample, from the seed alone),
    #    the GEO order is hierarchical — rank from the sample, equal-LOAD
    #    chunk cuts (the load histogram is additive across shards), per-chunk
    #    GEO — and a worker holds ONE ordered chunk at a time. Small scale
    #    here so the in-core oracle is cheap to compare against; the 2^23+
    #    2-process acceptance lives in tests/test_outofcore.py +
    #    benchmarks/bench_outofcore.py (DESIGN.md §12).
    from repro.core import hier_order as HO
    from repro.data import shards as DS

    plan = DS.RmatShardPlan(scale=10, edge_factor=8, seed=0, num_shards=4)
    cfg = HO.HierConfig(num_chunks=4, seam_window=0, seed=0)
    sample = DS.sample_edges(plan, stride=2)
    rank = HO.locality_rank(sample, plan.num_vertices, cfg.seed)
    load = sum(HO.chunk_load(rank, DS.shard_edges(plan, s))
               for s in range(plan.num_shards))      # additive: psum on a cluster
    splits = HO.chunk_splits(load, cfg)

    def ordered_chunk(c):  # pure in (plan, rank, splits) — any worker, any chunk
        shards = [DS.shard_edges(plan, s) for s in range(plan.num_shards)]
        block = np.concatenate(
            [es[HO.chunk_of_edges(splits, rank, es) == c] for es in shards])
        return block[HO.order_edge_block(block, cfg, seed=cfg.seed + c)]

    ordered = np.concatenate([ordered_chunk(c) for c in range(cfg.num_chunks)])
    from repro.core.graph import Graph

    key = ordered[:, 0] * np.int64(plan.num_vertices) + ordered[:, 1]
    gg = Graph.from_edges(ordered[np.sort(np.unique(key, return_index=True)[1])],
                          plan.num_vertices)
    oo = ordering.geo_order(gg, seed=0)
    rf_h = metrics.replication_factor_ordered(ordered[:, 0], ordered[:, 1],
                                              16, plan.num_vertices)
    rf_o = metrics.replication_factor_ordered(gg.src[oo], gg.dst[oo],
                                              16, plan.num_vertices)
    print(f"out-of-core hierarchical order: {ordered.shape[0]:,} edges in "
          f"{cfg.num_chunks} chunks (workers hold 1 ordered chunk at a time), "
          f"RF@16 {rf_h:.3f} vs in-core GEO {rf_o:.3f} ({rf_h/rf_o:.3f}x)")

    # 10. OBSERVE the runtime: hand the engine a span tracer + metrics
    #     registry (both default OFF — a disabled tracer costs one branch per
    #     would-be span), run a stream, and dump a Chrome trace you can open
    #     in chrome://tracing or ui.perfetto.dev — one swimlane per phase
    #     (ingest / rung / rebuild / rescale / transfer), plus exact latency
    #     percentiles from the registry's histograms (DESIGN.md §13;
    #     benchmarks/bench_stream.py --trace does this for the full scenario,
    #     and on a multi-process mesh registry.snapshot_global(mesh) sums the
    #     metrics across every process with one collective).
    from repro.obs import MetricsRegistry, Tracer, chrome_trace, write_chrome_trace

    tracer = Tracer()
    registry = MetricsRegistry()
    orderer3 = IncrementalOrderer(src, dst, g.num_vertices, regions=8)
    engine3 = StreamingEngine(orderer3, MM.make_graph_mesh(1),
                              tracer=tracer, metrics_registry=registry)
    stream3 = SyntheticStream(g, batch_size=256, seed=3)
    for _ in range(4):
        engine3.ingest(stream3.batch())
        engine3.monitor()
    engine3.rescale(12)
    write_chrome_trace("/tmp/quickstart_trace.json", chrome_trace(tracer))
    pct = registry.percentiles("stream.ingest.batch_s")
    print(f"observability: {len(tracer)} spans -> /tmp/quickstart_trace.json "
          f"(open in ui.perfetto.dev); ingest p50 {pct['p50']*1e3:.1f}ms "
          f"p99 {pct['p99']*1e3:.1f}ms, "
          f"{int(registry.counter('stream.scatter_ops').value)} scatter ops")

    # 11. SERVE + AUTOSCALE: close the loop — a traffic-driven policy reads
    #     the registry (queue depth, event rate, windowed p99) and moves k
    #     through the controller while PageRank/SSSP/WCC queries run against
    #     the live pack between ingest batches. One virtual clock drives the
    #     workload, the controller, and the policy's cooldowns, so the whole
    #     trajectory is deterministic in (seed, config); queries survive every
    #     policy rescale bit-identically (DESIGN.md §14; the two-day diurnal
    #     scenario lives in benchmarks/bench_serve.py → BENCH_serve.json).
    from repro.elastic import autoscale as AS
    from repro.elastic import controller as EC
    from repro.launch import serve as SV
    from repro.stream.workload import OpenLoopWorkload

    reg4 = MetricsRegistry()
    orderer4 = IncrementalOrderer(src, dst, g.num_vertices, regions=2)
    engine4 = StreamingEngine(orderer4, MM.make_graph_mesh(1),
                              metrics_registry=reg4)
    ref = []
    ctl = EC.ElasticController(2, clock=lambda: ref[0].now if ref else 0.0,
                               metrics_registry=reg4)
    ctl.attach_stream(engine4)
    ctl.attach_autoscaler(AS.AutoscalePolicy(AS.AutoscaleConfig(
        k_min=2, k_max=8, queue_high_per_host=2.0, queue_low=0.5,
        ema=0.6, out_cooldown_s=4.0, in_cooldown_s=8.0)))
    workload = OpenLoopWorkload(num_vertices=g.num_vertices, base_rate=8.0,
                                day_ticks=32, diurnal_amp=0.8, seed=0)
    loop = SV.ServeLoop(ctl, workload,
                        updates=SyntheticStream(g, batch_size=64, seed=4),
                        registry=reg4, config=SV.ServeConfig(probe_every=8))
    ref.append(loop)
    loop.run(32)
    loop.drain()
    assert engine4.verify_bit_identity()
    s = loop.summary()
    print(f"serve+autoscale: {s['served']} queries over one virtual day, "
          f"k path {'->'.join(map(str, s['k_path']))} "
          f"({s['scale_outs']} out / {s['scale_ins']} in), "
          f"p50 {s['latency_p50_s']:.1f}s p99 {s['latency_p99_s']:.1f}s, "
          f"{s['slo_violations']} SLO misses; pack bit-identical through "
          f"every policy rescale")

    # 12. SURVIVE A PREEMPTION: a 2-process cluster streams updates with
    #     every process renewing a file lease per batch and process 0
    #     checkpointing every batch (chunked snapshot + WAL). We SIGKILL
    #     process 1 mid-stream — no goodbye — detect it from the parent by
    #     lease expiry (no collective in the detection path: the victim died
    #     HOLDING the collective plane), abandon the stranded group, restore
    #     from the checkpoint, and shrink k over the survivors through the
    #     controller (FailureEvent + scale_in on one seq log). The restored
    #     order is the pre-failure order byte-for-byte: recovery replays raw
    #     slot ops, it does not re-run placement (DESIGN.md §15; full drill:
    #     tests/test_faults.py, numbers: BENCH_recovery.json).
    import tempfile

    from repro.checkpoint import SlotCheckpoint
    from repro.launch.multihost import LeaseBoard, launch_local_cluster

    drill_dir = tempfile.mkdtemp(prefix="quickstart_drill_")
    victim_worker = f"""
from repro.launch.multihost import LeaseBoard, initialize_from_env
spec = initialize_from_env()
import time
import jax
import numpy as np
from repro.checkpoint import SlotCheckpoint
from repro.core import ordering
from repro.core.graph import rmat_graph
from repro.elastic import controller as EC
from repro.launch import mesh as MM
from repro.stream import IncrementalOrderer, StreamingEngine, SyntheticStream
g = rmat_graph(scale=8, edge_factor=6, seed=0)
order = ordering.geo_order(g, seed=0)
o = IncrementalOrderer(g.src[order].astype(np.int64), g.dst[order].astype(np.int64),
                       g.num_vertices, regions=4)
eng = StreamingEngine(o, MM.make_graph_mesh())
ctl = EC.ElasticController(4)
ctl.attach_stream(eng)
board = LeaseBoard({drill_dir!r} + "/leases", lease_s=1.0)
pid = jax.process_index()
if pid == 0:  # one durability writer: its orderer is a full replica
    ctl.attach_checkpoint(SlotCheckpoint({drill_dir!r} + "/ckpt", interval=2))
stream = SyntheticStream(g, batch_size=128, seed=5)
for step in range(40):
    ctl.ingest(stream.batch())
    board.stamp(pid, step)
    time.sleep(0.1)
"""
    cluster = launch_local_cluster(2, 2, ["-c", victim_worker])
    board = LeaseBoard(drill_dir + "/leases", lease_s=1.0)
    try:
        board.wait_for_step(1, 3, timeout=120.0)  # let the stream get going
        t_kill = time.time()
        cluster.kill(1, reason="simulated preemption")
        while 1 not in board.dead(2):
            time.sleep(0.05)
        detect_s = time.time() - t_kill
        cluster.kill(0, reason="stranded survivor abandoned with the group")
    except TimeoutError:  # no localhost process-group support here
        cluster.wait(10.0)
        print("  fault drill skipped (no localhost process-group support here)")
    else:
        cluster.wait(30.0)
        o5, info = SlotCheckpoint(drill_dir + "/ckpt", interval=2).restore()
        eng5 = StreamingEngine.from_restored(o5, MM.make_graph_mesh(1))
        ctl5 = EC.ElasticController(4)
        ctl5.attach_stream(eng5)
        fev, sev = ctl5.report_failure([2, 3], detect_s=detect_s,
                                       reason="process lease expired",
                                       restored_bytes=info["bytes_read"])
        eng5.verify_bit_identity()
        print(f"fault drill: killed p1 mid-stream, lease expired after "
              f"{detect_s:.2f}s; restored batch {info['step']} from snapshot "
              f"chunks + {info['replayed']} WAL records ({info['bytes_read']:,}B), "
              f"k {fev.k_old} -> {fev.k_new} over the survivors "
              f"(events: {' -> '.join(e.kind for e in ctl5.events)}); recovered "
              f"pack bit-identical to the host slot state")


if __name__ == "__main__":
    main()
