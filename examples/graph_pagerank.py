"""Distributed PageRank on GEO+CEP partitions vs hash partitions (paper §6.4).

  PYTHONPATH=src python examples/graph_pagerank.py
"""
import time

import numpy as np

from repro.core import baselines, ordering
from repro.core.graph import rmat_graph
from repro.graphs import engine as E
from repro.launch import mesh as MM


def main() -> None:
    g = rmat_graph(scale=12, edge_factor=10, seed=1)
    mesh = MM.make_test_mesh(1, 1)  # run with XLA_FLAGS=...device_count=8 for real shards
    k = 8
    print(f"graph: |V|={g.num_vertices:,} |E|={g.num_edges:,}, k={k}")

    order = ordering.geo_order(g)
    variants = {
        "GEO+CEP": E.cep_engine_data(g, order, k),
        "1D hash": E.build_engine_data(g, baselines.hash_1d(g, k), k),
        "2D grid": E.build_engine_data(g, baselines.hash_2d(g, k), k),
    }
    results = {}
    for name, data in variants.items():
        t0 = time.time()
        pr = E.pagerank(data, mesh, iterations=20)
        dt = time.time() - t0
        com = E.comm_volume_per_iteration(data)
        results[name] = np.asarray(pr)
        print(f"  {name:8s}: RF={data.replication_factor:5.2f} mirrors={data.mirrors:7,} "
              f"comm/iter={com/1e6:6.2f}MB time={dt:.2f}s")
    # Same answer regardless of partitioning:
    a, b = results["GEO+CEP"], results["1D hash"]
    print(f"max |Δpagerank| across partitionings: {np.abs(a-b).max():.2e}")


if __name__ == "__main__":
    main()
