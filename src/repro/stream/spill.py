"""Cold-region spill layer: bounded-resident host mirror of the slot array.

``IncrementalOrderer`` keeps the WHOLE slot mirror hot (dense arrays plus
per-edge dicts — O(|E|) host memory), which is exactly what an out-of-core
ingest path must not do. This module bounds the resident set at the region
granularity the slot layout already has:

* ``SpillStore`` holds at most ``max_resident`` region blocks in memory;
  the rest live serialized on disk (or in a cold byte store when no
  directory is given — same code path, for tests without tmpdirs). Eviction
  is least-recently-ESCALATED: ``touch`` bumps a region's clock when ingest
  lands in it or a repair escalates it, so the regions the stream is
  actively mutating stay hot and long-cold spans pay the fault only when an
  ingest actually returns to them.
* ``OutOfCoreIngestor`` is the lean ingest front-end over that store. A new
  edge is CONTENT-ADDRESSED: region = splitmix64(u·V + v) mod regions — a
  pure function of the edge, so insert and delete touch exactly one region
  block and a delete is an O(spr) scan of it, with no global edge→slot dict.
  Content addressing gives up GEO placement quality for the ingest tail —
  the hierarchical preprocess (core/hier_order.py) owns bulk quality; this
  path owns the stream-of-updates tail under a memory bound, the same
  split the escalation ladder already makes (DESIGN.md §9/§11).

Counters (``spill_counters``) ride on every IngestEvent the elastic
controller emits, so spill/fault traffic is visible in the same event log
as escalations and rebuilds.
"""
from __future__ import annotations

import dataclasses
import io
import os
import time
from typing import Optional

import numpy as np

from ..core.baselines import splitmix64
from ..obs import metrics as OM

__all__ = ["SpillConfig", "SpillStore", "OutOfCoreIngestor", "LeanIngestStats"]


@dataclasses.dataclass(frozen=True)
class SpillConfig:
    """``max_resident`` bounds hot region blocks (the memory knob);
    ``directory`` is the spill target — None keeps spilled bytes in a cold
    in-process store (identical control flow, no filesystem)."""

    max_resident: int = 4
    directory: Optional[str] = None

    def __post_init__(self):
        if self.max_resident < 1:
            raise ValueError("max_resident must be >= 1")


class SpillStore:
    """Region-block store with an LRU-by-escalation resident set.

    A block is the (src, dst, valid) slot triple of one region. Blocks are
    created zeroed on first access; ``get`` faults spilled blocks back in
    and counts it; ``evict_to_budget`` (called after every mutation burst)
    serializes the least-recently-escalated blocks out until the resident
    count is within budget."""

    def __init__(
        self,
        regions: int,
        slots_per_region: int,
        config: SpillConfig,
        *,
        metrics_registry=None,
    ):
        if regions < 1:
            raise ValueError("regions must be >= 1")
        if slots_per_region < 1:
            raise ValueError("slots_per_region must be >= 1")
        self.regions = int(regions)
        self.spr = int(slots_per_region)
        self.config = config
        # Observability: spill/fault traffic histograms (block sizes) on top
        # of the exact counters below; the registry's snapshot aggregates
        # them across processes (obs/metrics.py). Defaults to the inert
        # registry — zero cost when unused.
        self.metrics = OM.NULL if metrics_registry is None else metrics_registry
        self._m_spill_bytes = self.metrics.histogram("spill.spill_block_bytes", OM.BYTE_BUCKETS)
        self._m_fault_bytes = self.metrics.histogram("spill.fault_block_bytes", OM.BYTE_BUCKETS)
        self._hot: dict[int, tuple] = {}  # region → (src, dst, valid)
        self._cold: dict[int, bytes] = {}  # region → serialized block
        self._clock = 0
        self._last_touch: dict[int, int] = {}
        self.counters = {
            "spills": 0,
            "faults": 0,
            "bytes_spilled": 0,
            "bytes_faulted": 0,
        }
        if config.directory is not None:
            os.makedirs(config.directory, exist_ok=True)

    # ------------------------------------------------------------- byte store
    def _path(self, p: int) -> str:
        return os.path.join(self.config.directory, f"region_{p:06d}.npz")

    def _write_cold(self, p: int, blob: bytes) -> None:
        if self.config.directory is None:
            self._cold[p] = blob
        else:
            with open(self._path(p), "wb") as f:
                f.write(blob)
            self._cold[p] = b""  # presence marker; bytes live on disk

    def _read_cold(self, p: int) -> bytes:
        if self.config.directory is None:
            return self._cold.pop(p)
        del self._cold[p]
        path = self._path(p)
        with open(path, "rb") as f:
            blob = f.read()
        os.remove(path)
        return blob

    # ---------------------------------------------------------------- access
    def touch(self, p: int) -> None:
        """Bump region p's escalation clock (it was ingested into / repaired)
        WITHOUT faulting it in — recency is free to maintain for cold spans."""
        self._clock += 1
        self._last_touch[p] = self._clock

    def get(self, p: int) -> tuple:
        """The (src, dst, valid) block of region p, faulting it in if
        spilled, creating it zeroed if never written. Marks recency."""
        if not 0 <= p < self.regions:
            raise IndexError(f"region {p} outside [0, {self.regions})")
        self.touch(p)
        if p in self._hot:
            return self._hot[p]
        if p in self._cold:
            blob = self._read_cold(p)
            with np.load(io.BytesIO(blob)) as z:
                block = (z["src"].copy(), z["dst"].copy(), z["valid"].copy())
            self.counters["faults"] += 1
            self.counters["bytes_faulted"] += len(blob)
            self._m_fault_bytes.observe(len(blob))
        else:
            block = (
                np.zeros(self.spr, dtype=np.int64),
                np.zeros(self.spr, dtype=np.int64),
                np.zeros(self.spr, dtype=bool),
            )
        self._hot[p] = block
        return block

    @property
    def resident(self) -> int:
        return len(self._hot)

    def evict_to_budget(self) -> int:
        """Spill least-recently-escalated hot blocks until resident ≤
        ``max_resident``; returns how many spilled. All-invalid blocks are
        dropped, not serialized (an empty region has no bytes worth keeping)."""
        spilled = 0
        while len(self._hot) > self.config.max_resident:
            victim = min(self._hot, key=lambda q: self._last_touch.get(q, 0))
            src, dst, valid = self._hot.pop(victim)
            if not valid.any():
                continue
            buf = io.BytesIO()
            np.savez(buf, src=src, dst=dst, valid=valid)
            blob = buf.getvalue()
            self._write_cold(victim, blob)
            self.counters["spills"] += 1
            self.counters["bytes_spilled"] += len(blob)
            self._m_spill_bytes.observe(len(blob))
            spilled += 1
        return spilled


@dataclasses.dataclass(frozen=True)
class LeanIngestStats:
    """Shape-compatible subset of ``ingest.IngestStats`` — what the elastic
    controller reads when an OutOfCoreIngestor is the attached stream."""

    inserted: int
    deleted: int
    skipped: int
    scatter_ops: int
    resynced: bool
    elapsed_s: float
    num_edges: int


class OutOfCoreIngestor:
    """Bounded-memory streaming ingest over a SpillStore.

    Implements the attached-stream protocol the elastic controller speaks
    (``ingest``/``monitor``/``k``), with O(max_resident · spr) hot state:
    no edge→slot dict, no incident sets. Dedup within the hot/faulted region
    is exact (content addressing sends a duplicate to the same region);
    quality maintenance is delegated to the preprocess/escalation machinery,
    so ``monitor`` always answers "none".
    """

    def __init__(
        self,
        num_vertices: int,
        regions: int,
        slots_per_region: int,
        config: SpillConfig = SpillConfig(),
        *,
        metrics_registry=None,
    ):
        self.num_vertices = int(num_vertices)
        self.store = SpillStore(
            regions, slots_per_region, config, metrics_registry=metrics_registry
        )
        self._num_edges = 0
        self.last_repair = ""

    @property
    def k(self) -> int:
        return self.store.regions

    @property
    def num_edges(self) -> int:
        return self._num_edges

    def region_of(self, u: int, v: int) -> int:
        """Content address: pure in the canonical edge, so every process (and
        every later delete) resolves the same region with zero state."""
        lo, hi = (u, v) if u <= v else (v, u)
        key = np.uint64(lo) * np.uint64(self.num_vertices) + np.uint64(hi)
        return int(splitmix64(key) % np.uint64(self.store.regions))

    def _insert(self, u: int, v: int) -> bool:
        src, dst, valid = self.store.get(self.region_of(u, v))
        lo, hi = (u, v) if u <= v else (v, u)
        if bool(((src == lo) & (dst == hi) & valid).any()):
            return False  # duplicate — idempotent skip
        free = np.flatnonzero(~valid)
        if free.shape[0] == 0:
            return False  # region full — skip, counted by the caller
        s = int(free[0])
        src[s], dst[s], valid[s] = lo, hi, True
        self._num_edges += 1
        return True

    def _delete(self, u: int, v: int) -> bool:
        src, dst, valid = self.store.get(self.region_of(u, v))
        lo, hi = (u, v) if u <= v else (v, u)
        hit = np.flatnonzero((src == lo) & (dst == hi) & valid)
        if hit.shape[0] == 0:
            return False
        valid[hit[0]] = False
        self._num_edges -= 1
        return True

    def ingest(self, batch) -> LeanIngestStats:
        """Apply an EdgeUpdateBatch; spill back to budget afterwards, so peak
        resident exceeds the budget only by the batch's own working set."""
        t0 = time.perf_counter()
        inserted = deleted = skipped = 0
        for u, v in np.asarray(batch.delete, dtype=np.int64).reshape(-1, 2):
            if self._delete(int(u), int(v)):
                deleted += 1
            else:
                skipped += 1
        for u, v in np.asarray(batch.insert, dtype=np.int64).reshape(-1, 2):
            if self._insert(int(u), int(v)):
                inserted += 1
            else:
                skipped += 1
        self.store.evict_to_budget()
        return LeanIngestStats(
            inserted=inserted,
            deleted=deleted,
            skipped=skipped,
            scatter_ops=inserted + deleted,
            resynced=False,
            elapsed_s=time.perf_counter() - t0,
            num_edges=self._num_edges,
        )

    def monitor(self) -> str:
        return "none"

    @property
    def spill_counters(self) -> dict:
        """What IngestEvent.spill carries: store counters + the resident set
        size at event time."""
        return dict(self.store.counters, resident=self.store.resident)

    def snapshot(self) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) of all live edges — faults EVERY region in; an oracle /
        test affordance, not part of the bounded-memory path."""
        srcs, dsts = [], []
        for p in range(self.store.regions):
            src, dst, valid = self.store.get(p)
            srcs.append(src[valid])
            dsts.append(dst[valid])
        self.store.evict_to_budget()
        return np.concatenate(srcs), np.concatenate(dsts)
