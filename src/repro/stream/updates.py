"""Streaming update model: edge insert/delete batches + deterministic generator.

``EdgeUpdateBatch`` is the unit every layer of the streaming subsystem speaks:
the host orderer applies it to the ordered slot array, the device engine
scatters it into slack slots, the controller logs it as an IngestEvent.

``SyntheticStream`` generates a reproducible dynamic-graph workload the same
way data/pipeline.py generates tokens: every candidate update is a stateless
splitmix64 hash of (seed, batch index, position), so any run — test, bench,
CI — sees bit-identical streams. Inserts mix uniform edges with "triadic"
edges attached to an endpoint of an existing edge (hash-selected), giving the
stream community structure for the orderer's locality placement to exploit;
deletes hash-index into the current edge list. Replaying the same seed always
yields the same batches because the generator's edge set evolves
deterministically.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.baselines import mix_hash
from ..core.graph import Graph

__all__ = ["EdgeUpdateBatch", "SyntheticStream", "canonical_edges"]


def canonical_edges(edges: np.ndarray) -> np.ndarray:
    """(n, 2) int64 with src < dst per row; self loops dropped, dups dropped
    (keeping first occurrence, order preserved)."""
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    keep = lo != hi
    lo, hi = lo[keep], hi[keep]
    seen: set = set()
    rows = []
    for u, v in zip(lo.tolist(), hi.tolist()):
        if (u, v) not in seen:
            seen.add((u, v))
            rows.append((u, v))
    return np.asarray(rows, dtype=np.int64).reshape(-1, 2)


@dataclasses.dataclass(frozen=True)
class EdgeUpdateBatch:
    """One batch of graph mutations: canonical (src < dst) edge pairs.

    ``insert`` rows not currently in the graph are added; ``delete`` rows not
    currently in the graph are ignored (idempotent semantics, so replays and
    at-least-once delivery are safe).
    """

    insert: np.ndarray  # (n_ins, 2) int64, src < dst
    delete: np.ndarray  # (n_del, 2) int64, src < dst

    def __post_init__(self):
        object.__setattr__(self, "insert", canonical_edges(self.insert))
        object.__setattr__(self, "delete", canonical_edges(self.delete))

    @property
    def num_inserts(self) -> int:
        return int(self.insert.shape[0])

    @property
    def num_deletes(self) -> int:
        return int(self.delete.shape[0])

    @property
    def num_updates(self) -> int:
        return self.num_inserts + self.num_deletes


class SyntheticStream:
    """Deterministic dynamic-graph generator over a base graph.

    ``batch(b)`` is a pure function of (seed, b, base graph): batches may be
    generated once and replayed, or regenerated independently by any process
    holding the same seed — mirroring the stateless-hash contract of
    data/pipeline.py. Internally the generator tracks the evolving edge set so
    inserts are always novel edges and deletes always name live edges.
    """

    def __init__(
        self,
        base: Graph,
        *,
        batch_size: int = 64,
        delete_frac: float = 0.25,
        triadic_frac: float = 0.5,
        seed: int = 0,
        burst_every: int = 0,
        burst_factor: int = 4,
        burst_delete_frac: float | None = None,
    ):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if not 0.0 <= delete_frac < 1.0:
            raise ValueError("delete_frac must be in [0, 1)")
        if burst_every < 0:
            raise ValueError("burst_every must be >= 0 (0 = no bursts)")
        if burst_factor < 1:
            raise ValueError("burst_factor must be >= 1")
        if burst_delete_frac is not None and not 0.0 <= burst_delete_frac < 1.0:
            raise ValueError("burst_delete_frac must be in [0, 1)")
        self.num_vertices = base.num_vertices
        self.batch_size = int(batch_size)
        self.delete_frac = float(delete_frac)
        self.triadic_frac = float(triadic_frac)
        self.seed = int(seed)
        # Bursty mode: every ``burst_every``-th batch (the last of each
        # window) is ``burst_factor``× the base size at ``burst_delete_frac``
        # (default: the base delete_frac) — churn spikes that stress the
        # rebuild-under-ingest delta-splice path. Burst SHAPE is a pure
        # function of the batch index, so the stateless-replay contract is
        # untouched: same (seed, b) → same batch, bursts included.
        self.burst_every = int(burst_every)
        self.burst_factor = int(burst_factor)
        self.burst_delete_frac = (
            self.delete_frac if burst_delete_frac is None else float(burst_delete_frac)
        )
        self._next_batch = 0
        # Live edge set: list for O(1) hash-indexed delete picks (swap-remove),
        # set for O(1) membership.
        self._edges: list[tuple[int, int]] = list(
            zip(base.src.astype(int).tolist(), base.dst.astype(int).tolist())
        )
        self._present: set = set(self._edges)

    # ------------------------------------------------------------------ hash
    def _h(self, batch: int, pos: int, salt: int) -> int:
        # One shared helper with data/shards.py's generator (same key layout,
        # same draw for the same (seed, index) — property-tested).
        return int(mix_hash(self.seed, batch, pos, salt))

    def _candidate_insert(self, batch: int, pos: int) -> tuple[int, int] | None:
        h = self._h(batch, pos, salt=1)
        v_total = self.num_vertices
        if (h >> 8) % 1000 < int(self.triadic_frac * 1000) and self._edges:
            # Triadic closure: attach to an endpoint of a hash-picked live edge.
            a, c = self._edges[(h >> 16) % len(self._edges)]
            u = a if (h >> 4) & 1 else c
            v = int(self._h(batch, pos, salt=2) % v_total)
        else:
            u = int(h % v_total)
            v = int(self._h(batch, pos, salt=3) % v_total)
        if u == v:
            return None
        lo, hi = (u, v) if u < v else (v, u)
        if (lo, hi) in self._present:
            return None
        return (lo, hi)

    # ------------------------------------------------------------------ api
    @property
    def num_edges(self) -> int:
        return len(self._edges)

    def is_burst(self, b: int) -> bool:
        """Whether batch ``b`` is a burst — the last batch of each
        ``burst_every`` window, a pure function of the index."""
        return self.burst_every > 0 and b % self.burst_every == self.burst_every - 1

    def batch_shape(self, b: int) -> tuple[int, int]:
        """(n_del, n_ins) of batch ``b`` before edge-set clamping — the
        deterministic size plan (bursts included)."""
        if self.is_burst(b):
            size = self.batch_size * self.burst_factor
            frac = self.burst_delete_frac
        else:
            size = self.batch_size
            frac = self.delete_frac
        n_del = int(size * frac)
        return n_del, size - n_del

    def batch(self, index: int | None = None) -> EdgeUpdateBatch:
        """Generate the next batch (or assert the caller is replaying in
        order: batches must be consumed sequentially because deletes index the
        evolving live edge set)."""
        b = self._next_batch if index is None else int(index)
        if b != self._next_batch:
            raise ValueError(
                f"stream batches must be consumed in order (next={self._next_batch}, got {b})"
            )
        n_del, n_ins = self.batch_shape(b)
        # Deletes are drawn FIRST, from the pre-batch live set — the same
        # delete-then-insert order IncrementalOrderer.apply uses — so the
        # generator's live set and a consumer's can never diverge (an edge
        # deleted and re-inserted in one batch nets to present on both sides).
        deletes: list[tuple[int, int]] = []
        for i in range(n_del):
            if not self._edges:
                break
            j = self._h(b, i, salt=7) % len(self._edges)
            e = self._edges[j]
            # Swap-remove keeps the pick O(1) and deterministic.
            self._edges[j] = self._edges[-1]
            self._edges.pop()
            self._present.discard(e)
            deletes.append(e)
        inserts: list[tuple[int, int]] = []
        pos = 0
        # Scan bound scales with the batch's own size so bursts aren't
        # starved; identical to the historical 16×batch_size off-burst.
        while len(inserts) < n_ins and pos < 16 * max(self.batch_size, n_del + n_ins):
            e = self._candidate_insert(b, pos)
            pos += 1
            if e is None:  # _present already covers within-batch dedup
                continue
            inserts.append(e)
            self._present.add(e)
            self._edges.append(e)
        self._next_batch = b + 1
        return EdgeUpdateBatch(
            insert=np.asarray(inserts, dtype=np.int64).reshape(-1, 2),
            delete=np.asarray(deletes, dtype=np.int64).reshape(-1, 2),
        )

    def batches(self, n: int):
        for _ in range(n):
            yield self.batch()

    def edges(self) -> np.ndarray:
        """(E, 2) int64 current live edge set (generator's view)."""
        return np.asarray(sorted(self._edges), dtype=np.int64).reshape(-1, 2)
