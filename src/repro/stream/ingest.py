"""On-device ingest: apply EdgeUpdateBatches to the (sharded) engine pack.

The host ``IncrementalOrderer`` owns the ordered slot array; the
``StreamingEngine`` mirrors it on the mesh as a ``ShardedEngineData`` whose
partition p holds region p's slots (``graphs/engine.py pack_slots`` layout:
occupied slots keep their column, gaps are masked, one trailing scratch
column). Three jitted device program families — all in one bounded
kind-prefixed ``ProgramCache`` LRU, the same container the migration programs
of elastic/rescale_exec.py use — keep the mirror current without ever
re-packing from the host:

* **scatter** (ingest): each drained ``SlotOp`` becomes one (row, col) write
  of the edge values + mask bit, plus a scatter-add of the per-vertex degree
  deltas into the replicated degree vector. Ops are padded to a power-of-two
  batch capacity; padding targets the scratch column, which the program
  re-zeroes, so one traced program serves every batch of similar size.
* **compact** (rescale-under-ingest): the orderer's re-layout gather map
  (new slot ← old slot) becomes one gather over the old buffers with the
  k_new output sharding — XLA's SPMD partitioner routes exactly the rows
  whose region changed devices as device-to-device transfers, so rescaling
  keeps its O(k)-plan character while the stream is live.
* **span_repair** (partial re-order, the escalation ladder's middle rung):
  one program reads the degraded span's live slots straight from the sharded
  buffers, recomputes the span-local order (neighbor-expansion scoring with
  exact-objective candidate selection — kernels/span_reorder.py), and writes
  the repaired layout back as a single scatter over the span rows. The host
  runs the byte-exact numpy mirror of the same algorithm to keep its slot
  array and drift counters current, so the rung needs NO device round-trip
  and no slot-op upload (``scatter_limit`` only governs the host-mode
  fallback). Host ``geo_order`` on the extracted span is retained as the
  oracle: ``span_repair="oracle"`` applies it verbatim on device
  (bit-identical to the PR-3 host path), ``"differential"`` feeds it to the
  candidate selection so the repair is never worse than GEO by construction.
* **full_reorder** + **splice** (the full-rebuild rung, async — DESIGN.md
  §11): when ``full_rebuild`` is an async mode, the top rung only DISPATCHES
  — the whole-graph re-order program (kernels/full_reorder.py, the span
  program generalized to s = k) runs against the current buffers WITHOUT
  donating them, producing shadow output buffers while ingest keeps
  scattering into the live ones. ``rebuild_flight`` batches later the commit
  re-layouts the host slot array to the candidate order, replays the batches
  queued during the flight (``IncrementalOrderer.commit_full_rebuild``), and
  the **splice** program scatters the replay's coalesced slot ops onto the
  shadow buffers in fixed-capacity chunks — the swap that makes them the
  live pack. Ingest is never blocked longer than that one commit batch.

All five program families live in ONE bounded ``ProgramCache`` LRU under
kind-prefixed keys, so ``program_cache_size`` bounds every cached program of
a long-lived engine — and the cache's per-kind hit/miss/eviction counters
(``program_cache_counters``) let the bench prove escalations never pay a
compile (every signature is warmed at layout changes; misses == compiles).

Bit-identity contract (DESIGN.md §9): after any sequence of ingests,
rescales, and span repairs, ``unshard_engine_data(engine.data)`` equals the
host-side ``pack_slots`` oracle byte-for-byte (``verify_bit_identity``;
asserted per step with ``verify=True``).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from .. import compat
from ..compat import donate_jit
from ..core import cep
from ..elastic.rescale_exec import EDGE_BYTES, ProgramCache
from ..graphs import engine as graph_engine
from ..kernels import full_reorder as FRK
from ..kernels import span_reorder as SRK
from ..launch import sharding as SH
from ..obs import metrics as OM
from ..obs import trace as OT
from .incremental import IncrementalOrderer
from .updates import EdgeUpdateBatch

__all__ = ["IngestStats", "StreamRescaleStats", "StreamingEngine"]

_LOG = logging.getLogger(__name__)

_MIN_OP_CAPACITY = 32
# Fixed op capacity of the commit splice: one warmed program signature serves
# every commit; larger replay deltas run as chained chunks of this size.
_SPLICE_CAP = 1024
# full_rebuild engine mode → full-reorder program mode (kernels/full_reorder):
#   "geo"          — host geo_order candidate applied verbatim (the oracle
#                    path: commits are byte-identical to a host full_rebuild
#                    of the snapshot, modulo the async delta replay)
#   "device"       — on-mesh step-parallel greedy; the host mirror's
#                    never-worse-than-incumbent selection ships as an operand
#   "differential" — geo candidate, greedy-vs-candidate selection ON device
_FULL_PROGRAM_MODE = {"geo": "apply", "device": "greedy", "differential": "select"}


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def _rows_of_regions(regions: np.ndarray, k: int, g: int) -> np.ndarray:
    """Vectorized launch.sharding.partition_row."""
    m = SH.padded_partition_count(k, g) // g
    return (regions % g) * m + regions // g


@dataclasses.dataclass(frozen=True)
class IngestStats:
    inserted: int  # edges added to the graph
    deleted: int  # edges removed
    skipped: int  # duplicate inserts / deletes of absent edges (idempotent)
    scatter_ops: int  # slot writes in the device scatter (0 when resynced)
    resynced: bool  # True when the slot array re-laid out (grow/escalation)
    elapsed_s: float  # host apply + device program, blocked
    num_edges: int  # live edges after the batch


@dataclasses.dataclass(frozen=True)
class StreamRescaleStats:
    k_old: int
    k_new: int
    num_edges: int
    moved_edges: int  # edges whose owning region changed (actual)
    cep_plan_edges: int  # what CEP-chunk layouts would move for this |E|, k_old → k_new
    cross_device_edges: int  # moved edges whose regions live on different devices
    cross_device_bytes: int
    elapsed_s: float
    cross_process_edges: int = 0  # moved edges whose devices live on different
    cross_process_bytes: int = 0  # jax.distributed processes (the NIC bill)


class StreamingEngine:
    """Keeps a mesh-resident engine pack in lock-step with an
    ``IncrementalOrderer`` under streaming updates and rescales.

    ``engine.data`` is always a live ``ShardedEngineData``: GAS algorithms
    (pagerank / sssp / wcc) run on it unchanged between — and across —
    ingests, because the slot layout is mask-driven. A mesh of 1
    (``launch.mesh.make_graph_mesh(1)``) is the degenerate case of the same
    code path, per the repo's graph-axis convention.
    """

    def __init__(
        self,
        orderer: IncrementalOrderer,
        mesh=None,
        *,
        donate: bool = True,
        program_cache_size: int = 24,
        scatter_limit: int = 1024,
        span_repair: str = "device",
        full_rebuild: str = "host",
        rebuild_flight: int = 2,
        warm_scatter_caps: tuple = (),
        tracer=None,
        metrics_registry=None,
        commit: str = "pack",
    ):
        if mesh is None:
            from ..launch import mesh as MM

            mesh = MM.make_graph_mesh(1)
        if span_repair not in ("device", "host", "oracle", "differential"):
            raise ValueError(f"unknown span_repair mode {span_repair!r}")
        if full_rebuild not in ("host", "geo", "device", "differential"):
            raise ValueError(f"unknown full_rebuild mode {full_rebuild!r}")
        if rebuild_flight < 0:
            raise ValueError("rebuild_flight must be >= 0")
        if commit not in ("pack", "stream"):
            raise ValueError(f"unknown commit mode {commit!r}")
        self.orderer = orderer
        self.mesh = mesh
        self.donate = donate
        # Above this many slot ops, a full pack re-upload beats a giant
        # scatter — on CPU meshes markedly so. Only the HOST-mode partial rung
        # still produces span-sized op batches; the device rung rewrites the
        # span on-mesh and uploads nothing. Real accelerator meshes, where
        # host→device uploads cross PCIe while the scatter stays device-local,
        # should raise it.
        self.scatter_limit = int(scatter_limit)
        # Partial-rung implementation (DESIGN.md §9):
        #   "device"       — on-mesh span repair + byte-exact host mirror
        #   "host"         — PR-3 path: host geo_order + slot-op scatter
        #   "oracle"       — host geo_order applied verbatim by the device
        #                    program (bit-identical to "host"; the tests'
        #                    apply-mode oracle)
        #   "differential" — device repair with the geo_order oracle as the
        #                    scored candidate (never worse than GEO)
        self.span_repair = span_repair
        # Full-rebuild rung implementation (DESIGN.md §11):
        #   "host"         — PR-3 path: synchronous host geo_order + re-upload
        #   "geo"          — async; host geo_order candidate applied on-mesh
        #                    (the production mode on hosts where the device
        #                    greedy is not profitable, and the oracle mode)
        #   "device"       — async; on-mesh step-parallel greedy, never worse
        #                    than the incumbent layout by exact selection
        #   "differential" — async; geo candidate with on-device selection,
        #                    bit-identity verified at every commit
        self.full_rebuild = full_rebuild
        # Batches a dispatched rebuild stays in flight before its commit. 0 =
        # commit inside the dispatching monitor call (synchronous semantics —
        # the oracle-equivalence mode the tests pin against "host").
        self.rebuild_flight = int(rebuild_flight)
        self._flight: Optional[dict] = None  # in-flight rebuild state
        self._last_drift = 1.0  # drift tracker for dispatch anticipation
        self._drift_rate = 0.0  # EMA of per-batch drift growth
        self.rebuild_log: list = []  # committed/aborted rebuild records
        self.rebuild_state = ""  # ""/"dispatch"/"flight"/"commit"/"abort"
        self.last_rebuild_s = 0.0  # rebuild work inside the last monitor call
        self._greedy_overflow_logged = False  # int32-fallback warning fires once
        # ONE kind-prefixed LRU for every program family (scatter / compact /
        # span_repair / full_reorder / splice), like ElasticRescaler's
        # migrate+counts cache. The default is sized for the families SHARING
        # it: several scatter op-capacity buckets per layout, one compact
        # program per (k_old, k_new) pair of an oscillating controller, one
        # span + one full-reorder + one splice program per layout — an
        # eviction of a warmed program would put its recompile back inside
        # the monitored escalation path.
        self._programs = ProgramCache(program_cache_size)
        # Per-rung escalation accounting, surfaced on IngestEvents.
        self.rung_counts = {"none": 0, "partial": 0, "full": 0}
        self.rung_s = {"none": 0.0, "partial": 0.0, "full": 0.0}
        self.last_repair = ""  # what the last partial/full rung executed
        # Scatter op-capacity buckets to keep warm. Buckets are added as the
        # stream uses them and re-warmed at every layout change; callers that
        # know their batch sizes seed the expected buckets here so not even
        # the FIRST batch pays a compile inside the ingest path.
        self._seen_scatter_caps = {
            int(_next_pow2(int(c))) for c in warm_scatter_caps
        }
        # Observability (obs/, DESIGN.md §13). tracer=None falls back to the
        # process-global tracer (disabled by default: spans cost one branch);
        # metric objects are bound once here so the per-batch hot path does
        # no registry lookups — against the default NULL registry every bound
        # object is the shared inert metric.
        self._tracer = tracer
        self.metrics = OM.NULL if metrics_registry is None else metrics_registry
        m = self.metrics
        self._m_ingest_s = m.histogram("stream.ingest.batch_s")
        self._m_monitor_s = m.histogram("stream.monitor.s")
        self._m_rung_s = {r: m.histogram(f"stream.rung.{r}_s") for r in ("none", "partial", "full")}
        self._m_updates = {k: m.counter(f"stream.updates.{k}") for k in ("inserted", "deleted", "skipped")}
        self._m_scatter_ops = m.counter("stream.scatter_ops")
        self._m_resyncs = m.counter("stream.resyncs")
        self._m_edges = m.gauge("stream.num_edges")
        self._m_in_flight = m.gauge("stream.rebuilds_in_flight")
        # commit="stream" builds the INITIAL pack shard-by-shard
        # (pack_slots_sharded_stream): each process stages only the slot
        # rows its devices own, never a full host pack — the recovery path's
        # commit mode (a restored orderer re-homing onto a smaller surviving
        # mesh must not require the dead cluster's per-host memory headroom).
        # Steady-state resyncs after a re-layout still use the in-core
        # upload; "stream" only changes how the FIRST pack is committed.
        self.data = self._upload() if commit == "pack" else self._stream_upload()
        orderer.needs_resync = False
        self._warm_span_program()
        self._warm_full_program()
        self._warm_scatter_programs()

    @classmethod
    def from_restored(cls, orderer, mesh=None, **kwargs) -> "StreamingEngine":
        """Build an engine around a checkpoint-restored orderer
        (``checkpoint.SlotCheckpoint.restore``), committing the initial pack
        via ``pack_slots_sharded_stream`` on the SURVIVING mesh — the
        recovery half of DESIGN.md §15. The orderer's slot array is already
        the recovered order (snapshot chunks + replayed WAL tail), so this is
        purely a commit: partition p's slot range feeds the shard streamer
        one region at a time, and only the rows this process's devices own
        are ever staged. Ingest then continues exactly as on the original
        cluster — the engine is indistinguishable from one that never died
        (the fault drill asserts that bit-for-bit)."""
        return cls(orderer, mesh, commit="stream", **kwargs)

    # ------------------------------------------------------------- plumbing
    @property
    def tracer(self):
        return self._tracer if self._tracer is not None else OT.get_tracer()

    @property
    def k(self) -> int:
        return self.orderer.regions

    @property
    def num_vertices(self) -> int:
        return self.orderer.num_vertices

    def oracle_pack(self) -> graph_engine.EngineData:
        """Host-side bit-identity oracle: pack_slots of the current host
        slot array (what the device buffers must equal after unshard)."""
        o = self.orderer
        return graph_engine.pack_slots(
            o.slot_src, o.slot_dst, o.slot_valid, o.regions, o.num_vertices
        )

    def _upload(self) -> graph_engine.ShardedEngineData:
        return graph_engine.shard_engine_data(self.oracle_pack(), self.mesh)

    def _stream_upload(self) -> graph_engine.ShardedEngineData:
        """Shard-streamed initial commit (see ``from_restored``): region r's
        slot range IS its CEP chunk, so the part_fn is a pure slice."""
        o = self.orderer
        spr = o.slots_per_region

        def part_fn(p: int):
            lo, hi = p * spr, (p + 1) * spr
            return o.slot_src[lo:hi], o.slot_dst[lo:hi], o.slot_valid[lo:hi]

        with self.tracer.span("ingest.stream_commit"):
            return graph_engine.pack_slots_sharded_stream(
                part_fn, o.regions, o.num_vertices, self.mesh, spr
            )

    def _host_operand(self, arr):
        """Host-built program operand (scatter indices, gather maps). On a
        multi-process mesh these must be committed replicated global arrays —
        every process builds the identical value from its replica of the host
        orderer state — because uncommitted single-device arrays cannot feed a
        program whose out_shardings span other processes. The single-process
        path stays the plain device transfer."""
        if compat.process_count() == 1:
            return jnp.asarray(arr)
        from ..launch import multihost as MH

        return MH.put_global(np.asarray(arr), NamedSharding(self.mesh, P()))

    def program_cache_counters(self) -> dict:
        """Per-kind {hits, misses, evictions} snapshot of the shared program
        cache — surfaced on IngestEvents/ScaleEvents so a stream log proves
        escalations never pay a compile (misses == compiles: the warm helpers
        probe with ``touch``, which counts nothing on absence)."""
        return self._programs.counters_snapshot()

    @property
    def rebuilds_in_flight(self) -> int:
        return 1 if self._flight is not None else 0

    def _resync(self) -> None:
        """Full host re-upload after a slot re-layout (grow / full rebuild).
        Rare by design — the escalation ladder's upper rungs. Aborts any
        in-flight rebuild: its snapshot geometry no longer exists."""
        if self._flight is not None:
            self._abort_rebuild("resync")
        with self.tracer.span("ingest.resync"):
            self.orderer.drain_ops()  # ops predate the re-layout; drop them
            self.data = self._upload()
        self._m_resyncs.inc()
        self.orderer.needs_resync = False
        self._warm_span_program()  # layout signature may have changed
        self._warm_full_program()
        self._warm_scatter_programs()

    def _warm_span_program(self) -> None:
        """Trace + compile the span-repair program for the CURRENT layout
        signature on throwaway buffers. Called at every layout change (init,
        rescale, resync) so a partial escalation never pays the compile
        inside the monitored stream path; a no-op when the signature is
        already cached."""
        if self.span_repair == "host":
            return
        o = self.orderer
        s = min(o.config.span_regions, o.regions)
        mode = {"oracle": "apply", "differential": "select"}.get(self.span_repair, "greedy")
        e_cap = int(self.data.edges.shape[1])
        key = self._span_key(mode, o.regions, self.data.k_pad, e_cap, s, self.mesh)
        # touch(), not `in`: a cache hit must refresh LRU recency, or a warmed
        # span program idling between escalations becomes the eviction victim
        # — and unlike get(), a touch of an ABSENT key counts no miss, which
        # keeps the counters' `misses == compiles` invariant exact.
        if self._programs.touch(key):
            return
        program = self._span_program(mode, o.regions, self.data.k_pad, e_cap, s, self.mesh)
        from ..launch import multihost as MH

        s_edges, s_mask, _ = SH.engine_shardings(self.mesh)
        dummy_e = MH.put_global(np.zeros(self.data.edges.shape, np.int32), s_edges)
        dummy_m = MH.put_global(np.zeros(self.data.mask.shape, np.float32), s_mask)
        out = program(
            dummy_e,
            dummy_m,
            self._host_operand(np.arange(s, dtype=np.int32)),
            self._host_operand(np.arange(s * (e_cap - 1), dtype=np.int32)),
            self._host_operand(np.zeros(1, dtype=np.int32)),
        )
        jax.block_until_ready(out[0])

    def _warm_full_program(self) -> None:
        """Trace + compile the async full-rebuild programs (whole-graph
        re-order + commit splice) for the CURRENT layout signature on
        throwaway buffers — same contract as ``_warm_span_program``: a full
        escalation must never pay a compile inside the monitored stream.
        No-op in the synchronous host mode."""
        if self.full_rebuild == "host":
            return
        from ..launch import multihost as MH

        o = self.orderer
        e_cap = int(self.data.edges.shape[1])
        mode = _FULL_PROGRAM_MODE[self.full_rebuild]
        s_edges, s_mask, _ = SH.engine_shardings(self.mesh)
        key = self._full_key(mode, o.regions, self.data.k_pad, e_cap, self.mesh)
        if not self._programs.touch(key):
            program = self._full_program(mode, o.regions, self.data.k_pad, e_cap, self.mesh)
            cap = o.regions * (e_cap - 1)
            operands = [
                MH.put_global(np.zeros(self.data.edges.shape, np.int32), s_edges),
                MH.put_global(np.zeros(self.data.mask.shape, np.float32), s_mask),
                self._host_operand(np.arange(o.regions, dtype=np.int32)),
                self._host_operand(np.arange(cap, dtype=np.int32)),
            ]
            if mode == "greedy":
                operands.append(self._host_operand(np.zeros(1, np.int32)))
            if mode in ("greedy", "select"):
                operands += [
                    self._host_operand(np.ones(1, np.int32)),  # alpha
                    self._host_operand(np.ones(1, np.int32)),  # beta
                    self._host_operand(np.ones(1, np.int32)),  # delta
                    self._host_operand(np.zeros(self.num_vertices, np.int32)),
                ]
            jax.block_until_ready(program(*operands)[0])
        skey = self._splice_key(self.data.k_pad, e_cap, self.mesh)
        if not self._programs.touch(skey):
            program = self._splice_program(self.data.k_pad, e_cap, self.mesh)
            out = program(
                MH.put_global(np.zeros(self.data.edges.shape, np.int32), s_edges),
                MH.put_global(np.zeros(self.data.mask.shape, np.float32), s_mask),
                self._host_operand(np.zeros(_SPLICE_CAP, np.int32)),
                self._host_operand(np.full(_SPLICE_CAP, e_cap - 1, np.int32)),
                self._host_operand(np.zeros((_SPLICE_CAP, 2), np.int32)),
                self._host_operand(np.zeros(_SPLICE_CAP, np.float32)),
            )
            jax.block_until_ready(out[0])

    def _warm_scatter_programs(self) -> None:
        """Trace + compile the ingest scatter program for every op-capacity
        bucket the stream has used (plus any caller-seeded buckets) under the
        CURRENT layout signature, on throwaway buffers. Re-run at every
        layout change, so steady-state ingest never pays a compile — not even
        on the first batch after a rescale swaps the program signature."""
        if not self._seen_scatter_caps:
            return
        from ..launch import multihost as MH

        e_cap = int(self.data.edges.shape[1])
        k_pad = self.data.k_pad
        s_edges, s_mask, s_vert = SH.engine_shardings(self.mesh)
        for cap in sorted(self._seen_scatter_caps):
            if self._programs.touch(("scatter", k_pad, e_cap, cap, self.mesh)):
                continue
            program = self._scatter_program(k_pad, e_cap, cap, self.mesh)
            out = program(
                MH.put_global(np.zeros(self.data.edges.shape, np.int32), s_edges),
                MH.put_global(np.zeros(self.data.mask.shape, np.float32), s_mask),
                MH.put_global(np.zeros(self.data.degrees.shape, np.float32), s_vert),
                self._host_operand(np.zeros(cap, np.int32)),
                self._host_operand(np.full(cap, e_cap - 1, np.int32)),
                self._host_operand(np.zeros((cap, 2), np.int32)),
                self._host_operand(np.zeros(cap, np.float32)),
                self._host_operand(np.zeros(2 * cap, np.int32)),
                self._host_operand(np.zeros(2 * cap, np.float32)),
            )
            jax.block_until_ready(out[0])

    def _sync_pending(self) -> None:
        """Bring the device mirror up to date with whatever the host orderer
        has applied since the last sync: resync after a re-layout, otherwise
        scatter the drained ops (re-upload beyond ``scatter_limit``)."""
        if self.orderer.needs_resync:
            self._resync()
            return
        ops, deg = self.orderer.drain_ops()
        if len(ops) > self.scatter_limit:
            self.data = self._upload()
        elif ops or deg:
            self._scatter(ops, deg)

    def verify_bit_identity(self) -> bool:
        got = graph_engine.unshard_engine_data(self.data)
        want = self.oracle_pack()
        if not (
            np.array_equal(np.asarray(got.edges), np.asarray(want.edges))
            and np.array_equal(np.asarray(got.mask), np.asarray(want.mask))
            and np.array_equal(np.asarray(got.degrees), np.asarray(want.degrees))
        ):
            raise AssertionError("sharded streaming pack diverged from the host slot oracle")
        return True

    # --------------------------------------------------------------- ingest
    def ingest(self, batch: EdgeUpdateBatch, *, verify: bool = False) -> IngestStats:
        """Apply one update batch: host slot placement, then the device
        scatter (or a resync when the batch forced a re-layout)."""
        t0 = time.perf_counter()
        with self.tracer.span("ingest.batch"):
            with self.tracer.span("ingest.apply"):
                counts = self.orderer.apply(batch)
            resynced = False
            n_ops = 0
            if self.orderer.needs_resync:
                self._resync()
                resynced = True
            else:
                ops, deg = self.orderer.drain_ops()
                n_ops = len(ops)
                if n_ops or deg:
                    self._scatter(ops, deg)
            jax.block_until_ready(self.data.edges)
        elapsed = time.perf_counter() - t0
        self._m_ingest_s.observe(elapsed)
        self._m_updates["inserted"].inc(counts["inserted"])
        self._m_updates["deleted"].inc(counts["deleted"])
        self._m_updates["skipped"].inc(counts["skipped"])
        self._m_edges.set(self.orderer.num_edges)
        if verify:
            self.verify_bit_identity()
        return IngestStats(
            inserted=counts["inserted"],
            deleted=counts["deleted"],
            skipped=counts["skipped"],
            scatter_ops=n_ops,
            resynced=resynced,
            elapsed_s=elapsed,
            num_edges=self.orderer.num_edges,
        )

    def _scatter(self, ops, deg: dict) -> None:
        with self.tracer.span("ingest.scatter"):
            self._scatter_inner(ops, deg)
        self._m_scatter_ops.inc(len(ops))

    def _scatter_inner(self, ops, deg: dict) -> None:
        o = self.orderer
        g = SH.graph_axis_size(self.mesh)
        k_pad = self.data.k_pad
        e_cap = int(self.data.edges.shape[1])  # slots_per_region + scratch
        cap = _next_pow2(max(len(ops), (len(deg) + 1) // 2, _MIN_OP_CAPACITY))
        self._seen_scatter_caps.add(cap)
        # Padding ops target the scratch column (always re-zeroed by the
        # program), so no real slot is ever clobbered by a no-op.
        rows = np.zeros(cap, dtype=np.int32)
        cols = np.full(cap, e_cap - 1, dtype=np.int32)
        vals = np.zeros((cap, 2), dtype=np.int32)
        mvals = np.zeros(cap, dtype=np.float32)
        for i, op in enumerate(ops):
            rows[i] = SH.partition_row(op.slot // o.slots_per_region, o.regions, g)
            cols[i] = op.slot % o.slots_per_region
            if op.valid:
                vals[i] = (op.u, op.v)
                mvals[i] = 1.0
        verts = np.zeros(2 * cap, dtype=np.int32)
        dvals = np.zeros(2 * cap, dtype=np.float32)
        for i, (v, d) in enumerate(sorted(deg.items())):
            verts[i] = v
            dvals[i] = float(d)
        program = self._scatter_program(k_pad, e_cap, cap, self.mesh)
        edges, mask, degrees = program(
            self.data.edges,
            self.data.mask,
            self.data.degrees,
            self._host_operand(rows),
            self._host_operand(cols),
            self._host_operand(vals),
            self._host_operand(mvals),
            self._host_operand(verts),
            self._host_operand(dvals),
        )
        self.data = dataclasses.replace(
            self.data,
            edges=edges,
            mask=mask,
            degrees=degrees,
            num_edges=o.num_edges,
        )

    def _scatter_program(self, k_pad: int, e_cap: int, cap: int, mesh):
        key = ("scatter", k_pad, e_cap, cap, mesh)
        cached = self._programs.get(key)
        if cached is not None:
            return cached

        def apply(edges, mask, degrees, rows, cols, vals, mvals, verts, dvals):
            edges = edges.at[rows, cols].set(vals)
            mask = mask.at[rows, cols].set(mvals)
            degrees = degrees.at[verts].add(dvals)
            # The scratch column absorbs padded no-op writes; keep it zero so
            # the pack stays bit-identical to the host oracle.
            edges = edges.at[:, -1, :].set(0)
            mask = mask.at[:, -1].set(0.0)
            return edges, mask, degrees

        s_edges, s_mask, s_vert = SH.engine_shardings(mesh)
        jit_kwargs = {"out_shardings": (s_edges, s_mask, s_vert)}
        if self.donate:
            program = donate_jit(apply, donate_argnums=(0, 1, 2), **jit_kwargs)
        else:
            program = jax.jit(apply, **jit_kwargs)
        return self._programs.put(key, program)

    # -------------------------------------------------------------- rescale
    def rescale(self, k_new: int, *, verify: bool = False) -> StreamRescaleStats:
        """Re-slice the live stream to ``k_new`` partitions without leaving
        the mesh: the orderer re-chunks the current incremental order (CEP at
        k_new) and the gather map executes as one compact program."""
        t0 = time.perf_counter()
        o = self.orderer
        # The host may have applied updates since the last device sync (e.g.
        # orderer.apply called directly): flush them first — the gather map
        # below describes the post-flush layout, and relayout drops pending
        # ops.
        self._sync_pending()
        # A rescale re-layouts every slot: an in-flight rebuild's snapshot
        # geometry (and its shadow buffers' shape) is void — abort it.
        if self._flight is not None:
            self._abort_rebuild("rescale")
        g = SH.graph_axis_size(self.mesh)
        k_old, spr_old = o.regions, o.slots_per_region
        old_edges = self.data.edges
        o.relayout(int(k_new))
        gm = o.drain_gather_map()
        spr_new = o.slots_per_region
        e_cap_old = int(old_edges.shape[1])
        e_cap_new = spr_new + 1
        k_pad_new = SH.padded_partition_count(int(k_new), g)

        new_slots = np.flatnonzero(gm >= 0)
        old_slots = gm[new_slots]
        new_regions = new_slots // spr_new
        old_regions = old_slots // spr_old
        src_row = np.zeros((k_pad_new, e_cap_new), dtype=np.int32)
        src_col = np.zeros((k_pad_new, e_cap_new), dtype=np.int32)
        validf = np.zeros((k_pad_new, e_cap_new), dtype=np.float32)
        dst_rows = _rows_of_regions(new_regions, int(k_new), g)
        dst_cols = new_slots % spr_new
        src_row[dst_rows, dst_cols] = _rows_of_regions(old_regions, k_old, g)
        src_col[dst_rows, dst_cols] = old_slots % spr_old
        validf[dst_rows, dst_cols] = 1.0

        moved = int(np.count_nonzero(new_regions != old_regions))
        cross = int(
            np.count_nonzero(
                (new_regions != old_regions) & (new_regions % g != old_regions % g)
            )
        )
        procs = SH.device_process_map(self.mesh)
        xproc = int(
            np.count_nonzero(
                (new_regions != old_regions)
                & (procs[new_regions % g] != procs[old_regions % g])
            )
        )
        program = self._compact_program(
            (int(old_edges.shape[0]), e_cap_old, k_pad_new, e_cap_new, self.mesh)
        )
        with self.tracer.span("rescale.compact"):
            edges, mask = program(
                old_edges,
                self._host_operand(src_row),
                self._host_operand(src_col),
                self._host_operand(validf),
            )
        self.data = graph_engine.ShardedEngineData(
            edges=edges,
            mask=mask,
            degrees=self.data.degrees,  # same graph, degrees unchanged
            num_vertices=self.num_vertices,
            k=int(k_new),
            mesh=self.mesh,
            mirrors=-1,
            replication_factor=float("nan"),
            num_edges=o.num_edges,
        )
        o.needs_resync = False
        # The k_new layout is a new span/full/scatter-program signature:
        # compile them here, inside the rescale's reported latency, not
        # inside the first escalation or ingest of the new layout.
        self._warm_span_program()
        self._warm_full_program()
        self._warm_scatter_programs()
        jax.block_until_ready(self.data.edges)
        elapsed = time.perf_counter() - t0
        m = self.metrics
        m.histogram("stream.rescale.s").observe(elapsed)
        m.counter("stream.rescale.cross_device_bytes").inc(cross * EDGE_BYTES)
        m.counter("stream.rescale.cross_process_bytes").inc(xproc * EDGE_BYTES)
        if verify:
            self.verify_bit_identity()
        return StreamRescaleStats(
            k_old=k_old,
            k_new=int(k_new),
            num_edges=o.num_edges,
            moved_edges=moved,
            cep_plan_edges=cep.migrated_edges_exact(o.num_edges, k_old, int(k_new)),
            cross_device_edges=cross,
            cross_device_bytes=cross * EDGE_BYTES,
            elapsed_s=elapsed,
            cross_process_edges=xproc,
            cross_process_bytes=xproc * EDGE_BYTES,
        )

    def _compact_program(self, key):
        cached = self._programs.get(("compact",) + key)
        if cached is not None:
            return cached
        mesh = key[-1]

        def compact(edges_old, src_row, src_col, validf):
            gathered = edges_old[src_row, src_col]  # (k_pad_new, e_cap_new, 2)
            new_edges = gathered * validf[..., None].astype(gathered.dtype)
            return new_edges, validf

        s_edges, s_mask, _ = SH.engine_shardings(mesh)
        jit_kwargs = {"out_shardings": (s_edges, s_mask)}
        if self.donate:
            program = donate_jit(compact, donate_argnums=(0,), **jit_kwargs)
        else:
            program = jax.jit(compact, **jit_kwargs)
        return self._programs.put(("compact",) + key, program)

    # ------------------------------------------------------------ escalation
    def monitor(self) -> str:
        """Quality-monitor step of the escalation ladder. The ladder decision
        stays in the orderer (``escalation()``); execution is delegated here
        per rung: a partial span re-order runs as the cached on-mesh
        span-repair program (mode ``span_repair``; host mode falls back to
        slot-op scatter / re-upload under ``scatter_limit``), a full rebuild
        as a synchronous resync (``full_rebuild="host"``) or an ASYNC
        dispatch (DESIGN.md §11): the whole-graph re-order program runs
        against shadow buffers for ``rebuild_flight`` batches, then commits,
        so ingest never blocks for longer than the one commit batch.
        Escalation is suppressed while a rebuild is in flight — the drift
        being measured is already being repaired, and the dispatch
        ANTICIPATION below fires the rung early enough that the commit lands
        before the live order leaves its quality margin. Per-rung counters
        and timings accumulate in ``rung_counts`` / ``rung_s`` (dispatch and
        commit both land in 'full'). Returns 'none' | 'partial' | 'full'."""
        t0 = time.perf_counter()
        with self.tracer.span("rung.monitor"):
            rung = self._monitor_inner()
        elapsed = time.perf_counter() - t0
        self.rung_counts[rung] += 1
        self.rung_s[rung] += elapsed
        self._m_monitor_s.observe(elapsed)
        self._m_rung_s[rung].observe(elapsed)
        self._m_in_flight.set(self.rebuilds_in_flight)
        return rung

    def _monitor_inner(self) -> str:
        self.rebuild_state = ""
        self.last_rebuild_s = 0.0
        # Flush anything the host applied since the last sync FIRST: the span
        # program reads the device buffers, which must mirror the host slots.
        self._sync_pending()
        # Dispatch anticipation: project the drift forward by the flight
        # window (per-batch growth rate × rebuild_flight) so an async full
        # rung fires early enough that its COMMIT lands at roughly the drift
        # a synchronous rebuild would have repaired at. The rate is an EMA of
        # the per-batch growth — anticipation projects the TREND; a single
        # noisy drift jump must not halve the rebuild cycle by inflating the
        # lookahead for one batch. Commits/rescales drop drift below the
        # tracker, clamping that batch's sample to 0 and decaying the EMA —
        # anticipation re-arms as growth resumes.
        d = self.orderer.drift()
        lookahead = 0.0
        if self.full_rebuild != "host" and self.rebuild_flight > 0:
            sample = max(0.0, d - self._last_drift)
            self._drift_rate = 0.7 * self._drift_rate + 0.3 * sample
            lookahead = self.rebuild_flight * self._drift_rate
        self._last_drift = d
        if self._flight is not None:
            self._flight["countdown"] -= 1
            if self._flight["countdown"] <= 0:
                self._commit_rebuild()
                rung = "full"
            else:
                self.rebuild_state = "flight"
                self.last_repair = ""
                rung = "none"
        else:
            # Partial shadow: with a full projected within two flight windows,
            # a span repair buys nothing the imminent whole-graph commit will
            # not erase (repeated partials on the same drifted layout plateau
            # after the first pass) — suppress it and save the rung's cost.
            rung = self.orderer.maybe_escalate(
                partial_fn=self._partial_rung, full_fn=self._full_rung,
                full_lookahead=lookahead, partial_shadow=2.0 * lookahead,
            )
            if rung == "none":
                self.last_repair = ""
            if self._flight is not None and self._flight["countdown"] <= 0:
                # rebuild_flight == 0: dispatch and commit inside one monitor
                # call — synchronous semantics, the oracle-equivalence mode.
                self._commit_rebuild()
        return rung

    def _full_rung(self) -> None:
        """Execute the full rung: host mode keeps the synchronous PR-3 path
        (host ``geo_order`` + full re-upload); the async modes dispatch the
        on-mesh rebuild and return without blocking."""
        if self.full_rebuild == "host":
            with self.tracer.span("rebuild.sync"):
                self.orderer.full_rebuild()
                self._resync()
            self.last_repair = "resync"
        else:
            self._dispatch_rebuild()
            self.rebuild_state = "dispatch"
            self.last_repair = "dispatch"

    # ------------------------------------------------------ async full rebuild
    def _dispatch_rebuild(self) -> None:
        """Dispatch the full rung asynchronously: snapshot the host slot
        arrays (``begin_full_rebuild`` starts queuing batches for the commit's
        replay), compute the candidate decision host-side via the byte-exact
        mirror, and launch the cached whole-graph re-order program against the
        CURRENT device buffers WITHOUT donating them — the program's fresh
        output arrays are the shadow pack the commit splices the flight's
        delta onto, while ingest keeps scattering into the live ones. Nothing
        here blocks on the device."""
        with self.tracer.span("rebuild.dispatch"):
            self._dispatch_rebuild_inner()
        self.last_rebuild_s = self._flight["dispatch_s"]

    def _dispatch_rebuild_inner(self) -> None:
        t0 = time.perf_counter()
        o = self.orderer
        u = o.slot_src.copy()
        v = o.slot_dst.copy()
        valid = o.slot_valid.copy()
        o.begin_full_rebuild()
        mode = _FULL_PROGRAM_MODE[self.full_rebuild]
        nv = self.num_vertices
        n_live = int(valid.sum())
        ks = FRK.eval_ks_full(o.config.k_min, o.config.k_max, o.regions)
        use_cand = True
        params = None
        mode_label = self.full_rebuild
        rung_mode = self.full_rebuild
        if rung_mode != "geo":
            deg = np.bincount(np.concatenate([u[valid], v[valid]]), minlength=1)
            if not FRK.greedy_fits_int32(
                n_live, o.config.k_min, o.config.k_max, int(deg.max())
            ):
                # The on-mesh greedy's int32 priorities would overflow on
                # this graph (out-of-core scales cross the bound routinely).
                # Degrade to the host-order "apply" path instead of raising —
                # a full rebuild must never abort the ingest loop.
                if not self._greedy_overflow_logged:
                    self._greedy_overflow_logged = True
                    _LOG.warning(
                        "full-rebuild greedy overflows int32 at |E|=%d, "
                        "max_degree=%d: falling back to host geo_order "
                        "(logged once per engine)",
                        n_live,
                        int(deg.max()),
                    )
                rung_mode = "geo"
                mode = _FULL_PROGRAM_MODE["geo"]
                mode_label = f"{self.full_rebuild}+host-fallback"
        if rung_mode == "geo":
            # Oracle path: host geo_order IS the committed order; the device
            # program applies it verbatim (mode "apply").
            chosen = FRK.geo_full_candidate(u, v, valid, nv, o.config.k_min, o.config.k_max)
            cand = chosen
        else:
            if rung_mode == "device":
                cand = FRK.identity_candidate(valid)  # incumbent = never-worse floor
            else:  # differential: geo oracle as the scored candidate
                cand = FRK.geo_full_candidate(u, v, valid, nv, o.config.k_min, o.config.k_max)
            alpha, beta, delta = FRK.greedy_params(
                n_live, o.config.k_min, o.config.k_max, int(deg.max())
            )
            permpos = FRK.fallback_positions(nv)
            chosen, use_cand = FRK.select_full_order_host(
                u, v, valid, nv, cand, ks, alpha, beta, delta, permpos
            )
            params = (alpha, beta, delta, permpos)
        live_order = np.asarray(chosen[:n_live], dtype=np.int64)
        cand_src = u[live_order]
        cand_dst = v[live_order]
        e_cap = int(self.data.edges.shape[1])
        g = SH.graph_axis_size(self.mesh)
        rows = np.asarray(
            [SH.partition_row(p, o.regions, g) for p in range(o.regions)], dtype=np.int32
        )
        program = self._full_program(mode, o.regions, self.data.k_pad, e_cap, self.mesh)
        operands = [
            self.data.edges,
            self.data.mask,
            self._host_operand(rows),
            self._host_operand(np.asarray(cand, dtype=np.int32)),
        ]
        if mode == "greedy":
            operands.append(
                self._host_operand(np.asarray([1 if use_cand else 0], np.int32))
            )
        if params is not None:
            alpha, beta, delta, permpos = params
            operands += [
                self._host_operand(np.asarray([alpha], np.int32)),
                self._host_operand(np.asarray([beta], np.int32)),
                self._host_operand(np.asarray([delta], np.int32)),
                self._host_operand(np.asarray(permpos, np.int32)),
            ]
        cand_edges, cand_mask = program(*operands)  # async — never blocked here
        self._flight = {
            "mode": mode_label,
            "countdown": self.rebuild_flight,
            "cand_dev": (cand_edges, cand_mask),
            "cand_src": cand_src,
            "cand_dst": cand_dst,
            "snapshot_edges": n_live,
            "dispatch_s": time.perf_counter() - t0,
        }

    def _commit_rebuild(self) -> None:
        """Commit the in-flight rebuild: re-layout the host slot array to the
        candidate order and replay the flight's queued batches
        (``commit_full_rebuild``), then splice the replay's coalesced slot ops
        onto the shadow buffers — the swap that makes them the live pack.
        Blocks, so the full rung's reported cost is honest. Falls back to a
        resync when the commit could not keep the buffer shape."""
        with self.tracer.span("rebuild.commit"):
            self._commit_rebuild_inner()

    def _commit_rebuild_inner(self) -> None:
        t0 = time.perf_counter()
        fl, self._flight = self._flight, None
        o = self.orderer
        replayed = o.rebuild_delta_batches
        ok = o.commit_full_rebuild(fl["cand_src"], fl["cand_dst"])
        splice_ops = 0
        if not ok:
            self._resync()
            self.last_repair = "resync"
        else:
            ops, _ = o.drain_ops()  # the replay's delta vs the candidate layout
            splice_ops = len(ops)
            edges, mask = fl["cand_dev"]
            if ops:
                edges, mask = self._splice(edges, mask, ops)
            self.data = dataclasses.replace(
                self.data, edges=edges, mask=mask, num_edges=o.num_edges
            )
            self.last_repair = fl["mode"]
        jax.block_until_ready(self.data.edges)
        self.rebuild_state = "commit"
        commit_s = time.perf_counter() - t0
        self.last_rebuild_s = commit_s
        if self.full_rebuild == "differential":
            self.verify_bit_identity()
        self.rebuild_log.append(
            {
                "kind": "full_rebuild",
                "mode": fl["mode"],
                "committed": bool(ok),
                "aborted": False,
                "snapshot_edges": fl["snapshot_edges"],
                "replayed_batches": replayed,
                "splice_ops": splice_ops,
                "flight_batches": self.rebuild_flight - fl["countdown"],
                "dispatch_s": fl["dispatch_s"],
                "commit_s": commit_s,
            }
        )

    def _abort_rebuild(self, reason: str) -> None:
        """Drop an in-flight rebuild: a re-layout (grow / rescale) voided its
        snapshot geometry. The shadow buffers are simply released; drift is
        untouched, so the ladder re-fires once the dust settles."""
        fl, self._flight = self._flight, None
        self.orderer.abort_full_rebuild()
        self.rebuild_state = "abort"
        self.rebuild_log.append(
            {
                "kind": "full_rebuild",
                "mode": fl["mode"],
                "committed": False,
                "aborted": True,
                "abort_reason": reason,
                "snapshot_edges": fl["snapshot_edges"],
                "replayed_batches": 0,
                "splice_ops": 0,
                "flight_batches": self.rebuild_flight - fl["countdown"],
                "dispatch_s": fl["dispatch_s"],
                "commit_s": 0.0,
            }
        )

    def drain_rebuild_events(self) -> list:
        """Completed (committed or aborted) rebuild records since the last
        drain. The controller wraps them into ``RebuildEvent``s, assigning
        the shared monotonic seq at drain — i.e. completion-commit — time."""
        log, self.rebuild_log = self.rebuild_log, []
        return log

    def _splice(self, edges, mask, ops):
        """Scatter the commit's replay ops onto the shadow buffers in
        fixed-capacity chunks (one warmed splice signature serves every
        commit; padding targets the re-zeroed scratch column, exactly like
        the ingest scatter)."""
        o = self.orderer
        g = SH.graph_axis_size(self.mesh)
        e_cap = int(edges.shape[1])
        program = self._splice_program(self.data.k_pad, e_cap, self.mesh)
        for base in range(0, len(ops), _SPLICE_CAP):
            chunk = ops[base : base + _SPLICE_CAP]
            rows = np.zeros(_SPLICE_CAP, dtype=np.int32)
            cols = np.full(_SPLICE_CAP, e_cap - 1, dtype=np.int32)
            vals = np.zeros((_SPLICE_CAP, 2), dtype=np.int32)
            mvals = np.zeros(_SPLICE_CAP, dtype=np.float32)
            for i, op in enumerate(chunk):
                rows[i] = SH.partition_row(op.slot // o.slots_per_region, o.regions, g)
                cols[i] = op.slot % o.slots_per_region
                if op.valid:
                    vals[i] = (op.u, op.v)
                    mvals[i] = 1.0
            edges, mask = program(
                edges,
                mask,
                self._host_operand(rows),
                self._host_operand(cols),
                self._host_operand(vals),
                self._host_operand(mvals),
            )
        return edges, mask

    def _splice_key(self, k_pad: int, e_cap: int, mesh):
        return ("splice", k_pad, e_cap, _SPLICE_CAP, mesh)

    def _splice_program(self, k_pad: int, e_cap: int, mesh):
        key = self._splice_key(k_pad, e_cap, mesh)
        cached = self._programs.get(key)
        if cached is not None:
            return cached

        def splice(edges, mask, rows, cols, vals, mvals):
            edges = edges.at[rows, cols].set(vals)
            mask = mask.at[rows, cols].set(mvals)
            # Scratch column absorbs the padded no-op writes (same contract
            # as the ingest scatter).
            edges = edges.at[:, -1, :].set(0)
            mask = mask.at[:, -1].set(0.0)
            return edges, mask

        s_edges, s_mask, _ = SH.engine_shardings(mesh)
        jit_kwargs = {"out_shardings": (s_edges, s_mask)}
        if self.donate:
            # Donating is safe HERE: the inputs are the shadow buffers (or a
            # previous chunk's output), which nothing else references.
            program = donate_jit(splice, donate_argnums=(0, 1), **jit_kwargs)
        else:
            program = jax.jit(splice, **jit_kwargs)
        return self._programs.put(key, program)

    def _full_key(self, mode: str, k: int, k_pad: int, e_cap: int, mesh):
        o = self.orderer
        ks = FRK.eval_ks_full(o.config.k_min, o.config.k_max, k)
        use_pallas = SH.graph_axis_size(mesh) == 1 and compat.process_count() == 1
        return ("full_reorder", mode, k, k_pad, e_cap, ks, use_pallas, mesh)

    def _full_program(self, mode: str, k: int, k_pad: int, e_cap: int, mesh):
        """Whole-graph re-order program — the span program generalized to
        s = k (kernels/full_reorder.py), with one structural difference: the
        input buffers are NOT donated. The outputs are fresh arrays — the
        shadow half of the double buffer — so ingest keeps scattering into
        the live pack while this runs.

        Modes: ``apply`` applies the host geo_order candidate verbatim (the
        oracle path); ``greedy`` recomputes the step-parallel greedy on
        device with the mirror's never-worse selection as a scalar operand;
        ``select`` scores greedy vs candidate on device (differential)."""
        spr = e_cap - 1
        cap = k * spr
        key = self._full_key(mode, k, k_pad, e_cap, mesh)
        ks, use_pallas = key[5], key[6]
        cached = self._programs.get(key)
        if cached is not None:
            return cached
        num_vertices = self.num_vertices

        def rebuild(edges, mask, rows, cand, *rest):
            blk_e = edges[rows]  # (k, e_cap, 2) — every region's row
            blk_m = mask[rows]
            u = blk_e[:, :spr, 0].reshape(cap)
            v = blk_e[:, :spr, 1].reshape(cap)
            valid = blk_m[:, :spr].reshape(cap) > 0
            n = jnp.sum(valid.astype(jnp.int32))
            if mode == "apply":
                order = cand
            elif mode == "select":
                alpha, beta, delta, permpos = rest
                order = FRK.select_full_order_device(
                    u, v, valid, num_vertices, cand, ks,
                    alpha[0], beta[0], delta[0], permpos, use_pallas=use_pallas,
                )
            else:  # greedy: the mirror's exact decision arrives as an operand
                use_cand, alpha, beta, delta, permpos = rest
                order = jax.lax.cond(
                    use_cand[0] > 0,
                    lambda: cand,
                    lambda: FRK.full_order_device(
                        u, v, valid, num_vertices, alpha[0], beta[0], delta[0], permpos
                    ),
                )
            tgt = SRK.splice_targets_device(n, k, spr, cap)
            j = jnp.arange(cap, dtype=jnp.int32)
            live = j < n
            new_u = jnp.zeros(cap + 1, jnp.int32).at[tgt].set(
                jnp.where(live, u[order], 0)
            )[:cap]
            new_v = jnp.zeros(cap + 1, jnp.int32).at[tgt].set(
                jnp.where(live, v[order], 0)
            )[:cap]
            new_m = jnp.zeros(cap + 1, jnp.float32).at[tgt].set(
                live.astype(jnp.float32)
            )[:cap]
            blk = jnp.stack([new_u.reshape(k, spr), new_v.reshape(k, spr)], axis=-1)
            blk = jnp.concatenate([blk, jnp.zeros((k, 1, 2), jnp.int32)], axis=1)
            mblk = jnp.concatenate(
                [new_m.reshape(k, spr), jnp.zeros((k, 1), jnp.float32)], axis=1
            )
            return edges.at[rows].set(blk), mask.at[rows].set(mblk)

        s_edges, s_mask, _ = SH.engine_shardings(mesh)
        # No donation by design — see the docstring.
        program = jax.jit(rebuild, out_shardings=(s_edges, s_mask))
        return self._programs.put(key, program)

    def _partial_rung(self) -> None:
        """Execute the partial rung in the configured mode. Host bookkeeping
        (slot array, drift counters) always advances through the orderer —
        via the byte-exact numpy mirror for the device modes — so the monitor
        needs no device readback."""
        with self.tracer.span("rung.partial"):
            self._partial_rung_inner()

    def _partial_rung_inner(self) -> None:
        o = self.orderer
        if self.span_repair == "host":
            o.partial_reorder()  # slot ops picked up by _sync_pending below
            self._sync_pending()
            self.last_repair = "host"
            return
        r0, r1 = o.span_bounds()
        u, v, valid = o.span_arrays(r0, r1)
        if int(valid.sum()) < 2:
            self.last_repair = "skipped"
            return
        if self.span_repair == "device":
            cand = SRK.identity_candidate(valid)
        else:  # "oracle" | "differential": host geo_order on the span
            cand = o.geo_span_candidate(u, v, valid)
        use_cand = False
        if self.span_repair == "oracle":
            o.apply_span_order(r0, r1, cand, emit_ops=False)
        else:
            _, use_cand = o.partial_reorder_mirror(
                region=r0, candidate=cand, emit_ops=False
            )
        self._span_repair_device(r0, r1, cand, use_cand)
        self.last_repair = self.span_repair

    def _span_repair_device(
        self, r0: int, r1: int, cand: np.ndarray, use_cand: bool
    ) -> None:
        """Run the cached span-repair program over regions [r0, r1): extract
        the span's live slots from the sharded buffers, re-order, splice back
        — one program, nothing read back (the host mirror already advanced
        the slot array, so the call is left ASYNC and overlaps the next
        batch's host placement). In the production mode the mirror's exact
        candidate decision ships as a scalar operand; differential mode keeps
        the whole selection — objectives included — on device."""
        o = self.orderer
        g = SH.graph_axis_size(self.mesh)
        rows = np.asarray(
            [SH.partition_row(p, o.regions, g) for p in range(r0, r1)], dtype=np.int32
        )
        mode = {"oracle": "apply", "differential": "select"}.get(self.span_repair, "greedy")
        program = self._span_program(
            mode, o.regions, self.data.k_pad, int(self.data.edges.shape[1]),
            r1 - r0, self.mesh,
        )
        edges, mask = program(
            self.data.edges,
            self.data.mask,
            self._host_operand(rows),
            self._host_operand(np.asarray(cand, dtype=np.int32)),
            # shape (1,), not 0-d: put_global's row-block math needs an axis
            self._host_operand(np.asarray([1 if use_cand else 0], dtype=np.int32)),
        )
        # Block here so the rung's reported cost INCLUDES the device program
        # (honest accounting: without this, async dispatch would push the
        # repair's runtime into whatever next touches the buffers).
        jax.block_until_ready(edges)
        # Degrees untouched: a re-order never changes the graph.
        self.data = dataclasses.replace(self.data, edges=edges, mask=mask)

    def _span_key(self, mode: str, k: int, k_pad: int, e_cap: int, s: int, mesh):
        ks = SRK.eval_ks(self.orderer.config.k_min, self.orderer.config.k_max)
        # Pallas custom calls don't SPMD-partition: only single-device,
        # single-process programs route the objective's distinct counting
        # through the segment_rf boundary kernel (same integers either way).
        use_pallas = SH.graph_axis_size(mesh) == 1 and compat.process_count() == 1
        return ("span_repair", mode, k, k_pad, e_cap, s, ks, use_pallas, mesh)

    def _span_program(self, mode: str, k: int, k_pad: int, e_cap: int, s: int, mesh):
        """Span-repair program, cached per static signature: kind-prefixed in
        the shared LRU; span length, k, and e_max changes all re-key.

        Modes: ``greedy`` recomputes the expansion order on device and takes
        the mirror's candidate decision as a scalar operand (production —
        nothing travels device→host); ``select`` scores both orders on device
        too (differential); ``apply`` applies the candidate verbatim (the
        geo_order oracle)."""
        spr = e_cap - 1
        cap = s * spr
        key = self._span_key(mode, k, k_pad, e_cap, s, mesh)
        ks, use_pallas = key[6], key[7]
        cached = self._programs.get(key)
        if cached is not None:
            return cached
        num_vertices = self.num_vertices

        def repair(edges, mask, rows, cand, use_cand):
            blk_e = edges[rows]  # (s, e_cap, 2) — span rows only
            blk_m = mask[rows]
            u = blk_e[:, :spr, 0].reshape(cap)
            v = blk_e[:, :spr, 1].reshape(cap)
            valid = blk_m[:, :spr].reshape(cap) > 0
            n = jnp.sum(valid.astype(jnp.int32))
            if mode == "apply":
                order = cand
            elif mode == "select":
                order = SRK.select_span_order_device(
                    u, v, valid, num_vertices, cand, ks, use_pallas=use_pallas
                )
            else:
                # greedy: the mirror's exact candidate decision arrives as an
                # operand; lax.cond executes ONLY the taken branch, so when
                # the current layout already scored best the program skips
                # the expansion-order compute and is a pure gap re-spread.
                order = jax.lax.cond(
                    use_cand[0] > 0,
                    lambda: cand,
                    lambda: SRK.span_order_device(u, v, valid, num_vertices),
                )
            tgt = SRK.splice_targets_device(n, s, spr, cap)
            j = jnp.arange(cap, dtype=jnp.int32)
            live = j < n
            new_u = jnp.zeros(cap + 1, jnp.int32).at[tgt].set(
                jnp.where(live, u[order], 0)
            )[:cap]
            new_v = jnp.zeros(cap + 1, jnp.int32).at[tgt].set(
                jnp.where(live, v[order], 0)
            )[:cap]
            new_m = jnp.zeros(cap + 1, jnp.float32).at[tgt].set(
                live.astype(jnp.float32)
            )[:cap]
            blk = jnp.stack([new_u.reshape(s, spr), new_v.reshape(s, spr)], axis=-1)
            blk = jnp.concatenate([blk, jnp.zeros((s, 1, 2), jnp.int32)], axis=1)
            mblk = jnp.concatenate(
                [new_m.reshape(s, spr), jnp.zeros((s, 1), jnp.float32)], axis=1
            )
            return edges.at[rows].set(blk), mask.at[rows].set(mblk)

        s_edges, s_mask, _ = SH.engine_shardings(mesh)
        jit_kwargs = {"out_shardings": (s_edges, s_mask)}
        if self.donate:
            program = donate_jit(repair, donate_argnums=(0, 1), **jit_kwargs)
        else:
            program = jax.jit(repair, **jit_kwargs)
        return self._programs.put(key, program)

    def rf_vs_oracle(self, k: Optional[int] = None) -> tuple[float, float]:
        """(incremental RF, full geo_order re-run RF) at k (default: current
        partition count) — the acceptance margin check."""
        return self.orderer.rf_vs_oracle(self.k if k is None else int(k))
