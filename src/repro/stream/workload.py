"""Open-loop query workload: deterministic bursty + diurnal arrival process.

The serving benchmark needs a traffic model, not just an update stream: an
OPEN-loop arrival process (arrivals don't wait for completions — the
millions-of-users regime, where load is exogenous) whose intensity moves
enough to exercise the autoscaler in both directions. ``OpenLoopWorkload``
composes three deterministic factors per tick:

* a **diurnal ramp** — one sinusoid period over ``day_ticks``, swinging the
  base rate by ``diurnal_amp`` (the scale-out morning and scale-in night);
* **bursts** — every ``burst_every``-th tick multiplies the rate by
  ``burst_factor`` (flash crowds; what hysteresis must NOT chase);
* **hash jitter** — ±``jitter`` of the tick's rate, drawn from the same
  stateless splitmix hash the update stream uses.

Everything is a pure function of (seed, tick) via ``core.baselines.mix_hash``
— the SyntheticStream contract — so any process replays the identical
workload: same arrival counts, same query kinds, same SSSP sources. No RNG
state, no wall clock; the serve loop supplies its own (virtual) timeline.
"""
from __future__ import annotations

import dataclasses
import math

from ..core.baselines import mix_hash

__all__ = ["OpenLoopWorkload", "QueryArrival"]


@dataclasses.dataclass(frozen=True)
class QueryArrival:
    """One query landing in the serve queue: what to run, against whom."""

    tick: int  # arrival tick (the open-loop timeline index)
    kind: str  # "pagerank" | "sssp" | "wcc"
    source: int  # SSSP source vertex (hash-drawn; ignored by other kinds)


class OpenLoopWorkload:
    """Deterministic open-loop arrival generator.

    ``arrivals(t)`` returns the queries landing during tick ``t`` — a pure
    function of (seed, t), so ticks may be generated in any order or by any
    process. ``rate(t)`` exposes the modeled intensity (queries/tick, before
    integer rounding) for plots and assertions.
    """

    def __init__(
        self,
        *,
        num_vertices: int,
        base_rate: float = 4.0,
        day_ticks: int = 64,
        diurnal_amp: float = 0.75,
        burst_every: int = 0,
        burst_factor: float = 4.0,
        burst_len: int = 1,
        jitter: float = 0.25,
        mix: tuple = (("pagerank", 2), ("sssp", 5), ("wcc", 3)),
        seed: int = 0,
    ):
        if base_rate < 0:
            raise ValueError("base_rate must be >= 0")
        if day_ticks < 1:
            raise ValueError("day_ticks must be >= 1")
        if not 0.0 <= diurnal_amp < 1.0:
            raise ValueError("diurnal_amp must be in [0, 1)")
        if burst_every < 0 or burst_factor < 1.0 or burst_len < 1:
            raise ValueError("burst_every >= 0, burst_factor >= 1, burst_len >= 1")
        if not 0.0 <= jitter < 1.0:
            raise ValueError("jitter must be in [0, 1)")
        weights = [int(w) for _, w in mix]
        if not mix or any(w < 0 for w in weights) or sum(weights) == 0:
            raise ValueError("mix must carry at least one positive weight")
        self.num_vertices = int(num_vertices)
        self.base_rate = float(base_rate)
        self.day_ticks = int(day_ticks)
        self.diurnal_amp = float(diurnal_amp)
        self.burst_every = int(burst_every)
        self.burst_factor = float(burst_factor)
        self.burst_len = int(burst_len)
        self.jitter = float(jitter)
        self.seed = int(seed)
        # Flatten the kind mix into a weight-replicated pick table: a single
        # hash mod len(table) draws the kind with the configured odds.
        self._kinds: tuple = tuple(k for k, w in mix for _ in range(int(w)))

    # ------------------------------------------------------------------ model
    def is_burst(self, t: int) -> bool:
        """Ticks ``[n*burst_every, n*burst_every + burst_len)`` for n >= 1 are
        burst ticks — a pure function of the index, like SyntheticStream's."""
        if self.burst_every <= 0:
            return False
        return t >= self.burst_every and (t % self.burst_every) < self.burst_len

    def rate(self, t: int) -> float:
        """Modeled arrival intensity at tick ``t`` (queries/tick, fractional).

        base × diurnal sinusoid × burst multiplier × hash jitter. The
        sinusoid starts at the trough (tick 0 = deepest night) so a workload
        opens calm, ramps through the day, and falls back — one scale-out and
        one scale-in per day by construction.
        """
        phase = 2.0 * math.pi * (t % self.day_ticks) / self.day_ticks
        diurnal = 1.0 - self.diurnal_amp * math.cos(phase)
        r = self.base_rate * diurnal
        if self.is_burst(t):
            r *= self.burst_factor
        if self.jitter > 0.0:
            h = int(mix_hash(self.seed, t, 0, 11)) % 10_000
            r *= 1.0 + self.jitter * (h / 5_000.0 - 1.0)  # ±jitter, hash-drawn
        return r

    def count(self, t: int) -> int:
        """Integer arrivals during tick ``t``: floor(rate) plus one more with
        probability frac(rate), decided by hash — so the long-run mean equals
        the modeled rate without any RNG state."""
        r = self.rate(t)
        n = int(r)
        frac = r - n
        if frac > 0.0 and (int(mix_hash(self.seed, t, 1, 13)) % 10_000) < frac * 10_000:
            n += 1
        return n

    # ------------------------------------------------------------------- api
    def arrivals(self, t: int) -> list:
        """The queries landing during tick ``t`` (possibly empty)."""
        out = []
        for i in range(self.count(t)):
            h = int(mix_hash(self.seed, t, i, 17))
            kind = self._kinds[h % len(self._kinds)]
            source = int(mix_hash(self.seed, t, i, 19)) % max(1, self.num_vertices)
            out.append(QueryArrival(tick=int(t), kind=kind, source=source))
        return out
