"""Streaming-graph subsystem: incremental edge ordering + on-device ingest.

The paper's pipeline is preprocess-once (GEO) then rescale-forever; this
package extends it to *evolving* graphs (the SDP / xDGP workload class in
PAPERS.md) without giving up the O(k)-plan, Thm.-2-minimal rescale property:

* ``updates``     — ``EdgeUpdateBatch`` (inserts + deletes) and a deterministic
                    splitmix-style synthetic dynamic-graph generator.
* ``incremental`` — host-side incremental maintenance of the GEO-ordered edge
                    list under updates (gap-buffer / packed-memory-array slot
                    layout, locality-best placement, bounded partial re-order).
* ``ingest``      — on-device ingest: jitted scatter of update batches into
                    per-partition slack slots of the (optionally mesh-sharded)
                    engine pack, and a compact/gather program that rescales the
                    streaming pack k→k' without leaving the mesh.
* ``spill``       — cold-region spill layer: bounded-resident host mirror
                    (LRU-by-escalation region blocks to host/disk) and the
                    lean content-addressed ingestor the out-of-core path
                    streams through.
* ``workload``    — open-loop query traffic model (bursty + diurnal arrival
                    process, stateless-hash deterministic) for the serving
                    front end and the autoscaler benchmarks.
"""
from .updates import EdgeUpdateBatch, SyntheticStream  # noqa: F401
from .incremental import IncrementalOrderer, StreamConfig, best_insert_position  # noqa: F401
from .ingest import StreamingEngine, IngestStats, StreamRescaleStats  # noqa: F401
from .spill import SpillConfig, SpillStore, OutOfCoreIngestor  # noqa: F401
from .workload import OpenLoopWorkload, QueryArrival  # noqa: F401
