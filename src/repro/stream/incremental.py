"""Incremental maintenance of the GEO-ordered edge list under updates.

The ordered edge list (DESIGN.md §2 — the single source of truth every packed
layout views) is held in a gap-buffer / packed-memory-array **slot array**:
``capacity = regions · slots_per_region`` slots, each empty or holding one
edge. Region p (== device partition p of the streaming pack) owns the
contiguous slot range ``[p·spr, (p+1)·spr)``; the logical edge order is slot
order restricted to occupied slots. Gaps are the per-partition slack capacity
(DESIGN.md §9): inserting an edge fills a gap, deleting tombstones a slot, and
neither shifts any other edge — which is what lets the device mirror apply an
``EdgeUpdateBatch`` as a tiny scatter instead of a re-pack.

Placement policy (the incremental analogue of GEO's locality greedy): a new
edge's *target* is the median slot of its endpoints' existing edges; candidate
regions — the median's region vs append-at-end — are scored by the exact
Eq.-(7)-style region objective delta ``(u ∉ V_p) + (v ∉ V_p)`` maintained in
O(1) per-region vertex counters, so locality placement never scores worse than
appending. The free slot nearest the target is used, searched within the
two-hop δ window reused from ``core/ordering.py`` (δ = capacity / k_max by
default); ``best_insert_position`` is the exact ``ordering_objective`` oracle
of the same decision, used by the property tests.

Escalation ladder (DESIGN.md §9): when the monitored objective drifts past a
threshold, the partial rung re-orders only the degraded span of regions —
on-mesh by default (``ingest.StreamingEngine`` delegates via
``maybe_escalate(partial_fn=...)`` and this class advances the host slot
array through ``partial_reorder_mirror``, the byte-exact numpy twin of the
device program in ``kernels/span_reorder.py``); ``partial_reorder`` keeps the
host ``geo_order``-on-the-span rung, which doubles as the repair-quality
oracle. ``full_rebuild`` re-runs ``geo_order`` on the whole current graph — a
full ``geo_order`` re-run is the oracle the incremental order must stay
within ``StreamConfig.rf_margin`` of (``rf_vs_oracle``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core import cep, metrics, ordering
from ..core.graph import Graph
from .updates import EdgeUpdateBatch

__all__ = ["StreamConfig", "IncrementalOrderer", "SlotOp", "best_insert_position"]


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Tuning knobs of the incremental orderer + quality monitor."""

    slack: float = 0.5  # free-slot fraction per region (gap-buffer headroom)
    k_min: int = ordering.K_MIN_DEFAULT  # objective range for GEO re-runs
    k_max: int = ordering.K_MAX_DEFAULT
    delta: Optional[int] = None  # placement search window; None → capacity // k_max
    partial_drift: float = 1.04  # normalized drift that triggers a span re-order
    full_drift: float = 1.08  # drift that escalates to a full geo_order rebuild
    span_regions: int = 1  # width (in regions) of a partial re-order
    partial_cooldown: int = 0  # monitor steps to skip after a partial repair
    # (hysteresis: a span repair needs fresh updates before repairing again
    # pays for itself; 0 = PR-3 behavior, re-fire while over threshold)
    rf_margin: float = 1.10  # incremental RF must stay within this × oracle RF

    def __post_init__(self):
        if not 0.0 < self.slack:
            raise ValueError("slack must be > 0")
        if self.partial_drift > self.full_drift:
            raise ValueError("partial_drift must not exceed full_drift")


@dataclasses.dataclass(frozen=True)
class SlotOp:
    """One slot mutation for the device mirror. ``u, v`` are always the edge's
    endpoints — on a tombstone (valid=False) the device writes zeros to the
    slot but still needs the endpoints for the degree update."""

    slot: int
    u: int
    v: int
    valid: bool


def best_insert_position(
    src_o: np.ndarray,
    dst_o: np.ndarray,
    u: int,
    v: int,
    num_vertices: int,
    k: int,
) -> int:
    """Exact-objective oracle of the incremental placement decision.

    Candidates are the median position of (u, v)'s existing edges and
    append-at-end; each is scored by ``ordering.ordering_objective`` with
    ``k_min = k_max = k`` on the list-with-insertion. Returns the best
    insertion index (ties → the median, i.e. locality wins). By construction
    the returned position's objective is never worse than append-at-end —
    the invariant the production O(1) region-counter placement approximates.
    Tiny lists only (each candidate costs a full objective evaluation).
    """
    src_o = np.asarray(src_o, dtype=np.int64)
    dst_o = np.asarray(dst_o, dtype=np.int64)
    n = src_o.shape[0]
    hits = np.flatnonzero((src_o == u) | (dst_o == u) | (src_o == v) | (dst_o == v))
    candidates = [int(n)]  # append-at-end is always a candidate
    if hits.size:
        candidates.insert(0, int(hits[hits.size // 2]))

    def objective(pos: int) -> float:
        s = np.insert(src_o, pos, min(u, v))
        d = np.insert(dst_o, pos, max(u, v))
        return ordering.ordering_objective(s, d, n + 1, num_vertices, k, k)

    scores = [objective(p) for p in candidates]
    return candidates[int(np.argmin(scores))]  # argmin keeps first on ties


class IncrementalOrderer:
    """Maintains the ordered edge list in a region-sliced slot array.

    The slot array is the host source of truth the device streaming pack
    mirrors slot-for-slot (``ingest.StreamingEngine``); ``drain_ops`` hands
    the engine exactly the slots each ``apply`` touched.
    """

    def __init__(
        self,
        src_ordered: np.ndarray,
        dst_ordered: np.ndarray,
        num_vertices: int,
        *,
        regions: int,
        config: StreamConfig = StreamConfig(),
    ):
        if regions < 1:
            raise ValueError("regions must be >= 1")
        self.num_vertices = int(num_vertices)
        self.config = config
        self.needs_resync = False  # set by re-layouts; cleared by the engine
        self._cooldown = 0  # partial-rung hysteresis counter (maybe_escalate)
        self._ops: dict[int, SlotOp] = {}
        self._deg_delta: dict[int, int] = {}  # vertex → degree change since drain
        # Async full-rebuild recording: while a rebuild is in flight, every
        # applied batch is ALSO queued here so the commit can replay it onto
        # the rebuilt order (DESIGN.md §11). None = no rebuild in flight.
        self._rebuild_delta: Optional[list] = None
        self._layout(
            np.asarray(src_ordered, dtype=np.int64),
            np.asarray(dst_ordered, dtype=np.int64),
            regions,
        )
        self._set_baseline()

    # ------------------------------------------------------------ properties
    @property
    def regions(self) -> int:
        return self._regions

    @property
    def slots_per_region(self) -> int:
        return self._spr

    @property
    def capacity(self) -> int:
        return self._regions * self._spr

    @property
    def num_edges(self) -> int:
        return len(self._edge2slot)

    @property
    def delta(self) -> int:
        if self.config.delta is not None:
            return int(self.config.delta)
        return max(1, self.capacity // self.config.k_max)

    # ---------------------------------------------------------------- layout
    def _layout(self, src_o: np.ndarray, dst_o: np.ndarray, regions: int, spr: Optional[int] = None) -> None:
        """(Re)build the slot array from an ordered list: CEP chunk at
        k=regions, each chunk's edges spread evenly over its region's slots so
        gaps are interleaved (PMA style) and early inserts never shift."""
        e = int(src_o.shape[0])
        if spr is None:
            raw = max(2, int(np.ceil(e * (1.0 + self.config.slack) / regions)))
            prev = self._spr if getattr(self, "_regions", None) == regions else None
            if prev is not None and prev >= raw:
                # Same region count and the current width still fits: KEEP it.
                # slots_per_region defines the device buffer width, i.e. the
                # static signature of every cached scatter / compact /
                # span-repair program — a full rebuild at |E|+500 must not
                # recompile three programs.
                spr = prev
            else:
                # Fresh width: 25% growth headroom, 256-aligned, so a k-phase
                # of steady ingest re-laying out at every full rebuild stays
                # on one program signature (compiles only at k changes, which
                # the engine warms inside the rescale).
                spr = max(2, -(-int(np.ceil(raw * 1.25)) // 256) * 256)
        self._regions = int(regions)
        self._spr = int(spr)
        # Checkpoint bookkeeping (DESIGN.md §15): a re-layout rewrites every
        # region, invalidates slot-addressed recovery ops, and changes the
        # chunk geometry the incremental snapshot addresses by — bump the
        # epoch so the checkpoint layer forces a full snapshot.
        self.layout_epoch = getattr(self, "layout_epoch", -1) + 1
        self._dirty_regions: set[int] = set(range(int(regions)))
        self._rec_ops: dict[int, tuple[int, int, bool]] = {}
        c = self.capacity
        self.slot_src = np.zeros(c, dtype=np.int64)
        self.slot_dst = np.zeros(c, dtype=np.int64)
        self.slot_valid = np.zeros(c, dtype=bool)
        self._edge2slot: dict[tuple[int, int], int] = {}
        self._incident: dict[int, set] = {}
        self._rc: list[dict[int, int]] = [dict() for _ in range(regions)]
        self._free = np.full(regions, self._spr, dtype=np.int64)  # free slots/region
        # Per-region sorted free-slot arrays, built lazily (one vectorized scan
        # per region per batch) and maintained incrementally as slots fill /
        # free — the batched replacement for the per-insert occupancy rescans
        # the placement loop used to do (ROADMAP follow-up).
        self._free_cache: list = [None] * int(regions)
        self._gather_from = None  # new slot ← old slot; only relayout builds it
        if e == 0:
            return
        # Vectorized fill (the same CEP spread the device splice computes):
        # the per-edge dict/set bookkeeping below is bulk-built — this runs on
        # every full rebuild and relayout, so it must not out-cost geo_order.
        bounds = np.asarray(cep.chunk_bounds(e, regions), dtype=np.int64)
        sizes = np.diff(bounds)
        if int(sizes.max()) > self._spr:
            p_bad = int(np.argmax(sizes))
            raise ValueError(
                f"region {p_bad} chunk ({int(sizes[p_bad])} edges) exceeds "
                f"slots_per_region={self._spr}"
            )
        j = np.arange(e, dtype=np.int64)
        p = np.asarray(cep.id2p(e, regions, j), dtype=np.int64)
        n_p = bounds[p + 1] - bounds[p]
        cols = ((j - bounds[p]) * self._spr) // n_p
        slots = p * self._spr + cols
        self.slot_src[slots] = src_o
        self.slot_dst[slots] = dst_o
        self.slot_valid[slots] = True
        self._free -= np.bincount(p, minlength=regions)
        self._edge2slot = dict(zip(zip(src_o.tolist(), dst_o.tolist()), slots.tolist()))
        self._rebuild_region_counts(0, regions, p, src_o, dst_o)
        idx, ws, starts, ends = self._vertex_groups(np.concatenate([src_o, dst_o]))
        sslots = np.concatenate([slots, slots])[idx].tolist()
        self._incident = {
            w: set(sslots[a:b]) for w, a, b in zip(ws, starts, ends)
        }

    def _set_baseline(self) -> None:
        """Record the current normalized objective as 'fresh-GEO quality'.

        Called at construction and after full rebuilds ONLY: partial reorders
        and re-layouts must not move the yardstick, or gradual degradation
        hides behind repeated rebaselining."""
        self._baseline_kappa = self._kappa()

    def _kappa(self) -> float:
        """Σ_p |V(region_p)| normalized by the Thm.-6-style capacity
        |V| + |E| + k, which makes the signal comparable across graph growth
        and region-count changes (both Σ|V_p| and the bound scale with them)."""
        return self.region_vertex_sum() / max(1, self.num_vertices + self.num_edges + self._regions)

    # -------------------------------------------------------------- counters
    @staticmethod
    def _vertex_groups(verts: np.ndarray):
        """Group a per-incidence vertex array: returns (idx, vertices, starts,
        ends) where ``idx`` sorts the incidences by vertex and group g of the
        sorted payload is ``[starts[g]:ends[g]]`` for ``vertices[g]`` — the
        shared bulk-build step of ``_layout`` and ``_rewrite_span``'s
        incident-set bookkeeping."""
        if verts.size == 0:
            return np.zeros(0, dtype=np.int64), [], [], []
        idx = np.argsort(verts, kind="stable")
        sv = verts[idx]
        cut = np.flatnonzero(np.diff(sv)) + 1
        starts = np.concatenate([[0], cut])
        ends = np.concatenate([cut, [sv.size]])
        return idx, sv[starts].tolist(), starts.tolist(), ends.tolist()

    def _rebuild_region_counts(
        self, base: int, regions: int, p: np.ndarray, src_o: np.ndarray, dst_o: np.ndarray
    ) -> None:
        """Region vertex counters for regions [base, base+regions) rebuilt
        from their chunk assignment ``p`` — a region's counts are fully
        determined by its chunk's endpoints."""
        for ridx in range(regions):
            sel = p == ridx
            ids, cnt = np.unique(
                np.concatenate([src_o[sel], dst_o[sel]]), return_counts=True
            )
            self._rc[base + ridx] = dict(zip(ids.tolist(), cnt.tolist()))

    def _count(self, region: int, vertex: int, d: int) -> None:
        rc = self._rc[region]
        n = rc.get(vertex, 0) + d
        if n <= 0:
            rc.pop(vertex, None)
        else:
            rc[vertex] = n

    def region_vertex_sum(self) -> int:
        """Σ_p |V(region_p)| — the monitored Eq.-(7)-style objective (equal to
        ``ordering_objective·|V|`` at k=regions when region fills are equal)."""
        return int(sum(len(rc) for rc in self._rc))

    def drift(self) -> float:
        """Normalized objective now vs at the last full-quality order (init or
        full rebuild): the quality monitor's escalation signal. 1.0 = as good
        as fresh GEO; growth alone is not drift (see ``_kappa``)."""
        return self._kappa() / max(self._baseline_kappa, 1e-12)

    # ----------------------------------------------------------------- apply
    def apply(self, batch: EdgeUpdateBatch) -> dict:
        """Apply one update batch to the slot array. Returns counts
        {inserted, deleted, skipped}. Deletes run first so a batch that
        replaces edges reuses the freed slots. Device-mirror ops accumulate in
        ``drain_ops`` order-insensitively (last write per slot wins)."""
        ins = batch.insert
        if ins.size:
            # Whole-batch range check, vectorized (negative ids would silently
            # wrap in both host np.add.at and the device scatter): reject the
            # batch before any mutation instead of dying halfway through it.
            bad = (ins[:, 0] < 0) | (ins[:, 1] >= self.num_vertices)
            if np.any(bad):
                u, v = ins[int(np.flatnonzero(bad)[0])].tolist()
                raise ValueError(f"edge ({u}, {v}) out of range (|V|={self.num_vertices})")
        if self._rebuild_delta is not None:
            # Double-buffer protocol: the live slot array keeps advancing
            # below; the queued copy replays onto the rebuilt order at commit.
            self._rebuild_delta.append(batch)
        inserted = deleted = skipped = 0
        for u, v in batch.delete.tolist():
            if self._delete(int(u), int(v)):
                deleted += 1
            else:
                skipped += 1
        for u, v in batch.insert.tolist():
            r = self._insert(int(u), int(v))
            if r is None:
                skipped += 1
            else:
                inserted += 1
        return {"inserted": inserted, "deleted": deleted, "skipped": skipped}

    def _delete(self, u: int, v: int) -> bool:
        s = self._edge2slot.pop((u, v), None)
        if s is None:
            return False
        region = s // self._spr
        self.slot_valid[s] = False
        self.slot_src[s] = 0
        self.slot_dst[s] = 0
        self._free[region] += 1
        self._cache_freed(s)
        for w in (u, v):
            inc = self._incident.get(w)
            if inc is not None:
                inc.discard(s)
                if not inc:
                    del self._incident[w]
            self._count(region, w, -1)
            self._deg_delta[w] = self._deg_delta.get(w, 0) - 1
        self._ops[s] = SlotOp(s, u, v, False)
        self._rec_ops[s] = (u, v, False)
        self._dirty_regions.add(region)
        return True

    def _insert(self, u: int, v: int) -> Optional[int]:
        if u == v:
            return None
        u, v = (u, v) if u < v else (v, u)
        if (u, v) in self._edge2slot:
            return None
        if u < 0 or v >= self.num_vertices:
            # Negative ids would silently wrap in both host np.add.at and the
            # device scatter, crediting some other vertex's degree.
            raise ValueError(f"edge ({u}, {v}) out of range (|V|={self.num_vertices})")
        slot = self._place(u, v)
        if slot is None:
            # All regions full: grow the slot array in place (same order,
            # bigger gaps) and retry — the engine re-uploads on resync.
            self.grow()
            slot = self._place(u, v)
            assert slot is not None
        region = slot // self._spr
        self.slot_src[slot] = u
        self.slot_dst[slot] = v
        self.slot_valid[slot] = True
        self._free[region] -= 1
        self._cache_fill(slot)
        self._edge2slot[(u, v)] = slot
        self._incident.setdefault(u, set()).add(slot)
        self._incident.setdefault(v, set()).add(slot)
        self._count(region, u, +1)
        self._count(region, v, +1)
        self._deg_delta[u] = self._deg_delta.get(u, 0) + 1
        self._deg_delta[v] = self._deg_delta.get(v, 0) + 1
        self._ops[slot] = SlotOp(slot, u, v, True)
        self._rec_ops[slot] = (u, v, True)
        self._dirty_regions.add(region)
        return slot

    def _median_slot(self, u: int, v: int) -> Optional[int]:
        """Median incident slot of (u, v) via an O(d) numpy partial sort — the
        element at sorted index d // 2, exactly what sorting would pick."""
        union = self._incident.get(u, set()) | self._incident.get(v, set())
        if not union:
            return None
        arr = np.fromiter(union, dtype=np.int64, count=len(union))
        mid = arr.size // 2
        return int(np.partition(arr, mid)[mid])

    def _place(self, u: int, v: int) -> Optional[int]:
        """Locality-best free slot for (u, v) — see module docstring."""
        target = self._median_slot(u, v)
        candidates: list[int] = []
        if target is not None:
            candidates.append(target // self._spr)
        append_region = self._append_region()
        if append_region is not None and append_region not in candidates:
            candidates.append(append_region)
        candidates = [r for r in candidates if self._free[r] > 0]
        if not candidates:
            return self._any_free_slot(target)
        # Exact region-objective delta: +1 per endpoint the region hasn't seen.
        # min() keeps the FIRST best — the median region — on ties, so
        # locality placement never scores worse than append-at-end.
        best = min(candidates, key=lambda r: (u not in self._rc[r]) + (v not in self._rc[r]))
        want = target if (target is not None and target // self._spr == best) else best * self._spr
        slot = self._free_in(best, near=want)
        if target is not None and slot is not None and abs(slot - target) > self.delta and best != append_region:
            # The δ window around the locality target is saturated: the edge
            # would land far from its neighbors anyway, so fall back to append.
            alt = self._free_in(append_region) if append_region is not None else None
            if alt is not None:
                return alt
        return slot

    def _append_region(self) -> Optional[int]:
        """Region of the append-at-end position: the last region with a free
        slot (append-at-end of the occupied prefix). O(k) via the per-region
        free counts — no occupancy rescans on the insert hot path."""
        for r in range(self._regions - 1, -1, -1):
            if self._free[r] > 0:
                return r
        return None

    def _free_slots(self, region: int) -> np.ndarray:
        """Sorted absolute slot ids of ``region``'s free slots, from the
        incremental cache (scanned at most once per region between bulk
        re-layouts; kept exact by ``_cache_fill`` / ``_cache_freed``)."""
        a = self._free_cache[region]
        if a is None:
            lo = region * self._spr
            a = lo + np.flatnonzero(~self.slot_valid[lo : lo + self._spr])
            self._free_cache[region] = a
        return a

    def _cache_fill(self, slot: int) -> None:
        a = self._free_cache[slot // self._spr]
        if a is not None:
            self._free_cache[slot // self._spr] = a[a != slot]

    def _cache_freed(self, slot: int) -> None:
        r = slot // self._spr
        a = self._free_cache[r]
        if a is not None:
            self._free_cache[r] = np.insert(a, int(np.searchsorted(a, slot)), slot)

    def _free_in(self, region: int, near: Optional[int] = None) -> Optional[int]:
        """Candidate-slot scoring over the cached free list: nearest free slot
        to ``near`` by |slot − near|, first-of-ties (identical decision to the
        historical per-insert occupancy rescan, minus the rescan)."""
        free = self._free_slots(region)
        if free.size == 0:
            return None
        if near is None:
            return int(free[0])
        return int(free[np.argmin(np.abs(free - near))])

    def _any_free_slot(self, near: Optional[int]) -> Optional[int]:
        free = np.concatenate([self._free_slots(r) for r in range(self._regions)])
        if free.size == 0:
            return None
        if near is None:
            return int(free[0])
        return int(free[np.argmin(np.abs(free - near))])

    # ------------------------------------------------------------ device ops
    def drain_ops(self) -> tuple[list[SlotOp], dict[int, int]]:
        """(slot mutations, per-vertex degree deltas) since the last drain.
        Slot ops are coalesced (last write per slot wins — safe because degree
        deltas are accumulated separately, so a delete+reinsert into the same
        slot still nets the right degrees). Meaningless after a re-layout —
        check ``needs_resync`` first."""
        ops = list(self._ops.values())
        deg = dict(self._deg_delta)
        self._ops.clear()
        self._deg_delta.clear()
        return ops, deg

    # --------------------------------------------------- checkpoint plumbing
    def drain_dirty_regions(self) -> list[int]:
        """Sorted region ids whose slot ranges changed since the last drain
        (inserts, deletes, span rewrites; a re-layout marks ALL regions).
        Consumed by the incremental checkpoint: snapshot cost is proportional
        to the drained set, not the slot-array size."""
        dirty = sorted(self._dirty_regions)
        self._dirty_regions.clear()
        return dirty

    def drain_recovery_ops(self) -> list[tuple[int, int, int, bool]]:
        """Coalesced ``(slot, u, v, valid)`` writes since the last drain, for
        the checkpoint WAL. Independent of ``drain_ops`` (the device-mirror
        stream): always on, and it DOES capture ``emit_ops=False`` span
        rewrites, so replaying a WAL tail onto a snapshot reproduces the slot
        array bit-exactly without re-running any placement or repair logic.
        Meaningless across a re-layout — the checkpoint layer snapshots
        instead (``layout_epoch``)."""
        ops = [(s, uvw[0], uvw[1], uvw[2]) for s, uvw in self._rec_ops.items()]
        ops.sort()
        self._rec_ops.clear()
        return ops

    @classmethod
    def from_slots(
        cls,
        slot_src: np.ndarray,
        slot_dst: np.ndarray,
        slot_valid: np.ndarray,
        num_vertices: int,
        *,
        regions: int,
        config: StreamConfig = StreamConfig(),
        baseline_kappa: Optional[float] = None,
        cooldown: int = 0,
    ) -> "IncrementalOrderer":
        """Reconstruct an orderer from a raw slot triple, preserving gaps and
        tombstone positions EXACTLY (``__init__`` would re-spread the edges
        and lose the layout). This is the checkpoint-restore path: all derived
        bookkeeping (edge→slot map, incident sets, region counters, free
        lists) is rebuilt from the arrays, and ``baseline_kappa`` /
        ``cooldown`` re-inject the monitor control state so post-restore
        escalation decisions replay identically to the pre-failure timeline."""
        slot_src = np.array(slot_src, dtype=np.int64)
        slot_dst = np.array(slot_dst, dtype=np.int64)
        slot_valid = np.array(slot_valid, dtype=bool)
        regions = int(regions)
        if regions < 1:
            raise ValueError("regions must be >= 1")
        if slot_src.shape != slot_dst.shape or slot_src.shape != slot_valid.shape:
            raise ValueError("slot arrays must share one shape")
        if slot_src.ndim != 1 or slot_src.size % regions != 0:
            raise ValueError(
                f"slot capacity {slot_src.size} is not a multiple of regions={regions}"
            )
        o = cls.__new__(cls)
        o.num_vertices = int(num_vertices)
        o.config = config
        o.needs_resync = False
        o._cooldown = int(cooldown)
        o._ops = {}
        o._deg_delta = {}
        o._rebuild_delta = None
        o._regions = regions
        o._spr = slot_src.size // regions
        o.layout_epoch = 0
        o._dirty_regions = set(range(regions))  # conservative: first snapshot is full
        o._rec_ops = {}
        o.slot_src = slot_src
        o.slot_dst = slot_dst
        o.slot_valid = slot_valid
        occ = np.flatnonzero(slot_valid)
        src_o = slot_src[occ]
        dst_o = slot_dst[occ]
        o._edge2slot = dict(zip(zip(src_o.tolist(), dst_o.tolist()), occ.tolist()))
        if len(o._edge2slot) != occ.size:
            raise ValueError("slot arrays hold duplicate edges")
        p = occ // o._spr
        o._rc = [dict() for _ in range(regions)]
        o._rebuild_region_counts(0, regions, p, src_o, dst_o)
        o._free = np.full(regions, o._spr, dtype=np.int64)
        o._free -= np.bincount(p, minlength=regions)
        o._free_cache = [None] * regions
        o._gather_from = None
        idx, ws, starts, ends = cls._vertex_groups(np.concatenate([src_o, dst_o]))
        sslots = np.concatenate([occ, occ])[idx].tolist()
        o._incident = {w: set(sslots[a:b]) for w, a, b in zip(ws, starts, ends)}
        if baseline_kappa is None:
            o._set_baseline()
        else:
            o._baseline_kappa = float(baseline_kappa)
        return o

    def drain_gather_map(self) -> np.ndarray:
        """(capacity,) int64: for each slot of the CURRENT layout, the slot of
        the previous layout it was filled from (-1 = empty). Only ``relayout``
        (the rescale path) produces one — the on-device compact program turns
        it into a single gather; grow / full_rebuild resync instead."""
        if self._gather_from is None:
            raise ValueError("no gather map: only relayout() produces one")
        gm, self._gather_from = self._gather_from, None
        return gm

    # ------------------------------------------------------------- snapshots
    def snapshot(self) -> tuple[np.ndarray, np.ndarray]:
        """The flat ordered (src, dst) lists — occupied slots in slot order."""
        vs = self.slot_valid
        return self.slot_src[vs].copy(), self.slot_dst[vs].copy()

    def graph(self) -> Graph:
        src, dst = self.snapshot()
        return Graph.from_edges(np.stack([src, dst], axis=1), self.num_vertices)

    def rf(self, k: int) -> float:
        """Replication factor of CEP chunks over the current incremental order."""
        src, dst = self.snapshot()
        return metrics.replication_factor_ordered(src, dst, k, self.num_vertices)

    def rf_vs_oracle(self, k: int, seed: int = 0) -> tuple[float, float]:
        """(incremental RF, full geo_order re-run RF) at k — the margin the
        incremental order must stay within (config.rf_margin)."""
        g = self.graph()
        order = ordering.geo_order(g, self.config.k_min, self.config.k_max, seed=seed)
        oracle = metrics.replication_factor_ordered(
            g.src[order], g.dst[order], k, self.num_vertices
        )
        return self.rf(k), oracle

    # ------------------------------------------------------------ escalation
    def escalation(
        self, full_lookahead: float = 0.0, partial_shadow: float = 0.0
    ) -> str:
        """The ladder DECISION only — 'none' | 'partial' | 'full' — so callers
        owning a device mirror (``ingest.StreamingEngine``) can execute the
        partial rung on-mesh instead of the host ``geo_order`` path.
        Thresholds are strict: drift exactly at a threshold does not fire.

        ``full_lookahead`` anticipates an asynchronous full rung: the caller
        adds its projected drift growth over the rebuild's flight window, so
        the dispatch fires early enough that the COMMIT lands at roughly the
        drift a synchronous rebuild would have repaired at. Zero (the
        default) keeps the classic instant-repair decision; the partial
        threshold never anticipates (that rung repairs synchronously).

        ``partial_shadow`` suppresses the partial rung when a full rebuild is
        projected within that drift horizon (caller-chosen, typically a
        couple of flight windows of growth): repeated span repairs on the
        same drifted layout plateau after the first pass, so a partial fired
        just before a whole-graph re-order buys nothing the imminent commit
        will not erase — the decision reports 'none' instead."""
        d = self.drift()
        if d + full_lookahead > self.config.full_drift:
            return "full"
        if d > self.config.partial_drift:
            if partial_shadow > 0.0 and d + partial_shadow > self.config.full_drift:
                return "none"
            return "partial"
        return "none"

    def maybe_escalate(
        self,
        partial_fn=None,
        full_fn=None,
        full_lookahead: float = 0.0,
        partial_shadow: float = 0.0,
    ) -> str:
        """Quality-monitor step: 'none' | 'partial' | 'full' (what ran).

        ``partial_fn`` delegates the partial rung (the streaming engine passes
        its on-device span repair; host-only replays pass the numpy mirror);
        None keeps the host ``geo_order`` span repair. ``full_fn`` delegates
        the full rung the same way — the streaming engine passes its async
        dispatch so the rebuild runs against a snapshot while ingest
        continues; None keeps the synchronous ``full_rebuild``. A fired
        partial starts a ``config.partial_cooldown``-step hysteresis window
        during which further partial triggers report 'none' (a just-repaired
        layout needs fresh updates before repairing again pays for itself);
        the full rung ignores the window and resets it. ``full_lookahead``
        and ``partial_shadow`` pass through to ``escalation()`` (async
        dispatch anticipation / partial-rung shadow suppression)."""
        rung = self.escalation(full_lookahead, partial_shadow)
        if rung == "full":
            if full_fn is None:
                self.full_rebuild()
            else:
                full_fn()
            self._cooldown = 0
        elif rung == "partial":
            if self._cooldown > 0:
                self._cooldown -= 1
                return "none"
            self._cooldown = self.config.partial_cooldown
            if partial_fn is None:
                self.partial_reorder()
            else:
                partial_fn()
        return rung

    def worst_region(self) -> int:
        """Region with the highest vertex count per occupied slot — the most
        locality-degraded span start."""
        scores = []
        for r in range(self._regions):
            lo = r * self._spr
            fill = int(self.slot_valid[lo : lo + self._spr].sum())
            scores.append(len(self._rc[r]) / max(1, fill))
        return int(np.argmax(scores))

    def span_bounds(self, region: Optional[int] = None) -> tuple[int, int]:
        """[r0, r1) region range of the repair span anchored at ``region``
        (default: the worst region), ``config.span_regions`` wide, clamped."""
        w = self.worst_region() if region is None else int(region)
        span = self.config.span_regions
        r0 = max(0, min(w, self._regions - span))
        r1 = min(self._regions, r0 + span)
        return r0, r1

    def span_arrays(self, r0: int, r1: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Copies of the span's (slot_src, slot_dst, slot_valid) slices —
        the host view of what the device span-repair program reads from the
        sharded pack_slots buffers (bit-identical by the mirror contract)."""
        lo, hi = r0 * self._spr, r1 * self._spr
        return (
            self.slot_src[lo:hi].copy(),
            self.slot_dst[lo:hi].copy(),
            self.slot_valid[lo:hi].copy(),
        )

    def geo_span_candidate(
        self, u: np.ndarray, v: np.ndarray, valid: np.ndarray, seed: int = 0
    ) -> np.ndarray:
        """Host ``geo_order`` of the span's live edges as a live-first slot
        permutation — the span repair's quality ORACLE. The production device
        rung never computes this; oracle / differential modes feed it to the
        repair program as the candidate order."""
        from ..kernels import span_reorder as SRK

        live = np.flatnonzero(valid)
        if live.size < 2:
            return SRK.identity_candidate(valid)
        sub = Graph.from_edges(
            np.stack([u[live], v[live]], axis=1), self.num_vertices
        )
        sub_order = ordering.geo_order(sub, self.config.k_min, self.config.k_max, seed=seed)
        # Map canonical sub edges back to span positions (slots hold unique
        # canonical u < v pairs, so the mapping is a bijection).
        pos = {
            (int(a), int(b)): int(s_)
            for s_, a, b in zip(live.tolist(), u[live].tolist(), v[live].tolist())
        }
        cand_live = np.asarray(
            [pos[(int(a), int(b))] for a, b in zip(sub.src[sub_order], sub.dst[sub_order])],
            dtype=np.int64,
        )
        return np.concatenate([cand_live, np.flatnonzero(~np.asarray(valid, bool))])

    def apply_span_order(
        self, r0: int, r1: int, order: np.ndarray, *, emit_ops: bool = True
    ) -> int:
        """Commit a live-first span permutation: splice the re-ordered edges
        back over regions [r0, r1) (CEP chunks spread evenly — the exact
        layout the device splice computes) and update all bookkeeping, so the
        drift monitor needs no device readback. ``emit_ops=False`` is the
        device-rung path: the repair program already rewrote the mesh rows, so
        no slot ops must travel. Returns the number of edges re-ordered."""
        lo, hi = r0 * self._spr, r1 * self._spr
        u = self.slot_src[lo:hi].copy()
        v = self.slot_dst[lo:hi].copy()
        valid = self.slot_valid[lo:hi].copy()
        n = int(valid.sum())
        order = np.asarray(order, dtype=np.int64)
        new_src = u[order[:n]]
        new_dst = v[order[:n]]
        self._rewrite_span(r0, r1, new_src, new_dst)
        if emit_ops:
            for s_ in range(lo, hi):
                self._ops[s_] = SlotOp(
                    s_, int(self.slot_src[s_]), int(self.slot_dst[s_]), bool(self.slot_valid[s_])
                )
        return n

    def partial_reorder(self, region: Optional[int] = None) -> int:
        """Bounded re-order of only the degraded span: GEO on the subgraph
        induced by ``span_regions`` consecutive regions' edges, spliced back
        into the same slots. Returns the number of edges re-ordered. The
        rewrite is emitted as ordinary slot ops (one op per span slot), so the
        device mirror follows with the same scatter program ingest uses — no
        full re-upload; degrees are untouched (a re-order never changes the
        graph). This is the HOST rung — the streaming engine's default runs
        the repair on-mesh instead (``partial_reorder_mirror`` + the span
        program of kernels/span_reorder.py)."""
        r0, r1 = self.span_bounds(region)
        u, v, valid = self.span_arrays(r0, r1)
        if int(valid.sum()) < 2:
            return 0
        cand = self.geo_span_candidate(u, v, valid)
        return self.apply_span_order(r0, r1, cand)

    def partial_reorder_mirror(
        self,
        region: Optional[int] = None,
        *,
        candidate: Optional[np.ndarray] = None,
        emit_ops: bool = True,
    ) -> tuple[int, bool]:
        """Partial rung via the numpy mirror of the DEVICE span repair
        (kernels/span_reorder.py): neighbor-expansion order vs ``candidate``
        (default: the current layout), better of the two by the exact span
        objective. Returns (edges re-ordered, chose_candidate). Byte-identical
        to what the on-mesh program writes — the differential-oracle
        contract."""
        from ..kernels import span_reorder as SRK

        r0, r1 = self.span_bounds(region)
        u, v, valid = self.span_arrays(r0, r1)
        if int(valid.sum()) < 2:
            return 0, False
        if candidate is None:
            candidate = SRK.identity_candidate(valid)
        ks = SRK.eval_ks(self.config.k_min, self.config.k_max)
        order, chose = SRK.select_span_order_host(
            u, v, valid, self.num_vertices, candidate, ks
        )
        n = self.apply_span_order(r0, r1, order, emit_ops=emit_ops)
        return n, chose

    def _rewrite_span(self, r0: int, r1: int, src_o: np.ndarray, dst_o: np.ndarray) -> None:
        """Rewrite regions [r0, r1) with the span order (CEP chunks spread
        evenly). Bookkeeping is vectorized on the partial-rung hot path: a
        re-order rewrites the SAME edge multiset, so ``_edge2slot`` needs only
        value updates (one C-level dict.update), region counters rebuild from
        per-chunk ``np.unique``, and incident sets swap old↔new slots in
        per-vertex bulk ops — this host pass rides along every device span
        repair, so it must not cost what the repair saves."""
        spr = self._spr
        lo, hi = r0 * spr, r1 * spr
        src_o = np.asarray(src_o, dtype=np.int64)
        dst_o = np.asarray(dst_o, dtype=np.int64)
        e = int(src_o.shape[0])
        old_rel = np.flatnonzero(self.slot_valid[lo:hi])
        old_slots = lo + old_rel
        old_u = self.slot_src[old_slots].copy()
        old_v = self.slot_dst[old_slots].copy()
        same_edges = e == old_slots.size and np.array_equal(
            np.sort(old_u * self.num_vertices + old_v),
            np.sort(src_o * self.num_vertices + dst_o),
        )
        if not same_edges:
            # General path (never hit by re-orders): old edges leave the maps.
            for s_, a, b in zip(old_slots.tolist(), old_u.tolist(), old_v.tolist()):
                del self._edge2slot[(a, b)]
                for w in (a, b):
                    inc = self._incident.get(w)
                    if inc is not None:
                        inc.discard(s_)
                        if not inc:
                            del self._incident[w]
        self.slot_valid[lo:hi] = False
        self.slot_src[lo:hi] = 0
        self.slot_dst[lo:hi] = 0
        self._free[r0:r1] = spr
        for r in range(r0, r1):  # bulk rewrite: rescan these regions lazily
            self._free_cache[r] = None
        # Re-fill: CEP chunks of the span order over the span regions, slot
        # targets computed in one closed-form vector pass (the exact layout
        # kernels/span_reorder.splice_targets_device writes on the mesh).
        regions = r1 - r0
        if e:
            j = np.arange(e, dtype=np.int64)
            p = np.asarray(cep.id2p(e, regions, j), dtype=np.int64)
            bounds = np.asarray(cep.chunk_bounds(e, regions), dtype=np.int64)
            n_p = bounds[p + 1] - bounds[p]
            cols = ((j - bounds[p]) * spr) // n_p
            slots = (r0 + p) * spr + cols
            self.slot_src[slots] = src_o
            self.slot_dst[slots] = dst_o
            self.slot_valid[slots] = True
            self._free[r0:r1] -= np.bincount(p, minlength=regions)
            self._edge2slot.update(
                zip(zip(src_o.tolist(), dst_o.tolist()), slots.tolist())
            )
        else:
            p = np.zeros(0, dtype=np.int64)
            slots = np.zeros(0, dtype=np.int64)
        self._rebuild_region_counts(r0, regions, p, src_o, dst_o)
        # Incident sets: swap each affected vertex's old span slots for its
        # new ones in one difference/update pair per vertex.
        if same_edges:
            # Align old and new slots per EDGE: both keyed by (u, v); the
            # edge multiset is identical, so sorting by edge key pairs them.
            old_key = np.argsort(old_u * self.num_vertices + old_v, kind="stable")
            new_key = np.argsort(src_o * self.num_vertices + dst_o, kind="stable")
            edge_new_slot = np.empty(e, dtype=np.int64)
            edge_new_slot[old_key] = slots[new_key]
            idx, ws, starts, ends = self._vertex_groups(np.concatenate([old_u, old_v]))
            # python-list slicing beats np.split's per-group view construction
            olds_l = np.concatenate([old_slots, old_slots])[idx].tolist()
            news_l = np.concatenate([edge_new_slot, edge_new_slot])[idx].tolist()
            for w, g0, g1 in zip(ws, starts, ends):
                inc = self._incident[w]
                inc.difference_update(olds_l[g0:g1])
                inc.update(news_l[g0:g1])
        else:
            for s_, a, b in zip(slots.tolist(), src_o.tolist(), dst_o.tolist()):
                self._incident.setdefault(a, set()).add(s_)
                self._incident.setdefault(b, set()).add(s_)
        self._dirty_regions.update(range(r0, r1))
        # Recovery ops: the span rewrite touched every slot of [lo, hi), and
        # the device rung's emit_ops=False path bypasses ``_ops`` entirely —
        # the checkpoint WAL must still see the writes (post-rewrite content).
        self._rec_ops.update(
            zip(
                range(lo, hi),
                zip(
                    self.slot_src[lo:hi].tolist(),
                    self.slot_dst[lo:hi].tolist(),
                    self.slot_valid[lo:hi].tolist(),
                ),
            )
        )

    def full_rebuild(self, seed: int = 0) -> None:
        """Escalation terminal: re-run geo_order on the current graph and
        re-layout every slot. Sets ``needs_resync``."""
        g = self.graph()
        order = ordering.geo_order(g, self.config.k_min, self.config.k_max, seed=seed)
        self._layout(g.src[order].astype(np.int64), g.dst[order].astype(np.int64), self._regions)
        self._finish_relayout()
        self._set_baseline()  # a fresh GEO order IS the new quality yardstick

    # -------------------------------------------------- async full rebuild
    @property
    def rebuild_in_flight(self) -> bool:
        return self._rebuild_delta is not None

    @property
    def rebuild_delta_batches(self) -> int:
        """Batches queued for replay by the in-flight rebuild (0 if none)."""
        return len(self._rebuild_delta) if self._rebuild_delta is not None else 0

    def begin_full_rebuild(self) -> tuple[np.ndarray, np.ndarray]:
        """Start the double-buffered rebuild protocol (DESIGN.md §11): return
        the ordered snapshot the rebuild will re-order, and start queuing
        every subsequently applied batch for the commit's replay. The live
        slot array keeps serving ingest untouched. The caller (the streaming
        engine) must be device-synced — pending slot ops are NOT snapshotted."""
        if self._rebuild_delta is not None:
            raise ValueError("a full rebuild is already in flight")
        self._rebuild_delta = []
        return self.snapshot()

    def abort_full_rebuild(self) -> int:
        """Drop the in-flight rebuild (re-layout / rescale invalidated its
        snapshot). Returns the number of queued batches discarded; drift
        stays as-is, so the ladder simply re-fires later."""
        n = self.rebuild_delta_batches
        self._rebuild_delta = None
        return n

    def commit_full_rebuild(self, cand_src: np.ndarray, cand_dst: np.ndarray) -> bool:
        """Commit an async rebuild: re-layout to the candidate order of the
        SNAPSHOT (``begin_full_rebuild``'s edge list, re-ordered), replay the
        batches queued during the flight, and re-baseline the drift monitor.

        Returns True when the commit kept the slot-array shape: the slot ops
        accumulated by the replay then describe EXACTLY the delta between the
        candidate layout and the committed state — the engine drains them into
        the device splice program, so the device never re-uploads. Returns
        False when the layout width changed underneath (the candidate chunks
        outgrew ``slots_per_region``, or a replayed insert forced ``grow``):
        the caller must resync (``needs_resync`` is set).

        The caller must be device-synced before calling (the engine's monitor
        is): pending ops are dropped, and the replay's degree deltas are
        discarded because the flight's ingests already applied them to the
        live device degrees — a re-order never changes the graph."""
        if self._rebuild_delta is None:
            raise ValueError("no full rebuild in flight")
        delta, self._rebuild_delta = self._rebuild_delta, None
        spr_before = self._spr
        self._ops.clear()
        self._deg_delta.clear()
        self._layout(
            np.asarray(cand_src, dtype=np.int64),
            np.asarray(cand_dst, dtype=np.int64),
            self._regions,
        )
        shape_kept = self._spr == spr_before
        for batch in delta:
            self.apply(batch)  # may grow() → needs_resync, handled below
        self._deg_delta.clear()  # flight ingests already applied these
        self._set_baseline()  # rebuilt + replayed = the new quality yardstick
        if not shape_kept or self.needs_resync:
            self._ops.clear()
            self.needs_resync = True
            return False
        return True

    def relayout(self, regions: int) -> None:
        """Re-slice the CURRENT incremental order into ``regions`` regions
        (rescale k→k' under ingest: order unchanged, slots re-chunked). Sets
        ``needs_resync``; ``drain_gather_map`` feeds the on-device compact."""
        d = self.drift()  # Σ|V_p| scales with the region count, so carry the
        src_o, dst_o = self.snapshot()  # drift VALUE across the k change
        old_slot = self._slot_of_edges(src_o, dst_o)
        self._layout(src_o, dst_o, int(regions))
        self._map_gather(old_slot, src_o, dst_o)
        self._finish_relayout()
        self._baseline_kappa = self._kappa() / max(d, 1e-12)

    def grow(self, factor: float = 2.0) -> None:
        """Enlarge slots_per_region (same region count, same order, bigger
        gaps) when the array runs out of free slots. Sets ``needs_resync``."""
        d = self.drift()
        src_o, dst_o = self.snapshot()
        spr = max(self._spr + 1, int(np.ceil(self._spr * factor)))
        self._layout(src_o, dst_o, self._regions, spr=spr)
        self._finish_relayout()
        self._baseline_kappa = self._kappa() / max(d, 1e-12)

    def _slot_of_edges(self, src_o: np.ndarray, dst_o: np.ndarray) -> dict:
        return {
            (int(a), int(b)): self._edge2slot[(int(a), int(b))]
            for a, b in zip(src_o.tolist(), dst_o.tolist())
        }

    def _map_gather(self, old_slot: dict, src_o: np.ndarray, dst_o: np.ndarray) -> None:
        gm = np.full(self.capacity, -1, dtype=np.int64)
        occupied = np.flatnonzero(self.slot_valid)
        for s_ in occupied.tolist():
            key = (int(self.slot_src[s_]), int(self.slot_dst[s_]))
            gm[s_] = old_slot[key]
        self._gather_from = gm

    def _finish_relayout(self) -> None:
        self._ops.clear()
        self._deg_delta.clear()
        self.needs_resync = True
