"""Chunked checkpointing with CEP-resharded restore.

Layout on disk:
  <dir>/step_<N>/manifest.json        tensor names, shapes, dtypes, k_shards
  <dir>/step_<N>/shard_<h>.npz        host h's CEP chunk of every tensor
                                      (flattened-index chunking per tensor)

Restore onto k' ≠ k hosts reads, per tensor, only the old shards overlapping
each new chunk (the CEP overlay plan) — a failed/preempted host's replacement
pulls O(1/k) of the state, not a full reshuffle.
"""
from __future__ import annotations

import json
import pathlib

import jax
import numpy as np

from ..core import cep


def _flatten_named(tree) -> list:
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, np.asarray(leaf)))
    return out


def save(tree, directory, step: int, k_shards: int) -> pathlib.Path:
    d = pathlib.Path(directory) / f"step_{step}"
    d.mkdir(parents=True, exist_ok=True)
    named = _flatten_named(tree)
    manifest = {
        "step": step,
        "k_shards": k_shards,
        "tensors": [
            {"name": n, "shape": list(a.shape), "dtype": str(a.dtype)} for n, a in named
        ],
    }
    (d / "manifest.json").write_text(json.dumps(manifest))
    for h in range(k_shards):
        shard = {}
        for n, a in named:
            flat = a.reshape(-1)
            b = cep.chunk_bounds(flat.shape[0], k_shards)
            shard[n] = flat[int(b[h]) : int(b[h + 1])]
        np.savez(d / f"shard_{h}.npz", **shard)
    return d


def restore(directory, step: int, k_new: int, template=None) -> tuple:
    """Returns (tree_or_named_dict, bytes_read_per_new_host list).

    Each new host h' reads only old shards overlapping its new chunk; we
    account bytes read per host to demonstrate Thm.-2 restore cost.
    """
    d = pathlib.Path(directory) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    k_old = manifest["k_shards"]
    shards = [np.load(d / f"shard_{h}.npz") for h in range(k_old)]
    arrays = {}
    bytes_touched = 0
    for t in manifest["tensors"]:
        n, shape, dtype = t["name"], tuple(t["shape"]), t["dtype"]
        total = int(np.prod(shape)) if shape else 1
        ob = cep.chunk_bounds(max(total, 1), k_old)
        flat = np.empty(total, dtype=dtype)
        for h in range(k_old):
            lo, hi = int(ob[h]), int(ob[h + 1])
            if hi > lo:
                flat[lo:hi] = shards[h][n]
        arrays[n] = flat.reshape(shape)
        if k_new != k_old:
            bytes_touched += cep.migrated_edges_exact(max(total, 1), k_old, k_new) * flat.itemsize
    if template is not None:
        leaves_with_path = jax.tree_util.tree_flatten_with_path(template)[0]
        treedef = jax.tree_util.tree_structure(template)
        ordered = []
        for path, leaf in leaves_with_path:
            name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            ordered.append(arrays[name].astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, ordered), bytes_touched
    return arrays, bytes_touched
