"""Chunked checkpointing with CEP-resharded restore + the incremental
slot-state checkpoint the failure-recovery path restores from (DESIGN.md §15).

Two layers share this module:

**Tree store** (``save`` / ``restore``) — the PR-7 contract: a pytree is
flattened to named tensors, each chunked by the CEP bounds at ``k_shards``,
so a replacement host pulls only the old shards overlapping its new chunk
(Thm.-2 restore cost, not a full reshuffle). Error paths raise typed
``CheckpointError`` subclasses — never silently corrupt arrays.

**Incremental slot checkpoint** (``SlotCheckpoint``) — the durable state of
the streaming runtime. Layout on disk::

  <dir>/chunk_r<region>_s<step>.npz   one region's slot range (src/dst/valid)
  <dir>/manifest_<step>.json          geometry + per-region chunk_step map +
                                      monitor control state; written via
                                      tmp+rename, so a partial snapshot is
                                      INVISIBLE (crash mid-commit falls back
                                      to the previous manifest)
  <dir>/wal.jsonl                     write-behind log: one record per ingest
                                      batch (coalesced slot writes from
                                      ``drain_recovery_ops`` — including
                                      emit_ops=False device span repairs —
                                      plus the raw batch and the monitor's
                                      baseline/cooldown after it) and one
                                      barrier record per executed rescale

A snapshot writes only the regions the orderer dirtied since the last one
(``drain_dirty_regions``) and carries clean regions forward by reference in
``chunk_step`` — snapshot cost is proportional to touched chunks. Layout
changes (grow, full-rebuild commit, resync) dirty every region AND invalidate
slot-addressed ops, so ``note_batch`` forces a full snapshot instead of a WAL
record; executed rescales write a ``scale`` barrier record the replay handles
with ``relayout`` (a pure function of slot state). Restore = latest manifest's
chunks + the WAL tail replayed as raw slot writes — bit-exact by construction,
no placement or repair logic re-runs. ``restore(partitions=...)`` reads only
the lost regions' chunks and replays only their slots' ops: recovery cost
scales with lost partitions, not graph size.
"""
from __future__ import annotations

import json
import os
import pathlib
from typing import Optional

import jax
import numpy as np

from ..core import cep

__all__ = [
    "CheckpointError",
    "MissingStepError",
    "TemplateMismatchError",
    "CorruptShardError",
    "SlotCheckpoint",
    "save",
    "restore",
]


class CheckpointError(Exception):
    """Base class of every typed checkpoint failure."""


class MissingStepError(CheckpointError):
    """The requested step directory / manifest does not exist."""


class TemplateMismatchError(CheckpointError):
    """The restore ``template``'s named leaves do not match the manifest."""


class CorruptShardError(CheckpointError):
    """A shard/chunk file is missing, truncated, or inconsistent with its
    manifest — restoring it would return silently corrupt arrays."""


# --------------------------------------------------------------- tree store
def _flatten_named(tree) -> list:
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, np.asarray(leaf)))
    return out


def save(tree, directory, step: int, k_shards: int) -> pathlib.Path:
    d = pathlib.Path(directory) / f"step_{step}"
    d.mkdir(parents=True, exist_ok=True)
    named = _flatten_named(tree)
    manifest = {
        "step": step,
        "k_shards": k_shards,
        "tensors": [
            {"name": n, "shape": list(a.shape), "dtype": str(a.dtype)} for n, a in named
        ],
    }
    (d / "manifest.json").write_text(json.dumps(manifest))
    for h in range(k_shards):
        shard = {}
        for n, a in named:
            flat = a.reshape(-1)
            b = cep.chunk_bounds(flat.shape[0], k_shards)
            shard[n] = flat[int(b[h]) : int(b[h + 1])]
        np.savez(d / f"shard_{h}.npz", **shard)
    return d


def restore(directory, step: int, k_new: int, template=None) -> tuple:
    """Returns (tree_or_named_dict, bytes_touched).

    Each new host h' reads only old shards overlapping its new chunk; we
    account bytes read per host to demonstrate Thm.-2 restore cost. Raises
    ``MissingStepError`` when the step was never saved,
    ``CorruptShardError`` on unreadable/truncated shard files, and
    ``TemplateMismatchError`` when ``template``'s leaves don't name the
    saved tensors.
    """
    d = pathlib.Path(directory) / f"step_{step}"
    try:
        manifest = json.loads((d / "manifest.json").read_text())
    except FileNotFoundError as e:
        raise MissingStepError(f"no checkpoint at step {step} under {directory}") from e
    except (OSError, json.JSONDecodeError) as e:
        raise CorruptShardError(f"unreadable manifest for step {step}: {e}") from e
    k_old = manifest["k_shards"]
    shards = []
    for h in range(k_old):
        try:
            shards.append(np.load(d / f"shard_{h}.npz"))
        except FileNotFoundError as e:
            raise CorruptShardError(f"step {step}: shard_{h}.npz missing") from e
        except Exception as e:  # zipfile/np.load raise a zoo of types on truncation
            raise CorruptShardError(f"step {step}: shard_{h}.npz unreadable: {e}") from e
    arrays = {}
    bytes_touched = 0
    for t in manifest["tensors"]:
        n, shape, dtype = t["name"], tuple(t["shape"]), t["dtype"]
        total = int(np.prod(shape)) if shape else 1
        ob = cep.chunk_bounds(max(total, 1), k_old)
        flat = np.empty(total, dtype=dtype)
        for h in range(k_old):
            lo, hi = int(ob[h]), int(ob[h + 1])
            if hi <= lo:
                continue
            try:
                chunk = shards[h][n]
            except Exception as e:
                raise CorruptShardError(
                    f"step {step}: shard_{h}.npz lacks tensor {n!r}: {e}"
                ) from e
            if chunk.shape != (hi - lo,):
                raise CorruptShardError(
                    f"step {step}: shard_{h}.npz tensor {n!r} holds {chunk.shape}, "
                    f"manifest chunk is ({hi - lo},)"
                )
            flat[lo:hi] = chunk
        arrays[n] = flat.reshape(shape)
        if k_new != k_old:
            bytes_touched += cep.migrated_edges_exact(max(total, 1), k_old, k_new) * flat.itemsize
    if template is not None:
        leaves_with_path = jax.tree_util.tree_flatten_with_path(template)[0]
        treedef = jax.tree_util.tree_structure(template)
        want = [
            "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in leaves_with_path
        ]
        if sorted(want) != sorted(arrays):
            missing = sorted(set(want) - set(arrays))
            extra = sorted(set(arrays) - set(want))
            raise TemplateMismatchError(
                f"template treedef does not match step {step}: "
                f"template-only leaves {missing}, checkpoint-only tensors {extra}"
            )
        ordered = [
            arrays[name].astype(leaf.dtype)
            for name, (_, leaf) in zip(want, leaves_with_path)
        ]
        return jax.tree_util.tree_unflatten(treedef, ordered), bytes_touched
    return arrays, bytes_touched


# ------------------------------------------------- incremental slot snapshot
_OP_BYTES = 25  # slot + u + v (int64) + valid (bool): the WAL replay bill


def _atomic_write_text(path: pathlib.Path, text: str) -> None:
    tmp = path.with_suffix(path.suffix + ".tmp")
    tmp.write_text(text)
    os.replace(tmp, path)  # atomic on POSIX: the manifest appears whole or not at all


class SlotCheckpoint:
    """Incremental per-CEP-chunk checkpoint of an ``IncrementalOrderer``.

    Region r's slot range ``[r·spr, (r+1)·spr)`` IS its CEP chunk at
    k = regions (the slot array's capacity divides evenly), so chunk files
    are addressable per partition — exactly what a partition-scoped restore
    needs. See the module docstring for the disk layout and replay contract.
    """

    def __init__(
        self,
        directory,
        *,
        interval: int = 4,
        tracer=None,
        metrics_registry=None,
    ):
        from ..obs import metrics as obs_metrics
        from ..obs import trace as obs_trace

        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.interval = int(interval)
        self._tracer = tracer if tracer is not None else obs_trace.get_tracer()
        reg = metrics_registry if metrics_registry is not None else obs_metrics.NULL
        self._c_snapshots = reg.counter("checkpoint.snapshots")
        self._c_snapshot_bytes = reg.counter("checkpoint.snapshot_bytes")
        self._c_wal_records = reg.counter("checkpoint.wal_records")
        self._c_wal_bytes = reg.counter("checkpoint.wal_bytes")
        self._c_restore_bytes = reg.counter("checkpoint.restore_bytes")
        m = self.latest_manifest()
        self._wal_seq = self._scan_wal_seq(m["wal_seq"] if m else -1)
        self._last_snap_step = m["step"] if m else None
        # The orderer's layout epoch as of the last snapshot / scale barrier;
        # a mismatch in note_batch means the batch re-laid-out the slot array
        # (grow / rebuild commit) and slot-addressed ops can't replay across
        # it — force a full snapshot instead. None = never synced (epoch
        # counters are per-process, so a fresh process always snapshots).
        self._epoch_seen: Optional[int] = None

    # ------------------------------------------------------------- manifests
    def latest_manifest(self) -> Optional[dict]:
        """The highest-step parseable manifest, or None. Unparseable files
        (a crash can't produce one — writes are atomic — but be defensive)
        are skipped, not fatal: recovery falls back to the previous one."""
        best = None
        for p in self.dir.glob("manifest_*.json"):
            try:
                m = json.loads(p.read_text())
            except (OSError, json.JSONDecodeError):
                continue
            if best is None or m["step"] > best["step"]:
                best = m
        return best

    def _scan_wal_seq(self, floor: int) -> int:
        seq = floor
        for rec in self._wal_records_raw():
            seq = max(seq, rec["seq"])
        return seq

    def _wal_path(self) -> pathlib.Path:
        return self.dir / "wal.jsonl"

    def _wal_records_raw(self) -> list[dict]:
        """Every parseable WAL record, stopping at the first torn line (a
        SIGKILL mid-append truncates the tail; everything after the tear is
        untrusted)."""
        path = self._wal_path()
        if not path.exists():
            return []
        out = []
        for line in path.read_text().splitlines():
            if not line.strip():
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                break
        return out

    def wal_tail(self, after_seq: int) -> list[dict]:
        return [r for r in self._wal_records_raw() if r["seq"] > after_seq]

    def _append_wal(self, rec: dict) -> None:
        line = json.dumps(rec) + "\n"
        with open(self._wal_path(), "a") as f:
            f.write(line)
            f.flush()
            os.fsync(f.fileno())
        self._c_wal_records.inc()
        self._c_wal_bytes.inc(len(line))

    # ------------------------------------------------------------ write path
    def note_batch(self, orderer, batch, step: int) -> Optional[dict]:
        """Make batch ``step``'s effects durable: a WAL record of the
        coalesced slot writes it (and any span repair between batches)
        produced, or — when the batch changed the slot-array layout, or the
        snapshot interval elapsed — a snapshot. Returns the snapshot info
        dict when one was taken, else None."""
        if self._epoch_seen is None or orderer.layout_epoch != self._epoch_seen:
            # Re-layout inside the batch window (grow / rebuild commit /
            # resync): slot ops can't replay across it, and every region is
            # dirty anyway — the snapshot IS this batch's durability record.
            return self.snapshot(orderer, step)
        ops = orderer.drain_recovery_ops()
        self._append_wal(
            {
                "kind": "batch",
                "seq": self._next_seq(),
                "step": int(step),
                "insert": np.asarray(batch.insert).tolist(),
                "delete": np.asarray(batch.delete).tolist(),
                "ops": [[int(s), int(u), int(v), int(valid)] for s, u, v, valid in ops],
                "baseline_kappa": float(orderer._baseline_kappa),
                "cooldown": int(orderer._cooldown),
            }
        )
        if self._last_snap_step is None or step - self._last_snap_step >= self.interval:
            return self.snapshot(orderer, step)
        return None

    def note_scale(self, orderer, k_new: int, step: int) -> None:
        """WAL barrier for an EXECUTED rescale (``relayout`` already ran).
        Replay reconstructs the orderer at the barrier and re-runs
        ``relayout(k_new)`` — a pure function of slot state — instead of
        replaying slot ops across the geometry change."""
        orderer.drain_recovery_ops()  # invalidated by the re-layout
        self._append_wal(
            {
                "kind": "scale",
                "seq": self._next_seq(),
                "step": int(step),
                "k_new": int(k_new),
                "baseline_kappa": float(orderer._baseline_kappa),
                "cooldown": int(orderer._cooldown),
            }
        )
        self._epoch_seen = orderer.layout_epoch

    def _next_seq(self) -> int:
        self._wal_seq += 1
        return self._wal_seq

    def snapshot(self, orderer, step: int) -> dict:
        """Write the regions dirtied since the last snapshot (all of them on
        the first, or after a re-layout), carry clean regions forward by
        reference, and commit the manifest atomically. Obsolete WAL records
        are pruned after the commit. Returns
        {step, dirty_regions, bytes_written}."""
        with self._tracer.span("checkpoint.snapshot"):
            prev = self.latest_manifest()
            dirty = orderer.drain_dirty_regions()
            orderer.drain_recovery_ops()  # baked into the chunks below
            regions, spr = orderer.regions, orderer.slots_per_region
            full = (
                prev is None
                or prev["regions"] != regions
                or prev["spr"] != spr
                or self._epoch_seen is None
                or orderer.layout_epoch != self._epoch_seen
            )
            if full:
                dirty = list(range(regions))
            chunk_step = (
                {} if full else {int(r): s for r, s in prev["chunk_step"].items()}
            )
            bytes_written = 0
            for r in dirty:
                lo = r * spr
                path = self.dir / f"chunk_r{r}_s{step}.npz"
                np.savez(
                    path,
                    src=orderer.slot_src[lo : lo + spr],
                    dst=orderer.slot_dst[lo : lo + spr],
                    valid=orderer.slot_valid[lo : lo + spr],
                )
                chunk_step[r] = int(step)
                bytes_written += path.stat().st_size
            manifest = {
                "step": int(step),
                "regions": int(regions),
                "spr": int(spr),
                "num_vertices": int(orderer.num_vertices),
                "wal_seq": int(self._wal_seq),
                "chunk_step": {str(r): int(s) for r, s in chunk_step.items()},
                "baseline_kappa": float(orderer._baseline_kappa),
                "cooldown": int(orderer._cooldown),
            }
            # The atomic rename is the COMMIT POINT: every chunk file above is
            # already durable, and until this rename lands the previous
            # manifest still names a complete, older snapshot.
            _atomic_write_text(self.dir / f"manifest_{step}.json", json.dumps(manifest))
            self._last_snap_step = int(step)
            self._epoch_seen = orderer.layout_epoch
            self._prune(manifest)
            self._c_snapshots.inc()
            self._c_snapshot_bytes.inc(bytes_written)
            return {
                "step": int(step),
                "dirty_regions": dirty,
                "bytes_written": bytes_written,
            }

    def _prune(self, manifest: dict) -> None:
        """Drop WAL records the new manifest covers and chunk files / old
        manifests nothing references anymore. Best-effort: a leftover file is
        garbage, never corruption (restore goes through the manifest)."""
        keep = self.wal_tail(manifest["wal_seq"])
        text = "".join(json.dumps(r) + "\n" for r in keep)
        _atomic_write_text(self._wal_path(), text)
        live = {f"chunk_r{r}_s{s}.npz" for r, s in manifest["chunk_step"].items()}
        live.add(f"manifest_{manifest['step']}.json")
        for p in list(self.dir.glob("chunk_r*.npz")) + list(self.dir.glob("manifest_*.json")):
            if p.name not in live:
                try:
                    p.unlink()
                except OSError:
                    pass

    # ----------------------------------------------------------- read path
    def _read_chunk(self, region: int, step: int, spr: int) -> tuple:
        path = self.dir / f"chunk_r{region}_s{step}.npz"
        try:
            with np.load(path) as z:
                src, dst, valid = z["src"], z["dst"], z["valid"]
        except FileNotFoundError as e:
            raise CorruptShardError(f"chunk file {path.name} missing") from e
        except Exception as e:
            raise CorruptShardError(f"chunk file {path.name} unreadable: {e}") from e
        if src.shape != (spr,) or dst.shape != (spr,) or valid.shape != (spr,):
            raise CorruptShardError(
                f"chunk file {path.name} holds {src.shape}, manifest spr is {spr}"
            )
        return src, dst, valid, path.stat().st_size

    @staticmethod
    def _apply_ops(slot_src, slot_dst, slot_valid, ops, only=None) -> int:
        """Replay coalesced slot writes; ``only`` filters to a region set.
        A tombstone zeroes the slot — matching what ``_delete`` wrote live,
        so replay is bit-exact, not just logically equal."""
        n = 0
        for s, u, v, valid in ops:
            if only is not None and s not in only:
                continue
            if valid:
                slot_src[s], slot_dst[s], slot_valid[s] = u, v, True
            else:
                slot_src[s], slot_dst[s], slot_valid[s] = 0, 0, False
            n += 1
        return n

    def restore(self, *, config=None):
        """Full cold restore: latest manifest's chunks + the WAL tail.

        Returns ``(orderer, info)`` where info carries the recovery point
        (``step`` = last durable batch), ``bytes_read``, ``replayed`` WAL
        records, and ``wal_steps`` (the replay-tail batch indices — what the
        staleness boundary tests pin). The orderer is reconstructed via
        ``IncrementalOrderer.from_slots`` with the WAL's final
        baseline/cooldown, so post-restore monitor decisions replay the
        pre-failure timeline exactly."""
        from ..stream.incremental import IncrementalOrderer, StreamConfig

        config = config if config is not None else StreamConfig()
        with self._tracer.span("checkpoint.restore"):
            m = self.latest_manifest()
            if m is None:
                raise MissingStepError(f"no manifest under {self.dir}")
            regions, spr = m["regions"], m["spr"]
            src = np.zeros(regions * spr, dtype=np.int64)
            dst = np.zeros(regions * spr, dtype=np.int64)
            valid = np.zeros(regions * spr, dtype=bool)
            bytes_read = 0
            for r in range(regions):
                cs = m["chunk_step"].get(str(r))
                if cs is None:
                    raise CorruptShardError(f"manifest step {m['step']} lacks region {r}")
                csrc, cdst, cvalid, nbytes = self._read_chunk(r, cs, spr)
                lo = r * spr
                src[lo : lo + spr] = csrc
                dst[lo : lo + spr] = cdst
                valid[lo : lo + spr] = cvalid
                bytes_read += nbytes
            kappa, cooldown = m["baseline_kappa"], m["cooldown"]
            tail = self.wal_tail(m["wal_seq"])
            step = m["step"]
            wal_steps = []
            for rec in tail:
                if rec["kind"] == "scale":
                    o = IncrementalOrderer.from_slots(
                        src, dst, valid, m["num_vertices"],
                        regions=regions, config=config,
                        baseline_kappa=kappa, cooldown=cooldown,
                    )
                    o.relayout(rec["k_new"])
                    regions, spr = o.regions, o.slots_per_region
                    src, dst, valid = o.slot_src, o.slot_dst, o.slot_valid
                else:
                    bytes_read += _OP_BYTES * len(rec["ops"])
                    self._apply_ops(src, dst, valid, rec["ops"])
                    wal_steps.append(rec["step"])
                kappa, cooldown = rec["baseline_kappa"], rec["cooldown"]
                step = rec["step"]
            orderer = IncrementalOrderer.from_slots(
                src, dst, valid, m["num_vertices"],
                regions=regions, config=config,
                baseline_kappa=kappa, cooldown=cooldown,
            )
            self._c_restore_bytes.inc(bytes_read)
            return orderer, {
                "step": int(step),
                "manifest_step": int(m["step"]),
                "regions": int(regions),
                "num_vertices": int(m["num_vertices"]),
                "bytes_read": int(bytes_read),
                "replayed": len(tail),
                "wal_steps": wal_steps,
            }

    def restore_partitions(self, partitions) -> tuple[dict, dict]:
        """Partition-scoped warm restore: read ONLY the lost regions' chunks
        and replay only their slots' WAL ops (valid because recovery ops are
        materialized placement decisions — no global state feeds the replay).
        Survivors keep their live state untouched. Refuses to cross a scale
        barrier (the chunk geometry changed; callers degrade to a full
        ``restore``). Returns ``({region: (src, dst, valid)}, info)``."""
        with self._tracer.span("checkpoint.restore"):
            m = self.latest_manifest()
            if m is None:
                raise MissingStepError(f"no manifest under {self.dir}")
            tail = self.wal_tail(m["wal_seq"])
            if any(r["kind"] != "batch" for r in tail):
                raise CheckpointError(
                    "partition-scoped restore cannot replay across a scale "
                    "barrier — use restore() (full)"
                )
            spr = m["spr"]
            lost = sorted({int(r) for r in partitions})
            for r in lost:
                if not 0 <= r < m["regions"]:
                    raise CheckpointError(f"region {r} out of range (k={m['regions']})")
            out = {}
            bytes_read = 0
            for r in lost:
                csrc, cdst, cvalid, nbytes = self._read_chunk(
                    r, m["chunk_step"][str(r)], spr
                )
                out[r] = (csrc.copy(), cdst.copy(), cvalid.copy())
                bytes_read += nbytes
            replayed = 0
            for rec in tail:
                for s, u, v, valid_ in rec["ops"]:
                    r = s // spr
                    if r not in out:
                        continue
                    csrc, cdst, cvalid = out[r]
                    rel = s - r * spr
                    if valid_:
                        csrc[rel], cdst[rel], cvalid[rel] = u, v, True
                    else:
                        csrc[rel], cdst[rel], cvalid[rel] = 0, 0, False
                    replayed += 1
                    bytes_read += _OP_BYTES
            self._c_restore_bytes.inc(bytes_read)
            return out, {
                "manifest_step": int(m["step"]),
                "bytes_read": int(bytes_read),
                "replayed_ops": replayed,
                "lost_bytes": int(
                    len(lost) * spr * (8 + 8 + 1)  # the lost slot state itself
                ),
            }
