from . import store  # noqa: F401
