from . import store  # noqa: F401
from .store import (  # noqa: F401
    CheckpointError,
    CorruptShardError,
    MissingStepError,
    SlotCheckpoint,
    TemplateMismatchError,
)
