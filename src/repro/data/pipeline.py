"""Deterministic synthetic token pipeline, CEP-sharded over hosts.

Every (step, global sample index, position) maps to a token via a stateless
mix hash, so any host can materialize exactly its shard of the global batch —
no data service required. Host shards are CEP chunks of the sample index
space: when the host count changes k→k±x, cep.scale_plan moves only the
boundary ranges (paper Thm. 2), and training resumes deterministically from
(step, k_new).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import cep
from ..core.baselines import mix_hash


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


NOISE_DENOM = 8  # 1/8 of positions are random; the rest follow the chain


def _tokens(dc: DataConfig, step: int, sample_ids: np.ndarray) -> np.ndarray:
    """(len(sample_ids), seq_len+1) int32 deterministic *learnable* stream.

    A noisy affine Markov chain: t_{i+1} = (a·t_i + c) mod V with probability
    7/8, else a fresh hash draw — stateless per (seed, step, sample, pos), so
    any host shard reproduces exactly its rows, yet a model can learn the
    transition and the loss visibly decreases.
    """
    n = sample_ids.shape[0]
    s = dc.seq_len + 1
    pos = np.arange(s, dtype=np.uint64)[None, :]
    sid = sample_ids.astype(np.uint64)[:, None]
    # Same stateless draw as every other deterministic stream in the repo:
    # (seed, step, sample, pos) through core.baselines.mix_hash.
    h = mix_hash(dc.seed, step, sid, pos)
    rand_tok = (h % np.uint64(dc.vocab_size)).astype(np.int64)
    is_noise = (h >> np.uint64(32)) % np.uint64(NOISE_DENOM) == 0
    a = 7 if dc.vocab_size % 7 else 11
    out = np.empty((n, s), dtype=np.int64)
    out[:, 0] = rand_tok[:, 0]
    for i in range(1, s):
        chain = (out[:, i - 1] * a + 3) % dc.vocab_size
        out[:, i] = np.where(is_noise[:, i], rand_tok[:, i], chain)
    return out.astype(np.int32)


def global_batch(dc: DataConfig, step: int) -> dict:
    toks = _tokens(dc, step, np.arange(dc.global_batch))
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def host_batch(dc: DataConfig, step: int, k_hosts: int, host: int) -> dict:
    """This host's CEP chunk of the step's global batch."""
    bounds = cep.chunk_bounds(dc.global_batch, k_hosts)
    ids = np.arange(int(bounds[host]), int(bounds[host + 1]))
    toks = _tokens(dc, step, ids)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:], "sample_ids": ids}


def rescale_moves(dc: DataConfig, k_old: int, k_new: int):
    """Sample-range migration plan for an elastic data-shard rescale."""
    return cep.scale_plan(dc.global_batch, k_old, k_new)
