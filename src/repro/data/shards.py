"""Per-process RMAT shard generation — stateless in the shard index.

``core.graph.rmat_graph`` draws its quadrant bits from a *stateful* rng over
the whole edge list, so generating a 2^23+-edge graph means materializing
2^23+ edges in one host process. This module replaces the rng with the
repo-wide stateless draw (``core.baselines.mix_hash``, the same helper
``SyntheticStream`` and ``data/pipeline`` hash through): every **candidate
index** ``i ∈ [0, num_candidates)`` maps to an edge as a pure function of
``(seed, i)``, so

* any process can generate exactly its shard — candidate range
  ``chunk_bounds(num_candidates, num_shards)[s : s+2)`` — with O(shard)
  memory and zero coordination;
* a "shuffle" between generation shards and consumer chunks (dgl's
  ``data_shuffle`` ships edges over the NIC for this) is just a *re-scan*:
  whoever needs an edge regenerates it;
* sampling for the hierarchical orderer's locality pass is free: generate
  every ``stride``-th candidate directly instead of scanning and discarding.

Candidates are canonicalized (``lo < hi``) and self-loops dropped — both
pure per-candidate decisions, so shard edge counts are additive across
shards. Duplicate candidates (inherent to RMAT) are KEPT by default: global
dedup needs global state, and the downstream hierarchical orderer handles
duplicates locally (core/hier_order.py packs copies adjacent to their first
occurrence, which costs nothing in locality). ``dedup=True`` dedups *within*
the requested range for in-core use.

Vertex ids are scrambled by a stateless invertible mix (odd-multiply +
xor-shift on ``scale`` bits) standing in for ``rmat_graph``'s rng
permutation — the default candidate order carries no id locality, same as
the in-core generator's contract.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import cep
from ..core.baselines import mix_hash, splitmix64

__all__ = ["RmatShardPlan", "candidate_edges", "shard_edges", "sample_edges", "stream_edges"]

# Salt lanes of the per-candidate draws (distinct from SyntheticStream's 1/2/3/7).
_SALT_QUAD = 101  # + bit index: quadrant draw of that RMAT recursion level
_SALT_STREAM = 211  # insert-stream lane (stream_edges)


@dataclasses.dataclass(frozen=True)
class RmatShardPlan:
    """A sharded RMAT graph, defined entirely by its parameters.

    The graph IS the plan: any process holding it can materialize any shard,
    sample, or single candidate, bit-identically. ``num_candidates`` counts
    raw draws; the realized edge count is slightly lower (self-loops drop).
    """

    scale: int
    edge_factor: int = 16
    a: float = 0.57
    b: float = 0.19
    c: float = 0.19
    seed: int = 0
    num_shards: int = 1

    @property
    def num_vertices(self) -> int:
        return 1 << self.scale

    @property
    def num_candidates(self) -> int:
        return self.num_vertices * self.edge_factor

    def shard_bounds(self) -> np.ndarray:
        """(num_shards+1,) candidate-index bounds — CEP chunks of the
        candidate space, so shard counts rebalance by Thm. 2 when
        num_shards changes."""
        return np.asarray(cep.chunk_bounds(self.num_candidates, self.num_shards))


def _scramble(v: np.ndarray, scale: int, seed: int) -> np.ndarray:
    """Stateless invertible permutation of [0, 2^scale): odd multiply +
    xor-shift rounds, constants drawn from the seed — destroys the quadrant
    id locality the same way rmat_graph's rng permutation does."""
    mask = np.uint64((1 << scale) - 1)
    c1 = (splitmix64(np.uint64(seed) + np.uint64(0xA5)) | np.uint64(1)) & mask
    c2 = (splitmix64(np.uint64(seed) + np.uint64(0xC3)) | np.uint64(1)) & mask
    s1 = max(1, scale // 2)
    s2 = max(1, (2 * scale) // 3)
    x = v.astype(np.uint64)
    with np.errstate(over="ignore"):
        x = (x * c1) & mask
        x ^= x >> np.uint64(s1)
        x = (x * c2) & mask
        x ^= x >> np.uint64(s2)
    return x


def candidate_edges(plan: RmatShardPlan, idx: np.ndarray, *, dedup: bool = False) -> np.ndarray:
    """(n, 2) int64 canonical edges of the given candidate indices.

    Pure in (plan.seed, idx): per recursion bit, a mix_hash draw picks the
    RMAT quadrant against the cumulative (a, b, c, d) thresholds on the u64
    scale. Self-loops are dropped (a per-candidate decision, so counts stay
    additive across shards); duplicates are kept unless ``dedup``.
    """
    idx = np.asarray(idx, dtype=np.uint64).reshape(-1)
    src = np.zeros(idx.shape[0], dtype=np.uint64)
    dst = np.zeros(idx.shape[0], dtype=np.uint64)
    d = 1.0 - plan.a - plan.b - plan.c
    cum = np.cumsum([plan.a, plan.b, plan.c, d])
    # Thresholds on the u64 scale (exact integer arithmetic); the last is
    # forced to 2^64-1 so rounding can never leave a draw unassigned.
    t = np.asarray(
        [min(int(x * 2**64), 2**64 - 1) for x in cum[:-1]] + [2**64 - 1], dtype=np.uint64
    )
    for bit in range(plan.scale):
        h = mix_hash(plan.seed, idx, bit, _SALT_QUAD)
        q = np.searchsorted(t, h, side="left").astype(np.uint64)
        src |= ((q >> np.uint64(1)) & np.uint64(1)) << np.uint64(bit)
        dst |= (q & np.uint64(1)) << np.uint64(bit)
    src = _scramble(src, plan.scale, plan.seed)
    dst = _scramble(dst, plan.scale, plan.seed + 1)
    lo = np.minimum(src, dst).astype(np.int64)
    hi = np.maximum(src, dst).astype(np.int64)
    keep = lo != hi
    edges = np.stack([lo[keep], hi[keep]], axis=1)
    if dedup:
        key = edges[:, 0] * np.int64(plan.num_vertices) + edges[:, 1]
        _, first = np.unique(key, return_index=True)
        edges = edges[np.sort(first)]
    return edges


def shard_edges(plan: RmatShardPlan, shard: int, *, dedup: bool = False) -> np.ndarray:
    """(n_s, 2) int64 edges of shard ``shard`` — THE per-process generator.
    O(shard) memory, stateless in the shard index: process p materializes
    shard p (or any other; regeneration is the shuffle)."""
    if not 0 <= shard < plan.num_shards:
        raise ValueError(f"shard {shard} outside [0, {plan.num_shards})")
    b = plan.shard_bounds()
    return candidate_edges(plan, np.arange(int(b[shard]), int(b[shard + 1])), dedup=dedup)


def sample_edges(plan: RmatShardPlan, stride: int, *, dedup: bool = True) -> np.ndarray:
    """Every ``stride``-th candidate, generated DIRECTLY (no full scan) —
    the bounded-memory locality sample core/hier_order.py builds its vertex
    rank from. Deduped by default (the sample feeds a Graph)."""
    if stride < 1:
        raise ValueError("stride must be >= 1")
    return candidate_edges(plan, np.arange(0, plan.num_candidates, stride), dedup=dedup)


def stream_edges(plan: RmatShardPlan, batch: int, size: int, *, salt: int = 0) -> np.ndarray:
    """(≤size, 2) int64 candidate INSERT edges for stream batch ``batch`` — a
    stateless insert stream over the plan's vertex set, for out-of-core
    ingest where SyntheticStream's live-set tracking (O(|E|) host state)
    is exactly what we must not hold. Draws are uniform pairs through the
    same mix_hash; self-loops drop, so batches may run slightly short."""
    pos = np.arange(size, dtype=np.uint64)
    nv = np.uint64(plan.num_vertices)
    u = mix_hash(plan.seed, batch, pos, _SALT_STREAM + 2 * salt) % nv
    v = mix_hash(plan.seed, batch, pos, _SALT_STREAM + 2 * salt + 1) % nv
    lo = np.minimum(u, v).astype(np.int64)
    hi = np.maximum(u, v).astype(np.int64)
    keep = lo != hi
    return np.stack([lo[keep], hi[keep]], axis=1)
