from . import pipeline  # noqa: F401
from . import shards  # noqa: F401
