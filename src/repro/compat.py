"""Version-portable JAX import surface (support policy: jax >= 0.4.35).

`shard_map` has moved twice and renamed a kwarg along the way:

* jax 0.4.35 … 0.5.x — ``jax.experimental.shard_map.shard_map`` with a
  ``check_rep=`` argument;
* newer jax — top-level ``jax.shard_map`` where the argument is ``check_vma=``
  (varying-manual-axes checking, the successor of replication checking).

Repo rule: **never import shard_map directly** — always go through this
module, which resolves whichever implementation the installed jax provides
and translates ``check_vma`` to ``check_rep`` on older versions.

The module also centralises two helpers the repo used to re-derive ad hoc:
mesh axis-size lookup and a donation-safe ``jit`` wrapper (buffer donation is
a no-op-with-warning on CPU; the wrapper keeps programs identical across
backends without spamming warnings on host-only test runs).
"""
from __future__ import annotations

import functools
import inspect
import re
import warnings

import jax
from jax import lax

__all__ = [
    "JAX_VERSION",
    "shard_map",
    "axis_size",
    "mesh_axis_sizes",
    "mesh_axis_size",
    "donate_jit",
    "enable_cpu_collectives",
    "distributed_initialize",
    "process_index",
    "process_count",
    "array_from_process_local_data",
    "profiler_annotation",
]


def _version_tuple(v: str) -> tuple:
    return tuple(int(x) for x in re.findall(r"\d+", v)[:3])


JAX_VERSION: tuple = _version_tuple(jax.__version__)


def _resolve_shard_map():
    impl = getattr(jax, "shard_map", None)
    if not callable(impl):
        from jax.experimental.shard_map import shard_map as impl  # jax >= 0.4.35
    return impl


_SHARD_MAP = _resolve_shard_map()
_SHARD_MAP_PARAMS = frozenset(inspect.signature(_SHARD_MAP).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    """Portable shard_map: new-style ``check_vma`` spelled for whatever the
    installed jax accepts (``check_rep`` before the rename)."""
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kwargs["check_rep"] = check_vma
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def axis_size(axis_name: str):
    """Size of a mapped mesh axis from inside shard_map — ``lax.axis_size``
    where the installed jax has it, ``psum(1)`` (same value, traced) before
    it existed."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def mesh_axis_sizes(mesh) -> dict:
    """{axis_name: size} for a Mesh (works for Mesh and AbstractMesh —
    ``mesh.shape`` exists on both; ``mesh.devices`` does not)."""
    return dict(mesh.shape)


def mesh_axis_size(mesh, axis: str, default: int = 1) -> int:
    """Size of one mesh axis; ``default`` for axes the mesh doesn't have."""
    return mesh_axis_sizes(mesh).get(axis, default)


# --------------------------------------------------------------- distributed
def enable_cpu_collectives(impl: str = "gloo") -> bool:
    """Turn on cross-process collectives for the CPU backend.

    The knob has moved across jax releases: newer jax has the enum flag
    ``jax_cpu_collectives_implementation`` ("gloo" / "mpi"); 0.4.x spells the
    gloo case as the bool flag ``jax_cpu_enable_gloo_collectives``; very old
    jaxlibs have neither (multi-process CPU unsupported). Returns True when a
    knob was found and set. Must run before the CPU backend initializes —
    i.e. before the first jax.devices()/computation in the process.
    """
    try:
        jax.config.update("jax_cpu_collectives_implementation", impl)
        return True
    except (AttributeError, ValueError):
        pass
    if impl == "gloo":
        try:
            jax.config.update("jax_cpu_enable_gloo_collectives", True)
            return True
        except (AttributeError, ValueError):
            pass
    return False


def distributed_initialize(coordinator_address: str, num_processes: int, process_id: int) -> None:
    """``jax.distributed.initialize`` for an explicitly-specified process
    group (the repo never relies on cluster auto-detection, which varies by
    jax version and scheduler). On CPU backends the collectives implementation
    is enabled first — without it multi-process CPU meshes initialize but every
    cross-process transfer fails at run time."""
    enable_cpu_collectives()
    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=int(num_processes),
        process_id=int(process_id),
    )


def process_index() -> int:
    return int(jax.process_index())


def process_count() -> int:
    return int(jax.process_count())


def array_from_process_local_data(sharding, local_data, global_shape):
    """``jax.make_array_from_process_local_data`` with the keyword spelling
    that works across supported versions (``global_shape`` became optional /
    keyword-only along the way)."""
    try:
        return jax.make_array_from_process_local_data(sharding, local_data, global_shape)
    except TypeError:
        return jax.make_array_from_process_local_data(
            sharding, local_data, global_shape=global_shape
        )


def profiler_annotation(name: str):
    """A ``jax.profiler`` trace annotation context for ``name`` — makes host
    spans (obs/trace.py) visible inside a jax profiler capture so device
    program time can be correlated with them. The annotation class has been
    spelled both ``TraceAnnotation`` and ``TraceContext`` across releases;
    a null context when the installed jax has neither (annotation is an
    optional correlation aid, never load-bearing)."""
    prof = getattr(jax, "profiler", None)
    cls = getattr(prof, "TraceAnnotation", None) or getattr(prof, "TraceContext", None)
    if cls is None:
        import contextlib

        return contextlib.nullcontext()
    return cls(name)


def donate_jit(fn=None, *, donate_argnums=(), **jit_kwargs):
    """``jax.jit`` with buffer donation that stays quiet on backends where
    donation is unimplemented (CPU): the XLA "buffers were not usable"
    warning is suppressed at call time, everything else passes through."""
    if fn is None:
        return functools.partial(donate_jit, donate_argnums=donate_argnums, **jit_kwargs)
    jitted = jax.jit(fn, donate_argnums=donate_argnums, **jit_kwargs)

    @functools.wraps(fn)
    def call(*args, **kwargs):
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message=".*[Dd]onat.*", category=UserWarning
            )
            return jitted(*args, **kwargs)

    call.lower = jitted.lower  # keep AOT inspection available
    return call
