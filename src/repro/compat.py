"""Version-portable JAX import surface (support policy: jax >= 0.4.35).

`shard_map` has moved twice and renamed a kwarg along the way:

* jax 0.4.35 … 0.5.x — ``jax.experimental.shard_map.shard_map`` with a
  ``check_rep=`` argument;
* newer jax — top-level ``jax.shard_map`` where the argument is ``check_vma=``
  (varying-manual-axes checking, the successor of replication checking).

Repo rule: **never import shard_map directly** — always go through this
module, which resolves whichever implementation the installed jax provides
and translates ``check_vma`` to ``check_rep`` on older versions.

The module also centralises two helpers the repo used to re-derive ad hoc:
mesh axis-size lookup and a donation-safe ``jit`` wrapper (buffer donation is
a no-op-with-warning on CPU; the wrapper keeps programs identical across
backends without spamming warnings on host-only test runs).
"""
from __future__ import annotations

import functools
import inspect
import re
import warnings

import jax
from jax import lax

__all__ = [
    "JAX_VERSION",
    "shard_map",
    "axis_size",
    "mesh_axis_sizes",
    "mesh_axis_size",
    "donate_jit",
]


def _version_tuple(v: str) -> tuple:
    return tuple(int(x) for x in re.findall(r"\d+", v)[:3])


JAX_VERSION: tuple = _version_tuple(jax.__version__)


def _resolve_shard_map():
    impl = getattr(jax, "shard_map", None)
    if not callable(impl):
        from jax.experimental.shard_map import shard_map as impl  # jax >= 0.4.35
    return impl


_SHARD_MAP = _resolve_shard_map()
_SHARD_MAP_PARAMS = frozenset(inspect.signature(_SHARD_MAP).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    """Portable shard_map: new-style ``check_vma`` spelled for whatever the
    installed jax accepts (``check_rep`` before the rename)."""
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kwargs["check_rep"] = check_vma
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def axis_size(axis_name: str):
    """Size of a mapped mesh axis from inside shard_map — ``lax.axis_size``
    where the installed jax has it, ``psum(1)`` (same value, traced) before
    it existed."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def mesh_axis_sizes(mesh) -> dict:
    """{axis_name: size} for a Mesh (works for Mesh and AbstractMesh —
    ``mesh.shape`` exists on both; ``mesh.devices`` does not)."""
    return dict(mesh.shape)


def mesh_axis_size(mesh, axis: str, default: int = 1) -> int:
    """Size of one mesh axis; ``default`` for axes the mesh doesn't have."""
    return mesh_axis_sizes(mesh).get(axis, default)


def donate_jit(fn=None, *, donate_argnums=(), **jit_kwargs):
    """``jax.jit`` with buffer donation that stays quiet on backends where
    donation is unimplemented (CPU): the XLA "buffers were not usable"
    warning is suppressed at call time, everything else passes through."""
    if fn is None:
        return functools.partial(donate_jit, donate_argnums=donate_argnums, **jit_kwargs)
    jitted = jax.jit(fn, donate_argnums=donate_argnums, **jit_kwargs)

    @functools.wraps(fn)
    def call(*args, **kwargs):
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message=".*[Dd]onat.*", category=UserWarning
            )
            return jitted(*args, **kwargs)

    call.lower = jitted.lower  # keep AOT inspection available
    return call
