"""gemma2-9b [dense] — alternating local(4096)/global, logit softcaps.
[arXiv:2408.00118; hf]"""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    layer_pattern=("l", "g"), window=4096,
    attn_softcap=50.0, final_softcap=30.0,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, window=32,
)
