"""qwen3-8b [dense] — GQA kv=8, qk_norm. [hf:Qwen/Qwen3-8B; hf]"""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=12288, vocab_size=151936,
    qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
)
