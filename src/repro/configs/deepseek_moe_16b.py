"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6 fine-grained experts.
[arXiv:2401.06066; hf]"""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400,
    num_experts=64, num_shared_experts=2, experts_per_token=6, moe_d_ff=1408,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=64, vocab_size=512, num_experts=8, experts_per_token=2, moe_d_ff=64, capacity_factor=8.0,
)
