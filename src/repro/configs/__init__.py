"""Architecture registry: the 10 assigned configs (+ reduced smoke variants).

``get_config(name)`` returns the exact published dims; ``get_smoke(name)``
returns a structurally identical but tiny variant for CPU tests.
"""
from __future__ import annotations

import dataclasses
import importlib

from ..models.config import SHAPES, LONG_CONTEXT_OK, ModelConfig, ShapeSpec, cell_is_runnable

ARCH_NAMES = [
    "phi-3-vision-4.2b",
    "gemma3-4b",
    "qwen3-8b",
    "qwen2-1.5b",
    "gemma2-9b",
    "whisper-small",
    "mamba2-1.3b",
    "deepseek-moe-16b",
    "granite-moe-3b-a800m",
    "hymba-1.5b",
]

_MODULES = {
    "phi-3-vision-4.2b": "phi3_vision",
    "gemma3-4b": "gemma3_4b",
    "qwen3-8b": "qwen3_8b",
    "qwen2-1.5b": "qwen2_1_5b",
    "gemma2-9b": "gemma2_9b",
    "whisper-small": "whisper_small",
    "mamba2-1.3b": "mamba2_1_3b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "hymba-1.5b": "hymba_1_5b",
}


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.SMOKE


def all_configs() -> dict:
    return {n: get_config(n) for n in ARCH_NAMES}
