"""granite-moe-3b-a800m [moe] — 40 experts top-8, d_ff 512 per expert.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    num_experts=40, num_shared_experts=0, experts_per_token=8, moe_d_ff=512,
    num_experts_alloc=48,  # padded to a multiple of TP16; pads carry no traffic
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=48, num_heads=4, num_kv_heads=2, head_dim=12,
    d_ff=64, vocab_size=512, num_experts=8, experts_per_token=2, moe_d_ff=32, capacity_factor=8.0,
    num_experts_alloc=None,
)
