"""whisper-small [audio] — enc-dec backbone; conv/audio frontend is a stub
providing 1500 precomputed frame embeddings. [arXiv:2212.04356; unverified]"""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865,
    encoder_layers=12, encoder_seq=1536, act="gelu", tie_embeddings=True,
    # 1500 mel frames padded to 1536 by the audio stub: 1500 forces 4-wide
    # attention kv-blocks (375-trip scans); 1536 = 3×512 tiles cleanly.
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, encoder_layers=2, encoder_seq=24,
)
