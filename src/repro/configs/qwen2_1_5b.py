"""qwen2-1.5b [dense] — GQA kv=2, QKV bias. [arXiv:2407.10671; hf]"""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936,
    qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=48, num_heads=4, num_kv_heads=2, head_dim=12,
    d_ff=96, vocab_size=512,
)
