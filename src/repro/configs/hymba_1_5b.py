"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer; SWA with
three global layers (first/middle/last). [arXiv:2411.13676; hf]"""
import dataclasses
from ..models.config import ModelConfig

_PATTERN = tuple(
    "g" if i in (0, 15, 31) else "l" for i in range(32)
)

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    layer_pattern=_PATTERN, window=1024,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, window=32, ssm_state=8, ssm_head_dim=16,
    layer_pattern=("g", "l", "l"),
)
