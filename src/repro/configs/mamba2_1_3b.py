"""mamba2-1.3b [ssm] — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=1, head_dim=1,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    layer_pattern=("m",),
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, vocab_size=512, ssm_state=16, ssm_head_dim=16,
)
