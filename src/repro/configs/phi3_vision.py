"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP vision stub.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064,
    num_patches=576,  # CLIP ViT-L/14 @336: (336/14)^2 patch embeddings (stub)
    rope_theta=10_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, num_patches=8,
)
