"""gemma3-4b [dense] — 5:1 local:global sliding-window, 128k context, 262k vocab.
[hf:google/gemma-3-1b-pt; unverified]"""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262144,
    layer_pattern=("l", "l", "l", "l", "l", "g"),  # 5 local : 1 global
    window=1024, rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=6, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, window=32,
)
