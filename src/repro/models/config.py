"""Unified model configuration for the 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- attention flavor ---
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None  # gemma2 attention-logit soft cap
    final_softcap: Optional[float] = None  # gemma2 final-logit soft cap
    window: Optional[int] = None  # sliding-window size for "local" layers
    layer_pattern: tuple = ("g",)  # cycled: g=global, l=local(window), m=mamba, h=hybrid
    rope_theta: float = 10_000.0
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # Allocated expert count (≥ num_experts). Set to the next multiple of the
    # TP degree when num_experts doesn't divide it (e.g. granite 40→48);
    # padded experts get −inf router logits and carry no traffic.
    num_experts_alloc: Optional[int] = None
    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # fixed frame count from the audio frontend stub
    # --- modality stubs ---
    num_patches: int = 0  # vlm: prefix positions fed by the vision stub
    act: str = "silu"
    tie_embeddings: bool = True
    norm_eps: float = 1e-6

    # ------------------------------------------------------------------
    @property
    def experts_alloc(self) -> int:
        return self.num_experts_alloc or self.num_experts

    @property
    def d_inner(self) -> int:  # SSD inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def block_kind(self, layer: int) -> str:
        return self.layer_pattern[layer % len(self.layer_pattern)]

    def layer_windows(self) -> list:
        """Per-layer sliding window (None ⇒ global) for attention layers."""
        out = []
        for i in range(self.num_layers):
            out.append(self.window if self.block_kind(i) == "l" else None)
        return out

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for roofline."""
        d, l = self.d_model, self.num_layers
        n = self.vocab_size * d  # embed (tied head)
        if not self.tie_embeddings:
            n += self.vocab_size * d
        hq = self.num_heads * self.head_dim
        hkv = self.num_kv_heads * self.head_dim
        attn = d * hq + 2 * d * hkv + hq * d
        if self.num_experts:
            ff = 3 * d * self.moe_d_ff * (self.num_experts + self.num_shared_experts) + d * self.num_experts
        else:
            ff = 3 * d * self.d_ff if self.d_ff else 0
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            ssm = d * (2 * di + 2 * ns + nh) + di * d + self.ssm_conv * di
        # Block composition is set by FAMILY (layer_pattern only selects the
        # attention window, e.g. hymba's pattern is g/l yet every layer is
        # a hybrid attn+SSD block).
        if self.family == "ssm":
            per_layer = 2 * d + ssm
        elif self.family == "hybrid":
            per_layer = 2 * d + attn + ssm + ff
        else:
            per_layer = 2 * d + attn + ff
        n += l * per_layer
        if self.encoder_layers:
            n += self.encoder_layers * (attn + 3 * d * self.d_ff + 2 * d)
            n += l * (attn + d)  # decoder cross-attention
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_ff = 3 * d * self.moe_d_ff * self.num_experts
        act_ff = 3 * d * self.moe_d_ff * self.experts_per_token
        return full - self.num_layers * (all_ff - act_ff)


# ---------------------------------------------------------------------------
# Input shapes (assigned): every arch runs these four cells unless skipped.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic / windowed / SSM decode).
LONG_CONTEXT_OK = {"gemma3-4b", "mamba2-1.3b", "hymba-1.5b"}


def cell_is_runnable(arch_name: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_name in LONG_CONTEXT_OK
    return True
