"""Neural building blocks (pure JAX) shared by the 10 assigned architectures.

Attention is memory-efficient (double-chunked online softmax) so 32k prefill
and 500k decode lower without materializing S×S logits; per-layer sliding
windows / soft caps / qk-norm / QKV bias cover the gemma/qwen/phi variants.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


# ---------------------------------------------------------------- norms/rope
def rms_norm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x, w, b, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(dt)


def rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, hd); positions: (S,) absolute positions."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------- chunked attention
def _mask_scores(s, q_pos, k_pos, *, causal, window, kv_len):
    """s: (B, H, bq, bk) f32; window: traced scalar (0 ⇒ global)."""
    qp = q_pos[None, None, :, None]
    kp = k_pos[None, None, None, :]
    mask = jnp.ones(s.shape[-2:], dtype=bool)[None, None]
    if causal:
        mask = mask & (kp <= qp)
    if window is not None:
        win_ok = jnp.where(window > 0, kp > qp - window, True)
        mask = mask & win_ok
    if kv_len is not None:
        mask = mask & (kp < kv_len[:, None, None, None])
    return jnp.where(mask, s, NEG_INF)


def mea_attention(
    q,  # (B, H, Sq, hd)
    k,  # (B, Hkv, Sk, hd) — expanded to H inside when Hkv < H (GQA)
    v,
    *,
    causal: bool = True,
    window=None,  # None | traced scalar (0 ⇒ global, >0 ⇒ sliding)
    softcap: Optional[float] = None,
    q_offset: int | jax.Array = 0,
    kv_len: Optional[jax.Array] = None,  # (B,) valid cache lengths
    scale: Optional[float] = None,
    block_q: int = 512,
    block_kv: int = 1024,
):
    """Memory-efficient attention: lax.scan over q chunks × kv chunks with
    online softmax; O(Sq·hd + bq·bk) live memory instead of O(Sq·Sk).

    The head dim stays FLAT (no (Hkv, G) reshape): reshapes of a sharded head
    axis force XLA to all-gather activations when H doesn't tile the model
    axis (measured: ~787 MiB/layer on qwen2 @ TP16 — EXPERIMENTS.md §Perf).
    GQA is handled by explicitly broadcasting K/V to H heads.
    """
    b, hq, sq, hd = q.shape
    hkv = k.shape[1]
    if hkv != hq:  # GQA: expand KV to match query heads (broadcast, no copy
        g = hq // hkv  # until XLA decides layout)
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    sk = k.shape[2]
    scale = (hd**-0.5) if scale is None else scale
    bq = min(block_q, sq)
    bk = min(block_kv, sk)
    while sq % bq:
        bq //= 2
    while sk % bk:
        bk //= 2
    bq, bk = max(bq, 1), max(bk, 1)
    nq, nk = sq // bq, sk // bk

    q_chunks = q.reshape(b, hq, nq, bq, hd).transpose(2, 0, 1, 3, 4)
    k_chunks = k.reshape(b, hq, nk, bk, hd).transpose(2, 0, 1, 3, 4)
    v_chunks = v.reshape(b, hq, nk, bk, hd).transpose(2, 0, 1, 3, 4)

    def q_step(_, qi_qc):
        qi, qc = qi_qc
        q_pos = q_offset + qi * bq + jnp.arange(bq)
        qcf = qc.astype(jnp.float32)

        def kv_step(carry, ki_kc):
            m, l, acc = carry
            ki, kc, vc = ki_kc
            k_pos = ki * bk + jnp.arange(bk)
            s = jnp.einsum(
                "bhqd,bhkd->bhqk", qcf, kc.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            ) * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            s = _mask_scores(s, q_pos, k_pos, causal=causal, window=window, kv_len=kv_len)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vc.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hq, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, bq), jnp.float32)
        a0 = jnp.zeros((b, hq, bq, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), k_chunks, v_chunks)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    # Remat each q-chunk: backward recomputes the inner online-softmax scan,
    # so only O(bq·hd) residuals survive per chunk instead of O(bq·bk) logits.
    q_step = jax.checkpoint(q_step, policy=jax.checkpoint_policies.nothing_saveable)
    _, out_chunks = lax.scan(q_step, None, (jnp.arange(nq), q_chunks))
    out = out_chunks.transpose(1, 2, 0, 3, 4).reshape(b, hq, sq, hd)
    return out


# -------------------------------------------------------------- attention block
def attention_block(
    p: dict,
    x,  # (B, S, D)
    cfg,
    *,
    window=None,
    causal: bool = True,
    q_offset=0,
    cache: Optional[dict] = None,  # {"k","v": (B,Hkv,Smax,hd), "pos": scalar}
    kv_len=None,
    positions=None,
):
    """Self-attention with RoPE/GQA/qk-norm/bias/softcap; optional KV cache.

    Projections are head-split 3-D tensors (D, H, hd) so the head axis can be
    model-sharded (when divisible) without any sharded-dim reshape."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    from . import dist as _dist
    q = _dist.hint_bshd(jnp.einsum("bsd,dhk->bshk", x, p["wq"]))
    k = _dist.hint_bshd(jnp.einsum("bsd,dhk->bshk", x, p["wk"]))
    v = _dist.hint_bshd(jnp.einsum("bsd,dhk->bshk", x, p["wv"]))
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = q.transpose(0, 2, 1, 3)  # (B, Hq, S, hd)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    if positions is None:
        positions = q_offset + jnp.arange(s)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    sp_out = None
    if cache is not None:
        from . import dist as dist_ctx  # late import (avoids cycle)

        dst = dist_ctx.current()
        if dst is not None and dst.sp_decode and s == 1:
            # Sequence-parallel decode: sharded cache write + LSE-merged attention.
            ck = dist_ctx.sp_cache_update(dst, cache["k"], k, cache["pos"])
            cv = dist_ctx.sp_cache_update(dst, cache["v"], v, cache["pos"])
            new_cache = {"k": ck, "v": cv}
            sp_out = dist_ctx.sp_decode_attention(
                dst, q, ck, cv, cache["pos"],
                window=window, softcap=cfg.attn_softcap, scale=hd**-0.5,
            )
        else:
            ck = lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, cache["pos"], 0)
            )
            cv = lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, cache["pos"], 0)
            )
            new_cache = {"k": ck, "v": cv}
            k, v = ck, cv
            kv_len = jnp.full((b,), cache["pos"] + s, jnp.int32) if kv_len is None else kv_len

    if sp_out is not None:
        out = sp_out
    else:
        out = mea_attention(
            q, k, v,
            causal=causal, window=window, softcap=cfg.attn_softcap,
            q_offset=q_offset, kv_len=kv_len,
        )
    out = out.transpose(0, 2, 1, 3)  # (B, S, Hq, hd)
    out = _dist.hint_bsd(jnp.einsum("bshk,hkd->bsd", out, p["wo"]))
    return out, new_cache


def cross_attention_block(p, x, enc_kv, cfg):
    """Decoder cross-attention (whisper): kv from encoder output, no mask."""
    b, s, d = x.shape
    from . import dist as _dist

    q = _dist.hint_bshd(jnp.einsum("bsd,dhk->bshk", x, p["wq"])).transpose(0, 2, 1, 3)
    k, v = enc_kv  # (B, Hkv, Se, hd) precomputed from encoder output
    out = mea_attention(q, k, v, causal=False, window=None, softcap=None)
    out = out.transpose(0, 2, 1, 3)
    return _dist.hint_bsd(jnp.einsum("bshk,hkd->bsd", out, p["wo"]))


# --------------------------------------------------------------------- MLPs
def mlp_block(p, x, act: str = "silu"):
    from . import dist as _dist

    if act == "gelu":  # non-gated (whisper)
        h = _dist.hint_bsf(jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"])))
        return _dist.hint_bsd(jnp.einsum("bsf,fd->bsd", h, p["w2"]))
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w1"]))
    h = _dist.hint_bsf(h * jnp.einsum("bsd,df->bsf", x, p["w3"]))
    return _dist.hint_bsd(jnp.einsum("bsf,fd->bsd", h, p["w2"]))


# ---------------------------------------------------------------------- MoE
def moe_block(p, x, cfg):
    """Top-k routed experts with DP-local capacity dispatch + shared experts.

    The token table is grouped as (DP, T_loc, …) so every sort/scatter is
    *local to a data shard* (independent per-row ops, no cross-shard
    collectives); the only EP communication is the buffer reshard to/from
    expert-sharded layout around the expert matmuls (the logical all-to-all).
    Padded experts (cfg.experts_alloc > num_experts) get −inf router logits.
    Returns (y, aux_loss).
    """
    from . import dist as _dist

    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    ea = cfg.experts_alloc
    t = b * s
    dp = _dist.dp_size()
    if t % max(dp, 1):
        dp = 1
    tl = t // dp
    cap = int(tl * k / e * cfg.capacity_factor) + 1

    xf = _dist.hint_moe_tokens(x.reshape(dp, tl, d))
    logits = jnp.einsum(
        "gtd,de->gte", xf.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    if ea > e:  # padded experts never win top-k
        logits = jnp.where(jnp.arange(ea)[None, None, :] < e, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = lax.top_k(probs, k)  # (DP, T_loc, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- slot assignment (all scatters below touch only int32 index maps;
    # every D-sized movement is a batched *gather*, which GSPMD partitions by
    # the DP batch dim instead of replicating — scatters of (DP,E,C,D) were
    # measured to replicate the whole buffer per device) ---
    flat_e = expert_ids.reshape(dp, tl * k)
    flat_g = gate_vals.reshape(dp, tl * k)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=1)  # (DP, TK) sorted expert ids
    st = order // k  # token index of each sorted entry
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(ea)))(se)
    pos = jnp.arange(tl * k)[None, :] - jnp.take_along_axis(starts, se, axis=1)
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)  # overflow → trash slot

    gidx = jnp.arange(dp)[:, None]
    # slot → token map (tl = sentinel row of zeros), entry → slot map.
    inv = jnp.full((dp, ea, cap + 1), tl, jnp.int32).at[gidx, se, pos_c].set(
        jnp.where(keep, st, tl)
    )
    slot_of = jnp.zeros((dp, tl * k), jnp.int32).at[gidx, order].set(pos_c)
    keep_of = jnp.zeros((dp, tl * k), jnp.bool_).at[gidx, order].set(keep)

    xf_pad = jnp.concatenate([xf, jnp.zeros((dp, 1, d), x.dtype)], axis=1)
    buf = jax.vmap(lambda xr, ir: xr[ir])(xf_pad, inv)  # (DP, E, C+1, D) gather
    # EP boundary: reshard to expert-sharded for the matmuls…
    buf = _dist.hint_moe_buf(buf, shard_experts=True)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w1"]))
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["w3"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["w2"])
    # …and back to DP-local for the combine (the return all-to-all).
    ye = _dist.hint_moe_buf(ye, shard_experts=False)
    contrib = jax.vmap(lambda yr, er, pr: yr[er, pr])(ye, flat_e, slot_of)  # (DP, TK, D)
    w = (flat_g * keep_of.astype(jnp.float32)).astype(jnp.float32)
    yf = jnp.sum(
        contrib.reshape(dp, tl, k, d).astype(jnp.float32)
        * w.reshape(dp, tl, k, 1),
        axis=2,
    )
    y = yf.reshape(b, s, d).astype(x.dtype)

    if cfg.num_shared_experts:
        y = y + mlp_block(p["shared"], x, cfg.act)

    # Load-balance aux loss (Switch-style): E · Σ_e f_e · P_e.
    inc = jnp.zeros(ea, jnp.float32).at[flat_e.reshape(-1)].add(1.0) / (t * k)
    pe = probs.mean((0, 1))
    aux = e * jnp.sum(inc * pe)
    return y, aux


# ----------------------------------------------------------------- SSD (mamba2)
def _ssd_chunked(xbar, dA, B_, C_, chunk: int):
    """Chunked state-space-duality scan (Mamba2 §6 reference, JAX form).

    xbar: (B,S,H,P) inputs pre-multiplied by dt; dA: (B,S,H) log-decay per step;
    B_, C_: (B,S,N) shared across heads (ngroups=1). Returns y (B,S,H,P) and
    final state (B,H,P,N).
    """
    b, s, h, pdim = xbar.shape
    n = B_.shape[-1]
    q = min(chunk, s)
    while s % q:
        q //= 2
    c = s // q
    # Chunk-major layout so ONE chunk at a time flows through the scan: the
    # O(q²·H) intra-chunk tensors exist only inside the (rematted) body —
    # vectorizing them over all chunks cost ~35 GiB/device on mamba2 train.
    xb = xbar.reshape(b, c, q, h, pdim).transpose(1, 0, 2, 3, 4)  # (c,B,q,H,P)
    da = dA.reshape(b, c, q, h).transpose(1, 0, 2, 3)  # (c,B,q,H)
    bb = B_.reshape(b, c, q, n).transpose(1, 0, 2, 3)  # (c,B,q,N)
    cc = C_.reshape(b, c, q, n).transpose(1, 0, 2, 3)

    tri = jnp.tril(jnp.ones((q, q), bool))[None, :, :, None]

    def step(st_prev, inp):
        xbc, dac, bbc, ccc = inp  # one chunk
        cums = jnp.cumsum(dac.astype(jnp.float32), axis=1)  # (B,q,H)
        li = cums[:, :, None, :] - cums[:, None, :, :]  # (B,i,j,H)
        l_mat = jnp.where(tri, jnp.exp(li), 0.0)
        g = jnp.einsum("bin,bjn->bij", ccc, bbc)  # (B,q,q)
        m = g[..., None] * l_mat  # (B,i,j,H)
        y_diag = jnp.einsum("bijh,bjhp->bihp", m.astype(xbc.dtype), xbc)
        decay = jnp.exp(cums[:, -1:, :] - cums).astype(xbc.dtype)  # (B,q,H)
        st_c = jnp.einsum("bjn,bjh,bjhp->bhpn", bbc, decay, xbc)
        y_off = jnp.einsum(
            "bqn,bhpn,bqh->bqhp", ccc, st_prev, jnp.exp(cums).astype(xbc.dtype)
        )
        chunk_decay = jnp.exp(cums[:, -1, :]).astype(xbc.dtype)  # (B,H)
        st_new = st_prev * chunk_decay[:, :, None, None] + st_c
        return st_new, (y_diag + y_off)

    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    st0 = jnp.zeros((b, h, pdim, n), xbar.dtype)
    final_state, y_chunks = lax.scan(step, st0, (xb, da, bb, cc))
    y = y_chunks.transpose(1, 0, 2, 3, 4).reshape(b, s, h, pdim)
    return y, final_state


def ssd_block(p, x, cfg, *, state=None, conv_state=None, chunk: int = 256):
    """Mamba2 block. Training/prefill: chunked SSD over the sequence.
    Decode (S == 1 with state): O(1) recurrent update.
    Returns (y, (new_state, new_conv_state))."""
    from . import dist as _dist

    b, s, d = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_head_dim
    # Pin the SSD activations batch-sharded/model-replicated: without the
    # hint GSPMD invents a model sharding inside the chunk scan and
    # all-reduces the O(q²·H) intra-chunk tensors (measured 549 GiB/step on
    # mamba2 train — EXPERIMENTS.md §Perf it. 7).
    zxbc = _dist.hint_bsd(jnp.einsum("bsd,dk->bsk", x, p["in_proj"]))  # replicated K: split offsets are unaligned with any K-sharding
    z, xi, b_, c_, dt = jnp.split(zxbc, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xi, b_, c_], axis=-1)  # (B,S,di+2n)

    w = p["conv_w"]  # (K, di+2n) depthwise causal conv
    kw = w.shape[0]
    if state is None:  # train/prefill: causal depthwise conv over seq
        pad = jnp.zeros((b, kw - 1, conv_in.shape[-1]), conv_in.dtype)
        ext = jnp.concatenate([pad, conv_in], axis=1)
        conv = sum(ext[:, i : i + s] * w[i] for i in range(kw))
        new_conv_state = ext[:, -(kw - 1) :] if kw > 1 else jnp.zeros((b, 0, conv_in.shape[-1]), x.dtype)
    else:  # decode: rolling window
        ext = jnp.concatenate([conv_state, conv_in], axis=1)  # (B, kw, C)
        conv = sum(ext[:, i : i + 1] * w[i] for i in range(kw))
        new_conv_state = ext[:, 1:]
    conv = jax.nn.silu(conv)
    xi, b_, c_ = jnp.split(conv, [di, di + n], axis=-1)

    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    xh = xi.reshape(b, s, h, pdim)
    xbar = xh * dt[..., None].astype(xh.dtype)
    da = dt * a  # (B,S,H)

    if state is None:
        y, final_state = _ssd_chunked(xbar, da.astype(xh.dtype), b_, c_, chunk)
    else:
        # Single-step recurrence: state ← state·exp(dA) + B ⊗ xbar; y = C·state.
        dec = jnp.exp(da[:, 0]).astype(state.dtype)  # (B,H)
        outer = jnp.einsum("bhp,bn->bhpn", xbar[:, 0], b_[:, 0]).astype(state.dtype)
        final_state = state * dec[:, :, None, None] + outer
        y = jnp.einsum("bn,bhpn->bhp", c_[:, 0], final_state)[:, None].reshape(b, 1, h, pdim)

    y = y.astype(x.dtype) + xh * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = _dist.hint_bsd(y.reshape(b, s, di))
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    out = _dist.hint_bsd(jnp.einsum("bsk,kd->bsd", y, p["out_proj"]).astype(x.dtype))
    return out, (final_state, new_conv_state)
