"""Distribution context: sequence-parallel decode attention + sharded cache
updates (shard_map building blocks consumed by the model when a mesh is live).

The LSE merge here is the jnp twin of kernels/decode_attention.merge_partials —
each device computes attention over its local KV shard, then partials are
all-gathered over the sequence axes and merged. That keeps per-device decode
memory at O(S/n_shards) instead of all-gathering a multi-GB cache.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import axis_size, mesh_axis_size, mesh_axis_sizes, shard_map

_CTX: Optional["Distribution"] = None


@dataclasses.dataclass(frozen=True)
class Distribution:
    mesh: object
    batch_axes: tuple = ("data",)  # mesh axes sharding the batch dim ( () ⇒ replicated )
    seq_axes: tuple = ("model",)  # mesh axes sharding the KV-cache sequence dim
    sp_decode: bool = True  # sequence-parallel decode attention on/off
    tp_axis: str = "model"

    @property
    def batch_spec(self):
        return tuple(self.batch_axes) if self.batch_axes else None

    @property
    def seq_spec(self):
        return tuple(self.seq_axes) if self.seq_axes else None

    @property
    def tp_size(self) -> int:
        return mesh_axis_size(self.mesh, self.tp_axis)


# --------------------------------------------------------- activation hints
# Explicit with_sharding_constraint on key activations. Without these, GSPMD
# propagation is free to invent shardings (measured: it split head_dim 2-way
# on qwen2 @ TP16, putting a logits all-reduce inside every attention chunk —
# EXPERIMENTS.md §Perf iteration 1).
def _wsc(x, spec):
    dist = current()
    if dist is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(dist.mesh, spec)
    )


def hint_bsd(x):
    """Residual stream (B, S, D): batch-sharded, model-replicated."""
    dist = current()
    if dist is None:
        return x
    return _wsc(x, P(dist.batch_spec, None, None))


def hint_bshd(x):
    """Projected q/k/v (B, S, H, hd): shard heads on tp axis iff divisible."""
    dist = current()
    if dist is None:
        return x
    h = x.shape[2]
    hax = dist.tp_axis if (h % dist.tp_size == 0 and dist.tp_size > 1) else None
    return _wsc(x, P(dist.batch_spec, None, hax, None))


def dp_size() -> int:
    """Product of the batch-sharding axes (1 without a distribution ctx)."""
    dist = current()
    if dist is None or not dist.batch_axes:
        return 1
    sizes = mesh_axis_sizes(dist.mesh)
    n = 1
    for a in dist.batch_axes:
        n *= sizes[a]
    return n


def hint_moe_buf(x, shard_experts: bool):
    """MoE dispatch buffer (DP, E, C, D): DP-sharded; experts on the tp axis
    when they divide it (this is where the EP a2a happens)."""
    dist = current()
    if dist is None:
        return x
    e = x.shape[1]
    eax = dist.tp_axis if (shard_experts and e % dist.tp_size == 0 and dist.tp_size > 1) else None
    return _wsc(x, P(dist.batch_spec, eax, None, None))


def hint_moe_tokens(x):
    """(DP, T_loc, D) token table: DP-sharded, model-replicated."""
    dist = current()
    if dist is None:
        return x
    return _wsc(x, P(dist.batch_spec, None, None))


def hint_bhsd(x):
    """(B, H, S, hd) attention-laid-out tensor: batch-sharded; heads on the
    tp axis iff divisible."""
    dist = current()
    if dist is None:
        return x
    h = x.shape[1]
    hax = dist.tp_axis if (h % dist.tp_size == 0 and dist.tp_size > 1) else None
    return _wsc(x, P(dist.batch_spec, hax, None, None))


def hint_bsf(x):
    """MLP hidden (B, S, F): shard F on the tp axis iff divisible."""
    dist = current()
    if dist is None:
        return x
    f = x.shape[-1]
    fax = dist.tp_axis if (f % dist.tp_size == 0 and dist.tp_size > 1) else None
    return _wsc(x, P(dist.batch_spec, None, fax))


def current() -> Optional[Distribution]:
    return _CTX


@contextlib.contextmanager
def use_distribution(dist: Optional[Distribution]):
    global _CTX
    prev = _CTX
    _CTX = dist
    try:
        yield
    finally:
        _CTX = prev


# ------------------------------------------------------------------ SP decode
def sp_decode_attention(dist, q, ck, cv, pos, *, window, softcap, scale, norm_eps=1e-6):
    """q: (B, Hq, 1, hd); ck/cv: (B, Hkv, S, hd) sharded on S over dist.seq_axes.

    Each device computes masked partial attention over its local S/n slice and
    partials are merged with a stable logsumexp combine (associative — see
    tests/test_kernels.py::test_decode_merge_is_associative_across_devices).
    """
    b, hq, _, hd = q.shape
    hkv = ck.shape[1]
    g = hq // hkv
    s_total = ck.shape[2]
    bspec = dist.batch_spec
    sspec = dist.seq_spec
    seq_axes = tuple(dist.seq_axes)

    def local(qv, kv, vv):
        # qv: (B, Hq, 1, hd) local-batch; kv/vv: (B, Hkv, S_loc, hd)
        s_loc = kv.shape[2]
        idx = jnp.zeros((), jnp.int32)
        mult = 1
        for ax in reversed(seq_axes):
            idx = idx + lax.axis_index(ax) * mult
            mult *= axis_size(ax)
        start = idx * s_loc
        qg = qv.reshape(qv.shape[0], hkv, g, hd).astype(jnp.float32)
        kf = kv.astype(jnp.float32)
        sc = jnp.einsum("bhgd,bhsd->bhgs", qg, kf) * scale
        if softcap is not None:
            sc = softcap * jnp.tanh(sc / softcap)
        kpos = start + jnp.arange(s_loc)[None, None, None, :]
        qpos = pos  # scalar: the query's absolute position
        mask = kpos <= qpos
        if window is not None:
            mask = mask & jnp.where(window > 0, kpos > qpos - window, True)
        sc = jnp.where(mask, sc, -1e30)
        m = jnp.max(sc, axis=-1, keepdims=True)
        p = jnp.exp(sc - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bhgs,bhsd->bhgd", p, vv.astype(jnp.float32))
        o = o / jnp.maximum(l, 1e-30)
        # Gather partials over the sequence axes and merge.
        parts = (o[:, :, :, None], m[..., None], l[..., None])  # add tile axis
        merged_o, merged_m, merged_l = parts
        for ax in seq_axes:
            merged_o = lax.all_gather(merged_o, ax, axis=3, tiled=True)
            merged_m = lax.all_gather(merged_m, ax, axis=3, tiled=True)
            merged_l = lax.all_gather(merged_l, ax, axis=3, tiled=True)
        mm = jnp.max(merged_m, axis=3, keepdims=True)
        w = merged_l * jnp.exp(merged_m - mm)
        denom = jnp.sum(w, axis=3, keepdims=True)
        out = jnp.sum(merged_o * (w / jnp.maximum(denom, 1e-30)), axis=3)
        return out.reshape(qv.shape[0], hq, 1, hd).astype(q.dtype)

    fn = shard_map(
        local,
        mesh=dist.mesh,
        in_specs=(
            P(bspec, None, None, None),
            P(bspec, None, sspec, None),
            P(bspec, None, sspec, None),
        ),
        out_specs=P(bspec, None, None, None),
        check_vma=False,
    )
    return fn(q, ck, cv)


def sp_cache_update(dist, cache, new_kv, pos):
    """Write the new token's K/V at ``pos`` into a sequence-sharded cache:
    only the shard owning ``pos`` writes; others pass through unchanged."""
    seq_axes = tuple(dist.seq_axes)
    bspec = dist.batch_spec
    sspec = dist.seq_spec

    def local(c, nk):
        s_loc = c.shape[2]
        idx = jnp.zeros((), jnp.int32)
        mult = 1
        for ax in reversed(seq_axes):
            idx = idx + lax.axis_index(ax) * mult
            mult *= axis_size(ax)
        off = pos - idx * s_loc
        in_range = (off >= 0) & (off < s_loc)
        safe = jnp.clip(off, 0, s_loc - 1)
        upd = lax.dynamic_update_slice(c, nk.astype(c.dtype), (0, 0, safe, 0))
        return jnp.where(in_range, upd, c)

    fn = shard_map(
        local,
        mesh=dist.mesh,
        in_specs=(P(bspec, None, sspec, None), P(bspec, None, None, None)),
        out_specs=P(bspec, None, sspec, None),
        check_vma=False,
    )
    return fn(cache, new_kv)
