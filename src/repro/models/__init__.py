from . import config, layers, model  # noqa: F401
from .config import SHAPES, ModelConfig, ShapeSpec  # noqa: F401
