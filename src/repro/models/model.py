"""Unified causal LM covering all assigned families.

One parameter pytree with layer-stacked leaves (axis 0 = layer) drives a
``lax.scan`` over layers, so the HLO is O(1) in depth — essential for the
512-device dry-run compiles. Families:

  dense / vlm / audio-backbone : attention + (Sw)iGLU MLP
  moe                          : attention + routed experts (+ shared)
  ssm                          : Mamba2 SSD blocks only
  hybrid                       : parallel attention+SSD heads (Hymba) + MLP
  encdec                       : whisper — bidirectional encoder + causal
                                 decoder with cross-attention

Positional encoding is unified to RoPE (DESIGN.md §8: backbone fidelity is
dims/heads/layers/routing; whisper's learned abs-pos is replaced by RoPE).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import layers
from .config import ModelConfig


# ---------------------------------------------------------------- param init
def _norm_init(key, shape, dtype, scale=0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> dict:
    keys = iter(jax.random.split(key, 64))
    d, l = cfg.d_model, cfg.num_layers
    hq = cfg.num_heads * cfg.head_dim
    hkv = cfg.num_kv_heads * cfg.head_dim
    out_scale = 0.02 / max(1.0, (2 * l) ** 0.5)

    def attn_params(nl):
        # Head-split 3-D projections: the head axis shards cleanly (or not at
        # all) — fused (H·hd) dims reshard on every reshape (see layers.py).
        nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        p = {
            "wq": _norm_init(next(keys), (nl, d, nh, hd), dtype),
            "wk": _norm_init(next(keys), (nl, d, nkv, hd), dtype),
            "wv": _norm_init(next(keys), (nl, d, nkv, hd), dtype),
            "wo": _norm_init(next(keys), (nl, nh, hd, d), dtype, out_scale),
        }
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((nl, nh, hd), dtype)
            p["bk"] = jnp.zeros((nl, nkv, hd), dtype)
            p["bv"] = jnp.zeros((nl, nkv, hd), dtype)
        if cfg.qk_norm:
            p["q_norm"] = jnp.zeros((nl, cfg.head_dim), dtype)
            p["k_norm"] = jnp.zeros((nl, cfg.head_dim), dtype)
        return p

    def mlp_params(nl, width):
        p = {
            "w1": _norm_init(next(keys), (nl, d, width), dtype),
            "w2": _norm_init(next(keys), (nl, width, d), dtype, out_scale),
        }
        if cfg.act != "gelu":
            p["w3"] = _norm_init(next(keys), (nl, d, width), dtype)
        return p

    def ssm_params(nl):
        di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        return {
            "in_proj": _norm_init(next(keys), (nl, d, 2 * di + 2 * n + h), dtype),
            "conv_w": _norm_init(next(keys), (nl, cfg.ssm_conv, di + 2 * n), dtype, 0.2),
            "a_log": jnp.zeros((nl, h), jnp.float32),
            "dt_bias": jnp.zeros((nl, h), jnp.float32),
            "d_skip": jnp.ones((nl, h), dtype),
            "out_norm": jnp.zeros((nl, di), dtype),
            "out_proj": _norm_init(next(keys), (nl, di, d), dtype, out_scale),
        }

    params: dict = {
        "embed": _norm_init(next(keys), (cfg.vocab_size, d), dtype),
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _norm_init(next(keys), (d, cfg.vocab_size), dtype)

    lay: dict = {"ln1": jnp.zeros((l, d), dtype)}
    fam = cfg.family
    if fam in ("dense", "vlm", "audio"):
        lay.update(attn_params(l))
        lay["ln2"] = jnp.zeros((l, d), dtype)
        lay.update(mlp_params(l, cfg.d_ff))
    elif fam == "moe":
        lay.update(attn_params(l))
        lay["ln2"] = jnp.zeros((l, d), dtype)
        e, f = cfg.experts_alloc, cfg.moe_d_ff
        lay["router"] = _norm_init(next(keys), (l, d, e), jnp.float32)
        lay["w1"] = _norm_init(next(keys), (l, e, d, f), dtype)
        lay["w3"] = _norm_init(next(keys), (l, e, d, f), dtype)
        lay["w2"] = _norm_init(next(keys), (l, e, f, d), dtype, out_scale)
        if cfg.num_shared_experts:
            sw = f * cfg.num_shared_experts
            lay["shared"] = {
                "w1": _norm_init(next(keys), (l, d, sw), dtype),
                "w3": _norm_init(next(keys), (l, d, sw), dtype),
                "w2": _norm_init(next(keys), (l, sw, d), dtype, out_scale),
            }
    elif fam == "ssm":
        lay.update(ssm_params(l))
    elif fam == "hybrid":
        lay.update(attn_params(l))
        ssm = ssm_params(l)
        lay["ssm"] = ssm
        lay["fuse_attn"] = jnp.zeros((l, d), dtype)
        lay["fuse_ssm"] = jnp.zeros((l, d), dtype)
        lay["ln2"] = jnp.zeros((l, d), dtype)
        lay.update(mlp_params(l, cfg.d_ff))
    elif fam == "encdec":
        lay.update(attn_params(l))
        lay["ln_cross"] = jnp.zeros((l, d), dtype)
        lay["cross"] = attn_params(l)
        lay["ln2"] = jnp.zeros((l, d), dtype)
        lay.update(mlp_params(l, cfg.d_ff))
        el = cfg.encoder_layers
        enc = {"ln1": jnp.zeros((el, d), dtype), "ln2": jnp.zeros((el, d), dtype)}
        enc.update(attn_params(el))
        enc.update(mlp_params(el, cfg.d_ff))
        params["encoder"] = enc
        params["enc_final_norm"] = jnp.zeros((d,), dtype)
    else:
        raise ValueError(fam)
    params["layers"] = lay
    return params


def _windows_array(cfg: ModelConfig) -> jnp.ndarray:
    return jnp.asarray([w or 0 for w in cfg.layer_windows()], jnp.int32)


# ------------------------------------------------------------------ caches
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32) -> dict:
    l = cfg.num_layers
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    fam = cfg.family
    if fam in ("dense", "vlm", "audio", "moe", "hybrid", "encdec"):
        cache["k"] = jnp.zeros((l, batch, cfg.num_kv_heads, max_len, cfg.head_dim), dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
    if fam == "encdec":
        cache["cross_k"] = jnp.zeros(
            (l, batch, cfg.num_kv_heads, cfg.encoder_seq, cfg.head_dim), dtype
        )
        cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    if fam in ("ssm", "hybrid"):
        di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        cache["ssm_state"] = jnp.zeros((l, batch, h, cfg.ssm_head_dim, n), dtype)
        cache["conv_state"] = jnp.zeros((l, batch, cfg.ssm_conv - 1, di + 2 * n), dtype)
    return cache


# -------------------------------------------------------------- layer stacks
def _block(cfg: ModelConfig, p, x, window, *, q_offset, cache_l, kv_len, enc_out=None):
    """One decoder block. cache_l: per-layer cache slice dict or None."""
    fam = cfg.family
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    attn_cache = None
    if cache_l is not None and "k" in cache_l:
        attn_cache = {"k": cache_l["k"], "v": cache_l["v"], "pos": q_offset}
    # SSD runs its O(1) recurrence only for single-token decode; any longer
    # sequence (train or prefill) goes through the chunked scan from state 0.
    is_decode = x.shape[1] == 1 and cache_l is not None

    def ssm_io():
        if is_decode:
            return cache_l.get("ssm_state"), cache_l.get("conv_state")
        return None, None

    if fam in ("dense", "vlm", "audio", "moe", "encdec"):
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        attn, ac = layers.attention_block(
            p, h, cfg, window=window, q_offset=q_offset, cache=attn_cache, kv_len=kv_len
        )
        x = x + attn
        if ac is not None:
            new_cache.update(ac)
        if fam == "encdec":
            if enc_out is not None:
                # Compute this layer's cross K/V from the (loop-invariant)
                # encoder output — passing precomputed stacked KV through scan
                # xs costs a full f32 cotangent (+14.5 GiB on whisper train).
                from . import dist as _dist

                ck = _dist.hint_bhsd(jnp.einsum("bsd,dhk->bhsk", enc_out, p["cross"]["wk"]))
                cv = _dist.hint_bhsd(jnp.einsum("bsd,dhk->bhsk", enc_out, p["cross"]["wv"]))
            else:  # decode: from the cache (filled at prefill)
                ck, cv = cache_l["cross_k"], cache_l["cross_v"]
            h = layers.rms_norm(x, p["ln_cross"], cfg.norm_eps)
            x = x + layers.cross_attention_block(p["cross"], h, (ck, cv), cfg)
            if cache_l is not None:
                cdt = cache_l["cross_k"].dtype
                new_cache["cross_k"] = ck.astype(cdt)
                new_cache["cross_v"] = cv.astype(cdt)
        h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        if fam == "moe":
            y, aux = layers.moe_block(p, h, cfg)
        else:
            y = layers.mlp_block(p, h, cfg.act)
        x = x + y
    elif fam == "ssm":
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        st, cv = ssm_io()
        y, (st2, cv2) = layers.ssd_block(p, h, cfg, state=st, conv_state=cv)
        x = x + y
        if cache_l is not None:
            new_cache["ssm_state"] = st2.astype(cache_l["ssm_state"].dtype)
            new_cache["conv_state"] = cv2.astype(cache_l["conv_state"].dtype)
    elif fam == "hybrid":
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        attn, ac = layers.attention_block(
            p, h, cfg, window=window, q_offset=q_offset, cache=attn_cache, kv_len=kv_len
        )
        st, cv = ssm_io()
        ssm_y, (st2, cv2) = layers.ssd_block(p["ssm"], h, cfg, state=st, conv_state=cv)
        fused = 0.5 * (
            layers.rms_norm(attn, p["fuse_attn"], cfg.norm_eps)
            + layers.rms_norm(ssm_y, p["fuse_ssm"], cfg.norm_eps)
        )
        x = x + fused
        if cache_l is not None:
            if ac is not None:
                new_cache.update(ac)
            new_cache["ssm_state"] = st2.astype(cache_l["ssm_state"].dtype)
            new_cache["conv_state"] = cv2.astype(cache_l["conv_state"].dtype)
        h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + layers.mlp_block(p, h, cfg.act)
    else:
        raise ValueError(fam)
    return x, aux, new_cache


def _run_layers(cfg, stacked, x, *, q_offset=0, caches=None, kv_len=None, enc_out=None, remat=True):
    windows = _windows_array(cfg)
    cache_xs = None
    if caches is not None:
        cache_xs = {k: v for k, v in caches.items() if k != "pos"}

    # Decode (one token): fori_loop with the stacked cache in the CARRY so
    # XLA updates it in place. A scan would stream the cache through xs→ys,
    # triple-buffering multi-GiB KV caches (measured +11 GiB on gemma2
    # decode_32k — EXPERIMENTS.md §Perf).
    if caches is not None and x.shape[1] == 1:
        def fbody(i, carry):
            x, aux, cache = carry
            p_l = jax.tree.map(lambda a: lax.dynamic_index_in_dim(a, i, 0, keepdims=False), stacked)
            cache_l = jax.tree.map(
                lambda a: lax.dynamic_index_in_dim(a, i, 0, keepdims=False), cache
            )
            x, aux_l, new_cache = _block(
                cfg, p_l, x, windows[i], q_offset=q_offset, cache_l=cache_l,
                kv_len=kv_len, enc_out=None,
            )
            cache = jax.tree.map(
                lambda buf, new: lax.dynamic_update_index_in_dim(
                    buf, new.astype(buf.dtype), i, 0
                ),
                cache,
                new_cache,
            )
            return (x, aux + aux_l, cache)

        x, aux, new_caches = lax.fori_loop(
            0, cfg.num_layers, fbody, (x, jnp.zeros((), jnp.float32), cache_xs)
        )
        return x, aux, new_caches

    def body(carry, xs):
        x, aux = carry
        p_l, w_l, cache_l = xs
        x, aux_l, new_cache = _block(
            cfg, p_l, x, w_l, q_offset=q_offset, cache_l=cache_l,
            kv_len=kv_len, enc_out=enc_out,
        )
        return (x, aux + aux_l), new_cache

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), new_caches = lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stacked, windows, cache_xs)
    )
    return x, aux, new_caches


# ------------------------------------------------------------------- embed/loss
def _embed(cfg, params, tokens, batch_extras):
    x = params["embed"][tokens]
    if cfg.family == "vlm" and batch_extras.get("patch_embeds") is not None:
        pe = batch_extras["patch_embeds"].astype(x.dtype)
        npatch = pe.shape[1]
        x = jnp.concatenate([pe, x[:, npatch:]], axis=1)
    return x


def _encode(cfg, params, frames):
    """Whisper encoder over stub frame embeddings (B, Se, D)."""
    x = frames
    enc = params["encoder"]
    windows = jnp.zeros((cfg.encoder_layers,), jnp.int32)

    def body(x, xs):
        p_l, w_l = xs
        h = layers.rms_norm(x, p_l["ln1"], cfg.norm_eps)
        attn, _ = layers.attention_block(p_l, h, cfg, window=w_l, causal=False)
        x = x + attn
        h = layers.rms_norm(x, p_l["ln2"], cfg.norm_eps)
        x = x + layers.mlp_block(p_l, h, cfg.act)
        return x, None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = lax.scan(body, x, (enc, windows))
    return layers.rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def chunked_ce_loss(x, embed, targets, mask=None, *, chunk: int = 512, softcap=None, lm_head=None):
    """Cross-entropy with sequence-chunked logits (never materializes
    (B, S, V) f32). x: (B,S,D); embed: (V,D) (tied) or lm_head (D,V)."""
    b, s, d = x.shape
    c = min(chunk, s)
    while s % c:
        c //= 2
    nc = s // c
    w = embed.T if lm_head is None else lm_head  # (D, V)

    def step(acc, idx):
        xc = lax.dynamic_slice(x, (0, idx * c, 0), (b, c, d))
        tc = lax.dynamic_slice(targets, (0, idx * c), (b, c))
        logits = jnp.einsum("bcd,dv->bcv", xc.astype(jnp.float32), w.astype(jnp.float32))
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = lse - gold
        if mask is not None:
            mc = lax.dynamic_slice(mask, (0, idx * c), (b, c))
            nll = nll * mc
            return (acc[0] + nll.sum(), acc[1] + mc.sum()), None
        return (acc[0] + nll.sum(), acc[1] + b * c), None

    # Remat: recompute the (b, c, V) f32 logits chunk in backward instead of
    # saving every chunk (unsharded-vocab archs would otherwise hold ~13 GiB
    # of logits residuals per device — see EXPERIMENTS.md §Dry-run).
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    (tot, cnt), _ = lax.scan(step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), jnp.arange(nc))
    return tot / jnp.maximum(cnt, 1.0)


# ----------------------------------------------------------------- public API
def forward_train(params, cfg: ModelConfig, batch: dict, *, remat: bool = True):
    """batch: tokens (B,S) int32, targets (B,S) int32, optional patch_embeds /
    frames. Returns (loss, metrics)."""
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens, batch)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch["frames"])
    x, aux, _ = _run_layers(cfg, params["layers"], x, enc_out=enc_out, remat=remat)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    loss = chunked_ce_loss(
        x, params["embed"], batch["targets"],
        softcap=cfg.final_softcap, lm_head=params.get("lm_head"),
    )
    total = loss + 0.01 * aux
    return total, {"ce_loss": loss, "aux_loss": aux}


def forward_prefill(params, cfg: ModelConfig, batch: dict, cache: dict):
    """Run the prompt through the model, filling the cache. Returns
    (last-position logits (B, V), new cache)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed(cfg, params, tokens, batch)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch["frames"])
    x, _, new_caches = _run_layers(
        cfg, params["layers"], x, q_offset=0, caches=cache, enc_out=enc_out, remat=False
    )
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _final_logits(params, cfg, x[:, -1:])
    out_cache = dict(new_caches)
    out_cache["pos"] = jnp.asarray(s, jnp.int32)
    return logits[:, 0], out_cache


def forward_decode(params, cfg: ModelConfig, token, cache: dict, batch_extras: Optional[dict] = None):
    """One decode step. token: (B, 1) int32. Returns (logits (B,V), cache)."""
    batch_extras = batch_extras or {}
    x = params["embed"][token]
    # encdec: cross K/V comes from the cache (filled at prefill) — the
    # encoder is NOT re-run per decode step.
    x, _, new_caches = _run_layers(
        cfg, params["layers"], x, q_offset=cache["pos"], caches=cache, remat=False
    )
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _final_logits(params, cfg, x)
    out_cache = dict(new_caches)
    out_cache["pos"] = cache["pos"] + 1
    return logits[:, 0], out_cache


def _final_logits(params, cfg, x):
    w = params.get("lm_head")
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32), (params["embed"].T if w is None else w).astype(jnp.float32))
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits
