"""Elastic state resharding via CEP chunk arithmetic (paper → framework).

Every 1-D-flattenable state tensor (parameter, optimizer moment, KV block,
dataset sample space) is owned in CEP chunks over its flattened index space.
Rescaling k→k±x therefore needs only the O(k+k') boundary-overlay plan from
core/cep.py — never a pass over the data — and moves the Thm.-2-minimal number
of elements, vs ≈k/(k+x) of everything for hash-sharded state.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from ..core import cep


@dataclasses.dataclass(frozen=True)
class TensorReshardPlan:
    name: str
    num_elements: int
    plan: cep.ScalePlan

    @property
    def moved_elements(self) -> int:
        return self.plan.migrated_edges

    def moved_bytes(self, itemsize: int) -> int:
        return self.moved_elements * itemsize


@dataclasses.dataclass(frozen=True)
class ReshardPlan:
    k_old: int
    k_new: int
    tensors: tuple

    @property
    def total_moved_bytes(self) -> int:
        return sum(t.moved_bytes(t_item) for t, t_item in self.tensors)

    def summary(self) -> dict:
        total = sum(t.num_elements * it for t, it in self.tensors)
        moved = self.total_moved_bytes
        return {
            "k_old": self.k_old,
            "k_new": self.k_new,
            "total_bytes": total,
            "moved_bytes": moved,
            "moved_frac": moved / max(total, 1),
            "random_frac": self.k_old / max(self.k_new, self.k_old),
        }


def plan_reshard(named_shapes: dict, k_old: int, k_new: int, itemsize_of: Callable = None) -> ReshardPlan:
    """named_shapes: {name: (shape, itemsize)}. O(1) per tensor."""
    tensors = []
    for name, (shape, itemsize) in named_shapes.items():
        n = int(np.prod(shape))
        tensors.append((TensorReshardPlan(name, n, cep.scale_plan(n, k_old, k_new)), itemsize))
    return ReshardPlan(k_old, k_new, tuple(tensors))


# ---------------------------------------------------------------- host shards
def shard_slices(num_elements: int, k: int, host: int) -> slice:
    b = cep.chunk_bounds(num_elements, k)
    return slice(int(b[host]), int(b[host + 1]))


def gather_host_shard(flat: np.ndarray, k: int, host: int) -> np.ndarray:
    return flat[shard_slices(flat.shape[0], k, host)]


def apply_reshard(old_shards: list, num_elements: int, k_old: int, k_new: int) -> list:
    """Rebuild the k_new host shards from k_old shards, touching ONLY the
    ranges in the scale plan (stay ranges are sliced in place). Returns
    (new_shards, moved_elements)."""
    plan = cep.scale_plan(num_elements, k_old, k_new)
    ob = cep.chunk_bounds(num_elements, k_old)
    nb = cep.chunk_bounds(num_elements, k_new)
    pieces: dict[int, list] = {p: [] for p in range(k_new)}
    moved = 0
    for lo, hi, src in plan.stay:
        seg = old_shards[src][lo - int(ob[src]) : hi - int(ob[src])]
        pieces[src].append((lo, seg))
    for lo, hi, src, dst in plan.moves:
        seg = old_shards[src][lo - int(ob[src]) : hi - int(ob[src])]
        pieces[dst].append((lo, seg))
        moved += hi - lo
    new_shards = []
    for p in range(k_new):
        segs = sorted(pieces[p], key=lambda t: t[0])
        if segs:
            new_shards.append(np.concatenate([s for _, s in segs]))
        else:
            new_shards.append(np.zeros(0, dtype=old_shards[0].dtype))
        assert new_shards[-1].shape[0] == int(nb[p + 1] - nb[p])
    return new_shards, moved
