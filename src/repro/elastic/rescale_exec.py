"""On-device elastic rescale executor — the paper's Thm.-1/2 promise, executed.

``cep.scale_plan(k_old → k_new)`` names the ≤ k_old + k_new − 1 ordered-edge
ranges whose owner changes; everything else stays where it is. This module
applies such a plan directly to the packed ``(k, E_max, 2)`` device buffers of
graphs/engine.py as ONE jitted program of static slice copies, with the old
buffer donated — so executing a rescale costs O(overlay ranges) program size
and moves exactly the Thm.-2-minimal edge ranges across partitions, instead of
re-running any partitioner or re-packing from the host.

Cost accounting distinguishes what a real multi-host deployment would see:

* ``migrated_*`` — rows whose owner partition changes (network traffic; equals
  ``ScalePlan.migrated_bytes`` by construction, asserted in tests);
* ``local_shift_edges`` — rows that keep their owner but land at a different
  slot in the padded buffer because the chunk start moved (device-local
  memmove, no network);
* pure stays are untouched semantically and alias through buffer donation on
  backends that implement it.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import donate_jit
from ..core import cep, metrics
from ..graphs import engine as graph_engine

__all__ = ["EDGE_BYTES", "RescaleStats", "ElasticRescaler"]

EDGE_BYTES = 8  # (src, dst) int32 per packed edge row


@dataclasses.dataclass(frozen=True)
class RescaleStats:
    k_old: int
    k_new: int
    num_edges: int
    migrated_edges: int  # cross-partition rows (network)
    migrated_bytes: int  # migrated_edges · EDGE_BYTES
    stay_edges: int  # rows whose owner is unchanged
    local_shift_edges: int  # stays that changed slot inside their partition
    copy_ops: int  # slice-copy instructions in the jitted program
    oracle_checked: bool  # compared bit-exactly vs a from-scratch pack
    elapsed_s: float  # wall time of the device program (blocked)
    recheck_s: float  # host-side metrics re-check (+ oracle compare) time


class ElasticRescaler:
    """Executes ``cep.ScalePlan``s against packed ``EngineData``.

    Jitted migration programs are cached per (num_edges, k_old, k_new) so a
    controller oscillating between two cluster sizes pays tracing once.
    ``verify=True`` re-packs from scratch on the host and asserts bit-equality
    (the tests' oracle); the metrics re-check (mirrors, replication factor)
    always runs so the returned EngineData is self-consistent.
    """

    def __init__(self, *, donate: bool = True):
        self.donate = donate
        self._programs: dict = {}

    # ------------------------------------------------------------- planning
    def plan(self, data: graph_engine.EngineData, k_new: int) -> cep.ScalePlan:
        return cep.scale_plan(data.num_edges, data.k, k_new)

    # ------------------------------------------------------------ execution
    def execute(
        self,
        data: graph_engine.EngineData,
        plan: cep.ScalePlan,
        *,
        verify: bool = False,
        recheck: bool = True,
    ):
        """Apply ``plan`` to ``data``; returns ``(new_data, RescaleStats)``.

        ``data`` must be CEP-chunked (partition p = ordered range p, as built
        by ``pack_ordered`` / ``cep_engine_data``). The old edge buffer is
        donated to the migration program: treat ``data`` as CONSUMED — on
        backends where XLA can alias it, reading ``data.edges`` afterwards
        raises "Array has been deleted".

        ``recheck=True`` recomputes mirrors / replication factor for k_new —
        an O(|E|) host pass (readback + per-chunk uniques). Latency-critical
        callers can pass ``recheck=False`` to keep the pure O(overlay-ranges)
        migration cost; the returned EngineData then carries ``mirrors=-1``,
        ``replication_factor=nan`` (engine algorithms never read them).
        ``verify=True`` implies the readback regardless.
        """
        n, k_old, k_new = plan.num_edges, plan.k_old, plan.k_new
        if data.k != k_old:
            raise ValueError(f"plan is for k_old={k_old} but EngineData has k={data.k}")
        if data.num_edges != n:
            raise ValueError(f"plan is for |E|={n} but EngineData has |E|={data.num_edges}")
        counts = np.asarray(data.mask).astype(bool).sum(axis=1)
        want = np.diff(cep.chunk_bounds(n, k_old))
        if not np.array_equal(counts, want):
            raise ValueError(
                "EngineData is not CEP-chunked (per-partition edge counts "
                f"{counts.tolist()} != chunk sizes {want.tolist()}); "
                "range-copy rescaling only applies to pack_ordered layouts"
            )
        if k_new == k_old:
            # No-op plan: hand the buffers back untouched instead of pushing
            # them through a donating identity program (which would alias and
            # delete them out from under the caller).
            stats = RescaleStats(
                k_old=k_old, k_new=k_new, num_edges=n, migrated_edges=0,
                migrated_bytes=0, stay_edges=n, local_shift_edges=0,
                copy_ops=0, oracle_checked=False, elapsed_s=0.0, recheck_s=0.0,
            )
            return data, stats

        # One host readback of the *pre-migration* buffers: the flat ordered
        # edge list is invariant under rescaling, so it serves both the k_new
        # metrics re-check and — crucially independent of the program's output
        # — the verify=True from-scratch oracle.
        readback = recheck or verify
        src_o, dst_o = graph_engine.unpack_ordered(data) if readback else (None, None)

        program, stats_base = self._program(n, k_old, k_new, plan)
        t0 = time.perf_counter()
        new_edges, new_mask = program(data.edges)
        jax.block_until_ready(new_edges)
        elapsed = time.perf_counter() - t0

        # Metrics re-check: recompute quality numbers for the new k (never
        # carried over from the old pack).
        t1 = time.perf_counter()
        if readback:
            counts_v = metrics.chunk_vertex_counts_ordered(src_o, dst_o, k_new)
            present = np.unique(np.concatenate([src_o, dst_o])).shape[0]
            mirrors = int(counts_v.sum() - present)
            rf = float(counts_v.sum()) / float(data.num_vertices)
        else:
            mirrors, rf = -1, float("nan")
        new_data = graph_engine.EngineData(
            edges=new_edges,
            mask=new_mask,
            degrees=data.degrees,
            num_vertices=data.num_vertices,
            k=k_new,
            mirrors=mirrors,
            replication_factor=rf,
            num_edges=n,
        )

        oracle_checked = False
        if verify:
            # From-scratch pack of the ORIGINAL ordered list at k_new — a
            # mis-routed move segment cannot fool this.
            oracle = graph_engine.pack_ordered(src_o, dst_o, data.num_vertices, k_new)
            if not (
                np.array_equal(np.asarray(oracle.edges), np.asarray(new_edges))
                and np.array_equal(np.asarray(oracle.mask), np.asarray(new_mask))
            ):
                raise AssertionError("executed rescale does not match from-scratch pack")
            oracle_checked = True
        recheck = time.perf_counter() - t1

        stats = dataclasses.replace(
            stats_base, oracle_checked=oracle_checked, elapsed_s=elapsed, recheck_s=recheck
        )
        return new_data, stats

    def rescale(
        self,
        data: graph_engine.EngineData,
        k_new: int,
        *,
        verify: bool = False,
        recheck: bool = True,
    ):
        """Plan + execute in one call (what the elastic controller uses)."""
        return self.execute(data, self.plan(data, k_new), verify=verify, recheck=recheck)

    # -------------------------------------------------------------- interns
    def _program(self, n: int, k_old: int, k_new: int, plan: cep.ScalePlan):
        key = (n, k_old, k_new)
        cached = self._programs.get(key)
        if cached is not None:
            return cached

        bo = cep.chunk_bounds(n, k_old)
        bn = cep.chunk_bounds(n, k_new)
        sizes_new = np.diff(bn)
        e_max_new = int(sizes_new.max())
        segments = sorted(
            [(lo, hi, p, p) for lo, hi, p in plan.stay]
            + [(lo, hi, s, d) for lo, hi, s, d in plan.moves]
        )
        local_shift = sum(
            hi - lo for lo, hi, s, d in segments if s == d and int(bo[s]) != int(bn[s])
        )
        stats = RescaleStats(
            k_old=k_old,
            k_new=k_new,
            num_edges=n,
            migrated_edges=plan.migrated_edges,
            migrated_bytes=plan.migrated_bytes(EDGE_BYTES),
            stay_edges=sum(hi - lo for lo, hi, _ in plan.stay),
            local_shift_edges=int(local_shift),
            copy_ops=len(segments),
            oracle_checked=False,
            elapsed_s=0.0,
            recheck_s=0.0,
        )
        mask_new = jnp.asarray(
            (np.arange(e_max_new)[None, :] < sizes_new[:, None]).astype(np.float32)
        )

        def migrate(edges_old):
            new = jnp.zeros((k_new, e_max_new, 2), edges_old.dtype)
            for lo, hi, s, d in segments:
                seg = edges_old[s, lo - int(bo[s]) : hi - int(bo[s]), :]
                new = new.at[d, lo - int(bn[d]) : hi - int(bn[d]), :].set(seg)
            return new, mask_new

        if self.donate:
            program = donate_jit(migrate, donate_argnums=(0,))
        else:
            program = jax.jit(migrate)
        self._programs[key] = (program, stats)
        return program, stats
