"""On-device elastic rescale executor — the paper's Thm.-1/2 promise, executed.

``cep.scale_plan(k_old → k_new)`` names the ≤ k_old + k_new − 1 ordered-edge
ranges whose owner changes; everything else stays where it is. This module
applies such a plan directly to the packed ``(k, E_max, 2)`` buffers of
graphs/engine.py as ONE jitted program of static slice copies, with the old
buffer donated — so executing a rescale costs O(overlay ranges) program size
and moves exactly the Thm.-2-minimal edge ranges across partitions, instead of
re-running any partitioner or re-packing from the host.

The same program executes on both layouts (DESIGN.md §6):

* ``EngineData`` — the replicated single-buffer pack. Partition p is row p;
  every copy is device-local. This is the degenerate mesh-of-1 case.
* ``ShardedEngineData`` — the pack distributed over a mesh's ``graph`` axis.
  Rows are permuted device-major (partition p on device p % g), the output
  carries the k_new NamedSharding, and XLA's SPMD partitioner turns exactly
  the plan's cross-device boundary ranges into device-to-device transfers
  while stays and local shifts compile to shard-local slice copies.

Cost accounting distinguishes what a real multi-host deployment would see:

* ``migrated_*`` — rows whose owner *partition* changes (equals
  ``ScalePlan.migrated_bytes`` by construction, asserted in tests);
* ``cross_device_*`` — the subset of migrated rows whose source and
  destination partitions live on different mesh devices (actual network /
  interconnect traffic; on a mesh of 1 this is 0);
* ``cross_process_*`` — the subset of cross-device rows whose devices belong
  to different ``jax.distributed`` processes (launch/multihost.py): what a
  real multi-host cluster pays on the NIC, reported separately from
  same-host device-to-device copies;
* ``on_device_edges`` — migrated rows whose partitions share a device
  (cross_device_edges + on_device_edges == migrated_edges);
* ``local_shift_edges`` — rows that keep their owner but land at a different
  slot in the padded buffer because the chunk start moved (device-local
  memmove, never network);
* pure stays are untouched semantically and alias through buffer donation on
  backends that implement it.
"""
from __future__ import annotations

import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import donate_jit
from ..core import cep, metrics
from ..graphs import engine as graph_engine
from ..launch import sharding as SH
from ..obs import metrics as OM
from ..obs import trace as OT

__all__ = [
    "EDGE_BYTES",
    "ProgramCache",
    "RescaleStats",
    "ElasticRescaler",
    "plan_segments",
    "cross_process_plan_edges",
]

EDGE_BYTES = 8  # (src, dst) int32 per packed edge row


class ProgramCache:
    """Bounded LRU of jitted device programs keyed by their static shape/mesh
    signature. Keys are KIND-prefixed tuples (("migrate", ...), ("counts",
    ...), ("scatter", ...), ("compact", ...), ("span_repair", ...)) so every
    program family of one runtime component shares a single cache — a
    long-lived controller oscillating between configurations pays tracing
    once per signature without any cache growing without limit, and
    ``program_cache_size`` bounds ALL of a component's cached programs at
    once (ElasticRescaler: migrate + counts; StreamingEngine: scatter +
    compact + span_repair + full_reorder + splice).

    Per-kind hit/miss/eviction counters (``counters`` / ``counters_snapshot``)
    make the cache's behavior auditable from event logs: a ``get`` returning a
    program is a hit, a ``get`` returning None a miss (the caller compiles and
    ``put``s), and ``put`` evicting an LRU victim an eviction — so the stream
    bench can PROVE an escalation never paid a compile (its kind's miss count
    is flat across the monitored stream) instead of asserting it by eye."""

    def __init__(self, size: int):
        if size < 1:
            raise ValueError("program_cache_size must be >= 1")
        self.size = int(size)
        self._programs: collections.OrderedDict = collections.OrderedDict()
        # kind (key[0] for tuple keys, "?" otherwise) → {hits, misses, evictions}
        self.counters: dict = {}
        self._counters_shared = False  # a snapshot aliases self.counters

    def __len__(self) -> int:
        return len(self._programs)

    def __contains__(self, key) -> bool:
        return key in self._programs

    def __iter__(self):
        return iter(self._programs)  # keys, least- to most-recently used

    @staticmethod
    def _kind(key) -> str:
        return str(key[0]) if isinstance(key, tuple) and key else "?"

    def _count(self, key, event: str) -> None:
        if self._counters_shared:
            # Copy-on-WRITE: a snapshot handed out earlier aliases the live
            # dicts — clone before mutating so every outstanding snapshot
            # stays frozen at its emit-time values.
            self.counters = {kind: dict(c) for kind, c in self.counters.items()}
            self._counters_shared = False
        c = self.counters.setdefault(
            self._kind(key), {"hits": 0, "misses": 0, "evictions": 0}
        )
        c[event] += 1

    def counters_snapshot(self) -> dict:
        """Per-kind counters, isolated from later cache activity — safe to
        attach to events. Lazily: the LIVE mapping is returned and the cache
        clones it before its next mutation (copy-on-write), so the per-event
        hot path (every IngestEvent snapshots) costs a flag set, not a deep
        copy per batch. Callers must treat the result as immutable."""
        self._counters_shared = True
        return self.counters

    def get(self, key):
        cached = self._programs.get(key)
        if cached is not None:
            self._programs.move_to_end(key)
            self._count(key, "hits")
        else:
            self._count(key, "misses")
        return cached

    def touch(self, key) -> bool:
        """Refresh recency if present (counted as a hit). Unlike ``get``, an
        absent key counts NOTHING — warm-up helpers probe with this before
        delegating to the builder (whose own ``get`` miss then counts the
        compile exactly once, keeping misses == compiles for the bench)."""
        cached = self._programs.get(key)
        if cached is not None:
            self._programs.move_to_end(key)
            self._count(key, "hits")
            return True
        return False

    def put(self, key, value):
        self._programs[key] = value
        while len(self._programs) > self.size:
            victim, _ = self._programs.popitem(last=False)
            self._count(victim, "evictions")
        return value


def _mesh_processes(mesh) -> int:
    """Distinct processes behind a mesh (1 for mesh=None / single-process)."""
    if mesh is None:
        return 1
    return len(set(SH.device_process_map(mesh).tolist()))


def cross_process_plan_edges(plan: cep.ScalePlan, mesh) -> int:
    """Edges of the plan's move ranges whose source and destination partitions
    live on different *processes* of ``mesh`` — the Thm.-2 subset that a
    multi-host deployment pays on the network. Pure host arithmetic over the
    overlay (no device readback), so the network bill is known before the
    migration runs."""
    g = SH.graph_axis_size(mesh)
    procs = SH.device_process_map(mesh)
    return int(
        sum(
            hi - lo
            for lo, hi, s, d in plan.moves
            if procs[s % g] != procs[d % g]
        )
    )


def plan_segments(plan: cep.ScalePlan) -> list:
    """The plan's overlay as ordered (lo, hi, src_part, dst_part) copy
    segments — stays spelled src == dst. This is the exact instruction list of
    the migration program; benchmarks reuse it for per-device accounting."""
    return sorted(
        [(lo, hi, p, p) for lo, hi, p in plan.stay]
        + [(lo, hi, s, d) for lo, hi, s, d in plan.moves]
    )


@dataclasses.dataclass(frozen=True)
class RescaleStats:
    k_old: int
    k_new: int
    num_edges: int
    migrated_edges: int  # cross-partition rows (owner changed)
    migrated_bytes: int  # migrated_edges · EDGE_BYTES
    stay_edges: int  # rows whose owner is unchanged
    local_shift_edges: int  # stays that changed slot inside their partition
    copy_ops: int  # slice-copy instructions in the jitted program
    oracle_checked: bool  # compared bit-exactly vs a from-scratch pack
    elapsed_s: float  # wall time of the device program (blocked)
    recheck_s: float  # host-side metrics re-check (+ oracle compare) time
    devices: int = 1  # graph-axis size the program ran over
    cross_device_edges: int = 0  # migrated rows crossing a device boundary
    cross_device_bytes: int = 0  # cross_device_edges · EDGE_BYTES
    on_device_edges: int = 0  # migrated rows staying on their device
    processes: int = 1  # jax.distributed process count behind the mesh
    cross_process_edges: int = 0  # migrated rows crossing a PROCESS boundary
    cross_process_bytes: int = 0  # cross_process_edges · EDGE_BYTES — the
    # network bill of a real multi-host deployment (subset of cross_device_*;
    # same-host device-to-device copies never touch the NIC)


class ElasticRescaler:
    """Executes ``cep.ScalePlan``s against packed engine state.

    Accepts both ``EngineData`` (replicated pack; mesh-of-1 degenerate case)
    and ``ShardedEngineData`` (partitions distributed round-robin over a
    ``graph`` mesh axis) — one program builder serves both, parameterized only
    by the row permutation and output sharding.

    Jitted migration programs are cached per (num_edges, k_old, k_new, mesh)
    in a bounded LRU (``program_cache_size``) so a controller oscillating
    between cluster sizes pays tracing once without the cache growing without
    limit across a long-lived serving process. ``verify=True`` re-packs from
    scratch on the host and asserts bit-equality (the tests' oracle); the
    metrics re-check (mirrors, replication factor) keeps the returned data
    self-consistent.
    """

    def __init__(
        self,
        *,
        donate: bool = True,
        program_cache_size: int = 8,
        tracer=None,
        metrics_registry=None,
    ):
        self.donate = donate
        self._programs = ProgramCache(program_cache_size)
        # Observability (obs/): tracer=None falls back to the process-global
        # tracer (disabled by default); metrics default to the inert registry.
        self._tracer = tracer
        self.metrics = OM.NULL if metrics_registry is None else metrics_registry

    @property
    def tracer(self):
        return self._tracer if self._tracer is not None else OT.get_tracer()

    @property
    def program_cache_size(self) -> int:
        return self._programs.size

    # ------------------------------------------------------------- planning
    def plan(self, data, k_new: int) -> cep.ScalePlan:
        return cep.scale_plan(data.num_edges, data.k, k_new)

    # ------------------------------------------------------------ execution
    def execute(
        self,
        data,
        plan: cep.ScalePlan,
        *,
        verify: bool = False,
        recheck: bool = True,
    ):
        """Apply ``plan`` to ``data``; returns ``(new_data, RescaleStats)``.

        ``data`` must be CEP-chunked (partition p = ordered range p, as built
        by ``pack_ordered`` / ``pack_ordered_sharded``). The old edge buffer
        is donated to the migration program: treat ``data`` as CONSUMED — on
        backends where XLA can alias it, reading ``data.edges`` afterwards
        raises "Array has been deleted".

        ``recheck=True`` recomputes mirrors / replication factor for k_new —
        an O(|E|) host pass (readback + per-chunk uniques). Latency-critical
        callers can pass ``recheck=False`` to keep the pure O(overlay-ranges)
        migration cost; the returned data then carries ``mirrors=-1``,
        ``replication_factor=nan`` (engine algorithms never read them).
        ``verify=True`` implies the readback regardless.
        """
        n, k_old, k_new = plan.num_edges, plan.k_old, plan.k_new
        sharded = isinstance(data, graph_engine.ShardedEngineData)
        mesh = data.mesh if sharded else None
        g = SH.graph_axis_size(mesh)
        if data.k != k_old:
            raise ValueError(f"plan is for k_old={k_old} but engine data has k={data.k}")
        if data.num_edges != n:
            raise ValueError(f"plan is for |E|={n} but engine data has |E|={data.num_edges}")
        # Layout check without gathering the full mask: reduce per-row counts
        # on device (sharded, O(k_pad) ints to host) so recheck=False keeps
        # the O(overlay-ranges) migration cost on a real mesh. On a
        # multi-process mesh the row sums must land replicated before the host
        # can read them (the sharded result spans non-addressable devices);
        # the tiny counts program is cached like the migration programs.
        if sharded and not data.mask.is_fully_addressable:
            counts = np.asarray(self._counts_program(data.mask.shape, mesh)(data.mask))
        else:
            counts = np.asarray(jnp.sum(data.mask > 0, axis=1))
        sizes_old = np.diff(cep.chunk_bounds(n, k_old))
        want = np.zeros(counts.shape[0], dtype=sizes_old.dtype)
        for p in range(k_old):  # padding rows (sharded pack) must stay empty
            want[SH.partition_row(p, k_old, g)] = sizes_old[p]
        if not np.array_equal(counts, want):
            raise ValueError(
                "engine data is not CEP-chunked (per-row edge counts "
                f"{counts.tolist()} != chunk sizes {want.tolist()}); "
                "range-copy rescaling only applies to pack_ordered layouts"
            )
        if k_new == k_old:
            # No-op plan: hand the buffers back untouched instead of pushing
            # them through a donating identity program (which would alias and
            # delete them out from under the caller).
            stats = RescaleStats(
                k_old=k_old, k_new=k_new, num_edges=n, migrated_edges=0,
                migrated_bytes=0, stay_edges=n, local_shift_edges=0,
                copy_ops=0, oracle_checked=False, elapsed_s=0.0, recheck_s=0.0,
                devices=g, processes=_mesh_processes(mesh),
            )
            return data, stats

        # One host readback of the *pre-migration* buffers: the flat ordered
        # edge list is invariant under rescaling, so it serves both the k_new
        # metrics re-check and — crucially independent of the program's output
        # — the verify=True from-scratch oracle.
        readback = recheck or verify
        if readback:
            flat = graph_engine.unshard_engine_data(data) if sharded else data
            src_o, dst_o = graph_engine.unpack_ordered(flat)
        else:
            src_o, dst_o = None, None

        program, stats_base = self._program(n, k_old, k_new, plan, mesh)
        t0 = time.perf_counter()
        with self.tracer.span("rescale.migrate"):
            new_edges, new_mask = program(data.edges)
            jax.block_until_ready(new_edges)
        elapsed = time.perf_counter() - t0
        m = self.metrics
        m.histogram("rescale.migrate_s").observe(elapsed)
        m.counter("rescale.migrated_bytes").inc(stats_base.migrated_bytes)
        m.counter("rescale.cross_device_bytes").inc(stats_base.cross_device_bytes)
        m.counter("rescale.cross_process_bytes").inc(stats_base.cross_process_bytes)

        # Metrics re-check: recompute quality numbers for the new k (never
        # carried over from the old pack).
        t1 = time.perf_counter()
        if readback:
            counts_v = metrics.chunk_vertex_counts_ordered(src_o, dst_o, k_new)
            present = np.unique(np.concatenate([src_o, dst_o])).shape[0]
            mirrors = int(counts_v.sum() - present)
            rf = float(counts_v.sum()) / float(data.num_vertices)
        else:
            mirrors, rf = -1, float("nan")
        # Same fields for both layouts (ShardedEngineData keeps its mesh).
        new_data = dataclasses.replace(
            data,
            edges=new_edges,
            mask=new_mask,
            k=k_new,
            mirrors=mirrors,
            replication_factor=rf,
        )

        oracle_checked = False
        if verify:
            # From-scratch pack of the ORIGINAL ordered list at k_new — a
            # mis-routed move segment cannot fool this.
            oracle = graph_engine.pack_ordered(src_o, dst_o, data.num_vertices, k_new)
            got = graph_engine.unshard_engine_data(new_data) if sharded else new_data
            if not (
                np.array_equal(np.asarray(oracle.edges), np.asarray(got.edges))
                and np.array_equal(np.asarray(oracle.mask), np.asarray(got.mask))
            ):
                raise AssertionError("executed rescale does not match from-scratch pack")
            oracle_checked = True
        recheck = time.perf_counter() - t1

        stats = dataclasses.replace(
            stats_base, oracle_checked=oracle_checked, elapsed_s=elapsed, recheck_s=recheck
        )
        return new_data, stats

    def rescale(
        self,
        data,
        k_new: int,
        *,
        verify: bool = False,
        recheck: bool = True,
    ):
        """Plan + execute in one call (what the elastic controller uses)."""
        return self.execute(data, self.plan(data, k_new), verify=verify, recheck=recheck)

    # -------------------------------------------------------------- interns
    def _counts_program(self, mask_shape, mesh):
        """Per-row mask counts, replicated so every process can host-read them
        (multi-process meshes only — fully-addressable arrays reduce eagerly).
        Lives in the one kind-prefixed ProgramCache with the migration
        programs, so program_cache_size bounds ALL cached programs."""
        key = ("counts", tuple(mask_shape), mesh)
        cached = self._programs.get(key)
        if cached is not None:
            return cached
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        program = jax.jit(
            lambda m: jnp.sum(m > 0, axis=1), out_shardings=NamedSharding(mesh, P())
        )
        return self._programs.put(key, program)

    def _program(self, n: int, k_old: int, k_new: int, plan: cep.ScalePlan, mesh):
        g = SH.graph_axis_size(mesh)
        key = ("migrate", n, k_old, k_new, mesh)
        cached = self._programs.get(key)
        if cached is not None:
            return cached

        bo = cep.chunk_bounds(n, k_old)
        bn = cep.chunk_bounds(n, k_new)
        sizes_new = np.diff(bn)
        e_max_new = int(sizes_new.max())
        k_pad_new = SH.padded_partition_count(k_new, g)
        # Device-major row of each partition in the old / new layouts. On a
        # mesh of 1 both are the identity and the program below is exactly the
        # historical single-buffer slice-copy program.
        row_old = [SH.partition_row(p, k_old, g) for p in range(k_old)]
        row_new = [SH.partition_row(p, k_new, g) for p in range(k_new)]
        segments = plan_segments(plan)
        local_shift = sum(
            hi - lo for lo, hi, s, d in segments if s == d and int(bo[s]) != int(bn[s])
        )
        cross = sum(
            hi - lo
            for lo, hi, s, d in plan.moves
            if SH.partition_device(s, g) != SH.partition_device(d, g)
        )
        xproc = cross_process_plan_edges(plan, mesh)
        stats = RescaleStats(
            k_old=k_old,
            k_new=k_new,
            num_edges=n,
            migrated_edges=plan.migrated_edges,
            migrated_bytes=plan.migrated_bytes(EDGE_BYTES),
            stay_edges=sum(hi - lo for lo, hi, _ in plan.stay),
            local_shift_edges=int(local_shift),
            copy_ops=len(segments),
            oracle_checked=False,
            elapsed_s=0.0,
            recheck_s=0.0,
            devices=g,
            cross_device_edges=int(cross),
            cross_device_bytes=int(cross) * EDGE_BYTES,
            on_device_edges=plan.migrated_edges - int(cross),
            processes=_mesh_processes(mesh),
            cross_process_edges=xproc,
            cross_process_bytes=xproc * EDGE_BYTES,
        )
        mask_rows = np.zeros(k_pad_new, dtype=np.int64)
        for p in range(k_new):
            mask_rows[row_new[p]] = sizes_new[p]
        mask_new = jnp.asarray(
            (np.arange(e_max_new)[None, :] < mask_rows[:, None]).astype(np.float32)
        )

        def migrate(edges_old):
            new = jnp.zeros((k_pad_new, e_max_new, 2), edges_old.dtype)
            for lo, hi, s, d in segments:
                seg = edges_old[row_old[s], lo - int(bo[s]) : hi - int(bo[s]), :]
                new = new.at[row_new[d], lo - int(bn[d]) : hi - int(bn[d]), :].set(seg)
            return new, mask_new

        jit_kwargs: dict = {}
        if mesh is not None:
            s_edges, s_mask, _ = SH.engine_shardings(mesh)
            jit_kwargs["out_shardings"] = (s_edges, s_mask)
        if self.donate:
            program = donate_jit(migrate, donate_argnums=(0,), **jit_kwargs)
        else:
            program = jax.jit(migrate, **jit_kwargs)
        return self._programs.put(key, (program, stats))
