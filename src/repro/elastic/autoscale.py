"""Traffic-driven autoscaling policy for the elastic controller.

Everything before this module rescales when *told to*; production rescales
because *load changed* (Spinner's cloud-elasticity scenario, xDGP's
adapt-to-workload loop — PAPERS.md). ``AutoscalePolicy`` closes that loop:
it reads the observability registry the runtime already publishes to
(DESIGN.md §13 — the ``controller.queue_depth`` / ``controller.events_per_s``
gauges and the ``controller.batch_wall_s`` latency histogram were added for
exactly this consumer) and turns load into ``k``:

* **scale out** when the smoothed queue backlog per alive host exceeds
  ``queue_high_per_host``, the event rate exceeds ``rate_high``, or the
  recent-window p99 of the wall histogram exceeds ``p99_high_s`` (the SLO);
* **scale in** only when EVERY signal sits under its low watermark —
  backlog at/below ``queue_low``, rate under ``rate_low``, p99 under
  ``p99_low_frac · p99_high_s`` — and at least one wall observation exists
  (an idle registry that has never seen load is "no signal", not "no load").

Hysteresis is modeled on the escalation ladder's ``partial_cooldown``
(stream/incremental.py): per-direction cooldown windows on the controller's
injected clock, and EVERY decision arms both — a reversal (out→in or in→out)
is therefore always separated by at least the smaller cooldown, which is
what makes "zero flap pairs" a structural property of the policy rather
than a lucky trajectory (bench_serve gates on it). A scale-out arms the
(typically longer) in-window in full; a scale-in arms the out-window in
full, delaying a post-shrink spike response by at most ``out_cooldown_s``.
Signals are EMA-smoothed (``ema`` = weight of the newest
reading, like the rebuild-dispatch anticipation's drift EMA) so one bursty
batch cannot whipsaw k. Thresholds are strict (``>`` high / ``<`` low);
cooldown expiry is inclusive (``now - last >= cooldown`` re-arms) — the
boundary tests pin both.

Decisions are (k_new, reason) tuples; ``ElasticController.autoscale()``
executes them through the same ``_execute`` path membership changes use, so
a policy-driven rescale is the same on-mesh migration as a preemption-driven
one — migrated bytes per decision come for free from
``ScaleEvent.cross_device_bytes``, and the bit-identity oracle covers it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class AutoscaleConfig:
    """Thresholds + hysteresis of a traffic-driven policy.

    The defaults read the controller's own metrics; serving front ends point
    ``wall_metric`` at their query-latency histogram instead (launch/serve.py
    uses ``serve.latency_s``) so the p99 signal tracks what the SLO actually
    covers.
    """

    k_min: int = 1  # clamp floor (>= the controller's eviction floor)
    k_max: int = 64  # clamp ceiling
    step_out: int = 2  # hosts provisioned per scale-out decision
    step_in: int = 1  # hosts retired per scale-in decision (shrink cautiously)
    queue_high_per_host: float = 4.0  # backlog / k that triggers scale-out
    # Total smoothed backlog at/below which scale-in is allowed. Must be > 0
    # in any config that smooths (ema < 1): the EMA decays geometrically and
    # never reaches exactly zero after load, so a 0.0 watermark would
    # permanently veto scale-in.
    queue_low: float = 0.5
    rate_high: float = math.inf  # events/s high watermark (inf = signal off)
    rate_low: float = math.inf  # events/s low watermark (inf = never blocks in)
    p99_high_s: float = math.inf  # recent-p99 SLO on the wall histogram
    p99_low_frac: float = 0.5  # scale-in needs p99 < p99_low_frac * p99_high_s
    p99_window: int = 256  # newest samples the p99 readout covers
    ema: float = 0.5  # weight of the newest reading (1.0 = unsmoothed)
    out_cooldown_s: float = 10.0  # min seconds between scale-outs
    in_cooldown_s: float = 30.0  # min seconds between scale-ins (and after an out)
    queue_metric: str = "controller.queue_depth"
    rate_metric: str = "controller.events_per_s"
    wall_metric: str = "controller.batch_wall_s"

    def __post_init__(self):
        if not 1 <= self.k_min <= self.k_max:
            raise ValueError(f"need 1 <= k_min <= k_max, got [{self.k_min}, {self.k_max}]")
        if self.step_out < 1 or self.step_in < 1:
            raise ValueError("step_out and step_in must be >= 1")
        if not 0.0 < self.ema <= 1.0:
            raise ValueError("ema must be in (0, 1]")
        if self.out_cooldown_s < 0 or self.in_cooldown_s < 0:
            raise ValueError("cooldowns must be >= 0")
        if not 0.0 <= self.p99_low_frac <= 1.0:
            raise ValueError("p99_low_frac must be in [0, 1]")


@dataclasses.dataclass(frozen=True)
class AutoscaleSignals:
    """One ``decide()`` evaluation's smoothed inputs + raw readings —
    appended to ``AutoscalePolicy.log`` so a bench can show WHY each
    decision (or non-decision) happened."""

    now: float
    k: int
    queue: float  # EMA-smoothed queue depth
    rate: float  # EMA-smoothed events/s
    p99_s: float  # recent-window p99 of the wall histogram (unsmoothed:
    # a percentile over a window is already an aggregate)
    raw_queue: float
    raw_rate: float
    wall_total: int  # lifetime wall observations (0 = no load signal yet)
    decision: str  # "scale_out" | "scale_in" | "" (held)
    held_by: str  # "" | "cooldown" | "clamp" | "no_signal" | "steady"


class AutoscalePolicy:
    """Stateful watermark policy: EMA-smoothed signals, per-direction
    cooldowns, k clamps. One instance per controller (it carries the EMA and
    cooldown state); ``decide`` is pure in (k, now, registry) given that
    state, so a fake clock + a hand-fed registry drive it deterministically
    in tests."""

    def __init__(self, config: AutoscaleConfig = AutoscaleConfig()):
        self.config = config
        self._ema_queue: Optional[float] = None
        self._ema_rate: Optional[float] = None
        self._next_out_t = -math.inf
        self._next_in_t = -math.inf
        self.log: list = []  # AutoscaleSignals, one per decide() call

    def _smooth(self, prev: Optional[float], new: float) -> float:
        a = self.config.ema
        return new if prev is None else (1.0 - a) * prev + a * new

    def note_external_scale(self, now: float) -> None:
        """An EXTERNAL actor changed k (a failure shrink through
        ``ElasticController.report_failure``, an operator override): arm both
        cooldown windows exactly like a policy decision would. Without this,
        a failure shrink looks like free headroom and the policy may bounce k
        right back out (or pile a policy shrink on top) while the cluster is
        still re-committing the restored pack — the same flap the
        double-armed windows exist to prevent. Never shortens a window
        already armed further out."""
        c = self.config
        self._next_out_t = max(self._next_out_t, now + c.out_cooldown_s)
        self._next_in_t = max(self._next_in_t, now + c.in_cooldown_s)

    def decide(self, *, k: int, now: float, registry) -> Optional[tuple[int, str]]:
        """At most one decision per call: (k_new, reason) or None. Reads the
        registry's current values, advances the EMAs, honors cooldowns and
        clamps. The reason string carries the signal values that fired, so
        the emitted ScaleEvent is self-explaining in the event log."""
        c = self.config
        wall = registry.histogram(c.wall_metric)
        raw_queue = float(registry.gauge(c.queue_metric).value)
        raw_rate = float(registry.gauge(c.rate_metric).value)
        wall_total = int(wall.total)
        p99 = float(wall.percentile(99, window=c.p99_window))
        self._ema_queue = self._smooth(self._ema_queue, raw_queue)
        self._ema_rate = self._smooth(self._ema_rate, raw_rate)
        queue, rate = self._ema_queue, self._ema_rate

        overloaded = (
            queue > c.queue_high_per_host * max(1, k)
            or rate > c.rate_high
            or p99 > c.p99_high_s
        )
        # Scale-in demands every signal calm AND at least one wall
        # observation: a registry that never saw load is silence, not idleness.
        underloaded = (
            wall_total > 0
            and queue <= c.queue_low
            and rate < c.rate_low
            and (math.isinf(c.p99_high_s) or p99 < c.p99_low_frac * c.p99_high_s)
        )

        decision, held = "", "steady"
        k_new, reason = k, ""
        if overloaded:
            if now < self._next_out_t:
                held = "cooldown"
            elif k >= c.k_max:
                held = "clamp"
            else:
                k_new = min(c.k_max, k + c.step_out)
                decision, held = "scale_out", ""
                reason = (
                    f"autoscale out {k}->{k_new}: queue {queue:.1f} "
                    f"(> {c.queue_high_per_host:g}/host)"
                    if queue > c.queue_high_per_host * max(1, k)
                    else f"autoscale out {k}->{k_new}: "
                    + (f"rate {rate:.1f}/s > {c.rate_high:g}" if rate > c.rate_high
                       else f"p99 {p99 * 1e3:.1f}ms > {c.p99_high_s * 1e3:.0f}ms")
                )
                # An out arms BOTH windows: capacity just provisioned must
                # not be torn down before it absorbed anything.
                self._next_out_t = now + c.out_cooldown_s
                self._next_in_t = max(self._next_in_t, now + c.in_cooldown_s)
        elif underloaded:
            if now < self._next_in_t:
                held = "cooldown"
            elif k <= c.k_min:
                held = "clamp"
            else:
                k_new = max(c.k_min, k - c.step_in)
                decision, held = "scale_in", ""
                reason = (
                    f"autoscale in {k}->{k_new}: queue {queue:.1f} <= {c.queue_low:g}, "
                    f"p99 {p99 * 1e3:.1f}ms"
                )
                self._next_in_t = now + c.in_cooldown_s
                # Symmetric guard: an immediate out after an in would be a
                # flap pair — the shrink must stand for at least one
                # out-window before load may reverse it.
                self._next_out_t = max(self._next_out_t, now + c.out_cooldown_s)
        elif wall_total == 0 and raw_queue == 0.0 and raw_rate == 0.0:
            held = "no_signal"
        self.log.append(
            AutoscaleSignals(
                now=now, k=k, queue=queue, rate=rate, p99_s=p99,
                raw_queue=raw_queue, raw_rate=raw_rate, wall_total=wall_total,
                decision=decision, held_by=held,
            )
        )
        return (k_new, reason) if decision else None
