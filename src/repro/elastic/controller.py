"""Elastic-cluster controller: heartbeats, preemption, stragglers, rescale.

Host processes (real or simulated) report heartbeats with step progress; the
controller detects dead hosts (missed beats) and stragglers (progress lag),
and emits ScaleEvents whose migration plans come from CEP — so reacting to a
spot-instance preemption costs an O(k) plan + Thm.-2-minimal data movement,
which is exactly the paper's motivating scenario (§1).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from ..core import cep


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float
    step: int
    alive: bool = True


@dataclasses.dataclass(frozen=True)
class ScaleEvent:
    kind: str  # "scale_in" | "scale_out" | "straggler"
    k_old: int
    k_new: int
    lost_hosts: tuple
    plan_edges_moved_frac: float
    reason: str
    executed: bool = False  # True when an attached engine was migrated on-device
    cross_device_bytes: int = 0  # executed device-to-device traffic (mesh runs)


class ElasticController:
    def __init__(
        self,
        num_hosts: int,
        *,
        dead_after_s: float = 10.0,
        straggler_lag_steps: int = 50,
        state_elements: int = 1_000_000,
        clock: Callable[[], float] = time.monotonic,
        rescaler=None,
    ):
        self.clock = clock
        self.dead_after_s = dead_after_s
        self.straggler_lag_steps = straggler_lag_steps
        self.state_elements = state_elements
        now = self.clock()
        self.hosts = {h: HostState(h, now, 0) for h in range(num_hosts)}
        self.events: list[ScaleEvent] = []
        self._rescaler = rescaler
        self.engine_data = None  # packed EngineData migrated on scale events
        self.rescale_stats: list = []

    @property
    def k(self) -> int:
        return sum(1 for h in self.hosts.values() if h.alive)

    def heartbeat(self, host_id: int, step: int) -> None:
        h = self.hosts[host_id]
        h.last_beat = self.clock()
        h.step = max(h.step, step)

    def add_hosts(self, n: int) -> ScaleEvent:
        k_old = self.k
        base = max(self.hosts) + 1 if self.hosts else 0
        now = self.clock()
        for i in range(n):
            self.hosts[base + i] = HostState(base + i, now, 0)
        return self._emit("scale_out", k_old, self.k, (), f"+{n} provisioned hosts")

    def poll(self) -> Optional[ScaleEvent]:
        """Detect failures/stragglers; emit at most one event per poll."""
        now = self.clock()
        dead = [h.host_id for h in self.hosts.values() if h.alive and now - h.last_beat > self.dead_after_s]
        if dead:
            k_old = self.k
            for hid in dead:
                self.hosts[hid].alive = False
            return self._emit(
                "scale_in", k_old, self.k, tuple(dead), f"hosts {dead} missed heartbeats"
            )
        alive = [h for h in self.hosts.values() if h.alive]
        if len(alive) >= 2:
            max_step = max(h.step for h in alive)
            lag = [h.host_id for h in alive if max_step - h.step > self.straggler_lag_steps]
            if lag:
                # Straggler mitigation = evict + rescale (chunk boundaries shift
                # away from the slow host; its chunk is Thm.-2-cheap to move).
                k_old = self.k
                for hid in lag:
                    self.hosts[hid].alive = False
                return self._emit(
                    "straggler", k_old, self.k, tuple(lag), f"hosts {lag} lag >{self.straggler_lag_steps} steps"
                )
        return None

    def attach_engine(self, data, mesh=None) -> None:
        """Attach packed graph-engine state (``engine.pack_ordered`` layout).

        With an engine attached, every rescale decision is *executed*: the
        emitted event carries ``executed=True`` and ``self.engine_data`` is
        replaced by the migrated k_new engine data (stats appended to
        ``self.rescale_stats``) — not just a plan.

        Passing ``mesh`` (a ``graph``-axis mesh from launch.mesh.make_graph_mesh)
        distributes the pack over its devices first, so every subsequent scale
        event is executed as an on-mesh migration and reports the device-to-
        device traffic it actually generated (``ScaleEvent.cross_device_bytes``).
        A ``ShardedEngineData`` may also be attached directly.
        """
        if mesh is not None:
            from ..graphs import engine as graph_engine

            if not isinstance(data, graph_engine.ShardedEngineData):
                data = graph_engine.shard_engine_data(data, mesh)
        self.engine_data = data

    def _emit(self, kind, k_old, k_new, lost, reason) -> ScaleEvent:
        executed = False
        cross_device_bytes = 0
        if self.engine_data is not None and k_new not in (0, self.engine_data.k):
            if self._rescaler is None:
                from .rescale_exec import ElasticRescaler

                self._rescaler = ElasticRescaler()
            self.engine_data, stats = self._rescaler.rescale(self.engine_data, k_new)
            self.rescale_stats.append(stats)
            executed = True
            cross_device_bytes = stats.cross_device_bytes
        if executed:
            # Report what was actually migrated, not the synthetic model.
            frac = stats.migrated_edges / max(stats.num_edges, 1)
        elif k_new == k_old or k_new == 0:
            frac = 0.0
        else:
            frac = cep.migrated_edges_exact(self.state_elements, k_old, k_new) / self.state_elements
        ev = ScaleEvent(kind, k_old, k_new, lost, frac, reason, executed, cross_device_bytes)
        self.events.append(ev)
        return ev
