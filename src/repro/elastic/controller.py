"""Elastic-cluster controller: heartbeats, preemption, stragglers, rescale.

Host processes (real or simulated) report heartbeats with step progress; the
controller detects dead hosts (missed beats) and stragglers (progress lag),
and emits ScaleEvents whose migration plans come from CEP — so reacting to a
spot-instance preemption costs an O(k) plan + Thm.-2-minimal data movement,
which is exactly the paper's motivating scenario (§1).

With a streaming engine attached (``attach_stream``) the controller also
accepts graph updates: ``ingest`` applies an EdgeUpdateBatch on-device and
runs the quality monitor, whose escalation ladder is ingest → partial
re-order → full GEO repartition (DESIGN.md §9). Every event — scale, ingest,
or rebuild — carries a monotonic ``seq`` from one shared counter, so
interleaved logs have a total order regardless of wall-clock resolution.

Decision vs dispatch (DESIGN.md §11): membership changes (``add_hosts``,
``poll``) first produce a ``ScaleDecision`` — the pure what-should-happen —
and ``_execute`` then dispatches it against whatever engine is attached.
Asynchronous work follows the same discipline one layer down: the engine's
full-rebuild rung dispatches against shadow buffers and the controller drains
the COMPLETED records (``drain_rebuild_events``) into ``RebuildEvent``s whose
``seq`` is assigned at completion-commit time — an in-flight rebuild has no
place in the total order until it commits (or aborts).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from ..core import cep
from ..obs import metrics as OM
from ..obs import trace as OT


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float
    step: int
    alive: bool = True


@dataclasses.dataclass(frozen=True)
class ScaleDecision:
    """The DECISION half of a membership change — what should happen, before
    any engine is touched. ``_execute`` turns one into a ScaleEvent."""

    kind: str  # "scale_in" | "scale_out" | "straggler"
    k_old: int
    k_new: int
    lost_hosts: tuple
    reason: str


@dataclasses.dataclass(frozen=True)
class ScaleEvent:
    kind: str  # "scale_in" | "scale_out" | "straggler"
    k_old: int
    k_new: int
    lost_hosts: tuple
    plan_edges_moved_frac: float
    reason: str
    executed: bool = False  # True when an attached engine was migrated on-device
    cross_device_bytes: int = 0  # executed device-to-device traffic (mesh runs)
    cross_process_bytes: int = 0  # subset crossing jax.distributed process
    # boundaries — the network bill of a multi-host run (launch/multihost.py)
    seq: int = -1  # monotonic event sequence, shared with IngestEvents
    program_cache: dict = dataclasses.field(default_factory=dict)
    # per-kind {hits, misses, evictions} of the engine's program cache at
    # emit time — flat misses across events prove no compile was paid


@dataclasses.dataclass(frozen=True)
class IngestEvent:
    kind: str  # always "ingest" (mirrors ScaleEvent.kind for shared logs)
    inserted: int
    deleted: int
    skipped: int
    escalation: str  # "none" | "partial" | "full" — monitor's ladder step
    num_edges: int  # live edges after the batch
    elapsed_s: float  # host placement + device ingest (excludes the monitor)
    monitor_s: float = 0.0  # quality monitor + any escalation it ran
    seq: int = -1
    repair: str = ""  # what the rung executed: "device" | "host" | "oracle" |
    # "differential" | "resync" | "skipped" | "" (none) | "dispatch" | "geo"
    rung_count: int = 0  # cumulative firings of THIS event's rung (incl. it)
    rung_total_s: float = 0.0  # cumulative seconds spent in this rung so far
    # --- async full-rebuild overlap accounting (DESIGN.md §11) ---
    rebuild_state: str = ""  # ""/"dispatch"/"flight"/"commit"/"abort"
    rebuild_s: float = 0.0  # rebuild work inside THIS batch's monitor call
    rebuilds_in_flight: int = 0  # rebuilds still in flight after the batch
    program_cache: dict = dataclasses.field(default_factory=dict)
    # Spill-layer traffic (stream/spill.py) — empty for streams without one.
    spill: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """An UNPLANNED membership loss (preemption, crash) detected by the
    liveness layer (launch.multihost.LeaseBoard or heartbeat expiry) and
    reported through ``report_failure``. Sequenced on the shared monotonic
    counter immediately BEFORE the scale-in event that re-plans k over the
    survivors — detection precedes the plan in the total order, exactly the
    order the system learned about it. Restore accounting (Thm-2-style: the
    recovery bill is the lost partitions' chunk bytes + the WAL tail, not
    graph size) rides on the event when the caller ran a restore."""

    kind: str  # always "failure"
    lost_hosts: tuple
    k_old: int
    k_new: int  # the re-plan over survivors (k_min floor applied)
    detect_s: float  # lease-expiry detection latency (0 when not measured)
    reason: str
    restored_bytes: int = 0  # checkpoint chunk + WAL-tail bytes the restore read
    restore_s: float = 0.0
    replayed_records: int = 0  # WAL tail length replayed onto the snapshot
    seq: int = -1


@dataclasses.dataclass(frozen=True)
class RebuildEvent:
    """A COMPLETED async full rebuild (committed or aborted). Emitted when
    the controller drains the engine's rebuild log, so ``seq`` is assigned at
    completion-commit time — in-flight work has no place in the total order.
    Appears in ``events`` immediately before the IngestEvent of the batch
    whose monitor call completed it."""

    kind: str  # always "full_rebuild"
    mode: str  # "geo" | "device" | "differential"
    committed: bool  # False on abort or resync fallback
    aborted: bool  # True when a re-layout voided the snapshot
    snapshot_edges: int  # live edges the dispatched program re-ordered
    replayed_batches: int  # delta batches replayed onto the new order
    splice_ops: int  # slot ops the commit splice scattered
    flight_batches: int  # batches between dispatch and completion
    dispatch_s: float  # host candidate compute + program dispatch (async)
    commit_s: float  # commit: re-layout + replay + splice, blocked
    seq: int = -1


class ElasticController:
    def __init__(
        self,
        num_hosts: int,
        *,
        dead_after_s: float = 10.0,
        straggler_lag_steps: int = 50,
        state_elements: int = 1_000_000,
        clock: Callable[[], float] = time.monotonic,
        rescaler=None,
        k_min: int = 1,
        tracer=None,
        metrics_registry=None,
    ):
        if k_min < 1:
            raise ValueError("k_min must be >= 1: a plan to zero partitions is not a rescale")
        self.clock = clock
        self.dead_after_s = dead_after_s
        self.straggler_lag_steps = straggler_lag_steps
        self.state_elements = state_elements
        # Eviction floor: poll() never drives k below this, however many
        # hosts went dark in one poll — a scale plan to zero partitions has
        # no executable meaning (and would zero the pack an attached engine
        # holds). Autoscale policies carry their own (>= this) k_min.
        self.k_min = int(k_min)
        now = self.clock()
        self.hosts = {h: HostState(h, now, 0) for h in range(num_hosts)}
        self.events: list = []  # ScaleEvents + IngestEvents, ordered by seq
        self._rescaler = rescaler
        self._seq = 0  # one counter for all event kinds
        self.engine_data = None  # packed EngineData migrated on scale events
        self.stream = None  # StreamingEngine: scale events + ingest run on it
        self.autoscaler = None  # AutoscalePolicy consulted by autoscale()
        self._backlog = 0  # externally-reported work backlog (serve queue)
        self.rescale_stats: list = []
        # Observability (obs/, DESIGN.md §13): the event wall histogram and
        # the queue-depth / events-per-second gauges are the signals the
        # ROADMAP's traffic-driven autoscaler will consume.
        self._tracer = tracer
        self.metrics = OM.NULL if metrics_registry is None else metrics_registry
        self._m_wall = self.metrics.histogram("controller.batch_wall_s")
        self._m_queue = self.metrics.gauge("controller.queue_depth")
        self._m_rate = self.metrics.gauge("controller.events_per_s")
        self._m_ingests = self.metrics.counter("controller.ingest_events")
        self._m_scales = self.metrics.counter("controller.scale_events")
        self._m_failures = self.metrics.counter("controller.failure_events")
        self._last_event_t: Optional[float] = None
        self.checkpoint = None  # SlotCheckpoint making ingested batches durable
        self._batch_step = -1  # durable batch index the checkpoint records under

    @property
    def tracer(self):
        return self._tracer if self._tracer is not None else OT.get_tracer()

    def _mark_event(self) -> None:
        """Update the events/s gauge: an EMA of the inter-event rate (the
        smoothing keeps a bursty stream from whipsawing the autoscaler
        signal; 0 until two events exist). Reads the INJECTED clock — the
        same one heartbeat/poll liveness runs on — so a fake clock drives
        the gauge deterministically in tests and the serve loop's virtual
        timeline feeds the autoscaler consistently."""
        now = self.clock()
        if self._last_event_t is not None:
            dt = now - self._last_event_t
            if dt > 0:
                prev = self._m_rate.value
                rate = 1.0 / dt
                self._m_rate.set(rate if prev == 0.0 else 0.8 * prev + 0.2 * rate)
        self._last_event_t = now

    def events_jsonl(self, *, drop_timings: bool = False) -> str:
        """The full event log (shared ``seq`` order) as JSONL — see
        obs/log.py; ``drop_timings`` zeroes wall-clock fields so logs from
        deterministic replica processes diff byte-identical."""
        from ..obs import log as OL

        return OL.events_jsonl(self.events, drop_timings=drop_timings)

    def _next_seq(self) -> int:
        s = self._seq
        self._seq += 1
        return s

    @property
    def k(self) -> int:
        return sum(1 for h in self.hosts.values() if h.alive)

    def heartbeat(self, host_id: int, step: int) -> None:
        h = self.hosts[host_id]
        h.last_beat = self.clock()
        h.step = max(h.step, step)

    def add_hosts(self, n: int) -> ScaleEvent:
        k_old = self.k
        base = max(self.hosts) + 1 if self.hosts else 0
        now = self.clock()
        for i in range(n):
            self.hosts[base + i] = HostState(base + i, now, 0)
        return self._emit("scale_out", k_old, self.k, (), f"+{n} provisioned hosts")

    def _clamp_eviction(self, evict: list) -> tuple[list, str]:
        """Apply the ``k_min`` floor to an eviction list: retain the
        most-recently-heard-from candidates so the survivors are the best
        liveness bets. Returns (evictable hosts, clamp note for the event
        reason — empty when the floor never engaged)."""
        survivors = self.k - len(evict)
        if survivors >= self.k_min:
            return evict, ""
        keep = self.k_min - survivors
        # Stalest-first, so the retained tail is the most recently beating.
        ranked = sorted(evict, key=lambda hid: (self.hosts[hid].last_beat, hid))
        retained = sorted(ranked[len(ranked) - keep :])
        return ranked[: len(ranked) - keep], (
            f" (clamped at k_min={self.k_min}: retained hosts {retained})"
        )

    def poll(self) -> Optional[ScaleEvent]:
        """Detect failures/stragglers; emit at most one event per poll.
        Eviction never drives k below ``k_min``: when every host went dark
        in one window, the most-recently-beating hosts stay in the working
        set (surfaced in the event reason) rather than emitting a scale
        plan to zero partitions."""
        now = self.clock()
        dead = [h.host_id for h in self.hosts.values() if h.alive and now - h.last_beat > self.dead_after_s]
        if dead:
            dead, clamp = self._clamp_eviction(dead)
            if not dead:
                return None  # the floor retained every candidate: no event
            k_old = self.k
            for hid in dead:
                self.hosts[hid].alive = False
            return self._emit(
                "scale_in", k_old, self.k, tuple(dead),
                f"hosts {dead} missed heartbeats{clamp}",
            )
        alive = [h for h in self.hosts.values() if h.alive]
        if len(alive) >= 2:
            max_step = max(h.step for h in alive)
            lag = [h.host_id for h in alive if max_step - h.step > self.straggler_lag_steps]
            if lag:
                # Straggler mitigation = evict + rescale (chunk boundaries shift
                # away from the slow host; its chunk is Thm.-2-cheap to move).
                lag, clamp = self._clamp_eviction(lag)
                if not lag:
                    return None
                k_old = self.k
                for hid in lag:
                    self.hosts[hid].alive = False
                return self._emit(
                    "straggler", k_old, self.k, tuple(lag),
                    f"hosts {lag} lag >{self.straggler_lag_steps} steps{clamp}",
                )
        return None

    def attach_engine(self, data, mesh=None) -> None:
        """Attach packed graph-engine state (``engine.pack_ordered`` layout).

        With an engine attached, every rescale decision is *executed*: the
        emitted event carries ``executed=True`` and ``self.engine_data`` is
        replaced by the migrated k_new engine data (stats appended to
        ``self.rescale_stats``) — not just a plan.

        Passing ``mesh`` (a ``graph``-axis mesh from launch.mesh.make_graph_mesh)
        distributes the pack over its devices first, so every subsequent scale
        event is executed as an on-mesh migration and reports the device-to-
        device traffic it actually generated (``ScaleEvent.cross_device_bytes``).
        A ``ShardedEngineData`` may also be attached directly.
        """
        if mesh is not None:
            from ..graphs import engine as graph_engine

            if not isinstance(data, graph_engine.ShardedEngineData):
                data = graph_engine.shard_engine_data(data, mesh)
        self.engine_data = data

    def attach_stream(self, stream) -> None:
        """Attach a live ``stream.ingest.StreamingEngine``.

        Scale events then execute as on-mesh compactions of the streaming
        pack (``StreamingEngine.rescale``) and ``ingest`` becomes available.
        Takes precedence over ``attach_engine`` state: a streaming graph's
        pack has gaps, which the range-copy rescaler correctly rejects.
        """
        self.stream = stream

    def attach_autoscaler(self, policy) -> None:
        """Attach an ``elastic.autoscale.AutoscalePolicy``: ``autoscale()``
        then closes the traffic→k loop, reading the metrics registry this
        controller publishes to and executing the policy's decisions through
        the same ``_execute`` path membership changes use."""
        if policy.config.k_min < self.k_min:
            raise ValueError(
                f"policy k_min={policy.config.k_min} below the controller's "
                f"eviction floor k_min={self.k_min}"
            )
        self.autoscaler = policy

    def attach_checkpoint(self, ckpt) -> None:
        """Attach a ``checkpoint.SlotCheckpoint``: every ingested batch then
        becomes durable (a WAL record, or a snapshot at the interval / after
        a re-layout) and every EXECUTED rescale writes a scale barrier — the
        state ``report_failure`` recoveries restore from."""
        if self.stream is None or getattr(self.stream, "orderer", None) is None:
            raise ValueError(
                "attach_stream first: the checkpoint snapshots the engine's orderer"
            )
        self.checkpoint = ckpt

    def report_failure(
        self,
        lost_hosts,
        *,
        detect_s: float = 0.0,
        reason: str = "process lease expired",
        restored_bytes: int = 0,
        restore_s: float = 0.0,
        replayed_records: int = 0,
    ) -> tuple[FailureEvent, Optional[ScaleEvent]]:
        """Treat process loss as an UNPLANNED rescale: mark the lost hosts
        dead (k_min floor applied, like ``poll`` eviction), sequence a
        FailureEvent, and re-plan k over the survivors through the same
        ``_emit``/``_execute`` path every planned decision takes. A failure
        shrink arms BOTH autoscaler cooldown windows like any other executed
        decision — the policy must not bounce k right back out (or further
        in) while the cluster is still settling. Returns (failure event,
        executed scale event or None when the floor retained every host)."""
        lost = [int(h) for h in lost_hosts if h in self.hosts and self.hosts[h].alive]
        k_old = self.k
        evict, clamp = self._clamp_eviction(lost)
        for hid in evict:
            self.hosts[hid].alive = False
        fev = FailureEvent(
            kind="failure",
            lost_hosts=tuple(evict),
            k_old=k_old,
            k_new=self.k,
            detect_s=float(detect_s),
            reason=f"{reason}{clamp}",
            restored_bytes=int(restored_bytes),
            restore_s=float(restore_s),
            replayed_records=int(replayed_records),
            seq=self._next_seq(),
        )
        self.events.append(fev)
        self._m_failures.inc()
        self._mark_event()
        sev = None
        if evict:
            sev = self._emit(
                "scale_in", k_old, self.k, tuple(evict), f"failure shrink: {reason}{clamp}"
            )
            if self.autoscaler is not None:
                self.autoscaler.note_external_scale(self.clock())
        return fev, sev

    def note_backlog(self, depth: int) -> None:
        """Report an external work backlog (a serve loop's query queue) into
        the ``controller.queue_depth`` gauge — the autoscaler's queue signal.
        The gauge always reads backlog + rebuilds-in-flight, so ingest-side
        pressure and serve-side pressure land on one signal."""
        self._backlog = int(depth)
        self._m_queue.set(self._backlog + int(getattr(self.stream, "rebuilds_in_flight", 0)))

    def autoscale(self) -> Optional[ScaleEvent]:
        """Consult the attached policy against the current metrics and clock;
        execute at most one decision. Scale-out provisions fresh host ids
        (the ``add_hosts`` path); scale-in retires the highest-id alive hosts
        — the CEP chunk boundary shifts are Thm.-2-cheap either way. Returns
        the executed ScaleEvent, or None (no policy / no decision)."""
        if self.autoscaler is None:
            return None
        decision = self.autoscaler.decide(
            k=self.k, now=self.clock(), registry=self.metrics
        )
        if decision is None:
            return None
        k_new, reason = decision
        k_old = self.k
        if k_new > k_old:
            base = max(self.hosts) + 1 if self.hosts else 0
            now = self.clock()
            for i in range(k_new - k_old):
                self.hosts[base + i] = HostState(base + i, now, 0)
            return self._emit("scale_out", k_old, self.k, (), reason)
        retired = sorted(h.host_id for h in self.hosts.values() if h.alive)[k_new - k_old:]
        for hid in retired:
            self.hosts[hid].alive = False
        return self._emit("scale_in", k_old, self.k, tuple(retired), reason)

    def _cache_counters(self) -> dict:
        """Per-kind program-cache counters of the attached stream engine (a
        host-only replay stream has none — default to empty)."""
        fn = getattr(self.stream, "program_cache_counters", None)
        return fn() if fn is not None else {}

    def _drain_rebuilds(self) -> list:
        """Wrap the engine's completed rebuild records into RebuildEvents,
        assigning the shared seq NOW — completion-commit time. Called before
        the IngestEvent of the completing batch is sequenced, so the log
        order is rebuild-then-ingest, exactly the order the state changed."""
        drain = getattr(self.stream, "drain_rebuild_events", None)
        if drain is None:
            return []
        out = []
        for rec in drain():
            ev = RebuildEvent(
                kind=rec["kind"],
                mode=rec["mode"],
                committed=rec["committed"],
                aborted=rec["aborted"],
                snapshot_edges=rec["snapshot_edges"],
                replayed_batches=rec["replayed_batches"],
                splice_ops=rec["splice_ops"],
                flight_batches=rec["flight_batches"],
                dispatch_s=rec["dispatch_s"],
                commit_s=rec["commit_s"],
                seq=self._next_seq(),
            )
            self.events.append(ev)
            out.append(ev)
        return out

    def ingest(self, batch) -> IngestEvent:
        """Apply an EdgeUpdateBatch to the attached stream, run the quality
        monitor (escalation ladder: ingest → partial re-order → async full
        rebuild), and log the event in the shared seq order. A rebuild the
        monitor completed (committed or aborted) is sequenced as its own
        RebuildEvent immediately before this batch's IngestEvent."""
        if self.stream is None:
            raise ValueError("no streaming engine attached (call attach_stream first)")
        stats = self.stream.ingest(batch)
        t0 = time.perf_counter()
        escalation = self.stream.monitor()
        monitor_s = time.perf_counter() - t0
        self._drain_rebuilds()
        if self.checkpoint is not None:
            # Durability point: the batch AND any monitor-run repair/rebuild
            # are applied — WAL-append (or snapshot) their slot writes now.
            self._batch_step += 1
            self.checkpoint.note_batch(self.stream.orderer, batch, self._batch_step)
        self._m_wall.observe(stats.elapsed_s + monitor_s)
        self._m_queue.set(self._backlog + int(getattr(self.stream, "rebuilds_in_flight", 0)))
        self._m_ingests.inc()
        self._mark_event()
        # Per-rung ladder accounting (StreamingEngine keeps the counters; a
        # host-only replay stream may not — default to empty).
        counts = getattr(self.stream, "rung_counts", {})
        totals = getattr(self.stream, "rung_s", {})
        ev = IngestEvent(
            kind="ingest",
            inserted=stats.inserted,
            deleted=stats.deleted,
            skipped=stats.skipped,
            escalation=escalation,
            num_edges=stats.num_edges,
            elapsed_s=stats.elapsed_s,
            monitor_s=monitor_s,
            seq=self._next_seq(),
            repair=getattr(self.stream, "last_repair", ""),
            rung_count=int(counts.get(escalation, 0)),
            rung_total_s=float(totals.get(escalation, 0.0)),
            rebuild_state=getattr(self.stream, "rebuild_state", ""),
            rebuild_s=float(getattr(self.stream, "last_rebuild_s", 0.0)),
            rebuilds_in_flight=int(getattr(self.stream, "rebuilds_in_flight", 0)),
            program_cache=self._cache_counters(),
            spill=dict(getattr(self.stream, "spill_counters", None) or {}),
        )
        self.events.append(ev)
        return ev

    def _emit(self, kind, k_old, k_new, lost, reason) -> ScaleEvent:
        """Decision + dispatch in one call — what the membership hooks
        (``add_hosts``/``poll``) use."""
        return self._execute(ScaleDecision(kind, k_old, k_new, tuple(lost), reason))

    def _execute(self, decision: ScaleDecision) -> ScaleEvent:
        """Dispatch a ScaleDecision against whatever engine is attached and
        sequence the resulting ScaleEvent. Pure plan (no engine): the CEP
        model supplies the migration fraction."""
        kind, k_old, k_new, lost, reason = (
            decision.kind,
            decision.k_old,
            decision.k_new,
            decision.lost_hosts,
            decision.reason,
        )
        executed = False
        cross_device_bytes = 0
        cross_process_bytes = 0
        frac = None
        if self.stream is not None and k_new not in (0, self.stream.k):
            stats = self.stream.rescale(k_new)
            self.rescale_stats.append(stats)
            executed = True
            cross_device_bytes = stats.cross_device_bytes
            cross_process_bytes = stats.cross_process_bytes
            frac = stats.moved_edges / max(stats.num_edges, 1)
            if self.checkpoint is not None:
                # Scale barrier: replay re-runs relayout(k_new) here instead
                # of replaying slot ops across the geometry change.
                self.checkpoint.note_scale(self.stream.orderer, k_new, self._batch_step)
        elif self.stream is None and self.engine_data is not None and k_new not in (0, self.engine_data.k):
            if self._rescaler is None:
                from .rescale_exec import ElasticRescaler

                self._rescaler = ElasticRescaler()
            self.engine_data, stats = self._rescaler.rescale(self.engine_data, k_new)
            self.rescale_stats.append(stats)
            executed = True
            cross_device_bytes = stats.cross_device_bytes
            cross_process_bytes = stats.cross_process_bytes
            # Report what was actually migrated, not the synthetic model.
            frac = stats.migrated_edges / max(stats.num_edges, 1)
        if frac is None:
            if k_new == k_old or k_new == 0:
                frac = 0.0
            else:
                frac = cep.migrated_edges_exact(self.state_elements, k_old, k_new) / self.state_elements
        # A rescale aborts any in-flight rebuild: sequence the abort record
        # BEFORE the scale event that caused it.
        self._drain_rebuilds()
        self._m_scales.inc()
        self._mark_event()
        ev = ScaleEvent(
            kind, k_old, k_new, lost, frac, reason, executed, cross_device_bytes,
            cross_process_bytes, seq=self._next_seq(),
            program_cache=self._cache_counters(),
        )
        self.events.append(ev)
        return ev
