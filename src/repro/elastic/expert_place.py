"""GEO+CEP expert placement for elastic expert parallelism (beyond-paper use
of the paper's technique).

Experts are vertices; co-routing mass (how often two experts serve the same
token under top-k routing) are weighted edges. GEO orders the experts so
co-activated experts get adjacent ids; CEP chunks the order into EP groups.
EP-group resize k→k±x then moves the Thm.-2-minimal number of experts AND
keeps co-activated experts colocated (fewer cross-group all-to-all bytes).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import cep, ordering
from ..core.graph import Graph


def coactivation_graph(expert_ids: np.ndarray, num_experts: int) -> Graph:
    """expert_ids: (T, K) routed experts per token → weighted co-occurrence
    graph (weights folded in by edge multiplicity capping)."""
    t, k = expert_ids.shape
    pairs = []
    for i in range(k):
        for j in range(i + 1, k):
            pairs.append(np.stack([expert_ids[:, i], expert_ids[:, j]], axis=1))
    e = np.concatenate(pairs, axis=0)
    return Graph.from_edges(e, num_experts)


def order_experts(routing_stats: np.ndarray, window: int | None = None) -> np.ndarray:
    """routing_stats: (E, E) symmetric co-activation counts → expert order.

    Weighted greedy expansion — GEO's priority (Eq. 8: prefer the frontier
    vertex most attached to the recently ordered window) generalized to
    weighted edges, which the unweighted Graph container would collapse.
    O(E²·window); experts-per-model is ≤ a few hundred, so this is free.
    """
    stats = np.asarray(routing_stats, dtype=np.float64)
    e = stats.shape[0]
    if e == 0 or stats.max() <= 0:
        return np.arange(e, dtype=np.int64)
    window = window or max(1, e // 8)
    placed: list[int] = []
    rest = set(range(e))
    cur = int(np.argmax(stats.sum(1)))  # densest expert first
    while rest:
        placed.append(cur)
        rest.discard(cur)
        if not rest:
            break
        recent = placed[-window:]
        rest_list = sorted(rest)
        scores = stats[np.ix_(recent, rest_list)].sum(axis=0)
        if scores.max() > 0:
            cur = rest_list[int(np.argmax(scores))]
        else:  # disconnected: jump to the densest remaining expert
            rem_mass = stats[np.ix_(rest_list, rest_list)].sum(axis=1)
            cur = rest_list[int(np.argmax(rem_mass))]
    return np.asarray(placed, dtype=np.int64)


@dataclasses.dataclass(frozen=True)
class ExpertPlacement:
    order: np.ndarray  # expert ids in GEO order
    k_groups: int

    def group_of(self, expert: int) -> int:
        pos = int(np.flatnonzero(self.order == expert)[0])
        return int(cep.id2p(self.order.shape[0], self.k_groups, pos))

    def groups(self) -> list:
        e = self.order.shape[0]
        b = cep.chunk_bounds(e, self.k_groups)
        return [self.order[int(b[p]) : int(b[p + 1])].tolist() for p in range(self.k_groups)]

    def rescale(self, k_new: int) -> tuple["ExpertPlacement", int]:
        """O(1) regroup; returns (new placement, experts moved)."""
        moved = cep.migrated_edges_exact(self.order.shape[0], self.k_groups, k_new)
        return ExpertPlacement(self.order, k_new), moved


def cross_group_traffic(routing_stats: np.ndarray, placement: ExpertPlacement) -> float:
    """Σ co-activation mass between experts in different EP groups — the
    all-to-all proxy minimized by GEO ordering."""
    e = routing_stats.shape[0]
    pos = np.empty(e, dtype=np.int64)
    pos[placement.order] = np.arange(e)
    grp = np.asarray(cep.id2p(e, placement.k_groups, pos))
    iu = np.triu_indices(e, 1)
    cross = grp[iu[0]] != grp[iu[1]]
    return float(routing_stats[iu][cross].sum())
