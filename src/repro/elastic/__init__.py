from . import controller, expert_place, resharder  # noqa: F401
