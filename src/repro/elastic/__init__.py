from . import autoscale, controller, expert_place, rescale_exec, resharder  # noqa: F401
