from . import controller, expert_place, rescale_exec, resharder  # noqa: F401
