"""Pallas kernel: blocked edge-centric gather-reduce (vertex-cut SpMV).

The hot loop of every GAS-style graph application (PageRank/SSSP/WCC) is
``y[dst] += w * x[src]`` over an edge chunk. The GEO ordering guarantees each
chunk touches a *narrow vertex window* (that is exactly what low RF means), so
the TPU-native formulation is:

  per chunk: load the x-window (W_V,) into VMEM, turn the local src/dst ids
  into one-hot matrices, and run two small matmuls on the MXU:

      vals   = onehot(src_local) @ x_window            (W_E,)
      y_win  = onehot(dst_local)^T @ (w * vals)        (W_V,)

This replaces the CPU hash-scatter with systolic matmuls — the adaptation
noted in DESIGN.md §4. The caller (ops.py) pre-windows x per chunk
(XLA dynamic_slice) so every Pallas block shape is static.

Shapes: src_local/dst_local (C, W_E) int32 (padded with W_V ⇒ contributes 0),
x_windows (C, W_V) f32, weights (C, W_E) f32. Output (C, W_V) f32 partial
accumulations, scattered back to the global vector by the caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(src_ref, dst_ref, w_ref, x_ref, out_ref):
    src = src_ref[...]  # (1, W_E) int32 local ids in [0, W_V] — W_V = padding
    dst = dst_ref[...]
    w = w_ref[...]  # (1, W_E) f32
    x = x_ref[...]  # (1, W_V) f32
    w_e = src.shape[1]
    w_v = x.shape[1]
    # One-hot gather: (W_E, W_V) @ (W_V,) on the MXU. Padding rows are all-zero.
    cols = jax.lax.broadcasted_iota(jnp.int32, (w_e, w_v), 1)
    gather = (cols == src.reshape(w_e, 1)).astype(jnp.float32)
    vals = gather @ x.reshape(w_v, 1)  # (W_E, 1)
    vals = vals * w.reshape(w_e, 1)
    scatter = (cols == dst.reshape(w_e, 1)).astype(jnp.float32)  # (W_E, W_V)
    out_ref[...] = (scatter.T @ vals).reshape(1, w_v)


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmv_blocked(src_local, dst_local, weights, x_windows, interpret: bool = True):
    """Per-chunk gather-reduce. Returns (C, W_V) partial y windows."""
    c, w_e = src_local.shape
    w_v = x_windows.shape[1]
    return pl.pallas_call(
        _spmv_kernel,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, w_e), lambda i: (i, 0)),
            pl.BlockSpec((1, w_e), lambda i: (i, 0)),
            pl.BlockSpec((1, w_e), lambda i: (i, 0)),
            pl.BlockSpec((1, w_v), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, w_v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, w_v), jnp.float32),
        interpret=interpret,
    )(src_local, dst_local, weights, x_windows)
