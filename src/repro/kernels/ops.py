"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container) so the kernel bodies
execute in Python-on-CPU for validation; on a TPU backend the same calls lower
to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import decode_attention as _dec
from . import edge_spmv as _spmv
from . import flash_attention as _fa
from . import segment_rf as _rf
from .segment_rf import PAD_ID

__all__ = [
    "on_tpu",
    "replication_factor_kernel",
    "chunked_spmv",
    "flash_attention",
    "decode_attention",
    "PAD_ID",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp() -> bool:
    return not on_tpu()


def replication_factor_kernel(src_ordered, dst_ordered, k: int, num_vertices: int) -> float:
    """RF of CEP chunks over an ordered edge list, via the segment_rf kernel.

    Chunks are padded to a common width; endpoint ids are sorted per row by
    XLA and the Pallas kernel counts distinct ids per row in VMEM.
    """
    from ..core import cep

    e = int(src_ordered.shape[0])
    bounds = np.asarray(cep.chunk_bounds(e, k))
    width = int(np.max(np.diff(bounds))) * 2
    width = max(8, int(np.ceil(width / 8)) * 8)
    rows = np.full((k, width), int(PAD_ID), dtype=np.int32)
    src_ordered = np.asarray(src_ordered)
    dst_ordered = np.asarray(dst_ordered)
    for p in range(k):
        lo, hi = bounds[p], bounds[p + 1]
        ids = np.concatenate([src_ordered[lo:hi], dst_ordered[lo:hi]]).astype(np.int32)
        rows[p, : ids.shape[0]] = ids
    rows = jnp.sort(jnp.asarray(rows), axis=1)
    counts = _rf.segment_distinct_counts(rows, interpret=_interp())
    return float(jnp.sum(counts)) / float(num_vertices)


def chunked_spmv(src, dst, weights, x, chunk_bounds, window_starts, window_size: int):
    """y[dst] += w·x[src] over GEO-ordered edge chunks via the blocked kernel.

    Caller supplies per-chunk vertex-window starts; edges whose endpoints fall
    outside their chunk window are handled in a (small) XLA fallback pass so
    the kernel result is exact.
    """
    c = len(window_starts)
    w_e = int(np.max(np.diff(chunk_bounds)))
    src_l = np.full((c, w_e), window_size, dtype=np.int32)
    dst_l = np.full((c, w_e), window_size, dtype=np.int32)
    wts = np.zeros((c, w_e), dtype=np.float32)
    fallback = []  # (src, dst, w) COO triples outside windows
    src = np.asarray(src)
    dst = np.asarray(dst)
    weights = np.asarray(weights, dtype=np.float32)
    for ci in range(c):
        lo, hi = chunk_bounds[ci], chunk_bounds[ci + 1]
        ws = window_starts[ci]
        for j, e in enumerate(range(lo, hi)):
            sl, dl = src[e] - ws, dst[e] - ws
            if 0 <= sl < window_size and 0 <= dl < window_size:
                src_l[ci, j] = sl
                dst_l[ci, j] = dl
                wts[ci, j] = weights[e]
            else:
                fallback.append((src[e], dst[e], weights[e]))
    x = np.asarray(x, dtype=np.float32)
    xw = np.stack([x[ws : ws + window_size] for ws in window_starts])
    y_win = _spmv.spmv_blocked(
        jnp.asarray(src_l), jnp.asarray(dst_l), jnp.asarray(wts), jnp.asarray(xw),
        interpret=_interp(),
    )
    y = np.zeros_like(x)
    y_win = np.asarray(y_win)
    for ci, ws in enumerate(window_starts):
        y[ws : ws + window_size] += y_win[ci]
    for s_, d_, w_ in fallback:
        y[d_] += w_ * x[s_]
    return y


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", _interp())
    return _fa.flash_attention(q, k, v, **kw)


def decode_attention(q, k, v, cache_len, **kw):
    kw.setdefault("interpret", _interp())
    return _dec.decode_attention(q, k, v, cache_len, **kw)
