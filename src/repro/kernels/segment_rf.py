"""Pallas kernel: per-chunk distinct-vertex counting (replication factor).

TPU adaptation of the paper's RF evaluation: the CPU code would walk each
chunk with a hash set; on TPU we (i) sort each chunk's endpoint ids (XLA sort,
done by the caller/ops.py), (ii) run this kernel, which counts boundaries
``ids[i] != ids[i-1]`` per VMEM-resident row block — a pure vector op on the
VPU, 8×128-lane friendly.

Layout: ids is (num_chunks, width) int32, each row sorted ascending with
padding = PAD_ID (int32 max) at the tail. Output is (num_chunks, 1) int32
distinct counts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PAD_ID = jnp.iinfo(jnp.int32).max

# Rows per grid step — one VMEM block is (BLOCK_ROWS, width) int32.
BLOCK_ROWS = 8


def _segment_rf_kernel(ids_ref, out_ref):
    ids = ids_ref[...]  # (BLOCK_ROWS, W) int32, each row sorted
    prev = jnp.concatenate([jnp.full((ids.shape[0], 1), -1, ids.dtype), ids[:, :-1]], axis=1)
    is_new = (ids != prev) & (ids != PAD_ID)
    out_ref[...] = jnp.sum(is_new.astype(jnp.int32), axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def segment_distinct_counts(ids_sorted: jax.Array, interpret: bool = True) -> jax.Array:
    """ids_sorted: (C, W) int32 rows sorted ascending, PAD_ID padded → (C,) counts."""
    c, w = ids_sorted.shape
    c_pad = (-c) % BLOCK_ROWS
    if c_pad:
        ids_sorted = jnp.concatenate(
            [ids_sorted, jnp.full((c_pad, w), PAD_ID, jnp.int32)], axis=0
        )
    grid = (ids_sorted.shape[0] // BLOCK_ROWS,)
    out = pl.pallas_call(
        _segment_rf_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ids_sorted.shape[0], 1), jnp.int32),
        interpret=interpret,
    )(ids_sorted)
    return out[:c, 0]
