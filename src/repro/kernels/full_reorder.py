"""Whole-graph GEO re-ordering as a device program — the full-rebuild rung.

The escalation ladder's top rung (DESIGN.md §9/§11) re-orders EVERY live slot,
not just a degraded span. This module generalizes the span-repair kernel of
``kernels/span_reorder.py`` from span scope to whole-graph scope, keeping the
same program shape — an order kernel finished by one fused multi-key
``lax.sort`` whose unique slot key makes the composite a total order (any
correct sort, host np.lexsort included, yields the identical permutation) —
and the same differential-oracle discipline: ``full_order_host`` is the
byte-exact numpy mirror of ``full_order_device``, proven by the differential
tests, so the engine advances host bookkeeping without a device round-trip.

The order kernel itself is NOT the span rung's label propagation. At span
scope label propagation works because a span holds one or two communities;
at whole-graph scope it was measured to never beat the incumbent layout under
mild drift (the whole point of a full rebuild is restoring fine-k locality,
which community labels alone cannot express). Instead the kernel is a
step-parallel form of GEO's greedy itself (core/ordering.py Algorithm 4):

1. Per step, pick v_min by the exact GEO priority α·D[v] − β·M[v] over
   touched unselected vertices (random-permutation fallback otherwise).
2. Order ALL of v_min's remaining edges at once (GEO orders them ascending
   by neighbor; here they share a step and sort by the neighbor key), then
   eagerly order the two-hop edges e_{u,w} whose w was touched within δ —
   the same Line-11 recency test, with M updated at step granularity.
3. Every ordered edge records (step, phase, key_a, key_b); the final 5-key
   ``lax.sort`` (step, phase, key_a, key_b, slot) IS the order. Dead slots
   key to int32-max and sort last, so the permutation is live-first like the
   span kernel's.

The step-granular M makes this a coarser recency than the sequential greedy's
per-edge M — measured within 1.05× of host ``geo_order``'s RF across the
k grid on drifted RMAT streams — while turning GEO's pointer chase into
O(|V_selected|) vectorized steps of scatter/gather, the form an accelerator
can run over the snapshot buffer while ingest keeps landing on the live one.

Candidate selection (``select_full_order_*``) reuses the span kernel's exact
integer objective at whole-graph scope: the greedy order and a caller-supplied
candidate permutation (production: the incumbent layout; oracle/differential
modes: host ``geo_order``) are scored over the CEP chunk grid and the better
one wins, ties to the greedy — a committed device rebuild can never regress
the objective below what is already there.

int32-range discipline: the device runs int32 (jax x64 off); the mirror runs
int64 and ``greedy_params`` rejects graphs whose priorities could overflow
int32, so the two never diverge by wraparound.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core import ordering
from .segment_rf import PAD_ID
from .span_reorder import (
    eval_ks,
    identity_candidate,
    span_objective_device,
    span_objective_host,
)

__all__ = [
    "greedy_fits_int32",
    "greedy_params",
    "fallback_positions",
    "eval_ks_full",
    "full_order_host",
    "full_order_device",
    "full_objective_host",
    "full_objective_device",
    "select_full_order_host",
    "select_full_order_device",
    "geo_full_candidate",
]

_PAD = int(PAD_ID)  # int32 max — dead-slot sort key


def greedy_fits_int32(num_edges: int, k_min: int, k_max: int, max_degree: int) -> bool:
    """Whether the step-parallel greedy's priorities α·D − β·M stay inside
    int32 for this graph — the precondition of ``greedy_params``. Callers on
    the rebuild path (stream/ingest, core/hier_order) test this and fall back
    to a host ordering instead of aborting: out-of-core chunks routinely
    cross the bound and a rebuild must degrade, not die."""
    ks = np.arange(k_min, k_max + 1, dtype=np.int64)
    alpha = int(np.sum(num_edges // ks))
    beta = int(k_max - k_min)
    return alpha * (int(max_degree) + 1) + beta * (num_edges + 1) < 2**31


def greedy_params(
    num_edges: int,
    k_min: int,
    k_max: int,
    max_degree: int,
) -> tuple[int, int, int]:
    """(alpha, beta, delta) of the step-parallel greedy — the SAME constants
    core/ordering.geo_order derives (Eq. 8 priorities, §4.1 δ), so the two
    rungs optimize one objective. Raises when a priority α·D − β·M could
    leave int32 range: the device computes int32, the mirror int64, and a
    silent wrap on only one side would break the byte-identity contract."""
    ks = np.arange(k_min, k_max + 1, dtype=np.int64)
    alpha = int(np.sum(num_edges // ks))
    beta = int(k_max - k_min)
    delta = max(1, num_edges // k_max)
    bound = alpha * (int(max_degree) + 1) + beta * (num_edges + 1)
    if bound >= 2**31:
        raise ValueError(
            f"greedy priorities may overflow int32 (bound {bound}): "
            "graph too large for the device full-reorder kernel"
        )
    return alpha, beta, delta


def fallback_positions(num_vertices: int, seed: int = 0) -> np.ndarray:
    """Random-vertex fallback ranks (paper: RandomVertex()): position of each
    vertex in a seeded permutation — the untouched-component tie-break, fixed
    per rebuild so host and device pick the identical fallback vertex."""
    rng = np.random.default_rng(seed)
    pos = np.empty(num_vertices, dtype=np.int64)
    pos[rng.permutation(num_vertices)] = np.arange(num_vertices)
    return pos


def eval_ks_full(k_min: int, k_max: int, regions: int) -> tuple:
    """Objective k grid for full-rebuild candidate selection: the span grid
    plus the CURRENT region count — a full rebuild must never regress the RF
    at the k the mesh is actually partitioned into."""
    ks = set(eval_ks(k_min, k_max))
    if k_min <= regions <= k_max:
        ks.add(int(regions))
    return tuple(sorted(ks))


# ----------------------------------------------------------------- host mirror
def full_order_host(
    u: np.ndarray,
    v: np.ndarray,
    valid: np.ndarray,
    num_vertices: int,
    alpha: int,
    beta: int,
    delta: int,
    permpos: np.ndarray,
) -> np.ndarray:
    """Numpy mirror of ``full_order_device`` — identical permutation byte for
    byte (int64 arithmetic over int32-range values; see ``greedy_params``)."""
    cap = u.shape[0]
    ui = np.asarray(u, dtype=np.int64)
    vi = np.asarray(v, dtype=np.int64)
    valid = np.asarray(valid, dtype=bool)
    permpos = np.asarray(permpos, dtype=np.int64)
    done = ~valid.copy()
    d = np.zeros(num_vertices, np.int64)
    np.add.at(d, ui[valid], 1)
    np.add.at(d, vi[valid], 1)
    m = np.zeros(num_vertices, np.int64)
    touched = np.zeros(num_vertices, bool)
    selected = np.zeros(num_vertices, bool)
    e_live = int(valid.sum())
    MAX = np.int64(_PAD)
    step = np.full(cap, MAX, np.int64)
    phase = np.full(cap, MAX, np.int64)
    ka = np.full(cap, MAX, np.int64)
    kb = np.full(cap, MAX, np.int64)
    i = 0
    for t in range(num_vertices):
        if i >= e_live:
            break
        cand = touched & ~selected & (d > 0)
        if cand.any():
            vmin = int(np.argmin(np.where(cand, alpha * d - beta * m, MAX)))
        else:
            vmin = int(np.argmin(np.where(~selected & (d > 0), permpos, MAX)))
        # --- one-hop: every remaining edge of v_min, keyed by the neighbor
        oh = (~done) & ((ui == vmin) | (vi == vmin))
        other = np.where(ui == vmin, vi, ui)
        n1 = int(oh.sum())
        i1 = i + n1
        step[oh] = t
        phase[oh] = 0
        ka[oh] = other[oh]
        kb[oh] = 0
        m[other[oh]] = i1
        np.subtract.at(d, other[oh], 1)
        touched[other[oh]] = True
        touched[vmin] = True
        done |= oh
        d[vmin] = 0
        selected[vmin] = True
        i = i1
        # --- two-hop: e_{u,w} with u in the fresh frontier, w recent (≤ δ)
        if n1:
            fr = np.zeros(num_vertices, bool)
            fr[other[oh]] = True
            u_in = fr[ui]
            v_in = fr[vi]
            wother = np.where(u_in, vi, ui)
            rec = (
                touched[wother]
                & ~selected[wother]
                & (m[wother] > 0)
                & ((i1 - m[wother]) <= delta)
            )
            th = (~done) & (u_in | v_in) & rec & (wother != vmin)
            n2 = int(th.sum())
            if n2:
                tu = np.where(u_in[th], ui[th], vi[th])
                tw = wother[th]
                step[th] = t
                phase[th] = 1
                ka[th] = tu
                kb[th] = tw
                i2 = i1 + n2
                np.subtract.at(d, tu, 1)
                np.subtract.at(d, tw, 1)
                m[tu] = i2
                m[tw] = i2
                done |= th
                i = i2
    slot = np.arange(cap, dtype=np.int64)
    # Unique composite (slot breaks all ties) → sort-implementation agnostic.
    return np.lexsort((slot, kb, ka, phase, step))


# -------------------------------------------------------------- device (jnp)
def full_order_device(u, v, valid, num_vertices: int, alpha, beta, delta, permpos):
    """Traced twin of ``full_order_host``. ``u``/``v`` int32 (cap,), ``valid``
    bool (cap,), ``alpha``/``beta``/``delta`` int32 scalars, ``permpos`` int32
    (|V|,) — all operands, so ONE compiled program serves every rebuild of a
    layout signature. Returns the (cap,) permutation, live slots first."""
    cap = u.shape[0]
    nv = int(num_vertices)
    MAX = jnp.int32(_PAD)
    ui = u.astype(jnp.int32)
    vi = v.astype(jnp.int32)
    e_live = jnp.sum(valid.astype(jnp.int32))
    # Degrees via a dump-row scatter: invalid slots target index nv, sliced off.
    iu = jnp.where(valid, ui, nv)
    iv = jnp.where(valid, vi, nv)
    d0 = jnp.zeros(nv + 1, jnp.int32).at[iu].add(1).at[iv].add(1)[:nv]
    state0 = (
        jnp.int32(0),  # t — step counter
        jnp.int32(0),  # i — edges ordered so far (|X^phi|)
        d0,  # D[v]
        jnp.zeros(nv, jnp.int32),  # M[v]
        jnp.zeros(nv, jnp.bool_),  # touched
        jnp.zeros(nv, jnp.bool_),  # selected
        ~valid,  # done (per slot)
        jnp.full(cap, MAX, jnp.int32),  # step key
        jnp.full(cap, MAX, jnp.int32),  # phase key
        jnp.full(cap, MAX, jnp.int32),  # neighbor key a
        jnp.full(cap, MAX, jnp.int32),  # neighbor key b
    )

    def cond(s):
        return (s[0] < nv) & (s[1] < e_live)

    def body(s):
        t, i, d, m, touched, selected, done, step, phase, ka, kb = s
        cand = touched & (~selected) & (d > 0)
        pri = jnp.where(cand, alpha * d - beta * m, MAX)
        vmin_c = jnp.argmin(pri).astype(jnp.int32)
        elig = (~selected) & (d > 0)
        vmin_f = jnp.argmin(jnp.where(elig, permpos, MAX)).astype(jnp.int32)
        vmin = jnp.where(cand.any(), vmin_c, vmin_f)
        # one-hop
        oh = (~done) & ((ui == vmin) | (vi == vmin))
        other = jnp.where(ui == vmin, vi, ui)
        n1 = oh.sum().astype(jnp.int32)
        i1 = i + n1
        step = jnp.where(oh, t, step)
        phase = jnp.where(oh, 0, phase)
        ka = jnp.where(oh, other, ka)
        kb = jnp.where(oh, 0, kb)
        oidx = jnp.where(oh, other, nv)  # dump row nv for unordered slots
        m = jnp.pad(m, (0, 1)).at[oidx].set(i1)[:nv]
        d = jnp.pad(d, (0, 1)).at[oidx].add(-1)[:nv]
        touched = jnp.pad(touched, (0, 1)).at[oidx].set(True)[:nv]
        touched = touched.at[vmin].set(True)
        done = done | oh
        d = d.at[vmin].set(0)
        selected = selected.at[vmin].set(True)
        # two-hop
        fr = jnp.zeros(nv + 1, jnp.bool_).at[oidx].set(True)[:nv]
        u_in = fr[ui]
        v_in = fr[vi]
        wother = jnp.where(u_in, vi, ui)
        rec = (
            touched[wother]
            & (~selected[wother])
            & (m[wother] > 0)
            & ((i1 - m[wother]) <= delta)
        )
        th = (~done) & (u_in | v_in) & rec & (wother != vmin) & (n1 > 0)
        n2 = th.sum().astype(jnp.int32)
        tu = jnp.where(u_in, ui, vi)
        step = jnp.where(th, t, step)
        phase = jnp.where(th, 1, phase)
        ka = jnp.where(th, tu, ka)
        kb = jnp.where(th, wother, kb)
        i2 = i1 + n2
        tui = jnp.where(th, tu, nv)
        twi = jnp.where(th, wother, nv)
        d = jnp.pad(d, (0, 1)).at[tui].add(-1).at[twi].add(-1)[:nv]
        m = jnp.pad(m, (0, 1)).at[tui].set(i2).at[twi].set(i2)[:nv]
        done = done | th
        return (t + 1, i2, d, m, touched, selected, done, step, phase, ka, kb)

    s = lax.while_loop(cond, body, state0)
    step, phase, ka, kb = s[7], s[8], s[9], s[10]
    slot = jnp.arange(cap, dtype=jnp.int32)
    # One fused 5-key sort — the whole-graph twin of the span kernel's finish.
    return lax.sort((step, phase, ka, kb, slot), num_keys=5)[4]


# ------------------------------------------------------- objective + selection
def full_objective_host(
    u: np.ndarray, v: np.ndarray, valid: np.ndarray, order: np.ndarray, ks: Sequence[int]
) -> int:
    """Exact whole-graph objective of a live-first permutation — the span
    objective evaluated at graph scope (the machinery is scope-free)."""
    return span_objective_host(u, v, valid, order, ks)


def full_objective_device(u, v, valid, order, n, ks, *, use_pallas: bool):
    """Traced twin of ``full_objective_host`` (identical integers)."""
    return span_objective_device(u, v, valid, order, n, ks, use_pallas=use_pallas)


def select_full_order_host(
    u: np.ndarray,
    v: np.ndarray,
    valid: np.ndarray,
    num_vertices: int,
    candidate: np.ndarray,
    ks: Sequence[int],
    alpha: int,
    beta: int,
    delta: int,
    permpos: np.ndarray,
) -> tuple[np.ndarray, bool]:
    """(chosen order, chose_candidate): the step-parallel greedy order vs the
    candidate permutation by the exact whole-graph objective; the candidate
    wins only on a STRICT improvement. With the incumbent layout as the
    candidate this is the never-worse guarantee; with host ``geo_order`` it is
    never-worse-than-GEO by construction."""
    greedy = full_order_host(u, v, valid, num_vertices, alpha, beta, delta, permpos)
    obj_g = full_objective_host(u, v, valid, greedy, ks)
    obj_c = full_objective_host(u, v, valid, candidate, ks)
    if obj_c < obj_g:
        return np.asarray(candidate, dtype=np.int64), True
    return greedy, False


def select_full_order_device(
    u, v, valid, num_vertices: int, candidate, ks, alpha, beta, delta, permpos,
    *, use_pallas: bool,
):
    """Traced twin of ``select_full_order_host`` (returns only the chosen
    permutation — the mirror recomputes the identical decision host-side)."""
    n = jnp.sum(valid.astype(jnp.int32))
    greedy = full_order_device(u, v, valid, num_vertices, alpha, beta, delta, permpos)
    obj_g = full_objective_device(u, v, valid, greedy, n, ks, use_pallas=use_pallas)
    obj_c = full_objective_device(u, v, valid, candidate, n, ks, use_pallas=use_pallas)
    return jnp.where(obj_c < obj_g, candidate.astype(jnp.int32), greedy)


def geo_full_candidate(
    slot_src: np.ndarray,
    slot_dst: np.ndarray,
    slot_valid: np.ndarray,
    num_vertices: int,
    k_min: int = ordering.K_MIN_DEFAULT,
    k_max: int = ordering.K_MAX_DEFAULT,
    seed: int = 0,
) -> np.ndarray:
    """Host ``geo_order`` of the WHOLE live slot array as a live-first slot
    permutation — the full-rebuild quality oracle, and the production
    candidate of the async rung on hosts where the greedy device program is
    not profitable. The graph is rebuilt from the slots, ordered, and mapped
    back to slot ids (slots hold unique canonical u < v pairs, so the mapping
    is a bijection — the order is expressed over the slots, never over the
    Graph's re-sorted edge arrays)."""
    from ..core.graph import Graph

    valid = np.asarray(slot_valid, dtype=bool)
    live = np.flatnonzero(valid)
    if live.size < 2:
        return identity_candidate(valid)
    u = np.asarray(slot_src, dtype=np.int64)
    v = np.asarray(slot_dst, dtype=np.int64)
    g = Graph.from_edges(np.stack([u[live], v[live]], axis=1), num_vertices)
    order = ordering.geo_order(g, k_min, k_max, seed=seed)
    # Slot lookup via scalar keys + searchsorted (the (u, v) pairs are unique
    # canonical edges, so u·V + v is a bijection — and V² fits int64 for any
    # graph this subsystem can hold).
    nv = np.int64(num_vertices)
    slot_keys = u[live] * nv + v[live]
    sorter = np.argsort(slot_keys, kind="stable")
    ordered_keys = g.src[order].astype(np.int64) * nv + g.dst[order].astype(np.int64)
    cand_live = live[sorter[np.searchsorted(slot_keys[sorter], ordered_keys)]]
    return np.concatenate([cand_live, np.flatnonzero(~valid)])
