"""Span-local GEO repair — the device side of the partial re-order rung.

The streaming escalation ladder's middle rung (DESIGN.md §9) repairs only the
worst span of regions. PR-3 ran host ``geo_order`` on the extracted span and
re-uploaded the rewritten slots; that host pass dominated the stream's
amortized cost (BENCH_stream.json). This module provides the on-device
replacement and its byte-exact host mirror — the *differential oracle*
discipline: the jitted program and the numpy mirror implement the identical
integer algorithm, so the engine can update host bookkeeping from the mirror
(no device round-trip) while ``verify_bit_identity`` proves the two never
diverge.

Algorithm (``span_order_*``): neighbor-expansion scoring over the span's live
edges, fully vectorized so it runs in O(rounds · span) VPU-friendly ops
instead of GEO's sequential greedy —

1. ``rounds`` iterations of min-label propagation over the span edges
   (scatter-min): every vertex adopts the smallest vertex id reachable within
   ``rounds`` hops inside the span. Connected neighborhoods collapse onto one
   label — the vectorized stand-in for GEO's frontier expansion.
2. Each vertex records the round its label last improved (``depth``) — its
   expansion distance from the neighborhood root, the analogue of GEO's
   recency M[v].
3. Edges sort by (label, depth, lo endpoint, hi endpoint, slot): one
   neighborhood at a time, inner edges before fringe edges. The slot key makes
   the composite unique, so ANY correct sort yields the same permutation —
   host np.lexsort and device jnp.lexsort agree bit-for-bit.

Candidate selection (``select_span_order_*``): the repair never commits blind.
The program scores its expansion order AND a caller-supplied candidate
permutation by the exact multi-k span objective (Eq.-(7)-style distinct-vertex
counts over CEP chunks at ``eval_ks``) and keeps the better, ties to the
expansion order. Production passes the *current* layout as the candidate, so a
repair can never worsen the span objective; the differential tests pass host
``geo_order`` as the candidate, making never-worse-than-GEO hold by
construction (ISSUE-5 satellite).

Objective evaluation is tombstone-aware (dead slots key to PAD and count
nothing) and, where profitable, runs the distinct counting through the Pallas
boundary-count kernel of ``kernels/segment_rf.py`` — the per-(chunk, k) key
rows are exactly that kernel's sorted-row layout. The Pallas path is gated to
single-device/single-process meshes; the jnp fallback computes the identical
integers.

Everything here sticks to int32-range arithmetic (jax x64 is off by default),
mirrored in int64 by numpy without divergence.
"""
from __future__ import annotations

from typing import Sequence

import jax.lax
import jax.numpy as jnp
import numpy as np

from ..core import cep
from .segment_rf import PAD_ID, segment_distinct_counts

__all__ = [
    "SPAN_ROUNDS",
    "eval_ks",
    "identity_candidate",
    "span_order_host",
    "span_objective_host",
    "select_span_order_host",
    "span_order_device",
    "span_objective_device",
    "select_span_order_device",
    "splice_targets_device",
]

# Label-propagation rounds: how far a neighborhood expands. Spans are one to
# three regions wide; 4 hops collapses any community that fits in one
# (measured identical span objective to 16 rounds on degraded RMAT spans),
# and each round costs two scatter-mins — the program's dominant op on CPU
# meshes, so rounds are the partial rung's main cost knob.
SPAN_ROUNDS = 4

_PAD = int(PAD_ID)  # int32 max — tombstone/padding key for ids and chunk keys


def eval_ks(k_min: int, k_max: int) -> tuple:
    """The static k grid the span objective sums over: geometric steps of the
    GEO objective's [k_min, k_max] range (evaluating all ~125 k's per repair
    would cost more than the repair; three decades rank candidates the same
    way the full grid does on every span tested)."""
    ks = tuple(k for k in (4, 16, 64) if k_min <= k <= k_max)
    return ks if ks else (max(2, int(k_min)),)


def identity_candidate(valid: np.ndarray) -> np.ndarray:
    """The current span layout as a live-first permutation: occupied slots in
    slot order, tombstones appended — the production candidate (a repair must
    never score worse than what's already there)."""
    valid = np.asarray(valid, dtype=bool)
    return np.concatenate([np.flatnonzero(valid), np.flatnonzero(~valid)])


# ----------------------------------------------------------------- host mirror
def span_order_host(
    u: np.ndarray,
    v: np.ndarray,
    valid: np.ndarray,
    num_vertices: int,
    rounds: int = SPAN_ROUNDS,
) -> np.ndarray:
    """Numpy mirror of ``span_order_device`` — identical permutation, proven
    byte-for-byte by the differential tests and ``verify_bit_identity``."""
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    valid = np.asarray(valid, dtype=bool)
    cap = u.shape[0]
    uu, vv = u[valid], v[valid]
    lbl = np.arange(num_vertices, dtype=np.int64)
    depth = np.zeros(num_vertices, dtype=np.int64)
    for t in range(1, rounds + 1):
        le = np.minimum(lbl[uu], lbl[vv])
        new = lbl.copy()
        np.minimum.at(new, uu, le)
        np.minimum.at(new, vv, le)
        depth = np.where(new < lbl, t, depth)
        if np.array_equal(new, lbl):
            break  # converged — the device runs all rounds as no-ops
        lbl = new
    comp = np.where(valid, np.minimum(lbl[u], lbl[v]), _PAD)
    dep = np.where(valid, np.minimum(depth[u], depth[v]), 0)
    lo = np.where(valid, np.minimum(u, v), 0)
    hi = np.where(valid, np.maximum(u, v), 0)
    slot = np.arange(cap, dtype=np.int64)
    # Unique composite (slot breaks every tie) → sort-implementation agnostic.
    return np.lexsort((slot, hi, lo, dep, comp))


def span_objective_host(
    u: np.ndarray,
    v: np.ndarray,
    valid: np.ndarray,
    order: np.ndarray,
    ks: Sequence[int],
) -> int:
    """Exact span objective of a live-first permutation: Σ_{k∈ks} Σ_chunks
    |V(chunk)| over CEP chunks of the span's live edges. Integer, so the host
    and device comparisons agree exactly."""
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    valid = np.asarray(valid, dtype=bool)
    order = np.asarray(order, dtype=np.int64)
    n = int(valid.sum())
    if n == 0:
        return 0
    uo, vo = u[order[:n]], v[order[:n]]
    total = 0
    j = np.arange(n, dtype=np.int64)
    for k in ks:
        p = np.asarray(cep.id2p(n, int(k), j), dtype=np.int64)
        key = np.concatenate([p, p])
        ids = np.concatenate([uo, vo])
        total += np.unique(key * (np.int64(2) ** 32) + ids).shape[0]
    return int(total)


def select_span_order_host(
    u: np.ndarray,
    v: np.ndarray,
    valid: np.ndarray,
    num_vertices: int,
    candidate: np.ndarray,
    ks: Sequence[int],
    rounds: int = SPAN_ROUNDS,
) -> tuple[np.ndarray, bool]:
    """(chosen order, chose_candidate): expansion order vs candidate by exact
    objective, candidate only on a strict win — mirror of the device select."""
    vec = span_order_host(u, v, valid, num_vertices, rounds)
    obj_vec = span_objective_host(u, v, valid, vec, ks)
    obj_cand = span_objective_host(u, v, valid, candidate, ks)
    if obj_cand < obj_vec:
        return np.asarray(candidate, dtype=np.int64), True
    return vec, False


# -------------------------------------------------------------- device (jnp)
def span_order_device(u, v, valid, num_vertices: int, rounds: int = SPAN_ROUNDS):
    """Traced twin of ``span_order_host``. ``u``/``v`` int32 (cap,), ``valid``
    bool (cap,); returns the (cap,) permutation, live slots first."""
    cap = u.shape[0]
    ui = jnp.where(valid, u, 0)
    vi = jnp.where(valid, v, 0)

    def body(i, carry):
        lbl, depth = carry
        le = jnp.where(valid, jnp.minimum(lbl[ui], lbl[vi]), jnp.int32(_PAD))
        new = lbl.at[ui].min(le).at[vi].min(le)
        depth = jnp.where(new < lbl, (i + 1).astype(jnp.int32), depth)
        return new, depth

    # fori_loop, not an unrolled python loop: the body compiles once, keeping
    # the span program's trace small (compile time is a real cost — one per
    # (k, e_cap, span) signature over a stream's life).
    lbl, depth = jax.lax.fori_loop(
        0,
        rounds,
        body,
        (jnp.arange(num_vertices, dtype=jnp.int32), jnp.zeros(num_vertices, jnp.int32)),
    )
    comp = jnp.where(valid, jnp.minimum(lbl[ui], lbl[vi]), jnp.int32(_PAD))
    dep = jnp.where(valid, jnp.minimum(depth[ui], depth[vi]), 0)
    lo = jnp.where(valid, jnp.minimum(u, v), 0)
    hi = jnp.where(valid, jnp.maximum(u, v), 0)
    slot = jnp.arange(cap, dtype=jnp.int32)
    # One fused 5-key sort; the unique slot key makes the composite a total
    # order, so the sorted slot column IS the permutation (and any correct
    # sort — np.lexsort on the host — produces the identical one).
    return jax.lax.sort((comp, dep, lo, hi, slot), num_keys=5)[4]


def _chunk_keys_device(u, v, valid, order, n, ks):
    """(len(ks), 2·cap) int32 rows of (chunk, vertex-rank) keys, PAD where
    dead — each row sorted is exactly the layout segment_rf counts over."""
    cap = u.shape[0]
    ids_sorted = jnp.sort(
        jnp.concatenate(
            [jnp.where(valid, u, jnp.int32(_PAD)), jnp.where(valid, v, jnp.int32(_PAD))]
        )
    )
    stride = jnp.int32(2 * cap + 2)
    uo = u[order]
    vo = v[order]
    ru = jnp.searchsorted(ids_sorted, uo).astype(jnp.int32)
    rv = jnp.searchsorted(ids_sorted, vo).astype(jnp.int32)
    j = jnp.arange(cap, dtype=jnp.int32)
    live = j < n
    rows = []
    for k in ks:
        p = cep.id2p(n, int(k), j).astype(jnp.int32)
        ku = jnp.where(live, p * stride + ru, jnp.int32(_PAD))
        kv = jnp.where(live, p * stride + rv, jnp.int32(_PAD))
        rows.append(jnp.concatenate([ku, kv]))
    return jnp.stack(rows)


def span_objective_device(u, v, valid, order, n, ks, *, use_pallas: bool):
    """Traced twin of ``span_objective_host`` (identical integer result).

    ``use_pallas=True`` routes the distinct counting through the segment_rf
    boundary-count kernel (interpret mode — CPU/VPU friendly); the jnp path is
    the same boundary comparison inline, for meshes where a Pallas custom call
    cannot be SPMD-partitioned."""
    keys = jnp.sort(_chunk_keys_device(u, v, valid, order, n, ks), axis=-1)
    if use_pallas:
        return jnp.sum(segment_distinct_counts(keys))
    prev = jnp.concatenate(
        [jnp.full((keys.shape[0], 1), -1, keys.dtype), keys[:, :-1]], axis=1
    )
    return jnp.sum(((keys != prev) & (keys != _PAD)).astype(jnp.int32))


def select_span_order_device(
    u, v, valid, num_vertices: int, candidate, ks, *, use_pallas: bool,
    rounds: int = SPAN_ROUNDS,
):
    """Traced twin of ``select_span_order_host``: returns the chosen (cap,)
    permutation (never returns the objective — the host mirror recomputes the
    identical choice, so nothing needs to travel back)."""
    n = jnp.sum(valid.astype(jnp.int32))
    vec = span_order_device(u, v, valid, num_vertices, rounds)
    obj_vec = span_objective_device(u, v, valid, vec, n, ks, use_pallas=use_pallas)
    obj_cand = span_objective_device(u, v, valid, candidate, n, ks, use_pallas=use_pallas)
    return jnp.where(obj_cand < obj_vec, candidate.astype(jnp.int32), vec)


def splice_targets_device(n, span_regions: int, spr: int, cap: int):
    """Span-local slot target of each order position — the traced twin of the
    host ``_rewrite_span`` splice: CEP chunks of the n live edges over the
    span's regions, each chunk spread evenly over its region's ``spr`` slots.
    Dead positions (j ≥ n) target the overflow slot ``cap``."""
    j = jnp.arange(cap, dtype=jnp.int32)
    p = cep.id2p(n, span_regions, j).astype(jnp.int32)
    start = cep.chunk_start(n, span_regions, p).astype(jnp.int32)
    nxt = cep.chunk_start(n, span_regions, p + 1).astype(jnp.int32)
    n_p = jnp.maximum(nxt - start, 1)
    col = ((j - start) * jnp.int32(spr)) // n_p
    return jnp.where(j < n, p * jnp.int32(spr) + col, jnp.int32(cap))
