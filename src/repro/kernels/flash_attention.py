"""Pallas kernel: causal flash attention (online softmax), TPU-tiled.

Grid is (batch*heads, num_q_blocks, num_kv_blocks); the kv dimension is the
innermost (sequential on TPU), accumulating into VMEM scratch across kv steps
and writing the output block on the last step. Supports:

  * causal masking,
  * sliding windows (gemma2/gemma3 local layers, hymba SWA),
  * attention logit soft-capping (gemma2),

so it is the shared train/prefill hot-spot kernel for the assigned archs.
Block shapes default to MXU-aligned (128, 128) tiles; accumulation is f32
regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int | None, softcap: float | None,
    block_q: int, block_kv: int, num_kv_blocks: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    k_pos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)

    # Skip fully-masked blocks (upper triangle / outside the local window).
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, (kj * block_kv) <= (qi * block_q + block_q - 1))
    if window is not None:
        run = jnp.logical_and(run, (kj + 1) * block_kv - 1 >= qi * block_q - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0].astype(jnp.float32)  # (block_kv, d)
        v = v_ref[0].astype(jnp.float32)  # (block_kv, d)
        s = (q @ k.T) * scale  # (block_q, block_kv)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (block_q, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (block_q, block_kv)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[...] = m_new

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_kv", "interpret", "scale"),
)
def flash_attention(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, H, S, D)  — GQA repeat done by caller/ops.py
    v: jax.Array,
    *,
    scale: float | None = None,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = True,
) -> jax.Array:
    b, h, s, d = q.shape
    assert k.shape == (b, h, s, d) and v.shape == (b, h, s, d)
    scale = (d**-0.5) if scale is None else scale
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    assert s % block_q == 0 and s % block_kv == 0, (s, block_q, block_kv)
    bh = b * h
    qf = q.reshape(bh, s, d)
    kf = k.reshape(bh, s, d)
    vf = v.reshape(bh, s, d)
    nq = s // block_q
    nkv = s // block_kv
    kernel = functools.partial(
        _flash_kernel,
        scale=float(scale), causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, num_kv_blocks=nkv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bhi, qi, kj: (bhi, qi, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bhi, qi, kj: (bhi, kj, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bhi, qi, kj: (bhi, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bhi, qi, kj: (bhi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
