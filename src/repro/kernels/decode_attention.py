"""Pallas kernel: single-token GQA decode attention over a long KV cache.

The serve_step hot spot for decode_32k / long_500k shapes. The KV cache is
tiled along the sequence axis; each grid step emits a *partial* (o, m, l)
triple for its tile, and the caller merges partials with a numerically-stable
LSE combine. The same merge composes across devices, which is exactly how the
sequence-parallel sharded-decode path in launch/sharding.py works — the kernel
is the per-device building block.

Shapes (per call): q (B*Hkv, Gq, D) — Gq = query heads per kv head,
k/v (B*Hkv, S, D). Output partials: o (B*Hkv, nb, Gq, D), m/l (B*Hkv, nb, Gq, 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
DEFAULT_BLOCK_S = 512


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, *, scale, block_s, softcap):
    sj = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)  # (Gq, D)
    k = k_ref[0].astype(jnp.float32)  # (block_s, D)
    v = v_ref[0].astype(jnp.float32)
    s = (q @ k.T) * scale  # (Gq, block_s)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    # Mask positions beyond the true cache length (padding tail).
    pos = sj * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)
    m = jnp.max(s, axis=1, keepdims=True)  # (Gq, 1)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=1, keepdims=True)
    o_ref[0, 0] = (p @ v) / jnp.maximum(l, 1e-30)
    m_ref[0, 0] = m
    l_ref[0, 0] = l


def merge_partials(o, m, l, axis: int = 1):
    """LSE-merge partial attention outputs along ``axis`` (tiles or devices).

    o: (..., nb, Gq, D) normalized partial outputs; m/l: (..., nb, Gq, 1).
    """
    m_max = jnp.max(m, axis=axis, keepdims=True)
    w = l * jnp.exp(m - m_max)  # un-normalized weights per tile
    denom = jnp.sum(w, axis=axis, keepdims=True)
    out = jnp.sum(o * (w / jnp.maximum(denom, 1e-30)), axis=axis)
    lse = jnp.squeeze(m_max, axis) + jnp.log(jnp.squeeze(denom, axis))
    return out, lse


@functools.partial(jax.jit, static_argnames=("scale", "block_s", "softcap", "interpret"))
def decode_attention_partials(
    q: jax.Array,  # (BHkv, Gq, D)
    k: jax.Array,  # (BHkv, S, D)
    v: jax.Array,
    cache_len: jax.Array,  # (BHkv,) int32 valid lengths
    *,
    scale: float | None = None,
    block_s: int = DEFAULT_BLOCK_S,
    softcap: float | None = None,
    interpret: bool = True,
):
    bh, gq, d = q.shape
    s = k.shape[1]
    block_s = min(block_s, s)
    assert s % block_s == 0
    nb = s // block_s
    scale = (d**-0.5) if scale is None else scale
    kernel = functools.partial(_decode_kernel, scale=float(scale), block_s=block_s, softcap=softcap)
    o, m, l = pl.pallas_call(
        kernel,
        grid=(bh, nb),
        in_specs=[
            pl.BlockSpec((1, gq, d), lambda bi, sj: (bi, 0, 0)),
            pl.BlockSpec((1, block_s, d), lambda bi, sj: (bi, sj, 0)),
            pl.BlockSpec((1, block_s, d), lambda bi, sj: (bi, sj, 0)),
            pl.BlockSpec((1,), lambda bi, sj: (bi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, gq, d), lambda bi, sj: (bi, sj, 0, 0)),
            pl.BlockSpec((1, 1, gq, 1), lambda bi, sj: (bi, sj, 0, 0)),
            pl.BlockSpec((1, 1, gq, 1), lambda bi, sj: (bi, sj, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, nb, gq, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, nb, gq, 1), jnp.float32),
            jax.ShapeDtypeStruct((bh, nb, gq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, cache_len)
    return o, m, l


def decode_attention(q, k, v, cache_len, **kw):
    """Full decode attention: kernel partials + LSE merge. Returns (BHkv, Gq, D)."""
    o, m, l = decode_attention_partials(q, k, v, cache_len, **kw)
    out, _ = merge_partials(o, m, l, axis=1)
    return out
