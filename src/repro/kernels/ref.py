"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def segment_distinct_counts_ref(ids_sorted: np.ndarray, pad_id: int) -> np.ndarray:
    """Distinct non-pad values per row (rows need not even be sorted here)."""
    out = np.zeros(ids_sorted.shape[0], dtype=np.int32)
    for i, row in enumerate(np.asarray(ids_sorted)):
        out[i] = np.unique(row[row != pad_id]).shape[0]
    return out


def spmv_blocked_ref(src_local, dst_local, weights, x_windows):
    """y_win[c, v] = Σ_e [dst_local[c,e]==v] · w[c,e] · x_windows[c, src_local[c,e]]."""
    c, w_e = src_local.shape
    w_v = x_windows.shape[1]
    out = np.zeros((c, w_v), dtype=np.float32)
    src = np.asarray(src_local)
    dst = np.asarray(dst_local)
    w = np.asarray(weights)
    x = np.asarray(x_windows)
    for ci in range(c):
        for e in range(w_e):
            s, d = src[ci, e], dst[ci, e]
            if s < w_v and d < w_v:
                out[ci, d] += w[ci, e] * x[ci, s]
    return out


def attention_ref(q, k, v, *, scale=None, causal=True, window=None, softcap=None):
    """Dense reference attention, (B, H, S, D) f32 math."""
    b, h, s, d = q.shape
    scale = (d**-0.5) if scale is None else scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, cache_len, *, scale=None, softcap=None):
    """q (BH, Gq, D), k/v (BH, S, D), cache_len (BH,) → (BH, Gq, D)."""
    bh, gq, d = q.shape
    s = k.shape[1]
    scale = (d**-0.5) if scale is None else scale
    logits = jnp.einsum("bgd,bsd->bgs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    mask = jnp.arange(s)[None, None, :] < cache_len[:, None, None]
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bgs,bsd->bgd", p, v.astype(jnp.float32))
