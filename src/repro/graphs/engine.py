"""Distributed vertex-cut graph engine (JAX shard_map) — the paper's §6.4
workloads (PageRank / SSSP / WCC) running on CEP edge partitions.

TPU adaptation (DESIGN.md §4): each device owns one edge chunk as a dense
padded (E_max, 2) int32 array; the GAS gather/apply/scatter is a dense
scatter-add into a (V,) accumulator (VPU-friendly), combined across devices
with psum/pmin. Per-iteration *communication volume* is reported with the
paper's own mirror metric (Σ_p |V(E_p)| − |V|), which is what the partition
quality controls on a real sparse-exchange system.

Two layouts (DESIGN.md §6):

* ``EngineData`` — the replicated pack: one (k, E_max, 2) buffer, partition p
  at row p. Fine on one device; the ``data`` mesh axis splits rows.
* ``ShardedEngineData`` — the distributed pack: a (k_pad, E_max, 2) buffer
  carrying a NamedSharding over the ``graph`` mesh axis, rows in device-major
  round-robin order (partition p on device p % g, at row
  launch.sharding.partition_row(p, k, g)). GAS iteration shard_maps directly
  over the sharded rows, and elastic/rescale_exec.py executes ScalePlans on it
  as on-mesh migrations.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..core import cep, metrics
from ..core.graph import Graph
from ..launch import sharding as SH

AXIS = "data"


@dataclasses.dataclass(frozen=True)
class EngineData:
    edges: jnp.ndarray  # (k, E_max, 2) int32 — undirected, both endpoints
    mask: jnp.ndarray  # (k, E_max) f32 1/0 padding mask
    degrees: jnp.ndarray  # (V,) f32
    num_vertices: int
    k: int
    mirrors: int  # Σ_p |V(E_p)| − |V(E)| — the paper's comm-volume metric
    replication_factor: float
    num_edges: int = 0  # total valid (unpadded) edges across partitions


def build_engine_data(g: Graph, part: np.ndarray, k: int) -> EngineData:
    """Pack per-partition edge chunks (padded to a common max) + quality metrics."""
    order = np.argsort(part, kind="stable")
    counts = np.bincount(part, minlength=k)
    e_max = int(counts.max())
    edges = np.zeros((k, e_max, 2), dtype=np.int32)
    mask = np.zeros((k, e_max), dtype=np.float32)
    src, dst = g.src[order], g.dst[order]
    off = 0
    for p in range(k):
        c = int(counts[p])
        edges[p, :c, 0] = src[off : off + c]
        edges[p, :c, 1] = dst[off : off + c]
        mask[p, :c] = 1.0
        off += c
    deg = np.zeros(g.num_vertices, dtype=np.float32)
    np.add.at(deg, g.src, 1.0)
    np.add.at(deg, g.dst, 1.0)
    mir = metrics.mirror_count(g.src, g.dst, part, k, g.num_vertices)
    rf = metrics.replication_factor(g.src, g.dst, part, k, g.num_vertices)
    return EngineData(
        edges=jnp.asarray(edges),
        mask=jnp.asarray(mask),
        degrees=jnp.asarray(deg),
        num_vertices=g.num_vertices,
        k=k,
        mirrors=mir,
        replication_factor=rf,
        num_edges=g.num_edges,
    )


def pack_ordered(
    src_ordered: np.ndarray,
    dst_ordered: np.ndarray,
    num_vertices: int,
    k: int,
    *,
    e_max: int | None = None,
) -> EngineData:
    """Pack CEP chunks of an already-ordered edge list: partition p owns
    ordered edge ids [bounds[p], bounds[p+1]), stored *in list order*.

    This partition-major layout is exactly what elastic/rescale_exec.py's
    range copies preserve, so an executed k_old → k_new migration is
    bit-comparable against a from-scratch pack at k_new.

    ``e_max`` overrides the per-partition row width: passing a value larger
    than the biggest chunk leaves masked slack rows at each partition's tail —
    the headroom the streaming subsystem's on-device ingest scatters new edges
    into (DESIGN.md §9), same masked-rows convention as the k-padding of §6.
    """
    e = int(src_ordered.shape[0])
    b = cep.chunk_bounds(e, k)
    sizes = np.diff(b)
    if e_max is None:
        e_max = int(sizes.max())
    elif e_max < int(sizes.max()):
        raise ValueError(f"e_max={e_max} is below the largest chunk ({int(sizes.max())})")
    edges = np.zeros((k, e_max, 2), dtype=np.int32)
    mask = np.zeros((k, e_max), dtype=np.float32)
    for p in range(k):
        lo, hi = int(b[p]), int(b[p + 1])
        c = hi - lo
        edges[p, :c, 0] = src_ordered[lo:hi]
        edges[p, :c, 1] = dst_ordered[lo:hi]
        mask[p, :c] = 1.0
    deg = np.zeros(num_vertices, dtype=np.float32)
    np.add.at(deg, src_ordered, 1.0)
    np.add.at(deg, dst_ordered, 1.0)
    mir = metrics.mirror_count_ordered(src_ordered, dst_ordered, k, num_vertices)
    rf = metrics.replication_factor_ordered(src_ordered, dst_ordered, k, num_vertices)
    return EngineData(
        edges=jnp.asarray(edges),
        mask=jnp.asarray(mask),
        degrees=jnp.asarray(deg),
        num_vertices=num_vertices,
        k=k,
        mirrors=mir,
        replication_factor=rf,
        num_edges=e,
    )


def unpack_ordered(data: EngineData) -> tuple[np.ndarray, np.ndarray]:
    """Host-side inverse of pack_ordered: the flat ordered (src, dst) lists."""
    edges = np.asarray(data.edges)
    counts = np.asarray(data.mask).astype(bool).sum(axis=1)
    src = np.concatenate([edges[p, : counts[p], 0] for p in range(data.k)])
    dst = np.concatenate([edges[p, : counts[p], 1] for p in range(data.k)])
    return src, dst


def cep_engine_data(g: Graph, order: np.ndarray, k: int) -> EngineData:
    return pack_ordered(g.src[order], g.dst[order], g.num_vertices, k)


# ------------------------------------------------------------ sharded layout
@dataclasses.dataclass(frozen=True)
class ShardedEngineData:
    """EngineData distributed over the ``graph`` axis of a mesh.

    ``edges``/``mask`` are (k_pad, E_max, 2) / (k_pad, E_max) arrays committed
    with a NamedSharding that splits the leading axis over ``graph``; rows are
    in device-major round-robin order (partition p at row
    ``launch.sharding.partition_row(p, k, g)``, hence on device p % g). Rows
    whose partition id ≥ k are padding: all-zero, fully masked. ``degrees`` is
    replicated. A mesh of 1 makes this layout bit-identical to ``EngineData``.
    """

    edges: jnp.ndarray  # (k_pad, E_max, 2) int32, sharded P("graph", ∅, ∅)
    mask: jnp.ndarray  # (k_pad, E_max) f32, sharded P("graph", ∅)
    degrees: jnp.ndarray  # (V,) f32, replicated
    num_vertices: int
    k: int  # logical partition count (rows may exceed it: k_pad = ⌈k/g⌉·g)
    mesh: object  # jax.sharding.Mesh with a "graph" axis
    mirrors: int
    replication_factor: float
    num_edges: int = 0

    @property
    def devices(self) -> int:
        return SH.graph_axis_size(self.mesh)

    @property
    def k_pad(self) -> int:
        return int(self.edges.shape[0])

    @property
    def rows_per_device(self) -> int:
        return self.k_pad // self.devices

    def partition_device(self, p: int) -> int:
        return SH.partition_device(p, self.devices)


def shard_engine_data(data: EngineData, mesh) -> ShardedEngineData:
    """Distribute a packed EngineData over ``mesh``'s ``graph`` axis.

    Works on multi-process meshes too: the host pack must then be replicated
    on every process (graphs are built deterministically from the seed, or
    broadcast by process 0 outside this function) and each process commits
    only the rows its devices own (``launch.multihost.put_global``)."""
    from ..launch import multihost as MH

    g = SH.graph_axis_size(mesh)
    k = data.k
    k_pad = SH.padded_partition_count(k, g)
    e_max = int(data.edges.shape[1])
    edges = np.zeros((k_pad, e_max, 2), dtype=np.int32)
    mask = np.zeros((k_pad, e_max), dtype=np.float32)
    rows = [SH.partition_row(p, k, g) for p in range(k)]
    edges[rows] = np.asarray(data.edges)
    mask[rows] = np.asarray(data.mask)
    s_edges, s_mask, s_vert = SH.engine_shardings(mesh)
    return ShardedEngineData(
        edges=MH.put_global(edges, s_edges),
        mask=MH.put_global(mask, s_mask),
        degrees=MH.put_global(np.asarray(data.degrees), s_vert),
        num_vertices=data.num_vertices,
        k=k,
        mesh=mesh,
        mirrors=data.mirrors,
        replication_factor=data.replication_factor,
        num_edges=data.num_edges,
    )


def unshard_engine_data(sdata: ShardedEngineData) -> EngineData:
    """Host-side inverse of shard_engine_data: gather + un-permute rows back to
    the partition-major replicated pack (the bit-identity oracle layout). On a
    multi-process mesh the gather is a collective (every process must call)."""
    from ..launch import multihost as MH

    rows = [SH.partition_row(p, sdata.k, sdata.devices) for p in range(sdata.k)]
    return EngineData(
        edges=jnp.asarray(MH.host_read(sdata.edges)[rows]),
        mask=jnp.asarray(MH.host_read(sdata.mask)[rows]),
        degrees=jnp.asarray(MH.host_read(sdata.degrees)),
        num_vertices=sdata.num_vertices,
        k=sdata.k,
        mirrors=sdata.mirrors,
        replication_factor=sdata.replication_factor,
        num_edges=sdata.num_edges,
    )


def pack_ordered_sharded(
    src_ordered: np.ndarray,
    dst_ordered: np.ndarray,
    num_vertices: int,
    k: int,
    mesh,
    *,
    e_max: int | None = None,
) -> ShardedEngineData:
    """pack_ordered, distributed: CEP chunks land round-robin on mesh devices."""
    return shard_engine_data(
        pack_ordered(src_ordered, dst_ordered, num_vertices, k, e_max=e_max), mesh
    )


# ------------------------------------------------------------- slot layout
def pack_slots(
    slot_src: np.ndarray,
    slot_dst: np.ndarray,
    slot_valid: np.ndarray,
    k: int,
    num_vertices: int,
) -> EngineData:
    """Pack a streaming slot array (stream/incremental.py) into engine buffers.

    Region p's ``slots_per_region`` slots become partition p's first columns —
    occupied slots keep their column (gaps are masked rows interleaved IN
    PLACE, not compacted, so a host slot maps 1:1 to a device (row, col) and
    an EdgeUpdateBatch applies as a scatter) — plus one trailing always-masked
    scratch column that padded scatter ops target (stream/ingest.py). GAS
    algorithms are mask-driven and run unchanged on this layout; this function
    is also the streaming bit-identity oracle: on-device ingest, unsharded,
    must equal it byte-for-byte.
    """
    slot_valid = np.asarray(slot_valid, dtype=bool)
    c = int(slot_valid.shape[0])
    if c % k:
        raise ValueError(f"slot capacity {c} is not a multiple of k={k}")
    spr = c // k
    e_cap = spr + 1  # + scratch column
    edges = np.zeros((k, e_cap, 2), dtype=np.int32)
    mask = np.zeros((k, e_cap), dtype=np.float32)
    edges[:, :spr, 0] = (np.asarray(slot_src) * slot_valid).reshape(k, spr)
    edges[:, :spr, 1] = (np.asarray(slot_dst) * slot_valid).reshape(k, spr)
    mask[:, :spr] = slot_valid.reshape(k, spr).astype(np.float32)
    deg = np.zeros(num_vertices, dtype=np.float32)
    np.add.at(deg, np.asarray(slot_src)[slot_valid], 1.0)
    np.add.at(deg, np.asarray(slot_dst)[slot_valid], 1.0)
    # Quality metrics are monitored incrementally by the orderer, not carried
    # on the pack (same convention as ElasticRescaler's recheck=False).
    return EngineData(
        edges=jnp.asarray(edges),
        mask=jnp.asarray(mask),
        degrees=jnp.asarray(deg),
        num_vertices=num_vertices,
        k=k,
        mirrors=-1,
        replication_factor=float("nan"),
        num_edges=int(slot_valid.sum()),
    )


def local_slot_partitions(k: int, mesh) -> list[int]:
    """Partition ids whose buffer rows this process's devices own, in row
    order (ids ≥ k are the all-masked padding rows and are omitted). The
    out-of-core commit materializes exactly these partitions' slots and no
    others — the full partition list never exists on one host."""
    from ..launch import multihost as MH

    g = SH.graph_axis_size(mesh)
    k_pad = SH.padded_partition_count(k, g)
    s_edges, _, _ = SH.engine_shardings(mesh)
    lo, hi = MH.addressable_row_block((k_pad, 1, 2), s_edges)
    parts = [SH.row_partition(r, k, g) for r in range(lo, hi)]
    return [p for p in parts if p < k]


def pack_slots_sharded_stream(
    part_fn,
    k: int,
    num_vertices: int,
    mesh,
    slots_per_region: int,
) -> ShardedEngineData:
    """``pack_slots`` committed shard by shard: no full-graph host array.

    ``part_fn(p) -> (slot_src, slot_dst, slot_valid)`` produces ONE
    partition's ``slots_per_region`` slots; it is called only for the
    partitions this process's devices own (``local_slot_partitions``), one
    at a time, into a staging buffer bounded by the local row block — which
    is per-process device memory, the floor for any commit. Degrees and the
    edge count are V-sized accumulators merged by ``psum_host``. Unsharded,
    the result is byte-identical to ``pack_slots`` over the concatenated
    slot arrays — the in-core oracle the out-of-core tests compare against.
    """
    from ..launch import multihost as MH

    g = SH.graph_axis_size(mesh)
    k_pad = SH.padded_partition_count(k, g)
    spr = int(slots_per_region)
    e_cap = spr + 1  # + scratch column, as pack_slots
    s_edges, s_mask, s_vert = SH.engine_shardings(mesh)
    lo, hi = MH.addressable_row_block((k_pad, e_cap, 2), s_edges)
    edges_local = np.zeros((hi - lo, e_cap, 2), dtype=np.int32)
    mask_local = np.zeros((hi - lo, e_cap), dtype=np.float32)
    deg_local = np.zeros(num_vertices, dtype=np.float32)
    count_local = 0
    for r in range(lo, hi):
        p = SH.row_partition(r, k, g)
        if p >= k:
            continue
        slot_src, slot_dst, slot_valid = part_fn(p)
        slot_valid = np.asarray(slot_valid, dtype=bool)
        if slot_valid.shape[0] != spr:
            raise ValueError(
                f"partition {p}: got {slot_valid.shape[0]} slots, expected {spr}"
            )
        edges_local[r - lo, :spr, 0] = np.asarray(slot_src) * slot_valid
        edges_local[r - lo, :spr, 1] = np.asarray(slot_dst) * slot_valid
        mask_local[r - lo, :spr] = slot_valid.astype(np.float32)
        np.add.at(deg_local, np.asarray(slot_src)[slot_valid], 1.0)
        np.add.at(deg_local, np.asarray(slot_dst)[slot_valid], 1.0)
        count_local += int(slot_valid.sum())
    deg = MH.psum_host(deg_local, mesh)
    total = int(MH.psum_host(np.asarray([count_local], dtype=np.int64), mesh)[0])
    return ShardedEngineData(
        edges=MH.put_global_local(edges_local, (k_pad, e_cap, 2), s_edges),
        mask=MH.put_global_local(mask_local, (k_pad, e_cap), s_mask),
        degrees=MH.put_global(deg, s_vert),
        num_vertices=num_vertices,
        k=k,
        mesh=mesh,
        mirrors=-1,
        replication_factor=float("nan"),
        num_edges=total,
    )


def _axis_and_mesh(data, mesh):
    """GAS dispatch: ShardedEngineData iterates over its own ``graph`` mesh;
    the replicated pack keeps the historical ``data``-axis path."""
    if isinstance(data, ShardedEngineData):
        return SH.GRAPH_AXIS, (mesh if mesh is not None else data.mesh)
    if mesh is None:
        raise ValueError("EngineData (replicated pack) requires an explicit mesh")
    return AXIS, mesh


def _sharded(fn, mesh, axis, extra_in=(), extra_out=P()):
    in_specs = (P(axis, None, None), P(axis, None)) + tuple(extra_in)
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=extra_out, check_vma=False)


def pagerank(data, mesh=None, *, iterations: int = 20, damping: float = 0.85):
    axis, mesh = _axis_and_mesh(data, mesh)
    v = data.num_vertices
    deg = jnp.maximum(data.degrees, 1.0)

    def local(edges, mask, x):
        e = edges.reshape(-1, 2)  # all chunks owned by this device
        m = mask.reshape(-1)
        contrib = x / deg
        y = jnp.zeros((v,), jnp.float32)
        # Undirected: each edge pushes both ways (vertex-cut GAS scatter).
        y = y.at[e[:, 1]].add(contrib[e[:, 0]] * m)
        y = y.at[e[:, 0]].add(contrib[e[:, 1]] * m)
        return lax.psum(y, axis)

    step = _sharded(local, mesh, axis, extra_in=(P(),), extra_out=P())
    dangling = data.degrees == 0

    def body(x, _):
        y = step(data.edges, data.mask, x)
        # Dangling vertices spread their mass uniformly (networkx convention).
        dm = jnp.sum(jnp.where(dangling, x, 0.0))
        return (1 - damping) / v + damping * (y + dm / v), None

    x0 = jnp.full((v,), 1.0 / v, jnp.float32)
    with mesh:
        x, _ = jax.jit(lambda x0: lax.scan(body, x0, None, length=iterations))(x0)
    return x


def sssp(data, mesh=None, *, source: int = 0, max_iters: int = 64):
    axis, mesh = _axis_and_mesh(data, mesh)
    v = data.num_vertices
    inf = jnp.float32(1e9)

    def local(edges, mask, dist):
        e = edges.reshape(-1, 2)
        m = mask.reshape(-1) > 0
        cand = jnp.full((v,), inf)
        du = jnp.where(m, dist[e[:, 0]] + 1.0, inf)
        dv = jnp.where(m, dist[e[:, 1]] + 1.0, inf)
        cand = cand.at[e[:, 1]].min(du)
        cand = cand.at[e[:, 0]].min(dv)
        return lax.pmin(cand, axis)

    step = _sharded(local, mesh, axis, extra_in=(P(),), extra_out=P())

    def cond(state):
        _, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        dist, _, it = state
        nd = jnp.minimum(dist, step(data.edges, data.mask, dist))
        return nd, jnp.any(nd < dist), it + 1

    d0 = jnp.full((v,), inf).at[source].set(0.0)
    with mesh:
        dist, _, iters = jax.jit(lambda d: lax.while_loop(cond, body, (d, jnp.bool_(True), 0)))(d0)
    return dist, int(iters)


def wcc(data, mesh=None, *, max_iters: int = 64):
    axis, mesh = _axis_and_mesh(data, mesh)
    v = data.num_vertices

    def local(edges, mask, lab):
        e = edges.reshape(-1, 2)
        m = mask.reshape(-1) > 0
        big = jnp.float32(1e9)
        cand = jnp.full((v,), big)
        lu = jnp.where(m, lab[e[:, 0]], big)
        lv = jnp.where(m, lab[e[:, 1]], big)
        cand = cand.at[e[:, 1]].min(lu)
        cand = cand.at[e[:, 0]].min(lv)
        return lax.pmin(cand, axis)

    step = _sharded(local, mesh, axis, extra_in=(P(),), extra_out=P())

    def cond(state):
        _, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        lab, _, it = state
        nl = jnp.minimum(lab, step(data.edges, data.mask, lab))
        return nl, jnp.any(nl < lab), it + 1

    l0 = jnp.arange(v, dtype=jnp.float32)
    with mesh:
        lab, _, iters = jax.jit(lambda l: lax.while_loop(cond, body, (l, jnp.bool_(True), 0)))(l0)
    return lab, int(iters)


def comm_volume_per_iteration(data: EngineData, bytes_per_value: int = 8) -> int:
    """Paper §6.4 COM metric: each mirror sends + receives one value/iteration."""
    return 2 * data.mirrors * bytes_per_value


# --------------------------------------------------------------------------
# Cached pure-operand query programs (the serving path, launch/serve.py).
#
# The module-level entry points above close over the pack and build a fresh
# ``jax.jit(lambda ...)`` per call — every call is a new callable, so every
# call retraces. Fine for a benchmark that runs PageRank once; fatal for a
# front end answering thousands of queries. ``query_program`` returns a
# callable that takes the pack OPERANDS (edges, mask, degrees[, source])
# explicitly: the jit compiles once per operand shape, so one program serves
# every query against any pack of that layout — including the packs that
# rescale / async full rebuild swap underneath a live StreamingEngine, which
# only retrace when (k_pad, e_cap) actually changes. SSSP's source is a
# traced int32 operand, so querying a new source is a cache hit, not a
# retrace. Programs iterate over the ``graph`` mesh axis (the sharded-pack
# layout both ShardedEngineData and StreamingEngine.data use).


def _pagerank_program(v: int, mesh, axis: str, iterations: int, damping: float):
    def local(edges, mask, contrib):
        e = edges.reshape(-1, 2)
        m = mask.reshape(-1)
        y = jnp.zeros((v,), jnp.float32)
        y = y.at[e[:, 1]].add(contrib[e[:, 0]] * m)
        y = y.at[e[:, 0]].add(contrib[e[:, 1]] * m)
        return lax.psum(y, axis)

    step = _sharded(local, mesh, axis, extra_in=(P(),), extra_out=P())

    def run(edges, mask, degrees):
        deg = jnp.maximum(degrees, 1.0)
        dangling = degrees == 0

        def body(x, _):
            y = step(edges, mask, x / deg)
            dm = jnp.sum(jnp.where(dangling, x, 0.0))
            return (1 - damping) / v + damping * (y + dm / v), None

        x0 = jnp.full((v,), 1.0 / v, jnp.float32)
        x, _ = lax.scan(body, x0, None, length=iterations)
        return x

    jitted = jax.jit(run)

    def call(edges, mask, degrees):
        with mesh:
            return jitted(edges, mask, degrees)

    return call


def _sssp_program(v: int, mesh, axis: str, max_iters: int):
    inf = jnp.float32(1e9)

    def local(edges, mask, dist):
        e = edges.reshape(-1, 2)
        m = mask.reshape(-1) > 0
        cand = jnp.full((v,), inf)
        du = jnp.where(m, dist[e[:, 0]] + 1.0, inf)
        dv = jnp.where(m, dist[e[:, 1]] + 1.0, inf)
        cand = cand.at[e[:, 1]].min(du)
        cand = cand.at[e[:, 0]].min(dv)
        return lax.pmin(cand, axis)

    step = _sharded(local, mesh, axis, extra_in=(P(),), extra_out=P())

    def cond(state):
        _, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body_fn(edges, mask):
        def body(state):
            dist, _, it = state
            nd = jnp.minimum(dist, step(edges, mask, dist))
            return nd, jnp.any(nd < dist), it + 1

        return body

    def run(edges, mask, source):
        d0 = jnp.full((v,), inf).at[source].set(0.0)
        return lax.while_loop(cond, body_fn(edges, mask), (d0, jnp.bool_(True), 0))

    jitted = jax.jit(run)

    def call(edges, mask, source=0):
        with mesh:
            dist, _, iters = jitted(edges, mask, jnp.int32(source))
        return dist, int(iters)

    return call


def _wcc_program(v: int, mesh, axis: str, max_iters: int):
    def local(edges, mask, lab):
        e = edges.reshape(-1, 2)
        m = mask.reshape(-1) > 0
        big = jnp.float32(1e9)
        cand = jnp.full((v,), big)
        lu = jnp.where(m, lab[e[:, 0]], big)
        lv = jnp.where(m, lab[e[:, 1]], big)
        cand = cand.at[e[:, 1]].min(lu)
        cand = cand.at[e[:, 0]].min(lv)
        return lax.pmin(cand, axis)

    step = _sharded(local, mesh, axis, extra_in=(P(),), extra_out=P())

    def cond(state):
        _, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body_fn(edges, mask):
        def body(state):
            lab, _, it = state
            nl = jnp.minimum(lab, step(edges, mask, lab))
            return nl, jnp.any(nl < lab), it + 1

        return body

    def run(edges, mask):
        l0 = jnp.arange(v, dtype=jnp.float32)
        return lax.while_loop(cond, body_fn(edges, mask), (l0, jnp.bool_(True), 0))

    jitted = jax.jit(run)

    def call(edges, mask):
        with mesh:
            lab, _, iters = jitted(edges, mask)
        return lab, int(iters)

    return call


QUERY_KINDS = ("pagerank", "sssp", "wcc")
_QUERY_PROGRAMS: dict = {}


def query_program(
    kind: str,
    *,
    num_vertices: int,
    mesh,
    iterations: int = 20,
    damping: float = 0.85,
    max_iters: int = 64,
):
    """Get-or-build the cached pure-operand program for ``kind``.

    Keyed on (kind, V, mesh, params); the returned callable's jit adds the
    per-shape level, so the full cache hierarchy is program → XLA executable
    per pack layout. Call signatures: pagerank ``(edges, mask, degrees) →
    ranks``; sssp ``(edges, mask, source=0) → (dist, iters)``; wcc
    ``(edges, mask) → (lab, iters)``.
    """
    key = (kind, int(num_vertices), mesh, int(iterations), float(damping), int(max_iters))
    prog = _QUERY_PROGRAMS.get(key)
    if prog is not None:
        return prog
    axis = SH.GRAPH_AXIS
    if kind == "pagerank":
        prog = _pagerank_program(int(num_vertices), mesh, axis, int(iterations), float(damping))
    elif kind == "sssp":
        prog = _sssp_program(int(num_vertices), mesh, axis, int(max_iters))
    elif kind == "wcc":
        prog = _wcc_program(int(num_vertices), mesh, axis, int(max_iters))
    else:
        raise ValueError(f"unknown query kind {kind!r} (expected one of {QUERY_KINDS})")
    _QUERY_PROGRAMS[key] = prog
    return prog
