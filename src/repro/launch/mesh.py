"""Production mesh construction (single-pod 16×16, multi-pod 2×16×16).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax

from ..compat import mesh_axis_sizes as _mesh_axis_sizes

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (host) devices exist — for smoke tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_graph_mesh(devices: int | None = None):
    """1-D mesh with the ``graph`` axis that owns graph partitions.

    ``devices=None`` spans every visible device — and ``jax.devices()`` is the
    GLOBAL list: in a ``jax.distributed`` process group
    (launch/multihost.py initialize_distributed) the same call on every
    process yields one mesh over all processes' devices, in process-major
    order, so graph-axis position d belongs to process
    ``jax.devices()[d].process_index``. A single-device (and single-process)
    mesh is the degenerate case the elastic runtime treats identically
    (DESIGN.md §6, §10). Partitions are assigned round-robin to axis
    positions — see launch/sharding.py partition_row / partition_device.
    """
    n = len(jax.devices()) if devices is None else int(devices)
    return jax.make_mesh((n,), ("graph",))


def mesh_axis_sizes(mesh) -> dict:
    return _mesh_axis_sizes(mesh)


def num_chips(mesh) -> int:
    return int(mesh.devices.size)
