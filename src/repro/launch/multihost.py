"""Multi-host ``graph`` mesh: jax.distributed process groups + local test clusters.

The single-process runtime (DESIGN.md §6) already routes every ingest scatter
and rescale migration through NamedShardings over the ``graph`` mesh axis, so
going multi-host "just" changes the mesh: ``make_graph_mesh`` spans
``jax.devices()``, which after ``initialize_distributed`` is the *global*
device list of every process in the group. This module owns everything that
becomes process-aware at that point (DESIGN.md §10):

* **Process bootstrap.** ``initialize_distributed`` / ``initialize_from_env``
  wrap ``jax.distributed.initialize`` through ``repro.compat`` (the CPU
  collectives knob and the initialize surface are the version-sensitive
  parts). Environment variables (``REPRO_MH_*``) carry the cluster spec so a
  worker script needs zero argument plumbing.
* **Global-array construction.** ``put_global`` builds a mesh-committed array
  from host data that every process holds replicas of (graphs are loaded /
  generated deterministically from the seed in each process), handing each
  process exactly its addressable block via
  ``jax.make_array_from_process_local_data``. A 1-process mesh is the
  degenerate case of the same call — never a separate code path.
* **Host readback.** Arrays sharded over a multi-process mesh are not fully
  addressable; ``host_read`` replicates through a jitted identity (one
  all-gather) so oracle checks can still compare bytes, and
  ``local_shard_rows`` fetches only this process's rows — what the
  multi-process acceptance harness writes out for the parent to reassemble.
* **Localhost clusters for tests/benchmarks.** ``spawn_local_cluster`` starts
  N processes on this machine, each with ``devs_per_proc`` forced host
  devices and a free-port coordinator, and returns per-process logs (printed
  on failure so CI flakes are diagnosable).

What crosses the NIC: partition p lives on graph-axis position p % g
(launch/sharding.py), and positions map to processes via the mesh's device
order — so exactly the ScalePlan move ranges whose source and destination
positions belong to different processes are network traffic. ``RescaleStats``
reports them as ``cross_process_edges/bytes``, computed from the plan overlay
and ``sharding.device_process_map`` (no device readback needed).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import socket
import subprocess
import sys
import threading
import time

import numpy as np

from .. import compat
from ..obs import trace as OT
from . import sharding as SH

__all__ = [
    "ClusterSpec",
    "LeaseBoard",
    "LocalCluster",
    "LocalClusterResult",
    "ProcResult",
    "initialize_distributed",
    "initialize_from_env",
    "force_host_device_flags",
    "free_port",
    "put_global",
    "put_global_local",
    "addressable_row_block",
    "psum_host",
    "host_read",
    "local_shard_rows",
    "launch_local_cluster",
    "spawn_local_cluster",
]

# Environment contract between spawn_local_cluster and worker processes.
ENV_COORD = "REPRO_MH_COORDINATOR"
ENV_NPROCS = "REPRO_MH_NUM_PROCESSES"
ENV_PID = "REPRO_MH_PROCESS_ID"
ENV_DEVS = "REPRO_MH_DEVS_PER_PROC"

_FORCE_FLAG = "--xla_force_host_platform_device_count"


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    coordinator: str  # "host:port" of process 0's coordinator service
    num_processes: int
    process_id: int
    devs_per_proc: int = 1


def force_host_device_flags(n: int, base: str = "") -> str:
    """XLA_FLAGS value forcing ``n`` host devices, built explicitly: any
    existing force-count flag in ``base`` is removed (never patched with
    string substitution — see tests/test_multidevice.py history) and every
    other flag is preserved."""
    kept = [f for f in str(base).split() if not f.startswith(_FORCE_FLAG)]
    return " ".join(kept + [f"{_FORCE_FLAG}={int(n)}"])


def free_port(host: str = "127.0.0.1") -> int:
    """An OS-assigned free TCP port (the usual bind(0) race caveat applies —
    fine for spawning one local coordinator right after)."""
    s = socket.socket()
    try:
        s.bind((host, 0))
        return int(s.getsockname()[1])
    finally:
        s.close()


def initialize_distributed(coordinator: str, num_processes: int, process_id: int) -> None:
    """Join this process to the ``jax.distributed`` group. After this,
    ``jax.devices()`` is the global device list (process-major order) and
    ``make_graph_mesh`` spans it — all version-sensitive surface lives in
    ``repro.compat``. Call before the first jax computation."""
    compat.distributed_initialize(coordinator, num_processes, process_id)


def initialize_from_env(environ=None) -> ClusterSpec | None:
    """Initialize from the ``REPRO_MH_*`` variables ``spawn_local_cluster``
    sets; returns the spec, or None (no-op) outside a spawned cluster — so a
    worker script runs unchanged as a plain single process."""
    env = os.environ if environ is None else environ
    if ENV_COORD not in env:
        return None
    spec = ClusterSpec(
        coordinator=env[ENV_COORD],
        num_processes=int(env[ENV_NPROCS]),
        process_id=int(env[ENV_PID]),
        devs_per_proc=int(env.get(ENV_DEVS, 1)),
    )
    initialize_distributed(spec.coordinator, spec.num_processes, spec.process_id)
    return spec


# ---------------------------------------------------------------- liveness
class LeaseBoard:
    """File-based liveness leases for a process group (DESIGN.md §15).

    Worker process ``i`` stamps ``lease_p{i}.json`` with its batch step and
    the lease clock after every unit of progress; anyone holding the shared
    directory (the drill parent, a sibling process) classifies the group
    without any collective — which is the point: a process that died inside
    a gloo collective strands its peers, so detection must not itself ride
    on the collective plane. Stamps are written via tmp+rename, so a reader
    never sees a torn lease; a SIGKILL mid-stamp leaves the previous stamp.

    The clock follows the runtime's injected-clock convention
    (``ElasticController(clock=...)``): default ``time.monotonic``, which is
    CLOCK_MONOTONIC on Linux — one system-wide timeline every local process
    shares, so cross-process lease ages are directly comparable. Tests
    inject a fake clock and drive expiry deterministically.

    A process that never stamped is aged from the board's construction time
    (a worker that died before its first stamp must still expire).
    """

    def __init__(self, directory, *, lease_s: float = 2.0, clock=time.monotonic):
        self.dir = os.fspath(directory)
        os.makedirs(self.dir, exist_ok=True)
        self.lease_s = float(lease_s)
        self.clock = clock
        self._t0 = clock()

    def _path(self, process_id: int) -> str:
        return os.path.join(self.dir, f"lease_p{int(process_id)}.json")

    def stamp(self, process_id: int, step: int) -> None:
        """Renew process ``process_id``'s lease at progress ``step``."""
        import json

        path = self._path(process_id)
        tmp = f"{path}.tmp{int(process_id)}"
        with open(tmp, "w") as f:
            f.write(json.dumps({"step": int(step), "t": float(self.clock())}))
        os.replace(tmp, path)  # atomic: readers see whole stamps or nothing

    def read(self, process_id: int) -> dict | None:
        """The last stamp of ``process_id`` — {"step", "t"} — or None."""
        import json

        try:
            with open(self._path(process_id)) as f:
                return json.loads(f.read())
        except (OSError, ValueError):
            return None

    def age(self, process_id: int, *, now: float | None = None) -> float:
        """Seconds since the last stamp (since board construction when the
        process never stamped)."""
        now = self.clock() if now is None else now
        stamp = self.read(process_id)
        return now - (self._t0 if stamp is None else stamp["t"])

    def step(self, process_id: int) -> int:
        """Last stamped progress step (-1 before the first stamp)."""
        stamp = self.read(process_id)
        return -1 if stamp is None else int(stamp["step"])

    def dead(self, num_processes: int, *, now: float | None = None) -> list[int]:
        """Process ids whose lease age exceeds ``lease_s`` — the failure
        detector's verdict at ``now``. A frozen stamp (the victim's last
        write before SIGKILL) ages past the lease like silence does."""
        now = self.clock() if now is None else now
        return [
            pid for pid in range(int(num_processes))
            if self.age(pid, now=now) > self.lease_s
        ]

    def survivors(self, num_processes: int, *, now: float | None = None) -> list[int]:
        gone = set(self.dead(num_processes, now=now))
        return [pid for pid in range(int(num_processes)) if pid not in gone]

    def surviving_devices(
        self, num_processes: int, devs_per_proc: int, *, now: float | None = None
    ) -> list[int]:
        """Global device indices still backed by a live process. Global
        devices are process-major after ``initialize_distributed`` (process
        i owns [i·d, (i+1)·d)), so the surviving list is exactly what a
        recovery mesh re-plans k over."""
        d = int(devs_per_proc)
        return [
            dev
            for pid in self.survivors(num_processes, now=now)
            for dev in range(pid * d, (pid + 1) * d)
        ]

    def wait_for_step(
        self, process_id: int, step: int, *, timeout: float = 60.0, poll_s: float = 0.01
    ) -> int:
        """Block (real time) until ``process_id``'s lease reaches ``step``.
        The drill parent uses this to align its SIGKILL with a chosen batch
        index. Returns the observed step; raises TimeoutError."""
        deadline = time.monotonic() + timeout
        while True:
            s = self.step(process_id)
            if s >= int(step):
                return s
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"process {process_id} never reached step {step} "
                    f"(last stamped {s}) within {timeout}s"
                )
            time.sleep(poll_s)


# ------------------------------------------------------------- global arrays
def put_global(host_arr, sharding):
    """Commit a host array (replicated on every process) to ``sharding``.

    Each process contributes exactly the rows its devices own
    (``jax.make_array_from_process_local_data``); with one process the local
    block is the whole array — the degenerate case of the same path. Falls
    back to ``device_put`` when the sharding has no multi-process structure
    helper available (plain single-process jax)."""
    import jax

    with OT.span("transfer.put_global"):
        host_arr = np.asarray(host_arr)
        if compat.process_count() == 1:
            return jax.device_put(host_arr, sharding)
        lo, hi = addressable_row_block(host_arr.shape, sharding)
        return compat.array_from_process_local_data(
            sharding, host_arr[lo:hi], host_arr.shape
        )


def put_global_local(local_block, global_shape, sharding):
    """Commit to ``sharding`` from ONLY this process's row block.

    The out-of-core counterpart of ``put_global``: the caller materializes
    just the rows this process's devices own (``addressable_row_block``
    says which) instead of replicating the full host array — the whole
    point of shard-streamed packing is that no process ever stages a
    global-shape buffer. Single-process shardings take the direct
    device_put path (the local block IS the array)."""
    import jax

    with OT.span("transfer.put_global"):
        local_block = np.asarray(local_block)
        lo, hi = addressable_row_block(global_shape, sharding)
        if local_block.shape[0] != hi - lo or local_block.shape[1:] != tuple(global_shape[1:]):
            raise ValueError(
                f"local block shape {local_block.shape} does not cover rows "
                f"[{lo}, {hi}) of global shape {tuple(global_shape)}"
            )
        if compat.process_count() == 1:
            return jax.device_put(local_block, sharding)
        return compat.array_from_process_local_data(sharding, local_block, tuple(global_shape))


def psum_host(local, mesh) -> np.ndarray:
    """Sum a host array over all processes of ``mesh`` (collective).

    How the out-of-core pipeline merges V-sized accumulators — the chunk
    load histogram, degree vectors, edge counts — that each process builds
    from its own shards: the local value is staged as this process's row of
    a (num_processes, …) device array sharded over ``graph`` and summed
    after one all-gather. Single-process meshes return the input unchanged."""
    with OT.span("transfer.psum_host"):
        local = np.asarray(local)
        n_procs = compat.process_count()
        if n_procs == 1:
            return local.copy()
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        g = SH.graph_axis_size(mesh)
        devs_per_proc = g // n_procs
        # One row per DEVICE (the graph axis shards by device): this process
        # contributes its value on its first device's row, zeros elsewhere.
        block = np.zeros((devs_per_proc,) + local.shape, dtype=local.dtype)
        block[0] = local
        sharding = NamedSharding(mesh, P("graph"))
        arr = compat.array_from_process_local_data(sharding, block, (g,) + local.shape)
        return host_read(arr).sum(axis=0)


def addressable_row_block(global_shape, sharding) -> tuple[int, int]:
    """[lo, hi) leading-axis rows this process's devices own under
    ``sharding``. The graph layouts shard only the leading axis (or nothing),
    so the addressable region is one contiguous row block; asserted here
    rather than assumed — O(devices) interval merging, never O(rows)."""
    spans = []
    for _, idx in sharding.addressable_devices_indices_map(tuple(global_shape)).items():
        sl = idx[0] if idx else slice(None)
        lo = 0 if sl.start is None else int(sl.start)
        hi = global_shape[0] if sl.stop is None else int(sl.stop)
        spans.append((lo, hi))
    spans.sort()
    lo, hi = spans[0]
    for s_lo, s_hi in spans[1:]:
        if s_lo > hi:  # gap between this device's rows and the block so far
            raise ValueError("addressable rows are not contiguous; not a graph-axis layout")
        hi = max(hi, s_hi)
    return lo, hi


@functools.lru_cache(maxsize=8)
def _replicate_fn(mesh):
    """One jitted identity-to-replicated program per mesh (jit caches per
    input shape internally) — host_read must not retrace on every readback."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    return jax.jit(lambda x: x, out_shardings=NamedSharding(mesh, P()))


def host_read(arr) -> np.ndarray:
    """Fetch a (possibly multi-process) committed array to host memory.

    Fully-addressable arrays read directly. Arrays spanning other processes
    are first replicated by a jitted identity with a replicated out_sharding —
    one all-gather over the interconnect; every process gets the full value
    (collective: all processes in the group must call this together)."""
    import jax

    if not isinstance(arr, jax.Array) or arr.is_fully_addressable:
        return np.asarray(arr)
    with OT.span("transfer.host_read"):
        out = _replicate_fn(arr.sharding.mesh)(arr)
        jax.block_until_ready(out)
        return np.asarray(out)


def local_shard_rows(arr) -> list[tuple[int, int, np.ndarray]]:
    """This process's addressable shards of a leading-axis-sharded array, as
    (row_lo, row_hi, data) blocks — what the acceptance harness persists so
    the parent can reassemble the global buffer without any collective."""
    blocks = []
    for s in arr.addressable_shards:
        sl = s.index[0] if s.index else slice(None)
        lo = 0 if sl.start is None else int(sl.start)
        hi = arr.shape[0] if sl.stop is None else int(sl.stop)
        blocks.append((lo, hi, np.asarray(s.data)))
    # Replicated arrays: every device holds full rows; dedup identical blocks.
    uniq: dict[tuple[int, int], np.ndarray] = {}
    for lo, hi, data in blocks:
        if (lo, hi) in uniq:
            if not np.array_equal(uniq[(lo, hi)], data):
                raise AssertionError(f"divergent replicas for rows [{lo}, {hi})")
        else:
            uniq[(lo, hi)] = data
    return sorted((lo, hi, d) for (lo, hi), d in uniq.items())


# --------------------------------------------------------- localhost clusters
@dataclasses.dataclass(frozen=True)
class ProcResult:
    process_id: int
    returncode: int
    stdout: str
    stderr: str


@dataclasses.dataclass(frozen=True)
class LocalClusterResult:
    spec_coordinator: str
    procs: tuple[ProcResult, ...]

    @property
    def ok(self) -> bool:
        return all(p.returncode == 0 for p in self.procs)

    def format_logs(self, tail: int = 4000) -> str:
        """Per-process logs, for test/CI failure diagnosis."""
        out = []
        for p in self.procs:
            out.append(f"--- process {p.process_id} (rc={p.returncode}) ---")
            if p.stdout:
                out.append(f"[stdout]\n{p.stdout[-tail:]}")
            if p.stderr:
                out.append(f"[stderr]\n{p.stderr[-tail:]}")
        return "\n".join(out)


class LocalCluster:
    """A RUNNING localhost cluster: the handle ``launch_local_cluster``
    returns. ``spawn_local_cluster`` is the blocking wrapper (launch +
    ``wait``); the fault drill holds the handle instead, so it can SIGKILL a
    chosen process mid-stream (``kill``) and still collect every process's
    partial log afterwards. Whatever happens — clean exits, a timeout, an
    injected kill, an exception in the caller — ``wait`` reaps every child
    (kill + OS ``wait()``): no zombies holding the coordinator port."""

    def __init__(self, coord: str, procs: list, captured: dict, threads: list):
        self.coordinator = coord
        self._procs = procs
        self._captured = captured
        self._threads = threads
        self._notes: dict[int, list] = {pid: [] for pid in range(len(procs))}

    @property
    def n_procs(self) -> int:
        return len(self._procs)

    def poll(self, pid: int):
        """Exit code of process ``pid``, or None while it runs."""
        return self._procs[pid].poll()

    def kill(self, pid: int, *, reason: str = "fault injection") -> None:
        """SIGKILL process ``pid`` and reap it immediately. The hard-kill is
        deliberate — a preempted instance gets no chance to flush, close, or
        say goodbye, and the drill must model exactly that. The victim's
        partial log stays captured (drained line-wise with the ``[p{pid}]``
        prefix as it was emitted) and gets an attributable kill note."""
        p = self._procs[pid]
        if p.poll() is None:
            p.kill()
            p.wait()
        self._notes[pid].append(
            f"[p{pid}] [local_cluster] SIGKILL injected mid-run ({reason})\n"
        )

    def wait(self, timeout: float = 600.0) -> LocalClusterResult:
        """Block until every process exits (killing the whole group at the
        deadline), reap everything, and return all logs."""
        deadline = time.monotonic() + timeout
        timed_out = []
        try:
            for pid, p in enumerate(self._procs):
                try:
                    p.wait(timeout=max(0.0, deadline - time.monotonic()))
                except subprocess.TimeoutExpired:
                    p.kill()
                    timed_out.append(pid)
        finally:
            for q in self._procs:
                if q.poll() is None:
                    q.kill()
            for q in self._procs:
                try:
                    q.wait(timeout=30.0)  # REAP: a killed child must not linger
                except subprocess.TimeoutExpired:  # pragma: no cover — SIGKILL
                    pass  # cannot be refused; defensive only
        for t in self._threads:  # readers end at EOF once every child exited
            t.join(30.0)
        results = []
        for pid, p in enumerate(self._procs):
            err = "".join(self._captured[(pid, 1)]) + "".join(self._notes[pid])
            if pid in timed_out:
                err += f"\n[p{pid}] [spawn_local_cluster] killed after {timeout}s timeout"
            rc = p.returncode if p.returncode is not None else -1
            results.append(ProcResult(pid, rc, "".join(self._captured[(pid, 0)]), err))
        return LocalClusterResult(self.coordinator, tuple(results))


def launch_local_cluster(
    n_procs: int,
    devs_per_proc: int,
    argv: list[str],
    *,
    env_extra: dict | None = None,
    cwd: str | None = None,
) -> LocalCluster:
    """Start ``python <argv>`` as an ``n_procs``-process localhost cluster
    and return the RUNNING handle (see ``LocalCluster``); the caller must
    ``wait()`` it. ``spawn_local_cluster`` wraps this for the common
    launch-and-block case."""
    if n_procs < 1:
        raise ValueError("n_procs must be >= 1")
    coord = f"127.0.0.1:{free_port()}"
    procs = []
    for pid in range(n_procs):
        env = dict(os.environ)
        env["XLA_FLAGS"] = force_host_device_flags(devs_per_proc, env.get("XLA_FLAGS", ""))
        env[ENV_COORD] = coord
        env[ENV_NPROCS] = str(n_procs)
        env[ENV_PID] = str(pid)
        env[ENV_DEVS] = str(devs_per_proc)
        if env_extra:
            env.update({k: str(v) for k, v in env_extra.items()})
        procs.append(
            subprocess.Popen(
                [sys.executable] + list(argv),
                stdout=subprocess.PIPE,
                stderr=subprocess.PIPE,
                text=True,
                env=env,
                cwd=cwd,
            )
        )
    # Drain every child's pipes CONCURRENTLY and LINE-WISE: the processes
    # form one collective group, so a single child blocked writing to a full
    # pipe (verbose backend logging, a long traceback) would stall every
    # other child at its next collective. Reading line-by-line (one thread
    # per pipe) lets each line be tagged with its process index AT EMIT TIME
    # — so interleaved multi-process logs stay attributable even when a test
    # prints them mid-run, instead of only in the per-process failure dump.
    # This also means a SIGKILLed process's PARTIAL log is already captured
    # the moment it dies — the drill's post-mortem needs no cooperation.
    captured: dict[tuple, list] = {(pid, s): [] for pid in range(n_procs) for s in (0, 1)}

    def drain(pid: int, stream, which: int) -> None:
        prefix = f"[p{pid}] "
        sink = captured[(pid, which)]
        for line in iter(stream.readline, ""):
            sink.append(prefix + line)
        stream.close()

    threads = [
        threading.Thread(target=drain, args=(pid, s, which), daemon=True)
        for pid, p in enumerate(procs)
        for which, s in ((0, p.stdout), (1, p.stderr))
    ]
    for t in threads:
        t.start()
    return LocalCluster(coord, procs, captured, threads)


def spawn_local_cluster(
    n_procs: int,
    devs_per_proc: int,
    argv: list[str],
    *,
    timeout: float = 600.0,
    env_extra: dict | None = None,
    cwd: str | None = None,
) -> LocalClusterResult:
    """Run ``python <argv>`` as an ``n_procs``-process localhost cluster.

    Each process gets ``devs_per_proc`` forced host devices (XLA_FLAGS built
    explicitly, preserving unrelated flags) and the ``REPRO_MH_*`` variables
    pointing at a free-port coordinator on process 0 — the worker calls
    ``initialize_from_env()`` and sees an ``n_procs · devs_per_proc``-device
    global platform. Blocks until every process exits (or kills the whole
    group on timeout), REAPS every child, and returns all logs; the caller
    decides what a failure means (tests print ``format_logs()``). Every
    captured log line is prefixed ``[p{pid}] `` at emit time, so interleaved
    cluster output stays attributable; marker scanners must search within
    lines, not at line starts (benchmarks.common.parse_peak_rss does).
    Fault drills that must kill a member mid-run hold the
    ``launch_local_cluster`` handle instead."""
    return launch_local_cluster(
        n_procs, devs_per_proc, argv, env_extra=env_extra, cwd=cwd
    ).wait(timeout)
