"""Multi-host ``graph`` mesh: jax.distributed process groups + local test clusters.

The single-process runtime (DESIGN.md §6) already routes every ingest scatter
and rescale migration through NamedShardings over the ``graph`` mesh axis, so
going multi-host "just" changes the mesh: ``make_graph_mesh`` spans
``jax.devices()``, which after ``initialize_distributed`` is the *global*
device list of every process in the group. This module owns everything that
becomes process-aware at that point (DESIGN.md §10):

* **Process bootstrap.** ``initialize_distributed`` / ``initialize_from_env``
  wrap ``jax.distributed.initialize`` through ``repro.compat`` (the CPU
  collectives knob and the initialize surface are the version-sensitive
  parts). Environment variables (``REPRO_MH_*``) carry the cluster spec so a
  worker script needs zero argument plumbing.
* **Global-array construction.** ``put_global`` builds a mesh-committed array
  from host data that every process holds replicas of (graphs are loaded /
  generated deterministically from the seed in each process), handing each
  process exactly its addressable block via
  ``jax.make_array_from_process_local_data``. A 1-process mesh is the
  degenerate case of the same call — never a separate code path.
* **Host readback.** Arrays sharded over a multi-process mesh are not fully
  addressable; ``host_read`` replicates through a jitted identity (one
  all-gather) so oracle checks can still compare bytes, and
  ``local_shard_rows`` fetches only this process's rows — what the
  multi-process acceptance harness writes out for the parent to reassemble.
* **Localhost clusters for tests/benchmarks.** ``spawn_local_cluster`` starts
  N processes on this machine, each with ``devs_per_proc`` forced host
  devices and a free-port coordinator, and returns per-process logs (printed
  on failure so CI flakes are diagnosable).

What crosses the NIC: partition p lives on graph-axis position p % g
(launch/sharding.py), and positions map to processes via the mesh's device
order — so exactly the ScalePlan move ranges whose source and destination
positions belong to different processes are network traffic. ``RescaleStats``
reports them as ``cross_process_edges/bytes``, computed from the plan overlay
and ``sharding.device_process_map`` (no device readback needed).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import socket
import subprocess
import sys
import threading
import time

import numpy as np

from .. import compat
from ..obs import trace as OT
from . import sharding as SH

__all__ = [
    "ClusterSpec",
    "LocalClusterResult",
    "ProcResult",
    "initialize_distributed",
    "initialize_from_env",
    "force_host_device_flags",
    "free_port",
    "put_global",
    "put_global_local",
    "addressable_row_block",
    "psum_host",
    "host_read",
    "local_shard_rows",
    "spawn_local_cluster",
]

# Environment contract between spawn_local_cluster and worker processes.
ENV_COORD = "REPRO_MH_COORDINATOR"
ENV_NPROCS = "REPRO_MH_NUM_PROCESSES"
ENV_PID = "REPRO_MH_PROCESS_ID"
ENV_DEVS = "REPRO_MH_DEVS_PER_PROC"

_FORCE_FLAG = "--xla_force_host_platform_device_count"


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    coordinator: str  # "host:port" of process 0's coordinator service
    num_processes: int
    process_id: int
    devs_per_proc: int = 1


def force_host_device_flags(n: int, base: str = "") -> str:
    """XLA_FLAGS value forcing ``n`` host devices, built explicitly: any
    existing force-count flag in ``base`` is removed (never patched with
    string substitution — see tests/test_multidevice.py history) and every
    other flag is preserved."""
    kept = [f for f in str(base).split() if not f.startswith(_FORCE_FLAG)]
    return " ".join(kept + [f"{_FORCE_FLAG}={int(n)}"])


def free_port(host: str = "127.0.0.1") -> int:
    """An OS-assigned free TCP port (the usual bind(0) race caveat applies —
    fine for spawning one local coordinator right after)."""
    s = socket.socket()
    try:
        s.bind((host, 0))
        return int(s.getsockname()[1])
    finally:
        s.close()


def initialize_distributed(coordinator: str, num_processes: int, process_id: int) -> None:
    """Join this process to the ``jax.distributed`` group. After this,
    ``jax.devices()`` is the global device list (process-major order) and
    ``make_graph_mesh`` spans it — all version-sensitive surface lives in
    ``repro.compat``. Call before the first jax computation."""
    compat.distributed_initialize(coordinator, num_processes, process_id)


def initialize_from_env(environ=None) -> ClusterSpec | None:
    """Initialize from the ``REPRO_MH_*`` variables ``spawn_local_cluster``
    sets; returns the spec, or None (no-op) outside a spawned cluster — so a
    worker script runs unchanged as a plain single process."""
    env = os.environ if environ is None else environ
    if ENV_COORD not in env:
        return None
    spec = ClusterSpec(
        coordinator=env[ENV_COORD],
        num_processes=int(env[ENV_NPROCS]),
        process_id=int(env[ENV_PID]),
        devs_per_proc=int(env.get(ENV_DEVS, 1)),
    )
    initialize_distributed(spec.coordinator, spec.num_processes, spec.process_id)
    return spec


# ------------------------------------------------------------- global arrays
def put_global(host_arr, sharding):
    """Commit a host array (replicated on every process) to ``sharding``.

    Each process contributes exactly the rows its devices own
    (``jax.make_array_from_process_local_data``); with one process the local
    block is the whole array — the degenerate case of the same path. Falls
    back to ``device_put`` when the sharding has no multi-process structure
    helper available (plain single-process jax)."""
    import jax

    with OT.span("transfer.put_global"):
        host_arr = np.asarray(host_arr)
        if compat.process_count() == 1:
            return jax.device_put(host_arr, sharding)
        lo, hi = addressable_row_block(host_arr.shape, sharding)
        return compat.array_from_process_local_data(
            sharding, host_arr[lo:hi], host_arr.shape
        )


def put_global_local(local_block, global_shape, sharding):
    """Commit to ``sharding`` from ONLY this process's row block.

    The out-of-core counterpart of ``put_global``: the caller materializes
    just the rows this process's devices own (``addressable_row_block``
    says which) instead of replicating the full host array — the whole
    point of shard-streamed packing is that no process ever stages a
    global-shape buffer. Single-process shardings take the direct
    device_put path (the local block IS the array)."""
    import jax

    with OT.span("transfer.put_global"):
        local_block = np.asarray(local_block)
        lo, hi = addressable_row_block(global_shape, sharding)
        if local_block.shape[0] != hi - lo or local_block.shape[1:] != tuple(global_shape[1:]):
            raise ValueError(
                f"local block shape {local_block.shape} does not cover rows "
                f"[{lo}, {hi}) of global shape {tuple(global_shape)}"
            )
        if compat.process_count() == 1:
            return jax.device_put(local_block, sharding)
        return compat.array_from_process_local_data(sharding, local_block, tuple(global_shape))


def psum_host(local, mesh) -> np.ndarray:
    """Sum a host array over all processes of ``mesh`` (collective).

    How the out-of-core pipeline merges V-sized accumulators — the chunk
    load histogram, degree vectors, edge counts — that each process builds
    from its own shards: the local value is staged as this process's row of
    a (num_processes, …) device array sharded over ``graph`` and summed
    after one all-gather. Single-process meshes return the input unchanged."""
    with OT.span("transfer.psum_host"):
        local = np.asarray(local)
        n_procs = compat.process_count()
        if n_procs == 1:
            return local.copy()
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        g = SH.graph_axis_size(mesh)
        devs_per_proc = g // n_procs
        # One row per DEVICE (the graph axis shards by device): this process
        # contributes its value on its first device's row, zeros elsewhere.
        block = np.zeros((devs_per_proc,) + local.shape, dtype=local.dtype)
        block[0] = local
        sharding = NamedSharding(mesh, P("graph"))
        arr = compat.array_from_process_local_data(sharding, block, (g,) + local.shape)
        return host_read(arr).sum(axis=0)


def addressable_row_block(global_shape, sharding) -> tuple[int, int]:
    """[lo, hi) leading-axis rows this process's devices own under
    ``sharding``. The graph layouts shard only the leading axis (or nothing),
    so the addressable region is one contiguous row block; asserted here
    rather than assumed — O(devices) interval merging, never O(rows)."""
    spans = []
    for _, idx in sharding.addressable_devices_indices_map(tuple(global_shape)).items():
        sl = idx[0] if idx else slice(None)
        lo = 0 if sl.start is None else int(sl.start)
        hi = global_shape[0] if sl.stop is None else int(sl.stop)
        spans.append((lo, hi))
    spans.sort()
    lo, hi = spans[0]
    for s_lo, s_hi in spans[1:]:
        if s_lo > hi:  # gap between this device's rows and the block so far
            raise ValueError("addressable rows are not contiguous; not a graph-axis layout")
        hi = max(hi, s_hi)
    return lo, hi


@functools.lru_cache(maxsize=8)
def _replicate_fn(mesh):
    """One jitted identity-to-replicated program per mesh (jit caches per
    input shape internally) — host_read must not retrace on every readback."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    return jax.jit(lambda x: x, out_shardings=NamedSharding(mesh, P()))


def host_read(arr) -> np.ndarray:
    """Fetch a (possibly multi-process) committed array to host memory.

    Fully-addressable arrays read directly. Arrays spanning other processes
    are first replicated by a jitted identity with a replicated out_sharding —
    one all-gather over the interconnect; every process gets the full value
    (collective: all processes in the group must call this together)."""
    import jax

    if not isinstance(arr, jax.Array) or arr.is_fully_addressable:
        return np.asarray(arr)
    with OT.span("transfer.host_read"):
        out = _replicate_fn(arr.sharding.mesh)(arr)
        jax.block_until_ready(out)
        return np.asarray(out)


def local_shard_rows(arr) -> list[tuple[int, int, np.ndarray]]:
    """This process's addressable shards of a leading-axis-sharded array, as
    (row_lo, row_hi, data) blocks — what the acceptance harness persists so
    the parent can reassemble the global buffer without any collective."""
    blocks = []
    for s in arr.addressable_shards:
        sl = s.index[0] if s.index else slice(None)
        lo = 0 if sl.start is None else int(sl.start)
        hi = arr.shape[0] if sl.stop is None else int(sl.stop)
        blocks.append((lo, hi, np.asarray(s.data)))
    # Replicated arrays: every device holds full rows; dedup identical blocks.
    uniq: dict[tuple[int, int], np.ndarray] = {}
    for lo, hi, data in blocks:
        if (lo, hi) in uniq:
            if not np.array_equal(uniq[(lo, hi)], data):
                raise AssertionError(f"divergent replicas for rows [{lo}, {hi})")
        else:
            uniq[(lo, hi)] = data
    return sorted((lo, hi, d) for (lo, hi), d in uniq.items())


# --------------------------------------------------------- localhost clusters
@dataclasses.dataclass(frozen=True)
class ProcResult:
    process_id: int
    returncode: int
    stdout: str
    stderr: str


@dataclasses.dataclass(frozen=True)
class LocalClusterResult:
    spec_coordinator: str
    procs: tuple[ProcResult, ...]

    @property
    def ok(self) -> bool:
        return all(p.returncode == 0 for p in self.procs)

    def format_logs(self, tail: int = 4000) -> str:
        """Per-process logs, for test/CI failure diagnosis."""
        out = []
        for p in self.procs:
            out.append(f"--- process {p.process_id} (rc={p.returncode}) ---")
            if p.stdout:
                out.append(f"[stdout]\n{p.stdout[-tail:]}")
            if p.stderr:
                out.append(f"[stderr]\n{p.stderr[-tail:]}")
        return "\n".join(out)


def spawn_local_cluster(
    n_procs: int,
    devs_per_proc: int,
    argv: list[str],
    *,
    timeout: float = 600.0,
    env_extra: dict | None = None,
    cwd: str | None = None,
) -> LocalClusterResult:
    """Run ``python <argv>`` as an ``n_procs``-process localhost cluster.

    Each process gets ``devs_per_proc`` forced host devices (XLA_FLAGS built
    explicitly, preserving unrelated flags) and the ``REPRO_MH_*`` variables
    pointing at a free-port coordinator on process 0 — the worker calls
    ``initialize_from_env()`` and sees an ``n_procs · devs_per_proc``-device
    global platform. Blocks until every process exits (or kills the whole
    group on timeout) and returns all logs; the caller decides what a failure
    means (tests print ``format_logs()``). Every captured log line is
    prefixed ``[p{pid}] `` at emit time, so interleaved cluster output stays
    attributable; marker scanners must search within lines, not at line
    starts (benchmarks.common.parse_peak_rss does)."""
    if n_procs < 1:
        raise ValueError("n_procs must be >= 1")
    coord = f"127.0.0.1:{free_port()}"
    procs = []
    for pid in range(n_procs):
        env = dict(os.environ)
        env["XLA_FLAGS"] = force_host_device_flags(devs_per_proc, env.get("XLA_FLAGS", ""))
        env[ENV_COORD] = coord
        env[ENV_NPROCS] = str(n_procs)
        env[ENV_PID] = str(pid)
        env[ENV_DEVS] = str(devs_per_proc)
        if env_extra:
            env.update({k: str(v) for k, v in env_extra.items()})
        procs.append(
            subprocess.Popen(
                [sys.executable] + list(argv),
                stdout=subprocess.PIPE,
                stderr=subprocess.PIPE,
                text=True,
                env=env,
                cwd=cwd,
            )
        )
    # Drain every child's pipes CONCURRENTLY and LINE-WISE: the processes
    # form one collective group, so a single child blocked writing to a full
    # pipe (verbose backend logging, a long traceback) would stall every
    # other child at its next collective. Reading line-by-line (one thread
    # per pipe) lets each line be tagged with its process index AT EMIT TIME
    # — so interleaved multi-process logs stay attributable even when a test
    # prints them mid-run, instead of only in the per-process failure dump.
    captured: dict[tuple, list] = {(pid, s): [] for pid in range(n_procs) for s in (0, 1)}

    def drain(pid: int, stream, which: int) -> None:
        prefix = f"[p{pid}] "
        sink = captured[(pid, which)]
        for line in iter(stream.readline, ""):
            sink.append(prefix + line)
        stream.close()

    threads = [
        threading.Thread(target=drain, args=(pid, s, which), daemon=True)
        for pid, p in enumerate(procs)
        for which, s in ((0, p.stdout), (1, p.stderr))
    ]
    for t in threads:
        t.start()
    deadline = time.monotonic() + timeout
    timed_out = []
    try:
        for pid, p in enumerate(procs):
            try:
                p.wait(timeout=max(0.0, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                p.kill()
                timed_out.append(pid)
    finally:
        for q in procs:
            if q.poll() is None:
                q.kill()
    for t in threads:  # readers end at EOF once every child has exited
        t.join(30.0)
    results = []
    for pid, p in enumerate(procs):
        err = "".join(captured[(pid, 1)])
        if pid in timed_out:
            err += f"\n[p{pid}] [spawn_local_cluster] killed after {timeout}s timeout"
        rc = p.returncode if p.returncode is not None else -1
        results.append(ProcResult(pid, rc, "".join(captured[(pid, 0)]), err))
    return LocalClusterResult(coord, tuple(results))
