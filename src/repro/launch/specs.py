"""ShapeDtypeStruct input specs for every (arch × shape) cell — the dry-run's
inputs. Nothing here allocates device memory; shardings are attached to the
structs so .lower() sees the full distribution plan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..models import model as M
from ..models.config import SHAPES, ModelConfig, ShapeSpec
from . import sharding as SH

PARAM_DTYPE = jnp.bfloat16
CACHE_DTYPE = jnp.bfloat16

# Micro-batch accumulation per arch for the train_4k cell (keeps per-device
# activations inside v5e HBM; see EXPERIMENTS.md §Dry-run).
TRAIN_MICROBATCHES = {
    "phi-3-vision-4.2b": 4,
    "gemma3-4b": 4,
    "qwen3-8b": 8,
    "qwen2-1.5b": 2,
    "gemma2-9b": 8,
    "whisper-small": 2,
    "mamba2-1.3b": 4,
    "deepseek-moe-16b": 4,
    "granite-moe-3b-a800m": 4,
    "hymba-1.5b": 4,
}


def sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(tuple(shape), dtype, sharding=sharding)


def _with_spec(mesh, shape, dtype, spec):
    return sds(shape, dtype, NamedSharding(mesh, spec))


def param_specs(cfg: ModelConfig, mesh) -> dict:
    shapes = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0), dtype=PARAM_DTYPE))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: sds(
            leaf.shape, leaf.dtype, NamedSharding(mesh, SH.param_spec(mesh, path, leaf.shape))
        ),
        shapes,
    )


def opt_state_specs(cfg: ModelConfig, mesh) -> dict:
    from ..train import optimizer as O

    pshapes = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0), dtype=PARAM_DTYPE))
    oshapes = jax.eval_shape(O.init_opt_state, pshapes)

    def leaf_spec(path, leaf):
        # path[0] is "m"/"v"/"step"
        if str(getattr(path[0], "key", "")) == "step":
            return sds(leaf.shape, leaf.dtype, NamedSharding(mesh, P()))
        sub = path[1:]
        return sds(
            leaf.shape, leaf.dtype, NamedSharding(mesh, SH.opt_state_spec(mesh, sub, leaf.shape))
        )

    return jax.tree_util.tree_map_with_path(leaf_spec, oshapes)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh, *, seq_len=None) -> dict:
    b = shape.global_batch
    s = seq_len if seq_len is not None else shape.seq_len
    bsp = SH.batch_spec(mesh, b)
    bax = list(bsp)[0] if len(list(bsp)) else None
    out = {
        "tokens": _with_spec(mesh, (b, s), jnp.int32, P(bax, None)),
        "targets": _with_spec(mesh, (b, s), jnp.int32, P(bax, None)),
    }
    if cfg.family == "vlm":
        out["patch_embeds"] = _with_spec(
            mesh, (b, cfg.num_patches, cfg.d_model), PARAM_DTYPE, P(bax, None, None)
        )
    if cfg.family == "encdec":
        out["frames"] = _with_spec(
            mesh, (b, cfg.encoder_seq, cfg.d_model), PARAM_DTYPE, P(bax, None, None)
        )
    return out


def cache_specs(cfg: ModelConfig, shape: ShapeSpec, mesh, *, seq_shard: bool) -> dict:
    cshapes = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len, dtype=CACHE_DTYPE)
    )

    def leaf_spec(path, leaf):
        key = str(getattr(path[-1], "key", path[-1]))
        return sds(
            leaf.shape,
            leaf.dtype,
            NamedSharding(mesh, SH.cache_spec(mesh, key, leaf.shape, seq_shard=seq_shard)),
        )

    return jax.tree_util.tree_map_with_path(leaf_spec, cshapes)


def token_specs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> jax.ShapeDtypeStruct:
    b = shape.global_batch
    bsp = SH.batch_spec(mesh, b)
    bax = list(bsp)[0] if len(list(bsp)) else None
    return _with_spec(mesh, (b, 1), jnp.int32, P(bax, None))


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> dict:
    """All ShapeDtypeStruct inputs for the cell's step function."""
    if shape.kind == "train":
        return {
            "params": param_specs(cfg, mesh),
            "opt_state": opt_state_specs(cfg, mesh),
            "batch": batch_specs(cfg, shape, mesh),
        }
    if shape.kind == "prefill":
        return {
            "params": param_specs(cfg, mesh),
            "batch": batch_specs(cfg, shape, mesh),
            "cache": cache_specs(cfg, shape, mesh, seq_shard=True),
        }
    if shape.kind == "decode":
        return {
            "params": param_specs(cfg, mesh),
            "token": token_specs(cfg, shape, mesh),
            "cache": cache_specs(cfg, shape, mesh, seq_shard=True),
        }
    raise ValueError(shape.kind)
