"""Training launcher: elastic LM training on real devices.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --steps 100 \
      [--smoke] [--batch 16] [--seq 128] [--hosts 4]

Uses the reduced (smoke) config by default so it runs on CPU; pass a real
mesh/TPU environment for full configs. Training state is CEP-checkpointed
every --ckpt-every steps and survives host-count changes (see
examples/train_elastic.py for the preemption scenario).
"""
from __future__ import annotations

import argparse
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..checkpoint import store
from ..data import pipeline as dp
from ..models import model as M
from ..train import optimizer as O
from ..train import steps as S


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=configs.ARCH_NAMES)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--full", action="store_true", help="use the full (non-smoke) config")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch) if args.full else configs.get_smoke(args.arch)
    dc = dp.DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch)
    opt = O.OptConfig(total_steps=args.steps)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = O.init_opt_state(params)
    step_fn = jax.jit(S.make_train_step(cfg, opt, microbatches=args.microbatches))
    n = cfg.param_count()
    print(f"arch={cfg.name} params={n/1e6:.1f}M hosts={args.hosts}")
    t0 = time.time()
    for step in range(args.steps):
        shards = [dp.host_batch(dc, step, args.hosts, h) for h in range(args.hosts)]
        batch = {
            "tokens": jnp.asarray(np.concatenate([s["tokens"] for s in shards])),
            "targets": jnp.asarray(np.concatenate([s["targets"] for s in shards])),
        }
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros((args.batch, cfg.num_patches, cfg.d_model))
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model))
        params, state, m = step_fn(params, state, batch)
        if step % 10 == 0:
            print(f"step {step:5d} loss={float(m['loss']):.4f} lr={float(m['lr']):.2e} "
                  f"gnorm={float(m['grad_norm']):.2f} ({(time.time()-t0):.1f}s)")
        if args.ckpt_every and step and step % args.ckpt_every == 0:
            store.save({"params": params, "opt": state}, args.ckpt_dir, step, k_shards=args.hosts)
            print(f"  checkpointed @{step} into {args.hosts} CEP shards")
    print(f"done: final loss {float(m['loss']):.4f} in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
