from . import mesh, sharding  # noqa: F401
