"""Sharding rules: logical-axis assignment with divisibility fallback.

Every rule is a *preference list*; a dimension is sharded on the first mesh
axis (or axis tuple) that divides it, otherwise replicated — so e.g. gemma3's
4 KV heads fall back to replicated on a 16-way model axis while its 10240-wide
FFN shards cleanly. This is what makes one rule set serve all 10 archs.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


GRAPH_AXIS = "graph"  # mesh axis owning graph partitions (DESIGN.md §6)


def graph_axis_size(mesh) -> int:
    """Size of the ``graph`` axis; 1 when the mesh doesn't have one (so a
    mesh-less / single-device run is the degenerate case of the same rules)."""
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get(GRAPH_AXIS, 1))


def padded_partition_count(k: int, g: int) -> int:
    """k rounded up to a multiple of the graph-axis size g. The extra
    partitions are empty (mask 0 everywhere) — k need not equal, divide, or
    exceed the device count."""
    return ((k + g - 1) // g) * g


def partition_device(p: int, g: int) -> int:
    """Round-robin assignment: partition p lives on graph-axis position p % g."""
    return p % g


def partition_row(p: int, k: int, g: int) -> int:
    """Buffer row of partition p in the device-major packed layout.

    NamedSharding over the leading axis gives device d the contiguous row
    block [d·m, (d+1)·m) with m = k_pad/g; storing partition p at row
    (p % g)·m + p // g therefore realizes the round-robin assignment
    device(p) = p % g. With g = 1 this is the identity (row p = partition p),
    which is exactly the single-device pack_ordered layout.
    """
    m = padded_partition_count(k, g) // g
    return (p % g) * m + p // g


def row_partition(r: int, k: int, g: int) -> int:
    """Inverse of partition_row. May return p >= k: that row is a padding
    partition (empty, masked)."""
    m = padded_partition_count(k, g) // g
    return (r % m) * g + r // m


def device_process_map(mesh) -> np.ndarray:
    """(g,) process index of each ``graph``-axis position. All zeros on a
    single-process mesh (and with ``mesh=None``), so single-process is the
    degenerate case of the same per-process accounting."""
    if mesh is None:
        return np.zeros(1, dtype=np.int64)
    devs = np.asarray(mesh.devices).reshape(-1)
    return np.asarray([int(getattr(d, "process_index", 0)) for d in devs], dtype=np.int64)


def partition_process(p: int, mesh) -> int:
    """Process owning partition p: the process of graph-axis position p % g.
    Composes the round-robin partition→device map with the mesh's
    device→process map (multi-host runs: launch/multihost.py)."""
    return int(device_process_map(mesh)[p % graph_axis_size(mesh)])


def edges_spec() -> P:
    """(k_pad, E_max, 2) packed edge buffer: partitions over the graph axis."""
    return P(GRAPH_AXIS, None, None)


def mask_spec() -> P:
    """(k_pad, E_max) validity mask: same leading-axis sharding as edges."""
    return P(GRAPH_AXIS, None)


def vertex_spec() -> P:
    """(V,) vertex state (degrees, ranks, labels): replicated — every device
    scatters into its own copy and the GAS combine is a psum/pmin."""
    return P()


def engine_shardings(mesh: Mesh) -> tuple:
    """NamedShardings for (edges, mask, degrees) of a sharded engine pack."""
    return (
        NamedSharding(mesh, edges_spec()),
        NamedSharding(mesh, mask_spec()),
        NamedSharding(mesh, vertex_spec()),
    )


def _axes_size(mesh: Mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axes, str):
        return sizes[axes]
    return int(np.prod([sizes[a] for a in axes]))


def _shard_if_divisible(mesh: Mesh, dim: int, axes) -> Optional[object]:
    if axes is None:
        return None
    if dim % _axes_size(mesh, axes) == 0:
        return axes
    return None


def batch_axes(mesh: Mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def param_spec(mesh: Mesh, path: tuple, shape: tuple) -> P:
    """PartitionSpec for a parameter leaf, keyed on its pytree path names."""
    names = [str(getattr(p, "key", getattr(p, "name", p))) for p in path]
    leaf = names[-1]
    in_layers = "layers" in names or "encoder" in names
    m = "model"

    def spec(*dims):
        return P(*dims)

    if leaf == "embed":  # (V, D) — shard vocab
        return spec(_shard_if_divisible(mesh, shape[0], m), None)
    if leaf == "lm_head":  # (D, V)
        return spec(None, _shard_if_divisible(mesh, shape[1], m))
    if leaf in ("final_norm", "enc_final_norm"):
        return spec(None)
    L = 1 if in_layers else 0  # layer-stacked leaves carry a leading L dim

    def stacked(*dims):
        return P(*(([None] * L) + list(dims)))

    if leaf in ("wq", "wk", "wv"):  # (L, D, H, hd) — shard the head axis only
        return stacked(None, _shard_if_divisible(mesh, shape[-2], m), None)
    if leaf == "wo":  # (L, H, hd, D)
        return stacked(_shard_if_divisible(mesh, shape[-3], m), None, None)
    if leaf in ("bq", "bk", "bv"):  # (L, H, hd)
        return stacked(_shard_if_divisible(mesh, shape[-2], m), None)
    if leaf in ("q_norm", "k_norm", "ln1", "ln2", "ln_cross", "fuse_attn", "fuse_ssm", "out_norm"):
        return stacked(None)
    if leaf == "router":  # (L, D, E) — replicated (tiny, avoids gather)
        return stacked(None, None)
    if leaf in ("w1", "w3"):
        if len(shape) == 2 + L:  # dense MLP (L, D, F)
            return stacked(None, _shard_if_divisible(mesh, shape[-1], m))
        # MoE (L, E, D, F): expert-parallel over the model axis
        return stacked(_shard_if_divisible(mesh, shape[-3], m), None, None)
    if leaf == "w2":
        if len(shape) == 2 + L:  # (L, F, D)
            return stacked(_shard_if_divisible(mesh, shape[-2], m), None)
        return stacked(_shard_if_divisible(mesh, shape[-3], m), None, None)
    if leaf == "in_proj":  # SSD: replicated on model (split offsets are static)
        return stacked(None, None)
    if leaf in ("conv_w", "a_log", "dt_bias", "d_skip"):
        return stacked(*([None] * (len(shape) - L)))
    if leaf == "out_proj":  # (L, di, D)
        return stacked(None, None)
    return P(*([None] * len(shape)))


def params_shardings(mesh: Mesh, params) -> object:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(mesh, path, leaf.shape)), params
    )


def opt_state_spec(mesh: Mesh, path: tuple, shape: tuple) -> P:
    """ZeRO-1: optimizer moments shard like the param but additionally over the
    data axis on the first already-unsharded dimension that divides."""
    base = param_spec(mesh, path, shape)
    dims = list(base)
    dims += [None] * (len(shape) - len(dims))
    dp = batch_axes(mesh)
    for i, d in enumerate(dims):
        if d is None and shape[i] % _axes_size(mesh, dp) == 0:
            dims[i] = dp if len(dp) > 1 else dp[0]
            break
    return P(*dims)


def batch_spec(mesh: Mesh, batch_size: int) -> P:
    ba = batch_axes(mesh)
    ax = _shard_if_divisible(mesh, batch_size, ba)
    if ax is None and len(ba) > 1:  # try the inner data axis alone
        ax = _shard_if_divisible(mesh, batch_size, ba[-1])
    return P(ax)


def batch_shardings(mesh: Mesh, batch_shapes: dict) -> dict:
    out = {}
    for k, sds in batch_shapes.items():
        bs = sds.shape[0]
        bsp = batch_spec(mesh, bs)
        out[k] = NamedSharding(mesh, P(*(list(bsp) + [None] * (len(sds.shape) - 1))))
    return out


def cache_spec(mesh: Mesh, key: str, shape: tuple, *, seq_shard: bool) -> P:
    """KV-cache shardings: (L, B, Hkv, S, hd). Batch over data axes when it
    divides; sequence over the model axis for SP decode; SSM state over batch."""
    ba = batch_axes(mesh)
    if key == "pos":
        return P()
    if key in ("k", "v", "cross_k", "cross_v"):
        l, b, hkv, s, hd = shape
        bax = _shard_if_divisible(mesh, b, ba)
        seq_axes = None
        if seq_shard:
            if bax is None:
                # batch unshardable (long_500k): put the sequence over everything
                cand = tuple(list(ba) + ["model"])
                seq_axes = _shard_if_divisible(mesh, s, cand) or _shard_if_divisible(mesh, s, "model")
            else:
                seq_axes = _shard_if_divisible(mesh, s, "model")
        return P(None, bax, None, seq_axes, None)
    if key in ("ssm_state", "conv_state"):
        b = shape[1]
        return P(None, _shard_if_divisible(mesh, b, ba), *([None] * (len(shape) - 2)))
    return P(*([None] * len(shape)))


def cache_shardings(mesh: Mesh, cache, *, seq_shard: bool):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh,
            cache_spec(mesh, str(getattr(path[-1], "key", path[-1])), leaf.shape, seq_shard=seq_shard),
        ),
        cache,
    )


def cache_seq_axes(mesh: Mesh, batch_size: int) -> tuple:
    """Axes used for the cache sequence dim in SP decode (must mirror cache_spec)."""
    ba = batch_axes(mesh)
    if batch_size % _axes_size(mesh, ba) == 0:
        return ("model",)
    return tuple(list(ba) + ["model"])
