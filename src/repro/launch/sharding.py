"""Sharding rules: logical-axis assignment with divisibility fallback.

Every rule is a *preference list*; a dimension is sharded on the first mesh
axis (or axis tuple) that divides it, otherwise replicated — so e.g. gemma3's
4 KV heads fall back to replicated on a 16-way model axis while its 10240-wide
FFN shards cleanly. This is what makes one rule set serve all 10 archs.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def _axes_size(mesh: Mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axes, str):
        return sizes[axes]
    return int(np.prod([sizes[a] for a in axes]))


def _shard_if_divisible(mesh: Mesh, dim: int, axes) -> Optional[object]:
    if axes is None:
        return None
    if dim % _axes_size(mesh, axes) == 0:
        return axes
    return None


def batch_axes(mesh: Mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def param_spec(mesh: Mesh, path: tuple, shape: tuple) -> P:
    """PartitionSpec for a parameter leaf, keyed on its pytree path names."""
    names = [str(getattr(p, "key", getattr(p, "name", p))) for p in path]
    leaf = names[-1]
    in_layers = "layers" in names or "encoder" in names
    m = "model"

    def spec(*dims):
        return P(*dims)

    if leaf == "embed":  # (V, D) — shard vocab
        return spec(_shard_if_divisible(mesh, shape[0], m), None)
    if leaf == "lm_head":  # (D, V)
        return spec(None, _shard_if_divisible(mesh, shape[1], m))
    if leaf in ("final_norm", "enc_final_norm"):
        return spec(None)
    L = 1 if in_layers else 0  # layer-stacked leaves carry a leading L dim

    def stacked(*dims):
        return P(*(([None] * L) + list(dims)))

    if leaf in ("wq", "wk", "wv"):  # (L, D, H, hd) — shard the head axis only
        return stacked(None, _shard_if_divisible(mesh, shape[-2], m), None)
    if leaf == "wo":  # (L, H, hd, D)
        return stacked(_shard_if_divisible(mesh, shape[-3], m), None, None)
    if leaf in ("bq", "bk", "bv"):  # (L, H, hd)
        return stacked(_shard_if_divisible(mesh, shape[-2], m), None)
    if leaf in ("q_norm", "k_norm", "ln1", "ln2", "ln_cross", "fuse_attn", "fuse_ssm", "out_norm"):
        return stacked(None)
    if leaf == "router":  # (L, D, E) — replicated (tiny, avoids gather)
        return stacked(None, None)
    if leaf in ("w1", "w3"):
        if len(shape) == 2 + L:  # dense MLP (L, D, F)
            return stacked(None, _shard_if_divisible(mesh, shape[-1], m))
        # MoE (L, E, D, F): expert-parallel over the model axis
        return stacked(_shard_if_divisible(mesh, shape[-3], m), None, None)
    if leaf == "w2":
        if len(shape) == 2 + L:  # (L, F, D)
            return stacked(_shard_if_divisible(mesh, shape[-2], m), None)
        return stacked(_shard_if_divisible(mesh, shape[-3], m), None, None)
    if leaf == "in_proj":  # SSD: replicated on model (split offsets are static)
        return stacked(None, None)
    if leaf in ("conv_w", "a_log", "dt_bias", "d_skip"):
        return stacked(*([None] * (len(shape) - L)))
    if leaf == "out_proj":  # (L, di, D)
        return stacked(None, None)
    return P(*([None] * len(shape)))


def params_shardings(mesh: Mesh, params) -> object:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(mesh, path, leaf.shape)), params
    )


def opt_state_spec(mesh: Mesh, path: tuple, shape: tuple) -> P:
    """ZeRO-1: optimizer moments shard like the param but additionally over the
    data axis on the first already-unsharded dimension that divides."""
    base = param_spec(mesh, path, shape)
    dims = list(base)
    dims += [None] * (len(shape) - len(dims))
    dp = batch_axes(mesh)
    for i, d in enumerate(dims):
        if d is None and shape[i] % _axes_size(mesh, dp) == 0:
            dims[i] = dp if len(dp) > 1 else dp[0]
            break
    return P(*dims)


def batch_spec(mesh: Mesh, batch_size: int) -> P:
    ba = batch_axes(mesh)
    ax = _shard_if_divisible(mesh, batch_size, ba)
    if ax is None and len(ba) > 1:  # try the inner data axis alone
        ax = _shard_if_divisible(mesh, batch_size, ba[-1])
    return P(ax)


def batch_shardings(mesh: Mesh, batch_shapes: dict) -> dict:
    out = {}
    for k, sds in batch_shapes.items():
        bs = sds.shape[0]
        bsp = batch_spec(mesh, bs)
        out[k] = NamedSharding(mesh, P(*(list(bsp) + [None] * (len(sds.shape) - 1))))
    return out


def cache_spec(mesh: Mesh, key: str, shape: tuple, *, seq_shard: bool) -> P:
    """KV-cache shardings: (L, B, Hkv, S, hd). Batch over data axes when it
    divides; sequence over the model axis for SP decode; SSM state over batch."""
    ba = batch_axes(mesh)
    if key == "pos":
        return P()
    if key in ("k", "v", "cross_k", "cross_v"):
        l, b, hkv, s, hd = shape
        bax = _shard_if_divisible(mesh, b, ba)
        seq_axes = None
        if seq_shard:
            if bax is None:
                # batch unshardable (long_500k): put the sequence over everything
                cand = tuple(list(ba) + ["model"])
                seq_axes = _shard_if_divisible(mesh, s, cand) or _shard_if_divisible(mesh, s, "model")
            else:
                seq_axes = _shard_if_divisible(mesh, s, "model")
        return P(None, bax, None, seq_axes, None)
    if key in ("ssm_state", "conv_state"):
        b = shape[1]
        return P(None, _shard_if_divisible(mesh, b, ba), *([None] * (len(shape) - 2)))
    return P(*([None] * len(shape)))


def cache_shardings(mesh: Mesh, cache, *, seq_shard: bool):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh,
            cache_spec(mesh, str(getattr(path[-1], "key", path[-1])), leaf.shape, seq_shard=seq_shard),
        ),
        cache,
    )


def cache_seq_axes(mesh: Mesh, batch_size: int) -> tuple:
    """Axes used for the cache sequence dim in SP decode (must mirror cache_spec)."""
    ba = batch_axes(mesh)
    if batch_size % _axes_size(mesh, ba) == 0:
        return ("model",)
    return tuple(list(ba) + ["model"])
