"""Roofline-term extraction from compiled dry-run artifacts.

  compute  = HLO_FLOPs(per chip) / peak_FLOPs
  memory   = HLO_bytes(per chip) / HBM_bw
  collect. = collective_bytes(per chip, from post-SPMD HLO) / link_bw

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def shape_bytes(shape_str: str) -> int:
    """Sum bytes of every typed shape appearing in the string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> dict:
    """computation name -> list of instruction lines.

    Computation headers sit at column 0: ``%name (args…) -> ret {`` (args may
    contain nested parens for tuple types, so match only the name prefix).
    """
    comps: dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        s = line.rstrip()
        if s and not s.startswith(" ") and s.endswith("{"):
            m = re.match(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(", s)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if s.strip() == "}" and not s.startswith("  "):
                cur = None
            else:
                comps[cur].append(s.strip())
    return comps


_TRIP_RE = re.compile(r"compare\([^)]*\).*direction=LT")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _trip_count(cond_lines: list) -> int:
    """Scan-lowered while conditions compare a counter to a constant bound.
    Take the largest plausible (≤10^6) integer constant in the condition."""
    bound = None
    for ln in cond_lines:
        if "constant(" in ln:
            m = _CONST_RE.search(ln)
            if m and int(m.group(1)) <= 1_000_000:
                bound = max(bound or 0, int(m.group(1)))
    return bound if bound else 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind result bytes per chip, *weighted by loop trip
    counts* (XLA HLO text nests scan bodies as named computations that run
    trip-count times; a flat line count would undercount by ~num_layers)."""
    comps = _split_computations(hlo_text)
    op_re = re.compile(r"\b(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(")
    while_re = re.compile(r"while\(.*\).*condition=%?([\w\.\-]+),.*body=%?([\w\.\-]+)")

    def direct(comp: str) -> dict:
        out = {k: 0 for k in _COLLECTIVES}
        counts = {k: 0 for k in _COLLECTIVES}
        for ls in comps.get(comp, ()):
            if "=" not in ls:
                continue
            _, rhs = ls.split("=", 1)
            m = op_re.search(rhs)
            if not m:
                continue
            op, suffix = m.group(1), m.group(2)
            if suffix == "-done":
                continue
            out[op] += shape_bytes(rhs[: m.start()])
            counts[op] += 1
        return out, counts

    memo: dict[str, dict] = {}

    def total(comp: str, depth=0) -> dict:
        if comp in memo:
            return memo[comp]
        if depth > 20:
            return {k: 0 for k in _COLLECTIVES}
        out, _ = direct(comp)
        for ls in comps.get(comp, ()):
            wm = while_re.search(ls)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                sub = total(body, depth + 1)
                for k in _COLLECTIVES:
                    out[k] += trips * sub[k]
                continue
            # calls / conditionals: count called computations once
            cm = re.search(r"(?:calls|branch_computations)=[{]?%?([\w\.\-,% ]+)", ls)
            if cm and "fusion" not in ls:
                for callee in re.findall(r"%?([\w\.\-]+)", cm.group(1)):
                    if callee in comps and callee != comp:
                        sub = total(callee, depth + 1)
                        for k in _COLLECTIVES:
                            out[k] += sub[k]
        memo[comp] = out
        return out

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    agg = total(entry) if entry else {k: 0 for k in _COLLECTIVES}
    _, entry_counts = direct(entry) if entry else ({}, {k: 0 for k in _COLLECTIVES})
    agg["total"] = sum(agg[k] for k in _COLLECTIVES)
    agg["counts"] = entry_counts
    return agg


@dataclasses.dataclass(frozen=True)
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    chips: int
    model_flops_global: float

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips) — fraction of compiled compute
        that is 'useful' model math (catches remat/redundancy waste)."""
        total = self.flops_per_chip * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def mfu_upper_bound(self) -> float:
        """Model FLOPs / (chips × peak × step-time lower bound)."""
        denom = self.chips * PEAK_FLOPS * self.step_time_lower_bound
        return self.model_flops_global / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "chips": self.chips,
            "model_flops_global": self.model_flops_global,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_upper_bound": self.mfu_upper_bound,
        }


def _attn_context(cfg, s: int) -> float:
    """Mean effective context length per query across layers (windowed layers
    attend to ≤ window tokens; causal global layers to s/2 on average)."""
    total = 0.0
    for i in range(cfg.num_layers):
        kind = cfg.block_kind(i)
        if kind == "m":
            total += 0.0
            continue
        w = cfg.window if (kind == "l" and cfg.window) else None
        if kind == "h" and cfg.window:
            w = cfg.window if cfg.layer_pattern[i % len(cfg.layer_pattern)] == "l" else None
        total += min(w, s) if w else s / 2.0
    return total / max(cfg.num_layers, 1)


def analytic_costs(cfg, shape, chips: int, *, microbatches: int = 1, model_shards: int = 16,
                   param_bytes: int = 2) -> dict:
    """Structural FLOP/byte model (trip-count exact, unlike XLA:CPU
    cost_analysis which visits scan bodies once — see EXPERIMENTS.md §Dry-run).

    FLOPs: matmul-dominated 2·N·token (+attention 4·B·H·hd·S·ctx per layer
    fwd), train = fwd + remat-fwd + 2×bwd = 4× fwd. Bytes: parameter +
    optimizer + activation + cache traffic with documented coefficients.
    """
    s = shape.seq_len
    b = shape.global_batch
    n_act = cfg.active_param_count()
    n_tot = cfg.param_count()
    l = cfg.num_layers
    d = cfg.d_model

    is_attn = cfg.family != "ssm"
    ctx = _attn_context(cfg, s)
    hq_hd = cfg.num_heads * cfg.head_dim

    if shape.kind == "train":
        tokens = b * s
        fwd = 2.0 * n_act * tokens + (4.0 * tokens * hq_hd * ctx * l if is_attn else 0.0)
        # SSD flops (mamba/hybrid): ~2·(intra-chunk + state) per token.
        if cfg.ssm_state:
            fwd += 6.0 * tokens * cfg.d_inner * cfg.ssm_state * l
        flops = 4.0 * fwd  # fwd + remat-fwd + 2×bwd
    elif shape.kind == "prefill":
        tokens = b * s
        flops = 2.0 * n_act * tokens + (4.0 * tokens * hq_hd * ctx * l if is_attn else 0.0)
        if cfg.ssm_state:
            flops += 6.0 * tokens * cfg.d_inner * cfg.ssm_state * l
    else:  # decode: one token per sequence
        tokens = b
        ctx_dec = 0.0
        for i in range(l):
            kind = cfg.block_kind(i)
            if kind == "m":
                continue
            w = cfg.window if (kind == "l" and cfg.window) else None
            ctx_dec += min(w, s) if w else s
        flops = 2.0 * n_act * tokens + 4.0 * tokens * hq_hd * ctx_dec
        if cfg.ssm_state:
            flops += 6.0 * tokens * cfg.d_inner * cfg.ssm_state * l

    flops_per_chip = flops / chips

    # --- HBM traffic per chip ---
    p_local = n_tot * param_bytes / model_shards  # params replicated over data
    data_shards = max(chips // model_shards, 1)
    if shape.kind == "train":
        opt_local = n_tot * 8 / chips  # ZeRO-1 f32 moments over all chips
        grad_local = n_tot * 4 / model_shards
        tokens_local = b * s / data_shards
        # params: read fwd + remat + bwd; grads: write+read; opt: m,v r/w + p write
        param_traffic = 3 * p_local + 3 * grad_local + 5 * opt_local
        act_traffic = tokens_local * d * 2 * l * 6  # ~6 tensor r/w per layer, bf16
        bytes_per_chip = param_traffic + act_traffic
    elif shape.kind == "prefill":
        tokens_local = b * s / data_shards
        bytes_per_chip = p_local + tokens_local * d * 2 * l * 4
        cache_local = l * b * cfg.num_kv_heads * s * cfg.head_dim * 2 * 2 / chips
        bytes_per_chip += cache_local
    else:
        cache_local = l * b * cfg.num_kv_heads * s * cfg.head_dim * 2 * 2 / chips
        state_local = (
            l * b * (cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state + 3 * (cfg.d_inner))
            * 4 / max(data_shards, 1) if cfg.ssm_state else 0.0
        )
        bytes_per_chip = p_local + cache_local + state_local
    return {"flops_per_chip": flops_per_chip, "hbm_bytes_per_chip": bytes_per_chip}


def model_flops(cfg, shape) -> float:
    """Useful model FLOPs: 6·N·D train / 2·N·D forward (MoE: N_active) plus a
    *window-aware* attention term (same context accounting as analytic_costs,
    so the useful-FLOPs ratio isolates remat/redundancy waste — 0.75 for
    full-remat training, 1.0 for inference — rather than window effects)."""
    n = cfg.active_param_count()
    s = shape.seq_len
    l = cfg.num_layers
    hq_hd = cfg.num_heads * cfg.head_dim
    is_attn = cfg.family != "ssm"
    if shape.kind in ("train", "prefill"):
        tokens = shape.global_batch * s
        ctx = _attn_context(cfg, s)
        fwd = 2.0 * n * tokens + (4.0 * tokens * hq_hd * ctx * l if is_attn else 0.0)
        if cfg.ssm_state:
            fwd += 6.0 * tokens * cfg.d_inner * cfg.ssm_state * l
        return 3.0 * fwd if shape.kind == "train" else fwd
    tokens = shape.global_batch
    ctx_dec = 0.0
    for i in range(l):
        kind = cfg.block_kind(i)
        if kind == "m":
            continue
        w = cfg.window if (kind == "l" and cfg.window) else None
        ctx_dec += min(w, s) if w else s
    fwd = 2.0 * n * tokens + 4.0 * tokens * hq_hd * ctx_dec
    if cfg.ssm_state:
        fwd += 6.0 * tokens * cfg.d_inner * cfg.ssm_state * l
    return fwd
